"""Sharding resolver: fallback chains, priorities, divisibility."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import resolve_spec, use_mesh
from repro.distributed.params import ParamSpec, abstract_params
from repro.configs import get_config
from repro.models import model_specs


def fake_mesh(data=16, model=16):
    """Abstract mesh over fake devices (no jax device allocation)."""
    devs = np.empty((data, model), dtype=object)
    for i in range(data):
        for j in range(model):
            devs[i, j] = jax.devices()[0]
    return Mesh(devs, ("data", "model"))


# resolve_spec math only needs axis sizes -> use a real 1-device mesh
# reshaped logically via a stub ctx.
class Ctx:
    def __init__(self, sizes):
        self.sizes = sizes
        from repro.distributed.sharding import DEFAULT_RULES
        self.rules = dict(DEFAULT_RULES)
        self.mesh = type("M", (), {"axis_names": tuple(sizes)})()

    def axis_size(self, name):
        return self.sizes[name]


CTX = Ctx({"data": 16, "model": 16})


def test_divisible_heads_take_model():
    spec = resolve_spec((32, 16, 4096, 128), ("batch", "kv_heads", None,
                                              None), CTX)
    assert spec == P("data", "model")


def test_nondivisible_heads_fall_back_to_seq():
    # granite: kv=8 not divisible by 16 -> cache seq picks up model
    spec = resolve_spec((128, 8, 32768, 64),
                        ("batch", "kv_heads", "kv_seq", None), CTX)
    assert spec == P("data", None, "model")


def test_experts_fallback_to_moe_d():
    # granite w_gate (E=40, d, f): experts fail, d takes model
    spec = resolve_spec((40, 1536, 512), ("experts", "moe_d", "mlp"), CTX)
    assert spec == P(None, "model")
    # moonshot w_gate (E=64, d, f): true EP; d falls to data (FSDP)
    spec = resolve_spec((64, 2048, 1408), ("experts", "moe_d", "mlp"), CTX)
    assert spec == P("model", "data")


def test_priority_moe_d_beats_mlp_on_w_down():
    spec = resolve_spec((40, 512, 1536), ("experts", "mlp", "moe_d"), CTX)
    assert spec == P(None, None, "model")


def test_vocab_fallback_ce_seq():
    # granite vocab 49155: ce_seq takes model instead
    spec = resolve_spec((256, 256, 49155), ("batch", "ce_seq", "vocab"),
                        CTX)
    assert spec == P("data", "model")
    # gemma vocab 262144 divisible: vocab wins, ce_seq replicated
    spec = resolve_spec((256, 256, 262144), ("batch", "ce_seq", "vocab"),
                        CTX)
    assert spec == P("data", None, "model")


def test_no_mesh_axis_used_twice():
    spec = resolve_spec((64, 64, 64), ("mlp", "qkv", "kv"), CTX)
    taken = [s for s in (spec + (None,) * 3)[:3] if s is not None]
    assert len(taken) == len(set(taken)) <= 1


def test_no_ctx_is_noop():
    assert resolve_spec((4, 4), ("batch", "mlp"), None) == P()


@pytest.mark.parametrize("arch", ["granite-moe-3b-a800m", "deepseek-67b"])
def test_abstract_params_have_shardings(arch):
    from repro.distributed.sharding import ShardingCtx
    mesh = fake_mesh()
    ctx = ShardingCtx(mesh=mesh)
    tree = abstract_params(model_specs(get_config(arch)), ctx)
    leaves = jax.tree.leaves(tree)
    assert all(l.sharding is not None for l in leaves)
    # at least half the parameter BYTES are sharded over >1 device
    def nshards(l):
        spec = l.sharding.spec
        n = 1
        for s in spec:
            if s is None:
                continue
            for ax in (s if isinstance(s, tuple) else (s,)):
                n *= dict(zip(mesh.axis_names, mesh.devices.shape))[ax]
        return n
    sharded = sum(l.size for l in leaves if nshards(l) >= 16)
    total = sum(l.size for l in leaves)
    assert sharded / total > 0.5
