"""The ``llm`` workload generator: determinism, prefill/decode token
accounting, replica economics, and cold-start metering."""
import math

import pytest

from repro.configs import get_config
from repro.core.containers import ContainerPool
from repro.core.metrics import collect
from repro.core.policies import FIFO
from repro.serving.llm import (LLMSpec, approx_param_bytes, llm_requests,
                               llm_workload, request_chunks)
from repro.serving.request import service_ms
from repro.traces import TraceSpec

TR = TraceSpec(minutes=1, invocations_per_min=200, n_functions=10, seed=5)
SPEC = LLMSpec(model="deepseek-7b")


def _stream(tasks):
    return [(t.tid, t.arrival, t.service, t.mem_mb, t.func_id)
            for t in tasks]


def test_llm_workload_deterministic():
    a, meta_a = llm_workload(SPEC, TR)
    b, meta_b = llm_workload(SPEC, TR)
    assert _stream(a) == _stream(b)
    assert meta_a == meta_b
    # a different trace seed must change the stream
    c, _ = llm_workload(SPEC, TraceSpec(minutes=1, invocations_per_min=200,
                                        n_functions=10, seed=6))
    assert _stream(a) != _stream(c)


def test_llm_workload_canonical_tids():
    tasks, _ = llm_workload(SPEC, TR)
    assert [t.tid for t in tasks] == list(range(len(tasks)))
    arrivals = [t.arrival for t in tasks]
    assert arrivals == sorted(arrivals)


def test_requests_match_request_spec():
    cfg = get_config("deepseek-7b")
    reqs = llm_requests(SPEC, TR)
    assert reqs
    for r in reqs:
        assert r.decode_tokens >= 1
        # prompt = U(2, 8) x decode, capped
        assert r.prompt_tokens <= min(8.0 * r.decode_tokens,
                                      SPEC.max_prompt)
        assert r.mem_gb == pytest.approx(SPEC.replica_mem_mb() / 1024.0)
    # service calibration: decode budget derived from the trace service
    assert any(r.decode_tokens > 1 for r in reqs)
    assert cfg.ms_per_token_decode > 0


def test_chunks_partition_service_time_exactly():
    """The chunk services must partition the request's modelled service
    time: chunking changes scheduling granularity, never total work."""
    cfg = get_config("deepseek-7b")
    for req in llm_requests(SPEC, TR)[:50]:
        chunks = request_chunks(cfg, SPEC, req)
        total = math.fsum(t.service for t in chunks)
        want = service_ms(cfg, req.prompt_tokens, req.decode_tokens)
        assert math.isclose(total, want, rel_tol=1e-9), (total, want)
        # prefill task carries exactly the prefill share
        if req.prompt_tokens > 0:
            assert chunks[0].service == pytest.approx(
                service_ms(cfg, req.prompt_tokens, 0))
        # no decode chunk exceeds the configured slice
        cap = SPEC.decode_chunk_tokens * cfg.ms_per_token_decode
        for t in chunks[1:]:
            assert t.service <= cap + 1e-9
        # ideal streaming cadence: chunk k arrives when chunk k-1's
        # tokens could first exist
        for a, b in zip(chunks, chunks[1:]):
            assert b.arrival == pytest.approx(a.arrival + a.service)


def test_whole_decode_single_task():
    cfg = get_config("deepseek-7b")
    spec = LLMSpec(model="deepseek-7b", decode_chunk_tokens=0)
    req = llm_requests(spec, TR)[0]
    chunks = request_chunks(cfg, spec, req)
    assert len(chunks) == (2 if req.prompt_tokens > 0 else 1)


def test_replica_economics():
    cfg = get_config("deepseek-7b")
    params_b = approx_param_bytes(cfg) / 1e9
    assert 10.0 < params_b < 20.0          # ~13.8 GB bf16 for a 7B
    assert SPEC.replica_mem_mb() > params_b * 1000.0 / 1.1
    # cold = weight stream + compile
    assert SPEC.cold_start_ms() == pytest.approx(
        params_b / SPEC.weight_gbps * 1000.0 + SPEC.compile_ms)
    cs = SPEC.container_spec()
    assert cs.cold_base_ms == pytest.approx(SPEC.cold_start_ms())
    assert cs.cold_per_gb_ms == 0.0
    assert cs.capacity_mb == pytest.approx(
        SPEC.warm_replicas * SPEC.replica_mem_mb())


def test_cold_metered_once_per_replica_instantiation():
    """Two back-to-back requests against the same endpoint inside the
    keep-alive window must pay ONE weight-load+compile: the second hits
    the warm replica."""
    cfg = get_config("deepseek-7b")
    reqs = llm_requests(SPEC, TR)
    req = reqs[0]
    chunks = request_chunks(cfg, SPEC, req)
    # a follow-up request for the same endpoint, shortly after
    last_end = chunks[-1].arrival + chunks[-1].service
    import dataclasses
    req2 = dataclasses.replace(req, rid=req.rid + 1,
                               arrival_ms=last_end + 1000.0)
    tasks = chunks + request_chunks(cfg, SPEC, req2)
    for i, t in enumerate(tasks):
        t.tid = i
    spec_cfg = dataclasses.replace(SPEC.container_spec().to_config(),
                                   cold_jitter=0.0)
    pool = ContainerPool(spec_cfg, seed=0)
    # one lane: every chunk serializes onto the same replica (a second
    # core would legitimately instantiate a second lane while the first
    # chunk still holds its sandbox)
    sched = FIFO(n_cores=1, containers=pool)
    sched.run(tasks)
    res = collect(sched, "fifo")
    stats = pool.stats()
    assert stats["cold_starts"] == 1
    cold_tasks = [t for t in res.tasks if t.cold_start]
    assert len(cold_tasks) == 1
    # the cold chunk's billed span carries the full load+compile
    assert cold_tasks[0].init_ms == pytest.approx(SPEC.cold_start_ms())
    assert cold_tasks[0].execution >= SPEC.cold_start_ms()


def test_meta_counts_requests_not_chunks():
    tasks, meta = llm_workload(SPEC, TR)
    assert meta["n_chunks"] == len(tasks)
    assert meta["n_requests"] < meta["n_chunks"]
    assert meta["n_requests"] == len(llm_requests(SPEC, TR))
    assert meta["model"] == "deepseek-7b"


def test_scenario_llm_summary_uses_request_denominator():
    from repro import (FleetSpec, PolicySpec, Scenario, WorkloadSpec,
                      run)
    res = run(Scenario(
        workload=WorkloadSpec(kind="llm", trace=TR, llm=SPEC),
        fleet=FleetSpec(n_nodes=2, cores_per_node=8,
                        dispatcher="least_loaded", seed=1),
        policy=PolicySpec(name="hybrid")))
    s = res.summary()
    assert s["workload"] == "llm"
    assert s["n"] == res.meta["n_chunks"]
    assert s["n_requests"] == res.meta["n_requests"] < s["n"]
    assert s["usd_per_1k_requests"] == pytest.approx(
        s["total_cost_usd"] / s["n_requests"] * 1000.0)
    assert s["cold_starts"] > 0      # replicas instantiated lazily
