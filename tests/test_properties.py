"""Property-based tests (hypothesis): scheduler + cost invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="install the [test] extra for property tests")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import run_policy
from repro.core.containers import ContainerConfig, ContainerPool
from repro.core.cost import cost_ladder, invocation_cost_usd
from repro.core.events import Task
from repro.core.hybrid import percentile

task_lists = st.lists(
    st.tuples(st.floats(0, 5_000), st.floats(0.5, 3_000)),
    min_size=1, max_size=60,
)


def _mk(specs):
    return [Task(tid=i, arrival=a, service=s, deadline=a + 2 * s)
            for i, (a, s) in enumerate(specs)]


@settings(max_examples=25, deadline=None)
@given(task_lists, st.sampled_from(["fifo", "cfs", "hybrid", "rr", "edf"]))
def test_scheduler_invariants(specs, policy):
    tasks = _mk(specs)
    res = run_policy(policy, tasks, n_cores=4)
    # no task lost, none duplicated
    assert len(res.tasks) == len(tasks)
    assert sorted(t.tid for t in res.tasks) == list(range(len(tasks)))
    for t in res.tasks:
        assert t.completion >= t.arrival
        assert t.first_run >= t.arrival - 1e-6
        assert t.response >= -1e-6
        # execution can never beat pure service time
        assert t.execution >= t.service - 1e-6
        assert t.remaining <= 1e-6


@settings(max_examples=25, deadline=None)
@given(task_lists)
def test_fifo_is_execution_optimal(specs):
    tasks = _mk(specs)
    res = run_policy("fifo", tasks, n_cores=4, ctx_switch_ms=0.0)
    for t in res.tasks:
        assert t.execution == np.float64(t.service) or \
            abs(t.execution - t.service) < 1e-6


@settings(max_examples=25, deadline=None)
@given(task_lists)
def test_work_conservation_no_idle_with_backlog(specs):
    """Makespan >= total work / cores (no scheduler can beat it)."""
    tasks = _mk(specs)
    res = run_policy("fifo", tasks, n_cores=4, ctx_switch_ms=0.0)
    lower = sum(t.service for t in tasks) / 4
    makespan = max(t.completion for t in res.tasks)
    assert makespan >= lower - 1e-6


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0.1, 1e6), min_size=1, max_size=200),
       st.floats(0, 100))
def test_percentile_bounds(vals, pct):
    v = sorted(vals)
    p = percentile(v, pct)
    assert v[0] - 1e-9 <= p <= v[-1] + 1e-9


@settings(max_examples=50, deadline=None)
@given(st.floats(1.0, 1e7), st.sampled_from([128, 256, 512, 1024, 10240]))
def test_cost_monotone_in_duration_and_memory(ms, mem):
    c1 = invocation_cost_usd(ms, mem)
    assert c1 > 0
    assert invocation_cost_usd(ms * 2, mem) > c1
    assert invocation_cost_usd(ms, mem * 2) > c1


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(1.0, 1e5), min_size=1, max_size=50))
def test_cost_ladder_ordering(execs):
    ladder = cost_ladder(execs)
    sizes = sorted(ladder)
    for a, b in zip(sizes, sizes[1:]):
        assert ladder[a] < ladder[b]


# -- container pool invariants ------------------------------------------------
#
# An op sequence is (dt, func_id, mem, kind): kind 0 = acquire+release
# (an instantaneous invocation), 1 = acquire only (container leaves the
# pool and never returns: invocation still running at horizon), 2 =
# reaper sweep, 3 = speculative pre-warm (provider-initiated placement),
# 4 = flush (decommission / chaos warm-pool wipe). Time advances
# monotonically by dt.

pool_ops = st.lists(
    st.tuples(st.floats(0.0, 10_000.0), st.integers(0, 6),
              st.sampled_from([128, 256, 512, 1024]),
              st.integers(0, 4)),
    min_size=1, max_size=80,
)
pool_cfgs = st.builds(
    ContainerConfig,
    capacity_mb=st.sampled_from([256.0, 1024.0, 4096.0]),
    policy=st.sampled_from(["fixed", "histogram"]),
    keepalive_ms=st.sampled_from([500.0, 5_000.0, 60_000.0]),
)


def _drive(pool: ContainerPool, ops):
    """Apply an op sequence; returns a trace of observable outcomes."""
    now, trace = 0.0, []
    for dt, fid, mem, kind in ops:
        now += dt
        if kind == 2:
            trace.append(("sweep", pool.evict_expired(now)))
            continue
        if kind == 3:
            trace.append(("prewarm", pool.prewarm(fid, mem, now, n=2)))
            pool.check_invariants()
            continue
        if kind == 4:
            trace.append(("flush", pool.flush(now)))
            pool.check_invariants()
            continue
        hit = pool.acquire(fid, mem, now)
        trace.append(("hit", hit))
        if kind == 0:
            pool.release(fid, mem, now)
        pool.check_invariants()
    pool.settle(now)
    trace.append(("stats", tuple(sorted(pool.stats().items()))))
    return trace


@settings(max_examples=40, deadline=None)
@given(pool_cfgs, pool_ops, st.integers(0, 3))
def test_container_pool_invariants(cfg, ops, seed):
    """Capacity is never exceeded, accounting never drifts, hit/miss
    counters reconcile, and the run is deterministic under a seed."""
    pool = ContainerPool(cfg, seed=seed)
    trace = _drive(pool, ops)
    n_acquires = sum(1 for _, _, _, kind in ops if kind in (0, 1))
    assert pool.warm_hits + pool.cold_starts == n_acquires
    assert pool.idle_mb <= cfg.capacity_mb + 1e-6
    assert pool.warm_mb_ms >= 0.0
    # determinism: same seed + same ops -> identical observable trace
    assert _drive(ContainerPool(cfg, seed=seed), ops) == trace


@settings(max_examples=40, deadline=None)
@given(pool_cfgs, pool_ops, st.integers(0, 3))
def test_deferred_releases_match_direct_releases(cfg, ops, seed):
    """Routing releases through the release_at buffer (times already
    monotone, as the event path guarantees) is observably identical to
    direct release calls — the deferred path is pure re-serialization,
    never a semantic fork. Also exercises the tombstone-compaction
    bound via check_invariants inside _drive."""
    direct = ContainerPool(cfg, seed=seed)
    trace = _drive(direct, ops)
    buffered = ContainerPool(cfg, seed=seed)
    now, btrace, tid = 0.0, [], 0
    for dt, fid, mem, kind in ops:
        now += dt
        if kind == 2:
            btrace.append(("sweep", buffered.evict_expired(now)))
            continue
        if kind == 3:
            btrace.append(("prewarm", buffered.prewarm(fid, mem, now, n=2)))
            buffered.check_invariants()
            continue
        if kind == 4:
            btrace.append(("flush", buffered.flush(now)))
            buffered.check_invariants()
            continue
        btrace.append(("hit", buffered.acquire(fid, mem, now)))
        if kind == 0:
            buffered.release_at(fid, mem, now, tid)
            tid += 1
        buffered.check_invariants()
    buffered.settle(now)
    btrace.append(("stats", tuple(sorted(buffered.stats().items()))))
    assert btrace == trace


@settings(max_examples=40, deadline=None)
@given(st.floats(0.0, 20_000.0), st.floats(0.0, 20_000.0))
def test_no_warm_hit_after_keepalive_expiry(idle_gap, ttl):
    pool = ContainerPool(ContainerConfig(keepalive_ms=ttl), seed=0)
    pool.acquire(1, 256, 0.0)
    pool.release(1, 256, 100.0)
    hit = pool.acquire(1, 256, 100.0 + idle_gap)
    # Oracle on the SUMMED floats, exactly as the pool compares them —
    # `idle_gap < ttl` disagrees on half-ulp pairs where both sums
    # round to the same value.
    assert hit == (100.0 + idle_gap < 100.0 + ttl)
    pool.check_invariants()


# -- correlated-failure topology (DESIGN.md Sec. 17) ---------------------------

@st.composite
def chaos_and_retry(draw):
    """A randomized correlated chaos schedule plus a retry policy."""
    from repro.cluster import ChaosEvent, ChaosSchedule, RetryPolicy
    events = []
    for _ in range(draw(st.integers(min_value=1, max_value=4))):
        t = draw(st.floats(min_value=1_000.0, max_value=50_000.0))
        action = draw(st.sampled_from(
            ("kill_zone", "kill_rack", "revoke_spot", "degrade",
             "restore", "heal")))
        kw = {}
        if action in ("kill_zone", "degrade", "restore"):
            kw["zone"] = draw(st.sampled_from(("z0", "z1")))
        if action == "kill_rack":
            kw["rack"] = draw(st.sampled_from(
                ("z0-r0", "z0-r1", "z1-r0", "z1-r1")))
        if action == "degrade":
            kw["severity"] = draw(st.floats(min_value=0.1, max_value=0.9))
        events.append(ChaosEvent(t=t, action=action, **kw))
    budget = draw(st.integers(min_value=0, max_value=6))
    seed = draw(st.integers(min_value=0, max_value=3))
    return (ChaosSchedule(events=tuple(events), heal_spec="hybrid"),
            RetryPolicy(budget=budget, base_ms=50.0, cap_ms=2_000.0),
            seed)


@settings(max_examples=15, deadline=None)
@given(chaos_and_retry())
def test_chaos_partitions_arrivals_and_bounds_retries(params):
    """Under ANY correlated schedule: completed + shed partitions the
    arrival set, retries never exceed the budget, and the same seed +
    schedule roll up bit-identically."""
    import json

    from repro.cluster import ClusterSim, TopologySpec

    chaos, policy, seed = params
    topo = TopologySpec(zones=("z0", "z1"), racks_per_zone=2,
                        nodes_per_rack=1,
                        sku_pattern=("std", "spot", "std", "spot"))
    tasks = _mk([(i * 40.0, 300.0) for i in range(60)])
    for i, t in enumerate(tasks):
        t.func_id = i % 7

    def go():
        import copy
        sim = ClusterSim(cores_per_node=2, node_policies="hybrid",
                         seed=seed,
                         containers=ContainerConfig(keepalive_ms=30_000.0,
                                                    cold_jitter=0.0),
                         topology=topo)
        res = sim.run(copy.deepcopy(tasks), chaos=chaos, retry=policy)
        return sim, res

    sim, res = go()
    done = {t.tid for t in res.tasks}
    shed = {t.tid for t in sim.shed}
    assert done.isdisjoint(shed)
    assert done | shed == {t.tid for t in tasks}
    assert all(t.retries <= policy.budget
               for t in list(res.tasks) + list(sim.shed))
    _, res2 = go()
    assert json.dumps(res.summary(), sort_keys=True) == \
        json.dumps(res2.summary(), sort_keys=True)
