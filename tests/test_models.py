"""Per-arch smoke tests + prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke, SHAPES, \
    shape_applicable
from repro.distributed import materialize
from repro.models import LM, cache_specs, model_specs

KEY = jax.random.PRNGKey(0)

# Full-model forward/backward passes dominate suite wall-clock (~110 s);
# the default tier must stay fast enough to run on every change.
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_smoke(arch)
            params = materialize(model_specs(cfg), KEY)
            cache[arch] = (cfg, LM(cfg), params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_shapes_and_finite(built, arch):
    cfg, lm, params = built(arch)
    B, S = 2, 32
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    loss = lm.loss(params, toks, jnp.roll(toks, -1, 1))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    logits = lm.logits_train(params, toks)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(built, arch):
    """Teacher-forced decode must reproduce the parallel (train) logits —
    exercises every cache type incl. ring buffers and shared-attn KV.
    Runs in f32 compute so the check isolates LOGIC errors from bf16
    drift (production uses bf16)."""
    from repro.models.layers import set_compute_dtype
    cfg, lm, params = built(arch)
    B, S, extra = 2, 32, 4
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + extra),
                              0, cfg.vocab)
    set_compute_dtype(jnp.float32)
    try:
        full = lm.logits_train(params, toks)       # (B, S+extra, V)
        logits_p, cache = lm.prefill(params, toks[:, :S],
                                     max_len=S + extra)
        np.testing.assert_allclose(
            np.array(logits_p[:, 0]), np.array(full[:, S - 1]),
            rtol=2e-3, atol=2e-3)
        pos = jnp.full((B,), S, jnp.int32)
        for i in range(extra):
            logits_d, cache = lm.decode_step(params, toks[:, S + i],
                                             cache, pos + i)
            np.testing.assert_allclose(
                np.array(logits_d[:, 0]), np.array(full[:, S + i]),
                rtol=2e-3, atol=2e-3)
    finally:
        set_compute_dtype(jnp.bfloat16)


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_specs_match_runtime_cache(built, arch):
    cfg, lm, params = built(arch)
    B, S = 2, 32
    specs = cache_specs(cfg, B, S)
    toks = jax.random.randint(KEY, (B, S // 2), 0, cfg.vocab)
    _, cache = lm.prefill(params, toks, max_len=S)
    spec_shapes = jax.tree.map(
        lambda p: tuple(p.shape), specs,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "shape"))
    got_shapes = jax.tree.map(lambda a: tuple(a.shape), cache)
    assert jax.tree.leaves(spec_shapes) == jax.tree.leaves(got_shapes)


def test_full_configs_match_pool_spec():
    spec = {
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    }
    for arch, (L, d, H, KV, ff, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, H, KV, ff, V), arch
    assert get_config("granite-moe-3b-a800m").n_experts == 40
    assert get_config("granite-moe-3b-a800m").top_k == 8
    assert get_config("moonshot-v1-16b-a3b").n_experts == 64
    assert get_config("moonshot-v1-16b-a3b").top_k == 6
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("qwen2-vl-2b").mrope


def test_long_500k_applicability_rules():
    ok = {a: shape_applicable(get_config(a), SHAPES["long_500k"])[0]
          for a in ARCHS}
    assert ok["rwkv6-1.6b"] and ok["zamba2-1.2b"]
    assert ok["gemma3-27b"] and ok["gemma3-12b"]
    for a in ("granite-moe-3b-a800m", "moonshot-v1-16b-a3b",
              "qwen2-vl-2b", "deepseek-67b", "deepseek-7b",
              "musicgen-large"):
        assert not ok[a], a
