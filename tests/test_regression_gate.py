"""CI benchmark-regression gate: compare logic and CLI exit codes."""
import importlib.util
import json
from pathlib import Path

# The gate is stdlib-only and must stay importable outside the
# installed package (CI invokes it before any editable install of
# benchmarks/ exists), so load it by path.
_SPEC = importlib.util.spec_from_file_location(
    "regression_gate",
    Path(__file__).resolve().parent.parent / "benchmarks"
    / "regression_gate.py")
gate = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(gate)


def _row(cost=1.0, n=100, makespan=10.0, **kw):
    base = {"node_policy": "hybrid", "dispatcher": "warm_affinity",
            "n_nodes": 4, "load_scale": 1.0, "containers": "fixed",
            "cost_usd": cost, "n": n, "makespan_s": makespan}
    base.update(kw)
    return base


def test_gate_passes_identical_runs():
    rows = [_row(), _row(dispatcher="least_loaded")]
    failures, notes = gate.compare(rows, rows, 0.15)
    assert failures == []
    assert any("2 shared cells" in n for n in notes)


def test_gate_flags_cost_and_throughput_regressions():
    prev = [_row()]
    worse_cost = [_row(cost=1.5)]
    failures, _ = gate.compare(prev, worse_cost, 0.15)
    assert len(failures) == 1 and "cost_usd" in failures[0]
    worse_tp = [_row(makespan=20.0)]  # throughput halves
    failures, _ = gate.compare(prev, worse_tp, 0.15)
    assert len(failures) == 1 and "throughput" in failures[0]
    # within tolerance: no failure
    failures, _ = gate.compare(prev, [_row(cost=1.1)], 0.15)
    assert failures == []


def test_gate_skips_cells_present_on_one_side_only():
    prev = [_row(), _row(dispatcher="affinity", cost=1.0)]
    new = [_row(cost=0.9), _row(dispatcher="cost_aware", cost=50.0)]
    failures, notes = gate.compare(prev, new, 0.15)
    assert failures == []
    assert sum("skipped" in n for n in notes) == 2


def test_gate_fails_when_schema_drift_disables_an_axis():
    """Shared cells whose metric keys vanished (renamed cost_usd /
    makespan_s) must FAIL the gate per axis, not silently pass it."""
    both_gone = [{k: v for k, v in _row().items()
                  if k not in ("cost_usd", "makespan_s")}]
    failures, _ = gate.compare(both_gone, both_gone, 0.15)
    assert len(failures) == 2
    assert all("schema" in f for f in failures)
    # losing ONE axis while the other still compares must also fail
    no_cost = [{k: v for k, v in _row().items() if k != "cost_usd"}]
    failures, _ = gate.compare(no_cost, no_cost, 0.15)
    assert len(failures) == 1 and "cost" in failures[0]
    no_tp = [{k: v for k, v in _row().items() if k != "makespan_s"}]
    failures, _ = gate.compare(no_tp, no_tp, 0.15)
    assert len(failures) == 1 and "throughput" in failures[0]


def test_gate_accepts_both_artifact_shapes(tmp_path):
    rows = [_row()]
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps(rows))
    wrapped = tmp_path / "wrapped.json"
    wrapped.write_text(json.dumps({"matrix": rows}))
    assert gate.load_rows(str(bare)) == rows
    assert gate.load_rows(str(wrapped)) == rows


def _engine_row(eps=100_000.0, **kw):
    base = {"policy": "cfs", "containers": "off", "n_cores": 16,
            "n_tasks": 6249, "events": 1_548_167,
            "wall_s": 1.0, "events_per_sec": eps}
    base.update(kw)
    return base


def test_engine_gate_detects_and_compares():
    rows = [_engine_row(), _engine_row(policy="hybrid", eps=200_000.0)]
    assert gate.is_engine_rows(rows)
    failures, notes = gate.compare_engine(rows, rows, 0.15)
    assert failures == []
    assert any("2 engine cells" in n for n in notes)
    # >15% slower fails; faster or within tolerance passes
    slower = [_engine_row(eps=80_000.0),
              _engine_row(policy="hybrid", eps=200_000.0)]
    failures, _ = gate.compare_engine(rows, slower, 0.15)
    assert len(failures) == 1 and "events/sec regressed" in failures[0]
    faster = [_engine_row(eps=500_000.0),
              _engine_row(policy="hybrid", eps=190_000.0)]
    failures, _ = gate.compare_engine(rows, faster, 0.15)
    assert failures == []


def test_engine_gate_notes_event_count_drift():
    """An event-count change means the SIMULATION changed — the gate
    must surface it even when throughput did not regress."""
    prev = [_engine_row()]
    new = [_engine_row(events=1_500_000)]
    failures, notes = gate.compare_engine(prev, new, 0.15)
    assert failures == []
    assert any("event count changed" in n for n in notes)


def test_engine_gate_schema_drift_fails():
    rows = [{k: v for k, v in _engine_row().items()
             if k != "events_per_sec"}]
    rows[0]["events_per_sec"] = 0.0  # present but unusable
    failures, _ = gate.compare_engine(rows, rows, 0.15)
    assert len(failures) == 1 and "schema" in failures[0]


def test_engine_gate_cli_autodetects(tmp_path):
    good = tmp_path / "good.json"
    good.write_text(json.dumps({"rows": [_engine_row()]}))
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"rows": [_engine_row(eps=50_000.0)]}))
    assert gate.main([str(good), str(good)]) == 0
    assert gate.main([str(good), str(bad)]) == 1
    assert gate.main([str(bad), str(good)]) == 0  # improvement passes


def test_median_baseline_damps_engine_outliers():
    """One lucky historical run must not anchor the gate: the median of
    the last N baselines is gated against, not the single previous."""
    lucky = [_engine_row(eps=200_000.0)]
    normal1 = [_engine_row(eps=101_000.0)]
    normal2 = [_engine_row(eps=99_000.0)]
    current = [_engine_row(eps=95_000.0)]
    # vs the lucky run alone: a phantom 52% "regression"
    failures, _ = gate.compare_engine(lucky, current, 0.15)
    assert len(failures) == 1
    # vs the median of the last 3: within tolerance
    synth = gate.median_baseline([lucky, normal1, normal2])
    assert synth[0]["events_per_sec"] == 101_000.0
    failures, _ = gate.compare_engine(synth, current, 0.15)
    assert failures == []
    # non-gated fields come from the NEWEST baseline (drift reporting)
    assert synth[0]["events"] == lucky[0]["events"]


def test_median_baseline_cluster_medians_the_throughput_ratio():
    fast = [_row(cost=1.0, n=100, makespan=5.0)]    # tp 20
    mid = [_row(cost=1.2, n=100, makespan=10.0)]    # tp 10
    slow = [_row(cost=1.4, n=100, makespan=20.0)]   # tp 5
    synth = gate.median_baseline([fast, mid, slow])
    assert synth[0]["cost_usd"] == 1.2
    assert gate.throughput(synth[0]) == 10.0
    # current within 15% of the median on both axes passes
    failures, _ = gate.compare(synth, [_row(cost=1.3, n=100,
                                            makespan=11.0)], 0.15)
    assert failures == []
    # but not of the best-ever run
    failures, _ = gate.compare(fast, [_row(cost=1.3, n=100,
                                           makespan=11.0)], 0.15)
    assert len(failures) == 2


def test_median_baseline_handles_cells_missing_from_some_runs():
    a = [_row(), _row(dispatcher="affinity", cost=3.0)]
    b = [_row(cost=2.0)]
    c = [_row(cost=4.0)]
    synth = gate.median_baseline([a, b, c])
    by_key = {gate.cell_key(r): r for r in synth}
    assert by_key[gate.cell_key(_row())]["cost_usd"] == 2.0  # median(1,2,4)
    # the affinity cell exists in one run only: carried through as-is
    assert by_key[gate.cell_key(_row(dispatcher="affinity"))]["cost_usd"] \
        == 3.0


def test_gate_cli_multiple_baselines_and_median_of(tmp_path):
    def write(name, rows):
        p = tmp_path / name
        p.write_text(json.dumps({"rows": rows}))
        return str(p)
    lucky = write("b0.json", [_engine_row(eps=200_000.0)])
    n1 = write("b1.json", [_engine_row(eps=101_000.0)])
    n2 = write("b2.json", [_engine_row(eps=99_000.0)])
    cur = write("cur.json", [_engine_row(eps=95_000.0)])
    # single-baseline call (back-compat shape) fails on the lucky run
    assert gate.main([lucky, cur]) == 1
    # median of three passes
    assert gate.main([lucky, n1, n2, cur]) == 0
    # --median-of 1 restricts to the newest -> fails again
    assert gate.main([lucky, n1, n2, cur, "--median-of", "1"]) == 1
    # missing baselines among the list are skipped, not fatal
    assert gate.main([str(tmp_path / "nope.json"), n1, n2, cur]) == 0
    # all baselines missing: vacuous pass
    assert gate.main([str(tmp_path / "nope.json"), cur]) == 0


def test_gate_cli_exit_codes(tmp_path):
    good = tmp_path / "good.json"
    good.write_text(json.dumps([_row()]))
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps([_row(cost=2.0)]))
    assert gate.main([str(good), str(good)]) == 0
    assert gate.main([str(good), str(bad)]) == 1
    assert gate.main([str(good), str(bad), "--threshold", "1.5"]) == 0
    # missing baseline passes vacuously (first run after enabling)
    assert gate.main([str(tmp_path / "absent.json"), str(good)]) == 0
