"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="install the [test] extra for property tests")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import (decode_attention, flash_attention,  # noqa: E402
                           fused_rmsnorm, ref, rwkv6_scan, ssm_scan)

pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bh,sq,sk,hd,qb,kb", [
    (2, 128, 128, 64, 64, 64),
    (1, 96, 96, 64, 64, 64),      # non-multiple of block
    (3, 256, 256, 128, 128, 64),
    (2, 64, 192, 64, 64, 64),     # cross-attn shaped (sq != sk)
])
def test_flash_attention_sweep(dtype, bh, sq, sk, hd, qb, kb):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (bh, sq, hd), dtype)
    k = rand(ks[1], (bh, sk, hd), dtype)
    v = rand(ks[2], (bh, sk, hd), dtype)
    causal = sq == sk
    out = flash_attention(q, k, v, causal=causal, q_block=qb, k_block=kb,
                          interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(exp, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_window(window):
    ks = jax.random.split(KEY, 3)
    q = rand(ks[0], (2, 128, 64), jnp.float32)
    k = rand(ks[1], (2, 128, 64), jnp.float32)
    v = rand(ks[2], (2, 128, 64), jnp.float32)
    out = flash_attention(q, k, v, window=window, q_block=32, k_block=32,
                          interpret=True)
    exp = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.array(out), np.array(exp),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,kb", [(128, 64), (96, 64), (512, 128)])
def test_decode_attention_sweep(dtype, s, kb):
    ks = jax.random.split(KEY, 3)
    bh, hd = 4, 64
    q = rand(ks[0], (bh, 1, hd), dtype)
    k = rand(ks[1], (bh, s, hd), dtype)
    v = rand(ks[2], (bh, s, hd), dtype)
    lengths = jnp.array([s, max(s // 2, 1), 7, 1], jnp.int32)
    out = decode_attention(q, k, v, lengths, k_block=kb, interpret=True)
    exp = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(exp, np.float32), **TOL[dtype])


@pytest.mark.parametrize("chunk", [32, 64])
@pytest.mark.parametrize("hd,ds", [(64, 16), (64, 64), (128, 32)])
def test_ssm_scan_sweep(chunk, hd, ds):
    bh, s = 2, 256
    ks = jax.random.split(KEY, 4)
    xb = rand(ks[0], (bh, s, hd), jnp.float32, 0.5)
    B = rand(ks[1], (bh, s, ds), jnp.float32, 0.5)
    C = rand(ks[2], (bh, s, ds), jnp.float32, 0.5)
    loga = -jnp.abs(rand(ks[3], (bh, s), jnp.float32, 0.2))
    cum = loga.reshape(bh, s // chunk, chunk).cumsum(-1).reshape(bh, s)
    out = ssm_scan(xb, B, C, cum, chunk=chunk, interpret=True)
    exp = ref.ssm_scan_ref(xb, B, C, cum, chunk=chunk)
    np.testing.assert_allclose(np.array(out), np.array(exp),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [16, 32])
def test_rwkv6_scan_sweep(chunk):
    bh, s, hd = 2, 128, 64
    ks = jax.random.split(KEY, 5)
    r = rand(ks[0], (bh, s, hd), jnp.float32, 0.3)
    k = rand(ks[1], (bh, s, hd), jnp.float32, 0.3)
    v = rand(ks[2], (bh, s, hd), jnp.float32, 0.3)
    w = jax.nn.sigmoid(rand(ks[3], (bh, s, hd), jnp.float32))
    u = rand(ks[4], (bh, hd), jnp.float32, 0.1)
    out = rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    exp = ref.rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.array(out), np.array(exp),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,rows", [(100, 128, 32), (256, 512, 256)])
def test_fused_rmsnorm_sweep(dtype, n, d, rows):
    x = rand(KEY, (n, d), dtype)
    w = rand(jax.random.PRNGKey(1), (d,), jnp.float32, 0.1)
    out = fused_rmsnorm(x, w, rows=rows, interpret=True)
    exp = ref.fused_rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(exp, np.float32), **TOL[dtype])


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.sampled_from([64, 128, 160]),
       st.sampled_from([64, 128]), st.integers(0, 3))
def test_flash_attention_property(bh, s, hd, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = rand(ks[0], (bh, s, hd), jnp.float32)
    k = rand(ks[1], (bh, s, hd), jnp.float32)
    v = rand(ks[2], (bh, s, hd), jnp.float32)
    out = flash_attention(q, k, v, q_block=64, k_block=64, interpret=True)
    exp = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.array(out), np.array(exp),
                               rtol=3e-5, atol=3e-5)
    # attention output is a convex combination of values
    assert np.array(out).max() <= np.array(v).max() + 1e-4
    assert np.array(out).min() >= np.array(v).min() - 1e-4
