"""Cluster subsystem: dispatch policies, fleet sim, sweep grid."""
import copy

import numpy as np
import pytest

from repro.cluster import (DISPATCHERS, AffinityDispatch, Cell, ClusterSim,
                           build_grid, make_dispatcher, run_cell,
                           run_cluster, run_sweep)
from repro.core import run_policy
from repro.core.events import Task
from repro.traces import (TraceSpec, generate_workload, scale_load,
                          shard_tasks)

from conftest import mk_tasks


@pytest.fixture(scope="module")
def fleet_workload():
    """~1 minute of downscaled Azure-like load; enough contention that
    dispatch and node policy both matter."""
    spec = TraceSpec(minutes=1, invocations_per_min=1200, n_functions=80,
                     seed=11)
    return generate_workload(spec).tasks


# -- dispatcher unit properties ------------------------------------------------

@pytest.mark.parametrize("dispatcher", sorted(DISPATCHERS))
def test_work_conservation(dispatcher, fleet_workload):
    """No invocation is lost or duplicated crossing the dispatch layer."""
    res = run_cluster(fleet_workload, n_nodes=3, cores_per_node=8,
                      node_policy="hybrid", dispatcher=dispatcher)
    assert len(res.tasks) == len(fleet_workload)
    assert len(res.failed) == 0
    assert sorted(t.tid for t in res.tasks) == \
        sorted(t.tid for t in fleet_workload)
    for t in res.tasks:
        assert t.completion is not None
        assert t.remaining <= 1e-6


@pytest.mark.parametrize("dispatcher", sorted(DISPATCHERS))
def test_deterministic_under_fixed_seed(dispatcher, fleet_workload):
    w = fleet_workload[:400]
    runs = []
    for _ in range(2):
        sim = ClusterSim(n_nodes=3, cores_per_node=8,
                         node_policies="cfs", dispatcher=dispatcher, seed=42)
        res = sim.run(w)
        runs.append((list(sim.assignments),
                     sorted((t.tid, round(t.completion, 6))
                            for t in res.tasks)))
    assert runs[0] == runs[1]


def test_round_robin_is_uniform(fleet_workload):
    w = fleet_workload[:300]
    sim = ClusterSim(n_nodes=4, cores_per_node=8, node_policies="fifo",
                     dispatcher="round_robin")
    res = sim.run(w)
    counts = res.assignment_counts()
    assert max(counts) - min(counts) <= 1


def test_least_loaded_beats_random_on_tail_latency(fleet_workload):
    # State-aware dispatch avoids queueing behind busy nodes; random
    # dispatch cannot, so its tail slowdown is no better.
    p99 = {}
    for d in ("random", "least_loaded"):
        res = run_cluster(fleet_workload, n_nodes=4, cores_per_node=8,
                          node_policy="cfs", dispatcher=d, seed=3)
        p99[d] = res.p_slowdown(99)
    assert p99["least_loaded"] <= p99["random"] * 1.05


def test_join_idle_queue_prefers_idle_nodes():
    # Two widely spaced short tasks: an idle node always exists, so JIQ
    # must never stack them on one busy node.
    tasks = mk_tasks([(0, 50), (10_000, 50), (20_000, 50), (30_000, 50)])
    sim = ClusterSim(n_nodes=2, cores_per_node=1, node_policies="fifo",
                     dispatcher="join_idle_queue")
    res = sim.run(tasks)
    for t in res.tasks:
        assert t.response < 1.0  # never queued behind another task


def test_affinity_keeps_functions_on_one_node(fleet_workload):
    sim = ClusterSim(n_nodes=4, cores_per_node=8, node_policies="hybrid",
                     dispatcher="affinity")
    sim.run(fleet_workload)
    node_of = {}
    by_tid = {t.tid: t for t in fleet_workload}
    for tid, node in sim.assignments:
        f = by_tid[tid].func_id
        assert node_of.setdefault(f, node) == node


def test_affinity_stable_under_node_add_remove():
    """Consistent hashing: changing the fleet by one node remaps only a
    small fraction of functions (vs ~all for modulo hashing)."""
    class FakeNode:
        def __init__(self, i):
            self.node_id = f"node{i}"

    funcs = range(500)
    d = AffinityDispatch(seed=0)
    nodes5 = [FakeNode(i) for i in range(5)]
    before = {f: nodes5[d.owner(f, nodes5)].node_id for f in funcs}
    # remove one node
    nodes4 = nodes5[:4]
    d4 = AffinityDispatch(seed=0)
    after_rm = {f: nodes4[d4.owner(f, nodes4)].node_id for f in funcs}
    moved = sum(1 for f in funcs
                if before[f] != "node4" and before[f] != after_rm[f])
    assert moved / len(funcs) < 0.10
    # every orphan of the removed node is re-homed
    assert all(after_rm[f] != "node4" for f in funcs)
    # add it back: mapping returns exactly to the original
    d5 = AffinityDispatch(seed=0)
    again = {f: nodes5[d5.owner(f, nodes5)].node_id for f in funcs}
    assert again == before


def test_unknown_dispatcher_raises():
    with pytest.raises(KeyError):
        make_dispatcher("nope")


# -- fleet sim semantics -------------------------------------------------------

def test_heterogeneous_fleet_and_single_node_equivalence(fleet_workload):
    w = fleet_workload[:300]
    res = run_cluster(w, n_nodes=2, cores_per_node=8,
                      node_policy=["hybrid", "cfs"],
                      dispatcher="round_robin")
    assert sorted(set(res.node_policies)) == ["cfs", "hybrid"]
    # A 1-node fleet behind any dispatcher is exactly the single-node sim.
    one = run_cluster(w, n_nodes=1, cores_per_node=8, node_policy="cfs",
                      dispatcher="random")
    solo = run_policy("cfs", w, n_cores=8)
    fleet_c = sorted((t.tid, round(t.completion, 6)) for t in one.tasks)
    solo_c = sorted((t.tid, round(t.completion, 6)) for t in solo.tasks)
    assert fleet_c == solo_c


def test_scheduler_stepping_hooks():
    """The core hooks the dispatcher relies on: prime/inject/step/drain
    and load snapshots."""
    from repro.core.policies import FIFO
    s = FIFO(n_cores=2)
    s.prime([])
    assert s.load_snapshot()["idle"]
    s.inject(Task(tid=0, arrival=0.0, service=100.0), 0.0)
    s.inject(Task(tid=1, arrival=0.0, service=100.0), 0.0)
    s.inject(Task(tid=2, arrival=0.0, service=100.0), 0.0)
    s.step(50.0)
    snap = s.load_snapshot()
    assert snap["running"] == 2 and snap["queued"] == 1
    assert not snap["idle"]
    assert s.next_event_time() <= 100.1
    s.drain()
    assert len(s.completed) == 3
    assert s.next_event_time() == float("inf")


def test_scale_load_and_shard_tasks(fleet_workload):
    w = fleet_workload[:200]
    doubled = scale_load(w, 2.0)
    assert len(doubled) == len(w)
    assert doubled[-1].arrival == pytest.approx(w[-1].arrival / 2.0)
    assert doubled[-1].service == w[-1].service
    shards = shard_tasks(w, 3, by="hash")
    assert sum(len(s) for s in shards) == len(w)
    for i, shard in enumerate(shards):
        assert all(t.func_id % 3 == i for t in shard)
    inter = shard_tasks(w, 3, by="interleave")
    assert max(len(s) for s in inter) - min(len(s) for s in inter) <= 1


def test_node_ids_unique_across_add_remove_churn():
    """Scaling down then up must not recycle node ids — the affinity
    ring hashes ids, so a duplicate would starve the new node — and
    scale-ups must come from the fleet's node factory."""
    made = []

    def factory(policy, n_cores, **kw):
        from repro.core.policies import FIFO
        made.append(policy)
        return FIFO(n_cores=n_cores)

    sim = ClusterSim(n_nodes=3, cores_per_node=2, node_policies="fifo",
                     dispatcher="affinity", node_factory=factory)
    sim.remove_node(0)
    added = sim.add_node("fifo")
    ids = [n.node_id for n in sim.nodes]
    assert len(set(ids)) == len(ids)
    assert added.node_id not in ("node1", "node2")
    assert len(made) == 4  # 3 initial + the scale-up
    # the fresh node takes a share of affinity traffic
    owners = {sim.dispatcher.owner(f, sim.nodes) for f in range(200)}
    assert sim.nodes.index(added) in owners


def test_periodic_timers_survive_quiescent_gaps():
    """Under inject/step a node can fall idle before any work arrives;
    parked timers (util sampling, rightsizing) must revive with the
    next injected task instead of dying for the rest of the run."""
    from repro.core.hybrid import HybridScheduler, Rightsizer
    s = HybridScheduler(n_cores=4, n_fifo=2, rightsizer=Rightsizer(),
                        trace_util=True)
    s.prime([])
    s.step(2_500.0)  # both timer chains fire into an empty node and park
    n_before = len(s.util_series)
    for i in range(8):
        s.inject(Task(tid=i, arrival=3_000.0 + 100.0 * i, service=2_000.0),
                 3_000.0)
    s.drain()
    assert len(s.util_series) > n_before  # util sampling resumed
    assert any(t > 3_000.0 for t, _, _ in s.util_series)


def test_parked_timers_revive_when_node_quiescent_mid_run():
    """A node that goes momentarily quiescent MID-run (batch completes,
    then more work is injected) must park and revive its periodic
    timers on every gap, not just before the first arrival."""
    from repro.core.hybrid import HybridScheduler, Rightsizer
    s = HybridScheduler(n_cores=4, n_fifo=2, rightsizer=Rightsizer(),
                        trace_util=True)
    s.prime([])
    # batch 1: run to completion, then the node idles past several
    # timer periods — the chains must park instead of free-running.
    s.inject(Task(tid=0, arrival=0.0, service=800.0), 0.0)
    s.step(10_000.0)
    assert len(s.completed) == 1
    assert s._parked_timers            # chains parked during the gap
    n_util_gap = len(s.util_series)
    s.step(30_000.0)                   # quiescence: nothing fires
    assert len(s.util_series) == n_util_gap
    # batch 2: injection revives every parked chain
    s.inject(Task(tid=1, arrival=40_000.0, service=2_000.0), 40_000.0)
    s.inject(Task(tid=2, arrival=40_100.0, service=2_000.0), 40_100.0)
    s.drain()
    assert len(s.completed) == 3
    assert any(t > 40_000.0 for t, _, _ in s.util_series)
    # and they park again once the second batch drains
    assert s._parked_timers


def test_snapshot_not_idle_while_core_locked():
    from repro.core.policies import FIFO
    s = FIFO(n_cores=1)
    s.prime([])
    assert s.load_snapshot()["idle"]
    s.cores[0].locked_until = 10.0  # rightsizer-style transition lock
    assert not s.load_snapshot()["idle"]


def test_hybrid_not_idle_when_only_cfs_cores_free():
    """New arrivals enter via the FIFO group, so free CFS cores must
    not advertise the node as idle to a pull-based dispatcher."""
    from repro.core.hybrid import HybridScheduler
    s = HybridScheduler(n_cores=4, n_fifo=2)
    s.prime([])
    assert s.load_snapshot()["idle"]
    s.inject(Task(tid=0, arrival=0.0, service=10_000.0), 0.0)
    s.inject(Task(tid=1, arrival=0.0, service=10_000.0), 0.0)
    s.step(100.0)  # both FIFO cores busy, both CFS cores free
    snap = s.load_snapshot()
    assert snap["running"] == 2
    assert not snap["idle"]


def test_assignment_counts_survive_node_churn(fleet_workload):
    w = fleet_workload[:200]
    sim = ClusterSim(n_nodes=3, cores_per_node=8, node_policies="fifo",
                     dispatcher="round_robin")
    res0 = sim.run(w)
    before = dict(zip(res0.node_ids, res0.assignment_counts()))
    sim.remove_node(0)  # retired node moves to the END of result()
    res = sim.result()
    after = dict(zip(res.node_ids, res.assignment_counts()))
    assert after == before
    assert sum(res.assignment_counts()) == len(w)
    # balance/size metrics describe the LIVE fleet, not retired nodes
    assert res.summary()["n_nodes"] == 2
    assert len(res.node_utilization()) == 2
    # ...but latency/cost roll-ups still count the retired node's work
    assert res.summary()["n"] == len(w)


# -- end-to-end: the paper's claim survives cluster dispatch -------------------

def test_hybrid_fleet_beats_cfs_fleet_on_cost(fleet_workload):
    """The node-level result the paper monetizes (hybrid executes
    cheaper than CFS) must survive realistic front-end dispatch."""
    costs = {}
    for policy in ("cfs", "hybrid"):
        res = run_cluster(fleet_workload, n_nodes=2, cores_per_node=8,
                          node_policy=policy, dispatcher="least_loaded")
        costs[policy] = res.cost_usd()
    assert costs["hybrid"] < costs["cfs"]


# -- sweep runner --------------------------------------------------------------

def test_sweep_grid_and_cells():
    grid = build_grid(["cfs", "hybrid"], ["random", "least_loaded"],
                      [2], load_scales=(1.0, 2.0),
                      cores_per_node=4, minutes=1,
                      invocations_per_min=200.0, n_functions=20)
    assert len(grid) == 2 * 2 * 1 * 2
    rows = run_sweep([grid[0], grid[2]], parallel=False)
    assert {r["dispatcher"] for r in rows} == {"random", "least_loaded"}
    for r in rows:
        assert r["cost_usd"] > 0
        assert r["n"] > 0


def test_run_cell_load_scale_increases_contention():
    base = Cell(node_policy="cfs", dispatcher="round_robin", n_nodes=2,
                cores_per_node=4, minutes=1, invocations_per_min=400.0,
                n_functions=20, seed=5)
    hot = copy.replace(base, load_scale=4.0) if hasattr(copy, "replace") \
        else Cell(**{**base.__dict__, "load_scale": 4.0})
    r0, r4 = run_cell(base), run_cell(hot)
    assert r4["makespan_s"] < r0["makespan_s"]  # compressed arrivals
    assert r4["p99_slowdown"] >= r0["p99_slowdown"]
