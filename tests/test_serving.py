"""Serving gateway + real-model engine."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, get_smoke
from repro.distributed import materialize
from repro.models import model_specs
from repro.serving import (LiveRequest, ServingEngine, kv_bytes,
                           preemption_penalty_ms, requests_from_trace,
                           run_gateway)
from repro.traces import TraceSpec

SMALL = TraceSpec(minutes=1, invocations_per_min=6000, n_functions=60,
                  seed=5)  # overload: 50 slots, rho ~= 2


def test_kv_bytes_family_scaling():
    dense = get_config("deepseek-7b")
    ssm = get_config("rwkv6-1.6b")
    hyb = get_config("zamba2-1.2b")
    # SSM state is constant in seq len; attention KV is linear
    assert kv_bytes(ssm, 4096) == kv_bytes(ssm, 65536)
    assert kv_bytes(dense, 65536) > 10 * kv_bytes(dense, 4096)
    assert kv_bytes(hyb, 65536) < kv_bytes(dense, 65536)
    # sliding-window archs cap most layers' KV
    g = get_config("gemma3-12b")
    assert kv_bytes(g, 65536) < kv_bytes(dense, 65536)


def test_preemption_penalty_cheaper_for_ssm():
    assert preemption_penalty_ms(get_config("rwkv6-1.6b"), 32768) < \
        preemption_penalty_ms(get_config("deepseek-7b"), 32768)


@pytest.fixture(scope="module")
def gw_requests():
    return requests_from_trace(get_config("deepseek-7b"), SMALL)


def test_gateway_hybrid_cheaper_than_cfs(gw_requests):
    cfg = get_config("deepseek-7b")
    cfs = run_gateway(cfg, "cfs", requests=gw_requests)
    hyb = run_gateway(cfg, "hybrid", requests=gw_requests)
    assert hyb.cost_usd() < cfs.cost_usd()
    assert hyb.sim.p("execution", 99) < cfs.sim.p("execution", 99)


def test_gateway_preemption_penalty_paid(gw_requests):
    cfg = get_config("deepseek-7b")
    hyb = run_gateway(cfg, "hybrid", requests=gw_requests)
    migrated = [t for t in hyb.sim.tasks if t.migrations > 0]
    assert migrated
    # migrated tasks paid at least one swap penalty in execution span
    pen = preemption_penalty_ms(cfg, 4096)
    assert all(t.execution >= t.service + pen - 1e-6 for t in migrated)


def test_gateway_straggler_redispatch(gw_requests):
    cfg = get_config("deepseek-7b")
    r = run_gateway(cfg, "hybrid", requests=gw_requests,
                    straggler_factor=3.0)
    assert r.redispatches >= 0          # hook wired (count depends on load)


def test_engine_end_to_end():
    cfg = get_smoke("qwen2-vl-2b")
    params = materialize(model_specs(cfg), jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, n_slots=3, n_fifo=2, max_len=48,
                        initial_limit_ms=25.0)
    key = jax.random.PRNGKey(1)
    for rid in range(5):
        toks = jax.random.randint(jax.random.fold_in(key, rid), (1, 6),
                                  0, cfg.vocab)
        eng.submit(LiveRequest(rid=rid, arrival_ms=0.0, tokens=toks,
                               max_new=3 + rid * 3))
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert len(r.generated) == 3 + r.rid * 3
        assert r.completion_ms > 0 and r.cost_usd() > 0
    # the long requests should have been preempted out of FIFO slots
    assert any(r.preemptions > 0 for r in done)
    # adapter learned from completions
    assert len(eng.adapter.window) == 5
