"""Order-canonical observables (DESIGN.md Sec. 13).

The engine is allowed to retire completions in batches, which means the
ORDER of the completed-task lists handed to the roll-ups is an
implementation detail, not part of the simulation's semantics. These
tests pin the contract that makes that legal: every metric and cost
roll-up on ``SimResult`` / ``ClusterResult`` must be BIT-IDENTICAL
under any permutation of the completed-task list(s).

The deterministic seeded tests always run; when hypothesis is
installed (the ``[test]`` extra) the same properties are additionally
fuzzed over generated task lists.
"""
import math
import random

import pytest

from repro.cluster.metrics import ClusterResult
from repro.core.cost import cost_ladder, invocation_cost_usd, workload_cost_usd
from repro.core.events import Task
from repro.core.metrics import SimResult

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tier needs the [test] extra
    HAVE_HYPOTHESIS = False


def _mk_finished(rng, n, tie_every=3):
    """Messy finished tasks, deliberately including exact completion
    TIES (same-instant batches are where order canon matters most) and
    cold starts."""
    tie = rng.uniform(0.0, 1e6)
    out = []
    for tid in range(n):
        arrival = rng.uniform(0.0, 1e6)
        service = rng.uniform(0.1, 1e5)
        t = Task(tid=tid, arrival=arrival, service=service,
                 mem_mb=rng.choice([128, 256, 512, 1024]))
        t.first_run = arrival + rng.uniform(0.0, 1e4)
        t.completion = tie if tid % tie_every == 0 \
            else t.first_run + service
        t.cpu_time = service
        t.preemptions = rng.randrange(50)
        if rng.random() < 0.5:
            t.cold_start = True
            t.init_ms = rng.uniform(1.0, 5e3)
        out.append(t)
    return out


def _result(tasks):
    return SimResult(policy="cfs", tasks=tasks,
                     container_stats={"warm_mb_ms": 1.0})


def _check_sim_invariance(tasks, rng):
    base = _result(list(tasks))
    shuffled = list(tasks)
    rng.shuffle(shuffled)
    perm = _result(shuffled)
    assert perm.summary() == base.summary()  # bit-identical floats
    assert perm.cost_usd() == base.cost_usd()
    assert perm.cost_usd(fixed_mem_mb=512) == base.cost_usd(fixed_mem_mb=512)
    assert perm.cost_ladder() == base.cost_ladder()
    assert perm.init_cost_usd() == base.init_cost_usd()
    assert perm.p99() == base.p99()


def _cluster(node_task_lists):
    nodes = [SimResult(policy="cfs", tasks=ts) for ts in node_task_lists]
    return ClusterResult(node_results=nodes,
                         node_ids=[f"n{i}" for i in range(len(nodes))],
                         node_policies=["cfs"] * len(nodes),
                         dispatcher="least_loaded", cores_per_node=4)


def _check_cluster_invariance(node_lists, rng):
    # Unique tids fleet-wide: the canonical sort's tie-breaker must
    # identify tasks uniquely.
    tid = 0
    for ts in node_lists:
        for t in ts:
            t.tid = tid
            tid += 1
    base = _cluster([list(ts) for ts in node_lists])
    shuffled = [list(ts) for ts in node_lists]
    for ts in shuffled:
        rng.shuffle(ts)
    perm = _cluster(shuffled)
    assert perm.summary() == base.summary()
    assert perm.cost_usd() == base.cost_usd()


@pytest.mark.parametrize("seed", range(8))
def test_simresult_rollups_permutation_invariant(seed):
    rng = random.Random(seed)
    _check_sim_invariance(_mk_finished(rng, rng.randrange(1, 40)), rng)


@pytest.mark.parametrize("seed", range(8))
def test_cluster_rollups_permutation_invariant(seed):
    rng = random.Random(1000 + seed)
    node_lists = [_mk_finished(rng, rng.randrange(1, 15))
                  for _ in range(rng.randrange(1, 5))]
    _check_cluster_invariance(node_lists, rng)


@pytest.mark.parametrize("seed", range(8))
def test_workload_cost_usd_permutation_invariant(seed):
    rng = random.Random(2000 + seed)
    pairs = [(rng.uniform(0.1, 1e6), rng.choice([128, 256, 512, 1024]))
             for _ in range(rng.randrange(1, 64))]
    base = workload_cost_usd((e for e, _ in pairs),
                             mem_mb=[m for _, m in pairs])
    shuffled = list(pairs)
    rng.shuffle(shuffled)
    assert workload_cost_usd((e for e, _ in shuffled),
                             mem_mb=[m for _, m in shuffled]) == base
    # exactly-rounded total, not merely order-stable
    assert base == math.fsum(invocation_cost_usd(e, m) for e, m in pairs)
    assert cost_ladder([e for e, _ in pairs]) == \
        cost_ladder([e for e, _ in shuffled])


def test_finished_tasks_sorted_by_completion_then_tid():
    a = Task(tid=3, arrival=0.0, service=1.0)
    b = Task(tid=1, arrival=0.0, service=1.0)
    c = Task(tid=2, arrival=0.0, service=1.0)
    a.completion = b.completion = 10.0  # exact tie: tid breaks it
    c.completion = 5.0
    a.first_run = b.first_run = c.first_run = 1.0
    res = SimResult(policy="fifo", tasks=[a, b, c])
    assert [t.tid for t in res.finished_tasks()] == [2, 1, 3]
    assert res.makespan() == 10.0


if HAVE_HYPOTHESIS:
    _times = st.floats(0.0, 1e6, allow_nan=False, allow_infinity=False)

    @given(st.integers(1, 40), st.randoms(use_true_random=False),
           st.integers(0, 2**32 - 1))
    @settings(max_examples=60, deadline=None)
    def test_simresult_rollups_permutation_invariant_fuzz(n, rng, seed):
        _check_sim_invariance(_mk_finished(random.Random(seed), n), rng)

    @given(st.lists(st.integers(1, 15), min_size=1, max_size=4),
           st.randoms(use_true_random=False), st.integers(0, 2**32 - 1))
    @settings(max_examples=40, deadline=None)
    def test_cluster_rollups_permutation_invariant_fuzz(sizes, rng, seed):
        gen = random.Random(seed)
        _check_cluster_invariance([_mk_finished(gen, n) for n in sizes],
                                  rng)
