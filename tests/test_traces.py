"""Workload synthesis: paper anchors + IAT construction (Sec. V-B)."""
import numpy as np
import pytest

from repro.traces import (BUCKET_MS, FIB_N, P90_ANCHOR_MS, PHI, TraceSpec,
                          generate_workload, workload_file)


def test_fibonacci_ladder_golden_ratio():
    for a, b in zip(BUCKET_MS, BUCKET_MS[1:]):
        assert b / a == pytest.approx(PHI)
    assert FIB_N[0] == 36


def test_volume_matches_paper():
    w = generate_workload(TraceSpec(minutes=2))
    # 12,442 invocations in the first two minutes (paper Sec. II)
    assert abs(len(w.tasks) - 12_442) / 12_442 < 0.05


def test_p90_calibrated_to_anchor():
    w = generate_workload(TraceSpec(minutes=2))
    assert w.p90_service() == pytest.approx(P90_ANCHOR_MS, rel=1e-6)


def test_duration_distribution_shape():
    w = generate_workload(TraceSpec(minutes=2))
    sv = np.array([t.service for t in w.tasks])
    assert np.percentile(sv, 80) < 1_000.0       # 80% under a second
    assert sv.max() > 30_000.0                   # minute-scale tail
    share = sv[sv > P90_ANCHOR_MS].sum() / sv.sum()
    assert 0.3 < share < 0.8                     # tail carries the work


def test_functions_have_consistent_buckets():
    w = generate_workload(TraceSpec(minutes=2))
    by_func = {}
    for t in w.tasks:
        by_func.setdefault(t.func_id, set()).add(t.bucket)
    assert all(len(b) == 1 for b in by_func.values())


def test_iat_construction():
    w = generate_workload(TraceSpec(minutes=1))
    rows = workload_file(w)
    arrivals = np.cumsum([r["iat_ms"] for r in rows])
    assert np.all(np.diff(arrivals) >= -1e-9)    # sorted
    assert len(rows) == len(w.tasks)
    assert all(36 <= r["fib_n"] <= 51 for r in rows)


def test_deterministic_given_seed():
    a = generate_workload(TraceSpec(minutes=1, seed=3))
    b = generate_workload(TraceSpec(minutes=1, seed=3))
    assert [t.arrival for t in a.tasks] == [t.arrival for t in b.tasks]
    assert [t.service for t in a.tasks] == [t.service for t in b.tasks]
