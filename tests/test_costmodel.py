"""Cost-model substrate (DESIGN.md Sec. 18).

Pins the three contracts the redesign makes:

* **bit-identity** — ``PricingSpec()`` is exactly the historical
  constants; cost helpers with ``pricing=None`` equal every explicit
  default-spec spelling; ``cost_model="static"`` equals no model.
* **calibration** — the artifact round-trips through JSON bit-for-bit,
  the fit meets the MAPE acceptance bound, and predictions are
  monotone non-decreasing in FLOPs and bytes by construction (fuzzed
  under hypothesis when installed).
* **consumers** — a perturbed artifact demonstrably changes the
  admission ceiling and cost-aware routing; an unobserved learning
  dispatcher routes exactly like a frozen one; the learned-coefficient
  state reaches the summary schema at runtime.
"""
import math
import warnings

import pytest

from repro.cluster.admission import AdmissionConfig, AdmissionControl
from repro.cluster.dispatch import CostAwareDispatch
from repro.core import cost
from repro.core.events import Task
from repro.costmodel import (DEFAULT_PRICING, LearnedCostModel, PRICINGS,
                             PricingSpec, ScalarRLS, StaticCostModel,
                             calibrate, fit_ridge, load_artifact,
                             make_cost_model, make_pricing, predict_ms,
                             save_artifact)
from repro.costmodel.online import EwmaRate

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # property tier needs the [test] extra
    HAVE_HYPOTHESIS = False


# -- satellite 1: PricingSpec consolidation + shims --------------------------

def test_default_pricing_is_the_historical_constants():
    p = PricingSpec()
    assert p.price_per_gb_second == 1.66667e-5
    assert p.price_per_request == 2.0e-7
    assert p.warm_hold_per_gb_second == 1.66667e-5 / 8.0
    assert p == DEFAULT_PRICING


def test_deprecated_constants_warn_and_match_spec():
    for name, want in (
            ("PRICE_PER_GB_SECOND", DEFAULT_PRICING.price_per_gb_second),
            ("PRICE_PER_REQUEST", DEFAULT_PRICING.price_per_request),
            ("WARM_HOLD_PER_GB_SECOND",
             DEFAULT_PRICING.warm_hold_per_gb_second)):
        with pytest.warns(DeprecationWarning, match=name):
            assert getattr(cost, name) == want
    with pytest.raises(AttributeError):
        cost.NO_SUCH_CONSTANT


def test_cost_helpers_bit_identical_default_vs_explicit():
    """pricing=None, pricing=DEFAULT_PRICING and pricing=PricingSpec()
    are the same bits on the whole helper battery."""
    specs = (None, DEFAULT_PRICING, PricingSpec())
    for mem in (128, 256, 1024, 1536):
        base = cost.price_per_ms(mem)
        assert all(cost.price_per_ms(mem, s) == base for s in specs)
        inv = cost.invocation_cost_usd(250.0, mem, price_mult=1.3)
        assert all(cost.invocation_cost_usd(250.0, mem, price_mult=1.3,
                                            pricing=s) == inv
                   for s in specs)
        cold = cost.cold_start_cost_usd(900.0, mem)
        assert all(cost.cold_start_cost_usd(900.0, mem, pricing=s) == cold
                   for s in specs)
    rej = cost.rejected_request_cost_usd(17)
    hold = cost.warm_pool_hold_cost_usd(5.5e8)
    assert all(cost.rejected_request_cost_usd(17, pricing=s) == rej
               for s in specs)
    assert all(cost.warm_pool_hold_cost_usd(5.5e8, pricing=s) == hold
               for s in specs)


def test_pricing_presets_and_coercions():
    assert set(PRICINGS) >= {"default", "premium", "free_requests"}
    assert make_pricing(None) is DEFAULT_PRICING
    assert make_pricing("premium") is PRICINGS["premium"]
    assert make_pricing({"name": "x", "price_per_request": 0.0}).name == "x"
    p = make_pricing("premium")
    assert cost.price_per_ms(1024, p) > cost.price_per_ms(1024)
    assert PRICINGS["free_requests"].price_per_request == 0.0
    with pytest.raises(KeyError):
        make_pricing("no_such_preset")
    with pytest.raises(ValueError):
        PricingSpec(price_per_gb_second=-1.0)


# -- calibration: artifact, bound, monotonicity ------------------------------

def test_calibration_meets_mape_bound_and_clips_weights():
    art = calibrate(mode="synthetic", seed=0)
    assert art["mape"] <= 0.25          # the acceptance bound
    assert all(w >= 0.0 for w in art["weights"])
    assert art["queue_ms_per_load"] > 0.0


def test_calibration_deterministic_per_seed():
    assert calibrate(seed=3) == calibrate(seed=3)
    a, b = calibrate(seed=1), calibrate(seed=2)
    assert a["weights"] != b["weights"]  # the noise seed matters


def test_artifact_roundtrip_bit_identical(tmp_path):
    art = calibrate(mode="synthetic", seed=0)
    path = save_artifact(art, tmp_path / "cal.json")
    loaded = load_artifact(path)
    m1, m2 = LearnedCostModel(art), LearnedCostModel(loaded)
    for row in art["rows"]:
        assert m1.predict_op_ms(row) == m2.predict_op_ms(row)
    assert m1.queue_ms_per_load() == m2.queue_ms_per_load()


def test_load_artifact_rejects_wrong_kind_and_version(tmp_path):
    art = calibrate()
    bad_kind = tmp_path / "k.json"
    save_artifact(dict(art, kind="something-else"), bad_kind)
    with pytest.raises(ValueError, match="not a"):
        load_artifact(bad_kind)
    bad_ver = tmp_path / "v.json"
    save_artifact(dict(art, version=99), bad_ver)
    with pytest.raises(ValueError, match="version"):
        load_artifact(bad_ver)


def test_fitted_predictions_monotone_seeded():
    art = calibrate(mode="synthetic", seed=0)
    w = art["weights"]
    base = {"flops": 1e6, "bytes": 1e5}
    assert predict_ms(w, {"flops": 2e6, "bytes": 1e5}) >= \
        predict_ms(w, base)
    assert predict_ms(w, {"flops": 1e6, "bytes": 2e5}) >= \
        predict_ms(w, base)
    assert predict_ms(w, {"flops": 0.0, "bytes": 0.0}) >= 0.0


if HAVE_HYPOTHESIS:
    _row = st.fixed_dictionaries({
        "flops": st.floats(1e3, 1e10),
        "bytes": st.floats(1e3, 1e9),
        "measured_ms": st.floats(1e-3, 1e5),
    })

    @given(rows=st.lists(_row, min_size=3, max_size=8),
           flops=st.floats(0.0, 1e10), bytes_=st.floats(0.0, 1e9),
           dflops=st.floats(0.0, 1e10), dbytes=st.floats(0.0, 1e9))
    @settings(max_examples=25, deadline=None)
    def test_fit_monotone_nonneg_fuzzed(rows, flops, bytes_, dflops,
                                        dbytes):
        """Any fit over any rows predicts non-negatively and monotone
        non-decreasing in both features (weights clipped at zero)."""
        try:
            w = fit_ridge(rows)
        except ValueError:
            return  # degenerate singular design: rejected loudly
        lo = predict_ms(w, {"flops": flops, "bytes": bytes_})
        hi = predict_ms(w, {"flops": flops + dflops,
                            "bytes": bytes_ + dbytes})
        assert lo >= 0.0
        assert hi >= lo


# -- the CostModel protocol and its consumers --------------------------------

def test_make_cost_model_coercions():
    assert isinstance(make_cost_model(None), StaticCostModel)
    assert isinstance(make_cost_model("static"), StaticCostModel)
    art = calibrate()
    m = make_cost_model(art)
    assert isinstance(m, LearnedCostModel)
    assert make_cost_model(m) is m
    assert make_cost_model("learned").kind == "learned"
    with pytest.raises(TypeError):
        make_cost_model(3.14)


def test_learned_token_costs_anchored_and_transferable():
    from repro.configs.registry import get_config
    art = calibrate(model="deepseek-7b", seq_len=4096)
    m = LearnedCostModel(art)
    ref = get_config("deepseek-7b")
    # Calibrated model: anchored to its own spec constants.
    assert m.token_costs(ref, 4096) == (ref.ms_per_ktoken_prefill,
                                        ref.ms_per_token_decode)
    # Another model: transferred by predicted ratio — positive, finite,
    # and NOT simply that model's spec constants.
    other = get_config("deepseek-67b")
    pre, dec = m.token_costs(other, 4096)
    assert pre > 0.0 and dec > 0.0
    assert math.isfinite(pre) and math.isfinite(dec)
    assert (pre, dec) != (other.ms_per_ktoken_prefill,
                          other.ms_per_token_decode)
    # Static model: no opinion, the spec constants stand.
    assert StaticCostModel().token_costs(ref, 4096) is None


class _FakeNode:
    """snapshot()-shaped stand-in for routing tests: warm-less with an
    advertised cold model, so the cold-vs-queue tradeoff is explicit."""

    def __init__(self, load, cold_ms):
        self._s = {"load": load, "warm": {}, "cold_model": (cold_ms, 0.0)}

    def snapshot(self):
        return dict(self._s)


def test_perturbed_artifact_changes_ceiling_and_routing():
    art = calibrate(mode="synthetic", seed=0)
    perturbed = dict(art, queue_ms_per_load=art["queue_ms_per_load"] * 25)
    m1, m2 = LearnedCostModel(art), LearnedCostModel(perturbed)

    # Consumer 3: the derived admission ceiling moves.
    assert m1.derive_max_load(10_000.0) != m2.derive_max_load(10_000.0)
    from repro.scenario import ResilienceSpec, _resolve_resilience
    res = ResilienceSpec(admission={"max_load": "auto"})
    r1 = _resolve_resilience(res, m1).admission["max_load"]
    r2 = _resolve_resilience(res, m2).admission["max_load"]
    assert r1 != r2 and r1 > 0 and r2 > 0

    # Consumer 2: the routing decision flips where the cold-start
    # price sits between the two queueing-penalty estimates.
    cold_ms = 5.0 * math.sqrt(m1.queue_ms_per_load()
                              * m2.queue_ms_per_load())
    nodes = [_FakeNode(load=5.0, cold_ms=0.0),     # loaded but free
             _FakeNode(load=0.0, cold_ms=cold_ms)]  # idle but cold
    task = Task(tid=0, arrival=0.0, service=100.0, mem_mb=512, func_id=1)
    d1 = CostAwareDispatch(queue_ms_per_load=m1.queue_ms_per_load(),
                           learn=False)
    d2 = CostAwareDispatch(queue_ms_per_load=m2.queue_ms_per_load(),
                           learn=False)
    assert d1.select(task, nodes, 0.0) != d2.select(task, nodes, 0.0)


def test_admission_auto_requires_a_cost_model():
    with pytest.raises(ValueError, match="auto"):
        AdmissionControl(AdmissionConfig(max_load="auto"))


def test_unobserved_fleet_routes_like_frozen_dispatcher():
    """Satellite 3's regression: learn=True with NO completions must
    route exactly like learn=False — the prior is pseudo-evidence, not
    a behavior change."""
    learner = CostAwareDispatch(seed=5, queue_ms_per_load=700.0,
                                learn=True)
    frozen = CostAwareDispatch(seed=5, queue_ms_per_load=700.0,
                               learn=False)
    assert learner.coeff == frozen.coeff == 700.0
    for tid in range(40):
        nodes = [_FakeNode(load=float((tid + i) % 7),
                           cold_ms=200.0 * ((tid * i) % 3))
                 for i in range(4)]
        task = Task(tid=tid, arrival=float(tid), service=50.0,
                    mem_mb=256 << (tid % 3), func_id=tid % 5)
        assert learner.select(task, nodes, float(tid)) == \
            frozen.select(task, nodes, float(tid))
    assert learner.n_observed == 0
    assert learner.snapshot()["coeff"] == 700.0


def test_scalar_rls_prior_then_evidence():
    rls = ScalarRLS(1000.0, prior_weight=25.0, lam=0.98)
    assert rls.coeff == 1000.0
    for _ in range(200):
        rls.observe(2.0, 2.0 * 40.0)   # true slope 40
    assert abs(rls.coeff - 40.0) < 5.0
    assert rls.n_observed == 200
    frozen = ScalarRLS(1000.0, learn=False)
    frozen.observe(2.0, 80.0)
    assert frozen.coeff == 1000.0


def test_ewma_rate_unseen_is_zero():
    fc = EwmaRate(alpha=0.5)
    assert fc.forecast(7) == 0.0
    fc.update(7, 8.0)
    assert fc.forecast(7) == 8.0
    fc.update(7, 0.0)
    assert fc.forecast(7) == 4.0
    with pytest.raises(ValueError):
        EwmaRate(alpha=0.0)


# -- consumer 4: the online forecaster ---------------------------------------

def _burst_tasks(minutes=3, per_min=10, fid=0):
    out = []
    for m in range(minutes):
        for i in range(per_min):
            out.append(Task(tid=len(out), arrival=m * 60_000.0 + i * 100.0,
                            service=6_000.0, mem_mb=256, func_id=fid))
    return out


def test_forecast_plan_only_uses_past_minutes():
    from repro.cluster.prewarm import PrewarmConfig, build_plan
    from repro.costmodel.forecast import build_forecast_plan, make_plan
    tasks = _burst_tasks()
    cfg = PrewarmConfig(lead_ms=2_000.0)
    oracle = build_plan(tasks, cfg)
    ewma = build_forecast_plan(tasks, cfg)
    # The oracle knows minute 0's burst; the forecaster cannot.
    assert any(row[0] == 0.0 for row in oracle)
    assert ewma and all(row[0] >= 60_000.0 - 2_000.0 for row in ewma)
    assert ewma == build_forecast_plan(tasks, cfg)  # deterministic
    # make_plan dispatches on the config's forecast field.
    assert make_plan(tasks, cfg) == oracle
    assert make_plan(tasks, PrewarmConfig(forecast="ewma")) == \
        build_forecast_plan(tasks, PrewarmConfig(forecast="ewma"))


# -- Scenario integration: schema, bit-identity, runtime state ---------------

def _tiny_llm_scenario(**kw):
    from repro.scenario import FleetSpec, PolicySpec, Scenario, WorkloadSpec
    from repro.serving.llm import LLMSpec
    from repro.traces import TraceSpec
    base = dict(
        workload=WorkloadSpec(
            kind="llm",
            trace=TraceSpec(minutes=1, invocations_per_min=60.0,
                            n_functions=6, seed=9),
            llm=LLMSpec(model="deepseek-7b")),
        fleet=FleetSpec(n_nodes=2, cores_per_node=4,
                        dispatcher="cost_aware", seed=2),
        policy=PolicySpec(name="hybrid"))
    base.update(kw)
    return Scenario(**base)


def test_summary_carries_costmodel_keys():
    from repro.scenario import SUMMARY_KEYS_V1, run
    s = run(_tiny_llm_scenario()).summary()
    for key in ("backend", "fallback_reason", "pricing", "cost_model",
                "cost_coeff", "cost_obs", "cost_pred_err_ms"):
        assert key in SUMMARY_KEYS_V1 and key in s
    assert s["pricing"] == "default"
    assert s["cost_model"] == "static"
    assert s["backend"] == "python"
    # Satellite 3: the cost_aware RLS state is live in the summary.
    assert s["cost_obs"] > 0
    assert s["cost_coeff"] > 0.0


def test_static_cost_model_bit_identical_to_none():
    from repro.scenario import run
    a = run(_tiny_llm_scenario()).summary()
    b = run(_tiny_llm_scenario(cost_model="static")).summary()
    assert a == b


def test_premium_pricing_raises_the_bill():
    from repro.scenario import run
    base = run(_tiny_llm_scenario()).summary()
    prem = run(_tiny_llm_scenario(pricing="premium")).summary()
    assert prem["pricing"] == "premium"
    assert prem["cost_usd"] > base["cost_usd"]
    # Pricing changes dollars, never the schedule.
    assert prem["n"] == base["n"]
    assert prem["makespan_s"] == base["makespan_s"]


def test_learned_model_threads_prior_into_dispatcher():
    from repro.scenario import run
    art = calibrate(mode="synthetic", seed=0)
    res = run(_tiny_llm_scenario(cost_model=dict(art)))
    s = res.summary()
    assert s["cost_model"] == "learned"
    assert s["cost_obs"] > 0


def test_sweep_cell_carries_pricing_and_cost_model_axes():
    from repro.cluster.sweep import Cell, _row_key
    cell = Cell(node_policy="hybrid", dispatcher="least_loaded",
                n_nodes=2, pricing="premium", cost_model="learned")
    sc = cell.to_scenario()
    assert sc.pricing == "premium"
    assert sc.cost_model == "learned"
    default = Cell(node_policy="hybrid", dispatcher="least_loaded",
                   n_nodes=2)
    assert default.to_scenario().pricing is None
    assert default.to_scenario().cost_model is None
    row = {"node_policy": "hybrid", "pricing": "premium",
           "cost_model": "learned"}
    key = _row_key(row)
    assert "premium" in key and "learned" in key
