import numpy as np
import pytest

from repro.core.events import Task
from repro.traces import TraceSpec, generate_workload


def pytest_addoption(parser):
    parser.addoption(
        "--slow", action="store_true", default=False,
        help="also run tests marked slow (JAX kernel/model tier)")


def pytest_collection_modifyitems(config, items):
    """Two-tier suite: the default tier must stay fast (<2 min) so it is
    practical to run on every change; ``--slow`` opts into the JAX
    kernel/model tier (CI runs it nightly)."""
    if config.getoption("--slow"):
        return
    skip = pytest.mark.skip(reason="slow tier: pass --slow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def small_workload():
    """Downscaled 30s Azure-like workload (fast enough for CFS sims)."""
    spec = TraceSpec(minutes=1, invocations_per_min=1500, n_functions=80,
                     seed=7)
    w = generate_workload(spec)
    return [t for t in w.tasks if t.arrival < 30_000]


def mk_tasks(specs):
    """specs: list of (arrival, service[, mem]) tuples."""
    out = []
    for i, s in enumerate(specs):
        arrival, service = s[0], s[1]
        mem = s[2] if len(s) > 2 else 256
        out.append(Task(tid=i, arrival=float(arrival),
                        service=float(service), mem_mb=mem,
                        deadline=arrival + 2.0 * service))
    return out
