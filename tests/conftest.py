import json
import os

import numpy as np
import pytest

from repro.core.events import Task
from repro.traces import TraceSpec, generate_workload

# Per-module wall-clock accounting, written as a benchmark-style
# artifact when REPRO_TEST_TIMINGS names a path — the nightly slow
# tier exports its timings into the trend dashboard
# (benchmarks.trend_report kind "test_timings"), so a test module
# quietly doubling its runtime shows up as a trend regression instead
# of an unexplained nightly slowdown.
_TIMINGS: dict = {}


def pytest_runtest_logreport(report):
    if not os.environ.get("REPRO_TEST_TIMINGS") or report.when != "call":
        return
    module = report.nodeid.split("::")[0]
    tier = "slow" if "slow" in report.keywords else "fast"
    acc = _TIMINGS.setdefault((module, tier), [0, 0.0])
    acc[0] += 1
    acc[1] += report.duration


def pytest_sessionfinish(session, exitstatus):
    path = os.environ.get("REPRO_TEST_TIMINGS")
    if not path or not _TIMINGS:
        return
    rows = [{"module": module, "tier": tier, "n_tests": n,
             "wall_s": round(wall, 3)}
            for (module, tier), (n, wall) in sorted(_TIMINGS.items())]
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump({"rows": rows}, f, indent=2)


def pytest_addoption(parser):
    parser.addoption(
        "--slow", action="store_true", default=False,
        help="also run tests marked slow (JAX kernel/model tier)")


def pytest_collection_modifyitems(config, items):
    """Two-tier suite: the default tier must stay fast (<2 min) so it is
    practical to run on every change; ``--slow`` opts into the JAX
    kernel/model tier (CI runs it nightly)."""
    if config.getoption("--slow"):
        return
    skip = pytest.mark.skip(reason="slow tier: pass --slow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def small_workload():
    """Downscaled 30s Azure-like workload (fast enough for CFS sims)."""
    spec = TraceSpec(minutes=1, invocations_per_min=1500, n_functions=80,
                     seed=7)
    w = generate_workload(spec)
    return [t for t in w.tasks if t.arrival < 30_000]


def mk_tasks(specs):
    """specs: list of (arrival, service[, mem]) tuples."""
    out = []
    for i, s in enumerate(specs):
        arrival, service = s[0], s[1]
        mem = s[2] if len(s) > 2 else 256
        out.append(Task(tid=i, arrival=float(arrival),
                        service=float(service), mem_mb=mem,
                        deadline=arrival + 2.0 * service))
    return out
