"""Container lifecycle layer: pool invariants, scheduler integration,
warm-aware dispatch, and cold-start economics."""
import math

import numpy as np
import pytest

from repro.cluster import run_cluster
from repro.core import ContainerConfig, ContainerPool, Task, run_policy
from repro.core.containers import expected_cold_ms
from repro.core.cost import price_per_ms, warm_pool_hold_cost_usd
from repro.core.metrics import SimResult
from repro.core.policies import FIFO

from conftest import mk_tasks


def _pool(**kw):
    kw.setdefault("cold_jitter", 0.0)
    return ContainerPool(ContainerConfig(**kw), seed=1)


# -- pool unit behaviour -------------------------------------------------------

def test_warm_hit_within_keepalive_and_miss_after_expiry():
    p = _pool(capacity_mb=1024, keepalive_ms=5_000)
    assert not p.acquire(7, 256, t0 := 0.0)       # nothing warm yet
    p.release(7, 256, 100.0)
    assert p.acquire(7, 256, 2_000.0)             # within TTL: warm
    p.release(7, 256, 3_000.0)
    assert not p.acquire(7, 256, 9_000.0)         # 3000+5000 < 9000: expired
    assert p.stats()["evictions_ttl"] == 1
    assert t0 == 0.0


def test_reaper_evicts_and_stops_memory_meter_at_expiry():
    p = _pool(capacity_mb=1024, keepalive_ms=1_000)
    p.acquire(1, 512, 0.0)
    p.release(1, 512, 0.0)
    p.evict_expired(10_000.0)  # reaper runs late; meter stops at t=1000
    assert p.idle_mb == 0.0
    assert p.stats()["warm_mb_ms"] == pytest.approx(512 * 1_000.0)
    assert warm_pool_hold_cost_usd(p.stats()["warm_mb_ms"]) > 0


def test_capacity_never_exceeded_evicts_oldest_idle():
    p = _pool(capacity_mb=1000, keepalive_ms=1e9)
    for fid, t in ((1, 0.0), (2, 10.0), (3, 20.0)):
        p.release(fid, 400, t)
        p.check_invariants()
    assert p.idle_mb <= 1000
    # func 1 (oldest idle) was evicted to make room for func 3
    assert not p.has_warm(1)
    assert p.has_warm(2) and p.has_warm(3)
    assert p.stats()["evictions_capacity"] == 1
    # a sandbox larger than the whole pool is dropped, not stored
    p.release(9, 4096, 30.0)
    assert not p.has_warm(9)
    p.check_invariants()


def test_warm_hit_requires_matching_memory_size():
    """A sandbox only satisfies a same-size request: a 1 GB invocation
    must not 'reuse' a 128 MB sandbox for free, and the right-sized
    container is picked even when other sizes idle for the same func."""
    p = _pool(capacity_mb=4096, keepalive_ms=1e9)
    p.release(1, 128, 0.0)
    assert not p.acquire(1, 1024, 10.0)   # size mismatch: cold
    p.check_invariants()
    assert p.has_warm(1)                  # the 128 MB sandbox survives
    p.release(1, 1024, 20.0)
    assert p.acquire(1, 1024, 30.0)       # exact match: warm
    assert p.acquire(1, 128, 40.0)
    p.check_invariants()


def test_pool_deterministic_under_fixed_seed():
    def run(seed):
        p = ContainerPool(ContainerConfig(), seed=seed)
        out = []
        for i in range(20):
            fid = i % 3
            if not p.acquire(fid, 256, i * 50.0):
                out.append(round(p.cold_start_ms(256), 9))
            p.release(fid, 256, i * 50.0 + 25.0)
        return out, p.stats()
    a, b, c = run(3), run(3), run(4)
    assert a == b
    assert a[0] != c[0]  # different seed, different jitter draws


def test_cold_start_model_scales_with_memory():
    assert expected_cold_ms(10_240) > expected_cold_ms(128)
    p = _pool()  # jitter disabled: sample == mean
    assert p.cold_start_ms(512) == pytest.approx(expected_cold_ms(512))
    pj = ContainerPool(ContainerConfig(cold_jitter=0.5), seed=0)
    draws = [pj.cold_start_ms(512) for _ in range(200)]
    assert all(d > 0 for d in draws)
    assert np.mean(draws) == pytest.approx(expected_cold_ms(512), rel=0.25)


def test_capacity_heap_tombstones_are_compacted():
    """Long heavy-traffic run with no capacity pressure: every acquire
    tombstones a heap entry; the lazy heap must stay within 2x the live
    count instead of growing one stale entry per completion."""
    p = _pool(capacity_mb=1e9, keepalive_ms=1e12)
    for i in range(5_000):
        fid = i % 7
        p.acquire(fid, 256, float(i))
        p.release(fid, 256, float(i) + 0.5)
    assert len(p._cap_heap) <= max(64, 2 * p._n_idle)
    p.check_invariants()
    # and the reaper path compacts too
    q = _pool(capacity_mb=1e9, keepalive_ms=10.0, sweep_ms=0.0)
    for i in range(2_000):
        q.release(i, 256, float(i) * 100.0)
        q.evict_expired(float(i) * 100.0 + 50.0)
    assert len(q._cap_heap) <= max(64, 2 * q._n_idle)
    q.check_invariants()


def test_deferred_releases_apply_in_canonical_time_order():
    """release_at buffers; effects land at the next read at/after t in
    (t, func_id, tid) order, regardless of call order."""
    p = _pool(capacity_mb=1e9, keepalive_ms=1e9)
    # Buffer out of call order: later time first.
    p.release_at(1, 256, 200.0, tid=7)
    p.release_at(1, 256, 100.0, tid=3)
    # A read at t=150 applies only the t=100 release.
    counts, mb = p.live_view(150.0)
    assert counts == {1: 1} and mb == 256
    assert p.acquire(1, 256, 150.0)          # the t=100 sandbox, warm
    assert not p.acquire(1, 256, 160.0)      # t=200 not yet visible
    assert p.acquire(1, 256, 250.0)          # now it is
    p.check_invariants()


def test_deferred_release_visible_to_same_instant_acquire():
    """Canonical same-instant rule: a buffered release at t applies
    BEFORE an acquire at the same t (ties keyed (func_id, tid), not
    call order)."""
    p = _pool(capacity_mb=1e9, keepalive_ms=1e9)
    p.release_at(4, 512, 1_000.0, tid=1)
    assert p.acquire(4, 512, 1_000.0)
    p.check_invariants()


def test_deferred_releases_equivalent_to_direct_when_in_order():
    """Routing every release through the buffer must reproduce the
    direct-release pool bit-for-bit when times are already ordered —
    the engine's serialized path and batch path share one semantics."""
    cfg = dict(capacity_mb=1000, keepalive_ms=700.0)
    direct, buffered = _pool(**cfg), _pool(**cfg)
    seq = [(i * 50.0, i % 5, 256) for i in range(200)]
    hits_d, hits_b = [], []
    for t, fid, mem in seq:
        hits_d.append(direct.acquire(fid, mem, t))
        direct.release(fid, mem, t + 25.0)
        hits_b.append(buffered.acquire(fid, mem, t))
        buffered.release_at(fid, mem, t + 25.0, tid=int(t))
    assert hits_d == hits_b
    direct.settle(10_001.0)
    buffered.settle(10_001.0)
    assert direct.stats() == buffered.stats()


def test_cold_start_draw_counter_indexes_stream():
    p = ContainerPool(ContainerConfig(cold_jitter=0.5), seed=9)
    draws = [p.cold_start_ms(256) for _ in range(5)]
    assert p.n_draws == 5
    q = ContainerPool(ContainerConfig(cold_jitter=0.5), seed=9)
    assert [q.cold_start_ms(256) for _ in range(5)] == draws


def test_prewarm_pool_invariants_property():
    """Pre-warm invariants under random interleavings: capacity holds,
    real warmth is never sacrificed for a bet, counters reconcile, and
    the interleaved run is deterministic. (The broader random-op
    hypothesis suite in test_properties.py also drives prewarm/flush
    through its op alphabet.)"""
    hyp = pytest.importorskip(
        "hypothesis", reason="install the [test] extra for property tests")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(
        st.tuples(st.floats(0.0, 5_000.0), st.integers(0, 4),
                  st.sampled_from([128, 256, 512]),
                  st.booleans()),  # True = prewarm, False = invoke
        min_size=1, max_size=60)

    @settings(max_examples=40, deadline=None)
    @given(ops, st.integers(0, 3))
    def check(seq, seed):
        p = ContainerPool(ContainerConfig(capacity_mb=1_024.0,
                                          keepalive_ms=8_000.0), seed=seed)
        now, trace = 0.0, []
        for dt, fid, mem, is_prewarm in seq:
            now += dt
            if is_prewarm:
                before, _ = p.live_view(now)
                trace.append(("pw", p.prewarm(fid, mem, now, n=2)))
                after, _ = p.live_view(now)
                # a bet never shrinks any OTHER function's LIVE warm
                # set (expired sandboxes may be reaped to make room)
                for k, v in before.items():
                    if k != fid:
                        assert after.get(k, 0) >= v
            else:
                trace.append(("hit", p.acquire(fid, mem, now)))
                p.release(fid, mem, now)
            p.check_invariants()
            assert p.idle_mb <= 1_024.0 + 1e-6
        assert p.warm_hits + p.cold_starts == \
            sum(1 for *_, ip in seq if not ip)
        assert p.prewarmed == sum(t[1] for t in trace if t[0] == "pw")
        q = ContainerPool(ContainerConfig(capacity_mb=1_024.0,
                                          keepalive_ms=8_000.0), seed=seed)
        now2, trace2 = 0.0, []
        for dt, fid, mem, is_prewarm in seq:
            now2 += dt
            if is_prewarm:
                trace2.append(("pw", q.prewarm(fid, mem, now2, n=2)))
            else:
                trace2.append(("hit", q.acquire(fid, mem, now2)))
                q.release(fid, mem, now2)
        assert trace == trace2

    check()


def test_histogram_keepalive_tracks_interarrival_times():
    cfg = ContainerConfig(policy="histogram", keepalive_ms=1e9,
                          hist_min_ms=100.0, hist_max_ms=4_000.0)
    p = ContainerPool(cfg, seed=0)
    # a function arriving every 1s: keep-alive settles near ~1.25s
    for i in range(6):
        p.acquire(5, 256, i * 1_000.0)
        p.release(5, 256, i * 1_000.0 + 10.0)
    ka = p._keepalive_for(5, 6_000.0)
    assert 1_000.0 <= ka <= 2_000.0
    # prewarm hints apply before enough arrivals are observed
    hinted = ContainerPool(ContainerConfig(
        policy="histogram", prewarm={9: 3_000.0}), seed=0)
    assert hinted._keepalive_for(9, 0.0) == 3_000.0


# -- scheduler integration -----------------------------------------------------

def test_cold_start_occupies_core_and_is_billed():
    """Back-to-back invocations of one function: first is cold (billed
    init inflates execution), the second reuses the warm sandbox."""
    cfg = ContainerConfig(keepalive_ms=60_000, cold_jitter=0.0)
    tasks = mk_tasks([(0, 500), (2_000, 500)])
    res = run_policy("fifo", tasks, n_cores=2, ctx_switch_ms=0.0,
                     containers=cfg)
    first, second = sorted(res.tasks, key=lambda t: t.tid)
    assert first.cold_start and not second.cold_start
    assert first.init_ms == pytest.approx(expected_cold_ms(256))
    assert first.execution == pytest.approx(500 + first.init_ms)
    assert second.execution == pytest.approx(500)
    s = res.summary()
    assert s["cold_starts"] == 1 and s["cold_start_rate"] == 0.5
    assert s["init_cost_usd"] == pytest.approx(
        first.init_ms * price_per_ms(256))
    assert s["warm_hold_usd"] > 0


def test_concurrent_invocations_need_separate_sandboxes():
    # Two overlapping invocations of the same function cannot share one
    # container: both start cold.
    cfg = ContainerConfig(cold_jitter=0.0)
    tasks = mk_tasks([(0, 1_000), (0, 1_000)])
    for t in tasks:
        t.func_id = 1
    res = run_policy("fifo", tasks, n_cores=2, containers=cfg,
                     fresh_tasks=False)
    assert sum(t.cold_start for t in res.tasks) == 2


def test_keepalive_reaper_rides_parked_timer_machinery():
    """A quiescent gap parks the reaper; the next inject revives it, and
    the sandbox idled past its TTL during the gap is NOT reused."""
    cfg = ContainerConfig(keepalive_ms=2_000, sweep_ms=500,
                          cold_jitter=0.0)
    s = FIFO(n_cores=2, containers=cfg)
    s.prime([])
    s.inject(Task(tid=0, arrival=0.0, service=300.0, func_id=4), 0.0)
    s.step(1_000.0)
    assert len(s.completed) == 1
    assert s.containers.has_warm(4)
    # long quiescent gap >> TTL, then a new invocation of the same func
    s.inject(Task(tid=1, arrival=60_000.0, service=300.0, func_id=4),
             60_000.0)
    s.drain()
    t1 = next(t for t in s.completed if t.tid == 1)
    assert t1.cold_start
    st = s.containers.stats()
    assert st["evictions_ttl"] >= 1
    # exact accounting: the gap did not inflate the hold integral beyond
    # the 2s TTL per idle period
    assert st["warm_mb_ms"] <= 256 * 2_000.0 * 2 + 1e-6


def test_load_snapshot_reports_warm_set():
    cfg = ContainerConfig(keepalive_ms=60_000)
    s = FIFO(n_cores=2, containers=cfg)
    s.prime([])
    s.inject(Task(tid=0, arrival=0.0, service=100.0, func_id=3,
                  mem_mb=512), 0.0)
    s.step(500.0)
    snap = s.load_snapshot()
    assert snap["warm"] == {3: 1}
    assert snap["warm_mb"] == 512


def test_hybrid_and_cfs_support_containers(small_workload):
    cfg = ContainerConfig(keepalive_ms=30_000)
    w = small_workload[:300]
    for policy in ("hybrid", "cfs"):
        res = run_policy(policy, w, n_cores=8, containers=cfg)
        assert len(res.tasks) == len(w)
        s = res.summary()
        assert 0.0 < s["cold_start_rate"] <= 1.0
        assert s["init_cost_usd"] > 0


# -- failed-task metric guards (regression) -----------------------------------

def test_unfinished_task_metrics_are_nan_not_typeerror():
    t = Task(tid=0, arrival=5.0, service=100.0)
    assert math.isnan(t.execution)     # used to raise TypeError
    assert math.isnan(t.response)
    assert math.isnan(t.turnaround)
    assert not t.finished


def test_metric_rollups_skip_failed_invocations():
    done = mk_tasks([(0, 100), (0, 200)])
    for t in done:
        t.first_run, t.completion = t.arrival + 1.0, t.arrival + 301.0
    ghost = Task(tid=99, arrival=0.0, service=50.0, failed=True)
    # defensive: even a failed task merged into ``tasks`` cannot poison
    # the vectors with NaN
    res = SimResult(policy="fifo", tasks=done + [ghost], failed=[ghost])
    assert len(res.execution()) == 2
    assert not np.isnan(res.execution()).any()
    s = res.summary()
    assert s["n"] == 2 and s["failed"] == 1
    assert not math.isnan(s["cost_usd"])
    assert res.makespan() == pytest.approx(301.0)


def test_microvm_admission_rejects_do_not_break_summaries():
    tasks = mk_tasks([(i * 10.0, 50.0) for i in range(30)])
    from repro.core.simulate import admit_microvm
    admitted, failed = admit_microvm(tasks, cap=20)
    assert len(failed) == 10
    res = run_policy("fifo", admitted, n_cores=4)
    res.failed.extend(failed)
    s = res.summary()
    assert s["failed"] == 10 and s["n"] == 20


# -- cluster: warm-aware dispatch ---------------------------------------------

@pytest.fixture(scope="module")
def container_workload():
    from repro.traces import TraceSpec, generate_workload
    spec = TraceSpec(minutes=1, invocations_per_min=600, n_functions=40,
                     seed=5)
    return generate_workload(spec).tasks


def _fleet(workload, policy, dispatcher, **kw):
    return run_cluster(workload, n_nodes=2, cores_per_node=8,
                       node_policy=policy, dispatcher=dispatcher,
                       containers=ContainerConfig(keepalive_ms=30_000),
                       **kw)


def test_warm_affinity_cuts_cold_starts_vs_state_oblivious(
        container_workload):
    rates = {d: _fleet(container_workload, "hybrid", d).cold_start_rate()
             for d in ("round_robin", "warm_affinity")}
    assert rates["warm_affinity"] < rates["round_robin"] * 0.8


def test_warm_affinity_hybrid_cheaper_than_oblivious_cfs(
        container_workload):
    """The acceptance headline at test scale: warm-aware affinity on
    hybrid nodes is strictly cheaper than state-oblivious dispatch on
    CFS nodes once containers are modelled."""
    warm = _fleet(container_workload, "hybrid", "warm_affinity")
    for base_disp in ("round_robin", "least_loaded"):
        base = _fleet(container_workload, "cfs", base_disp)
        assert warm.cost_usd() < base.cost_usd()


def test_cost_aware_chases_the_warm_node():
    # Sequential same-function invocations with idle gaps: after the
    # first lands anywhere, every later one should chase the warm
    # sandbox instead of paying a cold start elsewhere.
    tasks = mk_tasks([(i * 3_000.0, 200.0) for i in range(6)])
    for t in tasks:
        t.func_id = 1
    from repro.cluster import ClusterSim
    sim = ClusterSim(n_nodes=3, cores_per_node=2, node_policies="fifo",
                     dispatcher="cost_aware",
                     containers=ContainerConfig(keepalive_ms=60_000,
                                                cold_jitter=0.0))
    sim.run(tasks, fresh_tasks=False)
    assert len({nid for _, nid in sim.assignments[1:]}) == 1


def test_cost_aware_prices_with_the_advertised_cold_model():
    """Routing must use the fleet's CONFIGURED cold-start penalty from
    node heartbeats, not module defaults: with a huge configured
    penalty the warm-but-loaded node wins; with a zero penalty the
    idle cold node wins."""
    from repro.cluster import CostAwareDispatch

    class FakeNode:
        def __init__(self, snap):
            self._snap = snap

        def snapshot(self):
            return self._snap

    task = Task(tid=0, arrival=0.0, service=10.0, mem_mb=1024, func_id=1)
    d = CostAwareDispatch()

    def nodes(base_ms):
        cold_idle = {"load": 0.0, "warm": {}, "cold_model": (base_ms, 0.0)}
        warm_busy = {"load": 3.0, "warm": {1: 1},
                     "cold_model": (base_ms, 0.0)}
        return [FakeNode(cold_idle), FakeNode(warm_busy)]

    # configured penalty (50 s) >> load term (3 x 1000 ms): chase warmth.
    # Module defaults (~375 ms for 1 GB) would pick the idle node here.
    assert d.select(task, nodes(50_000.0), 0.0) == 1
    # zero configured penalty: pure load balancing
    assert d.select(task, nodes(0.0), 0.0) == 0


def test_snapshot_advertises_cold_model():
    cfg = ContainerConfig(cold_base_ms=2_000.0, cold_per_gb_ms=7.0)
    s = FIFO(n_cores=1, containers=cfg)
    s.prime([])
    assert s.load_snapshot()["cold_model"] == (2_000.0, 7.0)


def test_least_loaded_warm_breaks_ties_toward_warm_node():
    from repro.cluster import ClusterSim
    tasks = mk_tasks([(0.0, 100.0), (5_000.0, 100.0)])
    for t in tasks:
        t.func_id = 2
    sim = ClusterSim(n_nodes=3, cores_per_node=2, node_policies="fifo",
                     dispatcher="least_loaded_warm",
                     containers=ContainerConfig(keepalive_ms=60_000))
    res = sim.run(tasks, fresh_tasks=False)
    assert sim.assignments[0][1] == sim.assignments[1][1]
    assert res.cold_starts() == 1


def test_fleet_summary_reports_container_economics(container_workload):
    res = _fleet(container_workload, "hybrid", "warm_affinity")
    s = res.summary()
    assert s["cold_starts"] == res.cold_starts() > 0
    assert s["warm_hold_usd"] > 0
    assert s["init_cost_usd"] > 0
    agg = res.container_stats()
    assert agg["cold_starts"] + agg["warm_hits"] >= len(container_workload)
    # without the layer, the schema stays stable at zeros
    off = run_cluster(container_workload[:100], n_nodes=2,
                      cores_per_node=8, node_policy="cfs",
                      dispatcher="least_loaded")
    s_off = off.summary()
    assert s_off["cold_start_rate"] == 0.0
    assert s_off["warm_hold_usd"] == 0.0
    assert off.container_stats() is None


def test_sweep_cell_runs_with_containers():
    from repro.cluster import Cell, run_cell
    row = run_cell(Cell(node_policy="hybrid", dispatcher="warm_affinity",
                        n_nodes=2, cores_per_node=4, minutes=1,
                        invocations_per_min=120.0, n_functions=12,
                        containers="histogram"))
    assert row["containers"] == "histogram"
    assert 0.0 < row["cold_start_rate"] <= 1.0
    assert row["warm_hold_usd"] > 0


def test_serving_gateway_threads_container_layer():
    from repro.configs import get_config
    from repro.serving.gateway import run_gateway
    from repro.traces import TraceSpec
    cfg = get_config("zamba2-1.2b")
    res = run_gateway(cfg, policy="hybrid", n_slots=8, n_fifo=4,
                      containers=ContainerConfig(keepalive_ms=30_000),
                      trace=TraceSpec(minutes=1, invocations_per_min=120,
                                      n_functions=12))
    assert res.sim.container_stats is not None
    assert res.sim.cold_start_rate() > 0


def test_serving_fleet_pools_get_distinct_seed_streams():
    """run_gateway_fleet must route containers through ClusterSim so
    each node's pool jitters with its own seed, not seed=0 fleet-wide."""
    from repro.configs import get_config
    from repro.serving.gateway import run_gateway_fleet
    from repro.traces import TraceSpec
    cfg = get_config("zamba2-1.2b")
    seen = []
    orig = ContainerPool.__init__

    def spy(self, config=None, *, seed=0, **kw):
        seen.append(seed)
        orig(self, config, seed=seed, **kw)

    ContainerPool.__init__ = spy
    try:
        run_gateway_fleet(cfg, policy="cfs", n_nodes=3, slots_per_node=4,
                          containers=ContainerConfig(keepalive_ms=30_000),
                          seed=7,
                          trace=TraceSpec(minutes=1,
                                          invocations_per_min=60,
                                          n_functions=6))
    finally:
        ContainerPool.__init__ = orig
    assert sorted(seen) == [7, 8, 9]


# -- per-function concurrency limits -------------------------------------------

def test_concurrency_cap_queues_excess_and_grants_fifo():
    p = _pool(capacity_mb=4096, keepalive_ms=1e9, max_concurrency=2)
    assert p.request_slot(7, 256, 0.0, tid=1) == "cold"
    assert p.request_slot(7, 256, 1.0, tid=2) == "cold"
    assert p.request_slot(7, 256, 2.0, tid=3) == "queued"
    assert p.request_slot(7, 256, 3.0, tid=4) == "queued"
    assert p.running_counts() == {7: 2}
    assert p.queue_depths() == {7: 2}
    p.check_invariants()
    # A completion frees one slot; the HEAD waiter is admitted warm
    # (the finishing invocation just returned its sandbox).
    assert p.release_slot(7, 256, 10.0) == [(3, "warm")]
    assert p.release_slot(7, 256, 11.0) == [(4, "warm")]
    assert p.release_slot(7, 256, 12.0) == []
    assert p.release_slot(7, 256, 13.0) == []
    assert p.running_counts() == {} and p.queue_depths() == {}
    s = p.stats()
    assert s["queued_concurrency"] == 2
    assert s["granted_from_queue"] == 2
    assert s["queue_depth"] == 0
    p.check_invariants()


def test_concurrency_cap_is_per_function():
    p = _pool(keepalive_ms=1e9, max_concurrency=1)
    assert p.request_slot(1, 128, 0.0, tid=0) == "cold"
    assert p.request_slot(2, 128, 0.0, tid=1) == "cold"  # other func free
    assert p.request_slot(1, 128, 0.0, tid=2) == "queued"
    assert p.running_counts() == {1: 1, 2: 1}
    assert p.queue_depths() == {1: 1}


def test_no_cap_never_queues():
    p = _pool(keepalive_ms=1e9)  # max_concurrency=None
    for tid in range(10):
        assert p.request_slot(4, 128, float(tid), tid=tid) != "queued"
    assert p.running_counts() == {4: 10}
    assert p.release_slot(4, 128, 20.0) == []
    p.check_invariants()


def test_release_slot_crash_path_and_mismatched_release():
    p = _pool(keepalive_ms=1e9, max_concurrency=1)
    p.request_slot(3, 256, 0.0, tid=0)
    assert p.request_slot(3, 256, 1.0, tid=1) == "queued"
    # keep_warm=False models a crashed/decommissioned sandbox: the slot
    # frees (the waiter runs) but nothing returns to the warm set.
    assert p.release_slot(3, 256, 5.0, keep_warm=False) == [(1, "cold")]
    assert not p.has_warm(3, 5.0)
    p.release_slot(3, 256, 6.0)
    with pytest.raises(ValueError, match="without a matching"):
        p.release_slot(3, 256, 7.0)
    p.check_invariants()


def test_max_concurrency_threads_through_spec():
    from repro.core.containers import ContainerSpec, as_container_config
    assert ContainerSpec().to_config().max_concurrency is None
    assert ContainerSpec(max_concurrency=3).to_config().max_concurrency == 3
    assert ContainerSpec.from_legacy(
        ContainerConfig(max_concurrency=2)).max_concurrency == 2
    assert as_container_config(
        {"max_concurrency": 4}).max_concurrency == 4


def test_concurrency_slots_property():
    """Random dispatch/complete interleavings: the cap is never
    exceeded, waiters exist only while the function is saturated,
    grants are FIFO, with a fixed per-function memory size warm+running
    sandboxes stay within the cap, and the ledgers reconcile."""
    pytest.importorskip(
        "hypothesis", reason="install the [test] extra for property tests")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(
        st.tuples(st.floats(0.0, 2_000.0), st.integers(0, 3),
                  st.booleans()),  # True = dispatch, False = complete
        min_size=1, max_size=80)

    @settings(max_examples=40, deadline=None)
    @given(ops, st.integers(1, 3), st.integers(0, 3))
    def check(seq, cap, seed):
        mem = {fid: 128.0 * (fid + 1) for fid in range(4)}
        p = ContainerPool(ContainerConfig(capacity_mb=1e6,
                                          keepalive_ms=1e9,
                                          max_concurrency=cap), seed=seed)
        running = {f: [] for f in range(4)}
        queued = {f: [] for f in range(4)}
        now, tid, n_queued, n_granted = 0.0, 0, 0, 0
        for dt, fid, is_dispatch in seq:
            now += dt
            if is_dispatch:
                r = p.request_slot(fid, mem[fid], now, tid=tid)
                if r == "queued":
                    queued[fid].append(tid)
                    n_queued += 1
                else:
                    assert r in ("warm", "cold")
                    running[fid].append(tid)
                tid += 1
            elif running[fid]:
                running[fid].pop(0)
                for gtid, how in p.release_slot(fid, mem[fid], now):
                    assert gtid == queued[fid].pop(0)  # FIFO grants
                    assert how in ("warm", "cold")
                    running[fid].append(gtid)
                    n_granted += 1
            p.check_invariants()
            counts, depths = p.running_counts(), p.queue_depths()
            live, _ = p.live_view(now)
            for f in range(4):
                assert counts.get(f, 0) == len(running[f]) <= cap
                assert depths.get(f, 0) == len(queued[f])
                assert live.get(f, 0) + len(running[f]) <= cap
                if queued[f]:  # never queue while a slot is free
                    assert len(running[f]) == cap
        s = p.stats()
        assert s["queued_concurrency"] == n_queued
        assert s["granted_from_queue"] == n_granted
        assert s["queue_depth"] == sum(len(q) for q in queued.values())

    check()
