"""Batched Monte-Carlo engine vs the scalar engine (DESIGN.md Sec. 16).

The contract under test: for every in-regime cell, ``repro.mc`` must
reproduce the scalar engine's per-task observables and summary
roll-ups BIT-FOR-BIT (x64 CPU), and everything out of regime must be
refused by the static gate — never silently approximated.

Fast tier: the gate itself and the python-backend front door (no JAX).
Slow tier (``--slow``): compiled equivalence — smoke trace, golden
battery across seeds x policies, sweep-backend parity, and a
hypothesis sweep over randomized small task grids.
"""
from dataclasses import replace

import pytest

import repro
from conftest import mk_tasks
from repro import FleetSpec, PolicySpec, Scenario, WorkloadSpec
from repro.mc import MonteCarlo, supported
from repro.mc.dispatch import tasks_supported
from repro.mc.engine import _bucket, cell_params
from repro.traces import TraceSpec

SMOKE_TRACE = TraceSpec(minutes=1, invocations_per_min=60.0,
                        n_functions=10, seed=0)


def _scenario(policy, n_cores=4, trace=SMOKE_TRACE, **kw):
    return Scenario(
        workload=WorkloadSpec(kind="azure", trace=trace),
        fleet=FleetSpec(cores_per_node=n_cores),
        policy=PolicySpec(name=policy, kw=kw))


def digest(res):
    """Exact per-task observable tuple; repr() so two floats compare
    bit-for-bit, not approximately."""
    return sorted((t.tid, repr(t.completion), t.preemptions,
                   t.ctx_switches, repr(t.first_run), t.migrations)
                  for t in res.raw.tasks)


def assert_bit_identical(sc):
    from repro.mc.engine import run_scenarios
    scalar = repro.run(sc)
    batched = run_scenarios([sc])[0]
    assert digest(batched) == digest(scalar)
    assert batched.summary() == scalar.summary()


# -- fast tier: the static regime gate -----------------------------------------

def _fleet_scenario(policy, dispatcher, n_nodes=3, n_cores=4,
                    trace=SMOKE_TRACE, seed=5, **kw):
    return Scenario(
        workload=WorkloadSpec(kind="azure", trace=trace),
        fleet=FleetSpec(n_nodes=n_nodes, cores_per_node=n_cores,
                        dispatcher=dispatcher, seed=seed),
        policy=PolicySpec(name=policy, kw=kw))


def test_gate_accepts_the_batched_regime():
    for policy in ("fifo", "cfs", "hybrid"):
        assert supported(_scenario(policy)) is None
    assert supported(_scenario("hybrid", n_fifo=1,
                               time_limit_ms=500.0)) is None


def test_gate_accepts_replayable_flat_fleets():
    """ISSUE 9: state-oblivious dispatchers decompose into independent
    per-node cells, so flat round_robin/random fleets are in-regime."""
    for disp in ("round_robin", "random"):
        for policy in ("fifo", "cfs", "hybrid"):
            assert supported(_fleet_scenario(policy, disp)) is None


def test_gate_refusals_carry_stable_counter_keys():
    from repro.mc.dispatch import reason_key
    why = supported(replace(
        _scenario("cfs"),
        fleet=FleetSpec(cores_per_node=4, containers="fixed")))
    assert reason_key(why) == "containers"
    why = supported(_fleet_scenario("cfs", "least_loaded"))
    assert reason_key(why) == "fleet_dispatcher"
    assert reason_key("a plain string") == "other"


@pytest.mark.parametrize("sc, why", [
    (replace(_scenario("cfs"), fleet=FleetSpec(n_nodes=2,
                                               cores_per_node=4,
                                               dispatcher="least_loaded")),
     "fleet"),
    (replace(_scenario("cfs"),
             fleet=FleetSpec(cores_per_node=4, containers="fixed")),
     "container"),
    (_scenario("fifo_quantum"), "not batched"),
    (replace(_scenario("hybrid"),
             policy=PolicySpec(name="hybrid", microvm=True)), "microvm"),
    (replace(_scenario("hybrid"),
             policy=PolicySpec(name="hybrid", adapt_pct=95.0)),
     "adaptive"),
    (replace(_scenario("hybrid"),
             policy=PolicySpec(name="hybrid", n_fifo=2)),
     "PolicySpec.n_fifo"),
    (_scenario("cfs", sched_latency_ms=10.0), "kwargs"),
    (_scenario("hybrid", n_fifo=0), "1 <= n_fifo"),
    (_scenario("hybrid", n_fifo=4), "1 <= n_fifo"),
])
def test_gate_refuses_out_of_regime(sc, why):
    reason = supported(sc)
    assert reason is not None and why in reason


def test_gate_refuses_noncanonical_task_streams():
    ok = mk_tasks([(0, 100), (50, 100)])
    assert tasks_supported(ok) is None
    assert "non-decreasing" in tasks_supported(
        mk_tasks([(50, 100), (0, 100)]))
    shifted = mk_tasks([(0, 100)])
    shifted[0].tid = 7
    assert "indices" in tasks_supported(shifted)
    ran = mk_tasks([(0, 100)])
    ran[0].remaining = 40.0
    assert "partially-run" in tasks_supported(ran)


def test_cell_params_and_bucket():
    C = 8
    assert cell_params(_scenario("fifo", n_cores=C)) == (C, float("inf"))
    assert cell_params(_scenario("cfs", n_cores=C)) == (0, float("inf"))
    assert cell_params(_scenario("hybrid", n_cores=C)) == (4, 1633.0)
    assert cell_params(_scenario("hybrid", n_cores=C, n_fifo=3,
                                 time_limit_ms=250.0)) == (3, 250.0)
    assert _bucket(1) == 64 and _bucket(64) == 64
    assert _bucket(65) == 128 and _bucket(94) == 128


# -- fast tier: the MonteCarlo front door (scalar backend, no JAX) -------------

def test_montecarlo_cells_cross_seeds_and_loads():
    mc = MonteCarlo(_scenario("hybrid"), seeds=(3, 4), loads=(0.5, 2.0))
    cells = mc.cells()
    assert [(c.workload.trace.seed, c.workload.load_scale)
            for c in cells] == [(3, 0.5), (3, 2.0), (4, 0.5), (4, 2.0)]
    assert all(c.policy == mc.scenario.policy for c in cells)


def test_montecarlo_python_backend_rows():
    mc = MonteCarlo(_scenario("fifo"), seeds=(0,), loads=(1.0, 2.0),
                    backend="python")
    out = mc.run()
    assert out.meta["backends"] == ["python", "python"]
    rows = out.rows
    assert [r["load_scale"] for r in rows] == [1.0, 2.0]
    assert all(r["backend"] == "python" and r["n"] > 0 for r in rows)
    # Heavier load must not lose work, only compress arrivals.
    assert rows[0]["n"] == rows[1]["n"]


def test_montecarlo_requires_trace_driven_workload():
    sc = Scenario(workload=WorkloadSpec(kind="tasks",
                                        tasks=mk_tasks([(0, 100)])),
                  fleet=FleetSpec(cores_per_node=2))
    with pytest.raises(ValueError, match="trace-driven"):
        MonteCarlo(sc).cells()


def test_run_scenarios_refuses_out_of_regime():
    from repro.mc.engine import run_scenarios
    sc = replace(_scenario("cfs"),
                 fleet=FleetSpec(cores_per_node=4, containers="fixed"))
    with pytest.raises(ValueError, match="outside the batched regime"):
        run_scenarios([sc])


# -- slow tier: compiled bit-identity ------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("policy", ["fifo", "cfs", "hybrid"])
def test_smoke_equivalence(policy):
    assert_bit_identical(_scenario(policy))


@pytest.mark.slow
def test_hybrid_knobs_equivalence():
    assert_bit_identical(_scenario("hybrid", n_fifo=1))
    assert_bit_identical(_scenario("hybrid", n_fifo=3,
                                   time_limit_ms=400.0))


@pytest.mark.slow
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("policy", ["fifo", "cfs", "hybrid"])
def test_golden_battery(policy, seed):
    """The issue's acceptance battery: denser trace, 8 cores, three
    seeds x three policies, every observable bit-identical."""
    trace = TraceSpec(minutes=1, invocations_per_min=300.0,
                      n_functions=25, seed=seed)
    assert_bit_identical(_scenario(policy, n_cores=8, trace=trace))


@pytest.mark.slow
def test_montecarlo_jax_matches_python():
    base = _scenario("hybrid")
    kw = dict(seeds=(0, 1), loads=(0.5, 1.5))
    jax_rows = MonteCarlo(base, backend="jax", **kw).run().rows
    py_rows = MonteCarlo(base, backend="python", **kw).run().rows
    assert [r["backend"] for r in jax_rows] == ["jax"] * 4
    strip = lambda r: {k: v for k, v in r.items() if k != "backend"}
    assert [strip(r) for r in jax_rows] == [strip(r) for r in py_rows]


@pytest.mark.slow
def test_montecarlo_mixed_grid_falls_back_transparently():
    """A fleet-shaped scenario is out of regime: the jax backend must
    route it to the scalar engine, not refuse the whole grid."""
    sc = replace(_scenario("cfs"),
                 fleet=FleetSpec(cores_per_node=4, containers="fixed"))
    out = MonteCarlo(sc, seeds=(0,), loads=(1.0,), backend="jax").run()
    assert out.meta == {"backends": ["python"], "fallback": 1,
                        "fallback_reasons": {"containers": 1}}
    assert out.rows[0]["fallback_reason"] == "containers"
    assert out.rows[0]["n"] > 0


@pytest.mark.slow
def test_sweep_backend_parity():
    from repro.cluster.sweep import build_grid, run_sweep
    grid = build_grid(("fifo", "cfs", "hybrid"), ["none"], [1],
                      (1.0, 2.0), cores_per_node=4, minutes=1,
                      invocations_per_min=60.0, n_functions=10, seed=0)
    py = run_sweep(grid, parallel=False)
    jx = run_sweep(grid, parallel=False, backend="jax")
    assert [r["backend"] for r in jx] == ["jax"] * len(jx)
    strip = lambda r: {k: v for k, v in r.items() if k != "backend"}
    assert [strip(r) for r in jx] == [strip(r) for r in py]


# -- slow tier: the newly-admitted fleet class (ISSUE 9) -----------------------

@pytest.mark.slow
@pytest.mark.parametrize("dispatcher", ["round_robin", "random"])
@pytest.mark.parametrize("policy", ["fifo", "cfs", "hybrid"])
def test_fleet_golden_battery(policy, dispatcher):
    """Flat replayable fleets: the batched engine must rebuild the
    exact ClusterResult ClusterSim produces — canonical task digest,
    summary roll-up, AND the dispatch bookkeeping (assignments,
    roster) bit-for-bit."""
    from repro.mc.engine import run_scenarios
    trace = TraceSpec(minutes=1, invocations_per_min=120.0,
                      n_functions=10, seed=1)
    sc = _fleet_scenario(policy, dispatcher, trace=trace)
    scalar = repro.run(sc)
    batched = run_scenarios([sc])[0]
    assert digest(batched) == digest(scalar)
    assert batched.summary() == scalar.summary()
    assert batched.raw.assignments == scalar.raw.assignments
    assert batched.raw.node_ids == scalar.raw.node_ids
    assert batched.raw.node_policies == scalar.raw.node_policies
    assert batched.raw.dispatcher == scalar.raw.dispatcher
    assert batched.raw.node_meta == scalar.raw.node_meta


@pytest.mark.slow
def test_montecarlo_fleet_cells_ride_the_device():
    sc = _fleet_scenario("hybrid", "round_robin")
    kw = dict(seeds=(0, 1), loads=(1.0,))
    out = MonteCarlo(sc, backend="jax", **kw).run()
    assert out.meta["backends"] == ["jax", "jax"]
    assert out.meta["fallback_reasons"] == {}
    py = MonteCarlo(sc, backend="python", **kw).run()
    strip = lambda r: {k: v for k, v in r.items() if k != "backend"}
    assert [strip(r) for r in out.rows] == [strip(r) for r in py.rows]


# -- slow tier: randomized small grids (hypothesis) ----------------------------

@pytest.mark.slow
def test_property_batched_matches_scalar():
    pytest.importorskip(
        "hypothesis", reason="install the [test] extra for property tests")
    from hypothesis import given, settings, strategies as st
    from repro.mc.engine import run_scenarios

    # Arrivals/services on a coarse ms grid (exactly representable
    # floats keep the scalar/batched comparison about scheduling, not
    # about decimal literals), every count padded into ONE (C=2, N=64)
    # bucket so the whole sweep pays a single XLA compile.
    specs = st.lists(
        st.tuples(st.integers(0, 2_000), st.integers(1, 400)),
        min_size=1, max_size=12)

    @settings(max_examples=20, deadline=None)
    @given(specs=specs,
           policy=st.sampled_from(["fifo", "cfs", "hybrid"]),
           n_fifo=st.integers(1, 1),
           limit=st.sampled_from([200.0, 1633.0]))
    def check(specs, policy, n_fifo, limit):
        specs = sorted(specs)
        tasks = mk_tasks([(float(a), float(s)) for a, s in specs])
        kw = dict(n_fifo=n_fifo, time_limit_ms=limit) \
            if policy == "hybrid" else {}
        sc = Scenario(workload=WorkloadSpec(kind="tasks", tasks=tasks),
                      fleet=FleetSpec(cores_per_node=2),
                      policy=PolicySpec(name=policy, kw=kw))
        assert supported(sc) is None
        scalar = repro.run(sc)
        batched = run_scenarios([sc])[0]
        assert digest(batched) == digest(scalar)
        assert batched.summary() == scalar.summary()

    check()


@pytest.mark.slow
def test_property_multi_event_paths_exercised():
    """ISSUE 9 acceptance: on randomized DENSE grids the kernel must
    retire strictly more than one event per while-loop iteration
    (cycle/window/micro paths engaged) while staying bit-identical.
    ``mc_stats['iters'] < mc_stats['events']`` is exactly "below the
    one-event-per-iteration bound" — the PR 7 kernel ran at
    iters == events."""
    pytest.importorskip(
        "hypothesis", reason="install the [test] extra for property tests")
    from hypothesis import given, settings, strategies as st
    from repro.mc.engine import run_scenarios

    # Dense: 24-48 tasks arriving inside half a second on 2 cores, so
    # runqueues go deep and alternation cycles/windows dominate. One
    # (C=2, N=64) bucket -> a single XLA compile for the whole sweep.
    specs = st.lists(
        st.tuples(st.integers(0, 500), st.integers(50, 400)),
        min_size=24, max_size=48)

    @settings(max_examples=10, deadline=None)
    @given(specs=specs,
           policy=st.sampled_from(["fifo", "cfs", "hybrid"]))
    def check(specs, policy):
        specs = sorted(specs)
        tasks = mk_tasks([(float(a), float(s)) for a, s in specs])
        kw = {"n_fifo": 1} if policy == "hybrid" else {}
        sc = Scenario(workload=WorkloadSpec(kind="tasks", tasks=tasks),
                      fleet=FleetSpec(cores_per_node=2),
                      policy=PolicySpec(name=policy, kw=kw))
        scalar = repro.run(sc)
        batched = run_scenarios([sc])[0]
        assert digest(batched) == digest(scalar)
        assert batched.summary() == scalar.summary()
        stats = batched.mc_stats
        assert stats["iters"] < stats["events"], \
            f"one-event pace: {stats} ({policy}, n={len(specs)})"

    check()
