"""Unit tests: scheduling policies on hand-checkable workloads."""
import numpy as np
import pytest

from repro.core import (CFS, EDF, FIFO, FIFOPreempt, HybridScheduler,
                        Rightsizer, TimeLimitAdapter, run_policy)
from repro.core.hybrid import percentile

from conftest import mk_tasks


def test_fifo_runs_to_completion_in_order():
    # one core, three tasks: strict FCFS, exec == service
    tasks = mk_tasks([(0, 100), (1, 50), (2, 10)])
    sched = FIFO(n_cores=1, ctx_switch_ms=0.0).run(tasks)
    done = sorted(sched.completed, key=lambda t: t.tid)
    assert [t.completion for t in done] == [100, 150, 160]
    for t in done:
        assert t.execution == pytest.approx(t.service)
        assert t.preemptions == 0


def test_fifo_head_of_line_blocking():
    # monster in front blocks the short task (the paper's Obs. 2)
    tasks = mk_tasks([(0, 10_000), (1, 10)])
    sched = FIFO(n_cores=1, ctx_switch_ms=0.0).run(tasks)
    short = sched.completed[-1]
    assert short.response == pytest.approx(9_999)


def test_fifo_preempt_moves_to_queue_end():
    # FIFO_100ms: long task cycles, short task gets in after one quantum
    tasks = mk_tasks([(0, 250), (1, 50)])
    sched = FIFOPreempt(quantum_ms=100, n_cores=1,
                        ctx_switch_ms=0.0).run(tasks)
    long_t, short_t = sched.completed[-1], sched.completed[0]
    assert short_t.tid == 1 and short_t.response == pytest.approx(99)
    assert long_t.preemptions == 2


def test_cfs_fairness_slices():
    # two equal tasks on one core finish at ~the same time under CFS
    tasks = mk_tasks([(0, 300), (0.5, 300)])
    sched = CFS(n_cores=1, ctx_switch_ms=0.0).run(tasks)
    c = sorted(t.completion for t in sched.completed)
    assert c[1] - c[0] < 30.0          # within ~one slice of each other
    assert all(t.execution > 1.5 * t.service for t in sched.completed)


def test_cfs_response_beats_fifo_under_load(small_workload):
    f = run_policy("fifo", small_workload, n_cores=10)
    c = run_policy("cfs", small_workload, n_cores=10)
    assert c.p("response", 99) < f.p("response", 99)
    assert c.p("execution", 99) > f.p("execution", 99)


def test_edf_prioritizes_deadlines():
    tasks = mk_tasks([(0, 1000), (1, 10)])   # deadlines 2000 / 21
    sched = EDF(n_cores=1, ctx_switch_ms=0.0).run(tasks)
    short = next(t for t in sched.completed if t.tid == 1)
    assert short.response == pytest.approx(0.0)   # preempted the monster
    monster = next(t for t in sched.completed if t.tid == 0)
    assert monster.preemptions == 1


def test_edf_simultaneous_arrivals_preempt_latest_deadline_victim():
    """A burst arriving at the same instant: cores fill in deadline
    order, and when the burst exceeds the core count, each extra
    arrival with a tighter deadline preempts the currently-running task
    with the LATEST deadline — never a tighter one."""
    # 2 cores; four tasks all at t=0. Deadlines (= arrival + 2*service):
    # a:2000, b:1600, c:400, d:100.
    tasks = mk_tasks([(0, 1000), (0, 800), (0, 200), (0, 50)])
    sched = EDF(n_cores=2, ctx_switch_ms=0.0).run(tasks)
    by_tid = {t.tid: t for t in sched.completed}
    assert len(by_tid) == 4
    # the two tightest deadlines run first (both effectively at t=0)
    assert by_tid[3].response == pytest.approx(0.0)
    assert by_tid[2].response == pytest.approx(0.0)
    # the loosest-deadline tasks were the preemption victims
    assert by_tid[0].preemptions >= 1
    assert by_tid[1].preemptions >= 1
    assert by_tid[2].preemptions == 0 and by_tid[3].preemptions == 0
    # work conservation: every task still completes exactly its service
    for t in sched.completed:
        assert t.cpu_time == pytest.approx(t.service)
        assert t.remaining <= 1e-9


def test_edf_simultaneous_arrival_does_not_double_preempt():
    """Two same-instant arrivals on a saturated single core: only the
    running task with the latest deadline is displaced, and a victim
    that raced to completion is not re-queued."""
    tasks = mk_tasks([(0, 500), (0, 100), (0, 100)])  # dls 1000/200/200
    sched = EDF(n_cores=1, ctx_switch_ms=0.0).run(tasks)
    assert len(sched.completed) == 3
    monster = next(t for t in sched.completed if t.tid == 0)
    # preempted at most once per tight arrival, and finishes last
    assert monster.completion == pytest.approx(700.0)
    assert sorted(t.tid for t in sched.completed) == [0, 1, 2]


def test_hybrid_migrates_over_limit():
    tasks = mk_tasks([(0, 500), (0, 50)])
    sched = HybridScheduler(n_cores=2, n_fifo=1, time_limit_ms=100,
                            ctx_switch_ms=0.0).run(tasks)
    long_t = next(t for t in sched.completed if t.tid == 0)
    short_t = next(t for t in sched.completed if t.tid == 1)
    assert long_t.migrations == 1       # moved FIFO -> CFS at 100ms
    assert short_t.migrations == 0
    assert short_t.execution == pytest.approx(short_t.service)


def test_hybrid_short_tasks_uninterrupted(small_workload):
    r = run_policy("hybrid", small_workload, n_cores=10,
                   time_limit_ms=1633.0)
    short = [t for t in r.tasks if t.service < 1000]
    assert short, "workload should contain short tasks"
    frac_clean = np.mean([t.preemptions == 0 for t in short])
    assert frac_clean > 0.95


def test_percentile_interpolation():
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)
    assert percentile([5.0], 99) == 5.0
    with pytest.raises(ValueError):
        percentile([], 50)


def test_adapter_window_is_bounded():
    a = TimeLimitAdapter(pct=90, window=100)
    for i in range(250):
        a.record(float(i), now=float(i))
    assert len(a.window) == 100
    assert a.limit() >= 150.0           # only the recent 100 matter


def test_rightsizer_migrates_cores(small_workload):
    r = run_policy("hybrid", small_workload, n_cores=10,
                   adapt_pct=95.0, rightsize=True)
    assert r.migrations is not None and len(r.migrations) > 0


def test_ghost_mode_inflates_execution(small_workload):
    ideal = run_policy("fifo", small_workload, n_cores=10)
    ghost = run_policy("fifo", small_workload, n_cores=10,
                       ghost_mode=True)
    assert ghost.execution().mean() > ideal.execution().mean()
