"""Scenario API: legacy-entrypoint shim equality, ContainerSpec
coercion, and the frozen v1 summary schema."""
import warnings
from dataclasses import replace

import pytest

import repro
from repro import (FleetSpec, PolicySpec, Scenario, ServingSpec,
                   WorkloadSpec, run)
from repro.core import ContainerConfig, ContainerSpec, as_container_config
from repro.core.containers import ContainerPool
from repro.core.events import Scheduler
from repro.core.simulate import run_policy
from repro.cluster.sim import run_cluster
from repro.cluster.sweep import Cell, run_cell
from repro.traces import TraceSpec, generate_workload
from repro.traces.workload import keepalive_hints

TR = TraceSpec(minutes=1, invocations_per_min=400, n_functions=16, seed=3)


@pytest.fixture(scope="module")
def tasks():
    return generate_workload(TR).tasks


# -- shim equality: every legacy entrypoint must produce a roll-up
# bit-identical to the Scenario it now builds internally. ---------------------

def test_run_policy_shim_matches_scenario(tasks):
    with pytest.warns(DeprecationWarning):
        old = run_policy("hybrid", tasks, n_cores=16, containers="fixed")
    new = run(Scenario(
        workload=WorkloadSpec(kind="tasks", tasks=tasks),
        fleet=FleetSpec(cores_per_node=16, containers="fixed"),
        policy=PolicySpec(name="hybrid"))).raw
    assert old.summary() == new.summary()


def test_run_cluster_shim_matches_scenario(tasks):
    with pytest.warns(DeprecationWarning):
        old = run_cluster(tasks, n_nodes=3, cores_per_node=6,
                          dispatcher="least_loaded", containers="fixed",
                          seed=5)
    new = run(Scenario(
        workload=WorkloadSpec(kind="tasks", tasks=tasks),
        fleet=FleetSpec(n_nodes=3, cores_per_node=6,
                        dispatcher="least_loaded", containers="fixed",
                        seed=5),
        policy=PolicySpec(name="hybrid"))).raw
    assert old.summary() == new.summary()


def test_run_gateway_shim_matches_scenario():
    from repro.configs import get_config
    from repro.serving.gateway import requests_from_trace, run_gateway
    cfg = get_config("deepseek-7b")
    with pytest.warns(DeprecationWarning):
        old = run_gateway(cfg, "hybrid", n_slots=12, n_fifo=6, trace=TR,
                          straggler_factor=3.0)
    new = run(Scenario(
        workload=WorkloadSpec(kind="tasks",
                              tasks=requests_from_trace(cfg, TR),
                              fresh=False),
        fleet=FleetSpec(cores_per_node=12),
        policy=PolicySpec(
            name="hybrid", adapt_pct=95.0, rightsize=True, n_fifo=6,
            serving=ServingSpec(model=cfg, straggler_factor=3.0))))
    assert old.sim.summary() == new.raw.summary()
    assert old.redispatches == getattr(new.raw, "redispatches", 0)


def test_run_gateway_fleet_shim_matches_scenario():
    from repro.configs import get_config
    from repro.serving.gateway import (requests_from_trace,
                                       run_gateway_fleet)
    cfg = get_config("deepseek-7b")
    with pytest.warns(DeprecationWarning):
        old = run_gateway_fleet(cfg, "hybrid", n_nodes=2,
                                slots_per_node=6, trace=TR, seed=4,
                                containers="fixed")
    new = run(Scenario(
        workload=WorkloadSpec(kind="tasks",
                              tasks=requests_from_trace(cfg, TR),
                              fresh=False),
        fleet=FleetSpec(n_nodes=2, cores_per_node=6,
                        dispatcher="least_loaded", containers="fixed",
                        seed=4),
        policy=PolicySpec(name="hybrid", adapt_pct=95.0, rightsize=True,
                          serving=ServingSpec(model=cfg)))).raw
    assert old.summary() == new.summary()


def test_run_cell_row_matches_scenario():
    cell = Cell(node_policy="hybrid", dispatcher="least_loaded",
                n_nodes=2, cores_per_node=6, containers="fixed",
                minutes=1, invocations_per_min=300, n_functions=12,
                seed=2)
    row = run_cell(cell)
    res = run(cell.to_scenario())
    for k, v in res.summary().items():
        assert row[k] == v, k
    # the grid axes ride along for the regression-gate cell key
    assert row["workload"] == "azure"
    assert row["node_policy"] == "hybrid"


def test_shims_reusable_workload_not_consumed(tasks):
    """The historical contract: callers may reuse their task list."""
    before = [(t.tid, t.arrival, t.service, t.remaining) for t in tasks]
    with pytest.warns(DeprecationWarning):
        run_policy("cfs", tasks, n_cores=16)
    after = [(t.tid, t.arrival, t.service, t.remaining) for t in tasks]
    assert before == after


# -- ContainerSpec: the one sandbox-pool spec every layer accepts. ------------

def test_container_spec_from_legacy_roundtrip():
    cfg = ContainerConfig(policy="fixed", capacity_mb=1024.0,
                          keepalive_ms=5000.0)
    spec = ContainerSpec.from_legacy(cfg)
    assert spec.policy == "fixed"
    assert spec.capacity_mb == 1024.0
    assert spec.keepalive_ms == 5000.0
    back = spec.to_config()
    assert back.policy == cfg.policy
    assert back.capacity_mb == cfg.capacity_mb
    assert back.keepalive_ms == cfg.keepalive_ms
    # idempotent
    assert ContainerSpec.from_legacy(spec) is spec


def test_container_spec_from_strings_and_dicts():
    assert ContainerSpec.from_legacy(None) is None
    assert ContainerSpec.from_legacy("off") is None
    assert ContainerSpec.from_legacy("fixed").policy == "fixed"
    spec = ContainerSpec.from_legacy(
        {"policy": "fixed", "capacity_mb": 2048.0})
    assert spec.capacity_mb == 2048.0
    with pytest.raises((TypeError, ValueError)):
        ContainerSpec.from_legacy(42)


def test_container_spec_histogram_hints_match_legacy(tasks):
    """ContainerSpec's histogram policy must reproduce the old
    hand-rolled generate -> keepalive_hints wiring exactly."""
    spec = ContainerSpec(policy="histogram", capacity_mb=4096.0,
                         keepalive_ms=30_000.0)
    new_cfg = spec.to_config(tasks)
    base = ContainerConfig(policy="histogram", capacity_mb=4096.0,
                           keepalive_ms=30_000.0)
    old_cfg = replace(base, prewarm=keepalive_hints(tasks, base))
    assert new_cfg.prewarm == old_cfg.prewarm
    assert new_cfg.policy == old_cfg.policy


def test_as_container_config_accepts_everything(tasks):
    assert as_container_config(None) is None
    assert as_container_config("off") is None
    pool = ContainerPool(ContainerConfig(), seed=0)
    assert as_container_config(pool) is pool
    cfg = ContainerConfig(capacity_mb=512.0)
    assert as_container_config(cfg) is cfg
    out = as_container_config({"policy": "fixed", "capacity_mb": 512.0})
    assert isinstance(out, ContainerConfig)
    assert out.capacity_mb == 512.0


def test_scheduler_accepts_container_spec(tasks):
    """Scheduler coerces spec / dict / str directly — no manual
    ContainerPool plumbing needed anywhere."""
    from repro.core.policies import FIFO
    from repro.core.metrics import collect
    import copy
    results = []
    for containers in (ContainerSpec(policy="fixed"), "fixed",
                       {"policy": "fixed"}):
        sched = FIFO(n_cores=16, containers=containers)
        sched.run(copy.deepcopy(tasks))
        results.append(collect(sched, "fifo").summary())
    assert results[0] == results[1] == results[2]
    assert results[0]["cold_starts"] > 0


# -- versioned summary schema -------------------------------------------------

# Frozen copy of the v1 key set: the schema contract is additive-only,
# so this literal must NEVER shrink or change — only grow in a v2.
V1_KEYS = (
    "schema_version", "workload", "policy", "dispatcher", "n_nodes",
    "cores_per_node", "n", "failed", "n_requests", "p99_turnaround_s",
    "makespan_s", "cost_usd", "total_cost_usd", "usd_per_1k_requests",
    "cold_starts", "cold_start_rate", "init_cost_usd", "warm_hold_usd",
    "shed", "rejected_cost_usd", "requeued", "chaos_events", "queued",
    "spilled", "prewarmed",
)


def test_summary_schema_frozen():
    assert repro.SCHEMA_VERSION == 1
    assert set(V1_KEYS) <= set(repro.SUMMARY_KEYS_V1), \
        "v1 summary keys were removed — the schema is additive-only"


@pytest.mark.parametrize("fleet", [False, True])
def test_summary_carries_v1_keys(tasks, fleet):
    fl = FleetSpec(n_nodes=2, cores_per_node=8,
                   dispatcher="least_loaded") if fleet \
        else FleetSpec(cores_per_node=16)
    s = run(Scenario(workload=WorkloadSpec(kind="tasks", tasks=tasks),
                     fleet=fl, policy=PolicySpec(name="hybrid"))).summary()
    missing = set(repro.SUMMARY_KEYS_V1) - set(s)
    assert not missing, missing
    assert s["schema_version"] == repro.SCHEMA_VERSION
    assert s["n_requests"] == s["n"] > 0
    assert s["usd_per_1k_requests"] == pytest.approx(
        s["total_cost_usd"] / s["n_requests"] * 1000.0)


def test_summary_same_keys_single_vs_fleet(tasks):
    """The whole point of the versioned frame: benchmarks, the gate and
    the dashboard read ONE schema regardless of topology."""
    single = run(Scenario(
        workload=WorkloadSpec(kind="tasks", tasks=tasks),
        fleet=FleetSpec(cores_per_node=16),
        policy=PolicySpec(name="cfs"))).summary()
    fleet = run(Scenario(
        workload=WorkloadSpec(kind="tasks", tasks=tasks),
        fleet=FleetSpec(n_nodes=2, cores_per_node=8,
                        dispatcher="least_loaded"),
        policy=PolicySpec(name="cfs"))).summary()
    assert set(repro.SUMMARY_KEYS_V1) <= set(single) & set(fleet)


def test_scenario_determinism(tasks):
    a = run(Scenario(workload=WorkloadSpec(kind="tasks", tasks=tasks),
                     fleet=FleetSpec(n_nodes=2, cores_per_node=8,
                                     dispatcher="least_loaded", seed=9),
                     policy=PolicySpec(name="hybrid"))).summary()
    b = run(Scenario(workload=WorkloadSpec(kind="tasks", tasks=tasks),
                     fleet=FleetSpec(n_nodes=2, cores_per_node=8,
                                     dispatcher="least_loaded", seed=9),
                     policy=PolicySpec(name="hybrid"))).summary()
    assert a == b


def test_lazy_package_exports():
    assert callable(repro.run)
    assert repro.Scenario is Scenario
    with pytest.raises(AttributeError):
        repro.does_not_exist
