"""Fleet resilience & elasticity: chaos harness, admission control,
predictive pre-warming, learned dispatch, sharded sweeps, trend report,
and the remove_node decommission fix."""
import json
import math
import sys
from pathlib import Path

import pytest

from repro.cluster import (AdmissionConfig, AdmissionControl, ChaosEvent,
                           ChaosSchedule, ClusterSim, CostAwareDispatch,
                           PrewarmConfig, Provisioner, build_grid,
                           build_plan, churn_preset, kill_heal, merge_rows,
                           run_cluster, shard_grid)
from repro.core import ContainerConfig, ContainerPool, Task
from repro.core.containers import expected_cold_ms
from repro.costmodel.pricing import DEFAULT_PRICING

from conftest import mk_tasks

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from benchmarks import regression_gate as gate  # noqa: E402
from benchmarks import trend_report  # noqa: E402


@pytest.fixture(scope="module")
def fleet_workload():
    from repro.traces import TraceSpec, generate_workload
    spec = TraceSpec(minutes=1, invocations_per_min=900, n_functions=40,
                     seed=3)
    return generate_workload(spec).tasks


CC = ContainerConfig(keepalive_ms=30_000.0, cold_jitter=0.0)


# -- chaos schedules -----------------------------------------------------------

def test_chaos_schedule_sorts_and_validates():
    s = ChaosSchedule(events=(
        ChaosEvent(t=50.0, action="heal"),
        ChaosEvent(t=10.0, action="kill", node="node0"),
    ))
    assert [e.t for e in s] == [10.0, 50.0]
    with pytest.raises(ValueError):
        ChaosEvent(t=0.0, action="explode")
    with pytest.raises(ValueError):
        kill_heal(100.0, 50.0)


def test_kill_requeues_in_flight_work(fleet_workload):
    """A killed node's unfinished invocations restart elsewhere: nothing
    is lost, progress is reset, and the victim's FINISHED work still
    counts in the fleet roll-up."""
    chaos = kill_heal(15_000.0, 40_000.0, node="node0", spec="hybrid")
    sim = ClusterSim(n_nodes=3, cores_per_node=8, node_policies="hybrid",
                     dispatcher="least_loaded", containers=CC)
    res = sim.run(fleet_workload, chaos=chaos)
    assert len(res.tasks) == len(fleet_workload)
    assert len(res.failed) == 0 and len(res.shed) == 0
    assert sorted(t.tid for t in res.tasks) == \
        sorted(t.tid for t in fleet_workload)
    assert res.requeued() > 0
    # requeued tasks carry the retry marker and a clean billed span
    retried = [t for t in res.tasks if t.retries > 0]
    assert len(retried) == res.requeued()
    for t in retried:
        assert t.completion > 15_000.0     # finished after the kill
        assert t.first_run is not None
    # the victim is retired, the healed node is live
    assert res.n_retired == 1
    ids = {n.node_id for n in sim.nodes}
    assert "node0" not in ids and "node3" in ids
    # per-event fleet metrics
    assert [e["action"] for e in res.chaos_events] == ["kill", "heal"]
    assert res.chaos_events[0]["requeued"] == res.requeued()


def test_chaos_determinism_same_seed_same_rollup(fleet_workload):
    """Same seed + same schedule => bit-identical fleet roll-ups."""
    chaos = churn_preset(60_000.0, "hybrid")
    adm = AdmissionConfig(max_load=1.5, overload_action="queue")
    outs = []
    for _ in range(2):
        sim = ClusterSim(n_nodes=3, cores_per_node=8,
                         node_policies="hybrid", dispatcher="cost_aware",
                         seed=11, containers=CC, admission=adm)
        res = sim.run(fleet_workload,
                      chaos=chaos,
                      prewarm=Provisioner.from_workload(fleet_workload))
        outs.append((list(sim.assignments), res.summary(),
                     sorted((t.tid, t.completion) for t in res.tasks)))
    assert outs[0] == outs[1]


def test_kill_stops_victims_warm_pool_meter_at_kill():
    """The killed node's warm pool is destroyed AT the kill instant:
    idle memory is metered up to then and no further."""
    tasks = mk_tasks([(0.0, 100.0), (0.0, 100.0)])
    sim = ClusterSim(n_nodes=2, cores_per_node=2, node_policies="fifo",
                     dispatcher="round_robin", containers=CC)
    res = sim.run(tasks, chaos=ChaosSchedule(events=(
        ChaosEvent(t=5_000.0, action="kill", node="node0"),)),
        fresh_tasks=False)
    victim = next(n for n in sim._retired if n.node_id == "node0")
    pool = victim.sched.containers
    assert pool.idle_mb == 0.0
    done = victim.sched.completed[0]
    assert pool.warm_mb_ms == pytest.approx(
        256 * (5_000.0 - done.completion))
    assert pool.evictions_flush == 1
    assert len(res.tasks) == 2


def test_flush_warm_event_forces_cold_restarts():
    """flush_warm wipes the pool but keeps the node: the next invocation
    of a previously-warm function pays a cold start."""
    tasks = mk_tasks([(0.0, 100.0), (10_000.0, 100.0)])
    for t in tasks:
        t.func_id = 7
    sim = ClusterSim(n_nodes=1, cores_per_node=2, node_policies="fifo",
                     dispatcher="round_robin", containers=CC)
    res = sim.run(tasks, chaos=ChaosSchedule(events=(
        ChaosEvent(t=5_000.0, action="flush_warm", node="node0"),)),
        fresh_tasks=False)
    by_tid = {t.tid: t for t in res.tasks}
    assert by_tid[0].cold_start
    assert by_tid[1].cold_start          # would be warm without the wipe
    assert res.chaos_events[0]["warm_flushed"] == 1
    assert res.n_retired == 0


def test_heal_without_spec_uses_schedule_default():
    """A spec-less heal brings up the SCHEDULE's heal_spec policy, not
    a hardcoded hybrid."""
    tasks = mk_tasks([(0.0, 50.0), (6_000.0, 50.0)])
    sim = ClusterSim(n_nodes=1, cores_per_node=1, node_policies="cfs",
                     dispatcher="round_robin")
    sim.run(tasks, chaos=ChaosSchedule(events=(
        ChaosEvent(t=5_000.0, action="heal"),), heal_spec="cfs"),
        fresh_tasks=False)
    assert [n.policy for n in sim.nodes] == ["cfs", "cfs"]


def test_shed_after_conforming_admit_refunds_consumed_token():
    """A task that CONSUMED a token (conforming) and is then shed by
    the load ceiling also refunds it — not just queued reservations."""
    adm = AdmissionConfig(rate_per_s=1.0, burst=1.0, rate_action="shed",
                          max_load=0.5, overload_action="shed")
    ac = AdmissionControl(adm)
    busy = [{"load": 2.0}]
    assert ac.decide(_T(0, func_id=3), busy, 0.0)[0] == "shed"
    # the bucket is untouched: the very next arrival conforms
    assert ac.decide(_T(1, func_id=3), [], 1.0)[0] == "admit"
    assert ac.stats()["shed_overload"] == 1


def test_chaos_event_on_missing_node_is_noop():
    tasks = mk_tasks([(0.0, 50.0)])
    sim = ClusterSim(n_nodes=1, cores_per_node=1, node_policies="fifo",
                     dispatcher="round_robin")
    res = sim.run(tasks, chaos=ChaosSchedule(events=(
        ChaosEvent(t=10.0, action="kill", node="node9"),)),
        fresh_tasks=False)
    assert res.chaos_events[0]["action"] == "kill:noop"
    assert len(res.tasks) == 1


def test_kill_of_last_node_sheds_remaining_work():
    tasks = mk_tasks([(0.0, 10_000.0), (5_000.0, 100.0)])
    sim = ClusterSim(n_nodes=1, cores_per_node=1, node_policies="fifo",
                     dispatcher="round_robin",
                     admission=AdmissionConfig(rate_per_s=1.0, burst=5.0))
    res = sim.run(tasks, chaos=ChaosSchedule(events=(
        ChaosEvent(t=1_000.0, action="kill", node="node0"),)),
        fresh_tasks=False)
    # the in-flight task and the later arrival have nowhere to go
    assert len(res.tasks) == 0
    assert len(res.shed) == 2
    assert all(t.failed for t in res.shed)
    # the admission books balance even for fleet-empty sheds: counted,
    # and the consumed rate tokens refunded (nothing left charged)
    assert sim.admission.shed_no_capacity >= 1
    assert not sim.admission._rate_charged
    # an all-shed run still summarizes (zeros, not an IndexError)
    s = res.summary()
    assert s["n"] == 0 and s["shed"] == 2
    assert s["makespan_s"] == 0.0 and s["cost_usd"] == 0.0


def test_consumed_provisioner_is_rejected():
    tasks = mk_tasks([(60_500.0, 500.0), (61_000.0, 500.0)])
    prov = Provisioner.from_workload(tasks)
    sim = ClusterSim(n_nodes=1, cores_per_node=2, node_policies="fifo",
                     dispatcher="round_robin", containers=CC)
    sim.run(tasks, prewarm=prov)
    fresh = ClusterSim(n_nodes=1, cores_per_node=2, node_policies="fifo",
                       dispatcher="round_robin", containers=CC)
    with pytest.raises(ValueError, match="already consumed"):
        fresh.run(tasks, prewarm=prov)


# -- admission control ---------------------------------------------------------

class _T:
    def __init__(self, tid, func_id=0):
        self.tid = tid
        self.func_id = func_id


def test_token_bucket_gcra_conformance():
    """rate 1/s, burst 2: two immediate admits, the third sheds, and the
    sustained rate is honoured afterwards."""
    ac = AdmissionControl(rate_per_s=1.0, burst=2.0, rate_action="shed")
    assert ac.decide(_T(0), [], 0.0)[0] == "admit"
    assert ac.decide(_T(1), [], 0.0)[0] == "admit"
    assert ac.decide(_T(2), [], 0.0)[0] == "shed"
    assert ac.decide(_T(3), [], 1_000.0)[0] == "admit"  # 1 token matured
    assert ac.decide(_T(4), [], 1_000.0)[0] == "shed"
    st = ac.stats()
    assert st["admitted"] == 3 and st["shed"] == st["shed_rate"] == 2


def test_token_bucket_queue_reserves_future_token():
    ac = AdmissionControl(rate_per_s=1.0, burst=1.0, rate_action="queue")
    assert ac.decide(_T(0), [], 0.0)[0] == "admit"
    outcome, when = ac.decide(_T(1), [], 0.0)
    assert outcome == "queue" and when == pytest.approx(1_000.0)
    # re-presentation skips the bucket (token already reserved)
    assert ac.decide(_T(1), [], when, first=False)[0] == "admit"
    assert ac.stats()["queue_wait_ms"] == pytest.approx(1_000.0)
    # the reservation consumed the t=1000 token: a fresh arrival at
    # t=1000 queues behind it
    assert ac.decide(_T(2), [], 1_000.0)[0] == "queue"


def test_shed_completed_failed_partition_every_arrival(fleet_workload):
    """Admission accounting: every arrival ends in exactly one of
    {completed, shed, failed}, and shed invocations are priced."""
    adm = AdmissionConfig(rate_per_s=0.5, burst=2.0, rate_action="shed")
    res = run_cluster(fleet_workload, n_nodes=2, cores_per_node=8,
                      node_policy="fifo", dispatcher="least_loaded",
                      admission=adm)
    s = res.summary()
    assert s["shed"] > 0
    assert s["n"] + s["shed"] + s["failed"] == len(fleet_workload)
    shed_tids = {t.tid for t in res.shed}
    done_tids = {t.tid for t in res.tasks}
    assert not (shed_tids & done_tids)
    assert shed_tids | done_tids == {t.tid for t in fleet_workload}
    assert all(t.failed for t in res.shed)
    assert res.rejected_cost_usd() == pytest.approx(
        s["shed"] * DEFAULT_PRICING.price_per_request)
    assert res.total_cost_usd() == pytest.approx(
        res.cost_usd() + res.rejected_cost_usd())


def test_overload_queue_delays_but_completes_everything():
    """Load ceiling with queue action: nothing is lost, the overflow
    invocation just waits at the (unbilled) front door."""
    tasks = mk_tasks([(0.0, 1_000.0), (0.0, 1_000.0), (0.0, 1_000.0)])
    adm = AdmissionConfig(max_load=0.5, overload_action="queue",
                          queue_backoff_ms=100.0, max_queue_ms=60_000.0)
    sim = ClusterSim(n_nodes=1, cores_per_node=2, node_policies="fifo",
                     dispatcher="round_robin", admission=adm)
    res = sim.run(tasks, fresh_tasks=False)
    assert len(res.tasks) == 3 and len(res.shed) == 0
    assert sim.admission.queued > 0
    assert sim.admission.queue_wait_ms > 0
    late = max(res.tasks, key=lambda t: t.completion)
    assert late.response >= 900.0        # held until a core drained


def test_overload_spill_overrides_dispatcher_pick():
    """Spill: when the whole fleet is past the ceiling, the invocation
    is admitted anyway but force-routed to the least-loaded node, not
    the dispatcher's (affinity) pick."""
    adm = AdmissionConfig(max_load=0.9, overload_action="spill")
    sim = ClusterSim(n_nodes=2, cores_per_node=1, node_policies="fifo",
                     dispatcher="affinity", admission=adm)
    # two functions whose ring owners differ, so both nodes load up
    owners = {f: sim.dispatcher.owner(f, sim.nodes) for f in range(16)}
    fa = next(f for f, o in owners.items() if o == 0)
    fb = next(f for f, o in owners.items() if o == 1)
    tasks = mk_tasks([(0.0, 10_000.0), (1.0, 10_000.0), (2.0, 10_000.0)])
    tasks[0].func_id, tasks[1].func_id, tasks[2].func_id = fa, fb, fa
    res = sim.run(tasks, fresh_tasks=False)
    assert sim.admission.spilled == 1    # the third arrival spilled
    assert sim.admission.admitted == 3
    assert len(res.tasks) == 3 and len(res.shed) == 0


def test_overload_queue_gives_up_after_max_queue_ms():
    tasks = mk_tasks([(0.0, 60_000.0), (10.0, 100.0)])
    adm = AdmissionConfig(max_load=0.5, overload_action="queue",
                          queue_backoff_ms=100.0, max_queue_ms=1_000.0)
    sim = ClusterSim(n_nodes=1, cores_per_node=1, node_policies="fifo",
                     dispatcher="round_robin", admission=adm)
    res = sim.run(tasks, fresh_tasks=False)
    assert len(res.tasks) == 1 and len(res.shed) == 1
    assert res.shed[0].tid == 1
    assert sim.admission.shed_overload == 1


def test_chaos_requeue_bypasses_admission():
    """A requeued invocation was already admitted once: the retry must
    not be re-charged against the rate bucket (a tight shed-on-rate
    limit would otherwise reject already-running work) nor double-count
    'admitted'."""
    tasks = mk_tasks([(0.0, 8_000.0), (1.0, 100.0)])
    adm = AdmissionConfig(rate_per_s=0.2, burst=2.0, rate_action="shed")
    sim = ClusterSim(n_nodes=2, cores_per_node=1, node_policies="fifo",
                     dispatcher="round_robin", admission=adm)
    res = sim.run(tasks, chaos=ChaosSchedule(events=(
        ChaosEvent(t=1_000.0, action="kill", node="node0"),)),
        fresh_tasks=False)
    assert res.requeued() == 1
    assert len(res.tasks) == 2 and len(res.shed) == 0
    # one admission decision per ORIGINAL arrival only
    assert sim.admission.admitted == 2


def test_shed_after_rate_queue_refunds_the_token():
    """queue-on-rate + shed-on-overload: a task that reserved a future
    token and is then shed by the load ceiling gives the token back."""
    adm = AdmissionConfig(rate_per_s=1.0, burst=1.0, rate_action="queue",
                          max_load=0.5, overload_action="shed")
    ac = AdmissionControl(adm)
    busy = [{"load": 2.0}]
    assert ac.decide(_T(0, func_id=3), [], 0.0)[0] == "admit"
    outcome, when = ac.decide(_T(1, func_id=3), [], 0.0)
    assert outcome == "queue"                     # token reserved
    assert ac.decide(_T(1, func_id=3), busy, when,
                     first=False)[0] == "shed"    # overload kills it
    # the refunded token is immediately available to the next arrival
    assert ac.decide(_T(2, func_id=3), [], when)[0] == "admit"


def test_remove_node_feeds_final_completions_to_learner():
    """Graceful removal drains the node; those completions must still
    reach a learning dispatcher before the node is retired."""
    tasks = mk_tasks([(0.0, 1_000.0), (10.0, 1_000.0)])
    sim = ClusterSim(n_nodes=1, cores_per_node=1, node_policies="fifo",
                     dispatcher="cost_aware", containers=CC)
    for task in tasks:
        sim.nodes[0].step(task.arrival)
        i = sim.dispatcher.select(task, sim.nodes, task.arrival)
        sim.nodes[i].inject(task, task.arrival)
    sim.remove_node(0)
    # the second dispatch saw load 1.0: its completion must have been
    # harvested during removal and trained the estimator
    assert sim.dispatcher.n_observed == 1
    assert not sim.dispatcher._dispatch_load     # no leaked feedback keys


def test_admission_config_validation():
    with pytest.raises(ValueError):
        AdmissionConfig(rate_action="drop")
    with pytest.raises(ValueError):
        AdmissionConfig(overload_action="bounce")
    with pytest.raises(ValueError):
        AdmissionConfig(rate_per_s=0.0)   # would divide by zero later
    with pytest.raises(ValueError):
        AdmissionConfig(burst=0.0)


# -- predictive pre-warming ----------------------------------------------------

def test_build_plan_reads_per_minute_counts():
    tasks = []
    tid = 0
    # func 1: 30 invocations in minute 1; func 2: a single one (below
    # min_per_min); func 3: 10 in minute 0.
    for i in range(30):
        tasks.append(Task(tid=tid, arrival=60_000.0 + i * 1_000.0,
                          service=2_000.0, func_id=1, mem_mb=512))
        tid += 1
    tasks.append(Task(tid=tid, arrival=65_000.0, service=100.0, func_id=2))
    tid += 1
    for i in range(10):
        tasks.append(Task(tid=tid, arrival=i * 100.0, service=100.0,
                          func_id=3))
        tid += 1
    plan = build_plan(tasks, PrewarmConfig(lead_ms=2_000.0, min_per_min=2))
    rows = {(fid): (t, mem, n) for t, fid, mem, n in plan}
    assert 2 not in rows                 # single invocation: no bet
    t1, mem1, n1 = rows[1]
    assert t1 == pytest.approx(58_000.0)  # one lead ahead of minute 1
    assert mem1 == 512
    assert n1 == 1                       # 30 x 2s / 60s = 1 concurrent
    t3, _, n3 = rows[3]
    assert t3 == 0.0                     # minute 0 clamps to the origin
    assert n3 == 1


def test_pool_prewarm_never_evicts_live_sandboxes():
    """Speculative provisioning respects capacity and never sacrifices
    an observed-warm container for a bet."""
    p = ContainerPool(ContainerConfig(capacity_mb=1_024.0,
                                      keepalive_ms=60_000.0,
                                      cold_jitter=0.0), seed=0)
    p.release(1, 512, 0.0)               # real warmth
    placed = p.prewarm(2, 512, 10.0, n=3)
    assert placed == 1                   # room for one, then stop
    assert p.has_warm(1)                 # the real sandbox survived
    assert p.prewarmed == 1
    assert p.evictions_capacity == 0
    p.check_invariants()
    # a pre-warmed sandbox is a normal warm hit afterwards
    assert p.acquire(2, 512, 20.0)
    # ...and expired pre-warm slots are reaped so a bet can re-enter
    q = ContainerPool(ContainerConfig(capacity_mb=512.0,
                                      keepalive_ms=1_000.0), seed=0)
    q.prewarm(1, 512, 0.0)
    assert q.prewarm(2, 512, 5_000.0) == 1   # func 1's slot expired
    q.check_invariants()


def test_fleet_prewarm_cuts_cold_starts(fleet_workload):
    kw = dict(n_nodes=3, cores_per_node=8, node_policy="hybrid",
              dispatcher="warm_affinity", containers=CC)
    reactive = run_cluster(fleet_workload, **kw)
    prov = Provisioner.from_workload(fleet_workload)
    warmed = run_cluster(fleet_workload, prewarm=prov, **kw)
    assert warmed.cold_start_rate() < reactive.cold_start_rate()
    st = warmed.prewarm_stats
    assert st["placed"] > 0 and st["placed"] <= st["requested"]
    assert warmed.summary()["prewarmed"] == st["placed"]
    # pre-warming is paid for in provider-side hold dollars
    assert warmed.warm_hold_usd() > 0.0


def test_prewarm_placement_follows_affinity_owner():
    """With an affinity-family dispatcher, warmth lands on the ring
    owner — the node routing will send the function to."""
    tasks = [Task(tid=i, arrival=60_000.0 + i * 100.0, service=500.0,
                  func_id=4) for i in range(10)]
    sim = ClusterSim(n_nodes=3, cores_per_node=2, node_policies="fifo",
                     dispatcher="affinity", containers=CC)
    res = sim.run(tasks, prewarm=Provisioner.from_workload(tasks),
                  fresh_tasks=False)
    owner = sim.dispatcher.owner(4, sim.nodes)
    pool = sim.nodes[owner].sched.containers
    assert pool.prewarmed >= 1
    # the first invocation of the burst hit the pre-warmed sandbox
    first = min(res.tasks, key=lambda t: t.tid)
    assert not first.cold_start


# -- learned cost-aware dispatch ----------------------------------------------

def test_rls_converges_to_true_slope():
    d = CostAwareDispatch(queue_ms_per_load=1_000.0, prior_weight=1.0)
    for i in range(200):
        t = Task(tid=i, arrival=0.0, service=100.0)
        t.first_run = 0.0
        t.completion = 100.0 + 300.0 * 2.0   # inflation = 300 x load
        d._dispatch_load[i] = 2.0
        d.observe_completion(t)
    assert d.coeff == pytest.approx(300.0, rel=0.05)
    assert d.n_observed == 200


def test_unobserved_dispatcher_routes_like_fixed_coefficient():
    d = CostAwareDispatch(queue_ms_per_load=1_000.0)
    assert d.coeff == 1_000.0
    # zero-load completions carry no slope information
    t = Task(tid=0, arrival=0.0, service=100.0)
    t.first_run, t.completion = 0.0, 100.0
    d._dispatch_load[0] = 0.0
    d.observe_completion(t)
    assert d.coeff == 1_000.0
    # learn=False pins the constant forever
    frozen = CostAwareDispatch(learn=False)
    frozen.observe_completion(t)
    assert frozen.coeff == frozen.queue_ms_per_load


def test_learned_dispatch_learns_contention_on_fleet(fleet_workload):
    """After a CFS fleet run the learned coefficient has moved off the
    prior and reflects observed contention inflation (> 0)."""
    sim = ClusterSim(n_nodes=2, cores_per_node=8, node_policies="cfs",
                     dispatcher="cost_aware", containers=CC)
    sim.run(fleet_workload)
    d = sim.dispatcher
    assert d.n_observed > 100
    assert d.coeff != pytest.approx(1_000.0)
    assert d.coeff >= 0.0


def test_learned_dispatch_is_deterministic(fleet_workload):
    w = fleet_workload[:400]
    outs = []
    for _ in range(2):
        sim = ClusterSim(n_nodes=3, cores_per_node=8, node_policies="cfs",
                         dispatcher="cost_aware", seed=4, containers=CC)
        res = sim.run(w)
        outs.append((list(sim.assignments), sim.dispatcher.coeff,
                     res.summary()))
    assert outs[0] == outs[1]


# -- remove_node decommission (regression) ------------------------------------

def test_remove_node_closes_warm_meter_and_reaper():
    """Regression: graceful removal used to leave the node's warm pool
    (and its parked keep-alive reaper) dangling — the idle memory held
    between the node's last event and its decommission was never
    metered. Removal must settle the hold integral to the removal
    instant, destroy the warm set, and clear the parked timers."""
    tasks = mk_tasks([(0.0, 100.0), (0.0, 100.0)])
    sim = ClusterSim(n_nodes=2, cores_per_node=2, node_policies="fifo",
                     dispatcher="round_robin", containers=CC)
    sim.run(tasks, fresh_tasks=False)
    node = sim.nodes[0]
    assert node.sched._parked_timers            # reaper parked post-drain
    done = node.sched.completed[0]
    removed = sim.remove_node(0, t=5_000.0)
    pool = removed.sched.containers
    assert removed is node
    assert not removed.sched._parked_timers     # reaper died with the node
    assert pool.idle_mb == 0.0                  # warm set destroyed
    # exact metering: idle from completion to the removal instant
    assert pool.warm_mb_ms == pytest.approx(
        256 * (5_000.0 - done.completion))
    # the roll-up is stable however often it is recomputed
    r1 = sim.result().warm_hold_usd()
    r2 = sim.result().warm_hold_usd()
    assert r1 == r2 > 0.0


def test_remove_node_meter_stops_at_expiry_when_ttl_lapsed():
    """If the keep-alive lapsed during the quiescent gap, decommission
    meters only to the EXPIRY instant (TTL eviction), not to removal."""
    cc = ContainerConfig(keepalive_ms=2_000.0, cold_jitter=0.0)
    tasks = mk_tasks([(0.0, 100.0)])
    sim = ClusterSim(n_nodes=1, cores_per_node=1, node_policies="fifo",
                     dispatcher="round_robin", containers=cc)
    sim.run(tasks, fresh_tasks=False)
    done = sim.nodes[0].sched.completed[0]
    removed = sim.remove_node(0, t=60_000.0)
    pool = removed.sched.containers
    assert pool.evictions_ttl == 1 and pool.evictions_flush == 0
    assert pool.warm_mb_ms == pytest.approx(256 * 2_000.0)
    assert done.completion + 2_000.0 < 60_000.0


# -- sharded sweeps ------------------------------------------------------------

def _tiny_grid():
    return build_grid(["cfs", "fifo"], ["random", "round_robin"], [2],
                      cores_per_node=2, minutes=1,
                      invocations_per_min=60.0, n_functions=6)


def test_shard_grid_partitions_deterministically():
    grid = _tiny_grid()
    shards = [shard_grid(grid, f"{i}/3") for i in range(3)]
    flat = [c for s in shards for c in s]
    assert len(flat) == len(grid)
    assert len({id(c) for c in flat}) == len(grid)      # disjoint cover
    assert shard_grid(grid, "1/3") == shards[1]         # stable
    with pytest.raises(ValueError):
        shard_grid(grid, "3/3")
    with pytest.raises(ValueError):
        shard_grid(grid, "nope")


def test_merge_rows_equals_unsharded_run(tmp_path):
    """Per-shard artifacts merge into exactly the rows an unsharded
    sweep produces, in canonical order."""
    from repro.cluster import run_sweep
    grid = _tiny_grid()
    full = run_sweep(grid, parallel=False)
    paths = []
    for i in range(2):
        rows = run_sweep(shard_grid(grid, f"{i}/2"), parallel=False)
        p = tmp_path / f"shard{i}.json"
        p.write_text(json.dumps({"meta": {}, "rows": rows}))
        paths.append(str(p))
    merged = merge_rows(paths)
    key = lambda r: (r["node_policy"], r["dispatcher"])  # noqa: E731
    assert sorted(merged, key=key) == sorted(full, key=key)


# -- regression gate: resilience artifact -------------------------------------

def _res_row(cost, chaos="churn", admission="on", prewarm="on"):
    return {"node_policy": "hybrid", "dispatcher": "cost_aware",
            "n_nodes": 4, "chaos": chaos, "admission": admission,
            "prewarm": prewarm, "cost_usd": cost, "n": 100,
            "makespan_s": 10.0}


def test_gate_fails_on_cost_regression_under_chaos_preset(tmp_path):
    prev = [_res_row(1.0), _res_row(2.0, chaos="off")]
    new = [_res_row(1.4), _res_row(2.0, chaos="off")]
    failures, notes = gate.compare(prev, new, threshold=0.15)
    # ONE failure: the churn cell regressed; the chaos-off cell (a
    # distinct key) did not, so it produced no second failure.
    assert len(failures) == 1
    assert "churn" in failures[0] and "cost_usd" in failures[0]


def test_gate_resilience_cells_key_on_feature_axes():
    a = gate.cell_key(_res_row(1.0))
    b = gate.cell_key(_res_row(1.0, admission="off"))
    c = gate.cell_key({"node_policy": "hybrid", "dispatcher": "cost_aware",
                       "n_nodes": 4, "cost_usd": 1.0})
    assert a != b
    assert c == gate.cell_key(_res_row(1.0, chaos="off", admission="off",
                                       prewarm="off"))


# -- trend report --------------------------------------------------------------

def _write_artifacts(d, cost, evps):
    d.mkdir(parents=True, exist_ok=True)
    (d / "cluster_matrix.json").write_text(json.dumps({"matrix": [
        {"node_policy": "hybrid", "dispatcher": "warm_affinity",
         "n_nodes": 4, "containers": "fixed", "cost_usd": cost,
         "n": 100, "makespan_s": 10.0}]}))
    (d / "BENCH_engine.json").write_text(json.dumps([
        {"policy": "cfs", "containers": "off", "n_cores": 16,
         "n_tasks": 1000, "events_per_sec": evps}]))


def test_trend_report_folds_history_and_flags_regressions(tmp_path):
    hist = tmp_path / "hist"
    for i, (cost, evps) in enumerate([(1.0, 100_000.0), (1.05, 98_000.0),
                                      (0.95, 101_000.0)]):
        _write_artifacts(hist / str(i), cost, evps)
    cur = tmp_path / "cur"
    _write_artifacts(cur, 1.5, 50_000.0)   # cost up 50%, engine halved
    series = trend_report.collect_series(
        trend_report.discover_history(hist), cur)
    assert set(series) == {"cluster", "engine"}
    cl = series["cluster"][0]
    assert cl["latest"] == 1.5 and cl["median"] == pytest.approx(1.0)
    assert cl["delta"] == pytest.approx(0.5)
    assert len(cl["series"]) == 4
    md = trend_report.to_markdown(series)
    assert "moving the wrong way" in md
    assert "⚠" in md and "cluster" in md and "engine" in md
    # CLI round trip writes both artifacts
    out, mdf = tmp_path / "trend.json", tmp_path / "TREND.md"
    rc = trend_report.main(["--history", str(hist), "--current", str(cur),
                            "--out", str(out), "--md", str(mdf)])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert payload["history_runs"] == 3
    assert mdf.read_text().startswith("# Benchmark trends")


def test_sparkline_shapes():
    assert trend_report.sparkline([]) == ""
    assert trend_report.sparkline([1.0, 1.0]) == "▄▄"
    s = trend_report.sparkline([0.0, 0.5, 1.0])
    assert s[0] == "▁" and s[-1] == "█"


def test_trend_flags_regression_from_zero_baseline():
    """A cell whose history is all 0.0 and whose latest value is
    nonzero must warn (∞ regression), not render as missing data."""
    e = {"cell": "x", "metric": "cost_usd", "direction": "lower",
         "latest": 1.0, "median": 0.0, "delta": None, "series": [0.0, 1.0]}
    assert trend_report._regressed(e)
    assert "⚠" in trend_report._delta_cell(e)
    md = trend_report.to_markdown({"cluster": [e]})
    assert "moving the wrong way" in md


def test_discover_history_sorts_numerically(tmp_path):
    """Run 10 must not sort between runs 1 and 2 once history grows."""
    for name in [str(i) for i in range(12)] + ["zzz"]:
        (tmp_path / name).mkdir()
    order = [d.name for d in trend_report.discover_history(tmp_path)]
    assert order == [str(i) for i in range(12)] + ["zzz"]


# -- resilience bench smoke (headline contract) --------------------------------

def test_resilience_bench_rows_carry_gate_keys():
    from benchmarks.resilience_bench import VARIANTS
    assert {v[0] for v in VARIANTS} == \
        {"reactive", "admission", "prewarm", "full"}
    # the full variant is the learned dispatcher + both layers
    full = next(v for v in VARIANTS if v[0] == "full")
    assert full[1] == "cost_aware" and full[2] and full[3]
