"""Correlated-failure topology: zones/racks/SKUs, retry storms with
backoff + breaker, slow-not-dead degradation, zone-aware dispatch, and
the per-function concurrency cap wired into the cluster dispatch path."""
import json
import sys
from pathlib import Path

import pytest

from repro.cluster import (ChaosEvent, ChaosSchedule, ClusterSim,
                           RetryPolicy, RetryState, SKUS, TopologySpec,
                           as_sku, make_retry, zone_failure_preset)
from repro.cluster.topology import NodePlacement, SlowdownDial
from repro.core import ContainerConfig, Task
from repro.scenario import (FleetSpec, PolicySpec, ResilienceSpec,
                            Scenario, SUMMARY_KEYS_V1, WorkloadSpec, run)
from repro.traces import TraceSpec

from conftest import mk_tasks

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from benchmarks import regression_gate as gate  # noqa: E402
from benchmarks import trend_report  # noqa: E402


@pytest.fixture(scope="module")
def fleet_workload():
    from repro.traces import TraceSpec, generate_workload
    spec = TraceSpec(minutes=1, invocations_per_min=900, n_functions=40,
                     seed=3)
    return generate_workload(spec).tasks


CC = ContainerConfig(keepalive_ms=30_000.0, cold_jitter=0.0)

TOPO = TopologySpec(zones=("z0", "z1"), racks_per_zone=2,
                    nodes_per_rack=1,
                    sku_pattern=("std", "spot", "std", "spot"),
                    cross_zone_ms=30.0, heal_zone="z0")


# -- topology spec -------------------------------------------------------------

def test_placement_fills_racks_in_order():
    topo = TopologySpec(zones=("a", "b"), racks_per_zone=2,
                        nodes_per_rack=2, sku_pattern=("std", "spot"))
    places = topo.placement()
    assert topo.n_nodes == len(places) == 8
    assert [p.zone for p in places] == ["a"] * 4 + ["b"] * 4
    assert [p.rack for p in places] == \
        ["a-r0", "a-r0", "a-r1", "a-r1", "b-r0", "b-r0", "b-r1", "b-r1"]
    # SKU pattern cycles over nodes in placement order.
    assert [p.sku.name for p in places] == ["std", "spot"] * 4
    # Placement is a pure function of the spec.
    assert topo.placement() == places


def test_home_zone_and_heal_placement():
    topo = TopologySpec(zones=("z0", "z1", "z2"), heal_zone="z2")
    assert [topo.home_zone(f) for f in range(6)] == \
        ["z0", "z1", "z2", "z0", "z1", "z2"]
    heal = topo.heal_placement()
    assert heal.zone == "z2" and heal.rack == "z2-heal"
    assert heal.sku.name == "std"


def test_topology_validation_errors():
    with pytest.raises(ValueError):
        TopologySpec(zones=())
    with pytest.raises(ValueError):
        TopologySpec(racks_per_zone=0)
    with pytest.raises(ValueError):
        TopologySpec(cross_zone_ms=-1.0)
    with pytest.raises(KeyError):
        TopologySpec(sku_pattern=("gpu-9000",))
    with pytest.raises(ValueError):
        import dataclasses
        dataclasses.replace(SKUS["std"], clock=0.0)
    with pytest.raises(ValueError):
        import dataclasses
        dataclasses.replace(SKUS["std"], spot_discount=0.5)  # non-spot


def test_sku_effective_price_and_dial():
    spot = as_sku("spot")
    assert spot.effective_price_mult == pytest.approx(
        spot.price_mult * (1.0 - spot.spot_discount))
    assert as_sku("std").effective_price_mult == 1.0
    # rate = clock * (1 - degrade); fn(t) = 1 - rate.
    dial = SlowdownDial(clock=0.8)
    assert dial(0.0) == pytest.approx(1.0 - 0.8)
    dial.degrade = 0.5
    assert dial(123.0) == pytest.approx(1.0 - 0.8 * 0.5)
    dial.degrade = 0.0
    assert dial(999.0) == pytest.approx(0.2)


# -- retry policy --------------------------------------------------------------

def test_backoff_doubles_and_caps():
    pol = RetryPolicy(base_ms=100.0, cap_ms=500.0, jitter_frac=0.0)
    waits = [pol.backoff_ms(a, tid=7, seed=0) for a in range(1, 6)]
    assert waits == [100.0, 200.0, 400.0, 500.0, 500.0]


def test_backoff_jitter_is_deterministic_and_bounded():
    pol = RetryPolicy(base_ms=100.0, cap_ms=1e9, jitter_frac=0.5)
    a = pol.backoff_ms(3, tid=42, seed=11)
    assert a == pol.backoff_ms(3, tid=42, seed=11)       # pure function
    assert a != pol.backoff_ms(3, tid=43, seed=11)       # spreads by tid
    assert a != pol.backoff_ms(3, tid=42, seed=12)       # and by seed
    assert 400.0 <= a <= 600.0                           # 400 * (1 ± 0.5/...)


def test_retry_budget_sheds():
    st = RetryState(RetryPolicy(budget=2, jitter_frac=0.0), seed=0)
    task = mk_tasks([(0.0, 100.0)])[0]
    verdicts = []
    for _ in range(4):
        verdict, when = st.on_failure(task, 1_000.0)
        verdicts.append(verdict)
        if verdict == "retry":
            task.retries += 1
    assert verdicts == ["retry", "retry", "shed", "shed"]
    s = st.stats()
    assert s["retries"] == 2 and s["shed_budget"] == 2
    assert s["retry_wait_ms"] > 0.0


def test_circuit_breaker_trips_per_function():
    pol = RetryPolicy(budget=100, breaker_threshold=3,
                      breaker_window_ms=1_000.0, jitter_frac=0.0)
    st = RetryState(pol, seed=0)
    tasks = mk_tasks([(0.0, 10.0)] * 6)
    for task in tasks:
        task.func_id = 5
    # Three failures inside the window trip the breaker; the next shed.
    outs = [st.on_failure(t, 100.0 + i) for i, t in enumerate(tasks[:4])]
    assert [v for v, _ in outs] == ["retry", "retry", "retry", "shed"]
    assert st.stats()["breaker_trips"] == 1
    assert st.stats()["shed_breaker"] == 1
    # A different function is unaffected.
    other = tasks[4]
    other.func_id = 9
    assert st.on_failure(other, 105.0)[0] == "retry"
    # Outside the window the breaker closes again.
    late = tasks[5]
    assert st.on_failure(late, 10_000.0)[0] == "retry"


def test_make_retry_coercions():
    assert make_retry(None, seed=0) is None
    st = make_retry({"budget": 3}, seed=1)
    assert isinstance(st, RetryState) and st.policy.budget == 3
    st2 = make_retry(RetryPolicy(budget=4), seed=2)
    assert st2.policy.budget == 4
    assert make_retry(st2, seed=9) is st2


# -- correlated chaos ----------------------------------------------------------

def _sim(policy="hybrid", dispatcher="least_loaded", topo=TOPO, **kw):
    return ClusterSim(cores_per_node=8, node_policies=policy,
                      dispatcher=dispatcher, seed=0, containers=CC,
                      topology=topo, **kw)


def test_topology_actions_require_topology(fleet_workload):
    chaos = ChaosSchedule(events=(
        ChaosEvent(t=10_000.0, action="kill_zone", zone="z1"),))
    sim = ClusterSim(n_nodes=2, cores_per_node=8, containers=CC)
    with pytest.raises(ValueError, match="topology"):
        sim.run(fleet_workload, chaos=chaos)


def test_kill_zone_removes_whole_zone_and_work_completes(fleet_workload):
    chaos = ChaosSchedule(events=(
        ChaosEvent(t=15_000.0, action="kill_zone", zone="z1"),))
    sim = _sim()
    res = sim.run(fleet_workload, chaos=chaos)
    assert all(n.zone == "z0" for n in sim.nodes)
    assert len(sim.nodes) == 2          # z1's two nodes are gone
    assert len(res.tasks) == len(fleet_workload)
    assert not res.failed
    rec = next(r for r in res.chaos_events if r["action"] == "kill_zone")
    assert len(rec["nodes"]) == 2


def test_revoke_spot_kills_only_spot_nodes(fleet_workload):
    chaos = ChaosSchedule(events=(
        ChaosEvent(t=15_000.0, action="revoke_spot"),))
    sim = _sim()
    res = sim.run(fleet_workload, chaos=chaos)
    assert all(not n.spot for n in sim.nodes)
    assert len(sim.nodes) == 2
    assert res.revoked() == 2
    assert res.summary()["revoked"] == 2
    assert len(res.tasks) == len(fleet_workload)


def test_degrade_slows_and_restore_closes_interval(fleet_workload):
    chaos = ChaosSchedule(events=(
        ChaosEvent(t=5_000.0, action="degrade", zone="z0", severity=0.6),
        ChaosEvent(t=25_000.0, action="restore", zone="z0"),
    ))
    res = _sim().run(fleet_workload, chaos=chaos)
    s = res.summary()
    # Two z0 nodes degraded for 20s each.
    assert s["degraded_ms"] == pytest.approx(40_000.0)
    assert len(res.tasks) == len(fleet_workload)
    # Slow-not-dead: the brownout stretches executions vs a calm run.
    calm = _sim().run(fleet_workload)
    assert res.summary()["p99_slowdown"] >= calm.summary()["p99_slowdown"]


def test_unclosed_degrade_interval_is_still_metered(fleet_workload):
    chaos = ChaosSchedule(events=(
        ChaosEvent(t=5_000.0, action="degrade", zone="z1", severity=0.3),))
    res = _sim().run(fleet_workload, chaos=chaos)
    assert res.summary()["degraded_ms"] > 0.0


# -- retry integration ---------------------------------------------------------

def test_retry_storm_waits_and_bounded_by_budget(fleet_workload):
    chaos = zone_failure_preset(60_000.0, kill="z1", brownout="z0",
                                node_policy="hybrid")
    sim = _sim()
    res = sim.run(fleet_workload, chaos=chaos,
                  retry=RetryPolicy(budget=8, breaker_threshold=0))
    s = res.summary()
    assert s["retries"] > 0 and s["retry_wait_ms"] > 0.0
    assert all(t.retries <= 8 for t in res.tasks)
    # Budget sized above the storm: nothing shed, everything completes.
    assert s["shed"] == 0
    assert s["n"] == len(fleet_workload)


def test_tiny_retry_budget_sheds_through_admission(fleet_workload):
    chaos = zone_failure_preset(60_000.0, kill="z1", brownout="z0",
                                node_policy="hybrid")
    sim = _sim(admission={"max_queue_ms": 1e12})
    res = sim.run(fleet_workload, chaos=chaos,
                  retry=RetryPolicy(budget=0, jitter_frac=0.0))
    s = res.summary()
    assert s["shed"] > 0
    assert sim.admission.stats()["shed_retry"] == s["shed"]
    # Partition: every arrival either completed or was shed, never both.
    done = {t.tid for t in res.tasks}
    shed = {t.tid for t in sim.shed}
    assert done.isdisjoint(shed)
    assert done | shed == {t.tid for t in fleet_workload}


# -- zone-aware dispatch & pricing ---------------------------------------------

def test_cross_zone_dispatch_counted_and_penalized(fleet_workload):
    sim = _sim()
    res = sim.run(fleet_workload)
    s = res.summary()
    assert s["cross_zone"] == sim.cross_zone
    # least_loaded ignores zones, so a busy fleet does hop.
    assert s["cross_zone"] > 0


def test_cost_aware_prefers_home_zone(fleet_workload):
    base = _sim(dispatcher="least_loaded").run(fleet_workload).summary()
    aware = _sim(dispatcher="cost_aware").run(fleet_workload).summary()
    assert aware["cross_zone"] < base["cross_zone"]


def test_spot_savings_and_sku_pricing(fleet_workload):
    res = _sim().run(fleet_workload)
    s = res.summary()
    assert s["spot_savings_usd"] > 0.0
    # Spot discount makes the heterogeneous bill cheaper than the same
    # placement priced all-std.
    flat = TopologySpec(zones=("z0", "z1"), racks_per_zone=2,
                        nodes_per_rack=1, sku_pattern=("std",),
                        cross_zone_ms=30.0)
    flat_cost = _sim(topo=flat).run(fleet_workload).summary()["cost_usd"]
    assert s["cost_usd"] < flat_cost
    meta = {m["sku"] for m in res.node_meta}
    assert meta == {"std", "spot"}


def test_flat_fleet_new_summary_keys_are_zero(fleet_workload):
    """No topology, no retry: the additive keys exist and read zero."""
    sim = ClusterSim(n_nodes=4, cores_per_node=8, containers=CC)
    s = sim.run(fleet_workload).summary()
    for key in ("retries", "revoked", "cross_zone"):
        assert s[key] == 0
    for key in ("retry_wait_ms", "degraded_ms", "spot_savings_usd"):
        assert s[key] == 0.0


def test_full_stack_same_seed_bit_identical(fleet_workload):
    import copy

    def go():
        chaos = zone_failure_preset(60_000.0, node_policy="hybrid")
        sim = _sim(dispatcher="cost_aware")
        res = sim.run(copy.deepcopy(fleet_workload), chaos=chaos,
                      retry=RetryPolicy(budget=8, breaker_threshold=0))
        return json.dumps(res.summary(), sort_keys=True)

    assert go() == go()


# -- satellite 1: concurrency cap shapes cluster traffic -----------------------

def test_slot_cap_queues_and_grants_in_fleet_metrics():
    """With max_concurrency=1, same-function dispatches to one node
    serialize through the pool slot queue — the waits show up in the
    fleet container stats and the cap is actually respected."""
    cc = ContainerConfig(keepalive_ms=1e9, cold_jitter=0.0,
                         max_concurrency=1)
    tasks = mk_tasks([(0.0, 400.0), (0.0, 400.0), (0.0, 400.0)])
    sim = ClusterSim(n_nodes=1, cores_per_node=8, containers=cc)
    res = sim.run(tasks)
    assert len(res.tasks) == 3 and not res.failed
    cs = res.container_stats()
    assert cs["queued_concurrency"] == 2
    assert cs["granted_from_queue"] == 2
    # Cap=1: executions of the single function never overlap.
    spans = sorted((t.first_run, t.completion) for t in res.tasks)
    for (_, end), (start, _) in zip(spans, spans[1:]):
        assert start >= end - 1e-6


def test_slot_cap_off_is_bit_identical(fleet_workload):
    """No cap configured: the slot-routed dispatch path is bypassed and
    the fleet roll-up matches the historical direct-inject path."""
    a = ClusterSim(n_nodes=3, cores_per_node=8, containers=CC)
    sa = a.run(fleet_workload).summary()
    big = ContainerConfig(keepalive_ms=30_000.0, cold_jitter=0.0,
                          max_concurrency=10_000)
    b = ClusterSim(n_nodes=3, cores_per_node=8, containers=big)
    sb = b.run(fleet_workload).summary()
    assert sa["cost_usd"] == sb["cost_usd"]
    assert sa["p99_slowdown"] == sb["p99_slowdown"]


# -- satellite 2: node death with queued slot waiters --------------------------

def test_remove_node_grants_slot_waiters():
    cc = ContainerConfig(keepalive_ms=1e9, cold_jitter=0.0,
                         max_concurrency=1)
    tasks = mk_tasks([(0.0, 500.0), (0.0, 500.0)])
    sim = ClusterSim(n_nodes=1, cores_per_node=8, containers=cc)
    res = sim.run(tasks)
    # Graceful drain granted the waiter; nothing stranded, both done.
    assert len(res.tasks) == 2 and not res.failed
    assert res.container_stats()["granted_from_queue"] >= 1


def test_zone_kill_requeues_slot_waiters(fleet_workload):
    """A killed node holding queued slot waiters must hand them back to
    the dispatcher, not strand them: everything still completes and the
    requeue is visible in the chaos log."""
    cc = ContainerConfig(keepalive_ms=30_000.0, cold_jitter=0.0,
                         max_concurrency=1)
    chaos = ChaosSchedule(events=(
        ChaosEvent(t=10_000.0, action="kill_zone", zone="z1"),))
    sim = ClusterSim(cores_per_node=8, node_policies="hybrid",
                     dispatcher="least_loaded", seed=0, containers=cc,
                     topology=TOPO)
    res = sim.run(fleet_workload, chaos=chaos)
    assert len(res.tasks) == len(fleet_workload)
    assert not res.failed
    rec = next(r for r in res.chaos_events if r["action"] == "kill_zone")
    assert rec.get("slot_requeued", 0) + rec.get("requeued", 0) > 0


# -- scenario API --------------------------------------------------------------

def test_scenario_runs_topology_and_retry():
    sc = Scenario(
        workload=WorkloadSpec(trace=TraceSpec(
            minutes=1, invocations_per_min=600, n_functions=20, seed=5)),
        fleet=FleetSpec(topology=TOPO, cores_per_node=8,
                        dispatcher="least_loaded"),
        policy=PolicySpec(),
        resilience=ResilienceSpec(
            chaos=zone_failure_preset(60_000.0, node_policy="hybrid"),
            retry=RetryPolicy(budget=8, breaker_threshold=0)),
    )
    s = run(sc).summary()
    assert set(SUMMARY_KEYS_V1) <= set(s)
    assert s["n"] > 0 and s["chaos_events"] > 0
    assert s["retries"] >= 0 and s["degraded_ms"] > 0.0


# -- gate / trend wiring -------------------------------------------------------

def test_gate_cell_key_topology_axes_default_off():
    old = {"node_policy": "cfs", "dispatcher": "least_loaded",
           "chaos": "off", "minutes": 1}
    new = dict(old, zones="2", spot="on", retry="on")
    assert gate.cell_key(old) != gate.cell_key(new)
    # Old rows (pre-topology artifacts) key identically to new rows
    # with the axes explicitly off.
    assert gate.cell_key(old) == gate.cell_key(
        dict(old, zones="off", spot="off", retry="off"))


def test_trend_report_knows_topology_kind():
    fname, key_fn, metric, direction, _ = trend_report.KINDS["topology"]
    assert fname == "BENCH_topology.json"
    assert key_fn is gate.cell_key
    assert (metric, direction) == ("cost_usd", "lower")


# The hypothesis property over randomized correlated chaos schedules
# lives in tests/test_properties.py (module-level importorskip there
# would otherwise skip this whole file when hypothesis is absent).
