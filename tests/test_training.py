"""Training substrate: optimizer, accumulation, checkpoint, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_smoke
from repro.configs.base import TrainConfig
from repro.distributed import materialize
from repro.distributed.compression import (compress_int8, compress_topk,
                                           init_error)
from repro.distributed.elastic import StepWatchdog, viable_meshes
from repro.models import LM, model_specs
from repro.training import SyntheticLM, init_opt_state, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("deepseek-7b")
    lm = LM(cfg)
    params = materialize(model_specs(cfg), jax.random.PRNGKey(0))
    return cfg, lm, params


def test_loss_decreases(setup):
    cfg, lm, params = setup
    tcfg = TrainConfig(lr=1e-3, total_steps=30, warmup_steps=3)
    step = jax.jit(make_train_step(lm, tcfg))
    opt = init_opt_state(params)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, batch=4)
    losses = []
    for _ in range(30):
        params, opt, m = step(params, opt, data.next_batch())
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
    assert int(opt["step"]) == 30


def test_grad_accumulation_matches_full_batch(setup):
    cfg, lm, params = setup
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, batch=8)
    batch = data.next_batch()
    one = make_train_step(lm, TrainConfig(microbatches=1))
    acc = make_train_step(lm, TrainConfig(microbatches=4))
    p1, o1, m1 = jax.jit(one)(params, init_opt_state(params), batch)
    p4, o4, m4 = jax.jit(acc)(params, init_opt_state(params), batch)
    # loss means agree; parameters land close (fp accumulation order)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-2)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_checkpoint_roundtrip_and_integrity(tmp_path, setup):
    cfg, lm, params = setup
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": params, "step": jnp.asarray(7)}
    mgr.save(7, state)
    mgr.save(9, state)
    mgr.save(11, state)
    assert mgr.steps() == [9, 11]       # keep=2 GC
    step, restored = mgr.restore_latest(state)
    assert step == 11
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(restored)[0]),
        np.asarray(jax.tree.leaves(state)[0]))
    # corrupt the newest -> restore falls back to the previous
    victim = tmp_path / "step_00000011" / "arrays.npz"
    victim.write_bytes(b"garbage")
    step, _ = mgr.restore_latest(state)
    assert step == 9


def test_data_pipeline_resumable():
    a = SyntheticLM(vocab=1000, seq_len=16, batch=2, seed=1)
    _ = a.next_batch(); _ = a.next_batch()
    saved = a.state_dict()
    want = a.next_batch()
    b = SyntheticLM(vocab=1000, seq_len=16, batch=2, seed=1)
    b.load_state(saved)
    got = b.next_batch()
    np.testing.assert_array_equal(np.asarray(want["tokens"]),
                                  np.asarray(got["tokens"]))


def test_int8_error_feedback_converges():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,))
    e = jnp.zeros_like(g)
    acc_true = jnp.zeros_like(g)
    acc_q = jnp.zeros_like(g)
    for _ in range(50):
        q, scale, e = compress_int8(g, e)
        acc_q = acc_q + q.astype(jnp.float32) * scale
        acc_true = acc_true + g
    # error feedback keeps the long-run average unbiased
    rel = float(jnp.linalg.norm(acc_q - acc_true) /
                jnp.linalg.norm(acc_true))
    assert rel < 0.01


def test_topk_compression_sparsity():
    g = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    vals, idx, e = compress_topk(g, jnp.zeros_like(g), frac=0.05)
    assert vals.shape[0] == int(64 * 64 * 0.05)
    assert float(jnp.abs(e).sum()) > 0


def test_watchdog_flags_stragglers():
    w = StepWatchdog(factor=3.0)
    for _ in range(10):
        assert not w.record(1.0)
    assert w.record(10.0)


def test_viable_meshes():
    assert (16, 16) in viable_meshes(256)
    assert all(d * m == 256 for d, m in viable_meshes(256))
