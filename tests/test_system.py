"""End-to-end behaviour: the paper's headline claims on a downscaled
workload (fast), plus HLO analysis self-checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import run_policy
from repro.launch.hlo_analysis import analyze, shape_bytes


@pytest.fixture(scope="module")
def results(small_workload):
    return {p: run_policy(p, small_workload, n_cores=10)
            for p in ("fifo", "cfs", "hybrid")}


def test_obs2_fifo_vs_cfs_tradeoff(results):
    """Obs. 2: FIFO better execution, CFS better response."""
    f, c = results["fifo"], results["cfs"]
    assert f.execution().mean() < c.execution().mean()
    assert c.p("response", 99) < f.p("response", 99)


def test_obs5_cfs_cost_blowup(results):
    """Obs. 5 / Fig. 1: CFS costs several times FIFO (>=10x at the
    paper's full 12.4k-invocation scale; >=3x on this downscale)."""
    assert results["cfs"].cost_usd() > 3.0 * results["fifo"].cost_usd()


def test_hybrid_execution_near_fifo(results):
    """Hybrid keeps execution time near-optimal (Fig. 6/12)."""
    f, h, c = (results[p] for p in ("fifo", "hybrid", "cfs"))
    assert h.execution().mean() < 2.0 * f.execution().mean()
    assert h.execution().mean() < 0.5 * c.execution().mean()


def test_hybrid_cost_saves_vs_cfs(results):
    """Conclusion 4: hybrid significantly cheaper than CFS."""
    assert results["hybrid"].cost_usd() < 0.4 * results["cfs"].cost_usd()


def test_preemption_counts_ordering(results):
    """Fig. 13: hybrid has orders of magnitude fewer preemptions."""
    assert results["hybrid"].total_preemptions() < \
        0.2 * results["cfs"].total_preemptions()


def test_microvm_mode_admission_cap(small_workload):
    r = run_policy("hybrid", small_workload, n_cores=10, microvm=True)
    n = len(small_workload)
    assert len(r.tasks) + len(r.failed) == n
    # boot overhead shifts execution up
    assert r.execution().min() >= 100.0


# -- HLO analysis self-checks -------------------------------------------------

def test_hlo_shape_bytes():
    assert shape_bytes("f32[2,3]{1,0}") == 24
    assert shape_bytes("bf16[128]") == 256
    assert shape_bytes("(f32[2], s32[4])") == 24
    assert shape_bytes("pred[]") == 1


def test_hlo_while_trip_counts():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f_scan(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def f_unroll(x, ws):
        for i in range(ws.shape[0]):
            x = jnp.tanh(x @ ws[i])
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    fs = analyze(jax.jit(f_scan).lower(x, ws).compile().as_text())
    fu = analyze(jax.jit(f_unroll).lower(x, ws).compile().as_text())
    analytic = 6 * 2 * 64 * 64 * 64
    assert fs["flops"] == pytest.approx(analytic, rel=0.01)
    assert fu["flops"] == pytest.approx(analytic, rel=0.01)
