"""Resilience benchmark: chaos x admission x prewarm cost matrix.

The paper's cost claim is measured on a healthy static fleet; this bench
asks whether it survives the conditions real providers fight — node
churn (a kill/heal pair plus a warm-pool wipe, the ``churn`` chaos
preset) — and how much the resilience layers buy back:

variant     dispatcher     admission          pre-warming
reactive    least_loaded   off                off   (the PR-2 baseline)
admission   least_loaded   queue-on-overload  off
prewarm     least_loaded   off                trace-driven plan
full        cost_aware*    queue-on-overload  trace-driven plan

(* the LEARNED cost-aware dispatcher — RLS over completion feedback.)

Admission uses queue/spill (never shed) so every cell completes the
identical invocation set and the dollars are directly comparable; the
per-function token bucket is sized to engage only on per-minute
micro-bursts. Each variant runs for {cfs, hybrid} node fleets x chaos
{off, churn}. Headline: hybrid+full under churn must be STRICTLY
cheaper than cfs+reactive under churn — the paper's margin, measured
where it is hardest to keep.

Emits ``results/benchmarks/BENCH_resilience.json`` with one row per
cell (keyed on node_policy/dispatcher/chaos/admission/prewarm — the
regression gate's resilience cell key) and the headline folded into the
first row. Standalone: ``python -m benchmarks.resilience_bench
[--smoke]``; also registered as ``resilience_matrix`` in
``benchmarks.run``.

The 16 cells are independent, so the nightly full tier fans out over a
CI matrix exactly like the heavy-traffic sweep: ``--shard i/n`` runs
the deterministic i-mod-n slice of the cell list (same partition rule
as ``cluster.sweep``), ``--merge SHARD.json ... --out FULL.json``
folds the per-shard artifacts back into ONE canonical artifact — rows
in the unsharded cell order, the headline recomputed over the complete
set (a shard alone never carries a headline: it cannot see both of the
cells the claim compares).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.cluster import (AdmissionConfig, ClusterSim, PrewarmConfig,
                           Provisioner, churn_preset)
from repro.core import ContainerConfig
from repro.traces import TraceSpec, generate_workload

from .common import RESULTS

N_NODES = 4
CORES = 8

# Queue-not-shed: identical completed sets across cells, so cost deltas
# are real savings, never work quietly dropped. The load ceiling sits
# at 1.25 runnable tasks per core — on this trace the healthy fleet's
# p99 min-node load is ~1.0, so the guard is all but invisible in calm
# weather and engages exactly when churn overloads the survivors (past
# one task per core, fair-share contention inflates every admitted
# invocation's billed wall-clock, so holding overflow at the unbilled
# front door is directly cheaper). The token bucket engages only on
# per-function micro-bursts (Zipf head functions during burst minutes).
ADMISSION = AdmissionConfig(max_load=1.25, overload_action="queue",
                            queue_backoff_ms=500.0,
                            rate_per_s=10.0, burst=20.0,
                            rate_action="queue", max_queue_ms=600_000.0)

VARIANTS = (
    # (variant, dispatcher, admission?, prewarm?)
    ("reactive", "least_loaded", False, False),
    ("admission", "least_loaded", True, False),
    ("prewarm", "least_loaded", False, True),
    ("full", "cost_aware", True, True),
)

HEAD_WIN = ("hybrid", "full", "churn")
HEAD_BASE = ("cfs", "reactive", "churn")


def _trace(smoke: bool) -> TraceSpec:
    # 1800/min on 32 cores runs the fleet NEAR saturation (healthy p99
    # min-node load ~1.0): hot enough that losing a node genuinely
    # overloads the survivors — the regime admission control exists for
    # — while staying out of unstable queueing collapse, where every
    # cell's cost is dominated by the meltdown rather than the policy.
    # The full tier doubles the horizon and function population, not
    # the rate.
    return TraceSpec(minutes=1 if smoke else 2,
                     invocations_per_min=1800.0,
                     n_functions=40 if smoke else 80, seed=0)


def _cells():
    # Both tiers run the SAME 16 cells; only the trace scale differs.
    for policy in ("cfs", "hybrid"):
        for variant, disp, adm, pre in VARIANTS:
            for chaos in ("off", "churn"):
                yield policy, variant, disp, adm, pre, chaos


def _run_cell(tasks, spec, policy, variant, disp, adm, pre,
              chaos) -> dict:
    horizon_ms = spec.minutes * 60_000.0
    sim = ClusterSim(
        n_nodes=N_NODES, cores_per_node=CORES, node_policies=policy,
        dispatcher=disp, seed=0,
        containers=ContainerConfig(keepalive_ms=30_000.0),
        admission=ADMISSION if adm else None)
    res = sim.run(
        tasks,
        chaos=churn_preset(horizon_ms, policy) if chaos == "churn" else None,
        prewarm=Provisioner.from_workload(tasks, PrewarmConfig())
        if pre else None)
    s = res.summary()
    row = {
        "node_policy": policy,
        "variant": variant,
        "dispatcher": disp,
        "chaos": chaos,
        "admission": "on" if adm else "off",
        "prewarm": "on" if pre else "off",
        "n_nodes": N_NODES,
        "cores_per_node": CORES,
        # Trace scale keys the gate cell: smoke- and full-tier
        # artifacts must never cross-compare as if same-scale.
        "minutes": spec.minutes,
        "invocations_per_min": spec.invocations_per_min,
        "n_functions": spec.n_functions,
    }
    for k in ("n", "failed", "shed", "cost_usd", "rejected_cost_usd",
              "init_cost_usd", "warm_hold_usd", "cold_start_rate",
              "cold_starts", "requeued", "chaos_events", "queued",
              "spilled", "prewarmed", "p99_slowdown", "makespan_s"):
        row[k] = s[k]
    row["total_cost_usd"] = res.total_cost_usd()
    return row


def _pick(rows, policy, variant, chaos):
    for r in rows:
        if (r["node_policy"], r["variant"], r["chaos"]) == \
                (policy, variant, chaos):
            return r
    raise KeyError((policy, variant, chaos))


def _headline(rows) -> dict:
    win, base = _pick(rows, *HEAD_WIN), _pick(rows, *HEAD_BASE)
    calm_win = _pick(rows, HEAD_WIN[0], HEAD_WIN[1], "off")
    calm_base = _pick(rows, HEAD_BASE[0], HEAD_BASE[1], "off")
    return {
        "full_hybrid_churn_cost_usd": win["total_cost_usd"],
        "reactive_cfs_churn_cost_usd": base["total_cost_usd"],
        "saving_under_churn": 1.0 - win["total_cost_usd"]
        / base["total_cost_usd"],
        "saving_calm": 1.0 - calm_win["total_cost_usd"]
        / calm_base["total_cost_usd"],
        # Apples-to-apples guard: the headline only means something if
        # both cells completed the same invocations.
        "same_completed_set": win["n"] == base["n"]
        and win["shed"] == base["shed"] == 0,
        "cheaper": win["total_cost_usd"] < base["total_cost_usd"],
    }


def resilience_matrix(smoke: bool = None,
                      shard: str = None) -> list[dict]:
    if smoke is None:
        smoke = bool(os.environ.get("CLUSTER_BENCH_SMOKE"))
    spec = _trace(smoke)
    tasks = generate_workload(spec).tasks
    cells = list(_cells())
    if shard is not None:
        from repro.cluster.sweep import shard_grid
        cells = shard_grid(cells, shard)
    rows = [_run_cell(tasks, spec, *cell) for cell in cells]
    if shard is None:
        head = _headline(rows)
        rows[0] = {**rows[0],
                   **{f"headline_{k}": v for k, v in head.items()}}
    return rows


def _cell_order(row: dict) -> int:
    """Canonical position of a row in the unsharded ``_cells()`` order."""
    order = {(p, v, c): i for i, (p, v, _d, _a, _pr, c)
             in enumerate(_cells())}
    return order[(row["node_policy"], row["variant"], row["chaos"])]


def merge_shards(paths: list[str]) -> list[dict]:
    """Fold per-shard artifacts into the canonical full matrix: rows in
    unsharded cell order, headline recomputed over the complete set.
    Raises if the shards do not reassemble exactly the 16-cell grid
    (a lost shard must fail the merge, not silently shrink the
    artifact the regression gate trusts)."""
    rows: list[dict] = []
    for p in paths:
        payload = json.loads(open(p).read())
        rows.extend(payload["matrix"] if isinstance(payload, dict)
                    else payload)
    expected = len(list(_cells()))
    keys = {_cell_order(r) for r in rows}
    if len(rows) != expected or keys != set(range(expected)):
        raise SystemExit(
            f"shards reassemble {sorted(keys)} of 0..{expected - 1} "
            f"({len(rows)} rows) — refusing to merge a partial matrix")
    rows.sort(key=_cell_order)
    head = _headline(rows)
    rows[0] = {**rows[0], **{f"headline_{k}": v for k, v in head.items()}}
    return rows


COLS = ("node_policy", "variant", "chaos", "cost_usd", "total_cost_usd",
        "cold_start_rate", "requeued", "queued", "prewarmed",
        "p99_slowdown")


def main(argv=None) -> None:
    from repro.cluster.sweep import print_rows
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--shard", default=None, metavar="i/n",
                    help="run only this deterministic 1/n slice of the "
                         "16-cell matrix (no headline; recombine with "
                         "--merge)")
    ap.add_argument("--merge", nargs="+", default=None, metavar="JSON",
                    help="merge per-shard --out files into --out and "
                         "exit (headline recomputed; no cells run)")
    ap.add_argument("--out", default=None,
                    help="artifact path (default "
                         "results/benchmarks/BENCH_resilience.json)")
    args = ap.parse_args(argv)
    out = args.out or str(RESULTS / "BENCH_resilience.json")

    if args.merge:
        rows = merge_shards(args.merge)
    else:
        rows = resilience_matrix(smoke=args.smoke, shard=args.shard)
    RESULTS.mkdir(parents=True, exist_ok=True)
    with open(out, "w") as f:
        json.dump({"matrix": rows}, f, indent=2)
    print_rows(rows, COLS)
    if args.shard:
        print(f"# shard {args.shard}: {len(rows)} cells -> {out} "
              f"(headline deferred to --merge)", file=sys.stderr)
        return
    first = rows[0]
    print(f"# hybrid+prewarm+admission vs cfs+reactive under churn: "
          f"cheaper={first['headline_cheaper']} "
          f"(saving {first['headline_saving_under_churn']:.1%} churn, "
          f"{first['headline_saving_calm']:.1%} calm; "
          f"same completed set={first['headline_same_completed_set']})",
          file=sys.stderr)
    if not first["headline_cheaper"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
