"""Benchmark-regression gate: diff benchmark JSON artifacts.

CI runs the smoke-size benches on every PR and uploads the JSON. This
gate compares a fresh artifact against a BASELINE — one previous run,
or several: given multiple baseline artifacts it collapses them into a
synthetic per-cell MEDIAN baseline first (``--median-of N`` caps how
many of the newest are used), so a single lucky or noisy historical
run cannot anchor the gate. It FAILS (exit 1) on a regression beyond
``--threshold``. Four artifact kinds are understood, auto-detected
from the row schema:

* ``cluster_matrix`` / ``BENCH_resilience`` / ``heavy_traffic`` rows —
  fail when a shared grid cell's ``cost_usd`` goes UP or its
  completed-invocations-per-makespan-second goes DOWN by more than the
  threshold. Cells are matched on (node_policy, dispatcher, n_nodes,
  load_scale, containers, chaos, admission, prewarm) — the resilience
  axes default to "off", so pre-resilience artifacts stay comparable
  and cost regressions under the chaos preset gate like any other cell.
* ``BENCH_engine`` rows (``events_per_sec`` present) — fail when a
  shared engine cell's events/sec drops by more than the threshold.
  Cells are matched on (policy, containers, n_cores, n_tasks), so the
  engine throughput from the hot-path overhaul is a tracked trajectory,
  not a one-off measurement, and smoke-tier runs never cross-compare
  with full-trace baselines.
* ``BENCH_mc`` rows (``cells_per_sec`` present) — fail when a shared
  sweep-throughput cell's cells/sec drops by more than the threshold.
  Cells are matched on (policy, backend, n_cores, n_cells, n_tasks,
  cpu_count): the ``backend`` axis keeps the pool baseline and the
  batched JAX path as separate trajectories on the same runner, and
  ``cpu_count`` keeps differently-sized runners from ever
  cross-comparing (both backends' walls scale with host cores).
  ``jax_cold`` rows
  (wall dominated by the one-off XLA compile) are reported but never
  fail the gate. Sweep artifacts gain nothing here: their summary rows
  are backend-invariant by the bit-identity contract, so the cluster
  key deliberately ignores any ``backend`` field.
* ``BENCH_costmodel`` rows (``ape`` present) — fail when a shared
  calibration op's absolute percentage error grows by more than the
  threshold (absolute, not relative: APE is already a relative error).
  Cells are matched on (op, mode), so the synthetic trajectory never
  gates against the compiled-and-replayed one.

Cells present on only one side are reported but do not fail the gate
(grids evolve). Missing baseline files are skipped with a note; when
NO baseline exists the gate passes vacuously, so the first run after
enabling it is green.

Usage::

    python -m benchmarks.regression_gate PREV.json [OLDER.json ...] \
        NEW.json [--threshold 0.15] [--median-of N]

(The LAST positional path is the current run; everything before it is
baseline history, newest first.)
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from pathlib import Path


def load_rows(path: str) -> list[dict]:
    """Accept both artifact shapes: ``{"matrix": rows}`` (the standalone
    CLI) and a bare rows list (``benchmarks.run``)."""
    payload = json.loads(Path(path).read_text())
    if isinstance(payload, dict):
        payload = payload.get("matrix", payload.get("rows", []))
    return payload


def cell_key(row: dict) -> tuple:
    # The resilience axes default to "off": a pre-resilience baseline
    # artifact and a new run's features-off rows land on the SAME key,
    # so enabling the gate on BENCH_resilience.json needed no schema
    # fork — chaos/admission/prewarm cells simply become new cells.
    # The trace-scale axes (minutes / rate / function count — sweep
    # rows have always carried them) keep a smoke-tier artifact from
    # being "compared" against a full-trace baseline as if same-scale,
    # exactly as n_tasks does for engine cells.
    # The workload axis defaults to "azure" (every pre-Scenario artifact
    # was an Azure-trace run), so old baselines stay comparable and the
    # llm-FaaS bench's cells simply become new cells under the same key
    # function.
    # The topology axes (zones / spot / retry) default "off" the same
    # way: flat-fleet baselines keep their keys, and BENCH_topology's
    # zoned/spot/retry cells become new cells under the same function.
    # The pricing / cost_model axes default to "default" / "static" —
    # the bit-identity contract's spelling of "no CostModel involved" —
    # so every pre-costmodel baseline keeps its key and a swept pricing
    # or learned-model cell becomes a new trajectory.
    return (row.get("node_policy"), row.get("dispatcher"),
            row.get("n_nodes"), row.get("load_scale", 1.0),
            row.get("containers", "off"), row.get("chaos", "off"),
            row.get("admission", "off"), row.get("prewarm", "off"),
            row.get("zones", "off"), row.get("spot", "off"),
            row.get("retry", "off"),
            row.get("minutes"), row.get("invocations_per_min"),
            row.get("n_functions"), row.get("workload", "azure"),
            row.get("model"), row.get("pricing", "default"),
            row.get("cost_model", "static"))


def throughput(row: dict) -> float:
    makespan = row.get("makespan_s") or 0.0
    return (row.get("n", 0) / makespan) if makespan > 0 else 0.0


def is_engine_rows(rows: list[dict]) -> bool:
    return bool(rows) and "events_per_sec" in rows[0]


def is_mc_rows(rows: list[dict]) -> bool:
    return bool(rows) and "cells_per_sec" in rows[0]


def is_costmodel_rows(rows: list[dict]) -> bool:
    return bool(rows) and "ape" in rows[0]


def costmodel_key(row: dict) -> tuple:
    # mode separates the synthetic trajectory from the compiled-and-
    # replayed one — the two measure different machines by design.
    return (row.get("op"), row.get("mode"))


def compare_costmodel(prev_rows: list[dict], new_rows: list[dict],
                      threshold: float) -> tuple[list[str], list[str]]:
    """Calibration-accuracy gate: a shared op's absolute percentage
    error must not grow by more than ``threshold`` ABSOLUTE (APE is
    already a relative error; a ratio of two small errors would flap)."""
    prev = {costmodel_key(r): r for r in prev_rows}
    new = {costmodel_key(r): r for r in new_rows}
    failures, notes = [], []
    for k in sorted(set(prev) ^ set(new), key=str):
        side = "baseline" if k in prev else "new run"
        notes.append(f"costmodel cell {k} only in {side}; skipped")
    shared = sorted(set(prev) & set(new), key=str)
    if not shared:
        notes.append("no shared costmodel cells; nothing to gate")
        return failures, notes
    n_cmp = 0
    for k in shared:
        p, n = prev[k].get("ape"), new[k].get("ape")
        if p is None or n is None:
            continue
        n_cmp += 1
        if n > p + threshold:
            failures.append(
                f"costmodel cell {k}: prediction error grew "
                f"{p:.4f} -> {n:.4f} (+{n - p:.4f} absolute)")
    notes.append(f"compared {len(shared)} costmodel cells "
                 f"({n_cmp} on ape)")
    if n_cmp == 0:
        failures.append(
            f"{len(shared)} shared costmodel cells but 0 comparisons — "
            "artifact schema drifted? (rows need ape)")
    return failures, notes


def mc_key(row: dict) -> tuple:
    # backend separates the pool baseline from the batched-JAX
    # trajectory; n_cells / n_tasks key the grid scale, so a smoke
    # artifact never cross-compares with a full-grid baseline.
    # cpu_count keys the RUNNER: both backends' walls scale with core
    # count (pool worker fan-out, XLA intra-op threads), so a 1-core
    # runner's cells/sec must never gate against a 4-core baseline —
    # rows from differently-sized machines simply become disjoint
    # cells (reported, skipped). Pre-ISSUE-9 artifacts lack the field
    # and land on cpu_count=None, disjoint from every new runner.
    return (row.get("policy"), row.get("backend"), row.get("n_cores"),
            row.get("n_cells"), row.get("n_tasks"),
            row.get("cpu_count"))


def compare_mc(prev_rows: list[dict], new_rows: list[dict],
               threshold: float) -> tuple[list[str], list[str]]:
    """MC sweep-throughput gate: cells/sec must not drop > threshold.
    ``jax_cold`` rows are compile-dominated and never fail."""
    prev = {mc_key(r): r for r in prev_rows}
    new = {mc_key(r): r for r in new_rows}
    failures, notes = [], []
    for k in sorted(set(prev) ^ set(new), key=str):
        side = "baseline" if k in prev else "new run"
        notes.append(f"mc cell {k} only in {side}; skipped")
    shared = sorted(set(prev) & set(new), key=str)
    if not shared:
        notes.append("no shared mc cells; nothing to gate")
        return failures, notes
    n_cmp = 0
    for k in shared:
        p, n = prev[k].get("cells_per_sec"), new[k].get("cells_per_sec")
        if not p or not n:
            continue
        n_cmp += 1
        ratio = n / p
        if ratio < 1.0 - threshold:
            msg = (f"mc cell {k}: cells/sec regressed {ratio - 1.0:+.1%} "
                   f"({p:.1f} -> {n:.1f})")
            if k[1] == "jax_cold":
                notes.append(msg + " [compile-dominated; not gated]")
            else:
                failures.append(msg)
    notes.append(f"compared {len(shared)} mc cells "
                 f"({n_cmp} on cells/sec)")
    if n_cmp == 0:
        failures.append(
            f"{len(shared)} shared mc cells but 0 comparisons — "
            "artifact schema drifted? (rows need cells_per_sec)")
    return failures, notes


def median_baseline(rows_lists: list[list[dict]]) -> list[dict]:
    """Collapse N baseline artifacts (NEWEST FIRST) into one synthetic
    baseline: per cell, the median of each gated metric over the runs
    that have the cell. Non-gated fields (events, n, ...) come from the
    newest run containing the cell, so event-count drift is still
    reported against the most recent history. For cluster rows the
    throughput axis medians the n/makespan RATIO (medianing n and
    makespan separately would gate against a throughput no run had),
    carried via a synthetic makespan."""
    if len(rows_lists) == 1:
        return rows_lists[0]
    engine = any(is_engine_rows(rows) for rows in rows_lists)
    mc = not engine and any(is_mc_rows(rows) for rows in rows_lists)
    costmodel = not engine and not mc \
        and any(is_costmodel_rows(rows) for rows in rows_lists)
    key_fn = engine_key if engine else mc_key if mc \
        else costmodel_key if costmodel else cell_key
    cells: dict[tuple, list[dict]] = {}
    order: list[tuple] = []
    for rows in rows_lists:            # newest first
        for row in rows:
            k = key_fn(row)
            if k not in cells:
                cells[k] = []
                order.append(k)
            cells[k].append(row)
    out = []
    for k in order:
        history = cells[k]
        synth = dict(history[0])       # newest run's row
        if engine:
            vals = [r["events_per_sec"] for r in history
                    if r.get("events_per_sec")]
            if vals:
                synth["events_per_sec"] = statistics.median(vals)
        elif mc:
            vals = [r["cells_per_sec"] for r in history
                    if r.get("cells_per_sec")]
            if vals:
                synth["cells_per_sec"] = statistics.median(vals)
        elif costmodel:
            vals = [r["ape"] for r in history if r.get("ape") is not None]
            if vals:
                synth["ape"] = statistics.median(vals)
        else:
            costs = [r["cost_usd"] for r in history if r.get("cost_usd")]
            if costs:
                synth["cost_usd"] = statistics.median(costs)
            tps = [throughput(r) for r in history if throughput(r) > 0]
            if tps and synth.get("n"):
                synth["makespan_s"] = synth["n"] / statistics.median(tps)
        out.append(synth)
    return out


def engine_key(row: dict) -> tuple:
    # n_tasks keys the trace size, so a smoke-tier artifact never gets
    # (non-)compared against a full-trace baseline as if same-scale.
    return (row.get("policy"), row.get("containers"), row.get("n_cores"),
            row.get("n_tasks"))


def compare_engine(prev_rows: list[dict], new_rows: list[dict],
                   threshold: float) -> tuple[list[str], list[str]]:
    """Engine-throughput gate: events/sec must not drop > threshold."""
    prev = {engine_key(r): r for r in prev_rows}
    new = {engine_key(r): r for r in new_rows}
    failures, notes = [], []
    for k in sorted(set(prev) ^ set(new), key=str):
        side = "baseline" if k in prev else "new run"
        notes.append(f"engine cell {k} only in {side}; skipped")
    shared = sorted(set(prev) & set(new), key=str)
    if not shared:
        notes.append("no shared engine cells; nothing to gate")
        return failures, notes
    n_cmp = 0
    for k in shared:
        p, n = prev[k].get("events_per_sec"), new[k].get("events_per_sec")
        if not p or not n:
            continue
        n_cmp += 1
        ratio = n / p
        if ratio < 1.0 - threshold:
            failures.append(
                f"engine cell {k}: events/sec regressed {ratio - 1.0:+.1%} "
                f"({p:.0f} -> {n:.0f})")
        if prev[k].get("events") and new[k].get("events") and \
                prev[k]["events"] != new[k]["events"]:
            notes.append(
                f"engine cell {k}: logical event count changed "
                f"({prev[k]['events']} -> {new[k]['events']}) — the "
                "simulation itself changed, not just its speed")
    notes.append(f"compared {len(shared)} engine cells "
                 f"({n_cmp} on events/sec)")
    if n_cmp == 0:
        failures.append(
            f"{len(shared)} shared engine cells but 0 comparisons — "
            "artifact schema drifted? (rows need events_per_sec)")
    return failures, notes


def compare(prev_rows: list[dict], new_rows: list[dict],
            threshold: float) -> tuple[list[str], list[str]]:
    """Returns (failures, notes)."""
    prev = {cell_key(r): r for r in prev_rows}
    new = {cell_key(r): r for r in new_rows}
    failures, notes = [], []
    shared = sorted(set(prev) & set(new), key=str)
    for k in sorted(set(prev) ^ set(new), key=str):
        side = "baseline" if k in prev else "new run"
        notes.append(f"cell {k} only in {side}; skipped")
    if not shared:
        notes.append("no shared grid cells; nothing to gate")
        return failures, notes
    n_cost = n_tp = 0
    for k in shared:
        p, n = prev[k], new[k]
        if p.get("cost_usd") and n.get("cost_usd"):
            n_cost += 1
            ratio = n["cost_usd"] / p["cost_usd"]
            if ratio > 1.0 + threshold:
                failures.append(
                    f"cell {k}: cost_usd regressed {ratio - 1.0:+.1%} "
                    f"({p['cost_usd']:.6g} -> {n['cost_usd']:.6g})")
        tp, tn = throughput(p), throughput(n)
        if tp > 0 and tn > 0:
            n_tp += 1
            ratio = tn / tp
            if ratio < 1.0 - threshold:
                failures.append(
                    f"cell {k}: throughput regressed {ratio - 1.0:+.1%} "
                    f"({tp:.4g} -> {tn:.4g} inv/s)")
    notes.append(f"compared {len(shared)} shared cells "
                 f"({n_cost} on cost, {n_tp} on throughput)")
    # Schema drift (renamed cost_usd / makespan_s / n) must not silently
    # disable an axis of the gate: each axis needs at least one
    # comparison across the shared cells.
    if n_cost == 0:
        failures.append(
            f"{len(shared)} shared cells but 0 cost comparisons — "
            "artifact schema drifted? (rows need cost_usd)")
    if n_tp == 0:
        failures.append(
            f"{len(shared)} shared cells but 0 throughput comparisons — "
            "artifact schema drifted? (rows need n + makespan_s)")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", nargs="+",
                    help="previous runs' JSON artifacts, newest first; "
                         "the LAST path given is the current run")
    ap.add_argument("current", help="this run's JSON artifact")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="relative regression tolerance (default 0.15)")
    ap.add_argument("--median-of", type=int, default=0, metavar="N",
                    help="gate against the per-cell median of the "
                         "newest N baselines (0 = use all given)")
    args = ap.parse_args(argv)

    notes = []
    paths = list(args.baseline)
    if args.median_of > 0:
        paths = paths[:args.median_of]
    rows_lists = []
    for p in paths:
        if Path(p).exists():
            rows_lists.append(load_rows(p))
        else:
            notes.append(f"baseline {p} missing; skipped")
    if not rows_lists:
        for line in notes:
            print(f"note: {line}")
        print("no baseline artifacts exist; gate passes vacuously")
        return 0
    if len(rows_lists) > 1:
        notes.append(f"gating against per-cell median of "
                     f"{len(rows_lists)} baselines")
    prev_rows = median_baseline(rows_lists)
    new_rows = load_rows(args.current)
    if is_engine_rows(new_rows) or is_engine_rows(prev_rows):
        failures, more = compare_engine(prev_rows, new_rows,
                                        args.threshold)
    elif is_mc_rows(new_rows) or is_mc_rows(prev_rows):
        failures, more = compare_mc(prev_rows, new_rows, args.threshold)
    elif is_costmodel_rows(new_rows) or is_costmodel_rows(prev_rows):
        failures, more = compare_costmodel(prev_rows, new_rows,
                                           args.threshold)
    else:
        failures, more = compare(prev_rows, new_rows, args.threshold)
    notes.extend(more)
    for line in notes:
        print(f"note: {line}")
    for line in failures:
        print(f"FAIL: {line}", file=sys.stderr)
    if failures:
        print(f"{len(failures)} regression(s) beyond "
              f"{args.threshold:.0%}", file=sys.stderr)
        return 1
    print("benchmark regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
