"""CI trend dashboard: fold nightly bench artifacts into one summary.

The regression gate answers "did THIS run regress?"; history has been
invisible unless you download raw artifacts one by one. This report
folds the last-N runs' JSON artifacts — engine throughput, cluster
matrix, heavy-traffic sweep, resilience matrix — into a single
markdown + JSON trend summary: per benchmark cell, the newest value,
the median of history, the delta, and a sparkline of the trajectory
(oldest -> newest). CI appends the markdown to the GitHub Actions job
summary (``$GITHUB_STEP_SUMMARY``) and uploads both files with the
bench artifacts, so the trajectory is one click away instead of an
artifact-archaeology session.

Layout convention (what the CI fetch step already produces)::

    history/0/BENCH_engine.json      <- newest previous run
    history/1/BENCH_engine.json
    ...
    current/BENCH_engine.json        <- this run

Usage::

    python -m benchmarks.trend_report --history prev-bench \
        --current results/benchmarks \
        --out results/benchmarks/trend.json \
        --md results/benchmarks/TREND.md
"""
from __future__ import annotations

import argparse
import json
import statistics
from pathlib import Path

from .regression_gate import (cell_key, costmodel_key, engine_key,
                              load_rows, mc_key)


def _timing_key(row: dict) -> tuple:
    return (row.get("module"), row.get("tier"))


# kind -> (filename, cell key fn, metric, direction, format)
KINDS = {
    "engine": ("BENCH_engine.json", engine_key, "events_per_sec",
               "higher", "{:,.0f}"),
    "mc": ("BENCH_mc.json", mc_key, "cells_per_sec",
           "higher", "{:,.1f}"),
    "cluster": ("cluster_matrix.json", cell_key, "cost_usd",
                "lower", "{:.6g}"),
    "resilience": ("BENCH_resilience.json", cell_key, "cost_usd",
                   "lower", "{:.6g}"),
    "topology": ("BENCH_topology.json", cell_key, "cost_usd",
                 "lower", "{:.6g}"),
    "heavy_traffic": ("heavy_traffic.json", cell_key, "cost_usd",
                      "lower", "{:.6g}"),
    "llm_faas": ("BENCH_llm_faas.json", cell_key, "usd_per_1k_requests",
                 "lower", "{:.6g}"),
    # Calibration accuracy: per-op absolute percentage error of the
    # fitted cost predictor (benchmarks.costmodel_bench).
    "costmodel": ("BENCH_costmodel.json", costmodel_key, "ape",
                  "lower", "{:.4f}"),
    # Nightly slow-tier per-module test wall-clock (tests/conftest.py
    # writes the artifact when REPRO_TEST_TIMINGS is set): a module
    # quietly doubling its runtime trends here like any bench cell.
    "test_timings": ("test_timings.json", _timing_key, "wall_s",
                     "lower", "{:.2f}"),
}

_SPARK = "▁▂▃▄▅▆▇█"


def sparkline(vals: list[float]) -> str:
    """Unicode trajectory, oldest -> newest (empty-safe)."""
    real = [v for v in vals if v is not None]
    if not real:
        return ""
    lo, hi = min(real), max(real)
    if hi - lo <= 0:
        return _SPARK[3] * len(real)
    return "".join(
        _SPARK[min(len(_SPARK) - 1,
                   int((v - lo) / (hi - lo) * (len(_SPARK) - 1)))]
        for v in real)


def _label(key: tuple) -> str:
    return " ".join(str(k) for k in key if k not in (None, "off", 1.0))


def collect_series(history_dirs: list[Path], current_dir: Path,
                   ) -> dict[str, list[dict]]:
    """Per kind, per cell: the metric series [oldest .. newest]."""
    out: dict[str, list[dict]] = {}
    for kind, (fname, key_fn, metric, direction, _fmt) in KINDS.items():
        # newest first: current, then history/0, history/1, ...
        paths = [current_dir / fname] + [d / fname for d in history_dirs]
        runs = []
        for p in paths:
            runs.append(load_rows(str(p)) if p.exists() else None)
        if runs[0] is None and not any(r for r in runs):
            continue
        cells: dict[tuple, list] = {}
        order: list[tuple] = []
        for run_i, rows in enumerate(runs):
            for row in rows or ():
                k = key_fn(row)
                if k not in cells:
                    cells[k] = [None] * len(runs)
                    order.append(k)
                cells[k][run_i] = row.get(metric)
        entries = []
        for k in order:
            newest_first = cells[k]
            latest = newest_first[0]
            # None = cell absent from that run; 0.0 is real data (a
            # degenerate zero-cost cell must still trend and warn).
            hist = [v for v in newest_first[1:] if v is not None]
            series = [v for v in reversed(newest_first) if v is not None]
            med = statistics.median(hist) if hist else None
            delta = (latest / med - 1.0) \
                if latest is not None and med else None
            entries.append({
                "cell": _label(k),
                "key": [str(x) for x in k],
                "metric": metric,
                "direction": direction,
                "latest": latest,
                "median": med,
                "delta": delta,
                "series": series,
                "runs": len([v for v in newest_first if v is not None]),
            })
        if entries:
            out[kind] = entries
    return out


def _regressed(e: dict) -> bool:
    """Moving the wrong way by >10% vs the historical median — a
    nonzero value on an all-zero (lower-is-better) baseline counts as
    an infinite regression, not missing data."""
    if e["latest"] is None or e["median"] is None:
        return False
    if e["median"] == 0:
        return e["latest"] > 0 and e["direction"] == "lower"
    d = e["latest"] / e["median"] - 1.0
    return d > 0.10 if e["direction"] == "lower" else d < -0.10


def _delta_cell(e: dict) -> str:
    if e["median"] == 0 and (e["latest"] or 0) > 0:
        return "+∞ ⚠" if e["direction"] == "lower" else "+∞"
    if e["delta"] is None:
        return "–"
    return f"{e['delta']:+.1%}{' ⚠' if _regressed(e) else ''}"


def to_markdown(series: dict[str, list[dict]]) -> str:
    lines = ["# Benchmark trends", ""]
    if not series:
        return "\n".join(lines + ["_no benchmark artifacts found_", ""])
    for kind, entries in series.items():
        metric = entries[0]["metric"]
        arrow = "↑ better" if entries[0]["direction"] == "higher" \
            else "↓ better"
        fmt = KINDS[kind][4]
        lines += [f"## {kind} — `{metric}` ({arrow})", "",
                  "| cell | latest | median(prev) | Δ vs median | trend |",
                  "|---|---:|---:|---:|---|"]
        for e in entries:
            latest = fmt.format(e["latest"]) \
                if e["latest"] is not None else "–"
            med = fmt.format(e["median"]) \
                if e["median"] is not None else "–"
            lines.append(f"| {e['cell']} | {latest} | {med} | "
                         f"{_delta_cell(e)} | {sparkline(e['series'])} |")
        lines.append("")
    worst = [e for es in series.values() for e in es if _regressed(e)]
    if worst:
        lines += ["## ⚠ moving the wrong way (>10% vs median)", ""]
        for e in sorted(worst,
                        key=lambda e: -abs(e["delta"])
                        if e["delta"] is not None else -float("inf")):
            lines.append(f"- **{e['cell']}** ({e['metric']}): "
                         f"{_delta_cell(e)}")
        lines.append("")
    return "\n".join(lines)


def discover_history(root: Path) -> list[Path]:
    """CI downloads previous artifacts into root/0, root/1, ... (newest
    first); tolerate arbitrary subdir names. Numeric names sort
    NUMERICALLY (lexicographic order would rank '10' before '2',
    scrambling which runs a --last cap keeps and the sparkline
    direction once history passes ten runs)."""
    if not root.exists():
        return []
    return sorted((d for d in root.iterdir() if d.is_dir()),
                  key=lambda d: (0, int(d.name), "") if d.name.isdigit()
                  else (1, 0, d.name))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--history", default="prev-bench",
                    help="directory of previous runs' artifact dirs "
                         "(newest first by name)")
    ap.add_argument("--current", default="results/benchmarks",
                    help="this run's artifact directory")
    ap.add_argument("--out", default=None, help="write JSON trend here")
    ap.add_argument("--md", default=None, help="write markdown here")
    ap.add_argument("--last", type=int, default=5,
                    help="cap history at the newest N runs (default 5)")
    args = ap.parse_args(argv)

    history = discover_history(Path(args.history))[:args.last]
    series = collect_series(history, Path(args.current))
    md = to_markdown(series)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(
            {"history_runs": len(history), "kinds": series}, indent=2))
    if args.md:
        Path(args.md).parent.mkdir(parents=True, exist_ok=True)
        Path(args.md).write_text(md)
    print(md)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
