"""Monte-Carlo sweep throughput: cells/sec, batched JAX vs mp.Pool.

The batched MC engine (``repro.mc``, DESIGN.md Sec. 16) advances a
whole (seeds x loads x policies) grid of in-regime sweep cells in one
vmapped XLA program, bit-identical to the scalar engine. Since ISSUE 9
the kernel retires MANY scheduling events per ``lax.while_loop``
iteration (alternation-cycle + window closed forms + micro-step
chain), so this bench reports two axes:

* WALL — cells/sec for the POOL baseline (``cluster.sweep.run_sweep``
  over the multiprocessing pool at full worker count, each worker
  running the scalar engine) vs the JAX backend timed COLD (first
  call: XLA compilation included) and WARM (compiled program cached).
* ALGORITHM — kernel iterations and events retired per cell from the
  kernel's own counters. ``events_per_cell / iters_per_cell`` is the
  multi-event win, and because the PR 7 one-event kernel ran at
  exactly one event per iteration, ``events_per_cell`` IS its
  iteration count: ``iter_reduction_vs_one_event`` is directly the
  "x fewer iterations" acceptance number, visible even on 1-core CI
  where wall-clock hides it.

READ THE WALL HEADLINE WITH THE MACHINE IN MIND: one compiled program
does O(padded-slots) vector work per iteration across the whole
batch and the vmapped while-loop runs to the batch's SLOWEST cell,
where the scalar engine does O(1) dict work per event and
fast-forwards dense regimes analytically. On parallel hardware
(many-core CPU, GPU/TPU) the batch axis is free and the one-program
shape wins; on a single-core CI runner XLA executes the batch
serially and the pool baseline stays ahead. ``meta`` records
``cpu_count``, ``pool_workers``, the compile time, and the persistent
compile-cache hit evidence (entry counts when
``REPRO_MC_COMPILE_CACHE`` is set), so a number measured on one
machine is never mistaken for a hardware-independent ratio; CI gates
cells/sec run-over-run on same-``cpu_count`` runners (kind ``mc`` in
``benchmarks.regression_gate``) rather than against an absolute
cross-machine target.

Equivalence is re-asserted on a sample of cells each run (summaries
must match the pool rows exactly) — a throughput number for a wrong
simulation would be worse than no number.

Standalone::

    python -m benchmarks.mc_bench [--smoke] [--median-of N]

``--median-of N`` repeats each timed measurement N times and keeps
the median (matching engine_bench's smoke aggregation) — sub-second
smoke grids otherwise gate on single-run scheduler noise.

Writes ``results/benchmarks/BENCH_mc.json``:

    {"rows": [{"policy": ..., "backend": "pool" | "jax" | "jax_cold",
               "n_cells": ..., "n_cores": ..., "n_tasks": ...,
               "wall_s": ..., "cells_per_sec": ...}, ...],
     "meta": {"headline_speedup_vs_pool": ..., "compile_s": ...,
              "cpu_count": ..., "padded_slots": ...,
              "iters_per_cell": ..., "events_per_cell": ...,
              "iter_reduction_vs_one_event": ..., ...}}
"""
from __future__ import annotations

import json
import os
import statistics
import sys
import time
from dataclasses import asdict

from repro.cluster.sweep import build_grid, run_sweep

from .common import RESULTS

ARTIFACT = "BENCH_mc.json"

POLICIES = ("fifo", "cfs", "hybrid")

# Full tier: 16 seeds x 6 loads x 3 policies = 288 cells (the >= 256
# acceptance floor), each one minute of a small Azure-like trace on a
# 4-core node — the many-small-cells shape Monte-Carlo sweeps take.
FULL = dict(seeds=range(16), loads=(0.25, 0.5, 1.0, 1.5, 2.0, 3.0),
            minutes=1, invocations_per_min=60.0, n_functions=10,
            n_cores=4)
# Smoke tier (CI): same shape, 12 cells, finishes in well under a
# minute including the one XLA compile.
SMOKE = dict(seeds=range(2), loads=(0.5, 1.5),
             minutes=1, invocations_per_min=60.0, n_functions=10,
             n_cores=4)

# How many cells of each timed grid get their pool/jax summary rows
# byte-compared (bit-identity spot check riding along with the bench).
VERIFY_CELLS = 6


def _cpu_count() -> int:
    """Cores this process may actually use. ``os.cpu_count()`` ignores
    affinity masks, so under CI's ``taskset -c 0,1`` pinning it would
    report the whole runner and the gate key would lie about the
    machine the walls were measured on."""
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # non-Linux
        return os.cpu_count() or 2


def mc_grid(spec: dict) -> list:
    return build_grid(
        POLICIES, ["none"], [1], tuple(spec["loads"]),
        cores_per_node=spec["n_cores"], minutes=spec["minutes"],
        invocations_per_min=spec["invocations_per_min"],
        n_functions=spec["n_functions"])


def _expand_seeds(grid: list, seeds) -> list:
    from dataclasses import replace
    return [replace(c, seed=s) for c in grid for s in seeds]


def _strip(row: dict) -> dict:
    return {k: v for k, v in row.items() if k != "backend"}


def _jax_sweep(grid: list) -> tuple[list[dict], int, int]:
    """The sweep runner's jax route, inlined so the TIMED run also
    yields the kernel's iteration/event counters (``run_sweep`` rows
    drop ``mc_stats``). Returns (rows, total_iters, total_events)."""
    from repro.mc.dispatch import supported, tasks_supported
    from repro.mc.engine import run_scenarios

    scs = [c.to_scenario() for c in grid]
    prebuilt = []
    for sc in scs:
        why = supported(sc)
        if why is None:
            built = sc.workload.build()
            why = tasks_supported(built[0])
            prebuilt.append(built)
        if why is not None:
            raise RuntimeError(
                f"bench cell outside the batched regime ({why}) — the "
                "bench grid must ride the device end to end")
    results = run_scenarios(scs, prebuilt=prebuilt)
    rows, iters, events = [], 0, 0
    for cell, res in zip(grid, results):
        row = asdict(cell)
        row.update(res.summary())
        row["backend"] = "jax"
        rows.append(row)
        iters += res.mc_stats["iters"]
        events += res.mc_stats["events"]
    return rows, iters, events


def bench_grid(spec: dict, median_of: int = 1) -> tuple[list[dict], dict]:
    from repro.mc.dispatch import compile_cache_entries, enable_compile_cache
    from repro.mc.engine import _bucket

    grid = _expand_seeds(mc_grid(spec), spec["seeds"])
    n_cells = len(grid)
    pool_workers = min(n_cells, _cpu_count())

    pool_walls = []
    for _ in range(median_of):
        t0 = time.perf_counter()
        pool_rows = run_sweep(grid, parallel=True,
                              processes=pool_workers)
        pool_walls.append(time.perf_counter() - t0)
    pool_s = statistics.median(pool_walls)

    cache_dir = enable_compile_cache()
    cache_before = compile_cache_entries()
    t0 = time.perf_counter()
    jax_rows, _, _ = _jax_sweep(grid)
    cold_s = time.perf_counter() - t0
    cache_after_cold = compile_cache_entries()

    warm_walls = []
    for _ in range(median_of):
        t0 = time.perf_counter()
        jax_rows, iters, events = _jax_sweep(grid)
        warm_walls.append(time.perf_counter() - t0)
    warm_s = statistics.median(warm_walls)

    step = max(1, n_cells // VERIFY_CELLS)
    for k in range(0, n_cells, step):
        if _strip(jax_rows[k]) != pool_rows[k]:
            raise RuntimeError(
                f"bit-identity violated on bench cell {k}: "
                f"{pool_rows[k]} != {jax_rows[k]}")

    n_tasks = pool_rows[0]["n"]
    # Per-policy walls are not separable inside one batched program;
    # the artifact's gated rows are the all-policies aggregates per
    # backend (plus the cold row, reported but gate-exempt: its wall
    # is dominated by the one-off XLA compile). cpu_count rides on
    # every row because the gate keys on it: both backends' walls
    # scale with core count (pool workers / XLA intra-op threads), so
    # differently-sized runners must never cross-compare.
    cpus = _cpu_count()
    rows = [
        {"policy": "all", "backend": "pool", "n_cells": n_cells,
         "n_cores": spec["n_cores"], "n_tasks": n_tasks,
         "cpu_count": cpus,
         "wall_s": pool_s, "cells_per_sec": n_cells / pool_s},
        {"policy": "all", "backend": "jax", "n_cells": n_cells,
         "n_cores": spec["n_cores"], "n_tasks": n_tasks,
         "cpu_count": cpus,
         "wall_s": warm_s, "cells_per_sec": n_cells / warm_s},
        {"policy": "all", "backend": "jax_cold", "n_cells": n_cells,
         "n_cores": spec["n_cores"], "n_tasks": n_tasks,
         "cpu_count": cpus,
         "wall_s": cold_s, "cells_per_sec": n_cells / cold_s},
    ]
    meta = {
        "n_cells": n_cells,
        "n_tasks_per_cell": n_tasks,
        "padded_slots": _bucket(n_tasks),
        "grid": {k: (list(v) if isinstance(v, (range, tuple)) else v)
                 for k, v in spec.items()},
        "median_of": median_of,
        "pool_s": pool_s,
        "pool_workers": pool_workers,
        "jax_cold_s": cold_s,
        "jax_warm_s": warm_s,
        "compile_s": cold_s - warm_s,
        "headline_speedup_vs_pool": pool_s / warm_s,
        # Kernel-side counters: events_per_cell is exactly what the
        # PR 7 one-event kernel spent in iterations, so the reduction
        # ratio is the hardware-independent multi-event win.
        "iters_per_cell": iters / n_cells,
        "events_per_cell": events / n_cells,
        "events_per_iter": events / max(iters, 1),
        "iter_reduction_vs_one_event": events / max(iters, 1),
        "compile_cache": (
            None if cache_dir is None else
            {"dir": cache_dir, "entries_before": cache_before,
             "entries_after_cold": cache_after_cold,
             # cold run hit the cache iff no new entries appeared
             "cold_was_hit": cache_after_cold == cache_before}),
        "cpu_count": cpus,
        "verified_cells": len(range(0, n_cells, step)),
    }
    return rows, meta


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    median_of = 1
    if "--median-of" in argv:
        median_of = int(argv[argv.index("--median-of") + 1])
    rows, meta = bench_grid(SMOKE if smoke else FULL,
                            median_of=median_of)
    meta["smoke"] = smoke
    payload = {"rows": rows, "meta": meta}
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / ARTIFACT).write_text(json.dumps(payload, indent=2))
    print("policy,backend,n_cells,n_cores,wall_s,cells_per_sec")
    for r in rows:
        print(f"{r['policy']},{r['backend']},{r['n_cells']},"
              f"{r['n_cores']},{r['wall_s']:.3f},"
              f"{r['cells_per_sec']:.1f}")
    print(f"# headline: jax-warm vs pool "
          f"{meta['headline_speedup_vs_pool']:.2f}x on "
          f"{meta['n_cells']} cells "
          f"(compile {meta['compile_s']:.1f}s, "
          f"cpu_count={meta['cpu_count']})", file=sys.stderr)
    print(f"# kernel: {meta['iters_per_cell']:.1f} iters/cell for "
          f"{meta['events_per_cell']:.1f} events/cell = "
          f"{meta['iter_reduction_vs_one_event']:.1f}x fewer "
          f"iterations than the one-event kernel", file=sys.stderr)


if __name__ == "__main__":
    main()
