"""Monte-Carlo sweep throughput: cells/sec, batched JAX vs mp.Pool.

The batched MC engine (``repro.mc``, DESIGN.md Sec. 16) advances a
whole (seeds x loads x policies) grid of in-regime sweep cells in one
vmapped XLA program, bit-identical to the scalar engine. This bench
measures the throughput side of that trade on a >= 256-cell grid:

* the POOL baseline — ``cluster.sweep.run_sweep`` over the same cells
  through the ``multiprocessing`` pool, each worker regenerating its
  workload and running the scalar engine (the pre-PR sweep path,
  unchanged);
* the JAX backend — ``run_sweep(..., backend="jax")``, timed COLD
  (first call: XLA compilation included) and WARM (the compiled
  program cached, the steady-state cost of every later grid on the
  same shape bucket).

The headline is ``speedup_vs_pool`` = warm-JAX cells/sec over pool
cells/sec. READ IT WITH THE MACHINE IN MIND: one compiled program
does O(padded-slots) vector work per retired event across the whole
batch, where the scalar engine does O(1) dict work per event and
fast-forwards dense regimes analytically. On parallel hardware
(many-core CPU, GPU/TPU) the batch axis is free and the one-program
shape wins; on a single-core CI runner XLA executes the batch
serially and the batched backend sits near parity on fifo/hybrid
grids and behind on slice-expiry-dense pure-CFS cells. ``meta``
records ``cpu_count`` and the compile time so a number measured on
one machine is never mistaken for a hardware-independent ratio, and
CI gates cells/sec run-over-run on the same runner (kind ``mc`` in
``benchmarks.regression_gate``) rather than against an absolute
cross-machine target.

Equivalence is re-asserted on a sample of cells each run (summaries
must match the pool rows exactly) — a throughput number for a wrong
simulation would be worse than no number.

Standalone::

    python -m benchmarks.mc_bench [--smoke]

Writes ``results/benchmarks/BENCH_mc.json``:

    {"rows": [{"policy": ..., "backend": "pool" | "jax" | "jax_cold",
               "n_cells": ..., "n_cores": ..., "n_tasks": ...,
               "wall_s": ..., "cells_per_sec": ...}, ...],
     "meta": {"headline_speedup_vs_pool": ..., "compile_s": ...,
              "cpu_count": ..., ...}}
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro.cluster.sweep import build_grid, run_sweep

from .common import RESULTS

ARTIFACT = "BENCH_mc.json"

POLICIES = ("fifo", "cfs", "hybrid")

# Full tier: 16 seeds x 6 loads x 3 policies = 288 cells (the >= 256
# acceptance floor), each one minute of a small Azure-like trace on a
# 4-core node — the many-small-cells shape Monte-Carlo sweeps take.
FULL = dict(seeds=range(16), loads=(0.25, 0.5, 1.0, 1.5, 2.0, 3.0),
            minutes=1, invocations_per_min=60.0, n_functions=10,
            n_cores=4)
# Smoke tier (CI): same shape, 12 cells, finishes in well under a
# minute including the one XLA compile.
SMOKE = dict(seeds=range(2), loads=(0.5, 1.5),
             minutes=1, invocations_per_min=60.0, n_functions=10,
             n_cores=4)

# How many cells of each timed grid get their pool/jax summary rows
# byte-compared (bit-identity spot check riding along with the bench).
VERIFY_CELLS = 6


def mc_grid(spec: dict) -> list:
    return build_grid(
        POLICIES, ["none"], [1], tuple(spec["loads"]),
        cores_per_node=spec["n_cores"], minutes=spec["minutes"],
        invocations_per_min=spec["invocations_per_min"],
        n_functions=spec["n_functions"])


def _expand_seeds(grid: list, seeds) -> list:
    from dataclasses import replace
    return [replace(c, seed=s) for c in grid for s in seeds]


def _strip(row: dict) -> dict:
    return {k: v for k, v in row.items() if k != "backend"}


def bench_grid(spec: dict) -> tuple[list[dict], dict]:
    grid = _expand_seeds(mc_grid(spec), spec["seeds"])
    n_cells = len(grid)

    t0 = time.perf_counter()
    pool_rows = run_sweep(grid, parallel=True)
    pool_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    jax_rows = run_sweep(grid, backend="jax")
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    jax_rows = run_sweep(grid, backend="jax")
    warm_s = time.perf_counter() - t0

    n_jax = sum(r["backend"] == "jax" for r in jax_rows)
    if n_jax != n_cells:
        raise RuntimeError(
            f"{n_cells - n_jax} bench cells fell back to the scalar "
            "engine — the bench grid must sit fully inside the batched "
            "regime")
    step = max(1, n_cells // VERIFY_CELLS)
    for k in range(0, n_cells, step):
        if _strip(jax_rows[k]) != pool_rows[k]:
            raise RuntimeError(
                f"bit-identity violated on bench cell {k}: "
                f"{pool_rows[k]} != {jax_rows[k]}")

    n_tasks = pool_rows[0]["n"]
    # Per-policy walls are not separable inside one batched program;
    # the artifact's gated rows are the all-policies aggregates per
    # backend (plus the cold row, reported but gate-exempt: its wall
    # is dominated by the one-off XLA compile).
    rows = [
        {"policy": "all", "backend": "pool", "n_cells": n_cells,
         "n_cores": spec["n_cores"], "n_tasks": n_tasks,
         "wall_s": pool_s, "cells_per_sec": n_cells / pool_s},
        {"policy": "all", "backend": "jax", "n_cells": n_cells,
         "n_cores": spec["n_cores"], "n_tasks": n_tasks,
         "wall_s": warm_s, "cells_per_sec": n_cells / warm_s},
        {"policy": "all", "backend": "jax_cold", "n_cells": n_cells,
         "n_cores": spec["n_cores"], "n_tasks": n_tasks,
         "wall_s": cold_s, "cells_per_sec": n_cells / cold_s},
    ]
    meta = {
        "n_cells": n_cells,
        "n_tasks_per_cell": n_tasks,
        "grid": {k: (list(v) if isinstance(v, (range, tuple)) else v)
                 for k, v in spec.items()},
        "pool_s": pool_s,
        "jax_cold_s": cold_s,
        "jax_warm_s": warm_s,
        "compile_s": cold_s - warm_s,
        "headline_speedup_vs_pool": pool_s / warm_s,
        "cpu_count": os.cpu_count(),
        "verified_cells": len(range(0, n_cells, step)),
    }
    return rows, meta


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    rows, meta = bench_grid(SMOKE if smoke else FULL)
    meta["smoke"] = smoke
    payload = {"rows": rows, "meta": meta}
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / ARTIFACT).write_text(json.dumps(payload, indent=2))
    print("policy,backend,n_cells,n_cores,wall_s,cells_per_sec")
    for r in rows:
        print(f"{r['policy']},{r['backend']},{r['n_cells']},"
              f"{r['n_cores']},{r['wall_s']:.3f},"
              f"{r['cells_per_sec']:.1f}")
    print(f"# headline: jax-warm vs pool "
          f"{meta['headline_speedup_vs_pool']:.2f}x on "
          f"{meta['n_cells']} cells "
          f"(compile {meta['compile_s']:.1f}s, "
          f"cpu_count={meta['cpu_count']})", file=sys.stderr)


if __name__ == "__main__":
    main()
