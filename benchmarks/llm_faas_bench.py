"""LLM-inference-as-FaaS benchmark: hybrid vs CFS dollars for a replica
fleet serving mixed prefill/decode traffic.

This is the Scenario API's headline workload: Azure-trace arrivals are
mapped to inference requests against one model (replica = sandbox, cold
start = weight-load + compile, warm state = KV/weights residency priced
through the container pool, tasks = prefill + chunked-decode pieces
whose preemptions pay the KV-swap penalty). Both cells run the SAME
request stream through ``repro.run``; only the node scheduling policy
differs:

cell      node scheduler             adaptation
cfs       fair-share slot scheduler  none (the OS default)
hybrid    FIFO+CFS two-group slots   time-limit percentile + rightsize

Headline: hybrid must be STRICTLY cheaper than cfs in $/1k requests —
the paper's claim, measured where serverless providers feel it (billed
wall-clock x memory footprint of a 7B replica). Under contention CFS
time-slices decode chunks against each other and every displacement
swaps a multi-GB KV cache, inflating the billed span; the hybrid FIFO
group runs chunks to completion and only long stragglers migrate.

Emits ``results/benchmarks/BENCH_llm_faas.json`` with one row per cell
(keyed on node_policy/dispatcher/workload/model + trace scale — the
regression gate's llm cell key) and the headline folded into the first
row. Standalone: ``python -m benchmarks.llm_faas_bench [--smoke]``;
also registered as ``llm_faas`` in ``benchmarks.run``.
"""
from __future__ import annotations

import json
import os
import sys

from repro.scenario import (FleetSpec, PolicySpec, Scenario, WorkloadSpec,
                            run)
from repro.serving.llm import LLMSpec
from repro.traces import TraceSpec

from .common import RESULTS

N_NODES = 2
SLOTS = 8
MODEL = "deepseek-7b"


def _trace(smoke: bool) -> TraceSpec:
    # 300 requests/min on 16 decode lanes keeps the fleet contended
    # (several runnable chunks per slot at the burst minutes) without
    # tipping into queueing collapse: hot enough that CFS's KV-swap
    # churn shows up in the bill, stable enough that both cells finish
    # the identical request set. The full tier doubles the horizon and
    # function population, not the rate.
    return TraceSpec(minutes=1 if smoke else 2,
                     invocations_per_min=300.0,
                     n_functions=12 if smoke else 24, seed=7)


def _scenario(policy: str, spec: TraceSpec) -> Scenario:
    return Scenario(
        workload=WorkloadSpec(kind="llm", trace=spec,
                              llm=LLMSpec(model=MODEL)),
        fleet=FleetSpec(n_nodes=N_NODES, cores_per_node=SLOTS,
                        dispatcher="least_loaded", seed=1),
        # The hybrid cell runs the paper's full configuration (time-limit
        # adaptation + group rightsizing); cfs is the vanilla OS default.
        policy=PolicySpec(
            name=policy,
            adapt_pct=95.0 if policy == "hybrid" else None,
            rightsize=policy == "hybrid"))


def _run_cell(policy: str, spec: TraceSpec) -> dict:
    res = run(_scenario(policy, spec))
    row = {
        "node_policy": policy,
        "dispatcher": "least_loaded",
        "n_nodes": N_NODES,
        "cores_per_node": SLOTS,
        "workload": "llm",
        "model": MODEL,
        # Trace scale keys the gate cell: smoke- and full-tier
        # artifacts must never cross-compare as if same-scale.
        "minutes": spec.minutes,
        "invocations_per_min": spec.invocations_per_min,
        "n_functions": spec.n_functions,
    }
    row.update(res.summary())
    for k, v in res.meta.items():
        row.setdefault(k, v)
    return row


def _headline(rows: list[dict]) -> dict:
    by = {r["node_policy"]: r for r in rows}
    hyb, cfs = by["hybrid"], by["cfs"]
    return {
        "hybrid_usd_per_1k_requests": hyb["usd_per_1k_requests"],
        "cfs_usd_per_1k_requests": cfs["usd_per_1k_requests"],
        "saving": 1.0 - hyb["usd_per_1k_requests"]
        / cfs["usd_per_1k_requests"],
        # Apples-to-apples guard: $/1k only means something if both
        # cells completed the same request set.
        "same_completed_set": hyb["n"] == cfs["n"]
        and hyb["n_requests"] == cfs["n_requests"],
        "cheaper": hyb["usd_per_1k_requests"]
        < cfs["usd_per_1k_requests"],
    }


def llm_faas_matrix(smoke: bool = None) -> list[dict]:
    if smoke is None:
        smoke = bool(os.environ.get("CLUSTER_BENCH_SMOKE"))
    spec = _trace(smoke)
    rows = [_run_cell(policy, spec) for policy in ("cfs", "hybrid")]
    head = _headline(rows)
    rows[0] = {**rows[0], **{f"headline_{k}": v for k, v in head.items()}}
    return rows


COLS = ("node_policy", "n", "n_requests", "cost_usd", "total_cost_usd",
        "usd_per_1k_requests", "cold_starts", "p99_turnaround_s",
        "makespan_s")


def main() -> None:
    from repro.cluster.sweep import print_rows
    smoke = "--smoke" in sys.argv
    rows = llm_faas_matrix(smoke=smoke)
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_llm_faas.json").write_text(
        json.dumps({"matrix": rows}, indent=2))
    print_rows(rows, COLS)
    first = rows[0]
    print(f"# hybrid vs cfs, {MODEL} replica fleet: "
          f"cheaper={first['headline_cheaper']} "
          f"(${first['headline_hybrid_usd_per_1k_requests']:.4f} vs "
          f"${first['headline_cfs_usd_per_1k_requests']:.4f} per 1k "
          f"requests, saving {first['headline_saving']:.1%}; "
          f"same completed set={first['headline_same_completed_set']})",
          file=sys.stderr)
    if not first["headline_cheaper"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
