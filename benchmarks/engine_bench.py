"""Engine throughput benchmark: events/sec on the heavy_traffic smoke config.

The discrete-event core is the inner loop of every experiment in this
repo: a policy x dispatcher x fleet sweep is just many single-node
engine runs. This bench measures the engine itself — logical events
processed per wall-clock second (``Scheduler.n_events``: arrivals +
chunk expiries/completions + timers) and simulated milliseconds per
wall second — across the policy x containers grid on a single-node
slice of the ``heavy_traffic`` preset (one minute of the paper-volume
trace on a 16-core node).

Because the engine overhaul is bit-identical (tests/test_engine_
equivalence.py), the logical event count of each cell is an invariant:
events/sec ratios ARE wall-time ratios. ``PRE_PR_REFERENCE`` pins the
numbers measured on the pre-overhaul engine (same machine, same trace,
commit 14a871e) so the artifact records both sides of the overhaul's
speedup, per cell; the CI regression gate then tracks the trajectory
run-over-run via ``benchmarks.regression_gate``.

Standalone::

    python -m benchmarks.engine_bench [--smoke]

Writes ``results/benchmarks/BENCH_engine.json``:

    {"rows": [{"policy": ..., "containers": ..., "events": ...,
               "wall_s": ..., "events_per_sec": ...,
               "sim_ms_per_wall_s": ..., "speedup_vs_pre_pr": ...}, ...],
     "reference_pre_pr": [...], "meta": {...}}
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro.core.containers import ContainerConfig
from repro.core.simulate import make_scheduler
from repro.traces.azure import TraceSpec
from repro.traces.workload import generate_workload

from .common import RESULTS

ARTIFACT = "BENCH_engine.json"

# Single-node slice of the heavy_traffic preset (cluster.sweep.PRESETS):
# one minute at the paper's arrival volume on one 16-core node.
HEAVY_SMOKE = dict(minutes=1, invocations_per_min=6221.0,
                   n_functions=250, seed=0)
# CI smoke tier: same shape, ~10x fewer invocations, finishes in seconds
# even on the slowest runner.
CI_SMOKE = dict(minutes=1, invocations_per_min=600.0,
                n_functions=80, seed=0)

N_CORES = 16
POLICIES = ("fifo", "cfs", "hybrid")
CONTAINER_MODES = ("off", "fixed")

# The headline cell: CFS is the paper's expensive baseline and the
# slice-expiry-dominated worst case for the event loop. The overhaul's
# issue aspired to >=10x here; the honest measured result is ~4x (see
# DESIGN.md Sec. 13 for why the dense-queue regime is structurally
# capped, and ROADMAP.md for the path to more).
HEADLINE = ("cfs", "off")

# Pre-overhaul engine throughput, measured in this container on the
# default (non-smoke) grid immediately before the hot-path refactor
# (the pre-PR event loop patched only with the canonical same-instant
# tie rule and the n_events counter, so event counts match the new
# engine exactly). Event counts are simulation invariants; wall times
# are machine-dependent snapshots and only comparable to runs on the
# same hardware. The UNPATCHED pre-PR engine measured slower still
# (cfs,off: 97,767 events/s in 15.84 s), so these references are the
# conservative baseline.
PRE_PR_REFERENCE: list[dict] = [
    {"policy": "fifo", "containers": "off", "n_cores": 16,
     "n_tasks": 6249, "events": 12498, "wall_s": 0.069410,
     "events_per_sec": 180060.4, "sim_ms_per_wall_s": 5221152.5,
     "total_ctx": 6249},
    {"policy": "fifo", "containers": "fixed", "n_cores": 16,
     "n_tasks": 6249, "events": 12901, "wall_s": 0.128939,
     "events_per_sec": 100055.2, "sim_ms_per_wall_s": 3117966.6,
     "total_ctx": 6249},
    {"policy": "cfs", "containers": "off", "n_cores": 16,
     "n_tasks": 6249, "events": 1548167, "wall_s": 12.782637,
     "events_per_sec": 121114.2, "sim_ms_per_wall_s": 38469.9,
     "total_ctx": 1530669},
    {"policy": "cfs", "containers": "fixed", "n_cores": 16,
     "n_tasks": 6249, "events": 1963749, "wall_s": 16.262335,
     "events_per_sec": 120759.4, "sim_ms_per_wall_s": 35402.4,
     "total_ctx": 1944457},
    {"policy": "hybrid", "containers": "off", "n_cores": 16,
     "n_tasks": 6249, "events": 215266, "wall_s": 1.256512,
     "events_per_sec": 171320.5, "sim_ms_per_wall_s": 341158.1,
     "total_ctx": 174245},
    {"policy": "hybrid", "containers": "fixed", "n_cores": 16,
     "n_tasks": 6249, "events": 165976, "wall_s": 1.076976,
     "events_per_sec": 154108.4, "sim_ms_per_wall_s": 454951.1,
     "total_ctx": 106846},
]


def _container_cfg(mode: str) -> ContainerConfig | None:
    if mode == "off":
        return None
    return ContainerConfig(policy="fixed", capacity_mb=4096.0,
                           keepalive_ms=30_000.0)


def bench_cell(policy: str, containers: str, tasks, *,
               n_cores: int = N_CORES, repeats: int = 2) -> dict:
    """Run one policy over the trace and time the engine alone (workload
    generation and metric roll-ups excluded). Best-of-``repeats`` wall
    time, so one noisy-neighbour hiccup cannot trip the 15% regression
    gate."""
    import copy
    wall = None
    for _ in range(max(1, repeats)):
        work = copy.deepcopy(tasks)
        kw = {}
        cfg = _container_cfg(containers)
        if cfg is not None:
            kw["containers"] = cfg
        sched = make_scheduler(policy, n_cores=n_cores, **kw)
        t0 = time.perf_counter()
        sched.run(work)
        dt = time.perf_counter() - t0
        wall = dt if wall is None or dt < wall else wall
    sim_ms = max(t.completion for t in sched.completed)
    return {
        "policy": policy,
        "containers": containers,
        "n_cores": n_cores,
        "n_tasks": len(sched.completed),
        "events": sched.n_events,
        "wall_s": wall,
        "events_per_sec": sched.n_events / wall if wall > 0 else 0.0,
        "sim_ms_per_wall_s": sim_ms / wall if wall > 0 else 0.0,
        "total_ctx": sched.total_ctx,
    }


def _reference_row(policy: str, containers: str) -> dict | None:
    for r in PRE_PR_REFERENCE:
        if (r["policy"], r["containers"]) == (policy, containers):
            return r
    return None


def engine_matrix(smoke: bool | None = None) -> dict:
    if smoke is None:
        smoke = bool(os.environ.get("ENGINE_BENCH_SMOKE"))
    spec = TraceSpec(**(CI_SMOKE if smoke else HEAVY_SMOKE))
    tasks = generate_workload(spec).tasks
    # Warm up interpreter/numpy state off the clock so the first timed
    # cell is not charged for ufunc initialization.
    bench_cell("fifo", "off", tasks[:200], repeats=1)
    rows = []
    for policy in POLICIES:
        for mode in CONTAINER_MODES:
            row = bench_cell(policy, mode, tasks)
            ref = None if smoke else _reference_row(policy, mode)
            if ref is not None:
                row["pre_pr_events_per_sec"] = ref["events_per_sec"]
                row["speedup_vs_pre_pr"] = \
                    row["events_per_sec"] / ref["events_per_sec"]
            rows.append(row)
    meta = {"smoke": smoke, "n_tasks": len(tasks),
            "trace": CI_SMOKE if smoke else HEAVY_SMOKE,
            "headline": list(HEADLINE)}
    head = next((r for r in rows
                 if (r["policy"], r["containers"]) == HEADLINE), None)
    if head is not None and "speedup_vs_pre_pr" in head:
        meta["headline_speedup_vs_pre_pr"] = head["speedup_vs_pre_pr"]
    return {"rows": rows, "reference_pre_pr": PRE_PR_REFERENCE,
            "meta": meta}


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    payload = engine_matrix(smoke=smoke)
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / ARTIFACT).write_text(json.dumps(payload, indent=2))
    print("policy,containers,events,wall_s,events_per_sec,sim_ms_per_wall_s")
    for r in payload["rows"]:
        print(f"{r['policy']},{r['containers']},{r['events']},"
              f"{r['wall_s']:.3f},{r['events_per_sec']:.0f},"
              f"{r['sim_ms_per_wall_s']:.0f}")
    speedup = payload["meta"].get("headline_speedup_vs_pre_pr")
    if speedup is not None:
        print(f"# headline {HEADLINE} speedup vs pre-PR engine: "
              f"{speedup:.1f}x", file=sys.stderr)


if __name__ == "__main__":
    main()
