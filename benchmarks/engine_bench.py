"""Engine throughput benchmark: events/sec across policy/containers/scale.

The discrete-event core is the inner loop of every experiment in this
repo: a policy x dispatcher x fleet sweep is just many single-node
engine runs. This bench measures the engine itself — logical events
processed per wall-clock second (``Scheduler.n_events``: arrivals +
chunk expiries/completions + timers) and simulated milliseconds per
wall second — on two grids:

* the HEAVY grid: the policy x containers matrix on a single-node slice
  of the ``heavy_traffic`` preset (one minute of the paper-volume trace
  on a 16-core node), tracked since the PR 3 hot-path overhaul;
* the DENSE grid: the dense-queue regime the paper's cost argument
  rests on — tens of thousands of concurrent short functions queued
  hundreds deep per core (64 cores x ~48k invocations in one minute,
  ~760 tasks/core). This is the regime the completion-batching
  overhaul (DESIGN.md Sec. 13) targets: before it, every completion
  and first dispatch serialized through the event heap.

Because the engine overhauls are outcome-preserving (tests/test_engine_
equivalence.py), the logical event count of each cell is an invariant:
events/sec ratios ARE wall-time ratios. ``PRE_PR_REFERENCE`` pins the
heavy grid's numbers measured on the pre-PR-3 engine, and
``PR3_DENSE_REFERENCE`` pins the dense grid's numbers measured on the
PR 3 engine (same machine, same trace, commit 13b23e1) immediately
before completion batching landed — so the artifact records both sides
of each overhaul, per cell; the CI regression gate then tracks the
trajectory run-over-run via ``benchmarks.regression_gate``.

Standalone::

    python -m benchmarks.engine_bench [--smoke]

``--smoke`` (the CI tier) runs a tiny trace, times each cell three
times and reports the MEDIAN, so one noisy-neighbour hiccup on a shared
runner cannot fake a regression — that is what lets the CI gate
threshold tighten from the 0.30 the smoke tier needed at PR 3.

Writes ``results/benchmarks/BENCH_engine.json``:

    {"rows": [{"policy": ..., "containers": ..., "n_cores": ...,
               "events": ..., "wall_s": ..., "events_per_sec": ...,
               "sim_ms_per_wall_s": ..., "speedup_vs_pre_pr": ... |
               "speedup_vs_pr3": ...}, ...],
     "reference_pre_pr": [...], "reference_pr3_dense": [...],
     "meta": {...}}
"""
from __future__ import annotations

import copy
import json
import os
import statistics
import sys
import time

from repro.core.containers import ContainerConfig
from repro.core.simulate import make_scheduler
from repro.traces.azure import TraceSpec
from repro.traces.workload import generate_workload

from .common import RESULTS

ARTIFACT = "BENCH_engine.json"

# Single-node slice of the heavy_traffic preset (cluster.sweep.PRESETS):
# one minute at the paper's arrival volume on one 16-core node.
HEAVY_SMOKE = dict(minutes=1, invocations_per_min=6221.0,
                   n_functions=250, seed=0)
# Dense-queue grid: far past the paper volume on a 16-core node — the
# thousands-of-queued-invocations-per-core regime the paper's cost
# argument rests on (~3,000/core for cfs; ~6,000/core for the hybrid,
# whose CFS group only holds the over-limit tail and needs twice the
# volume to reach comparable per-core depth). cfs/hybrid only: fifo
# retires 2 events per task and has no dense-queue churn to measure.
DENSE_CFS = dict(minutes=1, invocations_per_min=48_000.0,
                 n_functions=800, seed=0)
DENSE_HYBRID = dict(minutes=1, invocations_per_min=96_000.0,
                    n_functions=1200, seed=1)
DENSE_N_CORES = 16
# CI smoke tier: same shape as the heavy grid, ~10x fewer invocations,
# finishes in seconds even on the slowest runner.
CI_SMOKE = dict(minutes=1, invocations_per_min=600.0,
                n_functions=80, seed=0)

N_CORES = 16
POLICIES = ("fifo", "cfs", "hybrid")
DENSE_POLICIES = ("cfs", "hybrid")
CONTAINER_MODES = ("off", "fixed")
# Dense cells run pool-free: completion batching is the variable under
# measurement, and with a pool attached every fresh task's first
# dispatch still serializes through the heap (the documented residual
# limit, DESIGN.md Sec. 13), which dilutes the dense contrast into a
# mixed measurement the heavy grid already covers.
DENSE_MODES = ("off",)

# The headline cell: CFS is the paper's expensive baseline and the
# slice-expiry-dominated worst case for the event loop.
HEADLINE = ("cfs", "off")
# The dense headline: the completion-batching overhaul's target cell.
DENSE_HEADLINE = ("cfs", "off")

# Pre-overhaul engine throughput, measured in this container on the
# default (non-smoke) HEAVY grid immediately before the PR 3 hot-path
# refactor (the pre-PR event loop patched only with the canonical
# same-instant tie rule and the n_events counter, so event counts match
# the new engine exactly). Event counts are simulation invariants; wall
# times are machine-dependent snapshots and only comparable to runs on
# the same hardware.
PRE_PR_REFERENCE: list[dict] = [
    {"policy": "fifo", "containers": "off", "n_cores": 16,
     "n_tasks": 6249, "events": 12498, "wall_s": 0.069410,
     "events_per_sec": 180060.4, "sim_ms_per_wall_s": 5221152.5,
     "total_ctx": 6249},
    {"policy": "fifo", "containers": "fixed", "n_cores": 16,
     "n_tasks": 6249, "events": 12901, "wall_s": 0.128939,
     "events_per_sec": 100055.2, "sim_ms_per_wall_s": 3117966.6,
     "total_ctx": 6249},
    {"policy": "cfs", "containers": "off", "n_cores": 16,
     "n_tasks": 6249, "events": 1548167, "wall_s": 12.782637,
     "events_per_sec": 121114.2, "sim_ms_per_wall_s": 38469.9,
     "total_ctx": 1530669},
    {"policy": "cfs", "containers": "fixed", "n_cores": 16,
     "n_tasks": 6249, "events": 1963749, "wall_s": 16.262335,
     "events_per_sec": 120759.4, "sim_ms_per_wall_s": 35402.4,
     "total_ctx": 1944457},
    {"policy": "hybrid", "containers": "off", "n_cores": 16,
     "n_tasks": 6249, "events": 215266, "wall_s": 1.256512,
     "events_per_sec": 171320.5, "sim_ms_per_wall_s": 341158.1,
     "total_ctx": 174245},
    {"policy": "hybrid", "containers": "fixed", "n_cores": 16,
     "n_tasks": 6249, "events": 165976, "wall_s": 1.076976,
     "events_per_sec": 154108.4, "sim_ms_per_wall_s": 454951.1,
     "total_ctx": 106846},
]

# PR 3-engine throughput on the DENSE grid, measured in this container
# (best-of-two, sequential, idle machine) at commit 13b23e1 — the
# engine with the analytic slice fast-forward but with every completion
# and first dispatch still serializing through the heap. These are the
# reference rows the completion-batching speedup is gated against.
PR3_DENSE_REFERENCE: list[dict] = [
    {"policy": "cfs", "containers": "off", "n_cores": 16,
     "n_tasks": 48407, "events": 18641994, "wall_s": 138.416554,
     "events_per_sec": 134680.4, "sim_ms_per_wall_s": 30572.7,
     "total_ctx": 18588160},
    {"policy": "hybrid", "containers": "off", "n_cores": 16,
     "n_tasks": 95993, "events": 20887634, "wall_s": 41.414011,
     "events_per_sec": 504361.5, "sim_ms_per_wall_s": 208050.7,
     "total_ctx": 20781242},
]


def _container_cfg(mode: str) -> ContainerConfig | None:
    if mode == "off":
        return None
    return ContainerConfig(policy="fixed", capacity_mb=4096.0,
                           keepalive_ms=30_000.0)


def bench_cell(policy: str, containers: str, tasks, *,
               n_cores: int = N_CORES, repeats: int = 2,
               aggregate: str = "best") -> dict:
    """Run one policy over the trace and time the engine alone (workload
    generation and metric roll-ups excluded). ``aggregate`` picks how
    the ``repeats`` wall times collapse: "best" (full tier: the least
    noisy estimate of the machine's capability) or "median" (smoke
    tier: robust against a single noisy-neighbour hiccup, so CI can
    gate tighter)."""
    walls = []
    while True:
        work = copy.deepcopy(tasks)
        kw = {}
        cfg = _container_cfg(containers)
        if cfg is not None:
            kw["containers"] = cfg
        sched = make_scheduler(policy, n_cores=n_cores, **kw)
        t0 = time.perf_counter()
        sched.run(work)
        walls.append(time.perf_counter() - t0)
        if len(walls) >= max(1, repeats) and \
                (min(walls) >= 0.5 or len(walls) >= 6):
            break  # sub-second cells get extra repeats: one scheduler
            # hiccup is a 30% swing there, far beyond the gate threshold
    wall = min(walls) if aggregate == "best" else statistics.median(walls)
    sim_ms = max(t.completion for t in sched.completed)
    return {
        "policy": policy,
        "containers": containers,
        "n_cores": n_cores,
        "n_tasks": len(sched.completed),
        "events": sched.n_events,
        "wall_s": wall,
        "events_per_sec": sched.n_events / wall if wall > 0 else 0.0,
        "sim_ms_per_wall_s": sim_ms / wall if wall > 0 else 0.0,
        "total_ctx": sched.total_ctx,
    }


def _reference_row(refs: list[dict], policy: str, containers: str) -> \
        dict | None:
    for r in refs:
        if (r["policy"], r["containers"]) == (policy, containers):
            return r
    return None


def engine_matrix(smoke: bool | None = None) -> dict:
    if smoke is None:
        smoke = bool(os.environ.get("ENGINE_BENCH_SMOKE"))
    spec = TraceSpec(**(CI_SMOKE if smoke else HEAVY_SMOKE))
    tasks = generate_workload(spec).tasks
    # Warm up interpreter/numpy state off the clock so the first timed
    # cell is not charged for ufunc initialization.
    bench_cell("fifo", "off", tasks[:200], repeats=1)
    rows = []
    for policy in POLICIES:
        for mode in CONTAINER_MODES:
            if smoke:
                # Satellite of the CI gate: 3 runs, median, so one
                # hiccup cannot trip the threshold.
                row = bench_cell(policy, mode, tasks, repeats=3,
                                 aggregate="median")
            else:
                row = bench_cell(policy, mode, tasks)
                ref = _reference_row(PRE_PR_REFERENCE, policy, mode)
                if ref is not None:
                    row["pre_pr_events_per_sec"] = ref["events_per_sec"]
                    row["speedup_vs_pre_pr"] = \
                        row["events_per_sec"] / ref["events_per_sec"]
            rows.append(row)
    if not smoke:
        for policy in DENSE_POLICIES:
            spec = DENSE_CFS if policy == "cfs" else DENSE_HYBRID
            dense_tasks = generate_workload(TraceSpec(**spec)).tasks
            for mode in DENSE_MODES:
                # Dense cells run tens of seconds: noisy-neighbour
                # episodes on a shared host last that long too, so
                # best-of-3 instead of best-of-2.
                row = bench_cell(policy, mode, dense_tasks,
                                 n_cores=DENSE_N_CORES, repeats=3)
                ref = _reference_row(PR3_DENSE_REFERENCE, policy, mode)
                if ref is not None:
                    row["pr3_events_per_sec"] = ref["events_per_sec"]
                    row["speedup_vs_pr3"] = \
                        row["events_per_sec"] / ref["events_per_sec"]
                rows.append(row)
    meta = {"smoke": smoke, "n_tasks": len(tasks),
            "trace": CI_SMOKE if smoke else HEAVY_SMOKE,
            "headline": list(HEADLINE)}
    head = next((r for r in rows
                 if (r["policy"], r["containers"]) == HEADLINE
                 and r["n_cores"] == N_CORES), None)
    if head is not None and "speedup_vs_pre_pr" in head:
        meta["headline_speedup_vs_pre_pr"] = head["speedup_vs_pre_pr"]
    if not smoke:
        meta["dense_trace_cfs"] = DENSE_CFS
        meta["dense_trace_hybrid"] = DENSE_HYBRID
        dhead = next((r for r in rows
                      if (r["policy"], r["containers"]) == DENSE_HEADLINE
                      and "speedup_vs_pr3" in r), None)
        if dhead is not None:
            meta["dense_headline_speedup_vs_pr3"] = dhead["speedup_vs_pr3"]
    return {"rows": rows, "reference_pre_pr": PRE_PR_REFERENCE,
            "reference_pr3_dense": PR3_DENSE_REFERENCE, "meta": meta}


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    payload = engine_matrix(smoke=smoke)
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / ARTIFACT).write_text(json.dumps(payload, indent=2))
    print("policy,containers,n_cores,events,wall_s,events_per_sec,"
          "sim_ms_per_wall_s")
    for r in payload["rows"]:
        print(f"{r['policy']},{r['containers']},{r['n_cores']},"
              f"{r['events']},{r['wall_s']:.3f},"
              f"{r['events_per_sec']:.0f},{r['sim_ms_per_wall_s']:.0f}")
    for key, label in (("headline_speedup_vs_pre_pr",
                        f"headline {HEADLINE} speedup vs pre-PR-3 engine"),
                       ("dense_headline_speedup_vs_pr3",
                        f"dense headline {DENSE_HEADLINE} speedup vs "
                        "PR 3 engine")):
        speedup = payload["meta"].get(key)
        if speedup is not None:
            print(f"# {label}: {speedup:.1f}x", file=sys.stderr)


if __name__ == "__main__":
    main()
