"""Cost-model calibration benchmark: predictor accuracy + the $ delta
the learned model actually moves end-to-end.

Two halves, one artifact:

1. **Per-op accuracy rows** — run the calibration pipeline
   (``repro.costmodel.calibrate``) and emit one row per calibration op
   with its measured vs predicted latency and absolute percentage
   error. ``--smoke`` (or ``CLUSTER_BENCH_SMOKE``) uses the synthetic
   mode (frozen row table + hidden roofline — deterministic, jax-free);
   the full tier compiles and replays the real Pallas kernels when jax
   is importable and falls back to synthetic when not. The ``mode``
   field keys the gate cell, so synthetic and measured trajectories
   never cross-compare.
2. **End-to-end $ delta** — the same small llm-FaaS scenario run twice
   through ``repro.run``, once with the static cost model and once with
   the learned one (cost-aware dispatch seeded by the calibrated
   queueing prior, ``max_load="auto"`` admission ceiling, EWMA
   pre-warm). Folded into the first row as ``headline_*`` fields.

Headline: calibration MAPE must clear the mode's bound — 0.25 for the
synthetic tier (the acceptance bound: a controlled experiment whose
ground truth IS linear in the features), 0.50 for compile-and-replay
(Pallas kernel bodies hide their FLOPs inside custom calls, so the
roofline fit on a CPU host is diagnostic; per-op APE *drift* is the
gated quantity there). Exit 1 past the bound. Emits
``results/benchmarks/BENCH_costmodel.json``;
registered as ``costmodel`` in ``benchmarks.run``; gated by
``benchmarks.regression_gate`` (kind ``costmodel``: a shared op's APE
must not grow by more than the threshold, absolute).

Standalone: ``python -m benchmarks.costmodel_bench [--smoke]``.
"""
from __future__ import annotations

import json
import os
import sys

from repro.costmodel.calibrate import calibrate
from repro.scenario import (FleetSpec, PolicySpec, ResilienceSpec,
                            Scenario, WorkloadSpec, run)
from repro.serving.llm import LLMSpec
from repro.traces import TraceSpec

from .common import RESULTS

MAPE_BOUND = 0.25          # synthetic tier: the acceptance bound
MEASURE_MAPE_BOUND = 0.50  # compile-and-replay tier: diagnostic bound
MODEL = "deepseek-7b"


def _calibrate(smoke: bool) -> dict:
    if smoke:
        return calibrate(mode="synthetic", seed=0)
    try:
        import jax  # noqa: F401
        return calibrate(mode="measure", repeats=5, small=True)
    except Exception:
        # No jax (or no functional backend) on this runner: the
        # synthetic tier still exercises fit + consumers end to end.
        return calibrate(mode="synthetic", seed=0)


def _op_rows(artifact: dict) -> list[dict]:
    rows = []
    for r in artifact["rows"]:
        ape = abs(r["predicted_ms"] - r["measured_ms"]) / r["measured_ms"] \
            if r["measured_ms"] > 0 else 0.0
        rows.append({
            "op": r["op"],
            # mode keys the gate cell: a synthetic trajectory must
            # never gate against a measured one.
            "mode": artifact["mode"],
            "flops": r["flops"],
            "bytes": r["bytes"],
            "measured_ms": r["measured_ms"],
            "predicted_ms": r["predicted_ms"],
            "ape": ape,
            "mape": artifact["mape"],
        })
    return rows


def _scenario(cost_model) -> Scenario:
    return Scenario(
        workload=WorkloadSpec(
            kind="llm",
            trace=TraceSpec(minutes=1, invocations_per_min=120.0,
                            n_functions=8, seed=11),
            llm=LLMSpec(model=MODEL)),
        fleet=FleetSpec(n_nodes=2, cores_per_node=4,
                        dispatcher="cost_aware", seed=3),
        policy=PolicySpec(name="hybrid"),
        resilience=ResilienceSpec(
            admission={"max_load": "auto", "overload_action": "queue"}),
        cost_model=cost_model)


def _e2e_delta(artifact: dict) -> dict:
    static = run(_scenario(None)).summary()
    learned = run(_scenario(dict(artifact))).summary()
    return {
        "static_total_cost_usd": static["total_cost_usd"],
        "learned_total_cost_usd": learned["total_cost_usd"],
        "usd_delta": learned["total_cost_usd"] - static["total_cost_usd"],
        "learned_cost_coeff": learned["cost_coeff"],
        "learned_cost_obs": learned["cost_obs"],
    }


def costmodel_matrix(smoke: bool = None) -> list[dict]:
    if smoke is None:
        smoke = bool(os.environ.get("CLUSTER_BENCH_SMOKE"))
    artifact = _calibrate(smoke)
    rows = _op_rows(artifact)
    bound = MAPE_BOUND if artifact["mode"] == "synthetic" \
        else MEASURE_MAPE_BOUND
    head = {
        "mape": artifact["mape"],
        "mape_bound": bound,
        "mape_ok": artifact["mape"] <= bound,
        "queue_ms_per_load": artifact["queue_ms_per_load"],
    }
    head.update(_e2e_delta(artifact))
    rows[0] = {**rows[0], **{f"headline_{k}": v for k, v in head.items()}}
    return rows


COLS = ("op", "mode", "measured_ms", "predicted_ms", "ape")


def main() -> None:
    from repro.cluster.sweep import print_rows
    smoke = "--smoke" in sys.argv
    rows = costmodel_matrix(smoke=smoke)
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "BENCH_costmodel.json").write_text(
        json.dumps({"matrix": rows}, indent=2))
    print_rows(rows, COLS)
    first = rows[0]
    print(f"# costmodel {first['mode']}: mape={first['headline_mape']:.4f} "
          f"(bound {first['headline_mape_bound']}); learned-vs-static "
          f"${first['headline_usd_delta']:+.6f} total on the llm cell "
          f"(coeff={first['headline_learned_cost_coeff']:.1f} after "
          f"{first['headline_learned_cost_obs']} observations)",
          file=sys.stderr)
    if not first["headline_mape_ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
