"""Cluster benchmark: cold-start-rate x cost matrix over the fleet grid.

Runs the full grid (8 dispatchers x {cfs, hybrid} x {2, 4} nodes) with
the container lifecycle layer attached, via the parallel sweep runner,
plus a small container-free baseline to show the margin widening: once
sandboxes are modelled, warm-aware affinity dispatch on hybrid nodes
beats state-oblivious dispatch on CFS nodes by MORE than scheduler
choice alone buys, because routing now also controls how often users are
billed for sandbox boot. Emits one JSON payload whose first row carries
sweep timing (``sweep_*``) and the headline comparison (``headline_*``):

    {"matrix": [{"node_policy": ..., "dispatcher": ..., "n_nodes": ...,
                 "cost_usd": ..., "cold_start_rate": ...,
                 "warm_hold_usd": ..., ...}, ...]}

Standalone: ``python -m benchmarks.cluster_bench [--smoke]``; also
registered as ``cluster_matrix`` in ``benchmarks.run``.
"""
from __future__ import annotations

import json
import os
import sys

from repro.cluster import build_grid, compare_serial, run_sweep
from repro.cluster import DISPATCHERS as _DISPATCHER_REGISTRY
from repro.cluster.sweep import print_rows

from .common import RESULTS

DISPATCHERS = tuple(sorted(_DISPATCHER_REGISTRY))
NODE_POLICIES = ("cfs", "hybrid")
FLEET_SIZES = (2, 4)

# The acceptance pair: warm-aware affinity on hybrid nodes must beat
# state-oblivious (and even state-aware but container-oblivious)
# dispatch on CFS nodes.
WARM_CELL = ("hybrid", "warm_affinity")
BASE_CELLS = (("cfs", "least_loaded"), ("cfs", "round_robin"))
HEADLINE_NODES = 4


def _trace_kw(smoke: bool) -> dict:
    return dict(cores_per_node=8, minutes=1,
                invocations_per_min=300.0 if smoke else 1200.0,
                n_functions=40 if smoke else 80, seed=0)


def _grid(smoke: bool = False):
    return build_grid(NODE_POLICIES, DISPATCHERS, FLEET_SIZES,
                      containers="fixed", **_trace_kw(smoke))


def _baseline_grid(smoke: bool = False):
    """Container-free margin baseline: the same acceptance pair without
    the lifecycle layer ('affinity' stands in for 'warm_affinity' —
    without containers there is no warm set to route on)."""
    return build_grid(("cfs", "hybrid"), ("least_loaded", "affinity"),
                      (HEADLINE_NODES,), containers="off",
                      **_trace_kw(smoke))


def _pick(rows, policy, dispatcher, n_nodes=HEADLINE_NODES):
    for r in rows:
        if (r["node_policy"], r["dispatcher"], r["n_nodes"]) == \
                (policy, dispatcher, n_nodes):
            return r
    raise KeyError((policy, dispatcher, n_nodes))


def _headline(rows, base_rows) -> dict:
    """The artifact the tentpole promises: affinity + hybrid beats
    least-loaded + CFS by a wider margin once containers are modelled."""
    warm = _pick(rows, *WARM_CELL)
    out = {
        "warm_affinity_hybrid_cost_usd": warm["cost_usd"],
        "warm_affinity_hybrid_cold_rate": warm["cold_start_rate"],
    }
    for pol, disp in BASE_CELLS:
        r = _pick(rows, pol, disp)
        out[f"{disp}_{pol}_cost_usd"] = r["cost_usd"]
        out[f"{disp}_{pol}_cold_rate"] = r["cold_start_rate"]
        out[f"saving_vs_{disp}_{pol}"] = \
            1.0 - warm["cost_usd"] / r["cost_usd"]
    # The "does modelling containers widen the routing margin" pair:
    # the with-containers side is the least_loaded+cfs saving above.
    base_pol, base_disp = BASE_CELLS[0]
    out["margin_with_containers"] = \
        out[f"saving_vs_{base_disp}_{base_pol}"]
    warm_off = _pick(base_rows, "hybrid", "affinity")
    base_off = _pick(base_rows, "cfs", "least_loaded")
    out["margin_without_containers"] = \
        1.0 - warm_off["cost_usd"] / base_off["cost_usd"]
    out["cheaper"] = all(
        warm["cost_usd"] < _pick(rows, pol, disp)["cost_usd"]
        for pol, disp in BASE_CELLS)
    return out


def cluster_matrix(smoke: bool = None) -> list[dict]:
    # ``benchmarks.run`` calls benches with no arguments; CI selects the
    # small-trace grid through the environment instead.
    if smoke is None:
        smoke = bool(os.environ.get("CLUSTER_BENCH_SMOKE"))
    cmp = compare_serial(_grid(smoke))
    rows = cmp.pop("rows")
    base_rows = run_sweep(_baseline_grid(smoke))
    # ``benchmarks.run`` persists the return value as <name>.json, so
    # fold the timing + headline meta into the first row.
    if rows:
        head = _headline(rows, base_rows)
        rows[0] = {**rows[0],
                   **{f"sweep_{k}": v for k, v in cmp.items()},
                   **{f"headline_{k}": v for k, v in head.items()}}
    return rows + base_rows


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = cluster_matrix(smoke=smoke)
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "cluster_matrix.json").write_text(
        json.dumps({"matrix": rows}, indent=2))
    print_rows(rows)
    first = rows[0] if rows else {}
    speedup = first.get("sweep_speedup")
    if speedup:
        print(f"# sweep speedup {speedup:.2f}x", file=sys.stderr)
    if "headline_cheaper" in first:
        print(f"# warm_affinity+hybrid cheaper than "
              f"state-oblivious cfs baselines: {first['headline_cheaper']} "
              f"(margin w/ containers "
              f"{first['headline_margin_with_containers']:.1%}, "
              f"w/o {first['headline_margin_without_containers']:.1%})",
              file=sys.stderr)


if __name__ == "__main__":
    main()
