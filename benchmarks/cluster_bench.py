"""Cluster benchmark: dispatcher x node-policy x fleet-size cost matrix.

Runs the full grid (5 dispatchers x {cfs, hybrid} x {2, 4} nodes) on a
downscaled Azure-like trace via the parallel sweep runner, and times the
same grid serially to report the speedup. Emits one JSON payload:

    {"meta": {"serial_s": ..., "parallel_s": ..., "speedup": ...},
     "matrix": [{"node_policy": ..., "dispatcher": ..., "n_nodes": ...,
                 "cost_usd": ..., "p99_slowdown": ..., ...}, ...]}

Standalone: ``python -m benchmarks.cluster_bench [--smoke]``; also
registered as ``cluster_matrix`` in ``benchmarks.run``.
"""
from __future__ import annotations

import json
import os
import sys

from repro.cluster import build_grid, compare_serial
from repro.cluster import DISPATCHERS as _DISPATCHER_REGISTRY
from repro.cluster.sweep import print_rows

from .common import RESULTS

DISPATCHERS = tuple(sorted(_DISPATCHER_REGISTRY))
NODE_POLICIES = ("cfs", "hybrid")
FLEET_SIZES = (2, 4)


def _grid(smoke: bool = False):
    return build_grid(
        NODE_POLICIES, DISPATCHERS, FLEET_SIZES,
        cores_per_node=8, minutes=1,
        invocations_per_min=300.0 if smoke else 1200.0,
        n_functions=40 if smoke else 80, seed=0)


def cluster_matrix(smoke: bool = None) -> list[dict]:
    # ``benchmarks.run`` calls benches with no arguments; CI selects the
    # small-trace grid through the environment instead.
    if smoke is None:
        smoke = bool(os.environ.get("CLUSTER_BENCH_SMOKE"))
    cmp = compare_serial(_grid(smoke))
    rows = cmp.pop("rows")
    # ``benchmarks.run`` persists the return value as <name>.json, so
    # fold the serial-vs-parallel timing meta into the first row.
    if rows:
        rows[0] = {**rows[0], **{f"sweep_{k}": v for k, v in cmp.items()}}
    return rows


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = cluster_matrix(smoke=smoke)
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / "cluster_matrix.json").write_text(
        json.dumps({"matrix": rows}, indent=2))
    print_rows(rows)
    speedup = rows[0].get("sweep_speedup") if rows else None
    if speedup:
        print(f"# sweep speedup {speedup:.2f}x", file=sys.stderr)


if __name__ == "__main__":
    main()
