"""One benchmark per paper table/figure (DESIGN.md Sec. 7 index).

Each ``figXX()`` returns rows that reproduce the figure's quantity; the
driver (run.py) times them and emits name,us_per_call,derived CSV.
"""
from __future__ import annotations

import numpy as np

from repro.core import execute_policy
from repro.core.cost import MEMORY_LADDER_MB
from repro.core.hybrid import Rightsizer, TimeLimitAdapter

from .common import cdf_points, paper_workload


def _metrics_row(res, policy):
    return {
        "policy": policy,
        "mean_execution_s": float(res.execution().mean()) / 1e3,
        "p50_execution_s": res.p("execution", 50) / 1e3,
        "p99_execution_s": res.p("execution", 99) / 1e3,
        "p99_response_s": res.p("response", 99) / 1e3,
        "p99_turnaround_s": res.p("turnaround", 99) / 1e3,
        "preemptions": res.total_preemptions(),
        "makespan_s": res.makespan() / 1e3,
        "cost_usd": res.cost_usd(),
    }


def fig01_cost_fifo_cfs():
    """Fig. 1: FIFO vs CFS cost over the memory-size ladder."""
    w = paper_workload()
    rows = []
    for policy in ("fifo", "cfs"):
        res = execute_policy(policy, w)
        ladder = res.cost_ladder()
        for mb in MEMORY_LADDER_MB:
            rows.append({"policy": policy, "mem_mb": mb,
                         "cost_usd": ladder[mb]})
    f = {r["mem_mb"]: r["cost_usd"] for r in rows if r["policy"] == "fifo"}
    c = {r["mem_mb"]: r["cost_usd"] for r in rows if r["policy"] == "cfs"}
    rows.insert(0, {"policy": "ratio", "mem_mb": 0,
                    "cost_usd": c[1024] / f[1024]})
    return rows


def fig04_fifo_vs_cfs():
    w = paper_workload()
    rows = []
    for policy in ("fifo", "cfs"):
        res = execute_policy(policy, w)
        row = _metrics_row(res, policy)
        row["execution_cdf"] = cdf_points(res.execution())
        row["response_cdf"] = cdf_points(res.response())
        row["turnaround_cdf"] = cdf_points(res.turnaround())
        rows.append(row)
    return rows


def fig05_fifo_preempt():
    """Fig. 5: FIFO vs FIFO_100ms (preemption improves response &
    turnaround at execution-time cost)."""
    w = paper_workload()
    rows = [_metrics_row(execute_policy("fifo", w), "fifo"),
            _metrics_row(execute_policy("fifo_preempt", w, quantum_ms=100.0),
                         "fifo_100ms")]
    return rows


def fig06_hybrid_vs_fifo():
    w = paper_workload()
    return [_metrics_row(execute_policy("fifo", w), "fifo"),
            _metrics_row(execute_policy("hybrid", w, time_limit_ms=1633.0),
                         "fifo+cfs(25/25)")]


def fig11_core_tuning():
    """Fig. 11: FIFO/CFS core-split sweep at the 1,633 ms limit."""
    w = paper_workload()
    rows = []
    for n_fifo in (10, 20, 25, 30, 40):
        res = execute_policy("hybrid", w, n_fifo=n_fifo,
                         time_limit_ms=1633.0)
        row = _metrics_row(res, f"hybrid({n_fifo}/{50 - n_fifo})")
        rows.append(row)
    rows.append(_metrics_row(execute_policy("cfs", w), "cfs"))
    return rows


def fig12_14_hybrid_vs_cfs():
    """Figs. 12-14: hybrid vs CFS metrics + per-core preemptions +
    group utilization."""
    w = paper_workload()
    hyb = execute_policy("hybrid", w, time_limit_ms=1633.0, trace_util=True)
    cfs = execute_policy("cfs", w)
    rows = [_metrics_row(hyb, "hybrid"), _metrics_row(cfs, "cfs")]
    rows[0]["preempt_per_core"] = hyb.preempt_per_core
    rows[1]["preempt_per_core"] = cfs.preempt_per_core
    if hyb.util_series:
        rows[0]["util_series"] = [
            {"t_s": t / 1e3, "fifo": u.get(0, 0.0), "cfs": u.get(1, 0.0)}
            for t, u, _ in hyb.util_series[:600]]
    return rows


def fig15_17_time_limit():
    """Figs. 15-17: adaptive limit percentile sweep."""
    w = paper_workload()
    rows = []
    for pct in (25, 50, 75, 90, 95):
        res = execute_policy("hybrid", w,
                         adapter=TimeLimitAdapter(pct=float(pct),
                                                  record_series=True))
        row = _metrics_row(res, f"ts=p{pct}")
        if res.limit_series:
            ls = res.limit_series
            row["limit_final_ms"] = ls[-1][1]
            row["limit_series"] = [
                {"t_s": t / 1e3, "limit_ms": l} for t, l in ls[::200]]
        rows.append(row)
    return rows


def fig18_19_rightsizing():
    w = paper_workload()
    fixed = execute_policy("hybrid", w, adapt_pct=95.0, trace_util=True)
    dyn = execute_policy("hybrid", w, adapt_pct=95.0, rightsize=True,
                     trace_util=True)
    rows = [_metrics_row(fixed, "fixed-cores"),
            _metrics_row(dyn, "rightsized")]
    rows[1]["core_migrations"] = len(dyn.migrations or [])
    if dyn.util_series:
        rows[1]["n_fifo_series"] = [
            {"t_s": t / 1e3, "n_fifo": n} for t, _, n in
            dyn.util_series[:600]]
    return rows


def fig20_table1_cost():
    """Fig. 20 + Table I: cost ladder + p99 table for FIFO/CFS/Ours
    (ghOSt-mode: native-CFS spawn-storm interference on, as measured
    in the paper's testbed; idealized numbers in fig0x benches)."""
    w = paper_workload()
    rows = []
    for policy, name, kw in (
            ("fifo", "fifo", {}),
            ("cfs", "cfs", {}),
            ("hybrid", "ours", dict(adapt_pct=95.0, rightsize=True))):
        res = execute_policy(policy, w, ghost_mode=True, **kw)
        row = _metrics_row(res, name)
        row["cost_ladder"] = {str(mb): c
                              for mb, c in res.cost_ladder().items()}
        rows.append(row)
    return rows


def fig21_22_microvm():
    """Figs. 21/22: Firecracker microVM mode (boot overhead, VMM tax,
    2,952-instance admission cap)."""
    w = paper_workload(minutes=2)
    rows = []
    for policy, kw in (("cfs", {}),
                       ("hybrid", dict(adapt_pct=95.0))):
        res = execute_policy(policy, w, microvm=True, **kw)
        row = _metrics_row(res, f"uvm-{policy}")
        row["failed_to_launch"] = len(res.failed)
        rows.append(row)
    c, h = rows[0]["cost_usd"], rows[1]["cost_usd"]
    rows.insert(0, {"policy": "saving", "value": (c - h) / c})
    return rows


def fig23_pareto():
    """Fig. 23: cost vs p99 response across the scheduler zoo."""
    w = paper_workload()
    rows = []
    for policy, name, kw in (
            ("fifo", "fifo", {}),
            ("cfs", "cfs", {}),
            ("rr", "rr", {}),
            ("edf", "edf", {}),
            ("fifo_preempt", "fifo_100ms", dict(quantum_ms=100.0)),
            ("hybrid", "hybrid", dict(time_limit_ms=1633.0)),
            ("hybrid", "hybrid+adapt+rs",
             dict(adapt_pct=95.0, rightsize=True))):
        res = execute_policy(policy, w, **kw)
        rows.append({"policy": name, "cost_usd": res.cost_usd(),
                     "p99_response_s": res.p("response", 99) / 1e3})
    return rows
