"""Serving-gateway benchmarks: the paper's technique on model serving
(per assigned arch) + roofline summary from the dry-run artifacts."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCHS, get_config
from repro.scenario import (FleetSpec, PolicySpec, Scenario, ServingSpec,
                            WorkloadSpec, run)
from repro.serving import requests_from_trace
from repro.traces import TraceSpec

GATEWAY_TRACE = TraceSpec(minutes=1, invocations_per_min=6000,
                          n_functions=120, seed=11)  # overload regime


def _gateway(cfg, policy, reqs):
    """One-big-node serving scenario — the historical run_gateway
    defaults (50 slots, 25 FIFO, 95th-pct adaptation, rightsizing)."""
    return run(Scenario(
        workload=WorkloadSpec(kind="tasks", tasks=reqs),
        fleet=FleetSpec(cores_per_node=50),
        policy=PolicySpec(name=policy, adapt_pct=95.0, rightsize=True,
                          n_fifo=25 if policy == "hybrid" else None,
                          serving=ServingSpec(model=cfg)))).raw


def serving_gateway():
    """Hybrid vs CFS-analogue vs FIFO per architecture (billing +
    p99s). The savings follow the per-arch preemption cost: SSM archs
    (cheap state swaps) vs long-KV dense archs."""
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        reqs = requests_from_trace(cfg, GATEWAY_TRACE)
        out = {}
        for policy in ("fifo", "cfs", "hybrid"):
            out[policy] = _gateway(cfg, policy, reqs)
        rows.append({
            "arch": arch,
            "cost_fifo": out["fifo"].cost_usd(),
            "cost_cfs": out["cfs"].cost_usd(),
            "cost_hybrid": out["hybrid"].cost_usd(),
            "saving_vs_cfs":
                out["cfs"].cost_usd() / max(out["hybrid"].cost_usd(),
                                            1e-12),
            "p99_exec_hybrid_s": out["hybrid"].p("execution", 99) / 1e3,
            "p99_resp_hybrid_s": out["hybrid"].p("response", 99) / 1e3,
        })
    rows.sort(key=lambda r: -r["saving_vs_cfs"])
    rows.insert(0, {"arch": "best", "value": rows[0]["saving_vs_cfs"]})
    return rows


def roofline_table(results_dir: str = "results/dryrun"):
    """Collate the dry-run artifacts into the Sec.-Roofline table."""
    rows = []
    for p in sorted(Path(results_dir).glob("*__single.json")):
        d = json.loads(p.read_text())
        if d.get("status") != "ok":
            rows.append({"cell": p.stem, "status": d.get("status"),
                         "reason": d.get("reason", "")[:60]})
            continue
        rows.append({
            "cell": f'{d["arch"]}__{d["shape"]}',
            "t_compute_s": round(d["t_compute"], 4),
            "t_memory_s": round(d["t_memory"], 4),
            "t_collective_s": round(d["t_collective"], 4),
            "bottleneck": d["bottleneck"],
            "useful_flops_ratio": (round(d["useful_flops_ratio"], 3)
                                   if d.get("useful_flops_ratio") else None),
            "mem_temp_gb": round((d.get("mem_temp_bytes") or 0) / 2**30, 2),
        })
    if not rows:
        rows = [{"cell": "missing", "value": 0}]
    return rows
