"""Topology benchmark: correlated zone failure x spot churn x retry.

The resilience bench measures uncorrelated churn (one node at a time);
real outages are CORRELATED — a zone loses power, a rack loses its
switch, the spot market reclaims every discounted machine at once. This
bench runs a 2-zone fleet with a heterogeneous SKU mix (std + spot in
both zones) through the ``zone_failure_preset`` storm — a brownout
(slow-not-dead degrade) in one zone, a full zone kill in the other,
then a fleet-wide spot revocation, with heals trickling in — and asks
what the degradation stack buys:

variant  dispatcher     retry policy                     topology pricing
none     least_loaded   off (instant requeue storms)     labels only
retry    least_loaded   backoff + jitter + budget        labels only
full     cost_aware*    backoff + jitter + budget        SKU $ + zone hops

(* cost_aware prices each route in dollars: SKU multiplier, spot
discount, and the cross-zone hop priced like billed latency.)

Each variant runs for {cfs, hybrid} node fleets x chaos {off,
zonefail}. The retry budget is sized so nothing is shed (the breaker is
off): every cell completes the identical invocation set and the dollars
are directly comparable. Headline: hybrid+full under the zone-failure
storm must be STRICTLY cheaper than cfs+none under the same storm —
the paper's margin, measured while a zone is down and the spot capacity
is being repossessed.

Emits ``results/benchmarks/BENCH_topology.json`` with one row per cell
(keyed on node_policy/dispatcher/chaos plus the topology axes
zones/spot/retry — the regression gate's topology cell key) and the
headline folded into the first row. Standalone: ``python -m
benchmarks.topology_bench [--smoke]``; also registered as
``topology_matrix`` in ``benchmarks.run``. ``--shard i/n`` and
``--merge`` follow the resilience bench's contract: deterministic
disjoint slices, headline recomputed only over the reassembled full
matrix.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.cluster import (ClusterSim, RetryPolicy, TopologySpec,
                           zone_failure_preset)
from repro.core import ContainerConfig
from repro.traces import TraceSpec, generate_workload

from .common import RESULTS

CORES = 8

# 2 zones x 3 racks x 1 node: z0 = {std, spot, std}, z1 = {spot, std,
# spot} — both zones hold revocable discounted capacity, so the spot
# revocation event bites whichever zone survives the kill. Heals come
# up as std machines in z0 (the surviving zone).
TOPOLOGY = TopologySpec(zones=("z0", "z1"), racks_per_zone=3,
                        nodes_per_rack=1,
                        sku_pattern=("std", "spot", "std", "spot",
                                     "std", "spot"),
                        cross_zone_ms=30.0, heal_zone="z0")

# Budget sized above the storm's worst retry chain (breaker off): no
# cell sheds, so every cell completes the identical invocation set and
# the headline compares dollars for the SAME work.
RETRY = RetryPolicy(base_ms=250.0, cap_ms=8_000.0, jitter_frac=0.5,
                    budget=8, breaker_threshold=0)

VARIANTS = (
    # (variant, dispatcher, retry?)
    ("none", "least_loaded", False),
    ("retry", "least_loaded", True),
    ("full", "cost_aware", True),
)

HEAD_WIN = ("hybrid", "full", "zonefail")
HEAD_BASE = ("cfs", "none", "zonefail")


def _trace(smoke: bool) -> TraceSpec:
    # 1800/min on 48 cores leaves calm-weather headroom; the zone kill
    # halves the fleet mid-storm, which is exactly when the retry and
    # pricing layers must earn their keep. Full tier doubles horizon
    # and function population, not the rate.
    return TraceSpec(minutes=1 if smoke else 2,
                     invocations_per_min=1800.0,
                     n_functions=40 if smoke else 80, seed=0)


def _cells():
    # Both tiers run the SAME 12 cells; only the trace scale differs.
    for policy in ("cfs", "hybrid"):
        for variant, disp, retry in VARIANTS:
            for chaos in ("off", "zonefail"):
                yield policy, variant, disp, retry, chaos


def _run_cell(tasks, spec, policy, variant, disp, retry,
              chaos) -> dict:
    horizon_ms = spec.minutes * 60_000.0
    sim = ClusterSim(
        cores_per_node=CORES, node_policies=policy, dispatcher=disp,
        seed=0, containers=ContainerConfig(keepalive_ms=30_000.0),
        topology=TOPOLOGY)
    res = sim.run(
        tasks,
        chaos=zone_failure_preset(horizon_ms, kill="z1", brownout="z0",
                                  node_policy=policy)
        if chaos == "zonefail" else None,
        retry=RETRY if retry else None)
    s = res.summary()
    row = {
        "node_policy": policy,
        "variant": variant,
        "dispatcher": disp,
        "chaos": chaos,
        # Topology axes of the regression-gate cell key (all default
        # "off" there, so flat-fleet baselines never cross-compare).
        "zones": str(len(TOPOLOGY.zones)),
        "spot": "on",
        "retry": "on" if retry else "off",
        "n_nodes": TOPOLOGY.n_nodes,
        "cores_per_node": CORES,
        # Trace scale keys the gate cell: smoke- and full-tier
        # artifacts must never cross-compare as if same-scale.
        "minutes": spec.minutes,
        "invocations_per_min": spec.invocations_per_min,
        "n_functions": spec.n_functions,
    }
    for k in ("n", "failed", "shed", "cost_usd", "rejected_cost_usd",
              "init_cost_usd", "warm_hold_usd", "cold_start_rate",
              "cold_starts", "requeued", "chaos_events", "retries",
              "retry_wait_ms", "revoked", "degraded_ms", "cross_zone",
              "spot_savings_usd", "p99_slowdown", "makespan_s"):
        row[k] = s[k]
    row["total_cost_usd"] = res.total_cost_usd()
    return row


def _pick(rows, policy, variant, chaos):
    for r in rows:
        if (r["node_policy"], r["variant"], r["chaos"]) == \
                (policy, variant, chaos):
            return r
    raise KeyError((policy, variant, chaos))


def _headline(rows) -> dict:
    win, base = _pick(rows, *HEAD_WIN), _pick(rows, *HEAD_BASE)
    calm_win = _pick(rows, HEAD_WIN[0], HEAD_WIN[1], "off")
    calm_base = _pick(rows, HEAD_BASE[0], HEAD_BASE[1], "off")
    return {
        "full_hybrid_zonefail_cost_usd": win["total_cost_usd"],
        "none_cfs_zonefail_cost_usd": base["total_cost_usd"],
        "saving_under_zonefail": 1.0 - win["total_cost_usd"]
        / base["total_cost_usd"],
        "saving_calm": 1.0 - calm_win["total_cost_usd"]
        / calm_base["total_cost_usd"],
        # Apples-to-apples guard: the headline only means something if
        # both cells completed the same invocations.
        "same_completed_set": win["n"] == base["n"]
        and win["shed"] == base["shed"] == 0,
        "cheaper": win["total_cost_usd"] < base["total_cost_usd"],
    }


def topology_matrix(smoke: bool = None,
                    shard: str = None) -> list[dict]:
    if smoke is None:
        smoke = bool(os.environ.get("CLUSTER_BENCH_SMOKE"))
    spec = _trace(smoke)
    tasks = generate_workload(spec).tasks
    cells = list(_cells())
    if shard is not None:
        from repro.cluster.sweep import shard_grid
        cells = shard_grid(cells, shard)
    rows = [_run_cell(tasks, spec, *cell) for cell in cells]
    if shard is None:
        head = _headline(rows)
        rows[0] = {**rows[0],
                   **{f"headline_{k}": v for k, v in head.items()}}
    return rows


def _cell_order(row: dict) -> int:
    """Canonical position of a row in the unsharded ``_cells()`` order."""
    order = {(p, v, c): i for i, (p, v, _d, _r, c)
             in enumerate(_cells())}
    return order[(row["node_policy"], row["variant"], row["chaos"])]


def merge_shards(paths: list[str]) -> list[dict]:
    """Fold per-shard artifacts into the canonical full matrix: rows in
    unsharded cell order, headline recomputed over the complete set.
    Raises if the shards do not reassemble exactly the 12-cell grid."""
    rows: list[dict] = []
    for p in paths:
        payload = json.loads(open(p).read())
        rows.extend(payload["matrix"] if isinstance(payload, dict)
                    else payload)
    expected = len(list(_cells()))
    keys = {_cell_order(r) for r in rows}
    if len(rows) != expected or keys != set(range(expected)):
        raise SystemExit(
            f"shards reassemble {sorted(keys)} of 0..{expected - 1} "
            f"({len(rows)} rows) — refusing to merge a partial matrix")
    rows.sort(key=_cell_order)
    head = _headline(rows)
    rows[0] = {**rows[0], **{f"headline_{k}": v for k, v in head.items()}}
    return rows


COLS = ("node_policy", "variant", "chaos", "cost_usd", "total_cost_usd",
        "retries", "requeued", "revoked", "cross_zone",
        "spot_savings_usd", "p99_slowdown")


def main(argv=None) -> None:
    from repro.cluster.sweep import print_rows
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--shard", default=None, metavar="i/n",
                    help="run only this deterministic 1/n slice of the "
                         "12-cell matrix (no headline; recombine with "
                         "--merge)")
    ap.add_argument("--merge", nargs="+", default=None, metavar="JSON",
                    help="merge per-shard --out files into --out and "
                         "exit (headline recomputed; no cells run)")
    ap.add_argument("--out", default=None,
                    help="artifact path (default "
                         "results/benchmarks/BENCH_topology.json)")
    args = ap.parse_args(argv)
    out = args.out or str(RESULTS / "BENCH_topology.json")

    if args.merge:
        rows = merge_shards(args.merge)
    else:
        rows = topology_matrix(smoke=args.smoke, shard=args.shard)
    RESULTS.mkdir(parents=True, exist_ok=True)
    with open(out, "w") as f:
        json.dump({"matrix": rows}, f, indent=2)
    print_rows(rows, COLS)
    if args.shard:
        print(f"# shard {args.shard}: {len(rows)} cells -> {out} "
              f"(headline deferred to --merge)", file=sys.stderr)
        return
    first = rows[0]
    print(f"# hybrid+retry+priced-dispatch vs cfs+instant-requeue under "
          f"zone failure + spot churn: cheaper={first['headline_cheaper']} "
          f"(saving {first['headline_saving_under_zonefail']:.1%} storm, "
          f"{first['headline_saving_calm']:.1%} calm; "
          f"same completed set={first['headline_same_completed_set']})",
          file=sys.stderr)
    if not first["headline_cheaper"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
