"""Benchmark driver: one entry per paper table/figure + serving/roofline.

Prints ``name,us_per_call,derived`` CSV; full rows land in
results/benchmarks/*.json.
"""
from __future__ import annotations

import sys

from . import cluster_bench as C
from . import costmodel_bench as CM
from . import paper_figures as F
from . import llm_faas_bench as L
from . import resilience_bench as R
from . import topology_bench as T
from . import serving_bench as S
from .common import emit, timed

BENCHES = [
    ("fig01_cost_fifo_cfs", F.fig01_cost_fifo_cfs),
    ("fig04_fifo_vs_cfs", F.fig04_fifo_vs_cfs),
    ("fig05_fifo_preempt", F.fig05_fifo_preempt),
    ("fig06_hybrid_vs_fifo", F.fig06_hybrid_vs_fifo),
    ("fig11_core_tuning", F.fig11_core_tuning),
    ("fig12_14_hybrid_vs_cfs", F.fig12_14_hybrid_vs_cfs),
    ("fig15_17_time_limit", F.fig15_17_time_limit),
    ("fig18_19_rightsizing", F.fig18_19_rightsizing),
    ("fig20_table1_cost", F.fig20_table1_cost),
    ("fig21_22_microvm", F.fig21_22_microvm),
    ("fig23_pareto", F.fig23_pareto),
    ("serving_gateway", S.serving_gateway),
    ("roofline_table", S.roofline_table),
    ("cluster_matrix", C.cluster_matrix),
    ("resilience_matrix", R.resilience_matrix),
    ("topology_matrix", T.topology_matrix),
    ("llm_faas", L.llm_faas_matrix),
    ("costmodel", CM.costmodel_matrix),
]


def main() -> None:
    import json
    from .common import RESULTS
    args = [a for a in sys.argv[1:] if a != "--reuse"]
    only = args[0] if args else None
    reuse = "--reuse" in sys.argv
    print("name,us_per_call,derived", flush=True)
    for name, fn in BENCHES:
        if only and only not in name:
            continue
        path = RESULTS / f"{name}.json"
        if reuse and path.exists():
            rows = json.loads(path.read_text())
            emit(name, rows, 0.0)
            continue
        rows, dt = timed(fn)
        emit(name, rows, dt)


if __name__ == "__main__":
    main()
