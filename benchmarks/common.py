"""Shared benchmark plumbing: cached workload, timing, CSV emission."""
from __future__ import annotations

import functools
import json
import time
from pathlib import Path

from repro.traces import TraceSpec, generate_workload

RESULTS = Path("results/benchmarks")


@functools.lru_cache(maxsize=4)
def paper_workload(minutes: int = 2):
    """The paper's workload: first `minutes` of the (synthesized) Azure
    trace — 12,442 invocations for minutes=2."""
    return generate_workload(TraceSpec(minutes=minutes)).tasks


def timed(fn, *a, **kw):
    t0 = time.time()
    out = fn(*a, **kw)
    return out, time.time() - t0


def emit(name: str, rows: list[dict], elapsed_s: float) -> None:
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(
        json.dumps(rows, indent=2, default=str))
    us = elapsed_s * 1e6
    derived = rows[0] if rows else {}
    key = next((k for k in ("cost_usd", "p99_execution_s", "value")
                if k in derived), None)
    dv = derived.get(key, "")
    print(f"{name},{us:.0f},{dv}", flush=True)


def cdf_points(vals, n: int = 50):
    import numpy as np
    v = np.sort(np.asarray(vals))
    qs = np.linspace(0, 100, n)
    return [{"pct": float(q), "value_ms": float(np.percentile(v, q))}
            for q in qs]
