"""Fleet simulation quickstart: does the paper's hybrid still win once a
cluster dispatcher sits in front of the nodes?

Runs a 5-dispatcher x {cfs, hybrid} x {2, 4}-node grid in parallel and
prints the cost matrix plus the serial-vs-parallel sweep speedup.

    python examples/cluster_sweep.py
"""
import repro
from repro import FleetSpec, PolicySpec, Scenario, WorkloadSpec
from repro.cluster import build_grid, compare_serial
from repro.traces import TraceSpec


def main():
    # -- one cell, spelled out ------------------------------------------------
    spec = TraceSpec(minutes=1, invocations_per_min=1200, n_functions=80,
                     seed=0)
    res = repro.run(Scenario(
        workload=WorkloadSpec(kind="azure", trace=spec),
        fleet=FleetSpec(n_nodes=4, cores_per_node=8,
                        dispatcher="join_idle_queue"),
        policy=PolicySpec(name="hybrid"))).raw
    s = res.summary()
    print(f"one cell: {s['n_nodes']} nodes x {s['cores_per_node']} cores, "
          f"{s['dispatcher']} dispatch, hybrid nodes")
    print(f"  cost ${s['cost_usd']:.4f}  "
          f"p99 slowdown {s['p99_slowdown']:.1f}x  "
          f"util {s['util_mean']:.2f} (range {s['util_range']:.2f})\n")

    # -- the full grid, in parallel -------------------------------------------
    grid = build_grid(
        ["cfs", "hybrid"],
        ["random", "round_robin", "least_loaded", "join_idle_queue",
         "affinity"],
        [2, 4], cores_per_node=8, minutes=1, invocations_per_min=1200.0,
        n_functions=80)
    cmp = compare_serial(grid)
    print(f"{len(grid)}-cell sweep: serial {cmp['serial_s']:.1f}s, "
          f"parallel {cmp['parallel_s']:.1f}s "
          f"({cmp['speedup']:.1f}x speedup)\n")

    print(f"{'node policy':<12} {'dispatcher':<16} {'nodes':>5} "
          f"{'cost $':>9} {'p99 slow':>9}")
    for row in sorted(cmp["rows"], key=lambda r: r["cost_usd"]):
        print(f"{row['node_policy']:<12} {row['dispatcher']:<16} "
              f"{row['n_nodes']:>5} {row['cost_usd']:>9.4f} "
              f"{row['p99_slowdown']:>9.1f}")


if __name__ == "__main__":
    # compare_serial forks a multiprocessing pool: spawn-start platforms
    # (macOS, Windows) re-import this module in the children.
    main()
