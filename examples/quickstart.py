"""Quickstart: reproduce the paper's headline result in ~a minute.

Runs the first two minutes of the (synthesized) Azure FaaS trace through
FIFO, CFS, and the hybrid scheduler on a 50-core host and prints the
Table-I-style comparison: the Linux default (CFS) costs an order of
magnitude more than FIFO; the hybrid scheduler keeps FIFO's cost with
far better tail response. Everything goes through the one front door,
``repro.run`` (DESIGN.md Sec. 15).

    PYTHONPATH=src python examples/quickstart.py [--fast]
"""
import argparse
import sys

sys.path.insert(0, "src")

import repro
from repro import FleetSpec, PolicySpec, Scenario, WorkloadSpec
from repro.traces import TraceSpec, generate_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="30s workload instead of the full 2 minutes")
    args = ap.parse_args()

    spec = TraceSpec(minutes=2)
    w = generate_workload(spec)
    tasks = w.tasks
    if args.fast:
        tasks = [t for t in tasks if t.arrival < 30_000]
    print(f"workload: {len(tasks)} invocations "
          f"(p90 duration {w.p90_service():.0f} ms)")

    rows = {}
    for policy, pol_kw in (("fifo", {}), ("cfs", {}),
                           ("hybrid", dict(adapt_pct=95.0,
                                           rightsize=True))):
        res = repro.run(Scenario(
            workload=WorkloadSpec(kind="tasks", tasks=tasks),
            fleet=FleetSpec(cores_per_node=50),
            policy=PolicySpec(name=policy, **pol_kw)))
        rows[policy] = res
        s = res.raw.summary()
        print(f"{policy:8s} p99resp={s['p99_response_s']:8.2f}s "
              f"p99exec={s['p99_execution_s']:8.2f}s "
              f"cost=${s['cost_usd']:.4f}")
    ratio = rows["cfs"].total_cost_usd() / rows["fifo"].total_cost_usd()
    save = rows["cfs"].total_cost_usd() / rows["hybrid"].total_cost_usd()
    print(f"\nCFS costs {ratio:.1f}x FIFO (paper: >10x).")
    print(f"Hybrid saves {save:.1f}x vs CFS (paper Table I: ~41x).")


if __name__ == "__main__":
    main()
