"""End-to-end training driver: train a ~100M-param deepseek-family model
for a few hundred steps with the production substrate (AdamW + cosine,
remat, checkpoint/restart, resumable data, straggler watchdog).

    PYTHONPATH=src python examples/train_lm.py --steps 300

By default uses a reduced width so a few hundred steps fit CPU minutes;
pass --d-model 768 --layers 12 for the full ~100M config if you have
time (or a TPU).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.distributed import count_params, materialize
from repro.models import LM, model_specs
from repro.training import SyntheticLM, init_opt_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config("deepseek-7b").with_(
        n_layers=args.layers, d_model=args.d_model,
        n_heads=max(args.d_model // 64, 1),
        n_kv_heads=max(args.d_model // 64, 1),
        d_ff=args.d_model * 4, vocab=8192)
    lm = LM(cfg)
    specs = model_specs(cfg)
    print(f"model: {count_params(specs) / 1e6:.1f}M params")
    params = materialize(specs, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    tcfg = TrainConfig(lr=3e-4, total_steps=args.steps,
                       warmup_steps=args.steps // 10)
    step_fn = jax.jit(make_train_step(lm, tcfg), donate_argnums=(0, 1))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                      batch=args.batch)
    ckpt = CheckpointManager(args.ckpt, keep=2, async_save=True)
    start, state = ckpt.restore_latest(
        {"params": params, "opt": opt, "data": data.state_dict()})
    if start is not None:
        params, opt = state["params"], state["opt"]
        data.load_state(state["data"])
        print(f"resumed from step {start}")
    t0 = time.time()
    for step in range((start or 0), args.steps):
        params, opt, m = step_fn(params, opt, data.next_batch())
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(m['loss']):.4f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
        if (step + 1) % 100 == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt,
                                 "data": data.state_dict()})
    ckpt.wait()
    print("done")


if __name__ == "__main__":
    main()
