"""Fault-tolerance drill: kill training mid-run, restart, verify exact
resume; then simulate a device-count change and re-mesh.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import subprocess
import sys

sys.path.insert(0, "src")

import jax

from repro.distributed.elastic import ElasticRunner, viable_meshes


def main():
    ckpt = "/tmp/repro_elastic_demo"
    subprocess.run(["rm", "-rf", ckpt])
    base = [sys.executable, "-m", "repro.launch.train",
            "--arch", "deepseek-7b", "--steps", "40", "--batch", "2",
            "--seq", "32", "--ckpt-dir", ckpt, "--ckpt-every", "10"]
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    print("== phase 1: run 40 steps, checkpoints every 10 ==")
    r1 = subprocess.run(base, capture_output=True, text=True, env=env)
    print("\n".join(r1.stdout.splitlines()[-3:]))
    print("== phase 2: 'crash' happened; restart asks for 60 steps ==")
    base[base.index("40")] = "60"
    r2 = subprocess.run(base, capture_output=True, text=True, env=env)
    out = r2.stdout.splitlines()
    assert any("resumed from step 40" in l for l in out), out[:5]
    print("\n".join(out[:2] + out[-2:]))
    print("== phase 3: elastic re-mesh after device-count change ==")
    for n in (256, 512, 128):
        print(f"  {n} devices -> viable (data, model) meshes: "
              f"{viable_meshes(n)[:3]} ...")
    runner = ElasticRunner(
        build_step=lambda ctx: (lambda: ctx.mesh.devices.shape))
    fn = runner.ensure(jax.devices())
    print(f"  re-lowered step on mesh {fn()} (1-device CPU container)")


if __name__ == "__main__":
    main()
