"""LLM-inference-as-FaaS: serve a 7B replica fleet through the cluster
scheduler sim and compare what the OS scheduler choice costs.

One Scenario per policy: the trace's functions become model endpoints,
replicas are sandboxes (cold start = weight-load + compile, warm state
= KV/weights residency in the container pool), and every request is a
prefill task plus decode chunks whose preemptions pay the KV swap
(DESIGN.md Sec. 15).

    PYTHONPATH=src python examples/llm_faas.py
"""
import sys

sys.path.insert(0, "src")

import repro
from repro import FleetSpec, PolicySpec, Scenario, WorkloadSpec
from repro.serving.llm import LLMSpec
from repro.traces import TraceSpec


def main():
    trace = TraceSpec(minutes=1, invocations_per_min=300,
                      n_functions=12, seed=7)
    llm = LLMSpec(model="deepseek-7b")
    print(f"replica: {llm.replica_mem_mb() / 1024:.1f} GB "
          f"(weights + {llm.seq_len}-token KV), "
          f"cold start {llm.cold_start_ms() / 1e3:.1f}s "
          f"(weight stream + compile)")

    for policy in ("cfs", "hybrid"):
        res = repro.run(Scenario(
            workload=WorkloadSpec(kind="llm", trace=trace, llm=llm),
            fleet=FleetSpec(n_nodes=2, cores_per_node=8,
                            dispatcher="least_loaded", seed=1),
            policy=PolicySpec(
                name=policy,
                adapt_pct=95.0 if policy == "hybrid" else None,
                rightsize=policy == "hybrid")))
        s = res.summary()
        print(f"{policy:7s} {s['n_requests']} requests "
              f"({s['n']} chunks)  ${s['usd_per_1k_requests']:.4f}/1k  "
              f"p99 turnaround {s['p99_turnaround_s']:.1f}s  "
              f"{s['cold_starts']} replica instantiations")


if __name__ == "__main__":
    main()
