"""Serve a small model behind the FaaS gateway with batched requests:
the paper's hybrid scheduler decides which requests hold decode slots.

Shows both layers:
 1. REAL model serving (reduced gemma3: local/global attention, ring
    caches) through the engine with hybrid slot scheduling;
 2. the at-scale gateway simulation for the same arch, comparing
    hybrid vs CFS-analogue billing.

    PYTHONPATH=src python examples/serve_faas.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

import repro
from repro import (FleetSpec, PolicySpec, Scenario, ServingSpec,
                   WorkloadSpec)
from repro.configs import get_config, get_smoke
from repro.distributed import materialize
from repro.models import model_specs
from repro.serving import LiveRequest, ServingEngine, requests_from_trace
from repro.traces import TraceSpec


def main():
    # -- 1. real model through the engine ---------------------------------
    cfg = get_smoke("gemma3-12b")
    params = materialize(model_specs(cfg), jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, n_slots=4, n_fifo=2, max_len=64,
                        initial_limit_ms=30.0)
    key = jax.random.PRNGKey(1)
    for rid in range(8):
        toks = jax.random.randint(jax.random.fold_in(key, rid), (1, 8),
                                  0, cfg.vocab)
        eng.submit(LiveRequest(rid=rid, arrival_ms=0.0, tokens=toks,
                               max_new=3 + (rid % 4) * 5))
    print("== real-model engine (reduced gemma3-12b) ==")
    for r in eng.run():
        print(f"  req {r.rid}: {len(r.generated)} tokens, "
              f"exec {r.execution_ms():.0f} ms, "
              f"{r.preemptions} preemptions, ${r.cost_usd():.2e}")
    print(f"  adaptive limit ended at {eng.adapter.limit():.0f} ms")

    # -- 2. gateway at scale ------------------------------------------------
    print("== gateway simulation (full gemma3-12b service model) ==")
    cfg_full = get_config("gemma3-12b")
    reqs = requests_from_trace(
        cfg_full, TraceSpec(minutes=1, invocations_per_min=2500, seed=2))
    for policy in ("cfs", "hybrid"):
        r = repro.run(Scenario(
            workload=WorkloadSpec(kind="tasks", tasks=reqs),
            fleet=FleetSpec(cores_per_node=50),
            policy=PolicySpec(name=policy, adapt_pct=95.0,
                              rightsize=True,
                              n_fifo=25 if policy == "hybrid" else None,
                              serving=ServingSpec(model=cfg_full)))).raw
        print(f"  {policy:7s} cost=${r.cost_usd():.4f} "
              f"p99exec={r.p('execution', 99) / 1e3:.1f}s "
              f"p99resp={r.p('response', 99) / 1e3:.1f}s")


if __name__ == "__main__":
    main()
