"""Pallas TPU flash-decode: single-query attention over a long KV cache.

Grid (batch*heads, n_k_blocks): each step combines one KV block into a
running (m, l, acc) partial-softmax state in VMEM scratch — the classic
flash-decode block-parallel reduction, laid out sequentially per TPU
core. Per-row valid lengths (cache fill levels) are passed as a scalar
array and masked inside the kernel.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   k_block: int, nk: int, scale: float, window: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale            # (1, hd)
    k = k_ref[0].astype(jnp.float32)                    # (kb, hd)
    v = v_ref[0].astype(jnp.float32)
    s = (q @ k.T)[0]                                    # (kb,)
    pos = len_ref[0] - 1                                # current position
    k_idx = ki * k_block + jax.lax.iota(jnp.int32, s.shape[0])
    mask = k_idx <= pos
    if window > 0:
        mask &= k_idx > pos - window
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, s.max())
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                              # (kb,)
    acc_ref[...] = acc_ref[...] * alpha + (p[None, :] @ v)
    l_ref[0] = l_ref[0] * alpha + p.sum()
    m_ref[0] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[0], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k, v, lengths, *, k_block: int = 512,
                     window: int = 0, interpret: bool = False):
    """q: (BH, 1, hd); k, v: (BH, S, hd); lengths: (BH,) int32 — number
    of valid cache entries per row. Returns (BH, 1, hd)."""
    BH, _, hd = q.shape
    S = k.shape[1]
    k_block = min(k_block, S)
    nk = -(-S // k_block)
    if nk * k_block != S:
        pad = nk * k_block - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_decode_kernel, k_block=k_block, nk=nk,
                               scale=scale, window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(BH, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, k_block, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, k_block, hd), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b, j: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BH, 1, hd), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)
    return out
