"""Pallas TPU fused RMSNorm (+ scale) over row tiles."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))) \
        .astype(o_ref.dtype)


def fused_rmsnorm(x, w, *, eps: float = 1e-6, rows: int = 256,
                  interpret: bool = False):
    """x: (N, d); w: (d,). Returns rmsnorm(x) * (1 + w)."""
    N, d = x.shape
    rows = min(rows, N)
    nr = -(-N // rows)
    if nr * rows != N:
        x = jnp.pad(x, ((0, nr * rows - N), (0, 0)))
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nr * rows, d), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:N]
