"""Pallas TPU flash attention (prefill/train).

Grid (batch*heads, n_q_blocks, n_k_blocks); the k axis is the innermost
(sequential on TPU), so the online-softmax state (m, l, acc) lives in
VMEM scratch persisted across k steps. Causal/window blocks that are
entirely masked are skipped with pl.when. Block shapes are MXU-aligned
(q_block x head_dim, k_block x head_dim with 128-multiples preferred).

VMEM budget per step: q (qb,hd) + k,v (kb,hd) + acc (qb,hd) f32 +
scores (qb,kb) f32 — e.g. qb=kb=512, hd=128: ~2.4 MB, well inside the
16 MB/core v5e VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: int, scale: float,
                  q_block: int, k_block: int, nk: int, sk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * q_block
    k_lo = ki * k_block
    run = True
    if causal:
        run = k_lo <= q_lo + q_block - 1
    # (window check depends only on static ids -> python bool is fine
    #  when blocks are statically skippable; dynamic skip via pl.when)
    dyn_run = jnp.asarray(run)
    if window > 0:
        dyn_run &= (k_lo + k_block - 1) > (q_lo - window)

    @pl.when(dyn_run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale        # (qb, hd)
        k = k_ref[0].astype(jnp.float32)                # (kb, hd)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T                                     # (qb, kb)
        q_idx = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_idx = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_idx < sk
        if causal:
            mask &= k_idx <= q_idx
        if window > 0:
            mask &= k_idx > q_idx - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_block: int = 512, k_block: int = 512,
                    interpret: bool = False):
    """q: (BH, Sq, hd); k, v: (BH, Sk, hd). Returns (BH, Sq, hd)."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    q_block = min(q_block, Sq)
    k_block = min(k_block, Sk)
    nq = -(-Sq // q_block)
    nk = -(-Sk // k_block)
    if nq * q_block != Sq:
        q = jnp.pad(q, ((0, 0), (0, nq * q_block - Sq), (0, 0)))
    if nk * k_block != Sk:
        k = jnp.pad(k, ((0, 0), (0, nk * k_block - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, nk * k_block - Sk), (0, 0)))
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, scale=scale,
        q_block=q_block, k_block=k_block, nk=nk, sk=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, k_block, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, k_block, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * q_block, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
