"""repro.kernels — Pallas TPU kernels (+ jnp oracles in ref.py).

Validated in interpret mode on CPU; BlockSpecs sized for v5e VMEM.
"""
from . import ref
from .ops import (decode_attention, flash_attention, fused_rmsnorm,
                  gqa_flash_attention, rwkv6_scan, ssm_scan)

__all__ = ["ref", "decode_attention", "flash_attention", "fused_rmsnorm",
           "gqa_flash_attention", "rwkv6_scan", "ssm_scan"]
