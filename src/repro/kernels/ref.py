"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (BH, Sq, hd); k, v: (BH, Sk, hd) — naive softmax attention."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    q_idx = jnp.arange(Sq)[:, None]
    k_idx = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_idx <= q_idx
    if window > 0:
        mask &= k_idx > q_idx - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, lengths, *, window: int = 0):
    """q: (BH, 1, hd); k, v: (BH, S, hd); lengths: (BH,)."""
    BH, _, hd = q.shape
    S = k.shape[1]
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    pos = lengths[:, None] - 1
    k_idx = jnp.arange(S)[None, :]
    mask = k_idx <= pos
    if window > 0:
        mask &= k_idx > pos - window
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssm_scan_ref(xbar, B, C, cumlog, *, chunk: int = 64):
    """Sequential-oracle SSD scan. cumlog resets at chunk boundaries;
    the underlying per-step decay is a_t = exp(cumlog_t - cumlog_{t-1})
    (with the reset handled per chunk)."""
    BH, S, hd = xbar.shape
    # recover per-step log-decay from the chunked cumsum
    cl = cumlog.reshape(BH, S // chunk, chunk)
    step_log = jnp.concatenate(
        [cl[..., :1], cl[..., 1:] - cl[..., :-1]], axis=-1).reshape(BH, S)
    a = jnp.exp(step_log.astype(jnp.float32))            # (BH, S)

    def scan_one(xb_b, B_b, C_b, a_b):
        def step(h, inp):
            xb_t, B_t, C_t, a_t = inp
            h = h * a_t + xb_t[:, None] * B_t[None, :]
            y = h @ C_t
            return h, y
        h0 = jnp.zeros((hd, B_b.shape[-1]), jnp.float32)
        _, ys = jax.lax.scan(step, h0, (xb_b.astype(jnp.float32),
                                        B_b.astype(jnp.float32),
                                        C_b.astype(jnp.float32), a_b))
        return ys
    return jax.vmap(scan_one)(xbar, B, C, a).astype(xbar.dtype)


def rwkv6_scan_ref(r, k, v, w, u):
    """Sequential RWKV6 recurrence oracle."""
    BH, S, hd = r.shape

    def scan_one(r_b, k_b, v_b, w_b, u_b):
        def step(S_, inp):
            r_t, k_t, v_t, w_t = inp
            kv = k_t[:, None] * v_t[None, :]
            o = r_t @ (S_ + u_b[:, None] * kv)
            S_ = w_t[:, None] * S_ + kv
            return S_, o
        S0 = jnp.zeros((hd, hd), jnp.float32)
        _, os = jax.lax.scan(step, S0, (r_b.astype(jnp.float32),
                                        k_b.astype(jnp.float32),
                                        v_b.astype(jnp.float32),
                                        w_b.astype(jnp.float32)))
        return os
    return jax.vmap(scan_one)(r, k, v, w, u).astype(r.dtype)


def fused_rmsnorm_ref(x, w, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * (1.0 + w.astype(jnp.float32))).astype(x.dtype)
