"""jit'd public wrappers for the Pallas kernels.

``interpret=None`` auto-selects: real TPU lowering on TPU backends,
interpret mode elsewhere (this CPU container). The wrappers also accept
the model-layout GQA tensors and flatten them to kernel layout.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import decode_attention as _decode
from .flash_attention import flash_attention as _flash
from .fused_rmsnorm import fused_rmsnorm as _rmsnorm
from .rwkv6_scan import rwkv6_scan as _rwkv
from .ssm_scan import ssm_scan as _ssm


def _auto_interpret(interpret):
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "q_block",
                                   "k_block", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_block=512,
                    k_block=512, interpret=None):
    return _flash(q, k, v, causal=causal, window=window, q_block=q_block,
                  k_block=k_block, interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("window", "k_block", "interpret"))
def decode_attention(q, k, v, lengths, *, window=0, k_block=512,
                     interpret=None):
    return _decode(q, k, v, lengths, window=window, k_block=k_block,
                   interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(xbar, B, C, cumlog, *, chunk=64, interpret=None):
    return _ssm(xbar, B, C, cumlog, chunk=chunk,
                interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, w, u, *, chunk=32, interpret=None):
    return _rwkv(r, k, v, w, u, chunk=chunk,
                 interpret=_auto_interpret(interpret))


@partial(jax.jit, static_argnames=("eps", "rows", "interpret"))
def fused_rmsnorm(x, w, *, eps=1e-6, rows=256, interpret=None):
    return _rmsnorm(x, w, eps=eps, rows=rows,
                    interpret=_auto_interpret(interpret))


def gqa_flash_attention(q, k, v, **kw):
    """Model-layout wrapper: q (B, KV, G, S, hd), k/v (B, KV, S, hd)."""
    B, KV, G, S, hd = q.shape
    qf = q.reshape(B * KV, G * S, hd) if G == 1 else \
        q.transpose(0, 1, 2, 3, 4).reshape(B * KV * G, S, hd)
    kf = jnp.repeat(k.reshape(B * KV, -1, hd), G, axis=0) if G > 1 \
        else k.reshape(B * KV, -1, hd)
    vf = jnp.repeat(v.reshape(B * KV, -1, hd), G, axis=0) if G > 1 \
        else v.reshape(B * KV, -1, hd)
    out = flash_attention(qf, kf, vf, **kw)
    return out.reshape(B, KV, G, S, hd)
