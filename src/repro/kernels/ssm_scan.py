"""Pallas TPU Mamba2 (SSD) chunked selective scan.

Grid (batch*heads, n_chunks): the recurrent state h (hd, ds) persists in
VMEM scratch across sequential chunk steps; within a chunk the
intra-chunk term is the quadratic (Q,Q) decay-masked form (MXU work),
matching repro.models.ssm.ssm_block chunk math exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(xb_ref, b_ref, c_ref, cum_ref, y_ref, h_ref, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xb = xb_ref[0].astype(jnp.float32)          # (Q, hd)
    B = b_ref[0].astype(jnp.float32)            # (Q, ds)
    C = c_ref[0].astype(jnp.float32)            # (Q, ds)
    cum = cum_ref[0].astype(jnp.float32)        # (Q,) within-chunk cumsum
    tot = cum[-1]

    # intra-chunk: L[i,j] = exp(cum_i - cum_j), j <= i
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)
    sBB = C @ B.T                                # (Q, Q)
    y_intra = (sBB * L) @ xb                     # (Q, hd)
    # inter-chunk: state contribution decayed to each position
    h = h_ref[...]                               # (hd, ds)
    y_inter = jnp.exp(cum)[:, None] * (C @ h.T)  # (Q, hd)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)
    # state update
    decay_to_end = jnp.exp(tot - cum)            # (Q,)
    h_ref[...] = h * jnp.exp(tot) + \
        (xb * decay_to_end[:, None]).T @ B       # (hd, ds)


def ssm_scan(xbar, B, C, cumlog, *, chunk: int = 64,
             interpret: bool = False):
    """xbar: (BH, S, hd) dt-weighted inputs; B, C: (BH, S, ds);
    cumlog: (BH, S) per-chunk-reset cumulative log-decay.
    Returns y: (BH, S, hd).

    NOTE: cumlog must already be reset at chunk boundaries
    (cumsum within each chunk), matching the ref oracle.
    """
    BH, S, hd = xbar.shape
    ds = B.shape[-1]
    assert S % chunk == 0, "pad sequence to a chunk multiple"
    nc = S // chunk
    kernel = functools.partial(_ssm_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), xbar.dtype),
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(xbar, B, C, cumlog)
    return y
