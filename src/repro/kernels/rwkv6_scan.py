"""Pallas TPU RWKV6 time-mix recurrence (data-dependent decay).

Grid (batch*heads, n_chunks): matrix state S (hd, hd) persists in VMEM
scratch; within a chunk the recurrence is stepped with a fori_loop
(chunk is small; each step is rank-1 work on the VPU/MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                 chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)            # (Q, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)            # (Q, hd) decays in (0,1)
    u = u_ref[0].astype(jnp.float32)            # (hd,) bonus

    def step(t, carry):
        S, out = carry
        kv = k[t][:, None] * v[t][None, :]      # (hd, hd)
        o_t = r[t] @ (S + u[:, None] * kv)      # (hd,)
        out = out.at[t].set(o_t)
        S = w[t][:, None] * S + kv
        return S, out

    S0 = s_ref[...]
    out0 = jnp.zeros((chunk, r.shape[1]), jnp.float32)
    S_fin, out = jax.lax.fori_loop(0, chunk, step, (S0, out0))
    o_ref[0] = out.astype(o_ref.dtype)
    s_ref[...] = S_fin


def rwkv6_scan(r, k, v, w, u, *, chunk: int = 32,
               interpret: bool = False):
    """r, k, v, w: (BH, S, hd); u: (BH, hd) per-head bonus.
    Returns o: (BH, S, hd)."""
    BH, S, hd = r.shape
    assert S % chunk == 0, "pad sequence to a chunk multiple"
    nc = S // chunk
    kernel = functools.partial(_rwkv_kernel, chunk=chunk)
    o = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return o
