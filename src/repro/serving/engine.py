"""Real-model serving engine: per-slot decode with actual KV caches.

This is the executable (CPU-scale) counterpart of the gateway
simulation: a small LM really runs; the hybrid two-group slot scheduler
makes the same decisions the paper's scheduler makes (FIFO
run-to-completion group + fair-share group, sliding-window time-limit
adaptation); preemptions really evict/restore the request's cache
object and pay the modelled swap penalty in simulated wall-clock.

Slots are decode lanes (B=1 each here for clarity; the production
engine batches lanes into one decode step — scheduling logic is
identical).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.hybrid import TimeLimitAdapter
from ..costmodel.pricing import DEFAULT_PRICING
from ..models import LM
from .request import preemption_penalty_ms


@dataclass
class LiveRequest:
    rid: int
    arrival_ms: float
    tokens: Any                       # prompt token array (1, S)
    max_new: int
    mem_gb: float = 0.5
    # runtime
    generated: list = field(default_factory=list)
    cache: Any = None
    pos: int = 0
    cpu_ms: float = 0.0               # accumulated slot time
    vruntime: float = 0.0
    first_run_ms: Optional[float] = None
    completion_ms: Optional[float] = None
    preemptions: int = 0

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new

    def execution_ms(self) -> float:
        return self.completion_ms - self.first_run_ms

    def cost_usd(self) -> float:
        return (self.execution_ms() / 1000.0 * self.mem_gb
                * DEFAULT_PRICING.price_per_gb_second
                + DEFAULT_PRICING.price_per_request)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, n_slots: int = 4,
                 n_fifo: int = 2, max_len: int = 128,
                 adapt_pct: float = 95.0, initial_limit_ms: float = 200.0,
                 fair_slice_steps: int = 4):
        self.cfg = cfg
        self.params = params
        self.lm = LM(cfg)
        self.n_slots = n_slots
        self.n_fifo = n_fifo
        self.max_len = max_len
        self.adapter = TimeLimitAdapter(pct=adapt_pct,
                                        initial_ms=initial_limit_ms)
        self.fair_slice_steps = fair_slice_steps
        self.step_ms = cfg.ms_per_token_decode
        self.penalty_ms = preemption_penalty_ms(cfg, max_len)
        self.pending: deque[LiveRequest] = deque()
        self.fair_queue: list[LiveRequest] = []
        self.slots: list[Optional[LiveRequest]] = [None] * n_slots
        self.slot_ready_ms = [0.0] * n_slots      # swap-penalty stalls
        self.completed: list[LiveRequest] = []
        self.now_ms = 0.0
        self._decode = jax.jit(self.lm.decode_step)

    # -- model ops ------------------------------------------------------
    def _prefill(self, req: LiveRequest):
        logits, cache = self.lm.prefill(self.params, req.tokens,
                                        self.max_len)
        req.cache = cache
        req.pos = req.tokens.shape[1]
        req.generated.append(int(jnp.argmax(logits[0, -1])))

    def _decode_one(self, req: LiveRequest):
        tok = jnp.array([req.generated[-1]], jnp.int32)
        pos = jnp.array([req.pos], jnp.int32)
        logits, cache = self._decode(self.params, tok, req.cache, pos)
        req.cache = cache
        req.pos += 1
        req.generated.append(int(jnp.argmax(logits[0, -1])))

    # -- scheduler ------------------------------------------------------
    def submit(self, req: LiveRequest):
        self.pending.append(req)

    def _admit(self):
        for i in range(self.n_fifo):
            if self.slots[i] is None and self.pending \
                    and self.now_ms >= self.slot_ready_ms[i]:
                req = self.pending.popleft()
                if req.arrival_ms > self.now_ms:
                    self.pending.appendleft(req)
                    break
                req.first_run_ms = (self.now_ms if req.first_run_ms is None
                                    else req.first_run_ms)
                self._prefill(req)
                self.slots[i] = req
        # fair slots pick min-vruntime from the fair queue
        for i in range(self.n_fifo, self.n_slots):
            if self.slots[i] is None and self.fair_queue \
                    and self.now_ms >= self.slot_ready_ms[i]:
                self.fair_queue.sort(key=lambda r: r.vruntime)
                req = self.fair_queue.pop(0)
                # restore costs the swap penalty (stall the slot)
                self.slot_ready_ms[i] = self.now_ms + self.penalty_ms
                self.slots[i] = req

    def _complete(self, i: int):
        req = self.slots[i]
        req.completion_ms = self.now_ms
        req.cache = None                      # free KV
        self.adapter.record(req.execution_ms(), self.now_ms)
        self.completed.append(req)
        self.slots[i] = None

    def step(self):
        """One engine tick = one decode step per busy, unstalled slot."""
        self._admit()
        self.now_ms += self.step_ms
        limit = self.adapter.limit()
        for i in range(self.n_slots):
            req = self.slots[i]
            if req is None or self.now_ms < self.slot_ready_ms[i]:
                continue
            self._decode_one(req)
            req.cpu_ms += self.step_ms
            req.vruntime += self.step_ms
            if req.done:
                self._complete(i)
                continue
            if i < self.n_fifo and req.cpu_ms > limit:
                # paper's core move: over-limit requests leave the
                # run-to-completion group; eviction = KV swap penalty
                req.preemptions += 1
                self.fair_queue.append(req)
                self.slots[i] = None
                self.slot_ready_ms[i] = self.now_ms + self.penalty_ms
            elif i >= self.n_fifo and \
                    req.cpu_ms % (self.fair_slice_steps * self.step_ms) \
                    < self.step_ms and (self.fair_queue):
                # fair-share slice expiry: rotate if someone is waiting
                req.preemptions += 1
                self.fair_queue.append(req)
                self.slots[i] = None
                self.slot_ready_ms[i] = self.now_ms + self.penalty_ms

    def run(self, max_steps: int = 100_000):
        steps = 0
        while (self.pending or self.fair_queue
               or any(s is not None for s in self.slots)):
            self.step()
            steps += 1
            if steps >= max_steps:
                raise RuntimeError("engine did not drain")
        return self.completed
