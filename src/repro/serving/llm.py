"""The ``llm`` workload: model replicas as serverless functions.

This is the ROADMAP's LLM-inference-as-FaaS mapping made concrete
(DESIGN.md Sec. 15):

* **replica <-> function** — every trace function becomes a model
  endpoint; a sandbox for it is a loaded replica lane (weights resident,
  KV block allocated), keyed by ``func_id`` in the ``ContainerPool``.
* **cold start = weight-load + compile** — instantiating a replica pays
  ``weights / weight_gbps`` of HBM load plus one XLA compile, metered
  exactly like a sandbox boot: sampled once per instantiation, billed on
  the first chunk's wall-clock span (``Task.init_ms``).
* **warm state = KV/weights residency** — an idle replica held for the
  keep-alive window serves the next request of its endpoint without the
  load+compile; the pool's idle-memory integral prices that residency
  (provider-side warm-pool hold cost).
* **task = prefill/decode chunk** — a request is split into one
  run-to-completion prefill task plus decode chunks on the ideal
  streaming cadence; preempting a chunk inside the fair-share group
  costs the KV swap penalty (``request.preemption_penalty_ms``) —
  exactly the billed-span inflation the paper attributes to CFS.

Chunk arrivals follow the *ideal* token cadence (prefill service, then
each decode chunk as soon as its tokens could exist); queueing delay
therefore shows up as per-chunk slowdown rather than as pipeline
back-pressure, the same modelling level as the gateway's request
stream. Everything is deterministic for a fixed ``TraceSpec.seed``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Union

import numpy as np

from ..configs.base import ModelConfig
from ..core.containers import ContainerSpec
from ..core.events import Task
from ..traces.azure import TraceSpec
from ..traces.workload import generate_workload, scale_load
from .request import RequestSpec, kv_bytes, service_ms

BYTES_PER_PARAM = 2.0      # bf16 checkpoints
MB = 1024.0 * 1024.0


@dataclass(frozen=True)
class LLMSpec:
    """Everything needed to turn a trace into an LLM request stream.

    ``model`` is a registry arch name (``configs.registry``) or a
    ``ModelConfig``; keep it a string inside sweep cells so the spec
    stays trivially picklable.
    """

    model: Union[str, ModelConfig] = "deepseek-7b"
    seq_len: int = 4096             # KV budget per replica lane
    decode_chunk_tokens: int = 256  # 0 = whole decode as one task
    prompt_ratio: tuple = (2.0, 8.0)   # prompt = U(lo,hi) x decode tokens
    max_prompt: int = 8192
    # Replica (= sandbox) economics.
    weight_gbps: float = 20.0       # host->HBM weight streaming bandwidth
    compile_ms: float = 1500.0      # one-time XLA compile on instantiation
    warm_replicas: int = 4          # idle replicas the warm pool may hold
    keepalive_ms: float = 30_000.0
    container_policy: str = "fixed"     # "off" | "fixed" | "histogram"

    def resolve_model(self) -> ModelConfig:
        if isinstance(self.model, ModelConfig):
            return self.model
        from ..configs.registry import get_config
        return get_config(self.model)

    # -- replica economics --------------------------------------------------
    def replica_mem_mb(self) -> float:
        cfg = self.resolve_model()
        return (approx_param_bytes(cfg)
                + kv_bytes(cfg, self.seq_len)) / MB

    def cold_start_ms(self) -> float:
        """Expected replica instantiation delay: stream the weights in,
        then compile. This becomes the pool's ``cold_base_ms`` (the
        per-GB slope is zeroed: the pool samples cold from the billed
        per-lane footprint, but weight load does not scale with it)."""
        cfg = self.resolve_model()
        weights_gb = approx_param_bytes(cfg) / 1e9
        return weights_gb / self.weight_gbps * 1000.0 + self.compile_ms

    def container_spec(self) -> ContainerSpec:
        """The sandbox layer this workload implies: capacity for
        ``warm_replicas`` idle lanes, cold = load + compile. Customize
        with ``dataclasses.replace`` before handing it to a Scenario."""
        return ContainerSpec(
            policy=self.container_policy,
            capacity_mb=self.warm_replicas * self.replica_mem_mb(),
            keepalive_ms=self.keepalive_ms,
            cold_base_ms=self.cold_start_ms(),
            cold_per_gb_ms=0.0)


def approx_param_bytes(cfg: ModelConfig,
                       bytes_per_param: float = BYTES_PER_PARAM) -> float:
    """Rough checkpoint size: embeddings + per-layer attention (or a
    4d^2 mixer stand-in for attention-free archs) + MLP, with every
    expert resident for MoE (a serving replica ships the full router
    fan-out). Good to ~10% for the registry archs — cold-start COST
    modelling, not a memory planner."""
    d, L = cfg.d_model, cfg.n_layers
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    if cfg.n_heads:
        mix = d * cfg.hd * cfg.n_heads * 2 + d * cfg.hd * cfg.n_kv_heads * 2
    else:
        mix = 4 * d * d
    mlp = 3 * d * cfg.d_ff * max(cfg.n_experts, 1)
    return float(emb + L * (mix + mlp)) * bytes_per_param


def llm_requests(spec: LLMSpec, trace: TraceSpec | None = None,
                 ) -> list[RequestSpec]:
    """Map the Azure-like arrival process onto inference requests: a
    trace function is a model endpoint, its calibrated CPU service time
    becomes a decode-token budget (same recipe as the gateway's
    ``requests_from_trace``), prompts are a uniform multiple of it."""
    trace = trace or TraceSpec()
    cfg = spec.resolve_model()
    w = generate_workload(trace)
    rng = np.random.default_rng(trace.seed + 11)
    mem_gb = spec.replica_mem_mb() / 1024.0
    reqs = []
    for t in w.tasks:
        decode = max(int(t.service / cfg.ms_per_token_decode), 1)
        prompt = int(min(decode * rng.uniform(*spec.prompt_ratio),
                         spec.max_prompt))
        reqs.append(RequestSpec(rid=t.tid, arrival_ms=t.arrival,
                                prompt_tokens=prompt, decode_tokens=decode,
                                mem_gb=mem_gb, func_id=t.func_id))
    return reqs


def request_chunks(cfg: ModelConfig, spec: LLMSpec, req: RequestSpec,
                   edf_slack: float = 2.0) -> list[Task]:
    """One request -> its prefill/decode chunk tasks (tids are per-
    request phase indices; ``llm_workload`` renumbers globally).

    The chunk services partition the request's modelled service time
    exactly: prefill carries the ``ms_per_ktoken_prefill`` share, the
    decode chunks split ``decode_tokens`` into ``decode_chunk_tokens``
    slices at ``ms_per_token_decode`` each.
    """
    prefill_ms = service_ms(cfg, req.prompt_tokens, 0)
    mem_mb = req.mem_gb * 1024.0
    chunk = spec.decode_chunk_tokens or req.decode_tokens
    n_chunks = max(1, math.ceil(req.decode_tokens / chunk))
    sizes = [chunk] * (n_chunks - 1) \
        + [req.decode_tokens - chunk * (n_chunks - 1)]
    out = []
    t0 = req.arrival_ms
    if prefill_ms > 0.0:
        out.append(Task(tid=0, arrival=t0, service=prefill_ms,
                        mem_mb=mem_mb, func_id=req.func_id,
                        deadline=t0 + edf_slack * prefill_ms))
        t0 += prefill_ms
    for tokens in sizes:
        svc = tokens * cfg.ms_per_token_decode
        out.append(Task(tid=len(out), arrival=t0, service=svc,
                        mem_mb=mem_mb, func_id=req.func_id,
                        deadline=t0 + edf_slack * svc))
        t0 += svc
    return out


def llm_workload(spec: LLMSpec, trace: TraceSpec | None = None,
                 load_scale: float = 1.0) -> tuple[list[Task], dict]:
    """Build the full ``llm`` task stream plus its roll-up metadata.

    Returns ``(tasks, meta)`` where ``meta`` carries what the summary
    schema needs and a chunk->request accounting (``n_requests`` is the
    $/1k-requests denominator — chunking must not inflate it).
    """
    trace = trace or TraceSpec()
    cfg = spec.resolve_model()
    reqs = llm_requests(spec, trace)
    chunks: list[Task] = []
    for req in reqs:
        for t in request_chunks(cfg, spec, req, trace.edf_slack):
            t.tid = len(chunks)     # provisional: request-stream order
            chunks.append(t)
    # Canonical ids: arrival order with the deterministic request-stream
    # order as the same-instant tie-break.
    chunks.sort(key=lambda t: (t.arrival, t.tid))
    for i, t in enumerate(chunks):
        t.tid = i
    if load_scale != 1.0:
        chunks = scale_load(chunks, load_scale)
    meta = {
        "model": cfg.name,
        "n_requests": len(reqs),
        "n_chunks": len(chunks),
        "replica_mem_mb": spec.replica_mem_mb(),
        "replica_cold_ms": spec.cold_start_ms(),
        "seq_len": spec.seq_len,
    }
    return chunks, meta
