"""FaaS-for-models gateway: the paper's hybrid two-group scheduler over
DEVICE SLOTS (decode-batch lanes) instead of CPU cores.

Requests (= serverless functions) arrive with Azure-trace statistics;
slots are partitioned into a FIFO group (run-to-completion, no KV swaps)
and a fair-share group (vruntime time-slicing where every preemption
pays the KV offload/restore penalty — the TPU context switch). The
paper's time-limit adaptation (percentile of the last 100 request
durations) and slot-group rightsizing are inherited unchanged from
repro.core. Billing is wall-clock execution x per-ms-per-GB.

A straggler-mitigation hook re-dispatches requests whose execution span
exceeds ``straggler_factor`` x the expected service time (models a slow
or failed device lane — Sec. "fault tolerance" in DESIGN.md).
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..configs.base import ModelConfig
from ..core.containers import ContainerConfig, as_container_config
from ..core.events import Task
from ..core.hybrid import HybridScheduler, Rightsizer, TimeLimitAdapter
from ..core.metrics import SimResult
from ..core.policies import CFS, FIFO
from ..costmodel.pricing import DEFAULT_PRICING
from ..traces.azure import TraceSpec
from ..traces.workload import generate_workload
from .request import RequestSpec, preemption_penalty_ms, service_ms


def _serving_quanta(penalty_ms: float) -> dict:
    """Fair-share quanta must dominate the KV-swap penalty or the
    fair group livelocks (every slice adds more swap work than it
    retires). Real serving engines use second-scale slices for exactly
    this reason."""
    q = max(4.0 * penalty_ms, 250.0)
    return {"sched_latency_ms": 2 * q, "min_granularity_ms": q}


class SlotHybridScheduler(HybridScheduler):
    """Hybrid scheduler whose preemptions carry the KV-swap penalty."""

    name = "slot_hybrid"
    # on_chunk_limit adds the KV-swap penalty whenever another request
    # displaces this one (non-empty runqueue): the analytic fast-forward
    # may only batch lone-task slice cycles, where the override is a
    # no-op (see HybridScheduler._ff_solo_only).
    _ff_solo_only = True

    def __init__(self, cfg: ModelConfig, seq_len: int = 4096,
                 straggler_factor: float = 0.0, **kw):
        penalty = preemption_penalty_ms(cfg, seq_len)
        kw.update(_serving_quanta(penalty))
        super().__init__(**kw)
        self.model_cfg = cfg
        self.penalty_ms = penalty
        self.straggler_factor = straggler_factor
        self.redispatches = 0

    def on_chunk_limit(self, core, task, t):
        # A preemption swaps the request's KV out and back in — but only
        # when another request actually displaces it (FIFO->CFS
        # migration always does; a fair-share slice expiry with an empty
        # queue keeps the cache resident).
        from ..core.events import GROUP_FIFO
        if core.group == GROUP_FIFO or core.rq:
            task.remaining += self.penalty_ms
        super().on_chunk_limit(core, task, t)

    def on_complete(self, task, t):
        super().on_complete(task, t)
        if (self.straggler_factor > 0
                and task.execution > self.straggler_factor * task.service):
            self.redispatches += 1


class SlotCFS(CFS):
    name = "slot_cfs"
    _ff_solo_only = True  # same contract as SlotHybridScheduler

    def __init__(self, cfg: ModelConfig, seq_len: int = 4096, **kw):
        penalty = preemption_penalty_ms(cfg, seq_len)
        kw.update(_serving_quanta(penalty))
        super().__init__(**kw)
        self.penalty_ms = penalty

    def on_chunk_limit(self, core, task, t):
        if core.rq:
            task.remaining += self.penalty_ms
        super().on_chunk_limit(core, task, t)


@dataclass
class GatewayResult:
    sim: SimResult
    arch: str
    policy: str
    redispatches: int = 0

    def cost_usd(self) -> float:
        # fsum over the canonical finished-task order: the bill is
        # bit-identical under any permutation of the completed list.
        return math.fsum(
            (t.execution / 1000.0) * (t.mem_mb / 1024.0)
            * DEFAULT_PRICING.price_per_gb_second
            + DEFAULT_PRICING.price_per_request
            for t in self.sim.finished_tasks())

    def summary(self) -> dict:
        s = self.sim.summary()
        s["arch"] = self.arch
        s["cost_usd"] = self.cost_usd()
        s["redispatches"] = self.redispatches
        return s


def requests_from_trace(cfg: ModelConfig, spec: Optional[TraceSpec] = None,
                        seed: int = 0) -> list[Task]:
    """Map the Azure-like workload onto inference requests: the task's
    CPU service time becomes (prefill + decode) token budgets with the
    per-arch tokens/s model; memory = weights share + KV footprint."""
    w = generate_workload(spec or TraceSpec())
    rng = np.random.default_rng(seed)
    tasks = []
    for t in w.tasks:
        decode = max(int(t.service / cfg.ms_per_token_decode), 1)
        prompt = int(min(decode * rng.uniform(2.0, 8.0), 8192))
        svc = service_ms(cfg, prompt, decode)
        mem_mb = t.mem_mb  # Azure memory-size distribution (billing)
        tasks.append(Task(tid=t.tid, arrival=t.arrival, service=svc,
                          mem_mb=mem_mb, func_id=t.func_id,
                          bucket=t.bucket, deadline=t.deadline))
    return tasks


def _request_workload(cfg: ModelConfig, requests, trace):
    """Shim helper: explicit requests are deep-copied (the historical
    contract lets callers reuse their list); trace-derived streams are
    fresh already."""
    from ..scenario import WorkloadSpec
    if requests is not None:
        return WorkloadSpec(kind="tasks", tasks=requests)
    return WorkloadSpec(kind="tasks",
                        tasks=requests_from_trace(cfg, trace), fresh=False)


def run_gateway(cfg: ModelConfig, policy: str = "hybrid", *,
                n_slots: int = 50, n_fifo: int = 25,
                requests: Optional[list[Task]] = None,
                adapt_pct: Optional[float] = 95.0,
                rightsize: bool = True,
                seq_len: int = 4096,
                straggler_factor: float = 0.0,
                containers: Optional[ContainerConfig] = None,
                trace: Optional[TraceSpec] = None) -> GatewayResult:
    """Deprecated: build a :class:`repro.Scenario` with a
    ``ServingSpec`` and call ``repro.run``. This shim routes through
    exactly that path (results stay bit-identical)."""
    warnings.warn(
        "run_gateway() is deprecated; use repro.run(Scenario(policy="
        "PolicySpec(serving=ServingSpec(...)), ...)) instead",
        DeprecationWarning, stacklevel=2)
    from ..scenario import (FleetSpec, PolicySpec, Scenario, ServingSpec,
                            run)
    sc = Scenario(
        workload=_request_workload(cfg, requests, trace),
        fleet=FleetSpec(n_nodes=1, cores_per_node=n_slots,
                        containers=containers),
        policy=PolicySpec(
            name=policy, adapt_pct=adapt_pct, rightsize=rightsize,
            n_fifo=n_fifo if policy == "hybrid" else None,
            serving=ServingSpec(model=cfg, seq_len=seq_len,
                                straggler_factor=straggler_factor)))
    res = run(sc)
    return GatewayResult(sim=res.raw, arch=cfg.name, policy=policy,
                         redispatches=getattr(res.raw, "redispatches", 0))


# -- fleet gateway ------------------------------------------------------------

def _slot_node_factory(cfg: ModelConfig, seq_len: int, n_fifo_frac: float,
                       adapt_pct: Optional[float], rightsize: bool,
                       straggler_factor: float = 0.0,
                       containers: Optional[ContainerConfig] = None):
    """Build slot schedulers for one node — the single switch shared by
    ``run_gateway`` (one big node) and ``run_gateway_fleet``. With
    ``containers`` set, each node gets a sandbox pool: the model-serving
    analogue of a warm container is resident per-function state (loaded
    adapters / compiled graphs), and a cold slot pays the boot delay on
    its billed wall-clock span like any other FaaS invocation.
    ``containers`` accepts any shape ``as_container_config`` does
    (spec / config / kwargs dict / policy name)."""
    containers = as_container_config(containers)

    def factory(policy: str, n_cores: int, **kw):
        if containers is not None:
            kw.setdefault("containers", containers)
        if policy == "hybrid":
            # An explicit n_fifo (single-node run_gateway) passes
            # through untouched so invalid splits still fail loudly.
            n_fifo = kw.pop("n_fifo", None)
            if n_fifo is None:
                n_fifo = max(1, min(n_cores - 1,
                                    round(n_cores * n_fifo_frac)))
            return SlotHybridScheduler(
                cfg, seq_len=seq_len, n_cores=n_cores, n_fifo=n_fifo,
                adapter=(TimeLimitAdapter(pct=adapt_pct)
                         if adapt_pct else None),
                rightsizer=Rightsizer() if rightsize else None,
                straggler_factor=straggler_factor, **kw)
        if policy == "cfs":
            return SlotCFS(cfg, seq_len=seq_len, n_cores=n_cores, **kw)
        if policy == "fifo":
            return FIFO(n_cores=n_cores, **kw)
        raise KeyError(policy)
    return factory


def run_gateway_fleet(cfg: ModelConfig, policy: str = "hybrid", *,
                      n_nodes: int = 4, slots_per_node: int = 16,
                      dispatcher: str = "least_loaded",
                      requests: Optional[list[Task]] = None,
                      adapt_pct: Optional[float] = 95.0,
                      rightsize: bool = True,
                      n_fifo_frac: float = 0.5,
                      seq_len: int = 4096,
                      straggler_factor: float = 0.0,
                      containers: Optional[ContainerConfig] = None,
                      seed: int = 0,
                      trace: Optional[TraceSpec] = None):
    """Deprecated: build a :class:`repro.Scenario` with a fleet spec
    and a ``ServingSpec`` and call ``repro.run``. This shim routes
    through exactly that path (results stay bit-identical). Returns a
    ``repro.cluster.ClusterResult`` (serving slots = "cores")."""
    warnings.warn(
        "run_gateway_fleet() is deprecated; use repro.run(Scenario("
        "fleet=FleetSpec(...), policy=PolicySpec(serving="
        "ServingSpec(...)))) instead",
        DeprecationWarning, stacklevel=2)
    from ..scenario import (FleetSpec, PolicySpec, Scenario, ServingSpec,
                            run)
    sc = Scenario(
        workload=_request_workload(cfg, requests, trace),
        fleet=FleetSpec(n_nodes=n_nodes, cores_per_node=slots_per_node,
                        dispatcher=dispatcher, containers=containers,
                        seed=seed),
        policy=PolicySpec(
            name=policy, adapt_pct=adapt_pct, rightsize=rightsize,
            serving=ServingSpec(model=cfg, seq_len=seq_len,
                                n_fifo_frac=n_fifo_frac,
                                straggler_factor=straggler_factor)))
    return run(sc).raw
