"""Inference request model + per-arch service/preemption cost models."""
from __future__ import annotations

from dataclasses import dataclass

from ..configs.base import ModelConfig

HOST_OFFLOAD_BW = 10e9          # bytes/s HBM<->host for KV offload
DISPATCH_BUBBLE_MS = 2.0        # re-dispatch latency after a swap


@dataclass
class RequestSpec:
    rid: int
    arrival_ms: float
    prompt_tokens: int
    decode_tokens: int
    mem_gb: float = 0.5          # billed footprint (weights share + KV)
    func_id: int = 0             # model endpoint (FaaS function) it hits


def service_ms(cfg: ModelConfig, prompt: int, decode: int) -> float:
    """Modelled uninterrupted service time of a request on one slot."""
    return (cfg.ms_per_ktoken_prefill * prompt / 1000.0
            + cfg.ms_per_token_decode * decode)


def kv_bytes(cfg: ModelConfig, seq_len: int) -> float:
    """Live state a preemption must save+restore. Attention archs carry
    O(seq) KV; SSM/hybrid archs carry O(1) recurrent state — this is why
    the CFS-group context-switch penalty nearly vanishes for rwkv6 and
    zamba2 (DESIGN.md Sec. 4)."""
    if cfg.family == "ssm":
        nh = cfg.d_model // cfg.rwkv_head_dim
        return cfg.n_layers * (nh * cfg.rwkv_head_dim ** 2 + 2 * cfg.d_model) * 4
    if cfg.family == "hybrid":
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        ssm = cfg.n_layers * nh * cfg.ssm_head_dim * cfg.ssm_state * 4
        napp = max(1, cfg.n_layers // max(cfg.shared_attn_every, 1))
        attn = napp * 2 * cfg.n_kv_heads * cfg.hd * seq_len * 2
        return ssm + attn
    per_layer = 2 * cfg.n_kv_heads * cfg.hd * seq_len * 2   # k+v bf16
    if cfg.local_global_ratio > 0:
        R = cfg.local_global_ratio
        G = cfg.n_layers // (R + 1)
        n_local = cfg.n_layers - G
        w = min(cfg.local_window, seq_len)
        return (G * per_layer
                + n_local * 2 * cfg.n_kv_heads * cfg.hd * w * 2)
    return cfg.n_layers * per_layer


def preemption_penalty_ms(cfg: ModelConfig, seq_len: int) -> float:
    """TPU analogue of a context switch: KV/state offload + restore +
    dispatch bubble."""
    xfer = 2.0 * kv_bytes(cfg, seq_len) / HOST_OFFLOAD_BW * 1000.0
    return xfer + DISPATCH_BUBBLE_MS
