"""repro.serving — FaaS-for-models gateway + real-model engine."""
from .request import (RequestSpec, kv_bytes, preemption_penalty_ms,
                      service_ms)
from .gateway import (GatewayResult, SlotCFS, SlotHybridScheduler,
                      requests_from_trace, run_gateway, run_gateway_fleet)
from .engine import LiveRequest, ServingEngine
from .llm import (LLMSpec, approx_param_bytes, llm_requests, llm_workload,
                  request_chunks)

__all__ = [
    "RequestSpec", "kv_bytes", "preemption_penalty_ms", "service_ms",
    "GatewayResult", "SlotCFS", "SlotHybridScheduler",
    "requests_from_trace", "run_gateway", "run_gateway_fleet",
    "LiveRequest", "ServingEngine",
    "LLMSpec", "approx_param_bytes", "llm_requests", "llm_workload",
    "request_chunks",
]
