"""Event-driven cluster simulator substrate.

This is the paper-faithful layer (L1 in DESIGN.md): a deterministic
discrete-event simulation of one big serverless host (the paper uses 50
enclave cores of a 2x18C/2T Xeon). Scheduling policies subclass
:class:`Scheduler` and receive the same "message pump" a ghOSt agent would:
task arrival, chunk expiry (slice / time-limit), completion, timers.

Time is in milliseconds (float). The simulation is exact (no ticks): every
core schedules its next decision point; stale decision points are
invalidated with per-core generation counters.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Optional, Union

from .containers import ContainerConfig, ContainerPool

ARRIVAL, CORE_EVT, TIMER = 0, 1, 2

# Group tags for two-level policies.
GROUP_FIFO = 0
GROUP_CFS = 1

_EPS = 1e-9


@dataclass
class Task:
    """One serverless function invocation.

    ``service`` is the pure CPU demand in ms (the Fibonacci run time in the
    paper). With a container pool attached, an invocation that misses the
    warm set additionally occupies its core for ``init_ms`` of sandbox
    initialization before (conceptually) doing useful work; the wall-clock
    execution span — what the provider bills — includes it. Metrics follow
    OSTEP (paper Sec. II-B):

    execution  = completion - first_run   (includes init_ms when cold)
    response   = first_run - arrival
    turnaround = completion - arrival

    Metric properties return NaN for a task that never ran or never
    finished (admission failures, mid-run snapshots) so roll-ups can
    filter instead of crashing on ``None`` arithmetic.
    """

    tid: int
    arrival: float
    service: float
    mem_mb: int = 256
    func_id: int = 0
    bucket: int = 0

    # -- runtime state ------------------------------------------------
    remaining: float = field(default=0.0, repr=False)
    cpu_time: float = 0.0
    first_run: Optional[float] = None
    completion: Optional[float] = None
    vruntime: float = 0.0
    deadline: float = float("inf")
    preemptions: int = 0
    migrations: int = 0
    ctx_switches: int = 0
    failed: bool = False
    aux_of: Optional[int] = None  # microVM mode: auxiliary thread's parent
    # -- container lifecycle ------------------------------------------
    cold_start: bool = False
    init_ms: float = 0.0          # sandbox init charged at first dispatch

    def __post_init__(self) -> None:
        self.remaining = self.service

    # -- metrics ------------------------------------------------------
    @property
    def finished(self) -> bool:
        return self.completion is not None

    @property
    def execution(self) -> float:
        if self.completion is None or self.first_run is None:
            return float("nan")
        return self.completion - self.first_run

    @property
    def response(self) -> float:
        if self.first_run is None:
            return float("nan")
        return self.first_run - self.arrival

    @property
    def turnaround(self) -> float:
        if self.completion is None:
            return float("nan")
        return self.completion - self.arrival


class Core:
    """One CPU core; holds at most one running chunk."""

    __slots__ = (
        "cid", "task", "gen", "chunk_start", "chunk_work_start", "chunk_len",
        "chunk_rate", "group", "locked_until", "busy_ms", "last_task", "rq",
        "rq_seq", "min_vruntime", "preempt_count", "busy_snapshot", "_rs_snap",
    )

    def __init__(self, cid: int, group: int = GROUP_FIFO):
        self.cid = cid
        self.task: Optional[Task] = None
        self.gen = 0
        self.chunk_start = 0.0
        self.chunk_work_start = 0.0
        self.chunk_len = 0.0
        self.chunk_rate = 1.0
        self.group = group
        self.locked_until = -1.0
        self.busy_ms = 0.0
        self.last_task: Optional[Task] = None
        # CFS per-core runqueue: heap of (vruntime, seq, Task)
        self.rq: list = []
        self.rq_seq = 0
        self.min_vruntime = 0.0
        self.preempt_count = 0
        self.busy_snapshot = 0.0
        self._rs_snap = 0.0

    @property
    def nr_running(self) -> int:
        return len(self.rq) + (1 if self.task is not None else 0)

    def busy_total(self, now: float) -> float:
        if self.task is not None:
            return self.busy_ms + max(0.0, now - self.chunk_start)
        return self.busy_ms

    def rq_push(self, task: Task) -> None:
        heapq.heappush(self.rq, (task.vruntime, self.rq_seq, task))
        self.rq_seq += 1

    def rq_pop(self) -> Task:
        vr, _, task = heapq.heappop(self.rq)
        self.min_vruntime = max(self.min_vruntime, vr)
        return task


class Scheduler:
    """Base event loop. Policies override the hooks at the bottom."""

    name = "base"

    def __init__(
        self,
        n_cores: int = 50,
        ctx_switch_ms: float = 0.06,
        util_sample_ms: float = 500.0,
        trace_util: bool = False,
        interference_fn: Optional[Callable[[float], float]] = None,
        containers: Optional[Union[ContainerPool, ContainerConfig]] = None,
        seed: int = 0,
    ):
        self.n_cores = n_cores
        self.ctx_switch_ms = ctx_switch_ms
        self.util_sample_ms = util_sample_ms
        self.trace_util = trace_util
        self.seed = seed
        # Container lifecycle layer (DESIGN.md Sec. 9): None keeps the
        # historical cold-start-free behaviour; a ContainerConfig builds
        # a per-node pool seeded from this scheduler's seed.
        if containers is not None and not isinstance(containers,
                                                     ContainerPool):
            containers = ContainerPool(containers, seed=seed)
        self.containers = containers
        # ghOSt mode: fraction of each enclave core stolen by NATIVE Linux
        # CFS tasks (freshly spawned, not yet pinned to the enclave) as a
        # function of time. The ghOSt scheduling class runs below CFS, so
        # spawn storms stall enclave tasks (paper Sec. VI, Table I FIFO
        # p99-execution artifact). None = idealized enclave.
        self.interference_fn = interference_fn
        self.cores = [Core(i) for i in range(n_cores)]
        self.heap: list = []
        self.seq = 0
        self.now = 0.0
        self.completed: list[Task] = []
        self.failed: list[Task] = []
        self.total_ctx = 0
        self.util_series: list = []  # (t, per-group {group: util})
        self._timers: list[tuple[float, Callable]] = []
        self._primed = False
        self._parked_timers: dict = {}  # payload -> interval, revived on inject

    # -- event machinery ------------------------------------------------
    def _push(self, t: float, kind: int, payload, gen: int = 0) -> None:
        heapq.heappush(self.heap, (t, self.seq, kind, payload, gen))
        self.seq += 1

    def run(self, tasks: list[Task]) -> "Scheduler":
        self.prime(tasks)
        return self.drain()

    # -- stepping interface ----------------------------------------------
    #
    # A cluster-level dispatcher interleaves N node schedulers: it primes
    # each node once, injects tasks as the front-end routes them, and
    # advances every node to the current cluster time with step().

    def prime(self, tasks: list[Task] = ()) -> "Scheduler":
        """Register initial arrivals and start timers without running."""
        # First prime: count pre-populated completed/failed (the microvm
        # admission path appends rejects before run()). Later primes:
        # ACCUMULATE, so injected in-flight tasks keep counting and
        # work_remaining() cannot go false mid-run.
        base = getattr(self, "total_tasks", None)
        if base is None:
            base = len(self.completed) + len(self.failed)
        self.total_tasks = base + len(tasks)
        for task in tasks:
            self._push(max(self.now, task.arrival), ARRIVAL, task)
        if not self._primed:
            self._primed = True
            if self.trace_util:
                self._push(self.util_sample_ms, TIMER, "util")
            if self.containers is not None and self.containers.cfg.sweep_ms:
                # Keep-alive reaper rides the same parked-timer machinery
                # as util sampling: it parks when the node drains and
                # revives with the next injected invocation.
                self._push(self.now + self.containers.cfg.sweep_ms, TIMER,
                           "keepalive")
            self.on_start()
        else:
            # A re-run (e.g. run() called again with more work): the
            # periodic timers parked when the first batch finished must
            # come back with the new work.
            self._revive_parked_timers(self.now)
        return self

    def _revive_parked_timers(self, at: float) -> None:
        for payload, interval in self._parked_timers.items():
            self._push(at + interval, TIMER, payload)
        self._parked_timers.clear()

    def inject(self, task: Task, t: Optional[float] = None) -> None:
        """Feed one task in at time ``t`` (>= now); used by cluster
        dispatch, where arrival times are decided by the front end. The
        arrival EVENT is clamped to now (the clock never rewinds); the
        task's ``arrival`` field keeps its original value so queueing
        delay is still measured from true arrival."""
        self.total_tasks = getattr(self, "total_tasks", 0) + 1
        ta = task.arrival if t is None else max(t, task.arrival)
        self._push(max(self.now, ta), ARRIVAL, task)
        self._revive_parked_timers(max(self.now, ta))

    def next_event_time(self) -> float:
        """Time of the earliest pending event (inf when drained)."""
        return self.heap[0][0] if self.heap else float("inf")

    def _pop_event(self) -> None:
        t, _, kind, payload, gen = heapq.heappop(self.heap)
        self.now = t
        if kind == ARRIVAL:
            self.on_arrival(payload, t)
        elif kind == CORE_EVT:
            core: Core = payload
            if gen == core.gen:
                self._finish_chunk(core, t)
            # else: stale decision point
        else:  # TIMER
            self.on_timer(payload, t)

    def step(self, until: float) -> "Scheduler":
        """Process every event with timestamp <= ``until`` and advance
        the clock there, so snapshots taken by a dispatcher see node
        state as of the cluster-wide current time."""
        while self.heap and self.heap[0][0] <= until:
            self._pop_event()
        self.now = max(self.now, until)
        return self

    def drain(self) -> "Scheduler":
        """Run the event loop to exhaustion."""
        while self.heap:
            self._pop_event()
        return self

    # -- load snapshot (cluster dispatch) ---------------------------------
    def n_running(self) -> int:
        return sum(1 for c in self.cores if c.task is not None)

    def global_queue_len(self) -> int:
        """Length of the policy's centralized queue, if it keeps one.
        Policies with a global queue MUST override this or heartbeat
        load reports undercount and state-aware dispatch misroutes."""
        return 0

    def n_queued(self) -> int:
        """Tasks admitted but not currently on a core: per-core
        runqueues plus the policy's global queue."""
        return sum(len(c.rq) for c in self.cores) + self.global_queue_len()

    def has_idle_core(self) -> bool:
        return self.idle_core() is not None

    def load_snapshot(self) -> dict:
        """Instantaneous occupancy — what a least-loaded or pull-based
        front end would learn from a node heartbeat. With a container
        pool attached the heartbeat also carries the warm-set contents,
        which warm-aware and cost-aware dispatchers route on."""
        running, queued = self.n_running(), self.n_queued()
        snap = {
            "running": running,
            "queued": queued,
            "load": (running + queued) / self.n_cores,
            # A rightsizer-locked core cannot start work, so it does not
            # make the node "idle" to a pull-based dispatcher.
            "idle": queued == 0 and self.has_idle_core(),
        }
        if self.containers is not None:
            # Heartbeats are taken per routing decision: a read-only
            # live view, never a pool mutation, on the dispatch hot
            # path (expired-but-unswept sandboxes are excluded).
            warm, warm_mb = self.containers.live_view(self.now)
            snap["warm"] = warm
            snap["warm_mb"] = warm_mb
            # Advertise this node's configured cold-start model so a
            # cost-aware front end prices cold routes with the ACTUAL
            # penalty, not module defaults.
            snap["cold_model"] = (self.containers.cfg.cold_base_ms,
                                  self.containers.cfg.cold_per_gb_ms)
        return snap

    # -- chunk lifecycle -------------------------------------------------
    def _start_chunk(self, core: Core, task: Task, t: float,
                     limit: Optional[float] = None) -> None:
        ctx = self.ctx_switch_ms if core.last_task is not task else 0.0
        if task.first_run is None:
            task.first_run = t
            if self.containers is not None and task.aux_of is None:
                # Cold/warm path decided the instant the invocation first
                # claims a core: a miss occupies the core for init_ms of
                # sandbox boot before useful work — wall-clock execution
                # (what the provider bills) includes it.
                if not self.containers.acquire(task.func_id, task.mem_mb, t):
                    task.cold_start = True
                    task.init_ms = self.containers.cold_start_ms(task.mem_mb)
                    task.remaining += task.init_ms
        run = task.remaining if limit is None else min(task.remaining, limit)
        run = max(run, _EPS)
        rate = 1.0
        if self.interference_fn is not None:
            rate = max(0.05, 1.0 - self.interference_fn(t))
        core.task = task
        core.chunk_start = t
        core.chunk_work_start = t + ctx
        core.chunk_len = run
        core.chunk_rate = rate
        core.gen += 1
        if ctx > 0.0:
            task.ctx_switches += 1
            self.total_ctx += 1
        self._push(t + ctx + run / rate, CORE_EVT, core, core.gen)

    def _complete(self, task: Task, t: float) -> None:
        """Single completion path: record, return the sandbox to the
        warm pool, and fire the policy hook."""
        task.remaining = 0.0
        task.completion = t
        if self.containers is not None and task.aux_of is None:
            self.containers.release(task.func_id, task.mem_mb, t)
        self.completed.append(task)
        self.on_complete(task, t)

    def _interrupt(self, core: Core, t: float) -> Task:
        """Stop the running chunk early; returns the (partially run) task."""
        task = core.task
        done = min(max(0.0, t - core.chunk_work_start) * core.chunk_rate,
                   core.chunk_len)
        task.remaining -= done
        task.cpu_time += done
        core.busy_ms += max(0.0, t - core.chunk_start)
        core.gen += 1
        core.task = None
        core.last_task = task
        if task.remaining <= _EPS:  # raced with completion
            self._complete(task, t)
        return task

    def _finish_chunk(self, core: Core, t: float) -> None:
        task = core.task
        task.remaining -= core.chunk_len
        task.cpu_time += core.chunk_len
        core.busy_ms += t - core.chunk_start
        core.task = None
        core.last_task = task
        if task.remaining <= _EPS:
            self._complete(task, t)
        else:
            self.on_chunk_limit(core, task, t)
        self.dispatch(core, t)

    def dispatch(self, core: Core, t: float) -> None:
        if core.task is not None or t < core.locked_until:
            return
        pick = self.pick_next(core, t)
        if pick is not None:
            task, limit = pick
            self._start_chunk(core, task, t, limit)

    def kick(self, core: Core, t: float) -> None:
        if core.task is None:
            self.dispatch(core, t)

    def idle_core(self, cores: Optional[list[Core]] = None) -> Optional[Core]:
        for core in cores if cores is not None else self.cores:
            if core.task is None and self.now >= core.locked_until:
                return core
        return None

    # -- utilization sampling ---------------------------------------------
    def sample_util(self, t: float) -> dict:
        groups: dict[int, list[float]] = {}
        for core in self.cores:
            total = core.busy_total(t)
            delta = total - core.busy_snapshot
            core.busy_snapshot = total
            groups.setdefault(core.group, []).append(delta)
        window = self.util_sample_ms
        return {g: sum(v) / (len(v) * window) for g, v in groups.items() if v}

    def work_remaining(self) -> bool:
        """True while any task is incomplete. Periodic timers must key
        off THIS, not heap emptiness — two timers would otherwise keep
        each other alive forever."""
        done = len(self.completed) + len(self.failed)
        return done < getattr(self, "total_tasks", 0)

    def _reschedule_timer(self, payload, interval: float) -> None:
        """Keep a periodic timer alive while work remains; otherwise PARK
        it so a later ``inject`` revives it. A cluster node is often
        momentarily quiescent between dispatched invocations — letting
        the timer chain die there would silently disable util tracing /
        rightsizing for the rest of the run."""
        if self.work_remaining():
            self._push(self.now + interval, TIMER, payload)
        else:
            self._parked_timers[payload] = interval

    def on_timer(self, payload, t: float) -> None:
        if payload == "util":
            util = self.sample_util(t)
            self.util_series.append(
                (t, util, sum(1 for c in self.cores if c.group == GROUP_FIFO)))
            self._reschedule_timer("util", self.util_sample_ms)
        elif payload == "keepalive":
            self.containers.evict_expired(t)
            self._reschedule_timer("keepalive", self.containers.cfg.sweep_ms)

    # -- policy hooks -------------------------------------------------------
    def on_start(self) -> None:  # pragma: no cover - trivial
        pass

    def on_arrival(self, task: Task, t: float) -> None:
        raise NotImplementedError

    def pick_next(self, core: Core, t: float):
        raise NotImplementedError

    def on_chunk_limit(self, core: Core, task: Task, t: float) -> None:
        raise NotImplementedError

    def on_complete(self, task: Task, t: float) -> None:
        pass
