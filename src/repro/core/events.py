"""Event-driven cluster simulator substrate.

This is the paper-faithful layer (L1 in DESIGN.md): a deterministic
discrete-event simulation of one big serverless host (the paper uses 50
enclave cores of a 2x18C/2T Xeon). Scheduling policies subclass
:class:`Scheduler` and receive the same "message pump" a ghOSt agent would:
task arrival, chunk expiry (slice / time-limit), completion, timers.

Time is in milliseconds (float). The simulation is exact (no ticks) and —
since the hot-path overhaul (DESIGN.md Sec. 13) — the event loop is
organized for throughput without changing a single simulated outcome
(tests/test_engine_equivalence.py locks the results bit-for-bit):

* Heap entries are pooled, mutable records
  ``[time, class, tie, kind, payload]``; a preempted core's in-flight
  record is *tombstoned* in place (``kind = DEAD``) and recycled when
  it surfaces, replacing the old per-core generation counters.
* Same-instant ordering is CANONICAL: arrivals, then timers, then core
  expiries in core-id order (the ``class``/``tie`` key fields). The
  historical engine broke timestamp ties by heap-push order — an
  emergent property of processing history that no event-eliding
  optimization can reproduce (eliding a push permutes every later tie
  on the machine). Value-determined tie order makes simultaneous-expiry
  semantics explicit, platform-stable, and elision-invariant; it is
  part of the engine contract (DESIGN.md Sec. 13).
* When a core's next chunk expiry lands strictly before every other
  pending event (and inside the ``step()`` horizon), the expiry is
  processed inline — no heap push/pop, no record allocation.
* On top of the inline loop, policies that slice with a constant quantum
  (CFS, the hybrid CFS group, FIFO_100ms) implement
  :meth:`Scheduler.fast_forward`: an analytic round loop that retires
  whole slice-expiry cycles with plain arithmetic, replicating the exact
  float operations the event path would perform (see hybrid.py).
* Since the completion-batching overhaul (DESIGN.md Sec. 13), the
  analytic fast-forward no longer stops at a task's own completion:
  every observable that used to force completions through the heap is
  order-canonical by construction (sorted roll-ups, fsum cost, the
  container pool's deferred-release buffer, the adapter's buffered
  observations), so a core may retire whole RUNS of completions —
  complete, pick, slice, complete, ... — between barrier events, with
  shared-state effects re-serialized canonically by (time, tie-key).
  First dispatches batch too when no container pool is attached; with
  a pool they still serialize through the heap, which keeps the
  cold-start RNG stream indexed by canonical acquire order. Barriers
  are policy-scoped: an arrival only stops the cores its placement can
  touch, and a hybrid FIFO chunk is a barrier only when it will
  actually migrate its task.
"""
from __future__ import annotations

import heapq
from bisect import insort
from dataclasses import dataclass, field
from typing import Callable, Optional, Union

import numpy as np

from .containers import (ContainerConfig, ContainerPool,
                         as_container_config)

ARRIVAL, CORE_EVT, TIMER, DEAD = 0, 1, 2, 3

# Group tags for two-level policies.
GROUP_FIFO = 0
GROUP_CFS = 1

_EPS = 1e-9
_INF = float("inf")


# -- regime arithmetic (pure) ---------------------------------------------
# The closed-form float recipes of the supported fast-forward regime,
# extracted so the scalar engine and the batched Monte-Carlo engine
# (repro.mc, DESIGN.md Sec. 16) execute the SAME operation sequences.
# Bit-identity rests on these being the only places the arithmetic
# lives: each helper takes ``_min``/``_max`` so the mc kernels can
# re-bind them to ``jnp.minimum``/``jnp.maximum`` while tracing — the
# resulting f64 ops are identical to Python's on non-NaN operands.

def chunk_run_ms(remaining, limit=None, *, _min=min, _max=max):
    """Chunk length granted to a task: remaining work clamped to the
    policy limit, floored at ``_EPS`` so a chunk always advances time."""
    run = remaining if limit is None else _min(remaining, limit)
    return _max(run, _EPS)


def chunk_end_ms(t, ctx, run):
    """Expiry instant of a chunk started at ``t``: left-associated
    ``(t + ctx) + run`` — the exact sequence ``_start_chunk`` bills."""
    return (t + ctx) + run


def cfs_slice_ms(nr_running, sched_latency_ms, min_granularity_ms,
                 *, _max=max):
    """CFS timeslice: target latency split over the runnable count
    (post-pick, so a lone task sees the full latency), floored at the
    minimum granularity."""
    return _max(sched_latency_ms / _max(1, nr_running),
                min_granularity_ms)


def fifo_budget_ms(limit_ms, cpu_time_ms, *, _max=max):
    """Hybrid FIFO-group budget: time limit minus CPU already consumed,
    floored at 0.01 ms so an over-budget task still runs one tick
    before migrating."""
    return _max(limit_ms - cpu_time_ms, 0.01)


def chunk_completes(remaining, run):
    """Completion predicate for a chunk of length ``run``: the
    subtraction FIRST, then the ``_EPS`` compare — the one float
    expression that decides whether a chunk retires its task.  Pure
    elementwise ops, so the batched kernels evaluate it on arrays
    unchanged."""
    return (remaining - run) <= _EPS


@dataclass(slots=True)
class Task:
    """One serverless function invocation.

    ``service`` is the pure CPU demand in ms (the Fibonacci run time in the
    paper). With a container pool attached, an invocation that misses the
    warm set additionally occupies its core for ``init_ms`` of sandbox
    initialization before (conceptually) doing useful work; the wall-clock
    execution span — what the provider bills — includes it. Metrics follow
    OSTEP (paper Sec. II-B):

    execution  = completion - first_run   (includes init_ms when cold)
    response   = first_run - arrival
    turnaround = completion - arrival

    Metric properties return NaN for a task that never ran or never
    finished (admission failures, mid-run snapshots) so roll-ups can
    filter instead of crashing on ``None`` arithmetic.
    """

    tid: int
    arrival: float
    service: float
    mem_mb: int = 256
    func_id: int = 0
    bucket: int = 0

    # -- runtime state ------------------------------------------------
    remaining: float = field(default=0.0, repr=False)
    cpu_time: float = 0.0
    first_run: Optional[float] = None
    completion: Optional[float] = None
    vruntime: float = 0.0
    deadline: float = float("inf")
    preemptions: int = 0
    migrations: int = 0
    ctx_switches: int = 0
    failed: bool = False
    retries: int = 0              # restarts after a chaos node kill
    aux_of: Optional[int] = None  # microVM mode: auxiliary thread's parent
    # -- container lifecycle ------------------------------------------
    cold_start: bool = False
    init_ms: float = 0.0          # sandbox init charged at first dispatch

    def __post_init__(self) -> None:
        self.remaining = self.service

    # -- metrics ------------------------------------------------------
    @property
    def finished(self) -> bool:
        return self.completion is not None

    @property
    def execution(self) -> float:
        if self.completion is None or self.first_run is None:
            return float("nan")
        return self.completion - self.first_run

    @property
    def response(self) -> float:
        if self.first_run is None:
            return float("nan")
        return self.first_run - self.arrival

    @property
    def turnaround(self) -> float:
        if self.completion is None:
            return float("nan")
        return self.completion - self.arrival


class Core:
    """One CPU core; holds at most one running chunk.

    ``pending`` is the core's in-flight expiry record in the scheduler
    heap, or None while the chunk is being advanced inline. Interrupting
    a chunk tombstones the record (lazy deletion) instead of bumping a
    generation counter.
    """

    __slots__ = (
        "cid", "task", "pending", "chunk_start", "chunk_work_start",
        "chunk_len", "chunk_rate", "group", "locked_until", "busy_ms",
        "last_task", "rq", "rq_seq", "min_vruntime", "preempt_count",
        "busy_snapshot", "_rs_snap", "ff_w",
    )

    def __init__(self, cid: int, group: int = GROUP_FIFO):
        self.cid = cid
        self.task: Optional[Task] = None
        self.pending: Optional[list] = None
        self.chunk_start = 0.0
        self.chunk_work_start = 0.0
        self.chunk_len = 0.0
        self.chunk_rate = 1.0
        self.group = group
        self.locked_until = -1.0
        self.busy_ms = 0.0
        self.last_task: Optional[Task] = None
        # CFS per-core runqueue: heap of (vruntime, seq, Task)
        self.rq: list = []
        self.rq_seq = 0
        self.min_vruntime = 0.0
        self.preempt_count = 0
        self.busy_snapshot = 0.0
        self._rs_snap = 0.0
        # Windowed fast-forward sizing hint: this core's last batch
        # length (purely a performance hint, never affects outcomes).
        self.ff_w = 1 << 20

    @property
    def nr_running(self) -> int:
        return len(self.rq) + (1 if self.task is not None else 0)

    def busy_total(self, now: float) -> float:
        if self.task is not None:
            return self.busy_ms + max(0.0, now - self.chunk_start)
        return self.busy_ms

    # The runqueue is kept SORTED (insort / pop(0)) rather than heapified:
    # pop-min semantics are identical, and the analytic fast-forward can
    # then read and splice the queue in place without re-sorting it on
    # every batch entry.
    def rq_push(self, task: Task) -> None:
        insort(self.rq, (task.vruntime, self.rq_seq, task))
        self.rq_seq += 1

    def rq_pop(self) -> Task:
        vr, _, task = self.rq.pop(0)
        self.min_vruntime = max(self.min_vruntime, vr)
        return task


class Scheduler:
    """Base event loop. Policies override the hooks at the bottom."""

    name = "base"
    # Policies with a constant-quantum slice cycle set this and implement
    # fast_forward() (see hybrid.py / policies.py); the event loop then
    # retires whole rounds analytically when no interacting event
    # intervenes.
    _has_ff = False
    # Restricts the analytic fast-forward to lone-task cores; see
    # HybridScheduler._ff_solo_only for the subclass contract.
    _ff_solo_only = False
    # Completion batching opt-out: a subclass whose on_complete hook is
    # order-SENSITIVE across cores beyond the buffered adapter/pool
    # channels (anything that must interleave with other cores' events
    # in exact global time order) sets this False, and its completions
    # serialize through the heap as before the batching overhaul.
    _batch_complete = True
    # Core groups whose chunk expiries can touch OTHER cores' state
    # (the hybrid FIFO group migrates over-limit tasks into CFS
    # runqueues): their expiry instants are fast-forward barriers.
    _barrier_groups: Optional[frozenset] = None

    def __init__(
        self,
        n_cores: int = 50,
        ctx_switch_ms: float = 0.06,
        util_sample_ms: float = 500.0,
        trace_util: bool = False,
        interference_fn: Optional[Callable[[float], float]] = None,
        containers: Optional[Union[ContainerPool, ContainerConfig,
                                   "ContainerSpec", dict, str]] = None,
        seed: int = 0,
    ):
        self.n_cores = n_cores
        self.ctx_switch_ms = ctx_switch_ms
        self.util_sample_ms = util_sample_ms
        self.trace_util = trace_util
        self.seed = seed
        # Container lifecycle layer (DESIGN.md Sec. 9): None keeps the
        # historical cold-start-free behaviour; any other accepted shape
        # (ContainerSpec / ContainerConfig / kwargs dict / policy name)
        # builds a per-node pool seeded from this scheduler's seed.
        if containers is not None and not isinstance(containers,
                                                     ContainerPool):
            containers = as_container_config(containers)
        if containers is not None and not isinstance(containers,
                                                     ContainerPool):
            containers = ContainerPool(containers, seed=seed)
        self.containers = containers
        # ghOSt mode: fraction of each enclave core stolen by NATIVE Linux
        # CFS tasks (freshly spawned, not yet pinned to the enclave) as a
        # function of time. The ghOSt scheduling class runs below CFS, so
        # spawn storms stall enclave tasks (paper Sec. VI, Table I FIFO
        # p99-execution artifact). None = idealized enclave.
        self.interference_fn = interference_fn
        self.cores = [Core(i) for i in range(n_cores)]
        self.heap: list = []
        self.seq = 0
        self.now = 0.0
        self.completed: list[Task] = []
        self.failed: list[Task] = []
        self.total_ctx = 0
        # Logical events processed (arrivals + chunk expiries/completions
        # + timers) — the engine-throughput denominator. Invariant under
        # engine-internal optimizations: two engines simulating the same
        # run count the same events, however they process them.
        self.n_events = 0
        self.util_series: list = []  # (t, per-group {group: util})
        self._timers: list[tuple[float, Callable]] = []
        self._primed = False
        self._parked_timers: dict = {}  # payload -> interval, revived on inject
        # Free pool of heap records; records are recycled when popped
        # (live or tombstoned), so the steady-state loop allocates no
        # event objects at all.
        self._pool: list[list] = []
        # step() horizon: inline chunk processing must not advance a
        # node past the time a cluster dispatcher stepped it to, or
        # heartbeat snapshots would observe the future.
        self._hz = _INF
        # Fast-forward barrier instants: the times of every pending
        # event that can interact with a core from outside — timers
        # (sampling, rightsizing, reaping) and interacting chunk
        # expiries (see _chunk_interacts) in ``_barriers``; arrivals in
        # ``_arr_barriers``, consulted only for cores the policy's
        # placement can actually touch (see _arrivals_touch: a hybrid
        # arrival enters the FIFO group's global queue and never reads
        # or mutates a CFS core). Pure slice expiries on OTHER cores
        # touch only their own core, so an analytic fast-forward may
        # cross them; it must stop strictly before the next barrier.
        # Stale times are popped lazily; tombstoned events leave a
        # conservative barrier behind. Maintained only when a
        # fast-forward can actually consume it (interference-rate
        # chunks always decline), so FIFO/EDF and ghost-mode runs pay
        # nothing on the arrival path.
        self._barriers: list[float] = []
        self._arr_barriers: list[float] = []
        self._use_ff = self._has_ff and interference_fn is None
        # Latest instant a fast-forward batch retired a completion at;
        # drain() reconciles the clock with it so end-of-run state
        # matches the event-by-event engine even when the final
        # completions never touched the heap.
        self._ff_now = 0.0

    # -- event machinery ------------------------------------------------
    def _push(self, t: float, kind: int, payload) -> list:
        # Canonical same-instant key: arrivals (class 0) before timers
        # (class 1) before core expiries (class 2, cid order). Arrivals
        # and timers keep a monotone seq among themselves — their pushes
        # happen at identical logical points in any equivalent engine,
        # so seq order is reproducible; core expiries must NOT use push
        # order (elision permutes it) and use the core id instead.
        if kind == CORE_EVT:
            klass, tie = 2, payload.cid
        else:
            klass, tie = (0 if kind == ARRIVAL else 1), self.seq
        pool = self._pool
        if pool:
            rec = pool.pop()
            rec[0] = t
            rec[1] = klass
            rec[2] = tie
            rec[3] = kind
            rec[4] = payload
        else:
            rec = [t, klass, tie, kind, payload]
        self.seq += 1
        heapq.heappush(self.heap, rec)
        if kind != CORE_EVT and self._use_ff:
            if kind == ARRIVAL:
                heapq.heappush(self._arr_barriers, t)
            else:
                heapq.heappush(self._barriers, (t, self.seq, None, 0.0))
        return rec

    def _chunk_barrier(self, core: Core, end: float) -> Optional[float]:
        """Earliest instant at which the chunk just installed on
        ``core`` — or anything this core does AFTER it, up to the next
        event this core pushes — can touch ANOTHER core's state. None
        when it never can. The returned time must be conservative (at
        or before the true first interaction): fast-forward batches on
        other cores run strictly before it. Policies refine this per
        chunk (the hybrid: a budget-limited FIFO chunk migrates AT its
        expiry; a completing one cannot trigger a migration earlier
        than its expiry plus the full static budget a fresh pick
        gets)."""
        bg = self._barrier_groups
        if bg is not None and core.group in bg:
            return end
        return None

    def _arrival_barrier_offset(self, core: Core) -> float:
        """How long after a pending ARRIVAL the earliest interaction
        with ``core`` can happen. 0.0 for single-level policies: the
        arrival's placement reads every core at its own instant. The
        hybrid overrides for CFS cores — an arrival enters the FIFO
        group's global queue and can only reach a CFS core via a later
        budget-expiry migration."""
        return 0.0

    def _push_core(self, core: Core, end: float) -> None:
        core.pending = self._push(end, CORE_EVT, core)
        if self._use_ff:
            bt = self._chunk_barrier(core, end)
            if bt is not None:
                # Tagged with the chunk's identity (core, chunk_start):
                # once this chunk is retired, its SUCCESSOR's barrier —
                # registered when the successor is pushed, and provably
                # no earlier than this one — supersedes it, so matured
                # entries from long-retired chunks are skipped instead
                # of pinning every batch to a stale conservative bound.
                heapq.heappush(self._barriers,
                               (bt, self.seq, core, core.chunk_start))
                self.seq += 1

    def _next_barrier(self, t: float, core: Optional[Core] = None) -> float:
        """Earliest pending interacting instant at/after ``t`` that can
        reach ``core`` (every event before ``t`` has been processed —
        the heap drains in time order). ``core=None`` is conservative:
        arrivals count immediately for everyone."""
        b = self._barriers
        while b:
            bt, _, c, cs = b[0]
            if bt < t or (c is not None
                          and (c.task is None or c.chunk_start != cs)):
                heapq.heappop(b)   # past, or the tagged chunk retired
            else:
                break
        bound = b[0][0] if b else _INF
        # Drain stale arrival instants (events before t are done; the
        # chunks they spawned registered their own barriers), then
        # apply the policy's reach offset for this core.
        a = self._arr_barriers
        while a and a[0] < t:
            heapq.heappop(a)
        if a:
            ab = a[0] if core is None else \
                a[0] + self._arrival_barrier_offset(core)
            if ab < bound:
                bound = ab
        return bound

    def run(self, tasks: list[Task]) -> "Scheduler":
        self.prime(tasks)
        return self.drain()

    # -- stepping interface ----------------------------------------------
    #
    # A cluster-level dispatcher interleaves N node schedulers: it primes
    # each node once, injects tasks as the front-end routes them, and
    # advances every node to the current cluster time with step().

    def prime(self, tasks: list[Task] = ()) -> "Scheduler":
        """Register initial arrivals and start timers without running."""
        # First prime: count pre-populated completed/failed (the microvm
        # admission path appends rejects before run()). Later primes:
        # ACCUMULATE, so injected in-flight tasks keep counting and
        # work_remaining() cannot go false mid-run.
        base = getattr(self, "total_tasks", None)
        if base is None:
            base = len(self.completed) + len(self.failed)
        self.total_tasks = base + len(tasks)
        for task in tasks:
            self._push(max(self.now, task.arrival), ARRIVAL, task)
        if not self._primed:
            self._primed = True
            if self.trace_util:
                self._push(self.util_sample_ms, TIMER, "util")
            if self.containers is not None and self.containers.cfg.sweep_ms:
                # Keep-alive reaper rides the same parked-timer machinery
                # as util sampling: it parks when the node drains and
                # revives with the next injected invocation.
                self._push(self.now + self.containers.cfg.sweep_ms, TIMER,
                           "keepalive")
            self.on_start()
        else:
            # A re-run (e.g. run() called again with more work): the
            # periodic timers parked when the first batch finished must
            # come back with the new work.
            self._revive_parked_timers(self.now)
        return self

    def _revive_parked_timers(self, at: float) -> None:
        for payload, interval in self._parked_timers.items():
            self._push(at + interval, TIMER, payload)
        self._parked_timers.clear()

    def inject(self, task: Task, t: Optional[float] = None) -> None:
        """Feed one task in at time ``t`` (>= now); used by cluster
        dispatch, where arrival times are decided by the front end. The
        arrival EVENT is clamped to now (the clock never rewinds); the
        task's ``arrival`` field keeps its original value so queueing
        delay is still measured from true arrival."""
        self.total_tasks = getattr(self, "total_tasks", 0) + 1
        ta = task.arrival if t is None else max(t, task.arrival)
        self._push(max(self.now, ta), ARRIVAL, task)
        self._revive_parked_timers(max(self.now, ta))

    def next_event_time(self) -> float:
        """Time of the earliest pending event (inf when drained).
        Tombstoned records may make this conservatively early, exactly
        as stale generation-counter events used to."""
        return self.heap[0][0] if self.heap else _INF

    def _pop_event(self) -> None:
        rec = heapq.heappop(self.heap)
        t = rec[0]
        kind = rec[3]
        payload = rec[4]
        rec[4] = None
        self._pool.append(rec)
        if kind == DEAD:
            return
        self.now = t
        if kind == ARRIVAL:
            self.n_events += 1
            self.on_arrival(payload, t)
        elif kind == CORE_EVT:
            payload.pending = None
            self._run_core(payload, t)
        else:  # TIMER
            self.n_events += 1
            self.on_timer(payload, t)

    def step(self, until: float) -> "Scheduler":
        """Process every event with timestamp <= ``until`` and advance
        the clock there, so snapshots taken by a dispatcher see node
        state as of the cluster-wide current time."""
        self._hz = until
        heap = self.heap
        while heap and heap[0][0] <= until:
            self._pop_event()
        self._hz = _INF
        self.now = max(self.now, until)
        return self

    def drain(self) -> "Scheduler":
        """Run the event loop to exhaustion."""
        self._hz = _INF
        heap = self.heap
        while heap:
            self._pop_event()
        # Completion batches can retire the tail of the run without any
        # heap traffic; land the clock where the last event-by-event
        # pop would have (end-of-run settle/stats read self.now).
        if self._ff_now > self.now:
            self.now = self._ff_now
        return self

    def shutdown(self, t: Optional[float] = None) -> "Scheduler":
        """Decommission this node at time ``t`` (>= now): the machine is
        gone, so the warm-pool memory meter must stop HERE — not keep
        (mis)counting until whenever a roll-up next settles the pool —
        and the parked periodic timers (keep-alive reaper, util
        sampling) must die with it instead of waiting for an inject that
        will never come. Idempotent; graceful removal drains first,
        chaos kills call it with work still in flight (the cluster layer
        requeues that work elsewhere)."""
        t = self.now if t is None else max(self.now, t)
        self.now = t
        self._parked_timers.clear()
        if self.containers is not None:
            # Bring the hold integral current, then destroy the idle
            # warm set: expired sandboxes stop metering at their expiry,
            # live ones at the decommission instant.
            self.containers.settle(self.now)
            self.containers.flush(self.now)
            # A dead machine holds no concurrency slots and owes its
            # queued slot waiters nothing — the cluster layer requeues
            # the waiting TASKS through the dispatcher; this clears the
            # pool-side accounting so invariants hold on the corpse.
            self.containers.drain_slots()
        return self

    def set_interference(self, fn) -> None:
        """Attach or adjust the interference function mid-run (SKU clock
        multipliers, chaos ``degrade`` events). Disables the analytic
        fast-forward ONE-WAY: barriers stop being maintained the moment
        interference appears, so re-enabling later would fast-forward
        over missing barrier state. Chunks already in flight keep their
        rate; the new rate applies from the next chunk start."""
        self.interference_fn = fn
        self._use_ff = False

    # -- load snapshot (cluster dispatch) ---------------------------------
    def n_running(self) -> int:
        return sum(1 for c in self.cores if c.task is not None)

    def global_queue_len(self) -> int:
        """Length of the policy's centralized queue, if it keeps one.
        Policies with a global queue MUST override this or heartbeat
        load reports undercount and state-aware dispatch misroutes."""
        return 0

    def n_queued(self) -> int:
        """Tasks admitted but not currently on a core: per-core
        runqueues plus the policy's global queue."""
        return sum(len(c.rq) for c in self.cores) + self.global_queue_len()

    def has_idle_core(self) -> bool:
        return self.idle_core() is not None

    def load_snapshot(self) -> dict:
        """Instantaneous occupancy — what a least-loaded or pull-based
        front end would learn from a node heartbeat. With a container
        pool attached the heartbeat also carries the warm-set contents,
        which warm-aware and cost-aware dispatchers route on."""
        running, queued = self.n_running(), self.n_queued()
        snap = {
            "running": running,
            "queued": queued,
            "load": (running + queued) / self.n_cores,
            # A rightsizer-locked core cannot start work, so it does not
            # make the node "idle" to a pull-based dispatcher.
            "idle": queued == 0 and self.has_idle_core(),
        }
        if self.containers is not None:
            # Heartbeats are taken per routing decision: a read-only
            # live view, never a pool mutation, on the dispatch hot
            # path (expired-but-unswept sandboxes are excluded).
            warm, warm_mb = self.containers.live_view(self.now)
            snap["warm"] = warm
            snap["warm_mb"] = warm_mb
            # Advertise this node's configured cold-start model so a
            # cost-aware front end prices cold routes with the ACTUAL
            # penalty, not module defaults.
            snap["cold_model"] = (self.containers.cfg.cold_base_ms,
                                  self.containers.cfg.cold_per_gb_ms)
        return snap

    # -- chunk lifecycle -------------------------------------------------
    def _start_chunk(self, core: Core, task: Task, t: float,
                     limit: Optional[float] = None) -> float:
        """Install ``task`` on ``core`` and return the chunk's expiry
        instant. The caller schedules the expiry: dispatch() pushes a
        heap record; the event loop may instead process it inline."""
        ctx = self.ctx_switch_ms if core.last_task is not task else 0.0
        if task.first_run is None:
            task.first_run = t
            if self.containers is not None and task.aux_of is None:
                # Cold/warm path decided the instant the invocation first
                # claims a core: a miss occupies the core for init_ms of
                # sandbox boot before useful work — wall-clock execution
                # (what the provider bills) includes it.
                if not self.containers.acquire(task.func_id, task.mem_mb, t):
                    task.cold_start = True
                    task.init_ms = self.containers.cold_start_ms(task.mem_mb)
                    task.remaining += task.init_ms
        run = chunk_run_ms(task.remaining, limit)
        rate = 1.0
        if self.interference_fn is not None:
            rate = max(0.05, 1.0 - self.interference_fn(t))
        core.task = task
        core.chunk_start = t
        core.chunk_work_start = t + ctx
        core.chunk_len = run
        core.chunk_rate = rate
        if ctx > 0.0:
            task.ctx_switches += 1
            self.total_ctx += 1
        return chunk_end_ms(t, ctx, run / rate)

    def _complete(self, task: Task, t: float) -> None:
        """Single completion path: record, return the sandbox to the
        warm pool, and fire the policy hook. The pool release is
        DEFERRED (buffered keyed (t, func_id, tid)) so event-path and
        batch-path completions share one canonical ordering; the pool
        applies it before its next read at/after ``t``."""
        task.remaining = 0.0
        task.completion = t
        if self.containers is not None and task.aux_of is None:
            self.containers.release_at(task.func_id, task.mem_mb, t,
                                       task.tid)
        self.completed.append(task)
        self.on_complete(task, t)

    def _retire_completion(self, core: Core, e: float) -> None:
        """Batch-path twin of the event loop's completion processing:
        the same float operations and hook order as `_run_core` +
        `_complete`, minus the heap record. Pool releases and adapter
        observations buffer and re-serialize canonically, so retiring
        completions per core (possibly out of global time order across
        cores) leaves every observable exactly as the heap path would
        (DESIGN.md Sec. 13)."""
        task = core.task
        task.remaining -= core.chunk_len
        task.cpu_time += core.chunk_len
        core.busy_ms += e - core.chunk_start
        core.task = None
        core.last_task = task
        self._complete(task, e)
        self.n_events += 1
        if e > self._ff_now:
            self._ff_now = e

    def _interrupt(self, core: Core, t: float) -> Task:
        """Stop the running chunk early; returns the (partially run)
        task. The in-flight heap record is tombstoned in place and
        recycled when it surfaces (lazy deletion)."""
        task = core.task
        done = min(max(0.0, t - core.chunk_work_start) * core.chunk_rate,
                   core.chunk_len)
        task.remaining -= done
        task.cpu_time += done
        core.busy_ms += max(0.0, t - core.chunk_start)
        rec = core.pending
        if rec is not None:
            rec[3] = DEAD
            rec[4] = None
            core.pending = None
        core.task = None
        core.last_task = task
        if task.remaining <= _EPS:  # raced with completion
            self._complete(task, t)
        return task

    def _run_core(self, core: Core, t: float) -> None:
        """Process a chunk expiry, then keep advancing this core inline
        while its next expiry lands strictly before every other pending
        event and inside the step() horizon. Equivalent to the pop-push
        loop event by event — same hooks, same float operations, same
        tie-breaking (ties go through the heap) — minus the heap churn.
        """
        hz = self._hz
        heap = self.heap
        while True:
            self.n_events += 1
            task = core.task
            task.remaining -= core.chunk_len
            task.cpu_time += core.chunk_len
            core.busy_ms += t - core.chunk_start
            core.task = None
            core.last_task = task
            if task.remaining <= _EPS:
                self._complete(task, t)
            else:
                self.on_chunk_limit(core, task, t)
            if core.task is not None or t < core.locked_until:
                return
            pick = self.pick_next(core, t)
            if pick is None:
                return
            ntask, limit = pick
            end = self._start_chunk(core, ntask, t, limit)
            if self._use_ff:
                end = self.fast_forward(core, end, hz)
                if end is None:
                    # The batch retired the chain through its last
                    # completion and the core went idle — there is no
                    # in-flight chunk left to schedule.
                    return
            if end < (heap[0][0] if heap else _INF) and end <= hz:
                self.now = t = end
                continue
            self._push_core(core, end)
            return

    def fast_forward(self, core: Core, end: float, hz: float):
        """Analytic round fast-forward hook (DESIGN.md Sec. 13).

        Called with ``core`` mid-chunk (expiry at ``end``). A policy
        whose slice cycle is closed-form may retire any number of
        expiry rounds here with plain arithmetic — replicating the
        exact per-round float operations — and return the new in-flight
        chunk's expiry, or ``None`` when the batch retired the chain
        through its final completion and left the core idle. Rounds may
        cross OTHER cores' pending chunk expiries (pure slice expiries
        touch only their own core) but must stop strictly before the
        next interacting event (:meth:`_next_barrier`) and at or before
        the ``hz`` horizon.

        Completions NO LONGER bound a batch (``_batch_complete``):
        their shared-state effects travel through order-canonical
        channels — the pool's deferred-release buffer, the adapter's
        buffered observations, the sorted/fsum roll-ups — and
        re-serialize by (time, tie-key) at the next read. The one
        shared effect with no such channel is a first dispatch's pool
        acquire (hit/miss feeds timing; a miss draws the cold-start
        RNG), so with a container pool attached a fresh task's pick
        still stops the batch; without one, first dispatches batch and
        only stamp ``first_run``. Must leave ALL observable state
        (task metrics, runqueue contents and seq numbers, min_vruntime,
        busy accounting) exactly as the event-by-event path would."""
        return end

    def dispatch(self, core: Core, t: float) -> None:
        if core.task is not None or t < core.locked_until:
            return
        pick = self.pick_next(core, t)
        if pick is not None:
            task, limit = pick
            end = self._start_chunk(core, task, t, limit)
            self._push_core(core, end)

    def kick(self, core: Core, t: float) -> None:
        if core.task is None:
            self.dispatch(core, t)

    def idle_core(self, cores: Optional[list[Core]] = None) -> Optional[Core]:
        for core in cores if cores is not None else self.cores:
            if core.task is None and self.now >= core.locked_until:
                return core
        return None

    # -- utilization sampling ---------------------------------------------
    def sample_util(self, t: float) -> dict:
        groups: dict[int, list[float]] = {}
        for core in self.cores:
            total = core.busy_total(t)
            delta = total - core.busy_snapshot
            core.busy_snapshot = total
            groups.setdefault(core.group, []).append(delta)
        window = self.util_sample_ms
        return {g: sum(v) / (len(v) * window) for g, v in groups.items() if v}

    def work_remaining(self) -> bool:
        """True while any task is incomplete. Periodic timers must key
        off THIS, not heap emptiness — two timers would otherwise keep
        each other alive forever."""
        done = len(self.completed) + len(self.failed)
        return done < getattr(self, "total_tasks", 0)

    def _reschedule_timer(self, payload, interval: float) -> None:
        """Keep a periodic timer alive while work remains; otherwise PARK
        it so a later ``inject`` revives it. A cluster node is often
        momentarily quiescent between dispatched invocations — letting
        the timer chain die there would silently disable util tracing /
        rightsizing for the rest of the run."""
        if self.work_remaining():
            self._push(self.now + interval, TIMER, payload)
        else:
            self._parked_timers[payload] = interval

    def on_timer(self, payload, t: float) -> None:
        if payload == "util":
            util = self.sample_util(t)
            self.util_series.append(
                (t, util, sum(1 for c in self.cores if c.group == GROUP_FIFO)))
            self._reschedule_timer("util", self.util_sample_ms)
        elif payload == "keepalive":
            self.containers.evict_expired(t)
            self._reschedule_timer("keepalive", self.containers.cfg.sweep_ms)

    # -- policy hooks -------------------------------------------------------
    def on_start(self) -> None:  # pragma: no cover - trivial
        pass

    def on_arrival(self, task: Task, t: float) -> None:
        raise NotImplementedError

    def pick_next(self, core: Core, t: float):
        raise NotImplementedError

    def on_chunk_limit(self, core: Core, task: Task, t: float) -> None:
        raise NotImplementedError

    def on_complete(self, task: Task, t: float) -> None:
        pass


def cfs_fast_forward(sched: Scheduler, core: Core, end: float, hz: float):
    """Shared precondition gate for CFS-style slice cycles, used by both
    the pure-CFS policy and the hybrid CFS group (the scheduler must
    expose ``sched_latency_ms`` / ``min_granularity_ms``). Validates
    that the in-flight chunk is a full slice of the constant quantum —
    or the task's FINAL (completing) chunk, which enters the chain
    driver directly — honours ``_ff_solo_only``, and requires a barrier
    window wide enough to batch at least one round before entering the
    round engine."""
    if sched.interference_fn is not None:
        return end
    rq = core.rq
    if rq and sched._ff_solo_only:
        return end
    task = core.task
    nr = len(rq)
    s = max(sched.sched_latency_ms / (nr if nr else 1),
            sched.min_granularity_ms)
    if core.chunk_len != s:
        # Not a full slice: the only other chunk CFS starts is the
        # task's final partial chunk (run == remaining < s). Retire
        # the completion chain from it when batching is on.
        if not (sched._batch_complete
                and chunk_completes(task.remaining, core.chunk_len)):
            return end
    elif not chunk_completes(task.remaining, s):
        bound = sched._next_barrier(core.chunk_start, core)
        if bound - end < s:
            return end               # window too short to batch a round
        return _cfs_chain(sched, core, end, bound, hz, s)
    elif not sched._batch_complete:
        return end                   # full-slice chunk that completes
    bound = sched._next_barrier(core.chunk_start, core)
    return _cfs_chain(sched, core, end, bound, hz, s)


def _cfs_chain(sched: Scheduler, core: Core, end: float, bound: float,
               hz: float, s: float):
    """Chain driver: alternate the closed-form slice-round engine with
    analytic completion retirement until an interacting event, the
    ``hz`` horizon, or a pick the batch may not perform (a fresh task's
    first dispatch with a container pool attached).

    Completion retirement replicates the event path exactly: retire the
    final chunk, `_complete` (deferred pool release, completed append,
    policy hook), then `pick_next` — pop the runqueue minimum, advance
    ``min_vruntime``, recompute the slice for the shrunk queue, charge
    the context switch, stamp ``first_run`` on a fresh pick (legal only
    with no pool — the gate in the loop guarantees it) — and start the
    next chunk with the same float expression `_start_chunk` uses.
    Returns the new in-flight chunk's expiry, or None when the chain
    drained the runqueue and the core went idle."""
    eps = _EPS
    batch_complete = sched._batch_complete
    pool = sched.containers
    lat = sched.sched_latency_ms
    gran = sched.min_granularity_ms
    ctx_ms = sched.ctx_switch_ms
    while True:
        task = core.task
        if task.remaining - core.chunk_len > eps:
            # Full-slice regime (chunk_len == s here by construction).
            end = cfs_round_fast_forward(sched, core, end, bound, hz, s)
            task = core.task         # the batch may have rotated tasks
            if task.remaining - core.chunk_len > eps:
                return end           # stopped at bound/hz/serialized pick
        # The in-flight chunk completes its task at `end`.
        if not (end < bound and end <= hz) or not batch_complete:
            return end               # engine path processes the expiry
        rq = core.rq
        if rq and pool is not None and rq[0][2].first_run is None:
            return end               # next pick serializes (pool + RNG)
        sched._retire_completion(core, end)
        if end < core.locked_until:
            return None              # rightsizer lock: timer dispatches
        if not rq:
            return None              # queue drained: core idles at `end`
        # -- pick_next, replicated -----------------------------------
        vr, _seq, ntask = rq.pop(0)
        if vr > core.min_vruntime:
            core.min_vruntime = vr
        nr = len(rq)
        s = max(lat / (nr if nr else 1), gran)
        ctx = ctx_ms if core.last_task is not ntask else 0.0
        if ntask.first_run is None:
            ntask.first_run = end    # no pool here: purely core-local
        rem = ntask.remaining
        run = rem if rem < s else s
        if run < eps:
            run = eps
        core.task = ntask
        core.chunk_start = end
        core.chunk_work_start = end + ctx
        core.chunk_len = run
        core.chunk_rate = 1.0
        if ctx > 0.0:
            ntask.ctx_switches += 1
            sched.total_ctx += 1
        end = (end + ctx) + run      # same ops as _start_chunk, rate 1


def cfs_round_fast_forward(sched: Scheduler, core: Core, end: float,
                           bound: float, hz: float, s: float) -> float:
    """Retire successive CFS slice-expiry rounds on one core analytically.

    Preconditions (checked by the calling policy): no interference model
    (chunk rate is exactly 1.0), the in-flight chunk is a full slice of
    length ``s``, and the policy's slice-expiry bookkeeping for this
    core is exactly the base CFS sequence (vruntime += slice, preemption
    counters, runqueue re-insert). While the runqueue membership is
    stable — every event that could change it lands at or after
    ``bound`` (the next interacting event) or past the ``hz`` horizon —
    the heap-mediated cycle

        expire -> vruntime += s -> rq_push -> rq_pop(min) -> next slice

    is a closed form over a small sorted list. Every float operation the
    event path would perform is replicated in the same order, so the
    result is bit-identical (tests/test_engine_equivalence.py); the
    runqueue is left as a sorted list, which is a valid heap with the
    exact (vruntime, seq) entries the push/pop sequence would produce.

    Returns the new in-flight chunk's expiry instant.
    """
    task = core.task
    rq = core.rq                     # kept sorted: spliced in place
    if not rq:
        return _solo_fast_forward(sched, core, task, end, bound, hz, s)
    # Long stable alternation cycles (every task gets one slice per
    # round, queue order fixed) are closed-form too: batch them with
    # vectorized exact accumulation, then let the engine re-enter for
    # whatever regime follows.
    lim = bound if bound <= hz else hz + 1.0
    if (lim - end) / (sched.ctx_switch_ms + s) >= 96.0:
        res = _cycle_fast_forward(sched, core, task, end, bound, hz, s, lim)
        if res is not None:
            return res
    t = core.chunk_start
    e = end
    ws = core.chunk_work_start
    cur_run = core.chunk_len         # == s
    busy = core.busy_ms
    mv = core.min_vruntime
    rq_seq = core.rq_seq
    ctx_ms = sched.ctx_switch_ms
    charge_ctx = ctx_ms > 0.0
    no_pool = sched.containers is None
    eps = _EPS
    last = core.last_task
    ctx_n = 0
    n = 0
    rq_pop = rq.pop
    while True:
        if not (e < bound and e <= hz):
            break                    # an interacting event intervenes
        nrem = task.remaining - s
        if nrem <= eps:
            break                    # chunk completes; the chain driver
            # (or the engine path, when batching is off) handles it
        vr = task.vruntime + s
        head = rq[0]
        if head[0] <= vr:
            ntask = head[2]
            if ntask.first_run is None:
                if not no_pool:
                    # The pick would be this task's FIRST dispatch:
                    # with a pool that path acquires a sandbox (and on
                    # a miss draws the cold-start RNG), which must
                    # interleave with other cores' pool operations in
                    # exact heap order.
                    break
                # No pool: a first dispatch only stamps first_run with
                # the new chunk's start instant — purely core-local.
                ntask.first_run = e
            # -- slice expiry at e: retire the in-flight chunk --------
            task.remaining = nrem
            task.cpu_time += s
            busy += e - t
            task.vruntime = vr
            task.preemptions += 1
            seq = rq_seq
            rq_seq = seq + 1         # the rq_push the event path would do
            # -- rq_pop: the fresh (vr, seq) entry loses ties ---------
            rq_pop(0)
            insort(rq, (vr, seq, task))
            hv = head[0]
            if hv > mv:
                mv = hv
            last = task
            task = ntask
            rem = task.remaining
            run = rem if rem < s else s
            if run < eps:
                run = eps
            if charge_ctx:
                task.ctx_switches += 1
                ctx_n += 1
            t = e
            ws = t + ctx_ms
            e = ws + run             # == t + ctx + run / 1.0, bit-exact
        else:
            # Catch-up: the running task stays ahead of the queue and
            # keeps the core (no context switch).
            task.remaining = nrem
            task.cpu_time += s
            busy += e - t
            task.vruntime = vr
            task.preemptions += 1
            rq_seq += 1
            if vr > mv:
                mv = vr
            last = task
            run = nrem if nrem < s else s
            if run < eps:
                run = eps
            t = e
            e = t + run              # ctx == 0.0: t + 0.0 + run / 1.0
            ws = t
        cur_run = run
        n += 1
        if run != s:
            break                    # final partial chunk is in flight
    if n:
        core.task = task
        core.last_task = last
        core.chunk_start = t
        core.chunk_work_start = ws
        core.chunk_len = cur_run
        core.busy_ms = busy
        core.min_vruntime = mv
        core.rq_seq = rq_seq
        core.preempt_count += n
        sched.total_ctx += ctx_n
        sched.n_events += n
        return e
    return end


# Windowed sub-round batching: queues deeper than _WINDOW_MIN use the
# completion-aware windowed pass (setup O(window), completions retired
# inline) instead of the full-queue cycle engine; windows evaluate 64
# chunks first and escalate to _WINDOW when the whole window retires.
_WINDOW_MIN = 256
_WINDOW = 256


def _window_fast_forward(sched: Scheduler, core: Core, task: Task,
                         end: float, bound: float, hz: float, s: float):
    """Sub-round vectorized batch — COMPLETIONS INCLUDED — over the
    first ``_WINDOW`` picks of a DEEP runqueue.

    Chunk i runs the i-th task of the rotation ([running] ++ queue
    order): within one rotation every pick is distinct, so per-task
    state needs no accumulation — one elementwise add/subtract
    reproduces the event path's single float operation per task
    exactly. Chunk lengths are ``min(remaining, s)``: a COMPLETING
    chunk simply runs short, retires its task analytically (deferred
    pool release, completed append, ``on_complete`` hook) and pushes
    nothing back, while every other chunk is a full slice that pushes
    ``vruntime + s`` at the tail. The chunk-end chain stays one exact
    interleaved ``accumulate`` over (+ctx, +run_i), so the whole braid
    — slices, completions, next picks — is evaluated in a handful of
    O(window) array ops. This is what retires dense-queue completion
    RUNS without per-event heap traffic (DESIGN.md Sec. 13).

    Stops (exact, per chunk, on the accumulated values): an
    interacting event at/after ``bound``; the ``hz`` horizon; a
    non-completing push that would not land at the queue tail (the
    same stability condition as the full-cycle engine); a pick whose
    first dispatch must serialize (fresh task + container pool); the
    slice leaving the constant-quantum regime (enough completions that
    ``latency / nr > min_granularity``); any completion when the
    policy opted out of completion batching. Returns the new in-flight
    expiry, or ``None`` to decline to the scalar/driver path. A fully
    retired window hands back to the chain driver, which re-enters —
    stable stretches advance window by window at O(1) amortized setup
    per chunk."""
    rq = core.rq
    no_pool = sched.containers is None
    if not no_pool and rq[0][2].first_run is None:
        return None                  # head pick is a serialized first
        # dispatch: don't pay the window setup to learn c == 0
    if end < core.locked_until:      # rightsizer lock pending: rare,
        return None                  # let the event path sort it out
    k1 = len(rq)
    lat = sched.sched_latency_ms
    gran = sched.min_granularity_ms
    # Adaptive sizing: evaluation is pure until the commit, so a too-
    # small window just costs one extra pass. Start from this core's
    # last batch length (completion cadence is locally stable) and
    # escalate to full width when the whole window retires.
    wmax = min(_WINDOW, k1 - 1)
    W = min(64, wmax) if core.ff_w < 56 else wmax
    while True:
        c, arrays = _window_eval(sched, core, task, end, bound, hz, s,
                                 W, k1, lat, gran, no_pool)
        if c >= W and W < wmax:
            W = wmax                   # whole window retired: go wide
            continue
        break
    core.ff_w = c
    tasks_w, cum = arrays[0], arrays[7]
    if c >= 2 and (
            (not no_pool and tasks_w[c].first_run is None)
            or (sched._batch_complete
                and lat / (k1 - int(cum[c - 1])) > gran)):
        # The stop is the pick of chunk c itself (a serialized first
        # dispatch, or a slice that would no longer be s): the batch
        # may not START that chunk either — leave the previous chunk
        # in flight, like the scalar loop's break-before-pick.
        c -= 1
    if c < 2:
        return None
    return _window_commit(sched, core, task, end, s, c, arrays, no_pool)


def _window_eval(sched, core, task, end, bound, hz, s, W, k1, lat, gran,
                 no_pool):
    """Pure evaluation half of the windowed pass: how many chunks of
    the rotation can retire, and the exact value arrays the commit
    needs. Mutates nothing."""
    rq = core.rq
    eps = _EPS
    ctx_ms = sched.ctx_switch_ms
    tasks_w = [task] + [rq[i][2] for i in range(W)]
    rem0 = np.array([x.remaining for x in tasks_w])          # W + 1
    vr0 = np.array([x.vruntime for x in tasks_w[:W]])
    pushed = vr0 + s                 # one add per task, same op as the loop
    rem_after = rem0[:W] - s
    completing = rem_after <= eps    # full-slice finishers AND short rests
    runs = np.minimum(rem0, s)       # chunk i's length = min(rem_i, s)
    buf = np.empty(2 * W + 1)
    buf[0] = end                     # chunk 0 (in flight) ends at `end`
    buf[1::2] = ctx_ms
    buf[2::2] = runs[1:]             # e_i = e_{i-1} + ctx + run_i
    half = np.add.accumulate(buf)    # exact interleaved (+ctx, +run) chain
    ends = half[0::2]                # e_0 .. e_W
    ok = (ends[:W] < bound) & (ends[:W] <= hz)
    cum = np.add.accumulate(completing)   # completions among chunks 0..i
    if sched._batch_complete:
        # Slice constancy: completions shrink the queue, and chunk i's
        # pick granted slice s only while latency/nr <= min_granularity
        # (the exact comparison slice_for flips on). nr at chunk i's
        # pick counts completions strictly before chunk i.
        slice_ok = np.empty(W, dtype=bool)
        slice_ok[0] = True           # chunk 0 started before the batch
        np.less_equal(lat / (k1 - cum[:-1]), gran, out=slice_ok[1:])
        ok &= slice_ok
    else:
        ok &= ~completing            # completions serialize (opt-out)
    # Stability: every NON-completing push must land at the queue tail
    # (>= the running max of the original tail and every prior push).
    pushed_eff = np.where(completing, -_INF, pushed)
    prior = np.empty(W)
    prior[0] = rq[-1][0]
    np.maximum.accumulate(pushed_eff[:-1], out=prior[1:])
    np.maximum(prior[1:], rq[-1][0], out=prior[1:])
    ok &= completing | (pushed >= prior)
    if not no_pool:
        # A fresh task's first dispatch acquires a sandbox (and may
        # draw the cold-start RNG): chunk i may not PICK a fresh task.
        ok &= np.fromiter((x.first_run is not None
                           for x in tasks_w[:W]), bool, W)
    c = int(np.argmin(ok)) if not ok.all() else W
    return c, (tasks_w, rem_after, completing, runs, pushed, ends, half,
               cum)


def _window_commit(sched, core, task, end, s, c, arrays, no_pool):
    """Commit half of the windowed pass: apply ``c`` retired chunks and
    start chunk ``c``. Bulk-converts the value arrays once (per-element
    numpy indexing + float() is the single largest cost of the whole
    pass at this batch size)."""
    tasks_w, rem_after, completing, runs, pushed, ends, half, cum = arrays
    rq = core.rq
    eps = _EPS
    ctx_ms = sched.ctx_switch_ms
    charge_ctx = ctx_ms > 0.0
    seq0 = core.rq_seq
    comp_l = completing[:c].tolist()
    rem_l = rem_after[:c].tolist()
    run_l = runs[:c + 1].tolist()
    push_l = pushed[:c].tolist()
    ends_l = ends[:c].tolist()
    pool = sched.containers
    completed = sched.completed
    if no_pool:
        # Stamp BEFORE the retirement loop: a fresh task may complete
        # in its very first chunk, and on_complete hooks read
        # execution = completion - first_run.
        for j in range(1, c + 1):    # in-flight pick included
            x = tasks_w[j]
            if x.first_run is None:
                x.first_run = ends_l[j - 1]   # chunk j starts at e_{j-1}
    npush = 0
    ff_now = sched._ff_now
    for j in range(c):
        x = tasks_w[j]
        if comp_l[j]:
            e = ends_l[j]
            x.cpu_time = x.cpu_time + run_l[j]
            x.remaining = 0.0
            x.completion = e
            if pool is not None and x.aux_of is None:
                pool.release_at(x.func_id, x.mem_mb, e, x.tid)
            completed.append(x)
            sched.on_complete(x, e)
            if e > ff_now:
                ff_now = e
        else:
            x.remaining = rem_l[j]
            x.vruntime = push_l[j]
            x.cpu_time = x.cpu_time + s
            x.preemptions += 1
            npush += 1
        if charge_ctx and j:         # chunk j (j>=1) starts with a switch
            x.ctx_switches += 1
            sched.total_ctx += 1
    sched._ff_now = ff_now
    nxt_task = tasks_w[c]
    if charge_ctx:
        nxt_task.ctx_switches += 1   # the in-flight chunk's switch
        sched.total_ctx += 1
    # survivors: original entries c.. plus the non-completing pushes,
    # in chunk order (each lands at the tail: checked above)
    tail = []
    seq = seq0
    for i in range(c):
        if not comp_l[i]:
            tail.append((push_l[i], seq, tasks_w[i]))
            seq += 1
    core.rq = rq[c:] + tail
    mv = rq[c - 1][0]                # last popped (original) entry
    if mv > core.min_vruntime:
        core.min_vruntime = mv
    core.rq_seq = seq
    core.preempt_count += npush
    sched.n_events += c
    d = np.empty(c)
    d[0] = end - core.chunk_start
    if c > 1:
        np.subtract(ends[1:c], ends[0:c - 1], out=d[1:])
    acc = np.empty(c + 1)
    acc[0] = core.busy_ms
    acc[1:] = d
    core.busy_ms = float(np.add.accumulate(acc)[-1])
    run = run_l[c]
    ws = float(half[2 * c - 1])      # t + ctx, exact
    e = float(ends[c])
    if run < eps:                    # unreachable for queued tasks
        run = eps                    # (remaining > eps), kept for parity
        e = ws + run
    core.task = nxt_task
    core.last_task = tasks_w[c - 1]
    core.chunk_start = ends_l[c - 1]
    core.chunk_work_start = ws
    core.chunk_len = run
    return e


def _cycle_fast_forward(sched: Scheduler, core: Core, task: Task,
                        end: float, bound: float, hz: float, s: float,
                        lim: float):
    """Vectorized stable-cycle batch: ``k`` tasks alternating, one full
    slice each per round, queue order fixed.

    In the stable regime the pushed-vruntime sequence is nondecreasing,
    so every ``insort`` lands at the queue tail and every pick takes the
    head — the whole braid is determined by per-task accumulation
    sequences. Those are single-operand float chains (``vr += s``,
    ``rem -= s``, ``e += ctx; e += s``), which ``ufunc.accumulate``
    reproduces bit-exactly at C speed. The stability condition itself is
    checked ON the exact accumulated values, so the batch stops at the
    precise chunk where the event path would first deviate (catch-up,
    completion, barrier, partial slice, queue reorder) and hands back;
    the scalar loops take over from identical state.

    Returns the new in-flight chunk expiry, or None to decline (the
    caller falls through to the scalar loop).
    """
    rq = core.rq
    k1 = len(rq)                     # waiting tasks (k = k1 + 1)
    k = k1 + 1
    # Cheap necessary condition for cycle stability (in exact arithmetic
    # gaps between vruntimes are cycle-invariant, so round one decides):
    # the running task must re-queue at the tail and behind the head.
    vr0 = task.vruntime
    if vr0 + s < rq[-1][0] or vr0 > rq[0][0]:
        return None
    if k1 > _WINDOW_MIN:
        # Deep queue: a batch usually stops well before one full
        # rotation (a completion, or instability), so the full-queue
        # O(k) setup below would swamp its own yield. The windowed
        # sub-round pass keeps setup O(window) and retires completions
        # inline; genuinely stable long cycles just retire window
        # after window through the driver.
        return _window_fast_forward(sched, core, task, end, bound, hz, s)
    fresh = []
    if sched.containers is None:
        # First dispatches are core-local without a pool: stamp them at
        # commit with their first chunk's start (task j's first chunk
        # is chunk j). Sub-round commits (c < k) are routine, so only
        # tasks whose first chunk actually ran (j <= c) get stamped.
        fresh = [j for j, ent in enumerate(rq, start=1)
                 if ent[2].first_run is None]
    else:
        for ent in rq:
            if ent[2].first_run is None:
                return None          # first dispatches go through the heap
    ctx_ms = sched.ctx_switch_ms
    eps = _EPS
    tasks = [task] + [ent[2] for ent in rq]   # cycle (pick) order
    rem0 = [x.remaining for x in tasks]
    # Cycle cap: the tightest task's remaining, and the time to bound.
    min_rem = min(rem0)
    r_cap = int(min((min_rem - s) / s + 2.0,
                    (lim - end) / (k * (ctx_ms + s)) + 2.0)) + 1
    if r_cap * k < 96:
        return None                  # too short to be worth the arrays
    r_cap = min(r_cap, max(2, (1 << 20) // k))
    # Allocate for a modest horizon first and escalate geometrically
    # only when the whole window retires — stability or a barrier
    # usually stops a batch long before the remaining-time cap.
    r_try = min(r_cap, max(2, 768 // k))
    while True:
        c_max = r_try * k
        # -- exact per-task accumulation sequences --------------------
        m = np.full((k, r_try + 1), s)
        m[:, 0] = rem0
        rem_arr = np.subtract.accumulate(m, axis=1)
        m[:, 0] = [x.vruntime for x in tasks]
        vr_arr = np.add.accumulate(m, axis=1)
        # Chunk-end chain: e_{c+1} = (e_c + ctx) + s — two rounding
        # steps, interleaved in one accumulate so every intermediate
        # is exact.
        buf = np.empty(2 * c_max + 1)
        buf[0] = end
        buf[1::2] = ctx_ms
        buf[2::2] = s
        half = np.add.accumulate(buf)
        ends = half[0::2]            # e_c, len c_max + 1
        # -- how many chunks can be retired? --------------------------
        pushed = vr_arr[:, 1:].T.ravel()          # vr pushed at chunk c
        rem_after = rem_arr[:, 1:].T.ravel()      # remaining after chunk c
        ok = (ends[:c_max] < bound) & (ends[:c_max] <= hz) \
            & (rem_after > eps)
        # Stability: each push must land at the queue tail
        # (nondecreasing pushed sequence, from the queue maximum up).
        stab = np.empty(c_max, dtype=bool)
        stab[0] = pushed[0] >= rq[-1][0]
        np.greater_equal(pushed[1:], pushed[:-1], out=stab[1:])
        ok &= stab
        c_stop = int(np.argmin(ok)) if not ok.all() else c_max
        if c_stop < c_max or r_try >= r_cap:
            break
        r_try = min(r_cap, r_try * 8)
    if c_stop < 2:                   # nothing worth committing: scalar
        return None
    c = c_stop
    m[:, 0] = [x.cpu_time for x in tasks]
    cpu_arr = np.add.accumulate(m, axis=1)
    # -- commit: per-task state ---------------------------------------
    charge_ctx = ctx_ms > 0.0
    seq0 = core.rq_seq
    for j in fresh:
        if j <= c:                   # task j's first chunk (index j) ran
            tasks[j].first_run = float(ends[j - 1])
    # A sub-round batch (c < k: a completion stopped the rotation)
    # leaves tasks beyond index c untouched — skip their no-op writes;
    # this loop is the vectorizer's main Python cost in deep queues.
    ck, cr = c // k, c % k
    for j in range(c + 1 if c < k else k):
        x = tasks[j]
        runs = ck + (1 if j < cr else 0)            # chunks j, j+k, ... < c
        if runs:
            x.remaining = float(rem_arr[j, runs])
            x.vruntime = float(vr_arr[j, runs])
            x.cpu_time = float(cpu_arr[j, runs])
            x.preemptions += runs
        if charge_ctx:
            # batch-started chunks (1..c, in-flight included) with a
            # context switch, i.e. chunk indices congruent to j
            starts = ck if j == 0 else (c - j) // k + 1
            if starts:
                x.ctx_switches += starts
                sched.total_ctx += starts
    # busy: same (e_c - t_c) subtraction/addition sequence as the loop.
    d = np.empty(c)
    d[0] = end - core.chunk_start
    if c > 1:
        np.subtract(ends[1:c], ends[0:c - 1], out=d[1:])
    acc = np.empty(c + 1)
    acc[0] = core.busy_ms
    acc[1:] = d
    core.busy_ms = float(np.add.accumulate(acc)[-1])
    # queue: entries C..C+k-2 of (original ++ pushed) survive. A batch
    # shorter than one full round (a completion stops it mid-rotation)
    # keeps a suffix of the ORIGINAL entries — their tuples are reused
    # untouched — ahead of the freshly pushed tail.
    core.rq = rq[c:] + [(float(pushed[i]), seq0 + i, tasks[i % k])
                        for i in range(c - k1 if c > k1 else 0, c)]
    nxt_task = tasks[c % k]          # the chunk-c pick
    # last popped value = (original ++ pushed)[c-1] (pops nondecreasing)
    mv = float(pushed[c - k]) if c >= k else rq[c - 1][0]
    if mv > core.min_vruntime:
        core.min_vruntime = mv
    core.rq_seq = seq0 + c
    core.preempt_count += c
    sched.n_events += c
    # -- in-flight chunk c --------------------------------------------
    rem = nxt_task.remaining
    run = rem if rem < s else s
    if run < eps:
        run = eps
    t = float(ends[c - 1])
    ws = float(half[2 * c - 1])      # t + ctx, exact
    e = float(half[2 * c]) if run == s else ws + run
    core.task = nxt_task
    core.last_task = tasks[(c - 1) % k]
    core.chunk_start = t
    core.chunk_work_start = ws
    core.chunk_len = run
    return e


def _solo_scalar(sched: Scheduler, core: Core, task: Task, end: float,
                 bound: float, hz: float, s: float) -> float:
    """Scalar lone-task round chain — the short-batch counterpart of
    :func:`_solo_fast_forward`, same exact operations."""
    eps = _EPS
    t = core.chunk_start
    e = end
    busy = core.busy_ms
    n = 0
    run = s
    while e < bound and e <= hz:
        nrem = task.remaining - s
        if nrem <= eps:
            break
        task.remaining = nrem
        task.cpu_time += s
        busy += e - t
        task.vruntime += s
        task.preemptions += 1
        n += 1
        run = nrem if nrem < s else s
        if run < eps:
            run = eps
        t = e
        e = t + run
        if run != s:
            break
    if n:
        core.last_task = task
        core.chunk_start = t
        core.chunk_work_start = t
        core.chunk_len = run
        core.busy_ms = busy
        vr = task.vruntime
        if vr > core.min_vruntime:
            core.min_vruntime = vr
        core.rq_seq += n
        core.preempt_count += n
        sched.n_events += n
        return e
    return end


def _solo_fast_forward(sched: Scheduler, core: Core, task: Task, end: float,
                       bound: float, hz: float, s: float) -> float:
    """Vectorized lone-task round chain (empty runqueue, zero context
    switches). The per-round float updates are single-operand
    accumulations — ``remaining -= s``, ``vruntime += s``,
    ``cpu_time += s``, ``e += s`` — and ``numpy``'s ``ufunc.accumulate``
    applies its operator strictly sequentially in float64, so the
    accumulated sequences are bit-identical to the scalar loop while
    running at C speed. Stopping conditions are evaluated on the exact
    accumulated arrays; the final (possibly partial) chunk is started
    scalar, exactly like the general loop."""
    eps = _EPS
    rem0 = task.remaining
    # Upper bound on retirable full-slice rounds: remaining must stay
    # > s + eps after each, and each round pushes e forward by s past
    # the current chunk's end. Cap by the time budget (and absolutely)
    # so a year-long lone task against a far barrier does not allocate
    # gigabytes; hitting the cap just hands back to the engine loop,
    # which re-enters the fast-forward on the next chunk.
    lim = bound if bound <= hz else hz + 1.0  # allocation cap only
    r_cap = int(max(0.0, min((rem0 - s) / s, (lim - end) / s + 2.0))) + 1
    if r_cap <= 1:
        return end
    if r_cap < 48:
        # ufunc/allocation overhead beats the scalar loop on short
        # chains (the arrival-phase common case); stay scalar there.
        return _solo_scalar(sched, core, task, end, bound, hz, s)
    if r_cap > (1 << 21):
        r_cap = 1 << 21
    buf = np.full(r_cap + 1, s)
    buf[0] = rem0
    rem_seq = np.subtract.accumulate(buf)      # rem_i after i rounds
    buf[0] = end
    e_seq = np.add.accumulate(buf)             # e_i: chunk end after i rounds
    # Round i (1-based) retires the chunk ending at e_{i-1}; it needs
    # e_{i-1} < bound, e_{i-1} <= hz, rem_{i-1} - s > eps, and the
    # PREVIOUS round's started chunk to have been a full slice
    # (rem_{i-1} >= s, implied by rem_{i-1} - s > eps).
    ok = (e_seq[:-1] < bound) & (e_seq[:-1] <= hz) & (rem_seq[:-1] - s > eps)
    bad = np.argmin(ok) if not ok.all() else len(ok)
    n = int(bad)
    if n <= 0:
        return end
    t = float(e_seq[n - 1])
    e = float(e_seq[n])
    rem = float(rem_seq[n])
    # busy accumulates (e_i - t_i) per retired chunk — identical
    # subtraction and addition sequence to the scalar loop.
    d = np.empty(n)
    d[0] = end - core.chunk_start
    if n > 1:
        d[1:] = e_seq[1:n] - e_seq[0:n - 1]
    busy = np.add.accumulate(np.concatenate(([core.busy_ms], d)))[-1]
    # vruntime/cpu_time: same accumulate trick, then write back finals.
    buf[0] = task.vruntime
    task.vruntime = vr = float(np.add.accumulate(buf[:n + 1])[-1])
    buf[0] = task.cpu_time
    task.cpu_time = float(np.add.accumulate(buf[:n + 1])[-1])
    task.remaining = rem
    task.preemptions += n
    # The final started chunk may be the task's last (partial) slice.
    run = rem if rem < s else s
    if run < eps:
        run = eps
    e = t + run if run != s else e   # same op the scalar loop performs
    core.task = task
    core.last_task = task
    core.chunk_start = t
    core.chunk_work_start = t
    core.chunk_len = run
    core.busy_ms = float(busy)
    if vr > core.min_vruntime:
        core.min_vruntime = vr
    core.rq_seq += n
    core.preempt_count += n
    sched.n_events += n
    return e
