"""repro.core — the paper's contribution: hybrid two-level scheduling.

Discrete-event simulation of OS-level scheduling policies for serverless
(L1), plus the policy objects reused by the serving gateway (L2).
"""
from .containers import (ContainerConfig, ContainerPool, ContainerSpec,
                         as_container_config, expected_cold_ms)
from .events import Core, Scheduler, Task, GROUP_CFS, GROUP_FIFO
from .policies import CFS, EDF, FIFO, FIFOPreempt, RoundRobin
from .hybrid import HybridScheduler, Rightsizer, TimeLimitAdapter, percentile
from .metrics import SimResult, collect
from .simulate import (POLICIES, execute_policy, make_scheduler,
                       run_policy)
from . import cost

__all__ = [
    "ContainerConfig", "ContainerPool", "ContainerSpec",
    "as_container_config", "expected_cold_ms",
    "Core", "Scheduler", "Task", "GROUP_CFS", "GROUP_FIFO",
    "CFS", "EDF", "FIFO", "FIFOPreempt", "RoundRobin",
    "HybridScheduler", "Rightsizer", "TimeLimitAdapter", "percentile",
    "SimResult", "collect", "POLICIES", "execute_policy",
    "make_scheduler", "run_policy",
    "cost",
]
