"""Result aggregation: the paper's three metrics + CDFs + p99 + cost."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .cost import workload_cost_usd, cost_ladder
from .events import GROUP_CFS, GROUP_FIFO, Scheduler, Task


@dataclass
class SimResult:
    policy: str
    tasks: list[Task]
    failed: list[Task] = field(default_factory=list)
    preempt_per_core: Optional[list[int]] = None
    util_series: Optional[list] = None
    limit_series: Optional[list] = None
    migrations: Optional[list] = None
    total_ctx: int = 0

    # -- metric vectors (ms) ------------------------------------------------
    def execution(self) -> np.ndarray:
        return np.array([t.execution for t in self.tasks])

    def response(self) -> np.ndarray:
        return np.array([t.response for t in self.tasks])

    def turnaround(self) -> np.ndarray:
        return np.array([t.turnaround for t in self.tasks])

    def service(self) -> np.ndarray:
        return np.array([t.service for t in self.tasks])

    def p(self, metric: str, pct: float) -> float:
        return float(np.percentile(getattr(self, metric)(), pct))

    def p99(self) -> dict[str, float]:
        return {m: self.p(m, 99) / 1000.0  # seconds, as in Table I
                for m in ("response", "execution", "turnaround")}

    def makespan(self) -> float:
        return max(t.completion for t in self.tasks)

    def total_preemptions(self) -> int:
        return sum(t.preemptions for t in self.tasks)

    # -- cost ---------------------------------------------------------------
    def cost_usd(self, fixed_mem_mb: Optional[float] = None) -> float:
        if fixed_mem_mb is not None:
            return workload_cost_usd(self.execution(),
                                     fixed_mem_mb=fixed_mem_mb)
        return workload_cost_usd(self.execution(),
                                 mem_mb=[t.mem_mb for t in self.tasks])

    def cost_ladder(self) -> dict[int, float]:
        return cost_ladder(self.execution())

    # -- CDF helper -----------------------------------------------------------
    def cdf(self, metric: str) -> tuple[np.ndarray, np.ndarray]:
        vals = np.sort(getattr(self, metric)())
        frac = np.arange(1, len(vals) + 1) / len(vals)
        return vals, frac

    def summary(self) -> dict:
        e, r, ta = self.execution(), self.response(), self.turnaround()
        return {
            "policy": self.policy,
            "n": len(self.tasks),
            "failed": len(self.failed),
            "mean_execution_s": float(e.mean()) / 1e3,
            "p50_execution_s": float(np.percentile(e, 50)) / 1e3,
            "p99_execution_s": float(np.percentile(e, 99)) / 1e3,
            "p99_response_s": float(np.percentile(r, 99)) / 1e3,
            "p99_turnaround_s": float(np.percentile(ta, 99)) / 1e3,
            "makespan_s": self.makespan() / 1e3,
            "preemptions": self.total_preemptions(),
            "ctx_switches": self.total_ctx,
            "cost_usd": self.cost_usd(),
        }


def collect(sched: Scheduler, policy: str) -> SimResult:
    limit_series = None
    migrations = None
    adapter = getattr(sched, "adapter", None)
    if adapter is not None:
        limit_series = adapter.series
    rs = getattr(sched, "rightsizer", None)
    if rs is not None:
        migrations = rs.migrations
    return SimResult(
        policy=policy,
        tasks=sched.completed,
        failed=sched.failed,
        preempt_per_core=[c.preempt_count for c in sched.cores],
        util_series=sched.util_series,
        limit_series=limit_series,
        migrations=migrations,
        total_ctx=sched.total_ctx,
    )
