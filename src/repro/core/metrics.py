"""Result aggregation: the paper's three metrics + CDFs + p99 + cost.

Failed invocations (admission rejects, injected faults) never ran to
completion, so their OSTEP metrics are undefined — ``Task`` properties
return NaN for them and every vector here is computed over *finished*
tasks only, with the failure count reported separately. With the
container layer attached, the summary additionally reports cold-start
counts, the billed-init share of the bill, and the provider-side cost of
holding the warm pool.

Every roll-up here is ORDER-CANONICAL (DESIGN.md Sec. 13): finished
tasks are viewed in (completion, tid) order regardless of how the list
was assembled, and cost sums are exactly rounded (``math.fsum``), so
summaries are bit-identical under any permutation of ``tasks``. This is
what lets the engine retire completions in batches: the completed list
is no longer required to be in heap-processing order.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import cached_property
from typing import Optional

import numpy as np

from .cost import (cold_start_cost_usd, cost_ladder, warm_pool_hold_cost_usd,
                   workload_cost_usd)
from .events import GROUP_CFS, GROUP_FIFO, Scheduler, Task


@dataclass
class SimResult:
    policy: str
    tasks: list[Task]
    failed: list[Task] = field(default_factory=list)
    preempt_per_core: Optional[list[int]] = None
    util_series: Optional[list] = None
    limit_series: Optional[list] = None
    migrations: Optional[list] = None
    total_ctx: int = 0
    container_stats: Optional[dict] = None
    # PricingSpec the roll-ups bill with (None = DEFAULT_PRICING,
    # bit-identically). Set post-run by the Scenario layer.
    pricing: Optional[object] = None

    # -- task views ---------------------------------------------------------
    @cached_property
    def _finished(self) -> list[Task]:
        return sorted((t for t in self.tasks if t.completion is not None),
                      key=lambda t: (t.completion, t.tid))

    def finished_tasks(self) -> list[Task]:
        """Tasks with defined metrics, in CANONICAL (completion, tid)
        order — every derived vector/percentile/sum is therefore
        invariant under permutations of ``self.tasks``. Roll-ups skip
        the rest (failed invocations that never completed end up in
        ``failed``, but be defensive against callers who merge the
        lists). Cached: ``summary()`` walks this ~8 times per sweep
        cell."""
        return self._finished

    # -- metric vectors (ms) ------------------------------------------------
    def execution(self) -> np.ndarray:
        return np.array([t.execution for t in self.finished_tasks()])

    def response(self) -> np.ndarray:
        return np.array([t.response for t in self.finished_tasks()])

    def turnaround(self) -> np.ndarray:
        return np.array([t.turnaround for t in self.finished_tasks()])

    def service(self) -> np.ndarray:
        return np.array([t.service for t in self.finished_tasks()])

    def p(self, metric: str, pct: float) -> float:
        return float(np.percentile(getattr(self, metric)(), pct))

    def p99(self) -> dict[str, float]:
        return {m: self.p(m, 99) / 1000.0  # seconds, as in Table I
                for m in ("response", "execution", "turnaround")}

    def makespan(self) -> float:
        # finished_tasks is sorted by (completion, tid): last wins.
        return self.finished_tasks()[-1].completion

    def total_preemptions(self) -> int:
        return sum(t.preemptions for t in self.tasks)

    # -- container lifecycle ------------------------------------------------
    def cold_starts(self) -> int:
        return sum(1 for t in self.finished_tasks() if t.cold_start)

    def cold_start_rate(self) -> float:
        done = self.finished_tasks()
        return (self.cold_starts() / len(done)) if done else 0.0

    def init_cost_usd(self) -> float:
        """The cold-start share of the user-facing bill (fsum over the
        canonical task order: permutation-invariant)."""
        return math.fsum(
            cold_start_cost_usd(t.init_ms, t.mem_mb, self.pricing)
            for t in self.finished_tasks() if t.cold_start)

    def warm_hold_usd(self) -> float:
        """Provider-side cost of the idle warm set over the run."""
        if not self.container_stats:
            return 0.0
        return warm_pool_hold_cost_usd(self.container_stats["warm_mb_ms"],
                                       self.pricing)

    # -- cost ---------------------------------------------------------------
    def cost_usd(self, fixed_mem_mb: Optional[float] = None) -> float:
        done = self.finished_tasks()
        if fixed_mem_mb is not None:
            return workload_cost_usd((t.execution for t in done),
                                     fixed_mem_mb=fixed_mem_mb,
                                     pricing=self.pricing)
        return workload_cost_usd((t.execution for t in done),
                                 mem_mb=[t.mem_mb for t in done],
                                 pricing=self.pricing)

    def cost_ladder(self) -> dict[int, float]:
        return cost_ladder(self.execution(), pricing=self.pricing)

    # -- CDF helper -----------------------------------------------------------
    def cdf(self, metric: str) -> tuple[np.ndarray, np.ndarray]:
        vals = np.sort(getattr(self, metric)())
        frac = np.arange(1, len(vals) + 1) / len(vals)
        return vals, frac

    def summary(self) -> dict:
        e, r, ta = self.execution(), self.response(), self.turnaround()
        out = {
            "policy": self.policy,
            "n": len(self.finished_tasks()),
            "failed": len(self.failed),
            "mean_execution_s": float(e.mean()) / 1e3,
            "p50_execution_s": float(np.percentile(e, 50)) / 1e3,
            "p99_execution_s": float(np.percentile(e, 99)) / 1e3,
            "p99_response_s": float(np.percentile(r, 99)) / 1e3,
            "p99_turnaround_s": float(np.percentile(ta, 99)) / 1e3,
            "makespan_s": self.makespan() / 1e3,
            "preemptions": self.total_preemptions(),
            "ctx_switches": self.total_ctx,
            "cost_usd": self.cost_usd(),
        }
        if self.container_stats is not None:
            out["cold_starts"] = self.cold_starts()
            out["cold_start_rate"] = self.cold_start_rate()
            out["init_cost_usd"] = self.init_cost_usd()
            out["warm_hold_usd"] = self.warm_hold_usd()
        return out


def collect(sched: Scheduler, policy: str) -> SimResult:
    limit_series = None
    migrations = None
    adapter = getattr(sched, "adapter", None)
    if adapter is not None:
        adapter.flush()  # apply any still-buffered completion samples
        limit_series = adapter.series
    rs = getattr(sched, "rightsizer", None)
    if rs is not None:
        migrations = rs.migrations
    container_stats = None
    pool = getattr(sched, "containers", None)
    if pool is not None:
        pool.settle(sched.now)  # bring the memory-hold meter current
        container_stats = pool.stats()
    return SimResult(
        policy=policy,
        tasks=sched.completed,
        failed=sched.failed,
        preempt_per_core=[c.preempt_count for c in sched.cores],
        util_series=sched.util_series,
        limit_series=limit_series,
        migrations=migrations,
        total_ctx=sched.total_ctx,
        container_stats=container_stats,
    )
