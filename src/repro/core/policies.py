"""Single-level scheduling policies (paper Sec. III-C).

FIFO          -- run to completion, global queue, no preemption.
FIFOPreempt   -- paper's FIFO_100ms: preempt after a fixed per-chunk budget
                 and move to the END of the global queue (Sec. II-D).
RoundRobin    -- global queue, fixed quantum.
CFS           -- per-core runqueues ordered by vruntime with
                 sched_latency / min_granularity slicing (Linux defaults for
                 a ~50 core box), least-loaded core placement on wakeup.
EDF           -- preemptive earliest-deadline-first, centralized.
"""
from __future__ import annotations

from collections import deque
from typing import Optional

from .events import (_EPS, Core, Scheduler, Task, cfs_fast_forward,
                     cfs_slice_ms)


class FIFO(Scheduler):
    name = "fifo"

    def __init__(self, **kw):
        super().__init__(**kw)
        self.queue: deque[Task] = deque()

    def on_arrival(self, task: Task, t: float) -> None:
        self.queue.append(task)
        core = self.idle_core()
        if core is not None:
            self.dispatch(core, t)

    def global_queue_len(self) -> int:
        return len(self.queue)

    def pick_next(self, core: Core, t: float):
        if self.queue:
            return self.queue.popleft(), None
        return None

    def on_chunk_limit(self, core: Core, task: Task, t: float) -> None:
        raise AssertionError("FIFO never sets a chunk limit")


class FIFOPreempt(FIFO):
    """FIFO with per-chunk preemption budget (FIFO_100ms in the paper)."""

    name = "fifo_preempt"
    _has_ff = True

    def __init__(self, quantum_ms: float = 100.0, **kw):
        super().__init__(**kw)
        self.quantum_ms = quantum_ms

    def pick_next(self, core: Core, t: float):
        if self.queue:
            return self.queue.popleft(), self.quantum_ms
        return None

    def on_chunk_limit(self, core: Core, task: Task, t: float) -> None:
        task.preemptions += 1
        core.preempt_count += 1
        self.queue.append(task)  # to the END of the global queue

    def fast_forward(self, core: Core, end: float, hz: float):
        # A lone task with an empty global queue cycles append ->
        # popleft with itself: retire whole quantum rounds analytically,
        # then the final completion (the queue is empty, so the core
        # goes idle — no pick can follow). Every core shares the global
        # queue, so ANY other pending event (including other cores'
        # expiries, which may queue their task) bounds the loop — the
        # heap top, not just the barrier heap.
        if self.queue or self.interference_fn is not None:
            return end
        q = self.quantum_ms
        task = core.task
        nxt = self.heap[0][0] if self.heap else float("inf")
        if core.chunk_len == q and task.remaining - q > _EPS:
            t = core.chunk_start
            e = end
            busy = core.busy_ms
            n = 0
            cur_run = q
            while True:
                if not (e < nxt and e <= hz):
                    break
                nrem = task.remaining - q
                if nrem <= _EPS:
                    break            # chunk completes; retired below
                task.remaining = nrem
                task.cpu_time += q
                busy += e - t
                task.preemptions += 1
                n += 1
                run = nrem if nrem < q else q
                if run < _EPS:
                    run = _EPS
                t = e
                e = t + 0.0 + run    # ctx == 0: same task keeps the core
                cur_run = run
                if run != q:
                    break            # final partial chunk is in flight
            if n:
                core.last_task = task
                core.chunk_start = t
                core.chunk_work_start = t + 0.0
                core.chunk_len = cur_run
                core.busy_ms = busy
                core.preempt_count += n
                self.n_events += n
                end = e
        # Retire the chain's completion when it lands before every
        # other pending event: queue empty means the core idles after.
        if (self._batch_complete
                and task.remaining - core.chunk_len <= _EPS
                and end < nxt and end <= hz):
            self._retire_completion(core, end)
            return None
        return end


class RoundRobin(FIFOPreempt):
    name = "rr"

    def __init__(self, quantum_ms: float = 24.0, **kw):
        super().__init__(quantum_ms=quantum_ms, **kw)


class CFS(Scheduler):
    """Completely Fair Scheduler model.

    Each core keeps a vruntime-ordered runqueue. The slice granted to the
    picked task is max(sched_latency / nr_running, min_granularity); on
    expiry the task's vruntime advances by the executed time and it is
    reinserted. New tasks are placed on the least-loaded core and start at
    that core's min_vruntime (so they neither starve nor dominate).
    """

    name = "cfs"
    _has_ff = True
    # See HybridScheduler._ff_solo_only: subclasses whose on_chunk_limit
    # does extra work only when the runqueue is non-empty set this to
    # keep the analytic fast-forward on lone-task cores only.
    _ff_solo_only = False

    def __init__(self, sched_latency_ms: float = 24.0,
                 min_granularity_ms: float = 3.0, **kw):
        super().__init__(**kw)
        self.sched_latency_ms = sched_latency_ms
        self.min_granularity_ms = min_granularity_ms
        self._rr = 0

    # -- placement ------------------------------------------------------
    def _least_loaded(self) -> Core:
        best, best_nr = None, None
        n = self.n_cores
        start = self._rr
        self._rr = (self._rr + 1) % n
        for i in range(n):
            core = self.cores[(start + i) % n]
            nr = core.nr_running
            if nr == 0 and core.task is None:
                return core
            if best_nr is None or nr < best_nr:
                best, best_nr = core, nr
        return best

    def on_arrival(self, task: Task, t: float) -> None:
        core = self._least_loaded()
        task.vruntime = max(task.vruntime, core.min_vruntime)
        core.rq_push(task)
        self.kick(core, t)

    def slice_for(self, core: Core) -> float:
        return cfs_slice_ms(core.nr_running, self.sched_latency_ms,
                            self.min_granularity_ms)

    def pick_next(self, core: Core, t: float):
        if core.rq:
            task = core.rq_pop()
            return task, self.slice_for(core)
        return None

    def on_chunk_limit(self, core: Core, task: Task, t: float) -> None:
        task.vruntime += core.chunk_len
        task.preemptions += 1
        core.preempt_count += 1
        core.rq_push(task)

    def fast_forward(self, core: Core, end: float, hz: float) -> float:
        return cfs_fast_forward(self, core, end, hz)


class EDF(Scheduler):
    """Preemptive earliest-deadline-first with a centralized queue.

    Deadlines are SLO-style: arrival + slack_factor * expected service
    (set by the workload generator). An arrival with an earlier deadline
    preempts the running task with the latest deadline.
    """

    name = "edf"

    def __init__(self, **kw):
        super().__init__(**kw)
        import heapq
        self._heapq = heapq
        self.queue: list = []
        self._qseq = 0

    def _qpush(self, task: Task) -> None:
        self._heapq.heappush(self.queue, (task.deadline, self._qseq, task))
        self._qseq += 1

    def global_queue_len(self) -> int:
        return len(self.queue)

    def on_arrival(self, task: Task, t: float) -> None:
        core = self.idle_core()
        if core is not None:
            self._qpush(task)
            self.dispatch(core, t)
            return
        # No idle core: consider preempting the latest-deadline running task.
        victim_core, victim_dl = None, task.deadline
        for core in self.cores:
            if core.task is not None and core.task.deadline > victim_dl:
                victim_core, victim_dl = core, core.task.deadline
        self._qpush(task)
        if victim_core is not None:
            victim = self._interrupt(victim_core, t)
            if victim.completion is None:
                victim.preemptions += 1
                victim_core.preempt_count += 1
                self._qpush(victim)
            self.dispatch(victim_core, t)

    def pick_next(self, core: Core, t: float):
        if self.queue:
            _, _, task = self._heapq.heappop(self.queue)
            return task, None
        return None

    def on_chunk_limit(self, core: Core, task: Task, t: float) -> None:
        raise AssertionError("EDF chunks run to completion unless preempted")
