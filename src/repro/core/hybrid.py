"""The paper's contribution: hybrid two-group FIFO+CFS scheduling (Sec. IV).

* Cores are split into a FIFO group (centralized global queue; tasks run
  WITHOUT preemption until a time limit) and a CFS group (per-core
  vruntime queues). Tasks that exceed the time limit are preempted and
  migrated round-robin onto the CFS cores (Fig. 7).
* ``TimeLimitAdapter`` keeps the most recent 100 task durations and sets
  the limit to a configurable percentile (Sec. IV-B, Fig. 15-17). The
  percentile window is maintained incrementally (mirrored sorted list +
  cached value), so ``limit()`` — called on every FIFO dispatch — is
  O(1) instead of a sort per call.
* ``Rightsizer`` monitors per-group utilization over a window and migrates
  one core from the hot group to the cold group when the imbalance
  exceeds a threshold, following the Lock / Preempt / Migrate /
  Transition / Unlock protocol of Fig. 8.

Group membership is tracked in maintained per-group core lists (cid
order, matching the historical filtered-list scans) so the arrival path
and heartbeat snapshots stop rescanning every core; rightsizer
migrations go through :meth:`HybridScheduler._set_group`.
"""
from __future__ import annotations

import heapq
from bisect import bisect_left, insort
from collections import deque
from typing import Optional

from .events import (_EPS, _INF, GROUP_CFS, GROUP_FIFO, Core, Scheduler,
                     Task, cfs_fast_forward, cfs_slice_ms, fifo_budget_ms)


def percentile(sorted_vals: list[float], pct: float) -> float:
    """Linear-interpolated percentile of a pre-sorted list."""
    if not sorted_vals:
        raise ValueError("empty window")
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    rank = (pct / 100.0) * (len(sorted_vals) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = rank - lo
    return sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac


class TimeLimitAdapter:
    """Sliding window (most recent ``window`` durations) percentile limit.

    The window deque is mirrored into an incrementally maintained sorted
    list: applying a sample does one bisect-remove + one insort, and
    ``limit`` interpolates the cached percentile without sorting — the
    historical implementation re-sorted the window on every call, on
    both the per-completion and per-dispatch hot paths.

    Batched observations (DESIGN.md Sec. 13): the engine's completion
    batches retire tasks per core, possibly out of global time order.
    :meth:`observe` BUFFERS a sample keyed ``(t, tid)``; samples enter
    the window at the next flush — any ``limit``/``record`` at or
    after their instant — in canonical time order. The window update
    is therefore permutation-invariant within a batch: whichever
    per-core batch ran first, the window (and the cached percentile
    every FIFO dispatch reads) evolves identically, with same-instant
    ties resolved by tid and buffered samples at instant ``t``
    applying before any same-instant read.

    ``record_series=True`` (opt-in) retains the full ``(t, limit)``
    trajectory for figure generation (appended at flush time, i.e. in
    canonical sample order). Left off (the default), a long
    heavy-traffic run holds only the fixed-size window instead of
    growing one tuple per completion forever.
    """

    def __init__(self, pct: float = 95.0, window: int = 100,
                 initial_ms: float = 1633.0, record_series: bool = False):
        self.pct = pct
        self.window: deque[float] = deque(maxlen=window)
        self.initial_ms = initial_ms
        self.record_series = record_series
        self.series: list[tuple[float, float]] = []
        self._sorted: list[float] = []
        self._cached: Optional[float] = None
        self._pending: list[tuple[float, int, float]] = []  # (t, tid, dur)

    def _apply(self, duration_ms: float, now: float) -> None:
        w = self.window
        if len(w) == w.maxlen:
            # deque(maxlen) is about to drop the oldest sample; drop its
            # mirror entry (bisect finds an equal value, which is all
            # the percentile cares about).
            del self._sorted[bisect_left(self._sorted, w[0])]
        w.append(duration_ms)
        insort(self._sorted, duration_ms)
        self._cached = None
        if self.record_series:
            self.series.append((now, self._limit_value()))

    def observe(self, duration_ms: float, now: float, tid: int) -> None:
        """Batch entry point: buffer one completion's duration; it
        enters the window at the next flush at/after ``now``."""
        heapq.heappush(self._pending, (now, tid, duration_ms))

    def flush(self, upto: Optional[float] = None) -> None:
        """Apply buffered samples with t <= ``upto`` (all, if None) in
        canonical (t, tid) order."""
        pending = self._pending
        while pending and (upto is None or pending[0][0] <= upto):
            t, _tid, dur = heapq.heappop(pending)
            self._apply(dur, t)

    def record(self, duration_ms: float, now: float) -> None:
        """Immediate-path record: flushes due buffered samples first so
        the window stays in canonical time order."""
        self.flush(now)
        self._apply(duration_ms, now)

    def _limit_value(self) -> float:
        if not self._sorted:
            return self.initial_ms
        if self._cached is None:
            self._cached = percentile(self._sorted, self.pct)
        return self._cached

    def limit(self, now: Optional[float] = None) -> float:
        self.flush(now)
        return self._limit_value()


class Rightsizer:
    """Utilization-driven core migration between the two groups."""

    def __init__(self, interval_ms: float = 1000.0, threshold: float = 0.15,
                 min_group: int = 1, lock_ms: float = 1.0):
        self.interval_ms = interval_ms
        self.threshold = threshold
        self.min_group = min_group
        self.lock_ms = lock_ms
        self.migrations: list[tuple[float, int, int]] = []  # (t, from, to)


class HybridScheduler(Scheduler):
    """FIFO+CFS two-group scheduler (the paper's design, Fig. 7/8)."""

    name = "hybrid"
    _has_ff = True
    # A FIFO-group chunk expiry can migrate its over-limit task into
    # any CFS core's runqueue (reading that core's min_vruntime), so
    # FIFO-group expiries are fast-forward barriers for the CFS group.
    _barrier_groups = frozenset({GROUP_FIFO})
    # Subclasses that override on_chunk_limit with extra bookkeeping
    # when a CFS-group slice expires with a NON-empty runqueue (e.g. the
    # serving gateway's KV-swap penalty) must set this, restricting the
    # analytic fast-forward to lone-task cores where their override is
    # a no-op. Overrides that also act on empty-runqueue expiries must
    # disable the fast-forward entirely (_has_ff = False).
    _ff_solo_only = False

    def __init__(
        self,
        n_fifo: Optional[int] = None,
        time_limit_ms: float = 1633.0,
        adapter: Optional[TimeLimitAdapter] = None,
        rightsizer: Optional[Rightsizer] = None,
        sched_latency_ms: float = 24.0,
        min_granularity_ms: float = 3.0,
        **kw,
    ):
        super().__init__(**kw)
        if n_fifo is None:
            n_fifo = self.n_cores // 2      # paper's best split (Fig. 11)
        assert 1 <= n_fifo < self.n_cores, "need at least one core per group"
        self.static_limit_ms = time_limit_ms
        self.adapter = adapter
        self.rightsizer = rightsizer
        self.sched_latency_ms = sched_latency_ms
        self.min_granularity_ms = min_granularity_ms
        self.fifo_queue: deque[Task] = deque()
        self._fifo_requeued = False  # degenerate requeue seen: see below
        self._groups: dict[int, list[Core]] = {GROUP_FIFO: [], GROUP_CFS: []}
        for i, core in enumerate(self.cores):
            core.group = GROUP_FIFO if i < n_fifo else GROUP_CFS
            self._groups[core.group].append(core)
        self._rr_cfs = 0

    # -- group views -----------------------------------------------------
    #
    # Maintained lists in cid order — the same order the historical
    # [c for c in cores if c.group == g] rescans produced, which the
    # idle-core scan and the round-robin migration target index rely on.
    # Treat as read-only; membership changes go through _set_group.
    @property
    def fifo_cores(self) -> list[Core]:
        return self._groups[GROUP_FIFO]

    @property
    def cfs_cores(self) -> list[Core]:
        return self._groups[GROUP_CFS]

    def _set_group(self, core: Core, group: int) -> None:
        self._groups[core.group].remove(core)
        core.group = group
        lst = self._groups[group]
        for i, c in enumerate(lst):
            if c.cid > core.cid:
                lst.insert(i, core)
                return
        lst.append(core)

    def time_limit(self, t: Optional[float] = None) -> float:
        if self.adapter is not None:
            # Flush buffered completion samples due at t so the limit
            # reflects every completion before this instant, whatever
            # batch produced them (None: flush all — end-of-run reads).
            return self.adapter.limit(t)
        return self.static_limit_ms

    def global_queue_len(self) -> int:
        return len(self.fifo_queue)

    def has_idle_core(self) -> bool:
        # New arrivals enter through the FIFO group (Fig. 7): an idle
        # CFS core cannot start them, so it must not make the node look
        # "idle" to a pull-based cluster dispatcher.
        return self.idle_core(self.fifo_cores) is not None

    # -- event hooks -------------------------------------------------------
    def on_start(self) -> None:
        if self.rightsizer is not None:
            self._push(self.rightsizer.interval_ms, 2, "rightsize")

    def on_arrival(self, task: Task, t: float) -> None:
        # New tasks always enter the FIFO group's global queue (Fig. 7).
        self.fifo_queue.append(task)
        core = self.idle_core(self.fifo_cores)
        if core is not None:
            self.dispatch(core, t)

    def pick_next(self, core: Core, t: float):
        if core.group == GROUP_FIFO:
            if self.fifo_queue:
                task = self.fifo_queue.popleft()
                # Remaining budget before this task must migrate to CFS.
                budget = fifo_budget_ms(self.time_limit(t), task.cpu_time)
                return task, budget
            return None
        if core.rq:
            task = core.rq_pop()
            return task, self._cfs_slice(core)
        return None

    def _cfs_slice(self, core: Core) -> float:
        return cfs_slice_ms(core.nr_running, self.sched_latency_ms,
                            self.min_granularity_ms)

    # -- fast-forward (DESIGN.md Sec. 13) ---------------------------------
    #
    # The only way the FIFO group reaches a CFS core is a budget-expiry
    # migration, and the global queue holds only FRESH tasks (cpu_time
    # 0: over-limit tasks migrate to CFS, never back — _migrate_to_cfs's
    # degenerate no-CFS-cores fallback would break that and trips
    # _fifo_requeued, conservatively disabling the relaxations). So
    # with a STATIC limit, nothing a completing FIFO chunk (or a
    # pending arrival) leads to can touch a CFS core earlier than its
    # own instant plus the full static budget every fresh pick gets —
    # CFS batches may run deep into the FIFO group's completion churn
    # and the arrival stream. With the adapter the budget at a future
    # pick is unknowable at push time: fall back to the chunk's own
    # expiry (the pre-batching conservative barrier).
    def _chunk_barrier(self, core: Core, end: float):
        if core.group != GROUP_FIFO:
            return None
        if core.task.remaining - core.chunk_len > _EPS:
            return end               # budget expiry: migrates AT end
        if self.adapter is not None or self._fifo_requeued:
            return end
        return end + self.static_limit_ms

    def _arrival_barrier_offset(self, core: Core) -> float:
        if core.group == GROUP_FIFO:
            return 0.0               # arrival may dispatch this core now
        if self.adapter is not None or self._fifo_requeued:
            return 0.0
        return self.static_limit_ms

    def fast_forward(self, core: Core, end: float, hz: float):
        if core.group != GROUP_CFS:
            return self._fifo_chain_ff(core, end, hz)
        return cfs_fast_forward(self, core, end, hz)

    def _fifo_chain_ff(self, core: Core, end: float, hz: float):
        """Budget-chunk chain on a FIFO-group core: retire a run of
        run-to-completion chunks (queued tasks whose remaining service
        fits their budget) without heap traffic.

        Sound only when a chunk's bookkeeping cannot read state that
        another core's pending event might change first: a STATIC time
        limit (with the adapter, budgets read the completion-ordered
        percentile window at pick time — and other cores' not-yet-run
        batches may still owe samples from earlier instants) and no
        container pool (every FIFO pick is a first dispatch, whose
        acquire must serialize). Bounded by the HEAP TOP, not the
        barrier heap: any other core's chunk end may pop the shared
        global queue, so the chain stops strictly before every pending
        event."""
        if (self.adapter is not None or self.containers is not None
                or not self._batch_complete):
            return end
        task = core.task
        if task.remaining - core.chunk_len > _EPS:
            return end               # budget-limited: expiry migrates
        nxt = self.heap[0][0] if self.heap else _INF
        eps = _EPS
        ctx_ms = self.ctx_switch_ms
        queue = self.fifo_queue
        limit = self.static_limit_ms
        while True:
            if not (end < nxt and end <= hz):
                return end           # engine path processes the expiry
            self._retire_completion(core, end)
            if end < core.locked_until:
                return None          # unlock timer will dispatch
            if not queue:
                return None          # core idles at `end`
            # -- pick_next (FIFO branch), replicated ------------------
            ntask = queue.popleft()
            budget = fifo_budget_ms(limit, ntask.cpu_time)
            ctx = ctx_ms if core.last_task is not ntask else 0.0
            if ntask.first_run is None:
                ntask.first_run = end    # no pool: core-local stamp
            rem = ntask.remaining
            run = rem if rem < budget else budget
            if run < eps:
                run = eps
            core.task = ntask
            core.chunk_start = end
            core.chunk_work_start = end + ctx
            core.chunk_len = run
            core.chunk_rate = 1.0
            if ctx > 0.0:
                ntask.ctx_switches += 1
                self.total_ctx += 1
            end = (end + ctx) + run  # same ops as _start_chunk, rate 1
            if rem - run > eps:
                # Budget-limited chunk: its expiry migrates the task
                # into a CFS runqueue — through the heap, with a
                # barrier (_chunk_interacts), in exact time order.
                return end

    def on_chunk_limit(self, core: Core, task: Task, t: float) -> None:
        if core.group == GROUP_FIFO:
            # Time limit hit: preempt and migrate to a CFS core (round
            # robin distribution over per-core queues, Sec. IV-A).
            task.preemptions += 1
            task.migrations += 1
            core.preempt_count += 1
            self._migrate_to_cfs(task, t)
        else:
            task.vruntime += core.chunk_len
            task.preemptions += 1
            core.preempt_count += 1
            core.rq_push(task)

    def _migrate_to_cfs(self, task: Task, t: float) -> None:
        cfs = self.cfs_cores
        if not cfs:  # degenerate (rightsizer keeps >=1, but be safe)
            # A partially-run task in the global queue voids the
            # fresh-tasks-only premise behind the relaxed barriers.
            self._fifo_requeued = True
            self.fifo_queue.append(task)
            return
        target = cfs[self._rr_cfs % len(cfs)]
        self._rr_cfs += 1
        task.vruntime = max(task.vruntime, target.min_vruntime)
        target.rq_push(task)
        self.kick(target, t)

    def on_complete(self, task: Task, t: float) -> None:
        if self.adapter is not None:
            # Buffered: completion batches may deliver these out of
            # global time order; the adapter re-serializes at the next
            # limit() read (canonical (t, tid) order).
            self.adapter.observe(task.execution, t, task.tid)

    # -- rightsizing ---------------------------------------------------------
    def on_timer(self, payload, t: float) -> None:
        if payload == "rightsize":
            self._rightsize(t)
            self._reschedule_timer("rightsize", self.rightsizer.interval_ms)
            return
        if isinstance(payload, tuple) and payload[0] == "unlock":
            self.dispatch(payload[1], t)
            return
        super().on_timer(payload, t)

    def _group_util(self, cores: list[Core], t: float, window: float) -> float:
        if not cores:
            return 0.0
        acc = 0.0
        for core in cores:
            acc += core.busy_total(t) - getattr(core, "_rs_snap", 0.0)
        return acc / (len(cores) * window)

    def _rightsize(self, t: float) -> None:
        rs = self.rightsizer
        window = rs.interval_ms
        fifo, cfs = self.fifo_cores, self.cfs_cores
        u_fifo = self._group_util(fifo, t, window)
        u_cfs = self._group_util(cfs, t, window)
        n_fifo, n_cfs = len(fifo), len(cfs)
        for core in self.cores:
            core._rs_snap = core.busy_total(t)  # type: ignore[attr-defined]
        if abs(u_fifo - u_cfs) <= rs.threshold:
            return
        if u_fifo > u_cfs and n_cfs > rs.min_group:
            self._migrate_core_cfs_to_fifo(t)
            rs.migrations.append((t, GROUP_CFS, GROUP_FIFO))
        elif u_cfs > u_fifo and n_fifo > rs.min_group:
            self._migrate_core_fifo_to_cfs(t)
            rs.migrations.append((t, GROUP_FIFO, GROUP_CFS))

    def _migrate_core_cfs_to_fifo(self, t: float) -> None:
        """Fig. 8 protocol: lock, preempt, migrate queue, transition, unlock."""
        cfs = self.cfs_cores
        # Pick the CFS core with the shortest queue to disturb least.
        core = min(cfs, key=lambda c: c.nr_running)
        rest = [c for c in cfs if c is not core]
        if not rest:
            return
        # Lock: no new tasks during the transition.
        core.locked_until = t + self.rightsizer.lock_ms
        # Preempt the running task into another CFS core's queue.
        if core.task is not None:
            task = self._interrupt(core, t)
            if task.completion is None:
                task.preemptions += 1
                core.preempt_count += 1
                tgt = min(rest, key=lambda c: c.nr_running)
                task.vruntime = max(task.vruntime, tgt.min_vruntime)
                tgt.rq_push(task)
                self.kick(tgt, t)
        # Migrate queued tasks to the remaining CFS cores (balance sizes).
        while core.rq:
            task = core.rq_pop()
            tgt = min(rest, key=lambda c: c.nr_running)
            tgt.rq_push(task)
            self.kick(tgt, t)
        # Transition + unlock (dispatch after the lock expires).
        self._set_group(core, GROUP_FIFO)
        self._push(core.locked_until, 2, ("unlock", core))

    def _migrate_core_fifo_to_cfs(self, t: float) -> None:
        fifo = self.fifo_cores
        core = min(fifo, key=lambda c: 0 if c.task is None else 1)
        self._set_group(core, GROUP_CFS)
        # A running FIFO task keeps its CPU but is re-chunked under CFS
        # rules (it will be preempted "when we schedule a new task", which
        # under CFS means at its next slice boundary).
        if core.task is not None:
            task = self._interrupt(core, t)
            if task.completion is None:
                task.vruntime = max(task.vruntime, core.min_vruntime)
                core.rq_push(task)
        # Steal tasks from the most loaded CFS cores to balance queues.
        others = [c for c in self.cfs_cores if c is not core]
        if others:
            total = sum(c.nr_running for c in others)
            target_len = total // (len(others) + 1)
            donor = max(others, key=lambda c: c.nr_running)
            while donor.rq and len(core.rq) < target_len:
                task = donor.rq_pop()
                core.rq_push(task)
                donor = max(others, key=lambda c: c.nr_running)
        self.dispatch(core, t)
