"""Container/sandbox lifecycle layer (DESIGN.md Sec. 9).

The paper bills users for wall-clock execution, so every millisecond a
sandbox spends initializing is money — yet a scheduler-only simulation
materializes invocations out of thin air. This module gives every
invocation a cold/warm path: a per-node :class:`ContainerPool` keyed by
``func_id`` holds warm *idle* sandboxes (memory-bounded), evicts them on
keep-alive expiry, and charges a cold-start delay (sampled per memory
size) to invocations that miss.

Keep-alive policies:

``fixed``      -- constant TTL per container (OpenWhisk-style).
``histogram``  -- Azure-style (Shahrad et al., "Serverless in the Wild"):
                  per-function keep-alive derived from the observed
                  inter-arrival-time distribution, so a function invoked
                  every 2 s is kept warm ~2.5 s while a once-a-minute
                  function does not pin memory for the full minute.
                  ``prewarm`` hints (``traces.workload.keepalive_hints``)
                  seed the per-function estimate before enough arrivals
                  have been observed.

Accounting is exact per container: a sandbox contributes
``mem_mb x idle-duration`` to ``warm_mb_ms`` only while it is actually
held (TTL evictions stop the meter at the expiry instant, even when the
reaper notices later), which is what the provider-side memory-hold cost
in :mod:`repro.core.cost` integrates.

Deferred releases (DESIGN.md Sec. 13): the engine's completion batches
hand sandboxes back via :meth:`release_at`, which BUFFERS the release
keyed by ``(t, func_id, tid)``. Every pool read or mutation first
drains the buffer up to its own instant, so the pool always applies
releases in canonical time order no matter which per-core batch
produced them first — release/acquire effects commute within a
same-instant batch, with ties resolved by (func_id, tid) instead of
call order, and buffered effects at instant ``t`` apply before any
same-instant read.

Running containers are not tracked here: a running invocation's memory
is accounted by the billing model; the pool bounds only the *idle* warm
set a provider keeps speculatively.
"""
from __future__ import annotations

import heapq
import math
import random
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Optional

# Cold-start model defaults: Firecracker-class base boot plus a
# per-GB image/runtime initialization slope (bigger functions ship
# bigger runtimes), with lognormal jitter.
COLD_BASE_MS = 125.0
COLD_PER_GB_MS = 250.0
COLD_JITTER_SIGMA = 0.25


def expected_cold_ms(mem_mb: float,
                     base_ms: float = COLD_BASE_MS,
                     per_gb_ms: float = COLD_PER_GB_MS) -> float:
    """Mean cold-start delay for a memory size (no jitter) — what a
    cost-aware dispatcher uses to price a cold route."""
    return base_ms + per_gb_ms * (mem_mb / 1024.0)


def _pct(sorted_vals: list[float], pct: float) -> float:
    """Linear-interpolated percentile of a pre-sorted list (local copy:
    importing hybrid.percentile here would cycle events->containers)."""
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    rank = (pct / 100.0) * (len(sorted_vals) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = rank - lo
    return sorted_vals[lo] * (1 - frac) + sorted_vals[hi] * frac


@dataclass(frozen=True)
class ContainerConfig:
    """Per-node sandbox-pool knobs (picklable: sweep cells carry one)."""

    capacity_mb: float = 4096.0       # memory reserved for idle warm set
    policy: str = "fixed"             # "fixed" | "histogram"
    keepalive_ms: float = 30_000.0    # fixed TTL / histogram fallback
    sweep_ms: float = 1_000.0         # reaper timer period (0 = lazy only)
    cold_base_ms: float = COLD_BASE_MS
    cold_per_gb_ms: float = COLD_PER_GB_MS
    cold_jitter: float = COLD_JITTER_SIGMA
    hist_pct: float = 99.0            # keep-alive = pct of observed IATs
    hist_margin: float = 1.25         # x safety margin over that pct
    hist_window: int = 64             # IAT observations kept per function
    hist_min_ms: float = 2_000.0
    hist_max_ms: float = 120_000.0
    prewarm: Optional[dict] = None    # func_id -> keep-alive hint (ms)
    # Per-function sandbox cap for SLOT-TRACKED dispatch (request_slot/
    # release_slot): at most this many invocations of one func_id hold
    # a sandbox at once; excess dispatches queue FIFO. None = no cap —
    # and the legacy acquire/release path never checks it.
    max_concurrency: Optional[int] = None


class _Warm:
    """One idle warm sandbox.

    ``live`` is the lazy-deletion flag for the capacity-eviction heap:
    acquiring or reaping a container just clears it, and the stale heap
    entry is skipped when it surfaces. ``seq`` is the release order,
    the heap's final tie-breaker (matching the historical append-order
    pop within a bucket)."""

    __slots__ = ("func_id", "mem_mb", "idle_since", "expires_at", "live",
                 "seq")

    def __init__(self, func_id: int, mem_mb: float, idle_since: float,
                 expires_at: float, seq: int = 0):
        self.func_id = func_id
        self.mem_mb = mem_mb
        self.idle_since = idle_since
        self.expires_at = expires_at
        self.live = True
        self.seq = seq


class ContainerPool:
    """Per-node warm-sandbox pool keyed by ``func_id``.

    Invariants (property-tested):

    * the idle warm set never exceeds ``capacity_mb``;
    * ``acquire`` never returns a warm hit for a container whose
      keep-alive expired at or before ``now``;
    * given the same seed and operation sequence, hits/misses, evictions
      and sampled cold-start delays are bit-identical.
    """

    def __init__(self, config: Optional[ContainerConfig] = None, *,
                 seed: int = 0, **overrides):
        if config is None:
            config = ContainerConfig(**overrides)
        elif overrides:
            raise TypeError("pass either a config or keyword overrides")
        self.cfg = config
        self.seed = seed
        self._rng = random.Random(seed ^ 0x5EED)
        self._idle: dict[int, list[_Warm]] = {}  # append-ordered by idle_since
        self.idle_mb = 0.0
        # Incremental eviction machinery (DESIGN.md Sec. 13): the
        # capacity victim — min (idle_since, func_id) over every idle
        # container — comes from a lazy-deletion heap instead of a
        # min() scan over all buckets per eviction, and the TTL reaper
        # skips its full walk entirely while nothing can have expired.
        self._cap_heap: list[tuple[float, int, int, _Warm]] = []
        self._cap_seq = 0
        self._n_idle = 0
        self._min_expiry = float("inf")
        # Deferred releases from engine completion batches: a heap of
        # (t, func_id, tid, mem_mb), drained in canonical time order
        # before any read/mutation at or after t.
        self._pending: list[tuple[float, int, int, float]] = []
        # Per-function concurrency limiting (request_slot/release_slot):
        # slots currently held per func_id, and FIFO queues of
        # (tid, mem_mb) dispatches waiting for one.
        self._running: dict[int, int] = {}
        self._waiters: dict[int, deque] = {}
        # histogram policy state
        self._last_seen: dict[int, float] = {}
        self._iat: dict[int, deque] = {}
        # counters
        self.warm_hits = 0
        self.cold_starts = 0
        self.evictions_ttl = 0
        self.evictions_capacity = 0
        self.evictions_flush = 0  # live sandboxes destroyed by flush()
        self.dropped = 0          # releases larger than the whole pool
        self.prewarmed = 0        # sandboxes provisioned speculatively
        self.warm_mb_ms = 0.0     # integral of idle warm memory over time
        self.n_draws = 0          # cold-start RNG draw counter (stream index)
        self.queued_concurrency = 0   # dispatches deferred by the cap
        self.granted_from_queue = 0   # queued dispatches later admitted

    # -- internal -----------------------------------------------------------
    def _flush(self, upto: float = float("inf")) -> None:
        """Apply buffered releases with timestamp <= ``upto`` in
        canonical (t, func_id, tid) order. Entries AT ``upto`` apply
        before the caller's own operation (same-instant releases are
        visible to a same-instant acquire — the canonical tie rule)."""
        pending = self._pending
        while pending and pending[0][0] <= upto:
            t, fid, _tid, mem = heapq.heappop(pending)
            self.release(fid, mem, t)

    def _maybe_compact(self) -> None:
        # Compact the lazy capacity heap when tombstones exceed half of
        # it, so a long heavy-traffic run cannot accumulate one stale
        # entry per completed invocation (acquires and reaps only
        # tombstone; they never shrink the heap).
        if len(self._cap_heap) > 64 and \
                len(self._cap_heap) > 2 * self._n_idle:
            self._rebuild_cap_heap()

    def _retire(self, c: _Warm, end: float) -> None:
        """Stop the memory meter for one container and drop it. The
        capacity-heap entry is tombstoned (live=False), not searched."""
        self.idle_mb -= c.mem_mb
        self.warm_mb_ms += max(0.0, end - c.idle_since) * c.mem_mb
        c.live = False
        self._n_idle -= 1

    def _keepalive_for(self, func_id: int, now: float) -> float:
        cfg = self.cfg
        if cfg.policy != "histogram":
            return cfg.keepalive_ms
        hint = (cfg.prewarm or {}).get(func_id)
        iats = self._iat.get(func_id)
        if iats is not None and len(iats) >= 3:
            ka = _pct(sorted(iats), cfg.hist_pct) * cfg.hist_margin
        elif hint is not None:
            ka = hint
        else:
            ka = cfg.keepalive_ms
        return min(max(ka, cfg.hist_min_ms), cfg.hist_max_ms)

    def _observe(self, func_id: int, now: float) -> None:
        last = self._last_seen.get(func_id)
        if last is not None and now > last:
            self._iat.setdefault(
                func_id, deque(maxlen=self.cfg.hist_window)).append(now - last)
        self._last_seen[func_id] = now

    def _evict_oldest(self, now: float) -> None:
        # Lazy-deletion pop: the heap orders by (idle_since, func_id,
        # release seq), which selects exactly the container the
        # historical min-over-buckets scan (then bucket-head pop) chose.
        heap = self._cap_heap
        while True:
            _, fid, _, c = heapq.heappop(heap)
            if c.live:
                break
        q = self._idle[fid]
        if q[0] is c:
            q.pop(0)
        else:  # unreachable while release times are monotone; stay safe
            q.remove(c)
        if not q:
            del self._idle[fid]
        self._retire(c, now)
        self.evictions_capacity += 1

    def _rebuild_cap_heap(self) -> None:
        self._cap_heap = [(c.idle_since, fid, c.seq, c)
                          for fid, q in self._idle.items() for c in q]
        heapq.heapify(self._cap_heap)

    # -- lifecycle ----------------------------------------------------------
    def acquire(self, func_id: int, mem_mb: float, now: float) -> bool:
        """Claim a warm sandbox sized ``mem_mb`` for an invocation
        starting at ``now``. Returns True on a warm hit (the container
        leaves the idle set); False means the caller pays a cold start.
        A sandbox only satisfies a same-size request — FaaS functions
        have a fixed memory config, but nothing here assumes it, and a
        1 GB invocation must not "reuse" a 128 MB sandbox for free."""
        self._flush(now)
        self._observe(func_id, now)
        q = self._idle.get(func_id)
        if q:
            # Lazily reap the bucket first (the meter stops at expiry
            # even when the periodic reaper hasn't swept yet).
            live = []
            for c in q:
                if c.expires_at <= now:
                    self._retire(c, c.expires_at)
                    self.evictions_ttl += 1
                else:
                    live.append(c)
            hit = None
            for idx in range(len(live) - 1, -1, -1):
                # most-recently-idled matching size: warmest caches
                if live[idx].mem_mb == mem_mb:
                    hit = live.pop(idx)
                    break
            if live:
                self._idle[func_id] = live
            else:
                del self._idle[func_id]
            if hit is not None:
                self._retire(hit, now)
                self.warm_hits += 1
                self._maybe_compact()
                return True
            self._maybe_compact()  # lazy reaps above tombstoned entries
        self.cold_starts += 1
        return False

    def release(self, func_id: int, mem_mb: float, now: float) -> None:
        """Return a finished invocation's sandbox to the warm set,
        evicting to stay within capacity. Reaping is lazy: only under
        capacity pressure (the meter stops at expiry regardless of when
        a sweep happens, so eager reaping buys no accounting accuracy
        on this per-completion hot path). Expired containers reap first
        — classified as TTL evictions — before any live one is
        sacrificed for capacity."""
        if mem_mb > self.cfg.capacity_mb:
            self.dropped += 1
            return
        if self.idle_mb + mem_mb > self.cfg.capacity_mb:
            self._evict_expired(now)
            while self.idle_mb + mem_mb > self.cfg.capacity_mb:
                self._evict_oldest(now)
        self._admit(func_id, mem_mb, now, self._keepalive_for(func_id, now))

    def _admit(self, func_id: int, mem_mb: float, now: float,
               keepalive_ms: float) -> None:
        """Insert one idle warm sandbox (shared by release and prewarm;
        the caller has already made room)."""
        expires = now + keepalive_ms
        c = _Warm(func_id, mem_mb, now, expires, seq=self._cap_seq)
        self._cap_seq += 1
        self._idle.setdefault(func_id, []).append(c)
        self.idle_mb += mem_mb
        self._n_idle += 1
        heapq.heappush(self._cap_heap, (now, func_id, c.seq, c))
        if expires < self._min_expiry:
            self._min_expiry = expires
        self._maybe_compact()

    def prewarm(self, func_id: int, mem_mb: float, now: float, n: int = 1,
                keepalive_ms: Optional[float] = None) -> int:
        """Provider-initiated speculative provisioning: place up to ``n``
        warm sandboxes for ``func_id`` in the idle set ahead of a
        predicted burst. Unlike ``release``, pre-warming never sacrifices
        an existing LIVE sandbox for room — an observed-warm container is
        evidence, a prediction is a bet — so provisioning stops once only
        live containers stand in the way (expired ones are reaped).
        Returns how many were actually placed. Pre-warmed sandboxes meter
        ``warm_mb_ms`` like any other idle container: prediction is not
        free, it is paid for in provider-side memory-hold dollars."""
        self._flush(now)
        placed = 0
        ka = keepalive_ms if keepalive_ms is not None \
            else self._keepalive_for(func_id, now)
        for _ in range(n):
            if self.idle_mb + mem_mb > self.cfg.capacity_mb:
                self._evict_expired(now)
                if self.idle_mb + mem_mb > self.cfg.capacity_mb:
                    break
            self._admit(func_id, mem_mb, now, ka)
            self.prewarmed += 1
            placed += 1
        return placed

    def flush(self, now: float) -> int:
        """Decommission the warm set (node removal / chaos kill / warm
        pool loss): every idle sandbox is destroyed at ``now``, with its
        memory meter stopped at ``min(expiry, now)`` — an already-expired
        container still counts as a TTL eviction, a live one as a flush
        eviction. Returns the number of LIVE sandboxes destroyed."""
        self._flush(now)
        n_live = 0
        for fid in list(self._idle):
            for c in self._idle.pop(fid):
                if c.expires_at <= now:
                    self._retire(c, c.expires_at)
                    self.evictions_ttl += 1
                else:
                    self._retire(c, now)
                    self.evictions_flush += 1
                    n_live += 1
        self._min_expiry = float("inf")
        self._maybe_compact()
        return n_live

    def release_at(self, func_id: int, mem_mb: float, now: float,
                   tid: int) -> None:
        """Buffered release, keyed (now, func_id, tid): the engine's
        completion batches retire tasks per core, possibly out of
        global time order; the buffer re-serializes their pool effects
        canonically at the next flush (any read or mutation at or
        after ``now``)."""
        heapq.heappush(self._pending, (now, func_id, tid, mem_mb))

    # -- per-function concurrency limits ------------------------------------
    def request_slot(self, func_id: int, mem_mb: float, now: float,
                     tid: int = -1, *, claim: bool = True) -> str:
        """Slot-tracked dispatch under ``cfg.max_concurrency``: claim a
        per-function sandbox slot and (on admission) a warm container.

        Returns ``"warm"`` (admitted, warm hit), ``"cold"`` (admitted,
        pays a cold start) or ``"queued"`` (the function already holds
        ``max_concurrency`` slots; the dispatch joins a FIFO queue and
        is granted by a later :meth:`release_slot` — the caller learns
        which via that call's return value, keyed by ``tid``).

        ``claim=False`` does SLOT ACCOUNTING ONLY — no warm container
        is acquired and ``"admitted"`` replaces the warm/cold verdict.
        This is the cluster-dispatch mode: the node's scheduler decides
        cold vs warm itself on the engine's first-dispatch path, and
        the slot layer must not consume the sandbox it will look for.

        With a fixed per-function memory size (the FaaS config model —
        see :meth:`acquire`), the cap bounds warm+running sandboxes of
        a slot-tracked function: at most ``max_concurrency`` slots run
        at once, every release returns at most one sandbox to the warm
        set, and a warm sandbox re-enters service only by converting
        back into a running slot. The legacy acquire/release path is
        untouched — callers opt into limiting by using the slot API.
        """
        cap = self.cfg.max_concurrency
        self._flush(now)
        if cap is not None and self._running.get(func_id, 0) >= cap:
            self._waiters.setdefault(func_id, deque()).append((tid, mem_mb))
            self.queued_concurrency += 1
            return "queued"
        self._running[func_id] = self._running.get(func_id, 0) + 1
        if not claim:
            return "admitted"
        return "warm" if self.acquire(func_id, mem_mb, now) else "cold"

    def release_slot(self, func_id: int, mem_mb: float, now: float, *,
                     keep_warm: bool = True,
                     claim: bool = True) -> list[tuple[int, str]]:
        """Finish a slot-tracked invocation: free its concurrency slot,
        return the sandbox to the warm set (unless ``keep_warm`` is
        False — crashed/decommissioned sandboxes free the slot only),
        then admit queued dispatches FIFO while slots remain. Returns
        the granted waiters as ``[(tid, "warm" | "cold"), ...]`` (at
        most one per release when a cap is set) so the caller can start
        them. With ``claim=False`` (cluster-dispatch mode, see
        :meth:`request_slot`) grants do not touch the warm set and
        report as ``"granted"``. Raises on a release without a
        matching request."""
        self._flush(now)
        n = self._running.get(func_id, 0)
        if n <= 0:
            raise ValueError(f"release_slot({func_id}) without a "
                             f"matching request_slot")
        if n == 1:
            del self._running[func_id]
        else:
            self._running[func_id] = n - 1
        if keep_warm:
            self.release(func_id, mem_mb, now)
        granted: list[tuple[int, str]] = []
        cap = self.cfg.max_concurrency
        w = self._waiters.get(func_id)
        while w and (cap is None or self._running.get(func_id, 0) < cap):
            tid, wmem = w.popleft()
            self._running[func_id] = self._running.get(func_id, 0) + 1
            self.granted_from_queue += 1
            if not claim:
                granted.append((tid, "granted"))
            else:
                granted.append(
                    (tid, "warm" if self.acquire(func_id, wmem, now)
                     else "cold"))
        if w is not None and not w:
            del self._waiters[func_id]
        return granted

    def drain_slots(self) -> list[int]:
        """Node decommission: forget all slot accounting. Running slots
        die with the machine (their invocations are requeued by the
        cluster layer) and queued waiters are STRANDED — their tids are
        returned so the caller can requeue the waiting dispatches
        through the front-end dispatcher instead of leaking them (a
        plain :meth:`flush` wipes the warm set but must NOT touch slot
        state: a warm-pool loss does not abort running invocations)."""
        stranded = [tid for q in self._waiters.values() for tid, _ in q]
        self._running.clear()
        self._waiters.clear()
        return stranded

    def running_counts(self) -> dict[int, int]:
        """func_id -> slot-tracked running invocations (nonzero only)."""
        return dict(self._running)

    def queue_depths(self) -> dict[int, int]:
        """func_id -> dispatches waiting on a concurrency slot."""
        return {fid: len(q) for fid, q in self._waiters.items()}

    def evict_expired(self, now: float) -> int:
        """Reap every container whose keep-alive lapsed; the memory
        meter stops at the expiry instant, not at ``now``."""
        self._flush(now)
        return self._evict_expired(now)

    def _evict_expired(self, now: float) -> int:
        """Reaper body (no flush: also runs from release under
        capacity pressure, including while the buffer itself is being
        flushed). O(1) while nothing can have expired: ``_min_expiry``
        lower-bounds every live keep-alive (conservatively — acquire
        may remove the minimum without raising it), so the common
        per-second sweep over a quiet pool skips the walk entirely."""
        if now < self._min_expiry:
            return 0
        n = 0
        nxt = float("inf")
        for fid in list(self._idle):
            q = self._idle[fid]
            keep = []
            for c in q:
                if c.expires_at <= now:
                    self._retire(c, c.expires_at)
                    self.evictions_ttl += 1
                    n += 1
                else:
                    keep.append(c)
                    if c.expires_at < nxt:
                        nxt = c.expires_at
            if keep:
                self._idle[fid] = keep
            else:
                del self._idle[fid]
        self._min_expiry = nxt
        self._maybe_compact()
        return n

    def settle(self, now: float) -> None:
        """Bring the memory-hold integral current (end-of-run, or before
        reading stats). Idempotent: still-idle containers re-anchor."""
        self.evict_expired(now)  # flushes deferred releases <= now first
        for q in self._idle.values():
            for c in q:
                self.warm_mb_ms += max(0.0, now - c.idle_since) * c.mem_mb
                c.idle_since = max(c.idle_since, now)
        # Re-anchoring changed the capacity-eviction keys; rebuild the
        # heap so later evictions keep selecting the same victim the
        # rescan implementation would.
        self._rebuild_cap_heap()

    # -- cold-start model ---------------------------------------------------
    def cold_start_ms(self, mem_mb: float) -> float:
        """Sample the init delay a cold invocation pays. Draw number
        ``n_draws`` of the pool's stream: cold starts happen on the
        engine's serialized first-dispatch path in canonical event
        order, so the counter indexes the stream reproducibly — a
        completion batch never draws (releases are draw-free), which is
        what keeps the stream identical however completions are
        batched (DESIGN.md Sec. 13)."""
        self.n_draws += 1
        m = expected_cold_ms(mem_mb, self.cfg.cold_base_ms,
                             self.cfg.cold_per_gb_ms)
        sigma = self.cfg.cold_jitter
        if sigma <= 0.0:
            return m
        return self._rng.lognormvariate(math.log(m) - 0.5 * sigma * sigma,
                                        sigma)

    # -- introspection ------------------------------------------------------
    def warm_counts(self, now: Optional[float] = None) -> dict[int, int]:
        """func_id -> number of idle warm sandboxes (heartbeat payload).
        Pass ``now`` to apply only deferred releases due by then;
        without it ALL are applied — only safe when the pool is
        quiescent or at a time past every buffered completion."""
        self._flush(float("inf") if now is None else now)
        return {fid: len(q) for fid, q in self._idle.items()}

    def live_view(self, now: float) -> tuple[dict[int, int], float]:
        """(warm counts, warm MB) counting only unexpired sandboxes —
        the heartbeat payload. Applies deferred releases due at
        ``now`` but never expires/evicts anything itself (this runs per
        node per routing decision)."""
        self._flush(now)
        counts: dict[int, int] = {}
        mb = 0.0
        for fid, q in self._idle.items():
            k = 0
            for c in q:
                if c.expires_at > now:
                    k += 1
                    mb += c.mem_mb
            if k:
                counts[fid] = k
        return counts, mb

    def has_warm(self, func_id: int, now: Optional[float] = None) -> bool:
        """See warm_counts: pass ``now`` unless the pool is quiescent."""
        self._flush(float("inf") if now is None else now)
        return bool(self._idle.get(func_id))

    def stats(self) -> dict:
        self._flush()
        total = self.warm_hits + self.cold_starts
        return {
            "warm_hits": self.warm_hits,
            "cold_starts": self.cold_starts,
            "cold_start_rate": (self.cold_starts / total) if total else 0.0,
            "evictions_ttl": self.evictions_ttl,
            "evictions_capacity": self.evictions_capacity,
            "evictions_flush": self.evictions_flush,
            "dropped": self.dropped,
            "prewarmed": self.prewarmed,
            "idle_mb": self.idle_mb,
            "warm_mb_ms": self.warm_mb_ms,
            "queued_concurrency": self.queued_concurrency,
            "granted_from_queue": self.granted_from_queue,
            "queue_depth": sum(len(q) for q in self._waiters.values()),
        }

    def check_invariants(self) -> None:
        """Raise if internal accounting drifted (test hook)."""
        self._flush()
        total = sum(c.mem_mb for q in self._idle.values() for c in q)
        assert abs(total - self.idle_mb) < 1e-6, \
            f"idle_mb gauge {self.idle_mb} != actual {total}"
        assert self.idle_mb <= self.cfg.capacity_mb + 1e-6, \
            f"warm set {self.idle_mb} MB over capacity {self.cfg.capacity_mb}"
        for q in self._idle.values():
            assert q, "empty per-function bucket left behind"
        live = {id(c) for q in self._idle.values() for c in q}
        heap_live = {id(e[3]) for e in self._cap_heap if e[3].live}
        assert live == heap_live, \
            "capacity heap out of sync with the idle set"
        assert self._n_idle == len(live), \
            f"_n_idle gauge {self._n_idle} != actual {len(live)}"
        # Tombstone bound: _maybe_compact caps the lazy heap at twice
        # the live count (above the 64-entry floor), so stale entries
        # cannot grow without bound in long heavy-traffic runs.
        assert len(self._cap_heap) <= max(64, 2 * self._n_idle), \
            (f"capacity heap {len(self._cap_heap)} entries for "
             f"{self._n_idle} live containers — compaction not firing")
        cap = self.cfg.max_concurrency
        assert all(n > 0 for n in self._running.values()), \
            "zero/negative slot count left in _running"
        if cap is None:
            assert not self._waiters, \
                "waiters queued with no concurrency cap configured"
        else:
            for fid, n in self._running.items():
                assert n <= cap, \
                    f"func {fid} holds {n} slots over cap {cap}"
            for fid, q in self._waiters.items():
                assert q, "empty waiter queue left behind"
                assert self._running.get(fid, 0) == cap, \
                    (f"func {fid} queues {len(q)} dispatches while "
                     f"holding only {self._running.get(fid, 0)}/{cap}")


# -- the ONE way to say "containers" ------------------------------------------

@dataclass(frozen=True)
class ContainerSpec:
    """Declarative sandbox-layer spec — the single currency for the
    ``containers=`` argument across every entrypoint (``Scenario``,
    ``Scheduler``, ``ClusterSim``, the serving gateway, sweep cells).

    Where :class:`ContainerConfig` is the pool's full knob set, a spec
    is the *intent*: which keep-alive policy, how much warm capacity,
    and optionally a cold-start cost model override (the LLM scenario
    prices cold = weight-load + compile through these three fields).
    ``None`` overrides inherit the ``ContainerConfig`` defaults.

    ``hints=True`` (histogram policy only) seeds per-function keep-alive
    hints from the workload's own inter-arrival distribution at run
    time — exactly what ``sweep._cell_containers`` historically did —
    which is why the workload-dependent conversion lives in
    :meth:`to_config` rather than in the frozen spec itself.
    """

    policy: str = "fixed"             # "off" | "fixed" | "histogram"
    capacity_mb: float = 4096.0
    keepalive_ms: float = 30_000.0
    hints: bool = True
    cold_base_ms: Optional[float] = None
    cold_per_gb_ms: Optional[float] = None
    cold_jitter: Optional[float] = None
    max_concurrency: Optional[int] = None   # per-function slot cap

    @property
    def enabled(self) -> bool:
        return self.policy != "off"

    @classmethod
    def from_legacy(cls, obj) -> "ContainerSpec | None":
        """Coerce every historical ``containers=`` shape to a spec.

        Accepts ``None`` (off), a policy-name string (``"off"`` /
        ``"fixed"`` / ``"histogram"`` — the sweep-cell encoding), a
        kwargs dict, a raw :class:`ContainerConfig`, or a spec.
        """
        if obj is None:
            return None
        if isinstance(obj, ContainerSpec):
            return obj
        if isinstance(obj, str):
            if obj not in ("off", "fixed", "histogram"):
                raise KeyError(f"unknown container policy {obj!r}")
            return None if obj == "off" else cls(policy=obj)
        if isinstance(obj, ContainerConfig):
            return cls(policy=obj.policy, capacity_mb=obj.capacity_mb,
                       keepalive_ms=obj.keepalive_ms,
                       hints=obj.prewarm is not None,
                       cold_base_ms=obj.cold_base_ms,
                       cold_per_gb_ms=obj.cold_per_gb_ms,
                       cold_jitter=obj.cold_jitter,
                       max_concurrency=obj.max_concurrency)
        if isinstance(obj, dict):
            return cls(**obj)
        raise TypeError(f"cannot build ContainerSpec from {type(obj)!r}")

    def to_config(self, tasks=None) -> Optional[ContainerConfig]:
        """Materialize the pool config. ``tasks`` (the workload about to
        run, post load-scaling) feeds histogram keep-alive hints when
        ``hints`` is set; without it the pool estimates online only."""
        if not self.enabled:
            return None
        overrides = {k: v for k, v in (
            ("cold_base_ms", self.cold_base_ms),
            ("cold_per_gb_ms", self.cold_per_gb_ms),
            ("cold_jitter", self.cold_jitter),
            ("max_concurrency", self.max_concurrency)) if v is not None}
        cfg = ContainerConfig(policy=self.policy,
                              capacity_mb=self.capacity_mb,
                              keepalive_ms=self.keepalive_ms, **overrides)
        if self.policy == "histogram" and self.hints and tasks is not None:
            from ..traces.workload import keepalive_hints
            cfg = replace(cfg, prewarm=keepalive_hints(tasks, cfg))
        return cfg


def as_container_config(obj, tasks=None) -> Optional[ContainerConfig]:
    """Normalize any accepted ``containers=`` value to a pool config.

    ``ContainerConfig`` instances pass through UNTOUCHED (legacy callers
    keep bit-identical behaviour); specs / dicts / policy strings are
    materialized via :meth:`ContainerSpec.to_config`.
    """
    if obj is None or isinstance(obj, (ContainerConfig, ContainerPool)):
        return obj
    spec = ContainerSpec.from_legacy(obj)
    return None if spec is None else spec.to_config(tasks)
