"""High-level entry point: run a named policy over a workload.

The microVM mode (paper Sec. VI-E, Fig. 21/22) models Firecracker:
per-invocation boot overhead, auxiliary VMM threads scheduled under the
same policy, a per-instance memory footprint, and admission failure when
the host memory is exhausted (the paper could launch at most 2,952
microVMs on a 512 GB box).
"""
from __future__ import annotations

import copy
import warnings
from typing import Optional

from .containers import ContainerConfig, ContainerPool
from .events import Scheduler, Task
from .hybrid import HybridScheduler, Rightsizer, TimeLimitAdapter
from .metrics import SimResult, collect
from .policies import CFS, EDF, FIFO, FIFOPreempt, RoundRobin

POLICIES = {
    "fifo": FIFO,
    "fifo_preempt": FIFOPreempt,
    "rr": RoundRobin,
    "cfs": CFS,
    "edf": EDF,
    "hybrid": HybridScheduler,
}


def make_scheduler(policy: str, **kw) -> Scheduler:
    if policy not in POLICIES:
        raise KeyError(f"unknown policy {policy!r}; have {sorted(POLICIES)}")
    return POLICIES[policy](**kw)


def execute_policy(policy: str, workload: list[Task], *,
                   n_cores: int = 50,
                   adapt_pct: Optional[float] = None,
                   rightsize: bool = False,
                   microvm: bool = False,
                   ghost_mode: bool = False,
                   containers: Optional[ContainerConfig] = None,
                   fresh_tasks: bool = True,
                   **kw) -> SimResult:
    """Simulate ``policy`` over ``workload`` and aggregate results —
    the single-node execution engine behind ``repro.run``.

    ``adapt_pct``/``rightsize`` only apply to the hybrid policy.
    ``ghost_mode`` enables the native-CFS spawn-storm interference model
    (DESIGN.md Sec. 8): the measured ghOSt system, not an ideal enclave.
    ``containers`` attaches the sandbox lifecycle layer (DESIGN.md
    Sec. 9): invocations take a cold/warm path through a per-node
    ``ContainerPool`` and cold starts occupy a core for their billed
    ``init_ms``. ``fresh_tasks`` deep-copies the workload so callers can
    reuse it.
    """
    tasks = copy.deepcopy(workload) if fresh_tasks else workload
    if containers is not None:
        kw.setdefault("containers", containers)
    if policy == "hybrid":
        if adapt_pct is not None:
            kw.setdefault("adapter", TimeLimitAdapter(pct=adapt_pct))
        if rightsize:
            kw.setdefault("rightsizer", Rightsizer())
    if ghost_mode:
        kw.setdefault("interference_fn",
                      spawn_storm_interference(workload, n_cores=n_cores))
    sched = make_scheduler(policy, n_cores=n_cores, **kw)
    if microvm:
        tasks = apply_microvm_model(tasks)
        tasks, failed = admit_microvm(tasks)
        sched.failed.extend(failed)
    sched.run(tasks)
    return collect(sched, policy)


def run_policy(policy: str, workload: list[Task], *,
               n_cores: int = 50,
               adapt_pct: Optional[float] = None,
               rightsize: bool = False,
               microvm: bool = False,
               ghost_mode: bool = False,
               containers: Optional[ContainerConfig] = None,
               fresh_tasks: bool = True,
               **kw) -> SimResult:
    """Deprecated: build a :class:`repro.Scenario` and call
    ``repro.run``. This shim routes through exactly that path (so its
    results stay bit-identical to the Scenario API) and will be removed
    after the deprecation window."""
    warnings.warn(
        "run_policy() is deprecated; use repro.run(Scenario(workload="
        "WorkloadSpec(kind='tasks', tasks=...), ...)) instead",
        DeprecationWarning, stacklevel=2)
    from ..scenario import (FleetSpec, PolicySpec, Scenario, WorkloadSpec,
                            run)
    sc = Scenario(
        workload=WorkloadSpec(kind="tasks", tasks=workload,
                              fresh=fresh_tasks),
        fleet=FleetSpec(n_nodes=1, cores_per_node=n_cores,
                        containers=containers),
        policy=PolicySpec(name=policy, adapt_pct=adapt_pct,
                          rightsize=rightsize, microvm=microvm,
                          ghost_mode=ghost_mode, kw=kw))
    return run(sc).raw


# -- ghOSt native-CFS interference model --------------------------------------
#
# ghOSt's scheduling class sits BELOW native CFS: any runnable native task
# on an enclave core starves the ghOSt task. Each invocation spawns as a
# native process and runs under native CFS until the workload generator
# pins its pid into the enclave (paper Fig. 9 step 4), so spawn storms
# steal enclave CPU. We model the stolen fraction per 1-second bin as
#   min(cap, arrivals_in_bin * pin_delay_ms / (n_cores * 1000)).

PIN_DELAY_MS = 400.0     # spawn -> enclave-pin latency under load
STEAL_CAP = 0.92


def spawn_storm_interference(workload: list[Task], n_cores: int = 50,
                             pin_delay_ms: float = PIN_DELAY_MS,
                             cap: float = STEAL_CAP):
    import numpy as np
    horizon = max(t.arrival for t in workload) + 1000.0
    nbins = int(horizon // 1000) + 2
    counts = np.zeros(nbins)
    for t in workload:
        counts[int(t.arrival // 1000)] += 1
    frac = np.minimum(cap, counts * pin_delay_ms / (n_cores * 1000.0))

    def fn(t_ms: float) -> float:
        b = int(t_ms // 1000)
        return float(frac[b]) if 0 <= b < nbins else 0.0

    return fn


# -- Firecracker microVM model (Sec. VI-E) -----------------------------------

MICROVM_BOOT_MS = 125.0          # Firecracker boot + guest kernel
MICROVM_VMM_OVERHEAD = 0.10      # VMM/vCPU emulation tax on service time
MICROVM_FOOTPRINT_MB = 170.0     # per-instance host memory footprint
HOST_MEMORY_MB = 512 * 1024.0    # the paper's 512 GB host
MICROVM_CAP = 2952               # matches the paper's observed limit


def apply_microvm_model(tasks: list[Task]) -> list[Task]:
    out = []
    for t in tasks:
        t = copy.copy(t)
        t.service = t.service * (1.0 + MICROVM_VMM_OVERHEAD) + MICROVM_BOOT_MS
        t.remaining = t.service
        out.append(t)
    return out


def admit_microvm(tasks: list[Task],
                  cap: int = MICROVM_CAP) -> tuple[list[Task], list[Task]]:
    """Admission control: instances beyond the host-memory cap fail to
    launch (horizontal line at the start of Fig. 21)."""
    admitted, failed = [], []
    for i, t in enumerate(sorted(tasks, key=lambda x: x.arrival)):
        if i < cap:
            admitted.append(t)
        else:
            t.failed = True
            failed.append(t)
    return admitted, failed
