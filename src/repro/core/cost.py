"""AWS Lambda pricing model (paper Fig. 1/20, Table I).

AWS bills wall-clock *execution duration* per millisecond, with a
per-GB-second rate plus a flat per-request fee. The paper multiplies each
function's measured execution time (T_completion - T_firstrun) by the
per-ms price for its memory size; Table I weights by the Azure-trace
memory-size distribution, Figs. 1/20 show the cost if ALL functions had a
given fixed size.

With the container lifecycle layer attached (``core.containers``), the
execution span of a cold invocation includes its sandbox ``init_ms`` —
the user is billed for boot time, exactly the economics that make
warm-container locality worth routing for. Two helpers split that bill
(`cold_start_cost_usd`) and price the PROVIDER-side cost of holding idle
warm memory (`warm_pool_hold_cost_usd`): keep-alive is not free, it is a
bet that a warm hit saves more billed-init than the idle DRAM costs.

Rates live on :class:`~repro.costmodel.pricing.PricingSpec`: every
helper takes an optional ``pricing=`` argument and defaults to
``DEFAULT_PRICING`` (the historical constants, bit-identically). The
legacy module constants (``PRICE_PER_GB_SECOND`` etc.) survive as
DeprecationWarning shims via module ``__getattr__`` — same pattern as
the PR 6 entrypoint shims.
"""
from __future__ import annotations

import math
import warnings
from typing import Iterable, Optional, Sequence

from ..costmodel.pricing import DEFAULT_PRICING, PricingSpec

# Legacy module-level constants, now served by __getattr__ below with a
# DeprecationWarning. Values (identical to the historical literals):
#   PRICE_PER_GB_SECOND     = DEFAULT_PRICING.price_per_gb_second
#   PRICE_PER_REQUEST       = DEFAULT_PRICING.price_per_request
#   WARM_HOLD_PER_GB_SECOND = DEFAULT_PRICING.warm_hold_per_gb_second
_DEPRECATED_CONSTANTS = {
    "PRICE_PER_GB_SECOND": lambda: DEFAULT_PRICING.price_per_gb_second,
    "PRICE_PER_REQUEST": lambda: DEFAULT_PRICING.price_per_request,
    "WARM_HOLD_PER_GB_SECOND":
        lambda: DEFAULT_PRICING.warm_hold_per_gb_second,
}


def __getattr__(name: str):
    if name in _DEPRECATED_CONSTANTS:
        warnings.warn(
            f"repro.core.cost.{name} is deprecated; use "
            "repro.costmodel.PricingSpec / DEFAULT_PRICING instead",
            DeprecationWarning, stacklevel=2)
        return _DEPRECATED_CONSTANTS[name]()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# Fig. 1 / Fig. 20 memory ladder (MB).
MEMORY_LADDER_MB = (128, 256, 512, 1024, 2048, 4096, 10240)

# Azure '20: >90% of functions allocate < 400 MB. Discrete stand-in
# distribution used for Table I-style overall cost.
AZURE_MEMORY_DISTRIBUTION = (
    (128, 0.45),
    (192, 0.15),
    (256, 0.15),
    (384, 0.15),
    (512, 0.05),
    (1024, 0.03),
    (2048, 0.015),
    (4096, 0.005),
)


def price_per_ms(mem_mb: float,
                 pricing: Optional[PricingSpec] = None) -> float:
    p = pricing if pricing is not None else DEFAULT_PRICING
    return (mem_mb / 1024.0) * p.price_per_gb_second / 1000.0


def invocation_cost_usd(execution_ms: float, mem_mb: float,
                        price_mult: float = 1.0,
                        pricing: Optional[PricingSpec] = None) -> float:
    """One invocation's bill. ``price_mult`` scales the DURATION share
    only (heterogeneous node SKUs / spot discounts — the per-request
    fee is a front-door charge, identical on every machine)."""
    p = pricing if pricing is not None else DEFAULT_PRICING
    return execution_ms * price_per_ms(mem_mb, p) * price_mult \
        + p.price_per_request


def cold_start_cost_usd(init_ms: float, mem_mb: float,
                        pricing: Optional[PricingSpec] = None) -> float:
    """The share of one invocation's bill attributable to sandbox boot
    (no per-request fee: the request is billed once, in
    ``invocation_cost_usd``)."""
    return init_ms * price_per_ms(mem_mb, pricing)


def rejected_request_cost_usd(n_rejected: int,
                              pricing: Optional[PricingSpec] = None,
                              ) -> float:
    """Admission-shed invocations still hit the front door: the
    per-request fee is incurred (and, for the operator, is pure loss —
    no execution revenue behind it). Reported SEPARATELY from the
    execution bill so shedding can never masquerade as savings."""
    p = pricing if pricing is not None else DEFAULT_PRICING
    return n_rejected * p.price_per_request


def warm_pool_hold_cost_usd(warm_mb_ms: float,
                            pricing: Optional[PricingSpec] = None,
                            ) -> float:
    """Provider-side cost of the idle warm set: the integral of resident
    idle sandbox memory over time (MB x ms), as accumulated by
    ``ContainerPool.warm_mb_ms``."""
    p = pricing if pricing is not None else DEFAULT_PRICING
    return (warm_mb_ms / 1024.0 / 1000.0) * p.warm_hold_per_gb_second


def workload_cost_usd(execution_ms: Iterable[float],
                      mem_mb: Optional[Iterable[float]] = None,
                      fixed_mem_mb: Optional[float] = None,
                      price_mult: float = 1.0,
                      pricing: Optional[PricingSpec] = None) -> float:
    """Total user-facing cost of a workload.

    With ``fixed_mem_mb`` set, prices every invocation at that size
    (Fig. 1 / Fig. 20 style); otherwise uses per-invocation sizes.
    ``price_mult`` scales every invocation's duration share (a node
    SKU's memory price / spot discount — see ``invocation_cost_usd``).

    Summation is ``math.fsum`` (exactly rounded), so the total is
    bit-identical under ANY permutation of the invocations — cost
    roll-ups are order-canonical observables (DESIGN.md Sec. 13): the
    engine may retire completions in batches, and the bill must not
    depend on the order tasks arrived at the completed list.
    """
    if fixed_mem_mb is not None:
        return math.fsum(
            invocation_cost_usd(e, fixed_mem_mb, price_mult, pricing)
            for e in execution_ms)
    assert mem_mb is not None
    return math.fsum(invocation_cost_usd(e, m, price_mult, pricing)
                     for e, m in zip(execution_ms, mem_mb))


def duration_cost_usd(execution_ms: Iterable[float],
                      mem_mb: Iterable[float],
                      pricing: Optional[PricingSpec] = None) -> float:
    """The duration share of a workload's bill alone (no per-request
    fees), exactly rounded — the base that SKU price multipliers and
    spot discounts scale, so spot savings are priced from the same sum
    the bill itself uses."""
    return math.fsum(e * price_per_ms(m, pricing)
                     for e, m in zip(execution_ms, mem_mb))


def cost_ladder(execution_ms: Sequence[float],
                pricing: Optional[PricingSpec] = None) -> dict[int, float]:
    """Cost for each memory size on the Fig. 1/20 ladder."""
    return {mb: workload_cost_usd(execution_ms, fixed_mem_mb=mb,
                                  pricing=pricing)
            for mb in MEMORY_LADDER_MB}


def sample_memory_sizes(n: int, rng) -> list[int]:
    """Draw n memory sizes from the Azure-like distribution."""
    sizes = [mb for mb, _ in AZURE_MEMORY_DISTRIBUTION]
    probs = [p for _, p in AZURE_MEMORY_DISTRIBUTION]
    idx = rng.choice(len(sizes), size=n, p=probs)
    return [sizes[i] for i in idx]
