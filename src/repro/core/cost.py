"""AWS Lambda pricing model (paper Fig. 1/20, Table I).

AWS bills wall-clock *execution duration* per millisecond, with a
per-GB-second rate plus a flat per-request fee. The paper multiplies each
function's measured execution time (T_completion - T_firstrun) by the
per-ms price for its memory size; Table I weights by the Azure-trace
memory-size distribution, Figs. 1/20 show the cost if ALL functions had a
given fixed size.

With the container lifecycle layer attached (``core.containers``), the
execution span of a cold invocation includes its sandbox ``init_ms`` —
the user is billed for boot time, exactly the economics that make
warm-container locality worth routing for. Two helpers split that bill
(`cold_start_cost_usd`) and price the PROVIDER-side cost of holding idle
warm memory (`warm_pool_hold_cost_usd`): keep-alive is not free, it is a
bet that a warm hit saves more billed-init than the idle DRAM costs.
"""
from __future__ import annotations

import math
from typing import Iterable, Optional, Sequence

# AWS Lambda x86 pricing (https://aws.amazon.com/lambda/pricing/, 2024).
PRICE_PER_GB_SECOND = 1.66667e-5  # USD
PRICE_PER_REQUEST = 2.0e-7        # USD ($0.20 per 1M requests)

# Provider-side cost of keeping one GB of warm-but-idle sandbox memory
# resident for one second. Idle DRAM is far cheaper than billed compute;
# ~12.5% of the user-facing rate is in line with provider COGS estimates.
WARM_HOLD_PER_GB_SECOND = PRICE_PER_GB_SECOND / 8.0

# Fig. 1 / Fig. 20 memory ladder (MB).
MEMORY_LADDER_MB = (128, 256, 512, 1024, 2048, 4096, 10240)

# Azure '20: >90% of functions allocate < 400 MB. Discrete stand-in
# distribution used for Table I-style overall cost.
AZURE_MEMORY_DISTRIBUTION = (
    (128, 0.45),
    (192, 0.15),
    (256, 0.15),
    (384, 0.15),
    (512, 0.05),
    (1024, 0.03),
    (2048, 0.015),
    (4096, 0.005),
)


def price_per_ms(mem_mb: float) -> float:
    return (mem_mb / 1024.0) * PRICE_PER_GB_SECOND / 1000.0


def invocation_cost_usd(execution_ms: float, mem_mb: float,
                        price_mult: float = 1.0) -> float:
    """One invocation's bill. ``price_mult`` scales the DURATION share
    only (heterogeneous node SKUs / spot discounts — the per-request
    fee is a front-door charge, identical on every machine)."""
    return execution_ms * price_per_ms(mem_mb) * price_mult \
        + PRICE_PER_REQUEST


def cold_start_cost_usd(init_ms: float, mem_mb: float) -> float:
    """The share of one invocation's bill attributable to sandbox boot
    (no per-request fee: the request is billed once, in
    ``invocation_cost_usd``)."""
    return init_ms * price_per_ms(mem_mb)


def rejected_request_cost_usd(n_rejected: int) -> float:
    """Admission-shed invocations still hit the front door: the
    per-request fee is incurred (and, for the operator, is pure loss —
    no execution revenue behind it). Reported SEPARATELY from the
    execution bill so shedding can never masquerade as savings."""
    return n_rejected * PRICE_PER_REQUEST


def warm_pool_hold_cost_usd(warm_mb_ms: float) -> float:
    """Provider-side cost of the idle warm set: the integral of resident
    idle sandbox memory over time (MB x ms), as accumulated by
    ``ContainerPool.warm_mb_ms``."""
    return (warm_mb_ms / 1024.0 / 1000.0) * WARM_HOLD_PER_GB_SECOND


def workload_cost_usd(execution_ms: Iterable[float],
                      mem_mb: Optional[Iterable[float]] = None,
                      fixed_mem_mb: Optional[float] = None,
                      price_mult: float = 1.0) -> float:
    """Total user-facing cost of a workload.

    With ``fixed_mem_mb`` set, prices every invocation at that size
    (Fig. 1 / Fig. 20 style); otherwise uses per-invocation sizes.
    ``price_mult`` scales every invocation's duration share (a node
    SKU's memory price / spot discount — see ``invocation_cost_usd``).

    Summation is ``math.fsum`` (exactly rounded), so the total is
    bit-identical under ANY permutation of the invocations — cost
    roll-ups are order-canonical observables (DESIGN.md Sec. 13): the
    engine may retire completions in batches, and the bill must not
    depend on the order tasks arrived at the completed list.
    """
    if fixed_mem_mb is not None:
        return math.fsum(invocation_cost_usd(e, fixed_mem_mb, price_mult)
                         for e in execution_ms)
    assert mem_mb is not None
    return math.fsum(invocation_cost_usd(e, m, price_mult)
                     for e, m in zip(execution_ms, mem_mb))


def duration_cost_usd(execution_ms: Iterable[float],
                      mem_mb: Iterable[float]) -> float:
    """The duration share of a workload's bill alone (no per-request
    fees), exactly rounded — the base that SKU price multipliers and
    spot discounts scale, so spot savings are priced from the same sum
    the bill itself uses."""
    return math.fsum(e * price_per_ms(m)
                     for e, m in zip(execution_ms, mem_mb))


def cost_ladder(execution_ms: Sequence[float]) -> dict[int, float]:
    """Cost for each memory size on the Fig. 1/20 ladder."""
    return {mb: workload_cost_usd(execution_ms, fixed_mem_mb=mb)
            for mb in MEMORY_LADDER_MB}


def sample_memory_sizes(n: int, rng) -> list[int]:
    """Draw n memory sizes from the Azure-like distribution."""
    sizes = [mb for mb, _ in AZURE_MEMORY_DISTRIBUTION]
    probs = [p for _, p in AZURE_MEMORY_DISTRIBUTION]
    idx = rng.choice(len(sizes), size=n, p=probs)
    return [sizes[i] for i in idx]
