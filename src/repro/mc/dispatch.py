"""Regime gate + transparent fallback for the batched MC engine.

``supported(scenario)`` returns ``None`` when a scenario sits inside
the regime the kernels reproduce bit-for-bit, else a short human
reason.  Everything the gate refuses routes to the scalar engine —
callers (``cluster.sweep --backend jax``, ``MonteCarlo``) partition
their cells with this gate and never change results, only speed
(DESIGN.md Sec. 16).

The gate is deliberately conservative and STATIC: it looks only at
the specs, never at run state, so a cell's route is decided before
any work happens.  In-regime means:

* single node (``FleetSpec.is_fleet`` false), no node_factory,
* no container pool, no serving slots, no microvm/ghost models,
* no chaos / admission / pre-warm resilience layers,
* policy ``fifo`` | ``cfs`` | ``hybrid`` with default knobs (a
  hybrid may override ``n_fifo`` / ``time_limit_ms`` via ``kw`` —
  both are traced kernel inputs),
* workload kinds ``azure``/``synthetic``/``tasks`` whose built task
  list is canonical: tids equal list indices, arrivals
  non-decreasing (the heap's (t, seq) arrival order), no aux tasks.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:
    from ..scenario import Scenario

SUPPORTED_POLICIES = ("fifo", "cfs", "hybrid")

# Hybrid kwargs the kernel accepts as traced inputs; anything else in
# PolicySpec.kw (adapters, custom latencies, interference) falls back.
_HYBRID_KW = {"n_fifo", "time_limit_ms"}


def supported(sc: "Scenario") -> Optional[str]:
    """None if the batched engine reproduces ``sc`` bit-for-bit,
    else the reason it must run on the scalar engine."""
    fl, pol, res, wl = sc.fleet, sc.policy, sc.resilience, sc.workload
    if fl.is_fleet:
        return "fleet (dispatcher/multi-node) runs through ClusterSim"
    if fl.node_factory is not None:
        return "custom node_factory"
    if fl.containers is not None:
        return "container pool attached"
    if pol.serving is not None:
        return "serving slot scheduler"
    if pol.name not in SUPPORTED_POLICIES:
        return f"policy {pol.name!r} not batched"
    if pol.microvm or pol.ghost_mode:
        return "microvm/ghost system-effect model"
    if pol.adapt_pct is not None or pol.rightsize:
        return "adaptive time limit / rightsizer"
    if pol.n_fifo is not None:
        # The scalar single-node path reads n_fifo only from pol.kw
        # (PolicySpec.n_fifo feeds the fleet/serving factories), so
        # mirroring it here would be guesswork — fall back.
        return "PolicySpec.n_fifo on the single-node path"
    if pol.kw:
        if pol.name != "hybrid" or not set(pol.kw) <= _HYBRID_KW:
            return f"scheduler kwargs {sorted(pol.kw)} not batched"
    if res.chaos is not None or res.admission is not None \
            or res.prewarm is not None:
        return "resilience layer (chaos/admission/prewarm)"
    if wl.kind not in ("azure", "synthetic", "tasks"):
        return f"workload kind {wl.kind!r} not batched"
    C = fl.cores_per_node
    if pol.name == "hybrid":
        n_fifo = pol.kw.get("n_fifo", C // 2)
        if not 1 <= n_fifo < C:
            return "hybrid needs 1 <= n_fifo < n_cores"
    return None


def tasks_supported(tasks) -> Optional[str]:
    """Canonical-stream check on a BUILT task list (dynamic half of
    the gate — ``kind='tasks'`` lists are caller-shaped)."""
    prev = float("-inf")
    for i, t in enumerate(tasks):
        if t.tid != i:
            return "tids must equal list indices"
        if t.arrival < prev:
            return "arrivals must be non-decreasing"
        prev = t.arrival
        if t.aux_of is not None:
            return "aux (microvm companion) tasks"
        if t.remaining != t.service:
            return "partially-run tasks"
    return None
