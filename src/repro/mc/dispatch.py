"""Regime gate + transparent fallback for the batched MC engine.

``supported(scenario)`` returns ``None`` when a scenario sits inside
the regime the kernels reproduce bit-for-bit, else a :class:`Refusal`
— a plain human-readable string that additionally carries a stable
``key`` for fallback accounting (``reason_key``), so sweeps can report
*why* cells fell back instead of silently reading as "batched".
Everything the gate refuses routes to the scalar engine — callers
(``cluster.sweep --backend jax``, ``MonteCarlo``) partition their
cells with this gate and never change results, only speed
(DESIGN.md Sec. 16).

The gate is deliberately conservative and STATIC: it looks only at
the specs, never at run state, so a cell's route is decided before
any work happens.  In-regime means:

* single node, OR a flat multi-node fleet behind a STATE-OBLIVIOUS
  dispatcher (``round_robin`` | ``random``): those routing decisions
  are a pure function of dispatch order and ``FleetSpec.seed``, so the
  fleet decomposes into independent per-node cells the kernel batches
  side by side (recombined by the canonical (completion, tid)
  roll-up).  State-AWARE dispatchers (least_loaded, affinity, ...)
  observe node heartbeats and still run through ``ClusterSim``;
* no node_factory, no heterogeneous ``nodes`` override, no topology,
* no container pool, no serving slots, no microvm/ghost models,
* no chaos / admission / pre-warm / retry resilience layers,
* policy ``fifo`` | ``cfs`` | ``hybrid`` with default knobs (a
  hybrid may override ``n_fifo`` / ``time_limit_ms`` via ``kw`` —
  both are traced kernel inputs),
* workload kinds ``azure``/``synthetic``/``tasks`` whose built task
  list is canonical: tids equal list indices, arrivals
  non-decreasing (the heap's (t, seq) arrival order), no aux tasks.
"""
from __future__ import annotations

import os
from typing import TYPE_CHECKING, Optional

if TYPE_CHECKING:
    from ..scenario import Scenario

SUPPORTED_POLICIES = ("fifo", "cfs", "hybrid")

# Fleet dispatchers whose routing is a pure function of (dispatch
# order, seed) — no node state observed, so assignments replay in
# Python and each node becomes an independent batched cell.
REPLAYABLE_DISPATCHERS = ("round_robin", "random")

# Hybrid kwargs the kernel accepts as traced inputs; anything else in
# PolicySpec.kw (adapters, custom latencies, interference) falls back.
_HYBRID_KW = {"n_fifo", "time_limit_ms"}


class Refusal(str):
    """A refusal reason: behaves as the human-readable message
    everywhere (tests match substrings, errors interpolate it) while
    carrying a stable ``key`` the fallback counters aggregate on."""

    key: str

    def __new__(cls, key: str, msg: str) -> "Refusal":
        self = super().__new__(cls, msg)
        self.key = key
        return self


def reason_key(why) -> str:
    """Stable counter key for a gate refusal (``"other"`` for plain
    strings from older callers)."""
    return getattr(why, "key", "other")


def supported(sc: "Scenario") -> Optional[Refusal]:
    """None if the batched engine reproduces ``sc`` bit-for-bit,
    else the reason it must run on the scalar engine."""
    fl, pol, res, wl = sc.fleet, sc.policy, sc.resilience, sc.workload
    if fl.is_fleet:
        if fl.topology is not None:
            return Refusal("topology",
                           "failure-domain topology attached")
        if fl.nodes is not None:
            return Refusal("hetero_nodes",
                           "heterogeneous per-node policy override")
        disp = fl.dispatcher if fl.dispatcher is not None \
            else "least_loaded"
        if not isinstance(disp, str):
            return Refusal("fleet_dispatcher",
                           "fleet dispatcher instance (unreplayable "
                           "state) runs through ClusterSim")
        if disp not in REPLAYABLE_DISPATCHERS:
            return Refusal(
                "fleet_dispatcher",
                f"fleet dispatcher {disp!r} is state-aware; runs "
                f"through ClusterSim")
    if fl.node_factory is not None:
        return Refusal("node_factory", "custom node_factory")
    if fl.containers is not None:
        return Refusal("containers", "container pool attached")
    if pol.serving is not None:
        return Refusal("serving", "serving slot scheduler")
    if pol.name not in SUPPORTED_POLICIES:
        return Refusal("policy", f"policy {pol.name!r} not batched")
    if pol.microvm or pol.ghost_mode:
        return Refusal("system_model",
                       "microvm/ghost system-effect model")
    if pol.adapt_pct is not None or pol.rightsize:
        return Refusal("adaptive", "adaptive time limit / rightsizer")
    if pol.n_fifo is not None:
        # The scalar engine reads n_fifo only from pol.kw on the
        # single-node path and via a policy node_factory on fleets, so
        # mirroring it here would be guesswork — fall back.
        return Refusal("n_fifo",
                       "PolicySpec.n_fifo feeds node factories; the "
                       "batched path reads kw only")
    if pol.kw:
        if pol.name != "hybrid" or not set(pol.kw) <= _HYBRID_KW:
            return Refusal("kwargs",
                           f"scheduler kwargs {sorted(pol.kw)} "
                           f"not batched")
    if res.chaos is not None or res.admission is not None \
            or res.prewarm is not None or res.retry is not None:
        return Refusal("resilience",
                       "resilience layer (chaos/admission/prewarm/"
                       "retry)")
    if wl.kind not in ("azure", "synthetic", "tasks"):
        return Refusal("workload", f"workload kind {wl.kind!r} "
                                   f"not batched")
    C = fl.cores_per_node
    if pol.name == "hybrid":
        n_fifo = pol.kw.get("n_fifo", C // 2)
        if not 1 <= n_fifo < C:
            return Refusal("hybrid_split",
                           "hybrid needs 1 <= n_fifo < n_cores")
    return None


def tasks_supported(tasks) -> Optional[Refusal]:
    """Canonical-stream check on a BUILT task list (dynamic half of
    the gate — ``kind='tasks'`` lists are caller-shaped)."""
    prev = float("-inf")
    for i, t in enumerate(tasks):
        if t.tid != i:
            return Refusal("stream_tids", "tids must equal list indices")
        if t.arrival < prev:
            return Refusal("stream_order",
                           "arrivals must be non-decreasing")
        prev = t.arrival
        if t.aux_of is not None:
            return Refusal("aux_tasks", "aux (microvm companion) tasks")
        if t.remaining != t.service:
            return Refusal("partial_tasks", "partially-run tasks")
    return None


# -- persistent compilation cache ----------------------------------------------

_CACHE_DIR: Optional[str] = None


def enable_compile_cache(path: Optional[str] = None) -> Optional[str]:
    """Opt in to JAX's persistent compilation cache.

    ``path`` wins; otherwise the ``REPRO_MC_COMPILE_CACHE`` env var is
    consulted.  Returns the active cache directory (None when neither
    is set — caching stays off, the historical default).  Idempotent:
    the first enabled directory sticks for the process, matching
    JAX's own one-shot config.  Compiled (C, N)-bucket programs then
    survive process restarts, which removes the ~8 s ``jax_cold``
    penalty from smoke-scale runs (ISSUE 9 satellite).
    """
    global _CACHE_DIR
    if _CACHE_DIR is not None:
        return _CACHE_DIR
    path = path or os.environ.get("REPRO_MC_COMPILE_CACHE")
    if not path:
        return None
    import jax

    os.makedirs(path, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", path)
    # Bucket programs compile in ~1 s; without this floor the cache
    # would skip exactly the programs we want it to keep.
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    _CACHE_DIR = path
    return path


def compile_cache_entries() -> Optional[int]:
    """Number of entries in the active persistent cache (None when
    caching is off) — benches diff this across a run to attribute
    wall-clock to recompiles vs kernel slowdowns."""
    if _CACHE_DIR is None or not os.path.isdir(_CACHE_DIR):
        return None
    return len(os.listdir(_CACHE_DIR))
