"""Batching layer: Scenario cells -> packed arrays -> one device program.

``run_scenarios`` takes a list of in-regime scenarios (see
``repro.mc.dispatch.supported``), decomposes each into kernel UNITS —
a single-node cell is one unit; an admitted flat fleet becomes one
unit per node, holding the dispatch subsequence its state-oblivious
dispatcher (round_robin/random) is replayed to in Python — groups
units into (n_cores, padded task count) shape buckets, advances each
bucket's whole grid in ONE vmapped XLA program, then rebuilds ordinary
``Task`` / ``SimResult`` / ``ClusterResult`` / ``ScenarioResult``
objects from the output arrays — so every downstream consumer (summary
schema, cost roll-ups, gate, dashboard) reads exactly what the scalar
engine would have produced, bit-for-bit (DESIGN.md Sec. 16).
"""
from __future__ import annotations

import random
from typing import TYPE_CHECKING, Optional, Sequence

import numpy as np

from .dispatch import enable_compile_cache, supported, tasks_supported

if TYPE_CHECKING:
    from ..scenario import Scenario, ScenarioResult

_INF = float("inf")

# Hybrid defaults mirrored from core.hybrid.HybridScheduler.
_HYBRID_TIME_LIMIT_MS = 1633.0


def _bucket(n: int) -> int:
    """Padded task-slot count: next power of two, floor 64 — few
    compilations, bounded padding waste."""
    return max(64, 1 << max(0, (n - 1)).bit_length())


def cell_params(sc: "Scenario") -> tuple[int, float]:
    """(n_fifo, fifo budget limit) for a supported scenario — the two
    traced per-cell scalars that select the policy inside the kernel."""
    C = sc.fleet.cores_per_node
    name = sc.policy.name
    if name == "fifo":
        return C, _INF
    if name == "cfs":
        return 0, _INF
    n_fifo = sc.policy.kw.get("n_fifo", C // 2)
    limit = float(sc.policy.kw.get("time_limit_ms",
                                   _HYBRID_TIME_LIMIT_MS))
    return n_fifo, limit


def replay_assignments(sc: "Scenario", n_tasks: int) -> list[int]:
    """Node index per task, in canonical stream order — exact because
    the admitted dispatchers never observe node state: ``round_robin``
    is a counter over dispatch order, ``random`` draws once per
    dispatch from ``random.Random(fleet.seed)``, and the gate's
    canonical-stream check (tids == indices, arrivals non-decreasing)
    makes ``ClusterSim``'s (arrival, tid) dispatch order the list
    order."""
    name = sc.fleet.dispatcher
    n = sc.fleet.n_nodes
    if name == "round_robin":
        return [k % n for k in range(n_tasks)]
    if name == "random":
        rng = random.Random(sc.fleet.seed)
        return [rng.randrange(n) for _ in range(n_tasks)]
    raise ValueError(f"dispatcher {name!r} is not replayable")


def _fleet_result(sc: "Scenario", tasks, sel: list[int]):
    """Rebuild the exact ``ClusterResult`` that ``ClusterSim.result()``
    produces for an in-regime fleet: flat node0..node{n-1} roster,
    assignments in dispatch order, unit price multipliers, no
    resilience bookkeeping (all those layers are gate-refused)."""
    from ..cluster.metrics import ClusterResult
    from ..core.metrics import SimResult

    fl, pol = sc.fleet, sc.policy
    n = fl.n_nodes
    node_ids = [f"node{i}" for i in range(n)]
    per_node: list[list] = [[] for _ in range(n)]
    for j, i in enumerate(sel):
        per_node[i].append(tasks[j])
    node_results = [
        SimResult(policy=pol.name, tasks=ts,
                  total_ctx=sum(t.ctx_switches for t in ts))
        for ts in per_node]
    meta = [{"node_id": nid, "zone": None, "rack": None, "sku": None,
             "spot": False, "price_mult": 1.0, "base_price_mult": 1.0,
             "spot_discount": 0.0} for nid in node_ids]
    return ClusterResult(
        node_results=node_results,
        node_ids=node_ids,
        node_policies=[pol.name] * n,
        dispatcher=fl.dispatcher,
        cores_per_node=fl.cores_per_node,
        assignments=[(tasks[j].tid, node_ids[i])
                     for j, i in enumerate(sel)],
        node_meta=meta,
    )


def run_scenarios(scenarios: Sequence["Scenario"],
                  prebuilt: Optional[Sequence] = None
                  ) -> list["ScenarioResult"]:
    """Run in-regime scenarios on the batched engine.

    ``prebuilt`` optionally supplies ``(tasks, meta)`` per scenario
    (e.g. ``MonteCarlo`` shares one trace generation across load
    scales); otherwise each ``workload.build()`` runs here. Raises
    ``ValueError`` on out-of-regime scenarios — callers partition
    with ``dispatch.supported`` first.  Each result carries
    ``mc_stats`` = ``{"iters", "events"}`` (kernel while-loop trips
    and scheduling events retired for that cell, summed over fleet
    units) — the algorithmic multi-event win stays visible even where
    1-core wall-clock hides it.
    """
    from ..core.metrics import SimResult
    from ..scenario import ScenarioResult
    from .kernels import run_grid

    enable_compile_cache()

    built = []
    for k, sc in enumerate(scenarios):
        why = supported(sc)
        tasks = meta = None
        if why is None:
            tasks, meta = (prebuilt[k] if prebuilt is not None
                           else sc.workload.build())
            why = tasks_supported(tasks)
        if why is not None:
            raise ValueError(f"scenario outside the batched regime "
                             f"({why}); route it to the scalar engine")
        built.append((tasks, meta))

    # Kernel units: (scenario idx, node idx | None, task index list).
    # Admitted fleets decompose node-by-node — the nodes never
    # interact once assignments are fixed, so each is an independent
    # cell batched alongside everything else.
    units: list[tuple[int, Optional[int], list[int]]] = []
    fleet_sel: dict[int, list[int]] = {}
    for k, sc in enumerate(scenarios):
        n = len(built[k][0])
        if sc.fleet.is_fleet:
            sel = replay_assignments(sc, n)
            fleet_sel[k] = sel
            for i in range(sc.fleet.n_nodes):
                idxs = [j for j in range(n) if sel[j] == i]
                if idxs:          # an empty node needs no kernel cell
                    units.append((k, i, idxs))
        else:
            units.append((k, None, list(range(n))))

    # Shape buckets: one compiled program per (C, N) pair.
    groups: dict[tuple[int, int], list[int]] = {}
    for u, (k, _i, idxs) in enumerate(units):
        key = (scenarios[k].fleet.cores_per_node, _bucket(len(idxs)))
        groups.setdefault(key, []).append(u)

    iters = [0] * len(scenarios)   # kernel while-loop trips per cell
    events = [0] * len(scenarios)  # scheduling events retired per cell
    for (C, N), us in groups.items():
        B = len(us)
        arrival = np.full((B, N), _INF)
        service = np.full((B, N), 1.0)
        n_tasks = np.zeros(B, np.int32)
        n_fifo = np.zeros(B, np.int32)
        limit = np.zeros(B)
        for b, u in enumerate(us):
            k, _i, idxs = units[u]
            tasks = built[k][0]
            arrival[b, :len(idxs)] = [tasks[j].arrival for j in idxs]
            service[b, :len(idxs)] = [tasks[j].service for j in idxs]
            n_tasks[b] = len(idxs)
            n_fifo[b], limit[b] = cell_params(scenarios[k])
        out = run_grid(arrival, service, n_tasks, n_fifo, limit,
                       n_cores=C)
        if not bool(np.all(out["ok"])):
            bad = sorted({units[us[b]][0] for b in range(B)
                          if not out["ok"][b]})
            raise RuntimeError(
                f"batched MC kernel failed to drain cells {bad} "
                f"(iteration cap hit or tasks left unfinished) — "
                f"regime bug, please report")
        for b, u in enumerate(us):
            k, _i, idxs = units[u]
            tasks = built[k][0]
            for pos, j in enumerate(idxs):
                task = tasks[j]
                task.completion = float(out["completion"][b, pos])
                task.first_run = float(out["first_run"][b, pos])
                task.preemptions = int(out["preemptions"][b, pos])
                task.ctx_switches = int(out["ctx_switches"][b, pos])
                task.migrations = int(out["migrations"][b, pos])
                task.cpu_time = float(out["cpu_time"][b, pos])
                task.remaining = 0.0
            iters[k] += int(out["n_iters"][b])
            events[k] += int(out["n_events"][b])

    results: list["ScenarioResult"] = []
    for k, sc in enumerate(scenarios):
        tasks, meta = built[k]
        if sc.fleet.is_fleet:
            raw = _fleet_result(sc, tasks, fleet_sel[k])
        else:
            raw = SimResult(
                policy=sc.policy.name, tasks=tasks,
                total_ctx=sum(t.ctx_switches for t in tasks))
        results.append(ScenarioResult(
            scenario=sc, raw=raw, meta=dict(meta),
            mc_stats={"iters": iters[k], "events": events[k]}))
    return results
