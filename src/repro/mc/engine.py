"""Batching layer: Scenario cells -> packed arrays -> one device program.

``run_scenarios`` takes a list of in-regime scenarios (see
``repro.mc.dispatch.supported``), groups them into (n_cores, padded
task count) shape buckets, advances each bucket's whole grid in ONE
vmapped XLA program, then rebuilds ordinary ``Task`` /
``SimResult`` / ``ScenarioResult`` objects from the output arrays —
so every downstream consumer (summary schema, cost roll-ups, gate,
dashboard) reads exactly what the scalar engine would have produced,
bit-for-bit (DESIGN.md Sec. 16).
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Optional, Sequence

import numpy as np

from .dispatch import supported, tasks_supported

if TYPE_CHECKING:
    from ..scenario import Scenario, ScenarioResult

_INF = float("inf")

# Hybrid defaults mirrored from core.hybrid.HybridScheduler.
_HYBRID_TIME_LIMIT_MS = 1633.0


def _bucket(n: int) -> int:
    """Padded task-slot count: next power of two, floor 64 — few
    compilations, bounded padding waste."""
    return max(64, 1 << max(0, (n - 1)).bit_length())


def cell_params(sc: "Scenario") -> tuple[int, float]:
    """(n_fifo, fifo budget limit) for a supported scenario — the two
    traced per-cell scalars that select the policy inside the kernel."""
    C = sc.fleet.cores_per_node
    name = sc.policy.name
    if name == "fifo":
        return C, _INF
    if name == "cfs":
        return 0, _INF
    n_fifo = sc.policy.kw.get("n_fifo", C // 2)
    limit = float(sc.policy.kw.get("time_limit_ms",
                                   _HYBRID_TIME_LIMIT_MS))
    return n_fifo, limit


def run_scenarios(scenarios: Sequence["Scenario"],
                  prebuilt: Optional[Sequence] = None
                  ) -> list["ScenarioResult"]:
    """Run in-regime scenarios on the batched engine.

    ``prebuilt`` optionally supplies ``(tasks, meta)`` per scenario
    (e.g. ``MonteCarlo`` shares one trace generation across load
    scales); otherwise each ``workload.build()`` runs here. Raises
    ``ValueError`` on out-of-regime scenarios — callers partition
    with ``dispatch.supported`` first.
    """
    from ..core.metrics import SimResult
    from ..scenario import ScenarioResult
    from .kernels import run_grid

    built = []
    for k, sc in enumerate(scenarios):
        why = supported(sc)
        tasks = meta = None
        if why is None:
            tasks, meta = (prebuilt[k] if prebuilt is not None
                           else sc.workload.build())
            why = tasks_supported(tasks)
        if why is not None:
            raise ValueError(f"scenario outside the batched regime "
                             f"({why}); route it to the scalar engine")
        built.append((tasks, meta))

    # Shape buckets: one compiled program per (C, N) pair.
    groups: dict[tuple[int, int], list[int]] = {}
    for k, sc in enumerate(scenarios):
        key = (sc.fleet.cores_per_node, _bucket(len(built[k][0])))
        groups.setdefault(key, []).append(k)

    results: list[Optional["ScenarioResult"]] = [None] * len(scenarios)
    for (C, N), idxs in groups.items():
        B = len(idxs)
        arrival = np.full((B, N), _INF)
        service = np.full((B, N), 1.0)
        n_tasks = np.zeros(B, np.int32)
        n_fifo = np.zeros(B, np.int32)
        limit = np.zeros(B)
        for b, k in enumerate(idxs):
            tasks = built[k][0]
            n = len(tasks)
            arrival[b, :n] = [t.arrival for t in tasks]
            service[b, :n] = [t.service for t in tasks]
            n_tasks[b] = n
            n_fifo[b], limit[b] = cell_params(scenarios[k])
        out = run_grid(arrival, service, n_tasks, n_fifo, limit,
                       n_cores=C)
        if not bool(np.all(out["ok"])):
            bad = [idxs[b] for b in range(B) if not out["ok"][b]]
            raise RuntimeError(
                f"batched MC kernel failed to drain cells {bad} "
                f"(iteration cap hit or tasks left unfinished) — "
                f"regime bug, please report")
        for b, k in enumerate(idxs):
            sc, (tasks, meta) = scenarios[k], built[k]
            total_ctx = 0
            for i, task in enumerate(tasks):
                task.completion = float(out["completion"][b, i])
                task.first_run = float(out["first_run"][b, i])
                task.preemptions = int(out["preemptions"][b, i])
                task.ctx_switches = int(out["ctx_switches"][b, i])
                task.migrations = int(out["migrations"][b, i])
                task.remaining = 0.0
                total_ctx += task.ctx_switches
            raw = SimResult(policy=sc.policy.name, tasks=tasks,
                            total_ctx=total_ctx)
            results[k] = ScenarioResult(scenario=sc, raw=raw,
                                        meta=dict(meta))
    return results
