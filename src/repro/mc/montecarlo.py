"""``MonteCarlo``: one Scenario, a grid of (seeds x loads), one launch.

The Scenario-level front door of the batched engine (DESIGN.md
Sec. 16): take a base scenario, cross it with trace seeds and load
scales, advance every resulting cell in a single vmapped device
program, and return per-cell summary rows ready for the sweep/bench/
gate toolchain.  Cells the batched regime cannot reproduce bit-for-bit
fall back to the scalar engine transparently (``meta["fallback"]``
counts them).

    mc = MonteCarlo(scenario, seeds=range(32), loads=(0.5, 1.0, 2.0))
    rows = mc.run().rows          # 96 cells, one compiled program
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import TYPE_CHECKING, Optional, Sequence

from ..traces.azure import TraceSpec
from .dispatch import (enable_compile_cache, reason_key, supported,
                       tasks_supported)

if TYPE_CHECKING:
    from ..scenario import Scenario, ScenarioResult


@dataclass
class MonteCarloResult:
    results: list["ScenarioResult"]
    seeds: tuple
    loads: tuple
    meta: dict = field(default_factory=dict)
    # Per-cell gate refusal keys, aligned with ``results``: None for
    # batched cells, a stable counter key (see ``dispatch.Refusal``)
    # for cells the gate demoted, "forced" under backend="python".
    reasons: tuple = ()

    @property
    def rows(self) -> list[dict]:
        out = []
        k = 0
        for seed in self.seeds:
            for load in self.loads:
                r = self.results[k]
                row = dict(seed=seed, load_scale=load,
                           backend=self.meta["backends"][k])
                why = self.reasons[k] if self.reasons else None
                if why is not None and why != "forced":
                    # Only genuine gate demotions are annotated — a
                    # forced scalar baseline must stay row-identical
                    # to its batched twin (the equivalence contract).
                    row["fallback_reason"] = why
                row.update(r.summary())
                out.append(row)
                k += 1
        return out


@dataclass
class MonteCarlo:
    """Expand ``scenario`` over ``seeds`` x ``loads`` and run the grid.

    ``seeds`` re-seed the workload's :class:`TraceSpec` (the workload
    must be trace-driven — ``azure``/``synthetic``); ``loads``
    override ``WorkloadSpec.load_scale``.  ``backend="jax"`` uses the
    batched engine wherever :func:`repro.mc.dispatch.supported`
    allows and the scalar engine elsewhere; ``backend="python"``
    forces the scalar engine everywhere (the equivalence baseline).
    """

    scenario: "Scenario"
    seeds: Sequence[int] = (0,)
    loads: Sequence[float] = (1.0,)
    backend: str = "jax"
    # Opt-in persistent XLA compilation cache directory (also settable
    # process-wide via the REPRO_MC_COMPILE_CACHE env var): compiled
    # bucket programs survive restarts, removing the jax_cold penalty.
    compile_cache_dir: Optional[str] = None

    def cells(self) -> list["Scenario"]:
        wl = self.scenario.workload
        if wl.kind not in ("azure", "synthetic"):
            raise ValueError("MonteCarlo needs a trace-driven workload "
                             "(kind='azure') to re-seed")
        base_trace = wl.trace or TraceSpec()
        out = []
        for seed in self.seeds:
            trace = replace(base_trace, seed=seed)
            for load in self.loads:
                out.append(replace(
                    self.scenario,
                    workload=replace(wl, trace=trace, load_scale=load)))
        return out

    def run(self) -> MonteCarloResult:
        from ..scenario import run as run_scalar
        from .engine import run_scenarios

        cells = self.cells()
        backends = []
        use_jax = []
        reasons: list[Optional[str]] = []
        if self.backend == "jax":
            enable_compile_cache(self.compile_cache_dir)
            for sc in cells:
                why = supported(sc)
                use_jax.append(why is None)
                backends.append("jax" if why is None else "python")
                reasons.append(None if why is None else reason_key(why))
        elif self.backend == "python":
            use_jax = [False] * len(cells)
            backends = ["python"] * len(cells)
            reasons = ["forced"] * len(cells)
        else:
            raise ValueError(f"unknown backend {self.backend!r}")

        results: list[Optional["ScenarioResult"]] = [None] * len(cells)
        jax_idx = [k for k, u in enumerate(use_jax) if u]
        if jax_idx:
            # Build workloads once per (seed, load): sharing the trace
            # generation across cells is fine — build() is
            # deterministic per spec and each cell gets its own list.
            prebuilt = [cells[k].workload.build() for k in jax_idx]
            # A caller-shaped task stream can still force a fallback.
            keep = []
            for j, k in enumerate(jax_idx):
                why = tasks_supported(prebuilt[j][0])
                if why is None:
                    keep.append(j)
                else:
                    use_jax[k] = False
                    backends[k] = "python"
                    reasons[k] = reason_key(why)
            jax_idx = [jax_idx[j] for j in keep]
            prebuilt = [prebuilt[j] for j in keep]
        if jax_idx:
            for k, res in zip(jax_idx,
                              run_scenarios([cells[k] for k in jax_idx],
                                            prebuilt=prebuilt)):
                results[k] = res
        for k, sc in enumerate(cells):
            if results[k] is None:
                results[k] = run_scalar(sc)

        counts: dict[str, int] = {}
        for why in reasons:
            if why is not None:
                counts[why] = counts.get(why, 0) + 1
        return MonteCarloResult(
            results=results, seeds=tuple(self.seeds),
            loads=tuple(self.loads),
            meta={"backends": backends,
                  "fallback": sum(b == "python" for b in backends),
                  "fallback_reasons": counts},
            reasons=tuple(reasons))
