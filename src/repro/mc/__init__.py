"""Batched Monte-Carlo engine: whole sweep grids in one JAX program.

Public surface (DESIGN.md Sec. 16):

* :class:`MonteCarlo` / :class:`MonteCarloResult` — Scenario-level
  (seeds x loads) grids, ``MonteCarlo(sc, seeds=..., loads=...)``;
* :func:`run_scenarios` — batch a list of in-regime scenarios;
* :func:`supported` — the regime gate (None = batched, str = reason
  for scalar fallback).

Bit-identity contract: under ``jax_enable_x64`` (entered per call via
``jax.experimental.enable_x64`` — the repo's global dtype default is
untouched) on the CPU backend the batched engine reproduces the
scalar engine's per-task digests and every cost roll-up exactly.
Other backends run but carry no bit-level promise.

Heavy imports (jax) are deferred until first use so ``import repro``
stays light.
"""
from __future__ import annotations

_EXPORTS = {
    "MonteCarlo": ("repro.mc.montecarlo", "MonteCarlo"),
    "MonteCarloResult": ("repro.mc.montecarlo", "MonteCarloResult"),
    "run_scenarios": ("repro.mc.engine", "run_scenarios"),
    "supported": ("repro.mc.dispatch", "supported"),
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    try:
        mod_name, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro.mc' has no attribute "
                             f"{name!r}") from None
    import importlib
    return getattr(importlib.import_module(mod_name), attr)
