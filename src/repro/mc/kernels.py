"""Batched Monte-Carlo kernels for the supported scheduling regime.

One cell = one (policy, seed, load) trajectory of the single-node
engine; the kernel below advances a WHOLE GRID of cells in a single
compiled XLA program via ``jax.vmap`` (DESIGN.md Sec. 16).

The kernel is a faithful re-expression of the scalar event loop
(`core/events.py` + `core/policies.py` + `core/hybrid.py`) restricted
to the regime the analytic fast-forwards already closed:

* single node, no container pool, no interference, no util timers,
* policies: ``fifo``, ``cfs``, ``hybrid`` with a STATIC time limit,
* default Linux knobs (sched_latency 24 ms, min_granularity 3 ms,
  ctx_switch 0.06 ms).

Within that regime the event braid has exactly three interacting
event classes, totally ordered by the scalar heap key ``(t, klass,
tie)``: arrivals (klass 0, tid order), hybrid FIFO-core expiries
(klass 2, cid tie-break — they touch shared state: the global queue,
migration round-robin, CFS runqueues), and CFS-core expiries (klass 2,
core-local).  CFS expiries before the next arrival/FIFO barrier are
INDEPENDENT across cores, so the kernel advances every eligible CFS
core in one vectorized step — and, since PR 9, retires MANY chunk
expiries per outer iteration via the Sec. 13 closed forms re-expressed
as fixed-length ``lax.scan`` batches:

* **cycle engine** — the stable-alternation-cycle fast-forward: when a
  core's runnable set is small (``<= _CYCLE_K`` members) the pop order
  is a fixed rotation; a scan walks up to one window of chunks across
  MULTIPLE rounds, carrying per-member (remaining, vruntime, cpu)
  accumulators and the end-time left fold, stopping at the first
  completion, instability, or barrier.  The lone-task solo regime is
  the ``k == 1`` case of the same engine.
* **window engine** — PR 4's ``_window_fast_forward`` twin: one full
  rotation of a deeper runqueue evaluated at once (stability /
  slice-constancy / bound / completion masks as vector predicates over
  the chunk axis), completions retired inline, the surviving prefix
  committed by scatter.
* **generic advance** — the original one-event expire+pick, kept as
  the universal fallback: any chunk the batches decline (unstable
  push, slice change, the completing chunk of a cycle) retires here
  with identical arithmetic.

Barrier events (arrivals in tid order, the minimal FIFO expiry) are
then re-serialized exactly as the heap would.

Bit-identity contract: under ``jax_enable_x64`` on the CPU backend
every float is computed by the SAME operation sequence as the scalar
engine — the shared pure helpers of ``core/events.py``
(`chunk_run_ms`, `chunk_end_ms`, `cfs_slice_ms`, `fifo_budget_ms`)
re-bound to ``jnp.minimum``/``jnp.maximum`` — so per-task digests
(completion, first_run, preemptions, ctx_switches, migrations,
cpu_time) and every cost roll-up derived from them match the scalar
engine bit-for-bit.  The multi-event batches preserve the contract
because they only ever retire FULL chunks whose parameters the event
path would compute identically: end times accumulate through an
explicit left fold ``e = (e + ctx) + run`` inside ``lax.scan`` —
NEVER ``cumsum``, which XLA may reassociate — and the only
associative scans used for predicates are exact ones (integer
``cumsum`` of completion flags, ``cummax`` of push keys).

A plain-FIFO cell runs as the hybrid machinery with ``n_fifo == C``
and an infinite budget: ``min(rem, inf) == rem`` and ``max(inf - 0.0,
0.01) == inf`` are bitwise no-ops, completions always beat the
(unreachable) migration branch, so the braid degenerates to FIFO's
run-to-completion semantics with identical arithmetic.  A pure-CFS
cell is ``n_fifo == 0``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.events import (_EPS, cfs_slice_ms, chunk_completes,
                               chunk_end_ms, chunk_run_ms,
                               fifo_budget_ms)

# Default Linux knobs of the supported regime (see module docstring);
# the dispatch gate (repro.mc.dispatch) refuses cells that override
# them, so baking them into the compiled program is safe.
SCHED_LATENCY_MS = 24.0
MIN_GRANULARITY_MS = 3.0
CTX_SWITCH_MS = 0.06

_INF = float("inf")
_I32MAX = 2 ** 31 - 1

# Safety valve: an upper bound on outer-loop iterations so a regime
# bug hangs nothing — the engine checks the `ok` output and raises.
_MAX_ITERS_PER_TASK = 1024

# Multi-event retirement knobs. The cycle engine covers alternation
# cycles of up to _CYCLE_K members (running task + up to _CYCLE_K - 1
# queued); both batches retire up to one window of chunks per outer
# iteration. The window length is the kernel twin of the scalar
# engine's adaptive 64/256 `Core.ff_w` escalation — under jit shapes
# are static, so the choice is made per compiled (C, N) bucket (small
# buckets take the 64-chunk window, deep-queue buckets the 256) rather
# than per core at runtime.
_CYCLE_K = 8
_WINDOW_SMALL = 64
_WINDOW_DEEP = 256
_MICRO_STEPS = 8


def _sel_tree(pred, new, old):
    """Per-cell select between two state pytrees (scalar bool pred)."""
    return {k: jnp.where(pred, new[k], old[k]) for k in old}


def make_cell_kernel(n_cores: int, n_slots: int):
    """Build the single-cell simulator for a static shape bucket.

    ``n_cores`` (C) and ``n_slots`` (N, padded task capacity) are
    compile-time constants; everything per-cell (arrival/service
    arrays, task count, FIFO split, migration budget) is traced, so
    one compilation serves every cell of the bucket and ``jax.vmap``
    batches them into a single program.
    """
    C, N = n_cores, n_slots
    LAT, GRAN, CTX = SCHED_LATENCY_MS, MIN_GRANULARITY_MS, CTX_SWITCH_MS
    KC = _CYCLE_K
    W = _WINDOW_SMALL if N <= 128 else _WINDOW_DEEP

    cids = jnp.arange(C, dtype=jnp.int32)

    def kernel(arrival, service, n_tasks, n_fifo, limit):
        """Run one cell to completion.

        arrival, service : f64[N]   (padded with +inf / 1.0)
        n_tasks          : i32      live prefix length
        n_fifo           : i32      C => plain FIFO, 0 => pure CFS
        limit            : f64      FIFO budget (inf outside hybrid)
        """
        is_fifo = cids < n_fifo
        n_cfs = C - n_fifo
        budget = fifo_budget_ms(limit, 0.0, _max=jnp.maximum)

        st = dict(
            # per-task
            rem=service,
            vr=jnp.zeros(N),
            cpu=jnp.zeros(N),
            seq=jnp.zeros(N, jnp.int32),
            qcore=jnp.zeros(N, jnp.int32),
            stat=jnp.zeros(N, jnp.int32),   # 0 unarrived, 1 fifo-q,
                                            # 2 on-rq, 3 running, 4 done
            fr=jnp.full(N, jnp.nan),
            comp=jnp.full(N, jnp.nan),
            npre=jnp.zeros(N, jnp.int32),
            nctx=jnp.zeros(N, jnp.int32),
            nmig=jnp.zeros(N, jnp.int32),
            # per-core
            cur=jnp.full(C, -1, jnp.int32),
            end=jnp.full(C, _INF),
            clen=jnp.zeros(C),
            last=jnp.full(C, -1, jnp.int32),
            minvr=jnp.zeros(C),
            seqc=jnp.zeros(C, jnp.int32),
            rqn=jnp.zeros(C, jnp.int32),
            # scalars
            ptr=jnp.int32(0),
            rr=jnp.int32(0),
            rrc=jnp.int32(0),
            it=jnp.int32(0),
            ev=jnp.int32(0),
        )

        def t_arr(st):
            p = st["ptr"]
            return jnp.where(p < n_tasks, arrival[jnp.minimum(p, N - 1)],
                             _INF)

        def fifo_candidate(st):
            """Minimal pending FIFO-group expiry: (time, cid, any)."""
            busy = is_fifo & (st["cur"] >= 0)
            e = jnp.where(busy, st["end"], _INF)
            tmin = jnp.min(e)
            fcid = jnp.argmax(busy & (e == tmin)).astype(jnp.int32)
            return tmin, fcid, jnp.any(busy)

        def bb(e, ta, tf, fcid):
            """Strictly-before-barrier test for a CFS expiry at ``e``
            (heap order: arrivals win ties, FIFO expiries tie-break on
            core id). Accepts [C] or [C, W] expiry arrays."""
            cw = cids < fcid
            if e.ndim == 2:
                cw = cw[:, None]
            return (e < ta) & ((e < tf) | ((e == tf) & cw))

        def rotation(st):
            """Per-core runqueue pop order: queued tasks sorted by
            (vruntime, seq) — two stable argsorts == lexsort. [C, N]
            task indices; entries past ``rqn[c]`` are padding."""
            member = (st["stat"][None, :] == 2) & \
                (st["qcore"][None, :] == cids[:, None])
            skey = jnp.where(member, st["seq"][None, :], _I32MAX)
            p1 = jnp.argsort(skey, axis=1, stable=True)
            vkey = jnp.where(member, st["vr"][None, :], _INF)
            vg = jnp.take_along_axis(vkey, p1, axis=1)
            p2 = jnp.argsort(vg, axis=1, stable=True)
            return jnp.take_along_axis(p1, p2, axis=1).astype(jnp.int32)

        # -- shared pick machinery ------------------------------------
        def cfs_pick_start(st, pickm, t_c, ctx_ref):
            """Pop-and-start on every core where ``pickm`` (bool[C]).

            ``t_c``   f64[C]: the instant each picking core picks at.
            ``ctx_ref`` i32[C]: the "last_task" each core compares the
            popped task against (ctx charge iff different).
            Mirrors pick_next's rq_pop + slice_for + _start_chunk.
            """
            stat, qcore, vr, seq = st["stat"], st["qcore"], st["vr"], st["seq"]
            member = (stat[None, :] == 2) & (qcore[None, :] == cids[:, None])
            vkey = jnp.where(member, vr[None, :], _INF)
            vmin = jnp.min(vkey, axis=1)
            tie = member & (vkey == vmin[:, None])
            skey = jnp.where(tie, seq[None, :], _I32MAX)
            smin = jnp.min(skey, axis=1)
            ntid = jnp.argmax(tie & (seq[None, :] == smin[:, None]),
                              axis=1).astype(jnp.int32)
            pickm = pickm & jnp.any(member, axis=1)

            drop = jnp.where(pickm, ntid, N)
            # rq_pop: min_vruntime ratchets to the popped key.
            minvr = jnp.where(pickm, jnp.maximum(st["minvr"], vmin),
                              st["minvr"])
            rqn = st["rqn"] - pickm.astype(jnp.int32)
            stat = stat.at[drop].set(3, mode="drop")
            # slice_for reads nr_running AFTER the pop, core.task still
            # unset: nr == len(rq) == rqn.
            slc = cfs_slice_ms(rqn, LAT, GRAN, _max=jnp.maximum)
            ctx = jnp.where(ctx_ref == ntid, 0.0, CTX)
            gat = jnp.where(pickm, ntid, 0)
            fr_v = st["fr"][gat]
            fr = st["fr"].at[
                jnp.where(pickm & jnp.isnan(fr_v), ntid, N)
            ].set(t_c, mode="drop")
            nctx = st["nctx"].at[
                jnp.where(pickm & (ctx > 0.0), ntid, N)
            ].add(1, mode="drop")
            run = chunk_run_ms(st["rem"][gat], slc,
                               _min=jnp.minimum, _max=jnp.maximum)
            nend = chunk_end_ms(t_c, ctx, run)
            return dict(st, stat=stat, fr=fr, nctx=nctx, minvr=minvr,
                        rqn=rqn,
                        cur=jnp.where(pickm, ntid, st["cur"]),
                        end=jnp.where(pickm, nend, st["end"]),
                        clen=jnp.where(pickm, run, st["clen"])), pickm

        # -- step 1: stable-alternation-cycle fast-forward ------------
        # A CFS core whose runnable set is small (k = rqn + 1 <= KC
        # members) pops in a FIXED rotation while every pushback lands
        # at the queue tail: slice-expiry -> push -> pop(next) -> start
        # repeats with no shared reads.  One fixed-length lax.scan
        # walks up to W chunks across MULTIPLE rounds, carrying the
        # per-member accumulators in [C, KC] slots and the end time as
        # an explicit left fold e = (e + ctx) + run (never cumsum).
        # It stops at the first would-be completion, unstable push, or
        # barrier; the chunk left in flight retires via the window or
        # generic paths with identical arithmetic.  k == 1 is the solo
        # regime of PR 3's fast-forward (ctx == 0, slice == latency).
        def cycle_ff(st, ta, tf, fcid, rot):
            cur, rqn = st["cur"], st["rqn"]
            act0 = (~is_fifo) & (cur >= 0) & (rqn + 1 <= KC) & \
                bb(st["end"], ta, tf, fcid)
            jj = jnp.arange(KC, dtype=jnp.int32)[None, :]
            idx = jnp.concatenate([cur[:, None], rot[:, :KC - 1]], axis=1)
            valid = act0[:, None] & (jj < (rqn + 1)[:, None])
            idx = jnp.where(valid, idx, N)
            safe = jnp.minimum(idx, N - 1)
            # Members never complete inside the batch, so the queue
            # size — and with it the slice and the ctx charge — is
            # invariant across the whole scan.
            s = cfs_slice_ms(rqn, LAT, GRAN, _max=jnp.maximum)
            ctx = jnp.where(rqn > 0, CTX, 0.0)
            kk = jnp.maximum(rqn + 1, 1)
            fr0 = st["fr"][safe]
            cy = dict(
                e=st["end"], L=st["clen"], m=jnp.zeros(C, jnp.int32),
                rem=st["rem"][safe], vr=st["vr"][safe],
                # Seed with the live totals: each fire then does ONE
                # left-chained `cpu + L` exactly like the scalar
                # `task.cpu_time += chunk_len` (a zero-seeded subtotal
                # scatter-added later would reassociate the chain).
                cpu=st["cpu"][safe],
                np=jnp.zeros((C, KC), jnp.int32),
                nctx=jnp.zeros((C, KC), jnp.int32),
                sq=jnp.full((C, KC), -1, jnp.int32),
                frv=fr0, frs=~jnp.isnan(fr0),
                mv=st["minvr"], alive=act0, c=jnp.zeros(C, jnp.int32),
            )

            def step(cy, _):
                e, L, m = cy["e"], cy["L"], cy["m"]
                onem = jj == m[:, None]
                r0 = jnp.take_along_axis(cy["rem"], m[:, None], 1)[:, 0]
                v0 = jnp.take_along_axis(cy["vr"], m[:, None], 1)[:, 0]
                r2 = r0 - L
                v2 = v0 + L
                # Stability: the pushback must land at the tail — at
                # or after every queued member's key (the push's seq is
                # fresher, so an equal vruntime still sorts after).
                qmax = jnp.max(jnp.where(valid & ~onem, cy["vr"], -_INF),
                               axis=1)
                fire = cy["alive"] & (r2 > _EPS) & (v2 >= qmax) & \
                    bb(e, ta, tf, fcid)
                fm = fire[:, None] & onem
                rem_u = jnp.where(fm, r2[:, None], cy["rem"])
                vr_u = jnp.where(fm, v2[:, None], cy["vr"])
                m2 = (m + 1) % kk
                onem2 = jj == m2[:, None]
                fm2 = fire[:, None] & onem2
                r_n = jnp.take_along_axis(rem_u, m2[:, None], 1)[:, 0]
                v_pop = jnp.take_along_axis(vr_u, m2[:, None], 1)[:, 0]
                run2 = chunk_run_ms(r_n, s, _min=jnp.minimum,
                                    _max=jnp.maximum)
                e2 = chunk_end_ms(e, ctx, run2)    # the left fold
                stamp = fm2 & ~cy["frs"]
                return dict(
                    e=jnp.where(fire, e2, e),
                    L=jnp.where(fire, run2, L),
                    m=jnp.where(fire, m2, m),
                    rem=rem_u, vr=vr_u,
                    cpu=jnp.where(fm, cy["cpu"] + L[:, None], cy["cpu"]),
                    np=cy["np"] + fm.astype(jnp.int32),
                    nctx=cy["nctx"] +
                        (fm2 & (ctx > 0.0)[:, None]).astype(jnp.int32),
                    sq=jnp.where(fm, cy["c"][:, None], cy["sq"]),
                    frv=jnp.where(stamp, e[:, None], cy["frv"]),
                    frs=cy["frs"] | fm2,
                    mv=jnp.where(fire, jnp.maximum(cy["mv"], v_pop),
                                 cy["mv"]),
                    alive=fire,
                    c=cy["c"] + fire.astype(jnp.int32),
                ), None

            cy, _ = lax.scan(step, cy, None, length=W, unroll=8)

            did = act0 & (cy["c"] >= 1)
            vc = valid & did[:, None]
            tgt = jnp.where(vc, idx, N).reshape(-1)
            m_f = cy["m"]
            cur2 = jnp.take_along_axis(idx, m_f[:, None], 1)[:, 0]
            last2 = jnp.take_along_axis(idx, ((m_f - 1) % kk)[:, None],
                                        1)[:, 0]
            pushed = vc & (cy["sq"] >= 0)
            return dict(
                st,
                rem=st["rem"].at[tgt].set(cy["rem"].reshape(-1),
                                          mode="drop"),
                vr=st["vr"].at[tgt].set(cy["vr"].reshape(-1),
                                        mode="drop"),
                cpu=st["cpu"].at[tgt].set(cy["cpu"].reshape(-1),
                                          mode="drop"),
                npre=st["npre"].at[tgt].add(cy["np"].reshape(-1),
                                            mode="drop"),
                nctx=st["nctx"].at[tgt].add(cy["nctx"].reshape(-1),
                                            mode="drop"),
                fr=st["fr"].at[tgt].set(cy["frv"].reshape(-1),
                                        mode="drop"),
                seq=st["seq"].at[
                    jnp.where(pushed, idx, N).reshape(-1)
                ].set((st["seqc"][:, None] + cy["sq"]).reshape(-1),
                      mode="drop"),
                stat=st["stat"].at[tgt].set(2, mode="drop")
                    .at[jnp.where(did, cur2, N)].set(3, mode="drop"),
                cur=jnp.where(did, cur2, st["cur"]),
                last=jnp.where(did, last2, st["last"]),
                end=jnp.where(did, cy["e"], st["end"]),
                clen=jnp.where(did, cy["L"], st["clen"]),
                minvr=jnp.where(did, cy["mv"], st["minvr"]),
                seqc=st["seqc"] + jnp.where(did, cy["c"], 0),
                ev=st["ev"] + jnp.sum(jnp.where(did, cy["c"], 0),
                      dtype=jnp.int32),
            ), did

        # -- step 2: windowed rotation retirement ---------------------
        # PR 4's `_window_fast_forward` twin: evaluate ONE rotation of
        # a core's runqueue (up to W chunks) at once. Chunk 0 is the
        # in-flight chunk; chunk i >= 1 pops rotation[i - 1]. All
        # masks are vector predicates over the chunk axis; only the
        # end-time chain is sequential (explicit lax.scan left fold).
        # Completions retire inline; the integer cumsum of completion
        # flags and the cummax of push keys are the ONLY associative
        # scans (both exact under reassociation).
        def window_ff(st, elig, ta, tf, fcid, rot):
            k1 = st["rqn"]
            winm = elig & (k1 >= 1)
            cur = st["cur"]
            ii = jnp.arange(W + 1, dtype=jnp.int32)[None, :]
            u = jnp.concatenate([jnp.where(winm, cur, N)[:, None],
                                 rot[:, :W]], axis=1)       # [C, W+1]
            uvalid = winm[:, None] & (ii <= k1[:, None])
            u = jnp.where(uvalid, u, N)
            su = jnp.minimum(u, N - 1)
            rem0 = st["rem"][su]
            vr0 = st["vr"][su]
            fr0 = st["fr"][su]
            s = cfs_slice_ms(k1, LAT, GRAN, _max=jnp.maximum)
            runs = chunk_run_ms(rem0, s[:, None], _min=jnp.minimum,
                                _max=jnp.maximum)
            runs = jnp.where(ii == 0, st["clen"][:, None], runs)
            comp = chunk_completes(rem0, runs)
            cum = jnp.cumsum(comp.astype(jnp.int32), axis=1)
            cumx = jnp.concatenate(
                [jnp.zeros((C, 1), jnp.int32), cum[:, :-1]], axis=1)
            # slice at chunk i's pick: queue holds k1 - (completions
            # among chunks < i) entries after the pop.
            s_i = cfs_slice_ms(k1[:, None] - cumx, LAT, GRAN,
                               _max=jnp.maximum)
            slice_ok = (s_i == s[:, None]) | (ii == 0)
            pushed = vr0 + runs
            # Stability: a non-completing pushback must land at the
            # tail — at/after the deepest original key and every
            # earlier in-window push (exact cummax).
            pkey = jnp.where(comp, -_INF, pushed)
            # Deepest original key: rotation is sorted, so the last
            # queue entry (possibly beyond the window) carries it.
            tail_tid = jnp.take_along_axis(
                rot, jnp.maximum(k1 - 1, 0)[:, None], 1)[:, 0]
            tail0 = st["vr"][tail_tid]
            prior = jnp.concatenate(
                [tail0[:, None],
                 jnp.maximum(tail0[:, None],
                             lax.cummax(pkey, axis=1)[:, :-1])], axis=1)
            stab = pushed >= prior

            def estep(e, run_col):
                e2 = chunk_end_ms(e, CTX, run_col)
                return e2, e2

            _, etail = lax.scan(estep, st["end"], runs[:, 1:].T,
                                unroll=8)
            E = jnp.concatenate([st["end"][:, None], etail.T], axis=1)
            ok = uvalid & (ii < k1[:, None]) & (ii < W) & \
                bb(E, ta, tf, fcid) & slice_ok & (comp | stab)
            c = jnp.argmax(~ok, axis=1).astype(jnp.int32)
            did = winm & (c >= 1)

            cm = c[:, None]
            Ec1 = jnp.take_along_axis(E, jnp.maximum(cm - 1, 0), 1)[:, 0]
            cumc = jnp.take_along_axis(cumx, cm, 1)[:, 0]
            s_c = jnp.take_along_axis(s_i, cm, 1)[:, 0]
            rem_c = jnp.take_along_axis(rem0, cm, 1)[:, 0]
            run_c = chunk_run_ms(rem_c, s_c, _min=jnp.minimum,
                                 _max=jnp.maximum)
            end_c = chunk_end_ms(Ec1, CTX, run_c)
            u_c = jnp.take_along_axis(u, cm, 1)[:, 0]
            u_cp = jnp.take_along_axis(u, jnp.maximum(cm - 1, 0),
                                       1)[:, 0]
            vr0_c = jnp.take_along_axis(vr0, cm, 1)[:, 0]

            R = winm[:, None] & (ii < cm)          # retired chunks
            Rc = R & comp
            Rp = R & ~comp
            tR = jnp.where(R, u, N).reshape(-1)
            tRc = jnp.where(Rc, u, N).reshape(-1)
            tRp = jnp.where(Rp, u, N).reshape(-1)
            # picks: chunks 1..c start at the previous chunk's end;
            # rotation members are pairwise distinct and distinct from
            # the chunk-0 task, so every pick charges a ctx switch.
            P = winm[:, None] & (ii >= 1) & (ii <= cm)
            Eprev = jnp.concatenate([jnp.zeros((C, 1)), E[:, :-1]],
                                    axis=1)
            tfr = jnp.where(P & jnp.isnan(fr0), u, N).reshape(-1)
            tP = jnp.where(P, u, N).reshape(-1)
            pushseq = st["seqc"][:, None] + (ii - cumx)

            st2 = dict(
                st,
                rem=st["rem"].at[tR].set(
                    jnp.where(comp, 0.0, rem0 - runs).reshape(-1),
                    mode="drop"),
                cpu=st["cpu"].at[tR].add(runs.reshape(-1), mode="drop"),
                comp=st["comp"].at[tRc].set(E.reshape(-1), mode="drop"),
                vr=st["vr"].at[tRp].set(pushed.reshape(-1), mode="drop"),
                npre=st["npre"].at[tRp].add(1, mode="drop"),
                seq=st["seq"].at[tRp].set(pushseq.reshape(-1),
                                          mode="drop"),
                qcore=st["qcore"].at[tRp].set(
                    jnp.broadcast_to(cids[:, None], (C, W + 1)
                                     ).reshape(-1), mode="drop"),
                fr=st["fr"].at[tfr].set(Eprev.reshape(-1), mode="drop"),
                nctx=st["nctx"].at[tP].add(1, mode="drop"),
                stat=st["stat"].at[tRp].set(2, mode="drop")
                    .at[tRc].set(4, mode="drop")
                    .at[jnp.where(did, u_c, N)].set(3, mode="drop"),
                cur=jnp.where(did, u_c, st["cur"]),
                last=jnp.where(did, u_cp, st["last"]),
                end=jnp.where(did, end_c, st["end"]),
                clen=jnp.where(did, run_c, st["clen"]),
                # pops ratchet min_vruntime through nondecreasing keys:
                # the iterated max equals one max against the last pop.
                minvr=jnp.where(did,
                                jnp.maximum(st["minvr"], vr0_c),
                                st["minvr"]),
                seqc=st["seqc"] + jnp.where(did, c - cumc, 0),
                rqn=jnp.where(did, k1 - cumc, st["rqn"]),
                ev=st["ev"] + jnp.sum(jnp.where(did, c, 0), dtype=jnp.int32),
            )
            return st2, did

        # -- step 3: generic one-event CFS advance --------------------
        def cfs_advance(st, elig):
            """Advance every eligible CFS core one event: expire the
            in-flight chunk (complete or vruntime-charge + rq_push),
            then pick-and-start from the core's own runqueue — the
            exact hook order of `_run_core`."""
            cur = st["cur"]
            tid = jnp.where(elig, cur, 0)
            sidx = jnp.where(elig, cur, N)
            t_c, L = st["end"], st["clen"]
            rem2 = st["rem"][tid] - L
            d = chunk_completes(st["rem"][tid], L)  # rem2 <= _EPS, shared form
            pb = elig & ~d                      # pushback (chunk limit)
            de = elig & d                       # completion
            pidx = jnp.where(pb, cur, N)
            vr2 = st["vr"][tid] + L
            st = dict(
                st,
                rem=st["rem"].at[sidx].set(jnp.where(d, 0.0, rem2),
                                           mode="drop"),
                cpu=st["cpu"].at[sidx].add(L, mode="drop"),
                comp=st["comp"].at[jnp.where(de, cur, N)].set(t_c,
                                                              mode="drop"),
                vr=st["vr"].at[pidx].set(vr2, mode="drop"),
                npre=st["npre"].at[pidx].add(1, mode="drop"),
                seq=st["seq"].at[pidx].set(st["seqc"], mode="drop"),
                qcore=st["qcore"].at[pidx].set(cids, mode="drop"),
                stat=st["stat"].at[sidx].set(jnp.where(d, 4, 2),
                                             mode="drop"),
                seqc=st["seqc"] + pb.astype(jnp.int32),
                rqn=st["rqn"] + pb.astype(jnp.int32),
                last=jnp.where(elig, cur, st["last"]),
                cur=jnp.where(elig, -1, cur),
                # the in-flight record is consumed; an empty-rq pick
                # leaves the core idle (restored below if it picks).
                end=jnp.where(elig, _INF, st["end"]),
                clen=jnp.where(elig, 0.0, st["clen"]),
                ev=st["ev"] + jnp.sum(elig, dtype=jnp.int32),
            )
            picked, _ = cfs_pick_start(st, elig, t_c, st["last"])
            return picked

        # -- step 4: the minimal FIFO-group expiry --------------------
        def fifo_advance(st, fcid, t_f):
            c = fcid
            cur = st["cur"][c]
            tid = jnp.where(cur >= 0, cur, 0)
            L = st["clen"][c]
            rem2 = st["rem"][tid] - L
            d = chunk_completes(st["rem"][tid], L)  # rem2 <= _EPS, shared form
            st = dict(
                st,
                rem=st["rem"].at[tid].set(jnp.where(d, 0.0, rem2)),
                cpu=st["cpu"].at[tid].add(L),
                comp=jnp.where(d, st["comp"].at[tid].set(t_f), st["comp"]),
                stat=jnp.where(d, st["stat"].at[tid].set(4), st["stat"]),
                last=st["last"].at[c].set(cur),
                cur=st["cur"].at[c].set(-1),
                ev=st["ev"] + 1,
            )
            # -- budget expiry: migrate to a CFS core, round robin ----
            mig = ~d
            tgt = n_fifo + st["rrc"] % jnp.maximum(n_cfs, 1)
            midx = jnp.where(mig, tid, N)
            vrm = jnp.maximum(st["vr"][tid], st["minvr"][tgt])
            st_m = dict(
                st,
                npre=st["npre"].at[midx].add(1, mode="drop"),
                nmig=st["nmig"].at[midx].add(1, mode="drop"),
                rrc=st["rrc"] + mig.astype(jnp.int32),
                vr=st["vr"].at[midx].set(vrm, mode="drop"),
                seq=st["seq"].at[midx].set(st["seqc"][tgt], mode="drop"),
                qcore=st["qcore"].at[midx].set(tgt, mode="drop"),
                stat=st["stat"].at[midx].set(2, mode="drop"),
            )
            st_m["seqc"] = st_m["seqc"].at[jnp.where(mig, tgt, C)].add(
                1, mode="drop")
            st_m["rqn"] = st_m["rqn"].at[jnp.where(mig, tgt, C)].add(
                1, mode="drop")
            # kick(target): pick iff the target core is idle.
            kick = mig & (st_m["cur"][tgt] < 0)
            picked, _ = cfs_pick_start(
                st_m, (cids == tgt) & kick, jnp.full(C, t_f),
                st_m["last"])
            st = _sel_tree(mig, picked, st)

            # -- then the FIFO core itself picks from the global queue
            qm = st["stat"] == 1
            anyq = jnp.any(qm)
            ntid = jnp.argmax(qm).astype(jnp.int32)   # min tid: queue
            # order == arrival order == tid order (fresh tasks only).
            ctx = jnp.where(st["last"][c] == ntid, 0.0, CTX)
            fr_v = st["fr"][ntid]
            run = chunk_run_ms(st["rem"][ntid], budget,
                               _min=jnp.minimum, _max=jnp.maximum)
            nend = chunk_end_ms(t_f, ctx, run)
            started = dict(
                st,
                stat=st["stat"].at[ntid].set(3),
                fr=jnp.where(jnp.isnan(fr_v), st["fr"].at[ntid].set(t_f),
                             st["fr"]),
                nctx=jnp.where(ctx > 0.0, st["nctx"].at[ntid].add(1),
                               st["nctx"]),
                cur=st["cur"].at[c].set(ntid),
                end=st["end"].at[c].set(nend),
                clen=st["clen"].at[c].set(run),
            )
            return _sel_tree(anyq, started, st)

        # -- step 5: one arrival --------------------------------------
        def arrival_step(st, ta):
            tid = jnp.minimum(st["ptr"], N - 1)
            st = dict(st, ptr=st["ptr"] + 1, ev=st["ev"] + 1)

            # hybrid / plain-fifo routing: global FIFO queue + first
            # idle FIFO core (idle_core scans in cid order).
            st_q = dict(st, stat=st["stat"].at[tid].set(1))
            idle = is_fifo & (st_q["cur"] < 0)
            anyi = jnp.any(idle)
            c = jnp.argmax(idle).astype(jnp.int32)
            qm = st_q["stat"] == 1
            ntid = jnp.argmax(qm).astype(jnp.int32)
            ctx = jnp.where(st_q["last"][c] == ntid, 0.0, CTX)
            fr_v = st_q["fr"][ntid]
            run = chunk_run_ms(st_q["rem"][ntid], budget,
                               _min=jnp.minimum, _max=jnp.maximum)
            nend = chunk_end_ms(ta, ctx, run)
            st_d = dict(
                st_q,
                stat=st_q["stat"].at[ntid].set(3),
                fr=jnp.where(jnp.isnan(fr_v),
                             st_q["fr"].at[ntid].set(ta), st_q["fr"]),
                nctx=jnp.where(ctx > 0.0, st_q["nctx"].at[ntid].add(1),
                               st_q["nctx"]),
                cur=st_q["cur"].at[c].set(ntid),
                end=st_q["end"].at[c].set(nend),
                clen=st_q["clen"].at[c].set(run),
            )
            st_f = _sel_tree(anyi, st_d, st_q)

            # pure-CFS routing: least-loaded with rotating scan start,
            # early-exit on idle == lexicographic (nr, rotation) argmin.
            nr = st["rqn"] + (st["cur"] >= 0).astype(jnp.int32)
            rot = (cids - st["rr"]) % C
            nmin = jnp.min(nr)
            cand = nr == nmin
            rmin = jnp.min(jnp.where(cand, rot, C))
            core = jnp.argmax(cand & (rot == rmin)).astype(jnp.int32)
            vrp = jnp.maximum(st["vr"][tid], st["minvr"][core])
            st_c = dict(
                st,
                rr=(st["rr"] + 1) % C,
                vr=st["vr"].at[tid].set(vrp),
                seq=st["seq"].at[tid].set(st["seqc"][core]),
                qcore=st["qcore"].at[tid].set(core),
                stat=st["stat"].at[tid].set(2),
                seqc=st["seqc"].at[core].add(1),
                rqn=st["rqn"].at[core].add(1),
            )
            kick = st_c["cur"][core] < 0
            picked, _ = cfs_pick_start(
                st_c, (cids == core) & kick, jnp.full(C, ta),
                st_c["last"])
            st_c = _sel_tree(kick, picked, st_c)

            return _sel_tree(n_fifo > 0, st_f, st_c)

        # -- one-event micro step (PR 7's whole body): barriers, then
        # exactly one of {generic CFS advance on all eligible cores,
        # earliest FIFO expiry, next arrival}. No rotation, no scan.
        def micro(st):
            ta = t_arr(st)
            tf, fcid, anyf = fifo_candidate(st)
            elig = (~is_fifo) & (st["cur"] >= 0) & \
                bb(st["end"], ta, tf, fcid)
            any_cfs = jnp.any(elig)
            do_f = anyf & ~any_cfs & (tf < ta)
            do_a = ~any_cfs & ~do_f & (st["ptr"] < n_tasks)
            st_cfs = cfs_advance(st, elig)
            st_fifo = fifo_advance(st, fcid, tf)
            st_arr = arrival_step(st, ta)
            return _sel_tree(
                any_cfs, st_cfs,
                _sel_tree(do_f, st_fifo, _sel_tree(do_a, st_arr, st)))

        # -- outer loop ------------------------------------------------
        max_it = jnp.int32(_MAX_ITERS_PER_TASK) * \
            jnp.maximum(n_tasks, 1) + 64

        def cond(st):
            live = (st["ptr"] < n_tasks) | jnp.any(st["cur"] >= 0)
            return live & (st["it"] < max_it)

        def body(st):
            st = dict(st, it=st["it"] + 1)
            ta = t_arr(st)
            tf, fcid, _ = fifo_candidate(st)
            # ONE rotation serves both engines: the cycle commits task
            # state only on the cores it fires, so the pre-cycle rows
            # of every unfired core are still the exact pop order the
            # window needs (fired-but-still-eligible cores fall to the
            # rotation-free generic advance this iteration).
            rot = rotation(st)
            st, cdid = cycle_ff(st, ta, tf, fcid, rot)

            tf, fcid, anyf = fifo_candidate(st)
            e = st["end"]
            elig = (~is_fifo) & (st["cur"] >= 0) & bb(e, ta, tf, fcid)
            any_cfs = jnp.any(elig)
            do_f = anyf & ~any_cfs & (tf < ta)
            do_a = ~any_cfs & ~do_f & (st["ptr"] < n_tasks)

            st_w, handled = window_ff(st, elig & ~cdid, ta, tf, fcid,
                                      rot)
            st_cfs = cfs_advance(st_w, elig & ~handled)
            st_fifo = fifo_advance(st, fcid, tf)
            st_arr = arrival_step(st, ta)
            stn = _sel_tree(
                any_cfs, st_cfs,
                _sel_tree(do_f, st_fifo, _sel_tree(do_a, st_arr, st)))

            # Micro-step chain: the sparse phases (arrival interleave,
            # FIFO expiries, unstable pushes) advance one event at a
            # time; retiring a handful of them per while-loop trip with
            # the sort-free one-event machinery amortizes the fixed
            # per-iteration cost (rotation sorts, cycle/window scans,
            # state selects) over several events.
            stn = lax.fori_loop(0, _MICRO_STEPS,
                                lambda _, s: micro(s), stn)
            return stn

        out = lax.while_loop(cond, body, st)
        live = jnp.arange(N) < n_tasks
        ok = jnp.all(jnp.where(live, out["stat"] == 4, True)) & \
            (out["it"] < max_it)
        return dict(completion=out["comp"], first_run=out["fr"],
                    preemptions=out["npre"], ctx_switches=out["nctx"],
                    migrations=out["nmig"], cpu_time=out["cpu"],
                    ok=ok, n_iters=out["it"], n_events=out["ev"])

    return kernel


# One compiled program per (C, N) shape bucket; each call batches an
# arbitrary number of cells along the leading axis.
_GRID_CACHE: dict = {}


def grid_kernel(n_cores: int, n_slots: int):
    key = (n_cores, n_slots)
    fn = _GRID_CACHE.get(key)
    if fn is None:
        fn = jax.jit(jax.vmap(make_cell_kernel(n_cores, n_slots)))
        _GRID_CACHE[key] = fn
    return fn


def run_grid(arrival, service, n_tasks, n_fifo, limit, *, n_cores: int):
    """Advance a whole grid of cells in one device program.

    arrival, service : f64[B, N]
    n_tasks, n_fifo  : i32[B]
    limit            : f64[B]

    Returns a dict of [B, N] observable arrays (see ``make_cell_kernel``)
    as NumPy, computed under x64 on whatever backend JAX selected.
    """
    from jax.experimental import enable_x64

    n_slots = arrival.shape[1]
    with enable_x64():
        fn = grid_kernel(n_cores, n_slots)
        out = fn(jnp.asarray(arrival, jnp.float64),
                 jnp.asarray(service, jnp.float64),
                 jnp.asarray(n_tasks, jnp.int32),
                 jnp.asarray(n_fifo, jnp.int32),
                 jnp.asarray(limit, jnp.float64))
        return {k: jax.device_get(v) for k, v in out.items()}
