"""Batched Monte-Carlo kernels for the supported scheduling regime.

One cell = one (policy, seed, load) trajectory of the single-node
engine; the kernel below advances a WHOLE GRID of cells in a single
compiled XLA program via ``jax.vmap`` (DESIGN.md Sec. 16).

The kernel is a faithful re-expression of the scalar event loop
(`core/events.py` + `core/policies.py` + `core/hybrid.py`) restricted
to the regime the analytic fast-forwards already closed:

* single node, no container pool, no interference, no util timers,
* policies: ``fifo``, ``cfs``, ``hybrid`` with a STATIC time limit,
* default Linux knobs (sched_latency 24 ms, min_granularity 3 ms,
  ctx_switch 0.06 ms).

Within that regime the event braid has exactly three interacting
event classes, totally ordered by the scalar heap key ``(t, klass,
tie)``: arrivals (klass 0, tid order), hybrid FIFO-core expiries
(klass 2, cid tie-break — they touch shared state: the global queue,
migration round-robin, CFS runqueues), and CFS-core expiries (klass 2,
core-local).  CFS expiries before the next arrival/FIFO barrier are
INDEPENDENT across cores, so the kernel advances every eligible CFS
core in one vectorized step, and cycles lone-task cores (empty
runqueue — the solo regime PR 3's fast-forward batches) in a cheap
``[C]``-wide inner loop.  Barrier events (arrivals in tid order, the
minimal FIFO expiry) are then re-serialized exactly as the heap
would.

Bit-identity contract: under ``jax_enable_x64`` on the CPU backend
every float is computed by the SAME operation sequence as the scalar
engine — the shared pure helpers of ``core/events.py``
(`chunk_run_ms`, `chunk_end_ms`, `cfs_slice_ms`, `fifo_budget_ms`)
re-bound to ``jnp.minimum``/``jnp.maximum`` — so per-task digests
(completion, first_run, preemptions, ctx_switches, migrations) and
every cost roll-up derived from them match the scalar engine
bit-for-bit.  XLA's CPU backend does not reassociate or fuse these
scalar chains (no FMA contraction across the explicit ``(t + ctx) +
run`` ordering), which the golden equivalence battery pins.

A plain-FIFO cell runs as the hybrid machinery with ``n_fifo == C``
and an infinite budget: ``min(rem, inf) == rem`` and ``max(inf - 0.0,
0.01) == inf`` are bitwise no-ops, completions always beat the
(unreachable) migration branch, so the braid degenerates to FIFO's
run-to-completion semantics with identical arithmetic.  A pure-CFS
cell is ``n_fifo == 0``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.events import (_EPS, cfs_slice_ms, chunk_end_ms,
                               chunk_run_ms, fifo_budget_ms)

# Default Linux knobs of the supported regime (see module docstring);
# the dispatch gate (repro.mc.dispatch) refuses cells that override
# them, so baking them into the compiled program is safe.
SCHED_LATENCY_MS = 24.0
MIN_GRANULARITY_MS = 3.0
CTX_SWITCH_MS = 0.06

_INF = float("inf")
_I32MAX = 2 ** 31 - 1

# Safety valve: an upper bound on outer-loop iterations so a regime
# bug hangs nothing — the engine checks the `ok` output and raises.
# Every processed event makes >= min_granularity progress on some
# task (or completes/queues one), so real cells sit far below this.
_MAX_ITERS_PER_TASK = 1024


def _sel_tree(pred, new, old):
    """Per-cell select between two state pytrees (scalar bool pred)."""
    return {k: jnp.where(pred, new[k], old[k]) for k in old}


def make_cell_kernel(n_cores: int, n_slots: int):
    """Build the single-cell simulator for a static shape bucket.

    ``n_cores`` (C) and ``n_slots`` (N, padded task capacity) are
    compile-time constants; everything per-cell (arrival/service
    arrays, task count, FIFO split, migration budget) is traced, so
    one compilation serves every cell of the bucket and ``jax.vmap``
    batches them into a single program.
    """
    C, N = n_cores, n_slots
    LAT, GRAN, CTX = SCHED_LATENCY_MS, MIN_GRANULARITY_MS, CTX_SWITCH_MS
    # The solo regime picks with an empty runqueue: nr_running == 0
    # after the pop, so the slice is the full target latency. Computed
    # through the SAME shared helper the scalar engine uses.
    SOLO_SLICE = cfs_slice_ms(0, LAT, GRAN)

    cids = jnp.arange(C, dtype=jnp.int32)

    def kernel(arrival, service, n_tasks, n_fifo, limit):
        """Run one cell to completion.

        arrival, service : f64[N]   (padded with +inf / 1.0)
        n_tasks          : i32      live prefix length
        n_fifo           : i32      C => plain FIFO, 0 => pure CFS
        limit            : f64      FIFO budget (inf outside hybrid)
        """
        is_fifo = cids < n_fifo
        n_cfs = C - n_fifo
        budget = fifo_budget_ms(limit, 0.0, _max=jnp.maximum)

        st = dict(
            # per-task
            rem=service,
            vr=jnp.zeros(N),
            seq=jnp.zeros(N, jnp.int32),
            qcore=jnp.zeros(N, jnp.int32),
            stat=jnp.zeros(N, jnp.int32),   # 0 unarrived, 1 fifo-q,
                                            # 2 on-rq, 3 running, 4 done
            fr=jnp.full(N, jnp.nan),
            comp=jnp.full(N, jnp.nan),
            npre=jnp.zeros(N, jnp.int32),
            nctx=jnp.zeros(N, jnp.int32),
            nmig=jnp.zeros(N, jnp.int32),
            # per-core
            cur=jnp.full(C, -1, jnp.int32),
            end=jnp.full(C, _INF),
            clen=jnp.zeros(C),
            last=jnp.full(C, -1, jnp.int32),
            minvr=jnp.zeros(C),
            seqc=jnp.zeros(C, jnp.int32),
            rqn=jnp.zeros(C, jnp.int32),
            # scalars
            ptr=jnp.int32(0),
            rr=jnp.int32(0),
            rrc=jnp.int32(0),
            it=jnp.int32(0),
        )

        def t_arr(st):
            p = st["ptr"]
            return jnp.where(p < n_tasks, arrival[jnp.minimum(p, N - 1)],
                             _INF)

        def fifo_candidate(st):
            """Minimal pending FIFO-group expiry: (time, cid, any)."""
            busy = is_fifo & (st["cur"] >= 0)
            e = jnp.where(busy, st["end"], _INF)
            tmin = jnp.min(e)
            fcid = jnp.argmax(busy & (e == tmin)).astype(jnp.int32)
            return tmin, fcid, jnp.any(busy)

        # -- shared pick machinery ------------------------------------
        def cfs_pick_start(st, pickm, t_c, ctx_ref):
            """Pop-and-start on every core where ``pickm`` (bool[C]).

            ``t_c``   f64[C]: the instant each picking core picks at.
            ``ctx_ref`` i32[C]: the "last_task" each core compares the
            popped task against (ctx charge iff different).
            Mirrors pick_next's rq_pop + slice_for + _start_chunk.
            """
            stat, qcore, vr, seq = st["stat"], st["qcore"], st["vr"], st["seq"]
            member = (stat[None, :] == 2) & (qcore[None, :] == cids[:, None])
            vkey = jnp.where(member, vr[None, :], _INF)
            vmin = jnp.min(vkey, axis=1)
            tie = member & (vkey == vmin[:, None])
            skey = jnp.where(tie, seq[None, :], _I32MAX)
            smin = jnp.min(skey, axis=1)
            ntid = jnp.argmax(tie & (seq[None, :] == smin[:, None]),
                              axis=1).astype(jnp.int32)
            pickm = pickm & jnp.any(member, axis=1)

            drop = jnp.where(pickm, ntid, N)
            # rq_pop: min_vruntime ratchets to the popped key.
            minvr = jnp.where(pickm, jnp.maximum(st["minvr"], vmin),
                              st["minvr"])
            rqn = st["rqn"] - pickm.astype(jnp.int32)
            stat = stat.at[drop].set(3, mode="drop")
            # slice_for reads nr_running AFTER the pop, core.task still
            # unset: nr == len(rq) == rqn.
            slc = cfs_slice_ms(rqn, LAT, GRAN, _max=jnp.maximum)
            ctx = jnp.where(ctx_ref == ntid, 0.0, CTX)
            gat = jnp.where(pickm, ntid, 0)
            fr_v = st["fr"][gat]
            fr = st["fr"].at[
                jnp.where(pickm & jnp.isnan(fr_v), ntid, N)
            ].set(t_c, mode="drop")
            nctx = st["nctx"].at[
                jnp.where(pickm & (ctx > 0.0), ntid, N)
            ].add(1, mode="drop")
            run = chunk_run_ms(st["rem"][gat], slc,
                               _min=jnp.minimum, _max=jnp.maximum)
            nend = chunk_end_ms(t_c, ctx, run)
            return dict(st, stat=stat, fr=fr, nctx=nctx, minvr=minvr,
                        rqn=rqn,
                        cur=jnp.where(pickm, ntid, st["cur"]),
                        end=jnp.where(pickm, nend, st["end"]),
                        clen=jnp.where(pickm, run, st["clen"])), pickm

        # -- step 1: solo fast path -----------------------------------
        # A CFS core running its only task (empty rq) cycles
        # slice-expiry -> push -> pop(self) -> start with no shared
        # reads: batch those rounds in a [C]-wide inner loop, bounded
        # by the SAME barrier the eligibility test uses.
        def solo_loop(st, ta, tf, fcid):
            def before_barrier(e):
                return (e < ta) & ((e < tf) | ((e == tf) & (cids < fcid)))

            cur, rqn = st["cur"], st["rqn"]
            act0 = (~is_fifo) & (cur >= 0) & (rqn == 0) & \
                before_barrier(st["end"])
            tid = jnp.where(cur >= 0, cur, 0)
            lane0 = dict(
                act=act0, any=act0,
                t=st["end"], L=st["clen"],
                r=st["rem"][tid], v=st["vr"][tid],
                mv=st["minvr"],
                np=jnp.zeros(C, jnp.int32), sq=jnp.zeros(C, jnp.int32),
                done=jnp.zeros(C, bool), ct=jnp.zeros(C),
            )

            def body(ln):
                r2 = ln["r"] - ln["L"]
                d = r2 <= _EPS
                v2 = ln["v"] + ln["L"]
                mv2 = jnp.maximum(ln["mv"], v2)
                run = chunk_run_ms(r2, SOLO_SLICE,
                                   _min=jnp.minimum, _max=jnp.maximum)
                # ctx == 0.0: the core keeps its own task.
                t2 = chunk_end_ms(ln["t"], 0.0, run)
                cont = ln["act"] & ~d & before_barrier(t2)
                a = ln["act"]
                nd = a & d
                adv = a & ~d
                return dict(
                    act=cont, any=ln["any"] | a,
                    t=jnp.where(adv, t2, ln["t"]),
                    L=jnp.where(adv, run, ln["L"]),
                    r=jnp.where(a, jnp.where(d, 0.0, r2), ln["r"]),
                    v=jnp.where(adv, v2, ln["v"]),
                    mv=jnp.where(adv, mv2, ln["mv"]),
                    np=ln["np"] + adv.astype(jnp.int32),
                    sq=ln["sq"] + adv.astype(jnp.int32),
                    done=ln["done"] | nd,
                    ct=jnp.where(nd, ln["t"], ln["ct"]),
                )

            ln = lax.while_loop(lambda ln: jnp.any(ln["act"]), body, lane0)

            touched = ln["any"]
            sidx = jnp.where(touched, tid, N)
            didx = jnp.where(ln["done"], tid, N)
            return dict(
                st,
                rem=st["rem"].at[sidx].set(ln["r"], mode="drop"),
                vr=st["vr"].at[sidx].set(ln["v"], mode="drop"),
                npre=st["npre"].at[sidx].add(ln["np"], mode="drop"),
                comp=st["comp"].at[didx].set(ln["ct"], mode="drop"),
                stat=st["stat"].at[didx].set(4, mode="drop"),
                minvr=jnp.where(touched, ln["mv"], st["minvr"]),
                seqc=st["seqc"] + ln["sq"],
                last=jnp.where(touched, tid, st["last"]),
                cur=jnp.where(ln["done"], -1, st["cur"]),
                end=jnp.where(ln["done"], _INF,
                              jnp.where(touched, ln["t"], st["end"])),
                clen=jnp.where(ln["done"], 0.0,
                               jnp.where(touched, ln["L"], st["clen"])),
            )

        # -- step 2: vectorized CFS expiries --------------------------
        def cfs_advance(st, elig):
            """Advance every eligible CFS core one event: expire the
            in-flight chunk (complete or vruntime-charge + rq_push),
            then pick-and-start from the core's own runqueue — the
            exact hook order of `_run_core`."""
            cur = st["cur"]
            tid = jnp.where(elig, cur, 0)
            sidx = jnp.where(elig, cur, N)
            t_c, L = st["end"], st["clen"]
            rem2 = st["rem"][tid] - L
            d = rem2 <= _EPS
            pb = elig & ~d                      # pushback (chunk limit)
            de = elig & d                       # completion
            pidx = jnp.where(pb, cur, N)
            vr2 = st["vr"][tid] + L
            st = dict(
                st,
                rem=st["rem"].at[sidx].set(jnp.where(d, 0.0, rem2),
                                           mode="drop"),
                comp=st["comp"].at[jnp.where(de, cur, N)].set(t_c,
                                                              mode="drop"),
                vr=st["vr"].at[pidx].set(vr2, mode="drop"),
                npre=st["npre"].at[pidx].add(1, mode="drop"),
                seq=st["seq"].at[pidx].set(st["seqc"], mode="drop"),
                qcore=st["qcore"].at[pidx].set(cids, mode="drop"),
                stat=st["stat"].at[sidx].set(jnp.where(d, 4, 2),
                                             mode="drop"),
                seqc=st["seqc"] + pb.astype(jnp.int32),
                rqn=st["rqn"] + pb.astype(jnp.int32),
                last=jnp.where(elig, cur, st["last"]),
                cur=jnp.where(elig, -1, cur),
                # the in-flight record is consumed; an empty-rq pick
                # leaves the core idle (restored below if it picks).
                end=jnp.where(elig, _INF, st["end"]),
                clen=jnp.where(elig, 0.0, st["clen"]),
            )
            picked, _ = cfs_pick_start(st, elig, t_c, st["last"])
            return picked

        # -- step 3: the minimal FIFO-group expiry --------------------
        def fifo_advance(st, fcid, t_f):
            c = fcid
            cur = st["cur"][c]
            tid = jnp.where(cur >= 0, cur, 0)
            L = st["clen"][c]
            rem2 = st["rem"][tid] - L
            d = rem2 <= _EPS
            st = dict(
                st,
                rem=st["rem"].at[tid].set(jnp.where(d, 0.0, rem2)),
                comp=jnp.where(d, st["comp"].at[tid].set(t_f), st["comp"]),
                stat=jnp.where(d, st["stat"].at[tid].set(4), st["stat"]),
                last=st["last"].at[c].set(cur),
                cur=st["cur"].at[c].set(-1),
            )
            # -- budget expiry: migrate to a CFS core, round robin ----
            mig = ~d
            tgt = n_fifo + st["rrc"] % jnp.maximum(n_cfs, 1)
            midx = jnp.where(mig, tid, N)
            vrm = jnp.maximum(st["vr"][tid], st["minvr"][tgt])
            st_m = dict(
                st,
                npre=st["npre"].at[midx].add(1, mode="drop"),
                nmig=st["nmig"].at[midx].add(1, mode="drop"),
                rrc=st["rrc"] + mig.astype(jnp.int32),
                vr=st["vr"].at[midx].set(vrm, mode="drop"),
                seq=st["seq"].at[midx].set(st["seqc"][tgt], mode="drop"),
                qcore=st["qcore"].at[midx].set(tgt, mode="drop"),
                stat=st["stat"].at[midx].set(2, mode="drop"),
            )
            st_m["seqc"] = st_m["seqc"].at[jnp.where(mig, tgt, C)].add(
                1, mode="drop")
            st_m["rqn"] = st_m["rqn"].at[jnp.where(mig, tgt, C)].add(
                1, mode="drop")
            # kick(target): pick iff the target core is idle.
            kick = mig & (st_m["cur"][tgt] < 0)
            picked, _ = cfs_pick_start(
                st_m, (cids == tgt) & kick, jnp.full(C, t_f),
                st_m["last"])
            st = _sel_tree(mig, picked, st)

            # -- then the FIFO core itself picks from the global queue
            qm = st["stat"] == 1
            anyq = jnp.any(qm)
            ntid = jnp.argmax(qm).astype(jnp.int32)   # min tid: queue
            # order == arrival order == tid order (fresh tasks only).
            ctx = jnp.where(st["last"][c] == ntid, 0.0, CTX)
            fr_v = st["fr"][ntid]
            run = chunk_run_ms(st["rem"][ntid], budget,
                               _min=jnp.minimum, _max=jnp.maximum)
            nend = chunk_end_ms(t_f, ctx, run)
            started = dict(
                st,
                stat=st["stat"].at[ntid].set(3),
                fr=jnp.where(jnp.isnan(fr_v), st["fr"].at[ntid].set(t_f),
                             st["fr"]),
                nctx=jnp.where(ctx > 0.0, st["nctx"].at[ntid].add(1),
                               st["nctx"]),
                cur=st["cur"].at[c].set(ntid),
                end=st["end"].at[c].set(nend),
                clen=st["clen"].at[c].set(run),
            )
            return _sel_tree(anyq, started, st)

        # -- step 4: one arrival --------------------------------------
        def arrival_step(st, ta):
            tid = jnp.minimum(st["ptr"], N - 1)
            st = dict(st, ptr=st["ptr"] + 1)

            # hybrid / plain-fifo routing: global FIFO queue + first
            # idle FIFO core (idle_core scans in cid order).
            st_q = dict(st, stat=st["stat"].at[tid].set(1))
            idle = is_fifo & (st_q["cur"] < 0)
            anyi = jnp.any(idle)
            c = jnp.argmax(idle).astype(jnp.int32)
            qm = st_q["stat"] == 1
            ntid = jnp.argmax(qm).astype(jnp.int32)
            ctx = jnp.where(st_q["last"][c] == ntid, 0.0, CTX)
            fr_v = st_q["fr"][ntid]
            run = chunk_run_ms(st_q["rem"][ntid], budget,
                               _min=jnp.minimum, _max=jnp.maximum)
            nend = chunk_end_ms(ta, ctx, run)
            st_d = dict(
                st_q,
                stat=st_q["stat"].at[ntid].set(3),
                fr=jnp.where(jnp.isnan(fr_v),
                             st_q["fr"].at[ntid].set(ta), st_q["fr"]),
                nctx=jnp.where(ctx > 0.0, st_q["nctx"].at[ntid].add(1),
                               st_q["nctx"]),
                cur=st_q["cur"].at[c].set(ntid),
                end=st_q["end"].at[c].set(nend),
                clen=st_q["clen"].at[c].set(run),
            )
            st_f = _sel_tree(anyi, st_d, st_q)

            # pure-CFS routing: least-loaded with rotating scan start,
            # early-exit on idle == lexicographic (nr, rotation) argmin.
            nr = st["rqn"] + (st["cur"] >= 0).astype(jnp.int32)
            rot = (cids - st["rr"]) % C
            nmin = jnp.min(nr)
            cand = nr == nmin
            rmin = jnp.min(jnp.where(cand, rot, C))
            core = jnp.argmax(cand & (rot == rmin)).astype(jnp.int32)
            vrp = jnp.maximum(st["vr"][tid], st["minvr"][core])
            st_c = dict(
                st,
                rr=(st["rr"] + 1) % C,
                vr=st["vr"].at[tid].set(vrp),
                seq=st["seq"].at[tid].set(st["seqc"][core]),
                qcore=st["qcore"].at[tid].set(core),
                stat=st["stat"].at[tid].set(2),
                seqc=st["seqc"].at[core].add(1),
                rqn=st["rqn"].at[core].add(1),
            )
            kick = st_c["cur"][core] < 0
            picked, _ = cfs_pick_start(
                st_c, (cids == core) & kick, jnp.full(C, ta),
                st_c["last"])
            st_c = _sel_tree(kick, picked, st_c)

            return _sel_tree(n_fifo > 0, st_f, st_c)

        # -- outer loop ------------------------------------------------
        max_it = jnp.int32(_MAX_ITERS_PER_TASK) * \
            jnp.maximum(n_tasks, 1) + 64

        def cond(st):
            live = (st["ptr"] < n_tasks) | jnp.any(st["cur"] >= 0)
            return live & (st["it"] < max_it)

        def body(st):
            st = dict(st, it=st["it"] + 1)
            ta = t_arr(st)
            tf, fcid, _ = fifo_candidate(st)
            st = solo_loop(st, ta, tf, fcid)

            tf, fcid, anyf = fifo_candidate(st)
            e = st["end"]
            elig = (~is_fifo) & (st["cur"] >= 0) & (e < ta) & \
                ((e < tf) | ((e == tf) & (cids < fcid)))
            any_cfs = jnp.any(elig)
            do_f = anyf & ~any_cfs & (tf < ta)
            do_a = ~any_cfs & ~do_f & (st["ptr"] < n_tasks)

            st_cfs = cfs_advance(st, elig)
            st_fifo = fifo_advance(st, fcid, tf)
            st_arr = arrival_step(st, ta)
            return _sel_tree(
                any_cfs, st_cfs,
                _sel_tree(do_f, st_fifo, _sel_tree(do_a, st_arr, st)))

        out = lax.while_loop(cond, body, st)
        live = jnp.arange(N) < n_tasks
        ok = jnp.all(jnp.where(live, out["stat"] == 4, True)) & \
            (out["it"] < max_it)
        return dict(completion=out["comp"], first_run=out["fr"],
                    preemptions=out["npre"], ctx_switches=out["nctx"],
                    migrations=out["nmig"], ok=ok, n_iters=out["it"])

    return kernel


# One compiled program per (C, N) shape bucket; each call batches an
# arbitrary number of cells along the leading axis.
_GRID_CACHE: dict = {}


def grid_kernel(n_cores: int, n_slots: int):
    key = (n_cores, n_slots)
    fn = _GRID_CACHE.get(key)
    if fn is None:
        fn = jax.jit(jax.vmap(make_cell_kernel(n_cores, n_slots)))
        _GRID_CACHE[key] = fn
    return fn


def run_grid(arrival, service, n_tasks, n_fifo, limit, *, n_cores: int):
    """Advance a whole grid of cells in one device program.

    arrival, service : f64[B, N]
    n_tasks, n_fifo  : i32[B]
    limit            : f64[B]

    Returns a dict of [B, N] observable arrays (see ``make_cell_kernel``)
    as NumPy, computed under x64 on whatever backend JAX selected.
    """
    from jax.experimental import enable_x64

    n_slots = arrival.shape[1]
    with enable_x64():
        fn = grid_kernel(n_cores, n_slots)
        out = fn(jnp.asarray(arrival, jnp.float64),
                 jnp.asarray(service, jnp.float64),
                 jnp.asarray(n_tasks, jnp.int32),
                 jnp.asarray(n_fifo, jnp.int32),
                 jnp.asarray(limit, jnp.float64))
        return {k: jax.device_get(v) for k, v in out.items()}
