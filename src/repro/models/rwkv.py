"""RWKV6 ("Finch") block — linear attention with data-dependent decay.

Time-mix: per-channel decay w_t = exp(-exp(lora(x_t))) (the Finch
feature), multi-head matrix-valued state S: (B, nh, hd, hd) updated as
    S_t = diag(w_t) @ S_{t-1} + k_t^T v_t,   o_t = r_t @ (S_{t-1} + u k_t^T v_t)
Channel-mix: squared-ReLU FFN with token shift.

Train/prefill scans over time in CHUNKS (sequential scan over chunks,
within-chunk parallel form), so HLO stays small at 32k tokens. Decode is
a constant-memory state update (what makes rwkv6 long_500k decode cheap).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.params import ParamSpec
from ..distributed.sharding import shard
from .layers import bf16


def rwkv_dims(cfg: ModelConfig):
    nh = cfg.d_model // cfg.rwkv_head_dim
    return nh, cfg.rwkv_head_dim


def rwkv_specs(cfg: ModelConfig, layers: int = 1) -> dict:
    d = cfg.d_model
    nh, hd = rwkv_dims(cfg)
    lead = (layers,) if layers > 1 else ()
    lax_ = (None,) if layers > 1 else ()
    lora = 64
    spec = {
        # time-mix
        "tm_norm": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
        "mu_r": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
        "mu_k": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
        "mu_v": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
        "mu_g": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
        "mu_w": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
        "w_r": ParamSpec(lead + (d, d), lax_ + ("embed_w", "qkv")),
        "w_k": ParamSpec(lead + (d, d), lax_ + ("embed_w", "qkv")),
        "w_v": ParamSpec(lead + (d, d), lax_ + ("embed_w", "qkv")),
        "w_g": ParamSpec(lead + (d, d), lax_ + ("embed_w", "qkv")),
        "w_o": ParamSpec(lead + (d, d), lax_ + ("qkv", "embed_w"),
                         scale=1.0 / math.sqrt(2 * cfg.n_layers)),
        # data-dependent decay LoRA (Finch)
        "wd_a": ParamSpec(lead + (d, lora), lax_ + ("embed_w", None)),
        "wd_b": ParamSpec(lead + (lora, d), lax_ + (None, None)),
        "w_bias": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
        "u": ParamSpec(lead + (nh, hd), lax_ + (None, None), init="zeros"),
        "o_norm": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
        # channel-mix
        "cm_norm": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
        "mu_ck": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
        "w_ck": ParamSpec(lead + (d, cfg.d_ff), lax_ + ("embed_w", "mlp")),
        "w_cv": ParamSpec(lead + (cfg.d_ff, d), lax_ + ("mlp", "embed_w"),
                          scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }
    return spec


def _token_shift(x, prev):
    """shifted[t] = x[t-1]; shifted[0] = prev (carry across chunks)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _lerp(x, shifted, mu):
    return x + (shifted - x) * mu.astype(x.dtype)


RWKV_CHUNK = 16           # chunked-parallel block (exponent-safe in f32)
_LOGW_MIN = -4.0          # clamp per-step log-decay (|cum| <= 64 in-chunk)


def _time_mix_projections(p, x, cfg: ModelConfig, state: dict):
    from .layers import rmsnorm
    B, S, d = x.shape
    nh, hd = rwkv_dims(cfg)
    h = rmsnorm(x, p["tm_norm"], cfg.norm_eps)
    shifted = _token_shift(h, state["x_tm"])
    r = _lerp(h, shifted, p["mu_r"]) @ bf16(p["w_r"])
    k = _lerp(h, shifted, p["mu_k"]) @ bf16(p["w_k"])
    v = _lerp(h, shifted, p["mu_v"]) @ bf16(p["w_v"])
    g = jax.nn.silu(_lerp(h, shifted, p["mu_g"]) @ bf16(p["w_g"]))
    xw = _lerp(h, shifted, p["mu_w"])
    logw = -jnp.exp(((xw @ bf16(p["wd_a"])) @ bf16(p["wd_b"])
                     + p["w_bias"]).astype(jnp.float32))
    logw = jnp.maximum(logw, _LOGW_MIN)
    rh = r.reshape(B, S, nh, hd).astype(jnp.float32)
    kh = k.reshape(B, S, nh, hd).astype(jnp.float32)
    vh = v.reshape(B, S, nh, hd).astype(jnp.float32)
    lw = logw.reshape(B, S, nh, hd)
    return h, rh, kh, vh, lw, g


def rwkv_time_mix(p, x, cfg: ModelConfig, state: dict):
    """Full-sequence time-mix.

    Uses the CHUNKED-PARALLEL form (matrix state advanced once per
    16-token chunk; intra-chunk term as decay-weighted (Q,Q) matmuls)
    whenever S is a chunk multiple — the per-timestep sequential scan
    re-reads the (nh,hd,hd) state from HBM every token, which made
    rwkv6 train_4k 2488 s memory-bound in the baseline dry-run
    (EXPERIMENTS.md Sec. Perf, iteration R1). Sequential scan kept as
    the S==1 / ragged fallback.

    x: (B,S,d). state: {"S": (B,nh,hd,hd), "x_tm": (B,d)}.
    """
    from .layers import rmsnorm
    B, S, d = x.shape
    nh, hd = rwkv_dims(cfg)
    h, rh, kh, vh, lw, g = _time_mix_projections(p, x, cfg, state)

    if S % RWKV_CHUNK == 0 and S > 1:
        S_final, o = _time_mix_chunked(p, rh, kh, vh, lw, state["S"])
    else:
        S_final, o = _time_mix_sequential(p, rh, kh, vh, lw, state["S"])
    o = o.reshape(B, S, d)
    o = rmsnorm(o.astype(x.dtype), p["o_norm"], cfg.norm_eps) * g
    out = (o @ bf16(p["w_o"])).astype(x.dtype)
    new_state = {"S": S_final, "x_tm": h[:, -1].astype(jnp.float32)}
    return shard(out, "batch", "seq", None), new_state


def _time_mix_sequential(p, rh, kh, vh, lw, S0):
    B, S, nh, hd = rh.shape
    wh = jnp.exp(lw)

    def step(S_, inp):
        r_t, k_t, v_t, w_t = inp                         # (B,nh,hd)
        kv = jnp.einsum("bnk,bnv->bnkv", k_t, v_t)
        o = jnp.einsum("bnk,bnkv->bnv", r_t,
                       S_ + p["u"][None, :, :, None] * kv)
        S_ = w_t[..., None] * S_ + kv
        return S_, o

    inputs = (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
              vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3))
    S_final, os = jax.lax.scan(step, S0, inputs)
    return S_final, os.transpose(1, 0, 2, 3)


def _time_mix_chunked(p, rh, kh, vh, lw, S0, chunk: int = RWKV_CHUNK):
    """Exact chunked-parallel RWKV6 (diagonal data-dependent decay):

    with per-chunk cumulative log-decay c_t (reset each chunk),
      o_t = (r_t * e^{c_{t-1}}) @ S_chunk + sum_{j<t} [(r_t e^{c_{t-1}})
            . (k_j e^{-c_j})] v_j + (r_t . (u*k_t)) v_t
      S'  = e^{c_Q} * S_chunk + sum_j (k_j e^{c_Q - c_j})^T v_j
    All exponents are <= 0 except e^{-c_j} in the score term, bounded by
    chunk * |LOGW_MIN| (safe in f32 for chunk=16)."""
    B, S, nh, hd = rh.shape
    Q = chunk
    nc = S // Q
    r_c = rh.reshape(B, nc, Q, nh, hd)
    k_c = kh.reshape(B, nc, Q, nh, hd)
    v_c = vh.reshape(B, nc, Q, nh, hd)
    cum = jnp.cumsum(lw.reshape(B, nc, Q, nh, hd), axis=2)  # c_t
    cum_prev = cum - lw.reshape(B, nc, Q, nh, hd)           # c_{t-1}
    r_dec = r_c * jnp.exp(cum_prev)
    k_dec = k_c * jnp.exp(-cum)                             # bounded
    k_end = k_c * jnp.exp(cum[:, :, -1:] - cum)             # <= 1
    # intra-chunk scores (strictly lower-triangular) + u-bonus diagonal
    scores = jnp.einsum("bcqnh,bctnh->bcnqt", r_dec, k_dec)
    tri = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    diag = jnp.einsum("bcqnh,bcqnh->bcnq", r_c,
                      p["u"][None, None, None] * k_c)
    idx = jnp.arange(Q)
    scores = scores.at[..., idx, idx].add(diag)

    def chunk_step(S_, inp):
        rd, sc, ke, vv, tot = inp
        o = jnp.einsum("bqnh,bnhv->bqnv", rd, S_) + \
            jnp.einsum("bnqt,btnv->bqnv", sc, vv)
        S_ = jnp.exp(tot)[:, 0, :, :, None] * S_ + \
            jnp.einsum("bqnh,bqnv->bnhv", ke, vv)
        return S_, o

    inputs = (r_dec.transpose(1, 0, 2, 3, 4),
              scores.transpose(1, 0, 2, 3, 4),
              k_end.transpose(1, 0, 2, 3, 4),
              v_c.transpose(1, 0, 2, 3, 4),
              cum[:, :, -1:].transpose(1, 0, 2, 3, 4))
    S_final, os = jax.lax.scan(chunk_step, S0, inputs)
    o = os.transpose(1, 0, 2, 3, 4).reshape(B, S, nh, hd)
    return S_final, o


def rwkv_channel_mix(p, x, cfg: ModelConfig, state: dict):
    from .layers import rmsnorm
    h = rmsnorm(x, p["cm_norm"], cfg.norm_eps)
    shifted = _token_shift(h, state["x_cm"])
    kx = _lerp(h, shifted, p["mu_ck"])
    hidden = jnp.square(jax.nn.relu(kx @ bf16(p["w_ck"])))
    hidden = shard(hidden, "batch", "seq", "mlp")
    out = (hidden @ bf16(p["w_cv"])).astype(x.dtype)
    return shard(out, "batch", "seq", None), \
        {"x_cm": h[:, -1].astype(jnp.float32)}


def rwkv_block(p, x, cfg: ModelConfig, state: Optional[dict] = None):
    B = x.shape[0]
    if state is None:
        state = rwkv_init_state(cfg, B)
    tm_out, tm_state = rwkv_time_mix(p, x, cfg, state)
    x = x + tm_out
    cm_out, cm_state = rwkv_channel_mix(p, x, cfg, state)
    x = x + cm_out
    return x, {**tm_state, **cm_state}


def rwkv_init_state(cfg: ModelConfig, batch: int) -> dict:
    nh, hd = rwkv_dims(cfg)
    return {
        "S": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "x_tm": jnp.zeros((batch, cfg.d_model), jnp.float32),
        "x_cm": jnp.zeros((batch, cfg.d_model), jnp.float32),
    }
