"""repro.models — raw-JAX model zoo for the assigned architectures."""
from .layers import (attention, attn_specs, attend_cache, bf16,
                     flash_attention_xla, mlp, mlp_specs, moe, moe_specs,
                     rmsnorm, apply_rope, apply_mrope)
from .ssm import ssm_block, ssm_decode, ssm_dims, ssm_init_state, ssm_specs
from .rwkv import rwkv_block, rwkv_dims, rwkv_init_state, rwkv_specs
from .transformer import (LM, cache_specs, family_kind, lg_groups,
                          model_specs, zamba_groups)
from . import frontends

__all__ = [
    "attention", "attn_specs", "attend_cache", "bf16",
    "flash_attention_xla", "mlp", "mlp_specs", "moe", "moe_specs",
    "rmsnorm", "apply_rope", "apply_mrope", "ssm_block", "ssm_decode",
    "ssm_dims", "ssm_init_state", "ssm_specs", "rwkv_block", "rwkv_dims",
    "rwkv_init_state", "rwkv_specs", "LM", "cache_specs", "family_kind",
    "lg_groups", "model_specs", "zamba_groups", "frontends",
]
