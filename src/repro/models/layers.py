"""Transformer building blocks (raw JAX, sharding-annotated).

Attention is implemented blocked ("flash-in-XLA": q-block unrolled,
k-block scanned with online-softmax carry) so 32k-token prefill never
materializes an (S, S) score matrix. GQA is computed in grouped layout
(B, kv, group, S, hd) to avoid repeating KV.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.params import ParamSpec
from ..distributed.sharding import shard

NEG_INF = -1e30


COMPUTE = {"dtype": jnp.bfloat16}


def set_compute_dtype(dtype):
    """Override the model compute dtype (tests use f32 so the
    prefill/decode-vs-train consistency checks isolate LOGIC errors
    from bf16 drift)."""
    COMPUTE["dtype"] = dtype


def bf16(w):
    """Weights are stored fp32 (optimizer master copies); compute in bf16
    so HLO FLOPs match the v5e bf16 peak used in the roofline."""
    return w.astype(COMPUTE["dtype"])


# -- norms ----------------------------------------------------------------

def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


# -- rotary ------------------------------------------------------------------

def _rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., S, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions_3d, sections, theta: float = 1e4):
    """Qwen2-VL M-RoPE: head_dim/2 split into (t, h, w) sections, each
    rotated by its own position stream. positions_3d: (3, ..., S)."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)                      # (hd/2,)
    sec = jnp.concatenate([jnp.full((s,), i) for i, s in enumerate(sections)])
    # pick the position stream per frequency slot
    pos = jnp.take(positions_3d, sec.astype(jnp.int32), axis=0)  # (hd/2,...,S)
    pos = jnp.moveaxis(pos, 0, -1)                      # (..., S, hd/2)
    angles = pos.astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# -- attention ----------------------------------------------------------------

def attn_specs(cfg: ModelConfig, layers: int = 1) -> dict:
    d, hd = cfg.d_model, cfg.hd
    H, KV = cfg.n_heads, cfg.n_kv_heads
    lead = (layers,) if layers > 1 else ()
    lax_ = (None,) if layers > 1 else ()
    return {
        "wq": ParamSpec(lead + (d, H * hd), lax_ + ("embed_w", "qkv")),
        "wk": ParamSpec(lead + (d, KV * hd), lax_ + ("embed_w", "kv")),
        "wv": ParamSpec(lead + (d, KV * hd), lax_ + ("embed_w", "kv")),
        "wo": ParamSpec(lead + (H * hd, d), lax_ + ("qkv", "embed_w"),
                        scale=1.0 / math.sqrt(2 * cfg.n_layers)),
        "norm": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
    }


def _qkv(p, x, cfg: ModelConfig, positions):
    """Project + rope. Returns q: (B,KV,G,S,hd), k/v: (B,KV,S,hd)."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KV
    q = (x @ bf16(p["wq"])).reshape(B, S, H, hd)
    k = (x @ bf16(p["wk"])).reshape(B, S, KV, hd)
    v = (x @ bf16(p["wv"])).reshape(B, S, KV, hd)
    if cfg.mrope:
        pos3 = positions if positions.ndim == 3 else jnp.broadcast_to(
            positions[None], (3,) + positions.shape)
        q = apply_mrope(q.swapaxes(1, 2), pos3[:, :, None],
                        cfg.mrope_sections, cfg.rope_theta).swapaxes(1, 2)
        k = apply_mrope(k.swapaxes(1, 2), pos3[:, :, None],
                        cfg.mrope_sections, cfg.rope_theta).swapaxes(1, 2)
    else:
        q = apply_rope(q.swapaxes(1, 2), positions[:, None],
                       cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), positions[:, None],
                       cfg.rope_theta).swapaxes(1, 2)
    q = q.reshape(B, S, KV, G, hd).transpose(0, 2, 3, 1, 4)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    # NOTE: no explicit q/k/v constraints here. The projections inherit
    # (batch->data, heads*hd->model) from x/w, and GSPMD propagates a
    # partial head sharding even for non-divisible GQA head counts
    # (e.g. granite's 24H/8KV on a 16-way model axis becomes an 8-way
    # head shard with 2-way replication) — measurably better than any
    # full constraint we can express with NamedSharding (see
    # EXPERIMENTS.md "involuntary rematerialization" note).
    return q, k, v


def _softcap(logits, cap: float):
    if cap > 0.0:
        return jnp.tanh(logits / cap) * cap
    return logits


def flash_attention_xla(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset: int = 0, softcap: float = 0.0,
                        q_block: int = 1024, k_block: int = 1024):
    """Blocked attention with online softmax (pure XLA).

    q: (B, KV, G, Sq, hd); k, v: (B, KV, Sk, hd).
    ``q_offset``: absolute position of q[...,0,:] relative to k (for
    caches / chunked prefill). Causal blocks that lie entirely in the
    future are skipped at trace time (halves prefill FLOPs).
    """
    B, KV, G, Sq, hd = q.shape
    Sk = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    q_block = min(q_block, Sq)
    k_block = min(k_block, Sk)
    nq, nk = -(-Sq // q_block), -(-Sk // k_block)
    # pad to block multiples (padded k columns masked, q rows sliced off)
    if nq * q_block != Sq:
        q = jnp.pad(q, ((0, 0),) * 3 + ((0, nq * q_block - Sq), (0, 0)))
    if nk * k_block != Sk:
        k = jnp.pad(k, ((0, 0),) * 2 + ((0, nk * k_block - Sk), (0, 0)))
        v = jnp.pad(v, ((0, 0),) * 2 + ((0, nk * k_block - Sk), (0, 0)))

    outs = []
    for qi in range(nq):
        q_i = jax.lax.dynamic_slice_in_dim(q, qi * q_block, q_block, axis=3)
        q_lo = q_offset + qi * q_block
        q_hi = q_lo + q_block - 1
        acc = jnp.zeros((B, KV, G, q_block, hd), jnp.float32)
        m = jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32)
        l = jnp.zeros((B, KV, G, q_block), jnp.float32)

        for ki in range(nk):
            k_lo = ki * k_block
            if causal and k_lo > q_hi:
                continue                      # entirely in the future
            if window > 0 and (k_lo + k_block - 1) < q_lo - window + 1 - 1:
                continue                      # entirely out of the window
            k_i = jax.lax.dynamic_slice_in_dim(k, k_lo, k_block, axis=2)
            v_i = jax.lax.dynamic_slice_in_dim(v, k_lo, k_block, axis=2)
            # bf16 MXU dot, f32 accumulate (keeps operand traffic and
            # backward collectives in bf16 — EXPERIMENTS.md iter G4)
            s = jnp.einsum("bkgqh,bkth->bkgqt", q_i, k_i,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            q_idx = q_lo + jnp.arange(q_block)[:, None]
            k_idx = k_lo + jnp.arange(k_block)[None, :]
            mask = jnp.ones((q_block, k_block), bool)
            if causal:
                mask &= k_idx <= q_idx
            if window > 0:
                mask &= k_idx > q_idx - window
            mask &= k_idx < Sk               # padded k columns
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p_ = jnp.exp(s - m_new[..., None])
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,bkth->bkgqh", p_.astype(v_i.dtype), v_i,
                preferred_element_type=jnp.float32)
            l = l * alpha + p_.sum(-1)
            m = m_new
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
    out = jnp.concatenate(outs, axis=3) if len(outs) > 1 else outs[0]
    return out[..., :Sq, :].astype(q.dtype)


def attend_cache(q, k_cache, v_cache, pos, *, window: int = 0,
                 softcap: float = 0.0, key_positions=None):
    """Single-token decode attention over a (padded or ring) cache.

    q: (B, KV, G, 1, hd); caches: (B, KV, S, hd); pos: (B,) current
    ABSOLUTE position. ``key_positions`` (B, S): absolute position of
    each cache slot (ring-buffer window caches); default = arange(S).
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    # bf16 dot with f32 accumulation: converting the cache to f32 here
    # makes XLA hoist the convert around the cache update, i.e. the
    # decode scan would convert the ENTIRE stacked KV cache every layer
    # (measured 2.7 TB/step on deepseek-67b decode_32k — EXPERIMENTS
    # iter D2).
    s = jnp.einsum("bkgqh,bkth->bkgqt", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = _softcap(s, softcap)
    S = k_cache.shape[2]
    if key_positions is None:
        key_positions = jnp.broadcast_to(jnp.arange(S)[None, :],
                                         (q.shape[0], S))
    valid = (key_positions <= pos[:, None]) & (key_positions >= 0)
    if window > 0:
        valid &= key_positions > pos[:, None] - window
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,bkth->bkgqh", p.astype(v_cache.dtype),
                     v_cache, preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def attention(p, x, cfg: ModelConfig, *, positions, window: int = 0,
              cache: Optional[dict] = None, cache_pos=None,
              write_pos=None, key_positions=None,
              update_cache: bool = False):
    """Full attention sublayer (pre-norm, residual outside).

    Train/prefill: cache=None; update_cache=True returns k/v (bf16).
    Decode: x is (B, 1, d); cache holds (B, KV, S_cache, hd);
    ``cache_pos`` (B,) is the ABSOLUTE position, ``write_pos`` the cache
    slot to write (defaults to cache_pos; ring caches pass pos % W with
    ``key_positions`` giving slot->absolute-position mapping).
    Returns (out, new_cache_kv or None).
    """
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    q, k, v = _qkv(p, h, cfg, positions)
    new_cache = None
    if cache is not None and S == 1:            # decode step
        pos = cache_pos                          # (B,) absolute
        wpos = write_pos if write_pos is not None else pos
        k = k.astype(cache["k"].dtype)
        v = v.astype(cache["v"].dtype)
        k_cache = jax.vmap(
            lambda c, upd, i: jax.lax.dynamic_update_slice_in_dim(
                c, upd, i, axis=1))(cache["k"], k, wpos)
        v_cache = jax.vmap(
            lambda c, upd, i: jax.lax.dynamic_update_slice_in_dim(
                c, upd, i, axis=1))(cache["v"], v, wpos)
        out = attend_cache(q, k_cache, v_cache, pos, window=window,
                           softcap=cfg.attn_logit_softcap,
                           key_positions=key_positions)
        new_cache = {"k": k_cache, "v": v_cache}
    else:                                        # train / prefill
        out = flash_attention_xla(q, k, v, causal=True, window=window,
                                  softcap=cfg.attn_logit_softcap)
        if update_cache:
            new_cache = {"k": k.astype(COMPUTE["dtype"]),
                         "v": v.astype(COMPUTE["dtype"])}
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H * hd)
    out = out @ bf16(p["wo"])
    return shard(out, "batch", "seq", None), new_cache


# -- MLP -----------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, layers: int = 1, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    lead = (layers,) if layers > 1 else ()
    lax_ = (None,) if layers > 1 else ()
    return {
        "w_gate": ParamSpec(lead + (d, f), lax_ + ("embed_w", "mlp")),
        "w_up": ParamSpec(lead + (d, f), lax_ + ("embed_w", "mlp")),
        "w_down": ParamSpec(lead + (f, d), lax_ + ("mlp", "embed_w"),
                            scale=1.0 / math.sqrt(2 * cfg.n_layers)),
        "norm": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
    }


def mlp(p, x, cfg: ModelConfig):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(h @ bf16(p["w_gate"])) * (h @ bf16(p["w_up"]))
    h = shard(h, "batch", "seq", "mlp")
    return shard(h @ bf16(p["w_down"]), "batch", "seq", None)


# -- MoE (sort-based dispatch, static shapes, true EP) --------------------------

def moe_specs(cfg: ModelConfig, layers: int = 1):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    lead = (layers,) if layers > 1 else ()
    lax_ = (None,) if layers > 1 else ()
    return {
        "router": ParamSpec(lead + (d, E), lax_ + ("embed_w", None)),
        "w_gate": ParamSpec(lead + (E, d, f),
                            lax_ + ("experts", "moe_d", "mlp")),
        "w_up": ParamSpec(lead + (E, d, f),
                          lax_ + ("experts", "moe_d", "mlp")),
        "w_down": ParamSpec(lead + (E, f, d),
                            lax_ + ("experts", "mlp", "moe_d"),
                            scale=1.0 / math.sqrt(2 * cfg.n_layers)),
        "norm": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
    }


def _dispatch_row(flat, eids, gates, E: int, K: int, C: int):
    """Per-batch-row sort-based dispatch: (S,D) tokens -> (E,C,D) buffer
    + combine metadata. Runs UNDER vmap over the (data-sharded) batch
    dim so the sort never crosses devices. The scatter uses SORTED,
    UNIQUE flattened (expert*C + slot) indices — without those hints XLA
    materializes buf-sized u32 sort scratch (measured 4 GB/layer)."""
    S = flat.shape[0]
    a_exp = eids.reshape(-1)                               # (S*K,)
    a_gate = gates.reshape(-1)
    order = jnp.argsort(a_exp)                             # stable
    s_exp = a_exp[order]
    s_tok = (jnp.arange(S * K) // K)[order]
    s_gate = a_gate[order]
    # position within expert = rank among same-expert assignments
    seg_pos = jnp.cumsum(jnp.ones_like(s_exp)) - 1
    counts = jnp.bincount(s_exp, length=E)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = seg_pos - starts[s_exp]
    # strictly-increasing flat slot; overflow pushed out of bounds
    flat_idx = jnp.where(pos_in_e < C, s_exp * C + pos_in_e, E * C)
    buf = jnp.zeros((E * C, flat.shape[1]), flat.dtype)
    buf = buf.at[flat_idx].set(flat[s_tok], mode="drop",
                               unique_indices=True,
                               indices_are_sorted=True)
    return buf.reshape(E, C, flat.shape[1]), \
        (order, flat_idx, s_gate)


def _combine_row(yexp, meta, S: int, K: int, D: int):
    """Scatter-free combine: gather expert outputs back in sorted
    order, unsort by the inverse permutation, reduce over the K
    assignments per token."""
    order, flat_idx, s_gate = meta
    E, C, _ = yexp.shape
    gathered = yexp.reshape(E * C, D).at[flat_idx].get(
        mode="fill", fill_value=0.0, indices_are_sorted=True,
        unique_indices=True)                               # (S*K, D)
    contrib = gathered * s_gate[:, None].astype(gathered.dtype)
    inv = jnp.argsort(order)
    return contrib.at[inv].get(unique_indices=True) \
        .reshape(S, K, D).sum(axis=1)


def moe(p, x, cfg: ModelConfig):
    """Top-k MoE with PER-ROW sort-based capacity dispatch.

    Each batch row's tokens are sorted by assigned expert and scattered
    into a (E, C, d) buffer (overflow dropped — capacity semantics),
    vmapped over the data-sharded batch dim (sorts stay device-local).
    Expert FFNs run as batched einsums with E sharded (expert
    parallelism — GSPMD inserts the all-to-alls); combine weights by
    renormalized router gates. Returns (y, aux_load_balance_loss).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    h = rmsnorm(x, p["norm"], cfg.norm_eps)

    logits = (h @ bf16(p["router"])).astype(jnp.float32)   # (B, S, E)
    probs = jax.nn.softmax(logits, -1)
    gates, eids = jax.lax.top_k(probs, K)                  # (B, S, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style)
    density = jnp.mean(
        jax.nn.one_hot(eids[..., 0], E).reshape(-1, E), axis=0)
    density_prob = probs.reshape(-1, E).mean(0)
    aux = E * jnp.sum(density * density_prob)

    C = max(int(S * K / E * cfg.capacity_factor), 1)
    buf, meta = jax.vmap(
        lambda f, e, g: _dispatch_row(f, e, g, E, K, C))(h, eids, gates)
    buf = shard(buf, "batch", "experts", "moe_cap", "moe_d")

    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    # keep the (possibly partial-sum) expert activations in bf16 so the
    # contraction all-reduce moves half the bytes (EXPERIMENTS iter G5)
    hexp = (act(jnp.einsum("becd,edf->becf", buf, bf16(p["w_gate"])))
            * jnp.einsum("becd,edf->becf", buf, bf16(p["w_up"]))) \
        .astype(jnp.bfloat16)
    hexp = shard(hexp, "batch", "experts", "moe_cap", "mlp")
    yexp = jnp.einsum("becf,efd->becd", hexp, bf16(p["w_down"]))
    yexp = shard(yexp, "batch", "experts", "moe_cap", "moe_d")

    y = jax.vmap(lambda ye, m: _combine_row(ye, m, S, K, D))(yexp, meta)
    return shard(y, "batch", "seq", None), aux
