"""Decoder-only LM assembly for all assigned architecture families.

Families:
* uniform      — dense / MoE / vlm / audio stacks (identical layers,
                 optional leading dense-MLP layers), lax.scan over layers
* local_global — gemma3: scanned super-blocks of (R local + 1 global)
                 attention with SEPARATE window/full KV cache trees
                 (window caches are ring buffers in decode)
* zamba        — Mamba2 backbone scanned as super-blocks of
                 ``shared_attn_every`` SSM layers + one WEIGHT-SHARED
                 attention block (its own per-application KV cache)
* rwkv         — RWKV6 time-mix/channel-mix stack

Three entry points per model: ``loss`` (train, chunked CE — never
materializes (B,S,V)), ``prefill`` (returns last-token logits + cache),
``decode_step`` (one token, updates the cache).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.params import ParamSpec
from ..distributed.sharding import shard
from .layers import attention, attn_specs, mlp, mlp_specs, moe, moe_specs, \
    rmsnorm
from .rwkv import rwkv_block, rwkv_dims, rwkv_specs
from .ssm import ssm_block, ssm_decode, ssm_dims, ssm_specs

CE_CHUNK = 256


def family_kind(cfg: ModelConfig) -> str:
    if cfg.family == "ssm":
        return "rwkv"
    if cfg.family == "hybrid":
        return "zamba"
    if cfg.local_global_ratio > 0:
        return "local_global"
    return "uniform"


def _stack(specs, *lead: int):
    """Add leading stacking axes to every ParamSpec in a tree."""
    extra = tuple(lead)
    return jax.tree.map(
        lambda p: ParamSpec(extra + p.shape, (None,) * len(extra) + p.axes,
                            p.init, p.scale, p.dtype),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def zamba_groups(cfg: ModelConfig) -> tuple[int, int]:
    """(#super-blocks, #tail ssm layers)."""
    every = cfg.shared_attn_every or cfg.n_layers + 1
    return divmod(cfg.n_layers, every)


def lg_groups(cfg: ModelConfig) -> tuple[int, int]:
    return divmod(cfg.n_layers, cfg.local_global_ratio + 1)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def model_specs(cfg: ModelConfig) -> dict:
    kind = family_kind(cfg)
    specs: dict[str, Any] = {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed_w"),
                           scale=1.0),
        "final_norm": ParamSpec((cfg.d_model,), (None,), init="zeros"),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab),
                                     ("embed_w", "vocab"))
    if kind == "uniform":
        n_body = cfg.n_layers - cfg.first_k_dense
        body = {"attn": _stack(attn_specs(cfg), n_body)}
        if cfg.n_experts > 0:
            body["moe"] = _stack(moe_specs(cfg), n_body)
        else:
            body["mlp"] = _stack(mlp_specs(cfg), n_body)
        specs["blocks"] = body
        if cfg.first_k_dense:
            d_ff_head = (cfg.top_k * cfg.d_ff
                         if cfg.n_experts else cfg.d_ff)
            specs["head_layers"] = {
                "attn": _stack(attn_specs(cfg), cfg.first_k_dense),
                "mlp": _stack(mlp_specs(cfg, d_ff=d_ff_head),
                              cfg.first_k_dense),
            }
    elif kind == "local_global":
        R = cfg.local_global_ratio
        G, tail = lg_groups(cfg)
        specs["blocks"] = {
            "local": _stack(attn_specs(cfg), G, R),
            "local_mlp": _stack(mlp_specs(cfg), G, R),
            "global": {"attn": _stack(attn_specs(cfg), G),
                       "mlp": _stack(mlp_specs(cfg), G)},
        }
        if tail:
            specs["tail"] = {"attn": _stack(attn_specs(cfg), tail),
                             "mlp": _stack(mlp_specs(cfg), tail)}
    elif kind == "zamba":
        G, tail = zamba_groups(cfg)
        every = cfg.shared_attn_every
        specs["blocks"] = _stack(ssm_specs(cfg), G, every)
        if tail:
            specs["tail"] = _stack(ssm_specs(cfg), tail)
        specs["shared_attn"] = attn_specs(cfg)
        specs["shared_mlp"] = mlp_specs(cfg)
    elif kind == "rwkv":
        specs["blocks"] = _stack(rwkv_specs(cfg), cfg.n_layers)
    return specs


# ---------------------------------------------------------------------------
# cache specs (shapes + logical axes, consumed by the dry-run)
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    kind = family_kind(cfg)
    KV, hd = cfg.n_kv_heads, cfg.hd

    def kv(length, *lead):
        axes = (None,) * len(lead) + ("batch", "kv_heads", "kv_seq", None)
        shape = tuple(lead) + (batch, KV, length, hd)
        return {"k": ParamSpec(shape, axes, init="zeros", dtype="bfloat16"),
                "v": ParamSpec(shape, axes, init="zeros", dtype="bfloat16")}

    if kind == "uniform":
        out = {"body": kv(max_len, cfg.n_layers - cfg.first_k_dense)}
        if cfg.first_k_dense:
            out["head"] = kv(max_len, cfg.first_k_dense)
        return out
    if kind == "local_global":
        R = cfg.local_global_ratio
        G, tail = lg_groups(cfg)
        W = min(cfg.local_window, max_len)
        out = {"local": kv(W, G, R), "global": kv(max_len, G)}
        if tail:
            out["tail"] = kv(W, tail)
        return out
    if kind == "zamba":
        d_in, nh, shd, ds = ssm_dims(cfg)
        G, tail = zamba_groups(cfg)
        every = cfg.shared_attn_every
        h_axes = ("batch", None, None, None)
        out = {
            "ssm_h": ParamSpec((G, every, batch, nh, shd, ds),
                               (None, None) + h_axes, init="zeros"),
            "shared": kv(max_len, G),
        }
        if tail:
            out["tail_h"] = ParamSpec((tail, batch, nh, shd, ds),
                                      (None,) + h_axes, init="zeros")
        return out
    if kind == "rwkv":
        nh, rhd = rwkv_dims(cfg)
        L, d = cfg.n_layers, cfg.d_model
        return {
            "S": ParamSpec((L, batch, nh, rhd, rhd),
                           (None, "batch", None, None, None), init="zeros"),
            "x_tm": ParamSpec((L, batch, d), (None, "batch", None),
                              init="zeros"),
            "x_cm": ParamSpec((L, batch, d), (None, "batch", None),
                              init="zeros"),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

@dataclass
class LM:
    cfg: ModelConfig

    # -- embeddings -----------------------------------------------------
    def embed(self, params, tokens):
        from .layers import COMPUTE
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x * math.sqrt(self.cfg.d_model)
        return shard(x.astype(COMPUTE["dtype"]), "batch", "seq", None)

    def embed_vectors(self, params, embeds):
        """Modality-frontend stub entry: precomputed patch/frame embeds."""
        from .layers import COMPUTE
        return shard(embeds.astype(COMPUTE["dtype"]), "batch", "seq", None)

    def unembed(self, params, h):
        head = (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])
        logits = h.astype(jnp.float32) @ head.astype(jnp.float32)
        return shard(logits, "batch", "seq", "vocab")

    # -- one attention+mlp/moe layer --------------------------------------
    def _layer(self, p, x, positions, *, window=0, cache=None,
               cache_pos=None, write_pos=None, key_positions=None,
               update_cache=False, mlp_p=None):
        cfg = self.cfg
        a, new_kv = attention(p["attn"], x, cfg, positions=positions,
                              window=window, cache=cache,
                              cache_pos=cache_pos, write_pos=write_pos,
                              key_positions=key_positions,
                              update_cache=update_cache)
        x = x + a
        aux = 0.0
        if "moe" in p:
            mo, aux = moe(p["moe"], x, cfg)
            x = x + mo
        else:
            mp = mlp_p if mlp_p is not None else p["mlp"]
            x = x + mlp(mp, x, cfg)
        return x, new_kv, aux

    # ======================== TRAIN =====================================
    def hidden_train(self, params, x, positions, remat: bool = True):
        cfg = self.cfg
        kind = family_kind(cfg)
        ck = jax.checkpoint if remat else (lambda f: f)
        aux_total = 0.0

        if kind == "uniform":
            if cfg.first_k_dense:
                def head_body(xc, p_l):
                    xc, _, _ = self._layer({"attn": p_l["attn"]}, xc,
                                           positions, mlp_p=p_l["mlp"])
                    return xc, None
                x, _ = jax.lax.scan(ck(head_body), x, params["head_layers"])

            def body(carry, p_l):
                xc, aux = carry
                xc, _, a = self._layer(p_l, xc, positions)
                return (xc, aux + a), None
            (x, aux_total), _ = jax.lax.scan(ck(body), (x, 0.0),
                                             params["blocks"])

        elif kind == "local_global":
            W = cfg.local_window

            def group(carry, p_g):
                xc, aux = carry

                def loc(xc, p_l):
                    p_a, p_m = p_l
                    xc, _, _ = self._layer({"attn": p_a}, xc, positions,
                                           window=W, mlp_p=p_m)
                    return xc, None
                xc, _ = jax.lax.scan(loc, xc,
                                     (p_g["local"], p_g["local_mlp"]))
                xc, _, _ = self._layer(
                    {"attn": p_g["global"]["attn"]}, xc, positions,
                    mlp_p=p_g["global"]["mlp"])
                return (xc, aux), None
            (x, aux_total), _ = jax.lax.scan(ck(group), (x, 0.0),
                                             params["blocks"])
            if "tail" in params:
                def tail(xc, p_l):
                    xc, _, _ = self._layer({"attn": p_l[0]}, xc, positions,
                                           window=W, mlp_p=p_l[1])
                    return xc, None
                x, _ = jax.lax.scan(ck(tail), x, (params["tail"]["attn"],
                                                  params["tail"]["mlp"]))

        elif kind == "zamba":
            def group(xc, p_g):
                def ssm_l(xc, p_l):
                    out, _ = ssm_block(p_l, xc, cfg)
                    return xc + out, None
                xc, _ = jax.lax.scan(ssm_l, xc, p_g)
                xc, _, _ = self._layer({"attn": params["shared_attn"]}, xc,
                                       positions,
                                       mlp_p=params["shared_mlp"])
                return xc, None
            x, _ = jax.lax.scan(ck(group), x, params["blocks"])
            if "tail" in params:
                def ssm_t(xc, p_l):
                    out, _ = ssm_block(p_l, xc, cfg)
                    return xc + out, None
                x, _ = jax.lax.scan(ck(ssm_t), x, params["tail"])

        elif kind == "rwkv":
            def body(xc, p_l):
                xc, _ = rwkv_block(p_l, xc, cfg)
                return xc, None
            x, _ = jax.lax.scan(ck(body), x, params["blocks"])

        return rmsnorm(x, params["final_norm"], cfg.norm_eps), aux_total

    # -- loss (chunked CE) -----------------------------------------------
    def loss(self, params, tokens, targets, z_loss: float = 1e-4,
             embeds=None):
        cfg = self.cfg
        B, S = tokens.shape
        x = (self.embed(params, tokens) if embeds is None
             else self.embed_vectors(params, embeds))
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h, aux = self.hidden_train(params, x, positions)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        n_chunk = max(S // CE_CHUNK, 1)
        cs = S // n_chunk

        def ce_chunk(carry, idx):
            h_c = jax.lax.dynamic_slice_in_dim(h, idx * cs, cs, axis=1)
            t_c = jax.lax.dynamic_slice_in_dim(targets, idx * cs, cs,
                                               axis=1)
            logits = h_c.astype(jnp.float32) @ head.astype(jnp.float32)
            logits = shard(logits, "batch", "ce_seq", "vocab")
            lse = jax.nn.logsumexp(logits, axis=-1)
            tgt = jnp.take_along_axis(logits, t_c[..., None],
                                      axis=-1)[..., 0]
            ce = (lse - tgt).sum() + z_loss * jnp.square(lse).sum()
            return carry + ce, None
        total, _ = jax.lax.scan(ce_chunk, 0.0, jnp.arange(n_chunk))
        loss = total / (B * n_chunk * cs)
        if cfg.n_experts:
            loss = loss + 0.01 * aux / max(cfg.n_layers, 1)
        return loss

    def logits_train(self, params, tokens):
        """Full logits — small inputs only (tests)."""
        B, S = tokens.shape
        x = self.embed(params, tokens)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h, _ = self.hidden_train(params, x, positions, remat=False)
        return self.unembed(params, h)

    # ======================== PREFILL =====================================
    def prefill(self, params, tokens, max_len: int, embeds=None):
        cfg = self.cfg
        kind = family_kind(cfg)
        B, S = tokens.shape
        x = (self.embed(params, tokens) if embeds is None
             else self.embed_vectors(params, embeds))
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        W = cfg.local_window

        def clip_window(kv_):
            """Last-W slice (ring-aligned when S % W == 0), padded if S<W."""
            def f(a):
                if a.shape[2] >= W:
                    return a[:, :, -W:]
                return jnp.pad(a, ((0, 0), (0, 0), (0, W - a.shape[2]),
                                   (0, 0)))
            return {k: f(v) for k, v in kv_.items()}

        if kind == "uniform":
            cache = {}
            if cfg.first_k_dense:
                def head_body(xc, p_l):
                    xc, kv_, _ = self._layer({"attn": p_l["attn"]}, xc,
                                             positions, mlp_p=p_l["mlp"],
                                             update_cache=True)
                    return xc, kv_
                x, head_kv = jax.lax.scan(head_body, x,
                                          params["head_layers"])
                cache["head"] = head_kv

            def body(xc, p_l):
                xc, kv_, _ = self._layer(p_l, xc, positions,
                                         update_cache=True)
                return xc, kv_
            x, body_kv = jax.lax.scan(body, x, params["blocks"])
            cache["body"] = body_kv

        elif kind == "local_global":
            def group(xc, p_g):
                def loc(xc, p_l):
                    p_a, p_m = p_l
                    xc, kv_, _ = self._layer({"attn": p_a}, xc, positions,
                                             window=W, mlp_p=p_m,
                                             update_cache=True)
                    return xc, clip_window(kv_)
                xc, loc_kv = jax.lax.scan(loc, xc,
                                          (p_g["local"], p_g["local_mlp"]))
                xc, glob_kv, _ = self._layer(
                    {"attn": p_g["global"]["attn"]}, xc, positions,
                    mlp_p=p_g["global"]["mlp"], update_cache=True)
                return xc, (loc_kv, glob_kv)
            x, (loc, glob) = jax.lax.scan(group, x, params["blocks"])
            cache = {"local": loc, "global": glob}
            if "tail" in params:
                def tail(xc, p_l):
                    xc, kv_, _ = self._layer({"attn": p_l[0]}, xc,
                                             positions, window=W,
                                             mlp_p=p_l[1],
                                             update_cache=True)
                    return xc, clip_window(kv_)
                x, tail_kv = jax.lax.scan(tail, x, (params["tail"]["attn"],
                                                    params["tail"]["mlp"]))
                cache["tail"] = tail_kv

        elif kind == "zamba":
            def group(xc, p_g):
                def ssm_l(xc, p_l):
                    out, st = ssm_block(p_l, xc, cfg)
                    return xc + out, st["h"]
                xc, hs = jax.lax.scan(ssm_l, xc, p_g)
                xc, kv_, _ = self._layer({"attn": params["shared_attn"]},
                                         xc, positions,
                                         mlp_p=params["shared_mlp"],
                                         update_cache=True)
                return xc, (hs, kv_)
            x, (ssm_h, shared_kv) = jax.lax.scan(group, x, params["blocks"])
            cache = {"ssm_h": ssm_h, "shared": shared_kv}
            if "tail" in params:
                def ssm_t(xc, p_l):
                    out, st = ssm_block(p_l, xc, cfg)
                    return xc + out, st["h"]
                x, tail_h = jax.lax.scan(ssm_t, x, params["tail"])
                cache["tail_h"] = tail_h

        elif kind == "rwkv":
            def body(xc, p_l):
                xc, st = rwkv_block(p_l, xc, cfg)
                return xc, st
            x, cache = jax.lax.scan(body, x, params["blocks"])

        h = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = self.unembed(params, h)
        return logits, _pad_cache(cache, cfg, max_len)

    # ======================== DECODE =====================================
    def decode_step(self, params, token, cache, pos):
        """token: (B,) int32; pos: (B,) absolute positions.
        Returns (logits (B,1,V), new_cache)."""
        cfg = self.cfg
        kind = family_kind(cfg)
        x = self.embed(params, token[:, None])
        positions = pos[:, None]
        W = cfg.local_window

        if kind == "uniform":
            new_cache = {}
            if cfg.first_k_dense:
                def head_body(xc, inp):
                    p_l, kv_in = inp
                    xc, kv_, _ = self._layer({"attn": p_l["attn"]}, xc,
                                             positions, cache=kv_in,
                                             cache_pos=pos,
                                             mlp_p=p_l["mlp"])
                    return xc, kv_
                x, head_kv = jax.lax.scan(
                    head_body, x, (params["head_layers"], cache["head"]))
                new_cache["head"] = head_kv

            def body(xc, inp):
                p_l, kv_in = inp
                xc, kv_, _ = self._layer(p_l, xc, positions, cache=kv_in,
                                         cache_pos=pos)
                return xc, kv_
            x, body_kv = jax.lax.scan(body, x,
                                      (params["blocks"], cache["body"]))
            new_cache["body"] = body_kv

        elif kind == "local_global":
            slot = pos % W
            key_pos = _ring_positions(pos, W)

            def group(xc, inp):
                p_g, (loc_in, glob_in) = inp

                def loc(xc, inp2):
                    (p_a, p_m), kv_l = inp2
                    xc, kv_, _ = self._layer(
                        {"attn": p_a}, xc, positions, window=W,
                        cache=kv_l, cache_pos=pos, write_pos=slot,
                        key_positions=key_pos, mlp_p=p_m)
                    return xc, kv_
                xc, loc_out = jax.lax.scan(
                    loc, xc, ((p_g["local"], p_g["local_mlp"]), loc_in))
                xc, glob_out, _ = self._layer(
                    {"attn": p_g["global"]["attn"]}, xc, positions,
                    cache=glob_in, cache_pos=pos,
                    mlp_p=p_g["global"]["mlp"])
                return xc, (loc_out, glob_out)
            x, (loc, glob) = jax.lax.scan(
                group, x,
                (params["blocks"], (cache["local"], cache["global"])))
            new_cache = {"local": loc, "global": glob}
            if "tail" in params:
                def tail(xc, inp):
                    (p_a, p_m), kv_l = inp
                    xc, kv_, _ = self._layer(
                        {"attn": p_a}, xc, positions, window=W,
                        cache=kv_l, cache_pos=pos, write_pos=slot,
                        key_positions=key_pos, mlp_p=p_m)
                    return xc, kv_
                x, tail_kv = jax.lax.scan(
                    tail, x, ((params["tail"]["attn"],
                               params["tail"]["mlp"]), cache["tail"]))
                new_cache["tail"] = tail_kv

        elif kind == "zamba":
            def group(xc, inp):
                p_g, h_in, kv_in = inp

                def ssm_l(xc, inp2):
                    p_l, h_l = inp2
                    out, st = ssm_decode(p_l, xc, cfg, {"h": h_l})
                    return xc + out, st["h"]
                xc, h_out = jax.lax.scan(ssm_l, xc, (p_g, h_in))
                xc, kv_, _ = self._layer({"attn": params["shared_attn"]},
                                         xc, positions, cache=kv_in,
                                         cache_pos=pos,
                                         mlp_p=params["shared_mlp"])
                return xc, (h_out, kv_)
            x, (ssm_h, shared_kv) = jax.lax.scan(
                group, x,
                (params["blocks"], cache["ssm_h"], cache["shared"]))
            new_cache = {"ssm_h": ssm_h, "shared": shared_kv}
            if "tail" in params:
                def ssm_t(xc, inp):
                    p_l, h_l = inp
                    out, st = ssm_decode(p_l, xc, cfg, {"h": h_l})
                    return xc + out, st["h"]
                x, tail_h = jax.lax.scan(
                    ssm_t, x, (params["tail"], cache["tail_h"]))
                new_cache["tail_h"] = tail_h

        elif kind == "rwkv":
            def body(xc, inp):
                p_l, st = inp
                xc, st2 = rwkv_block(p_l, xc, cfg, state=st)
                return xc, st2
            x, new_cache = jax.lax.scan(body, x,
                                        (params["blocks"], cache))

        h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return self.unembed(params, h), new_cache


def _ring_positions(pos, W: int):
    """Absolute key positions held by a ring-buffer window cache."""
    slots = jnp.arange(W)[None, :]
    offset = (pos[:, None] % W - slots) % W
    return pos[:, None] - offset                       # (B, W); <0 = unwritten


def _pad_cache(cache, cfg: ModelConfig, max_len: int):
    """Pad full-length KV caches out to max_len along the seq axis.
    Window (ring) caches and recurrent states pass through unchanged."""
    kind = family_kind(cfg)

    def pad_kv(tree):
        def f(a):
            if a.ndim >= 4 and a.shape[-2] < max_len:
                pads = [(0, 0)] * a.ndim
                pads[-2] = (0, max_len - a.shape[-2])
                return jnp.pad(a, pads)
            return a
        return jax.tree.map(f, tree)

    if kind == "uniform":
        return {k: pad_kv(v) for k, v in cache.items()}
    if kind == "local_global":
        out = dict(cache)
        out["global"] = pad_kv(cache["global"])
        return out
    if kind == "zamba":
        out = dict(cache)
        out["shared"] = pad_kv(cache["shared"])
        return out
    return cache
