"""Mamba2 (SSD) block — chunked selective state-space layer.

Implements the SSD chunked algorithm: intra-chunk quadratic term +
inter-chunk recurrence over chunk states (lax.scan over chunks). Decode
is a single recurrent state update (constant memory — this is what makes
zamba2 long_500k decode cheap).

Layout: d_inner = expand * d_model, nh = d_inner / ssm_head_dim heads,
scalar decay per head (Mamba2's A), single B/C group.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.params import ParamSpec
from ..distributed.sharding import shard
from .layers import bf16


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    return d_in, nh, cfg.ssm_head_dim, cfg.ssm_state


def ssm_specs(cfg: ModelConfig, layers: int = 1) -> dict:
    d = cfg.d_model
    d_in, nh, hd, ds = ssm_dims(cfg)
    lead = (layers,) if layers > 1 else ()
    lax_ = (None,) if layers > 1 else ()
    return {
        "w_xz": ParamSpec(lead + (d, 2 * d_in), lax_ + ("embed_w", "mlp")),
        "w_B": ParamSpec(lead + (d, ds), lax_ + ("embed_w", None)),
        "w_C": ParamSpec(lead + (d, ds), lax_ + ("embed_w", None)),
        "w_dt": ParamSpec(lead + (d, nh), lax_ + ("embed_w", None)),
        "dt_bias": ParamSpec(lead + (nh,), lax_ + (None,), init="zeros"),
        "A_log": ParamSpec(lead + (nh,), lax_ + (None,), init="zeros"),
        "D": ParamSpec(lead + (nh,), lax_ + (None,), init="ones"),
        "w_out": ParamSpec(lead + (d_in, d), lax_ + ("mlp", "embed_w"),
                           scale=1.0 / math.sqrt(2 * max(cfg.n_layers, 1))),
        "norm": ParamSpec(lead + (d,), lax_ + (None,), init="zeros"),
        "out_norm": ParamSpec(lead + (d_in,), lax_ + (None,), init="zeros"),
    }


def _proj(p, x, cfg: ModelConfig):
    """Shared projections. Returns xh (B,S,nh,hd), z, B_, C_, loga."""
    from .layers import rmsnorm
    d_in, nh, hd, ds = ssm_dims(cfg)
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    xz = h @ bf16(p["w_xz"])
    xin, z = jnp.split(xz, 2, axis=-1)
    B_ = h @ bf16(p["w_B"])                                    # (B,S,ds)
    C_ = h @ bf16(p["w_C"])                                    # (B,S,ds)
    dt = jax.nn.softplus((h @ bf16(p["w_dt"])) + p["dt_bias"]) # (B,S,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))         # (nh,)
    loga = dt.astype(jnp.float32) * A                    # log decay, <=0
    xh = xin.reshape(x.shape[0], x.shape[1], nh, hd)
    # dt-weighted input (Mamba2: x_bar = x * dt)
    xbar = xh.astype(jnp.float32) * dt[..., None]
    return xbar, xh, z, B_, C_, loga


def ssm_block(p, x, cfg: ModelConfig, *, state: Optional[dict] = None):
    """Train/prefill: full sequence, chunked scan.

    Returns (out, final_state) where state = {"h": (B,nh,hd,ds),
    "last": unused placeholder}.
    """
    from .layers import rmsnorm
    B, S, _ = x.shape
    d_in, nh, hd, ds = ssm_dims(cfg)
    Q = min(cfg.ssm_chunk, S)
    nchunks = -(-S // Q)
    pad = nchunks * Q - S
    xbar, xh, z, B_, C_, loga = _proj(p, x, cfg)
    if pad:
        xbar = jnp.pad(xbar, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))

    # (B, nc, Q, ...) chunked views
    xbar_c = xbar.reshape(B, nchunks, Q, nh, hd)
    B_c = B_.reshape(B, nchunks, Q, ds)
    C_c = C_.reshape(B, nchunks, Q, ds)
    loga_c = loga.reshape(B, nchunks, Q, nh)
    cum = jnp.cumsum(loga_c, axis=2)                     # (B,nc,Q,nh)
    total = cum[:, :, -1]                                # (B,nc,nh)

    h0 = (state["h"] if state is not None
          else jnp.zeros((B, nh, hd, ds), jnp.float32))

    def chunk_step(h, inp):
        xb, Bc, Cc, cm, tot = inp                        # per-chunk slices
        # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j <= i
        diff = cm[:, :, None, :] - cm[:, None, :, :]     # (B,Q,Q,nh)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        sBB = jnp.einsum("bqs,bts->bqt", Cc, Bc)         # (B,Q,Q)
        y_intra = jnp.einsum("bqt,bqtn,btnh->bqnh", sBB, L, xb)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bqs,bnhs,bqn->bqnh", Cc, h,
                             jnp.exp(cm))
        # state update: decay old + within-chunk outer products
        decay_to_end = jnp.exp(tot[:, None, :] - cm)     # (B,Q,nh)
        dstate = jnp.einsum("bqnh,bqs,bqn->bnhs", xb, Bc, decay_to_end)
        h_new = h * jnp.exp(tot)[:, :, None, None] + dstate
        return h_new, y_intra + y_inter

    inputs = (xbar_c.transpose(1, 0, 2, 3, 4), B_c.transpose(1, 0, 2, 3),
              C_c.transpose(1, 0, 2, 3), cum.transpose(1, 0, 2, 3),
              total.transpose(1, 0, 2))
    h_final, ys = jax.lax.scan(chunk_step, h0, inputs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nchunks * Q, nh, hd)[:, :S]
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = rmsnorm(y.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = y @ bf16(p["w_out"])
    return shard(out, "batch", "seq", None), {"h": h_final}


def ssm_decode(p, x, cfg: ModelConfig, state: dict):
    """Single-token recurrent update. x: (B,1,d)."""
    from .layers import rmsnorm
    B = x.shape[0]
    d_in, nh, hd, ds = ssm_dims(cfg)
    xbar, xh, z, B_, C_, loga = _proj(p, x, cfg)
    xb = xbar[:, 0]                                      # (B,nh,hd)
    Bc, Cc = B_[:, 0], C_[:, 0]                          # (B,ds)
    a = jnp.exp(loga[:, 0])                              # (B,nh)
    h = state["h"] * a[:, :, None, None] + jnp.einsum(
        "bnh,bs->bnhs", xb, Bc)
    y = jnp.einsum("bnhs,bs->bnh", h, Cc)
    y = y + xh[:, 0].astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, d_in)
    y = rmsnorm(y.astype(x.dtype), p["out_norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = y @ bf16(p["w_out"])
    return out, {"h": h}


def ssm_init_state(cfg: ModelConfig, batch: int) -> dict:
    d_in, nh, hd, ds = ssm_dims(cfg)
    return {"h": jnp.zeros((batch, nh, hd, ds), jnp.float32)}
