"""Modality frontend STUBS (per assignment: [vlm]/[audio] entries specify
the transformer backbone only; ``input_specs()`` provides precomputed
frame/patch embeddings).

The stubs document the real interface shape and produce deterministic
embeddings for tests:

* Qwen2-VL: dynamic-resolution ViT patches -> (B, S_img, d) embeddings +
  3D M-RoPE position streams (t, h, w) for the image span.
* MusicGen: EnCodec RVQ tokens, 4 codebooks with the delay pattern ->
  summed codebook embeddings (B, S, d).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def vision_patch_embeds(cfg: ModelConfig, batch: int, n_patches: int,
                        key=None):
    """Stand-in for the Qwen2-VL ViT: (B, n_patches, d_model) embeddings."""
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.random.normal(key, (batch, n_patches, cfg.d_model),
                             jnp.bfloat16) * 0.02


def mrope_positions(batch: int, n_text: int, n_patches: int, grid_hw=None):
    """3D position streams for text+image spans (Qwen2-VL Sec. 3).

    Text tokens advance all three streams together; image patches share a
    time index and advance (h, w) over the patch grid.
    """
    h_g = int(n_patches ** 0.5) if grid_hw is None else grid_hw[0]
    w_g = -(-n_patches // h_g)
    t_img = jnp.zeros((n_patches,), jnp.int32)
    h_img = (jnp.arange(n_patches) // w_g).astype(jnp.int32)
    w_img = (jnp.arange(n_patches) % w_g).astype(jnp.int32)
    t_txt = jnp.arange(n_text, dtype=jnp.int32) + 1
    txt = jnp.stack([t_txt, t_txt, t_txt])
    img = jnp.stack([t_img, h_img, w_img])
    pos = jnp.concatenate([img, txt], axis=1)          # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, pos.shape[1]))


def encodec_token_embeds(params_embed, tokens_4cb):
    """MusicGen frontend: sum of 4 codebook embeddings with delay pattern.

    tokens_4cb: (B, 4, S) int32 in [0, 2048). The k-th codebook is
    delayed by k steps (MusicGen's delay interleaving).
    """
    B, K, S = tokens_4cb.shape
    embeds = jnp.zeros((B, S, params_embed.shape[1]), jnp.float32)
    for k in range(K):
        shifted = jnp.roll(tokens_4cb[:, k], k, axis=1)
        shifted = shifted.at[:, :k].set(0)
        embeds = embeds + jnp.take(params_embed, shifted, axis=0)
    return embeds / K


def input_embeds_for(cfg: ModelConfig, params, tokens, key=None):
    """Dispatch: text archs embed tokens; vlm/audio stubs build embeds."""
    if cfg.modality == "vision":
        B, S = tokens.shape
        n_img = min(S // 4, 256)
        img = vision_patch_embeds(cfg, B, n_img, key)
        txt = jnp.take(params["embed"], tokens[:, n_img:], axis=0)
        return jnp.concatenate([img, txt.astype(img.dtype)], axis=1)
    if cfg.modality == "audio":
        B, S = tokens.shape
        cb = jnp.stack([tokens, jnp.roll(tokens, 1, 1),
                        jnp.roll(tokens, 2, 1), jnp.roll(tokens, 3, 1)],
                       axis=1) % cfg.vocab
        return encodec_token_embeds(params["embed"], cb)
    return None
