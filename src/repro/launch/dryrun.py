import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell: build abstract (ShapeDtypeStruct, no allocation) params /
optimizer state / caches / inputs with resolved shardings, jit-lower the
train or serve step against the production mesh, compile, and record
memory_analysis + cost_analysis + the HLO-parsed roofline terms
(hlo_analysis handles while-loop trip counts; XLA's own cost model counts
scan bodies once).

Usage:
  python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k \
      --mesh single --out results/
  python -m repro.launch.dryrun --all [--mesh both] [--out results/]
"""
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path


def _cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from ..configs import SHAPES, get_config, shape_applicable
    from ..configs.base import TrainConfig
    from ..distributed import abstract_params, count_params, use_mesh
    from ..models import LM, cache_specs, model_specs
    from ..training.optimizer import make_train_step, opt_state_specs
    from .hlo_analysis import analyze
    from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    lm = LM(cfg)
    B, S = shape.global_batch, shape.seq_len
    t0 = time.time()

    with use_mesh(mesh) as ctx:
        p_specs = model_specs(cfg)
        params_abs = abstract_params(p_specs, ctx)

        def tok_struct(shp, dtype=jnp.int32, axes=("batch", "seq")):
            from ..distributed import named_sharding
            return jax.ShapeDtypeStruct(
                shp, dtype, sharding=named_sharding(shp, axes, ctx))

        if shape.kind == "train":
            tcfg = TrainConfig()
            opt_abs = abstract_params(opt_state_specs(p_specs), ctx)
            batch = {"tokens": tok_struct((B, S)),
                     "targets": tok_struct((B, S))}
            if cfg.modality != "text":
                batch["embeds"] = tok_struct((B, S, cfg.d_model),
                                             jnp.bfloat16,
                                             ("batch", "seq", None))
            step = make_train_step(lm, tcfg)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params_abs, opt_abs, batch)
        elif shape.kind == "prefill":
            args = {"tokens": tok_struct((B, S))}
            if cfg.modality != "text":
                args["embeds"] = tok_struct((B, S, cfg.d_model),
                                            jnp.bfloat16,
                                            ("batch", "seq", None))

            def prefill(params, batch):
                return lm.prefill(params, batch["tokens"], max_len=S,
                                  embeds=batch.get("embeds"))
            lowered = jax.jit(prefill).lower(params_abs, args)
        else:  # decode
            cache_abs = abstract_params(cache_specs(cfg, B, S), ctx)
            token = tok_struct((B,), axes=("batch",))
            pos = tok_struct((B,), axes=("batch",))

            def decode(params, token, cache, pos):
                return lm.decode_step(params, token, cache, pos)
            lowered = jax.jit(decode, donate_argnums=(2,)).lower(
                params_abs, token, cache_abs, pos)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = analyze(compiled.as_text())

    n_params = count_params(p_specs)
    # MODEL_FLOPS: 6*N*D for train, 2*N*D per generated/processed token
    # for serving (N = active params for MoE).
    n_active = n_params
    if cfg.n_experts:
        expert_params = 3 * cfg.d_model * cfg.d_ff
        n_moe_layers = cfg.n_layers - cfg.first_k_dense
        n_active = (n_params
                    - n_moe_layers * cfg.n_experts * expert_params
                    + n_moe_layers * cfg.top_k * expert_params)
    tokens = B * S if shape.kind != "decode" else B
    mult = 6 if shape.kind == "train" else 2
    model_flops = mult * n_active * tokens

    flops_dev = hlo["flops"]
    bytes_dev = hlo["bytes"]
    coll_dev = hlo["collective_bytes"]
    out = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "chips": chips,
        "n_params": n_params, "n_active_params": n_active,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # memory (per device)
        "mem_temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "mem_arg_bytes": getattr(mem, "argument_size_in_bytes", None),
        "mem_out_bytes": getattr(mem, "output_size_in_bytes", None),
        "mem_alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        # xla cost analysis (loops counted once — kept for reference)
        "xla_flops": cost.get("flops"),
        "xla_bytes": cost.get("bytes accessed"),
        # hlo-parsed, per device, trip-count corrected
        "hlo_flops_dev": flops_dev,
        "hlo_bytes_dev": bytes_dev,
        "coll_bytes_dev": coll_dev,
        "collectives": hlo["collectives"],
        # roofline terms (seconds)
        "t_compute": flops_dev / PEAK_FLOPS_BF16,
        "t_memory": bytes_dev / HBM_BW,
        "t_collective": coll_dev / ICI_BW,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / (flops_dev * chips)
                               if flops_dev else None),
    }
    terms = {"compute": out["t_compute"], "memory": out["t_memory"],
             "collective": out["t_collective"]}
    out["bottleneck"] = max(terms, key=terms.get)
    out["roofline_fraction"] = (
        max(terms["compute"], 1e-30) / max(sum(terms.values()), 1e-30))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--overwrite", action="store_true")
    args = ap.parse_args()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.all:
        from ..configs import ARCHS, SHAPES
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        cells = [(a, s, m) for a in ARCHS for s in SHAPES for m in meshes]
        failures = 0
        for a, s, m in cells:
            tag = f"{a}__{s}__{m}"
            path = outdir / f"{tag}.json"
            if path.exists() and not args.overwrite:
                print(f"[skip-cached] {tag}", flush=True)
                continue
            t0 = time.time()
            r = subprocess.run(
                [sys.executable, "-m", "repro.launch.dryrun",
                 "--arch", a, "--shape", s, "--mesh", m, "--out", args.out],
                capture_output=True, text=True, timeout=2400)
            dt = time.time() - t0
            if r.returncode != 0:
                failures += 1
                (outdir / f"{tag}.err").write_text(
                    r.stdout[-4000:] + "\n---\n" + r.stderr[-8000:])
                print(f"[FAIL {dt:6.1f}s] {tag}", flush=True)
            else:
                print(f"[ok   {dt:6.1f}s] {tag}", flush=True)
        print(f"done, {failures} failures", flush=True)
        return

    assert args.arch and args.shape and args.mesh in ("single", "multi")
    try:
        res = _cell(args.arch, args.shape, args.mesh == "multi")
    except Exception:
        traceback.print_exc()
        sys.exit(1)
    tag = f"{args.arch}__{args.shape}__{args.mesh}"
    path = outdir / f"{tag}.json"
    path.write_text(json.dumps(res, indent=2, default=str))
    print(json.dumps(
        {k: res.get(k) for k in
         ("arch", "shape", "mesh", "status", "reason", "compile_s",
          "mem_temp_bytes", "hlo_flops_dev", "t_compute", "t_memory",
          "t_collective", "bottleneck", "useful_flops_ratio")},
        indent=2, default=str))


if __name__ == "__main__":
    main()
