"""Post-optimization HLO text analysis for the roofline report.

XLA's built-in ``compiled.cost_analysis()`` counts every while-loop body
ONCE (verified in this container: a scanned 8-layer MLP reports 1/8th of
the unrolled FLOPs), so scanned layer stacks would be undercounted by
n_layers. This module parses ``compiled.as_text()`` into a computation
call-graph, extracts while-loop trip counts from their condition
computations, and accumulates:

* dot FLOPs           (2 * prod(result dims) * prod(contracting dims))
* HBM traffic         (operand + result bytes of top-level ops; fusion
                       bodies excluded — a fusion reads its operands and
                       writes its result once)
* collective bytes    (operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute)

All quantities are multiplied by the static call multiplicity (ENTRY=1,
while bodies x trip count, nested loops compose). Numbers are PER DEVICE
(the module is the SPMD-partitioned one).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0,
    "f8e4m3b11fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1, "f4e2m1fn": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.*)$")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclass
class Op:
    name: str
    type_str: str
    body: str
    kind: str
    operands: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: dict = field(default_factory=dict)        # name -> Op
    order: list = field(default_factory=list)
    is_fusion_body: bool = False
    is_entry: bool = False
    root: str = ""


def _split_type_and_rest(rest: str):
    """'(f32[2]{0}, s32[]) tuple(...)' -> (type_str, op_body)."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, c in enumerate(rest):
            depth += c == "("
            depth -= c == ")"
            if depth == 0:
                return rest[:i + 1], rest[i + 1:].strip()
    sp = rest.find(" ")
    if sp < 0:
        return rest, ""
    return rest[:sp], rest[sp + 1:].strip()


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        # computation header: '%name (args) -> type {' or 'ENTRY %name ...'
        if stripped.endswith("{") and ("(" in stripped) and "=" not in \
                stripped.split("(")[0]:
            header = stripped
            is_entry = header.startswith("ENTRY")
            m = _NAME_RE.search(header)
            name = m.group(1) if m else f"comp{len(comps)}"
            cur = Computation(name=name, is_entry=is_entry,
                              is_fusion_body="fused" in name)
            comps[name] = cur
            # parameters: 'param: f32[...]' pairs inside header parens
            sig = header[header.find("(") + 1:header.rfind("->")]
            for pm in re.finditer(r"([\w.\-]+):\s*([^,)]+)", sig):
                pname, ptype = pm.group(1), pm.group(2).strip()
                cur.ops[pname] = Op(pname, ptype, "", "parameter")
            continue
        if stripped == "}" or stripped == "})":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(stripped)
        if not m:
            continue
        name = m.group(1).lstrip("%")
        if stripped.startswith("ROOT"):
            cur.root = name
        type_str, body = _split_type_and_rest(m.group(2))
        kind_m = re.match(r"([\w\-]+)", body)
        kind = kind_m.group(1) if kind_m else ""
        op = Op(name, type_str, body, kind)
        # operand names: inside the FIRST parens of the body
        p0 = body.find("(")
        if p0 >= 0:
            depth, i = 0, p0
            for i in range(p0, len(body)):
                depth += body[i] == "("
                depth -= body[i] == ")"
                if depth == 0:
                    break
            op.operands = [x for x in
                           _NAME_RE.findall(body[p0:i + 1])]
        cur.ops[name] = op
        cur.order.append(name)
    return comps


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the while condition (scan bound)."""
    best = 1
    for op in cond.ops.values():
        for m in re.finditer(r"constant\((\d+)\)", op.body):
            best = max(best, int(m.group(1)))
    return best


_ATTR_COMP = {
    "while": ("body=", "condition="),
    "fusion": ("calls=",),
    "reduce": ("to_apply=",),
    "sort": ("to_apply=",),
    "map": ("to_apply=",),
    "scatter": ("to_apply=",),
    "all-reduce": ("to_apply=",),
    "reduce-scatter": ("to_apply=",),
    "select-and-scatter": ("select=", "scatter="),
    "call": ("to_apply=",),
    "custom-call": ("called_computations=",),
    "conditional": ("true_computation=", "false_computation=",
                    "branch_computations=",),
}


def analyze(text: str) -> dict:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {"flops": 0, "bytes": 0, "collective_bytes": 0,
                "collectives": {}}

    # multiplicity propagation (memoized DFS from entry)
    mult: dict[str, float] = defaultdict(float)
    mult[entry.name] = 1.0
    stack = [entry.name]
    seen_edges = set()
    while stack:
        cname = stack.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m_c = mult[cname]
        for opname in comp.order:
            op = comp.ops[opname]
            attrs = _ATTR_COMP.get(op.kind, ())
            for attr in attrs:
                for am in re.finditer(re.escape(attr) +
                                      r"\{?%?([\w.\-]+)", op.body):
                    callee = am.group(1)
                    if callee not in comps:
                        continue
                    factor = 1.0
                    if op.kind == "while" and attr == "body=":
                        cond_m = re.search(r"condition=%?([\w.\-]+)",
                                           op.body)
                        if cond_m and cond_m.group(1) in comps:
                            factor = _trip_count(comps[cond_m.group(1)])
                    edge = (cname, opname, callee)
                    if edge in seen_edges:
                        continue
                    seen_edges.add(edge)
                    mult[callee] += m_c * factor
                    stack.append(callee)

    flops = 0.0
    traffic = 0.0
    coll_bytes = 0.0
    coll_counts: dict[str, float] = defaultdict(float)

    def _fusion_dus_bytes(op: Op) -> Optional[float]:
        """Fusion whose root is an in-place dynamic-update-slice (the
        scan-carried KV-cache write pattern): XLA aliases the big
        operand, so real traffic is ~2x the UPDATE slice, not the whole
        buffer. Returns None when the pattern doesn't apply."""
        cm = re.search(r"calls=%?([\w.\-]+)", op.body)
        if not cm or cm.group(1) not in comps:
            return None
        body_c = comps[cm.group(1)]
        root = body_c.ops.get(body_c.root)
        if root is None or root.kind != "dynamic-update-slice":
            return None
        if len(root.operands) > 1 and root.operands[1] in body_c.ops:
            upd = shape_bytes(body_c.ops[root.operands[1]].type_str)
        else:
            upd = 0.0
        # other (small) fusion inputs still stream through HBM; the
        # largest operand is the aliased buffer itself -> excluded
        others = sorted(shape_bytes(comp.ops[o].type_str)
                        for o in op.operands if o in comp.ops)
        small = sum(others[:-1]) if others else 0.0
        return 2.0 * upd + small

    def op_traffic(comp: Computation, op: Op) -> float:
        """HBM bytes for one op. Slicing/indexing ops only touch the
        slice (XLA does not copy the full operand); control-flow ops
        carry no traffic themselves (their bodies are counted)."""
        res = shape_bytes(op.type_str)
        if op.kind in ("while", "conditional", "call"):
            return 0.0
        if op.kind == "fusion":
            dus = _fusion_dus_bytes(op)
            if dus is not None:
                return dus
            # XLA names fusions after their constituent ops. Two
            # slice-pattern cases where the big operand is NOT streamed:
            # (a) in-place cache writes ("dynamic-update-slice_*"):
            #     traffic = 2x everything except the aliased buffer
            #     (the buffer-sized operand). The CPU backend also wraps
            #     these in bf16<->f32 converts (no native bf16 dot) that
            #     a TPU build would not emit.
            # (b) slice reads ("*bitcast*"/"*slice*" fusions whose
            #     result is far smaller than the largest operand):
            #     traffic = 2x result + small operands.
            ops_b = sorted(shape_bytes(comp.ops[o].type_str)
                           for o in op.operands if o in comp.ops)
            if "dynamic-update-slice" in op.name:
                small = [b for b in ops_b if b < res]
                return 2.0 * sum(small)
            if (("bitcast" in op.name or "slice" in op.name)
                    and ops_b and res * 8 <= ops_b[-1]):
                return 2.0 * res + sum(ops_b[:-1])
        if op.kind in ("dynamic-slice", "gather", "slice"):
            return 2.0 * res
        if op.kind in ("dynamic-update-slice",):
            upd = (shape_bytes(comp.ops[op.operands[1]].type_str)
                   if len(op.operands) > 1 and op.operands[1] in comp.ops
                   else res)
            return 2.0 * upd
        if op.kind == "scatter":
            upd = (shape_bytes(comp.ops[op.operands[2]].type_str)
                   if len(op.operands) > 2 and op.operands[2] in comp.ops
                   else res)
            return 3.0 * upd
        ob = sum(shape_bytes(comp.ops[o].type_str)
                 for o in op.operands if o in comp.ops)
        return ob + res

    for cname, comp in comps.items():
        m_c = mult.get(cname, 0.0)
        if m_c == 0.0:
            continue
        for opname in comp.order:
            op = comp.ops[opname]
            if op.kind in ("parameter", "constant", "get-tuple-element",
                           "tuple", "bitcast"):
                continue
            # -- dot flops (counted everywhere, incl. fusion bodies)
            if op.kind in ("dot", "convolution"):
                _, rdims = shape_dims(op.type_str)
                contract = 1
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                               op.body)
                if cm and op.operands:
                    lhs = comp.ops.get(op.operands[0])
                    if lhs is not None:
                        _, ldims = shape_dims(lhs.type_str)
                        for idx in cm.group(1).split(","):
                            if idx and int(idx) < len(ldims):
                                contract *= ldims[int(idx)]
                import numpy as _np
                flops += m_c * 2.0 * float(_np.prod(rdims or [0])) \
                    * contract
            # -- collectives
            if op.kind in COLLECTIVES:
                ob = sum(shape_bytes(comp.ops[o].type_str)
                         for o in op.operands if o in comp.ops)
                coll_bytes += m_c * ob
                coll_counts[op.kind] += m_c
            # -- HBM traffic: top-level ops only (fusion bodies excluded)
            if not comp.is_fusion_body:
                traffic += m_c * op_traffic(comp, op)
    return {
        "flops": flops,
        "bytes": traffic,
        "collective_bytes": coll_bytes,
        "collectives": dict(coll_counts),
        "n_computations": len(comps),
    }
