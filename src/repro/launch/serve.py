"""Serving driver: the paper's hybrid scheduler over device slots.

Two modes:
  gateway (default) — trace-driven slot-scheduler comparison (hybrid vs
      CFS-analogue vs FIFO) for a chosen --arch, with billing.
  engine — run the REAL reduced model through the serving engine.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b
  PYTHONPATH=src python -m repro.launch.serve --mode engine --arch gemma3-12b
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke
from ..distributed import materialize
from ..models import model_specs
from ..serving import LiveRequest, ServingEngine, requests_from_trace, \
    run_gateway
from ..traces import TraceSpec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--mode", default="gateway",
                    choices=["gateway", "engine"])
    ap.add_argument("--minutes", type=int, default=1)
    ap.add_argument("--rate", type=float, default=3000.0)
    ap.add_argument("--slots", type=int, default=50)
    args = ap.parse_args()

    if args.mode == "gateway":
        cfg = get_config(args.arch)
        trace = TraceSpec(minutes=args.minutes,
                          invocations_per_min=args.rate)
        reqs = requests_from_trace(cfg, trace)
        rows = []
        for policy in ("fifo", "cfs", "hybrid"):
            r = run_gateway(cfg, policy, requests=reqs,
                            n_slots=args.slots)
            s = r.summary()
            rows.append({k: s[k] for k in
                         ("policy", "n", "p99_execution_s",
                          "p99_response_s", "p99_turnaround_s",
                          "cost_usd", "preemptions")})
            print(json.dumps(rows[-1]))
        cfs = next(r for r in rows if r["policy"] == "cfs")
        hyb = next(r for r in rows if r["policy"] == "hybrid")
        print(f"[serve] {args.arch}: hybrid saves "
              f"{cfs['cost_usd'] / max(hyb['cost_usd'], 1e-9):.1f}x vs "
              f"CFS-analogue")
        return

    cfg = get_smoke(args.arch)
    params = materialize(model_specs(cfg), jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, n_slots=4, n_fifo=2, max_len=64,
                        initial_limit_ms=40.0)
    key = jax.random.PRNGKey(1)
    for rid in range(8):
        toks = jax.random.randint(jax.random.fold_in(key, rid), (1, 8),
                                  0, cfg.vocab)
        eng.submit(LiveRequest(rid=rid, arrival_ms=0.0, tokens=toks,
                               max_new=4 + rid * 2))
    for r in eng.run():
        print(f"req {r.rid}: tokens={len(r.generated)} "
              f"exec={r.execution_ms():.1f}ms preempt={r.preemptions} "
              f"cost=${r.cost_usd():.2e}")


if __name__ == "__main__":
    main()
