"""Production mesh construction (MULTI-POD DRY-RUN spec).

A v5e pod is 16x16 = 256 chips; the multi-pod mesh prepends a "pod" axis
(2 pods = 512 chips). Functions, not module constants, so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)}; "
            "run under dryrun.py (XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512)")
    dev = np.array(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (1 device)."""
    dev = np.array(jax.devices()[:data * model]).reshape((data, model))
    return jax.sharding.Mesh(dev, ("data", "model"))


# Hardware constants (TPU v5e) for the roofline report.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
