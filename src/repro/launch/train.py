"""Training driver: real steps on CPU (smoke/reduced configs) with the
full production substrate — AdamW, remat, checkpoint/restart, straggler
watchdog, resumable data pipeline.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
      --smoke --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke
from ..configs.base import TrainConfig
from ..distributed import materialize
from ..distributed.elastic import StepWatchdog
from ..models import LM, model_specs
from ..training.data import SyntheticLM
from ..training.optimizer import init_opt_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1))
    lm = LM(cfg)
    params = materialize(model_specs(cfg), jax.random.PRNGKey(tcfg.seed))
    opt = init_opt_state(params)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       batch=args.batch, seed=tcfg.seed)
    step_fn = jax.jit(make_train_step(lm, tcfg), donate_argnums=(0, 1))
    watchdog = StepWatchdog()

    start = 0
    ckpt = None
    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir, keep=2)
        latest, state = ckpt.restore_latest(
            {"params": params, "opt": opt, "data": data.state_dict()})
        if latest is not None:
            params, opt = state["params"], state["opt"]
            data.load_state(state["data"])
            start = latest
            print(f"[train] resumed from step {latest}")

    t_run = time.time()
    for step in range(start, args.steps):
        batch = data.next_batch()
        t0 = time.time()
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if watchdog.record(dt):
            print(f"[train] straggler step {step}: {dt:.2f}s")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s",
                  flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt,
                                 "data": data.state_dict()})
    if ckpt:
        ckpt.save(args.steps, {"params": params, "opt": opt,
                               "data": data.state_dict()})
        ckpt.wait()
    print(f"[train] done in {time.time() - t_run:.1f}s")


if __name__ == "__main__":
    main()
