"""gemma3-12b [dense]: 48L d=3840 16H (GQA kv=8) d_ff=15360,
vocab 262144, 5:1 local(1024-window):global attention, 128k context.
[hf:google/gemma-3-1b-pt]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_ff=15360, vocab=262144, local_window=1024, local_global_ratio=5,
    tie_embeddings=True, rope_theta=1e6,
    ms_per_token_decode=8.0, ms_per_ktoken_prefill=28.0,
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=7, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab=256, local_window=16)
