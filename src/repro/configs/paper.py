"""Paper experiment configuration (scheduler + workload parameters)."""
from __future__ import annotations

from dataclasses import dataclass, field

from ..traces.azure import TraceSpec


@dataclass(frozen=True)
class SchedulerConfig:
    n_cores: int = 50                # 50-core ghOSt enclave (paper Sec. V-C)
    n_fifo: int = 25                 # best split (Fig. 11)
    time_limit_ms: float = 1633.0    # p90 of the workload (Sec. II-E)
    adapt_pct: float = 95.0          # best percentile (Fig. 15)
    adapt_window: int = 100          # most recent 100 durations (Sec. IV-B)
    rightsize_interval_ms: float = 1000.0
    rightsize_threshold: float = 0.15
    ctx_switch_ms: float = 0.06
    sched_latency_ms: float = 24.0
    min_granularity_ms: float = 3.0
    ghost_mode: bool = False         # native-CFS interference model


@dataclass(frozen=True)
class PaperConfig:
    trace: TraceSpec = field(default_factory=TraceSpec)
    sched: SchedulerConfig = field(default_factory=SchedulerConfig)


CONFIG = PaperConfig()
