"""moonshot-v1-16b-a3b [moe]: 48L d=2048 16H (GQA kv=16) d_ff=1408/expert,
vocab 163840, 64 experts top-6, first layer dense (Moonlight/DeepSeek
style).  [hf:moonshotai/Moonlight-16B-A3B]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840, n_experts=64, top_k=6, first_k_dense=1,
    tie_embeddings=False, rope_theta=5e4,
    ms_per_token_decode=6.0, ms_per_ktoken_prefill=18.0,
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
                        d_ff=48, vocab=256, n_experts=8, top_k=2,
                        first_k_dense=1, capacity_factor=8.0)
