"""zamba2-1.2b [hybrid]: 38L Mamba2 backbone (d=2048, ssm_state=64) with a
weight-SHARED attention+MLP block (32H kv=32, d_ff=8192) applied every 6
layers, vocab 32000.  [arXiv:2411.15242]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, ssm_state=64, shared_attn_every=6,
    tie_embeddings=True,
    ms_per_token_decode=2.5, ms_per_ktoken_prefill=6.0,
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=8, d_model=64, n_heads=4, n_kv_heads=4,
                        d_ff=128, vocab=256, ssm_state=16,
                        shared_attn_every=3, ssm_chunk=16)
