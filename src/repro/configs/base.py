"""Model/run configuration dataclasses + the input-shape set.

Every assigned architecture provides CONFIG (exact pool spec) and
``smoke()`` (reduced same-family config for CPU tests).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                # 0 => attention-free
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 => d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    first_k_dense: int = 0      # leading dense layers in MoE stacks
    # local/global attention pattern (gemma3): ratio L local : 1 global
    local_window: int = 0
    local_global_ratio: int = 0
    # hybrid (zamba2): shared attention block every k SSM layers
    shared_attn_every: int = 0
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # RWKV
    rwkv_head_dim: int = 64
    # misc
    rope_theta: float = 1e4
    mrope: bool = False         # qwen2-vl M-RoPE (3D sections)
    mrope_sections: tuple = (16, 24, 24)   # t/h/w halves of head_dim
    tie_embeddings: bool = True
    modality: str = "text"      # text | vision | audio
    attn_logit_softcap: float = 0.0
    norm_eps: float = 1e-6
    act: str = "silu"
    # serving-model parameters (L2 gateway service-time model)
    ms_per_token_decode: float = 8.0
    ms_per_ktoken_prefill: float = 30.0

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode (sliding-window / SSM / hybrid)."""
        return (self.family in ("ssm", "hybrid")
                or self.local_global_ratio > 0)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


# The assigned input-shape set (same four for every LM arch).
SHAPES = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md Sec. 6)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: pure full-attention arch; 500k-token KV "
                       "decode requires sub-quadratic attention")
    return True, ""


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    z_loss: float = 1e-4
    remat: str = "block"        # none | block | full
    microbatches: int = 1       # gradient accumulation
    seed: int = 0
