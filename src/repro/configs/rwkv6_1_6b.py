"""rwkv6-1.6b [ssm]: 24L d=2048 attention-free (RWKV6 "Finch"
data-dependent decay), d_ff=7168, vocab 65536.  [arXiv:2404.05892]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=7168, vocab=65536, tie_embeddings=False,
    ms_per_token_decode=2.0, ms_per_ktoken_prefill=5.0,
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=64, d_ff=128, vocab=256)
