"""musicgen-large [audio]: 48L d=2048 32H (MHA kv=32) d_ff=8192,
vocab 2048 — decoder-only over EnCodec RVQ tokens (4 codebooks, delay
pattern; EnCodec frontend is a STUB per the assignment).
[arXiv:2306.05284]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, modality="audio", tie_embeddings=False,
    ms_per_token_decode=4.0, ms_per_ktoken_prefill=12.0,
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_ff=128, vocab=128)
