"""gemma3-27b [dense]: 62L d=5376 32H (GQA kv=16) d_ff=21504,
vocab 262144, 5:1 local(1024-window):global attention, 128k context.
[hf:google/gemma-3-1b-pt]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21504, vocab=262144, local_window=1024, local_global_ratio=5,
    tie_embeddings=True, rope_theta=1e6, attn_logit_softcap=0.0,
    ms_per_token_decode=14.0, ms_per_ktoken_prefill=45.0,
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=13, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab=256, local_window=16)
