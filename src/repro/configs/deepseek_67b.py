"""deepseek-67b [dense]: 95L d=8192 64H (GQA kv=8) d_ff=22016,
vocab 102400, llama architecture.  [arXiv:2401.02954]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=102400, tie_embeddings=False, rope_theta=1e4,
    ms_per_token_decode=25.0, ms_per_ktoken_prefill=90.0,
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=96, n_heads=8, n_kv_heads=2,
                        d_ff=192, vocab=256)
