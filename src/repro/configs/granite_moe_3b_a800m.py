"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8) d_ff=512/expert,
vocab 49155, 40 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base]

Sharding notes: 24 heads, 40 experts, vocab 49155 are all non-divisible
by the 16-way model axis -> resolver falls back to replicated heads
(+ sequence-sharded KV), expert-TP on d_ff (512/16), embed-dim-sharded
vocab table (DESIGN.md Sec. 6).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155, n_experts=40, top_k=8,
    tie_embeddings=True, rope_theta=1e4,
    ms_per_token_decode=3.0, ms_per_ktoken_prefill=9.0,
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
                        d_ff=64, vocab=256, n_experts=4, top_k=2,
                        capacity_factor=8.0)  # dropless for path-consistency tests
