"""deepseek-7b [dense]: 30L d=4096 32H (MHA kv=32) d_ff=11008,
vocab 102400, llama architecture.  [arXiv:2401.02954]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=102400, tie_embeddings=False, rope_theta=1e4,
    ms_per_token_decode=4.5, ms_per_ktoken_prefill=14.0,
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_ff=128, vocab=256)
