"""Architecture registry: --arch <id> resolution + smoke variants."""
from __future__ import annotations

import importlib

from .base import ModelConfig, SHAPES, ShapeConfig, shape_applicable

ARCHS = (
    "granite-moe-3b-a800m",
    "moonshot-v1-16b-a3b",
    "zamba2-1.2b",
    "qwen2-vl-2b",
    "deepseek-67b",
    "gemma3-27b",
    "gemma3-12b",
    "deepseek-7b",
    "rwkv6-1.6b",
    "musicgen-large",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {list(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.smoke()


def all_cells():
    """All 40 (arch, shape) cells with applicability flags."""
    out = []
    for a in ARCHS:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            out.append((a, s.name, ok, why))
    return out
