"""qwen2-vl-2b [vlm]: 28L d=1536 12H (GQA kv=2) d_ff=8960, vocab 151936,
M-RoPE + dynamic resolution (ViT frontend is a STUB per the assignment;
input_specs() supplies precomputed patch embeddings).  [arXiv:2409.12191]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, mrope=True, mrope_sections=(16, 24, 24),
    modality="vision", tie_embeddings=True, rope_theta=1e6,
    ms_per_token_decode=2.5, ms_per_ktoken_prefill=7.0,
)

def smoke() -> ModelConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab=256, mrope_sections=(2, 3, 3))
