"""repro.configs — model + shape + paper configurations."""
from .base import (ModelConfig, SHAPES, ShapeConfig, TrainConfig,
                   shape_applicable)
from .registry import ARCHS, all_cells, get_config, get_smoke

__all__ = [
    "ModelConfig", "SHAPES", "ShapeConfig", "TrainConfig",
    "shape_applicable", "ARCHS", "all_cells", "get_config", "get_smoke",
]
