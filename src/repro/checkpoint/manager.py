"""Fault-tolerant checkpointing: atomic, content-hashed, auto-resuming.

Layout: <dir>/step_<N>/
    arrays.npz      flattened leaves (key = /-joined tree path)
    meta.json       step, tree structure digest, content hash, wall time

Writes go to a temp dir + atomic rename, so a crash mid-save never
corrupts the latest checkpoint. ``restore_latest`` walks steps downward
and skips checkpoints whose content hash fails (torn/bit-rotted files)
— together with the training loop's signal hook this gives
checkpoint/restart fault tolerance. Async mode hands the write to a
background thread (training continues; ``wait()`` joins before exit).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _content_hash(flat: dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(flat):
        h.update(k.encode())
        h.update(np.ascontiguousarray(flat[k]).tobytes())
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        tree = jax.tree.map(np.asarray, tree)   # device -> host copy now
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, tree, extra))
            self._thread.start()
        else:
            self._save_sync(step, tree, extra)

    def _save_sync(self, step: int, tree: Any, extra: Optional[dict]):
        flat = _flatten(tree)
        tmp = self.dir / f".tmp_step_{step}_{os.getpid()}"
        tmp.mkdir(parents=True, exist_ok=True)
        np.savez(tmp / "arrays.npz", **flat)
        meta = {
            "step": step,
            "hash": _content_hash(flat),
            "keys": sorted(flat),
            "time": time.time(),
            "extra": extra or {},
        }
        (tmp / "meta.json").write_text(json.dumps(meta))
        final = self.dir / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)                  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.dir.glob("step_*"))
        for old in steps[:-self.keep]:
            shutil.rmtree(old, ignore_errors=True)

    # -- restore -----------------------------------------------------------
    def steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1])
                      for p in self.dir.glob("step_*"))

    def restore(self, step: int, like: Any) -> Any:
        path = self.dir / f"step_{step:08d}"
        meta = json.loads((path / "meta.json").read_text())
        with np.load(path / "arrays.npz") as z:
            flat = {k: z[k] for k in z.files}
        if _content_hash(flat) != meta["hash"]:
            raise IOError(f"checkpoint {step} failed integrity check")
        leaves_like = jax.tree_util.tree_flatten_with_path(like)[0]
        out = []
        for p, leaf in leaves_like:
            key = "/".join(str(getattr(x, "key", getattr(x, "idx", x)))
                           for x in p)
            arr = flat[key]
            out.append(np.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
        return jax.tree.unflatten(jax.tree.structure(like), out)

    def restore_latest(self, like: Any) -> tuple[Optional[int], Any]:
        """Newest checkpoint that passes integrity; (None, like) if none."""
        for step in reversed(self.steps()):
            try:
                return step, self.restore(step, like)
            except Exception:
                continue
        return None, like
