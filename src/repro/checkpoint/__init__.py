"""repro.checkpoint — atomic, hashed, auto-resuming checkpoints."""
from .manager import CheckpointManager

__all__ = ["CheckpointManager"]
