"""repro — hybrid two-level FaaS scheduling (Zhao et al., 2024) as a
production JAX training/serving framework. See DESIGN.md."""
__version__ = "1.0.0"
