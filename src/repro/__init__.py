"""repro — hybrid two-level FaaS scheduling (Zhao et al., 2024) as a
production JAX training/serving framework. See DESIGN.md.

The public entrypoint is the Scenario API::

    import repro
    sc = repro.Scenario(...)
    res = repro.run(sc)
    print(res.summary())

Scenario machinery is imported lazily so that ``import repro`` stays
dependency-free (the serving layer pulls in JAX only when a scenario
actually needs it).
"""
__version__ = "1.0.0"

_SCENARIO_EXPORTS = (
    "run", "Scenario", "ScenarioResult", "WorkloadSpec", "FleetSpec",
    "PolicySpec", "ServingSpec", "ResilienceSpec",
    "SCHEMA_VERSION", "SUMMARY_KEYS_V1",
)

# Batched Monte-Carlo front door (imports JAX only when touched).
_MC_EXPORTS = ("MonteCarlo", "MonteCarloResult")

# Cost-model substrate (DESIGN.md Sec. 18): pricing + learned models.
_COSTMODEL_EXPORTS = ("PricingSpec", "CostModel", "StaticCostModel",
                      "LearnedCostModel", "make_cost_model")

__all__ = ["__version__", *_SCENARIO_EXPORTS, *_MC_EXPORTS,
           *_COSTMODEL_EXPORTS]


def __getattr__(name):
    if name in _SCENARIO_EXPORTS:
        from . import scenario
        return getattr(scenario, name)
    if name in _MC_EXPORTS:
        from . import mc
        return getattr(mc, name)
    if name in _COSTMODEL_EXPORTS:
        from . import costmodel
        return getattr(costmodel, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_SCENARIO_EXPORTS)
                  | set(_MC_EXPORTS) | set(_COSTMODEL_EXPORTS))
