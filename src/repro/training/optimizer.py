"""AdamW + cosine schedule + global-norm clipping (raw JAX).

Optimizer state mirrors the parameter tree (same sharding), so the
dry-run's memory analysis reflects a real training footprint:
params + grads + m + v in fp32.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig
from ..distributed.params import ParamSpec, is_spec


def opt_state_specs(param_specs) -> dict:
    """ParamSpec tree for (m, v) matching the parameter sharding."""
    def z(p: ParamSpec):
        return ParamSpec(p.shape, p.axes, init="zeros", dtype=p.dtype)
    zero = jax.tree.map(z, param_specs, is_leaf=is_spec)
    return {"m": zero, "v": jax.tree.map(z, param_specs, is_leaf=is_spec),
            "step": ParamSpec((), (), init="zeros", dtype="int32")}


def init_opt_state(params) -> dict:
    return {"m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32)}


def lr_at(step, tcfg: TrainConfig):
    warm = jnp.minimum(step / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - tcfg.warmup_steps) /
                    jnp.maximum(tcfg.total_steps - tcfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return tcfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads, opt, params, tcfg: TrainConfig):
    step = opt["step"] + 1
    lr = lr_at(step, tcfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tcfg.grad_clip / (gnorm + 1e-9))
    b1, b2, eps, wd = tcfg.beta1, tcfg.beta2, tcfg.eps, tcfg.weight_decay
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        new_p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}


def make_train_step(lm, tcfg: TrainConfig):
    """(params, opt, batch) -> (params, opt, metrics). Supports gradient
    accumulation over leading microbatch splits of the batch."""

    def loss_fn(params, batch):
        return lm.loss(params, batch["tokens"], batch["targets"],
                       z_loss=tcfg.z_loss, embeds=batch.get("embeds"))

    def single(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, opt, batch):
        if tcfg.microbatches > 1:
            mb = tcfg.microbatches

            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
            batch_mb = jax.tree.map(split, batch)

            def acc_body(carry, mbatch):
                loss_acc, g_acc = carry
                loss, g = single(params, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (loss_acc + loss, g_acc), None
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (0.0, g0), batch_mb)
            loss = loss / mb
            grads = jax.tree.map(lambda g: g / mb, grads)
        else:
            loss, grads = single(params, batch)
        params, opt, stats = adamw_update(grads, opt, params, tcfg)
        return params, opt, {"loss": loss, **stats}

    return train_step
