"""repro.training — optimizer, train step, data pipeline."""
from .optimizer import (adamw_update, init_opt_state, lr_at,
                        make_train_step, opt_state_specs)
from .data import SyntheticLM

__all__ = ["adamw_update", "init_opt_state", "lr_at", "make_train_step",
           "opt_state_specs", "SyntheticLM"]
