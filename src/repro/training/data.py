"""Deterministic, resumable synthetic token pipeline.

State = (seed, step); ``state_dict``/``load_state`` make it
checkpointable alongside the model, so restart resumes the exact batch
sequence (fault tolerance includes the data order).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class SyntheticLM:
    """Zipf-distributed token stream with local n-gram structure so the
    loss actually decreases (repeating motif + noise)."""
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    step: int = 0

    def next_batch(self) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, self.step]))
        self.step += 1
        # zipf base stream
        ranks = rng.zipf(1.3, size=(self.batch, self.seq_len + 1))
        toks = np.minimum(ranks, self.vocab - 1).astype(np.int32)
        # inject learnable motif: every 8th position repeats position 0
        toks[:, ::8] = toks[:, :1]
        return {
            "tokens": jnp.asarray(toks[:, :-1]),
            "targets": jnp.asarray(toks[:, 1:]),
        }

    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def load_state(self, state: dict) -> None:
        self.seed = int(state["seed"])
        self.step = int(state["step"])
