"""Online scalar estimators: the learning half of the cost model.

Two deterministic, allocation-free estimators:

* :class:`ScalarRLS` — through-origin recursive least squares with
  forgetting, extracted VERBATIM (same state variables, same update
  order, same guard expressions) from ``cluster.dispatch``'s
  ``cost_aware`` policy so the refactor is bit-identical. The
  configured coefficient is a *prior* worth ``prior_weight``
  squared-x units of evidence: an unobserved estimator reports exactly
  the prior, and the estimate moves only as real evidence accumulates.
* :class:`EwmaRate` — exponentially weighted per-key rates, the online
  forecaster behind predictive pre-warming (``costmodel.forecast``).

Both expose their state for the summary schema (coefficient, count,
mean absolute prediction error) — model drift is a reportable quantity,
not a hidden internal.
"""
from __future__ import annotations


class ScalarRLS:
    """y ≈ coeff·x through the origin, tracked with forgetting.

    ``observe(x, y)`` returns the signed prediction error *before* the
    update (the residual a monitoring dashboard wants), and accumulates
    its absolute value so ``mean_abs_err`` reports realized model error
    over the run.
    """

    def __init__(self, prior_coeff: float, prior_weight: float = 25.0,
                 lam: float = 0.98, learn: bool = True):
        self.prior_coeff = prior_coeff
        self.lam = lam
        self.learn = learn
        # Through-origin RLS state: coeff = _sxy / _sxx. The prior is
        # pseudo-evidence at the configured coefficient.
        self._sxx = prior_weight
        self._sxy = prior_weight * prior_coeff
        self.n_observed = 0
        self._abs_err = 0.0

    @property
    def coeff(self) -> float:
        """Current slope estimate (the prior until evidence arrives)."""
        if not self.learn or self._sxx <= 0.0:
            return self.prior_coeff
        return max(0.0, self._sxy / self._sxx)

    @property
    def mean_abs_err(self) -> float:
        """Mean |y - coeff·x| over the observations, each measured
        against the estimate in force when it arrived."""
        return self._abs_err / self.n_observed if self.n_observed else 0.0

    def observe(self, x: float, y: float) -> float:
        err = y - self.coeff * x
        self._abs_err += abs(err)
        lam = self.lam
        self._sxx = lam * self._sxx + x * x
        self._sxy = lam * self._sxy + x * y
        self.n_observed += 1
        return err

    def snapshot(self) -> dict:
        return {
            "coeff": self.coeff,
            "n_observed": self.n_observed,
            "prior_coeff": self.prior_coeff,
            "mean_abs_err": self.mean_abs_err,
            "learn": self.learn,
        }


class EwmaRate:
    """Per-key exponentially weighted rates over fixed-width buckets.

    ``update(key, count)`` folds one bucket's observed count in;
    ``forecast(key)`` is the smoothed per-bucket rate. A key never seen
    forecasts 0.0 — the estimator predicts nothing it has no evidence
    for, which is exactly how it differs from the oracle planner."""

    def __init__(self, alpha: float = 0.5):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self._rate: dict = {}
        self.n_updates = 0

    def update(self, key, count: float) -> float:
        prev = self._rate.get(key)
        rate = float(count) if prev is None \
            else self.alpha * count + (1.0 - self.alpha) * prev
        self._rate[key] = rate
        self.n_updates += 1
        return rate

    def forecast(self, key) -> float:
        return self._rate.get(key, 0.0)
