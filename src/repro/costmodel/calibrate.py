"""Compile-and-replay calibration: time the ops, fit the predictor.

The pipeline is three pure stages, each deterministic and seedable:

1. **features** — FLOP/byte rows from the compiled Pallas kernels
   (``costmodel.features.kernel_features``) plus the analytic llm chunk
   rows. ``mode="synthetic"`` substitutes a frozen representative row
   table so calibration (and every test built on it) runs without jax
   or a warm compiler cache.
2. **measure** — replay each compiled executable and take the median of
   ``repeats`` wall-clock timings (``mode="measure"``), or evaluate a
   hidden deterministic roofline with seeded multiplicative noise
   (``mode="synthetic"`` — ground truth the fit must recover, which
   gives the MAPE acceptance bound something objective to check).
3. **fit** — ridge regression of latency on ``(1, gflops, mbytes)`` via
   the 3x3 normal equations, solved in plain float64 with partial
   pivoting. Rows are weighted by ``1/measured²`` (the solve minimizes
   relative error — the MAPE the acceptance bound certifies), and
   feature weights are clipped at zero after the solve, so a fitted
   predictor is NON-NEGATIVE and MONOTONE non-decreasing in both FLOPs
   and bytes by construction (the hypothesis property in
   ``tests/test_costmodel.py`` pins this).

The result is a versioned JSON artifact (``results/costmodel/``) that
:class:`~repro.costmodel.model.LearnedCostModel` loads; round-tripping
the artifact reproduces predictions bit-for-bit (json round-trips
Python floats losslessly).
"""
from __future__ import annotations

import json
import math
import random
from pathlib import Path
from typing import Optional, Sequence

from .features import GFLOP, MBYTE, feature_vector, llm_chunk_features

ARTIFACT_VERSION = 1
ARTIFACT_KIND = "costmodel-calibration"
DEFAULT_ARTIFACT_DIR = Path("results") / "costmodel"

# Representative per-op rows (small-shape magnitudes) for the synthetic
# mode: calibration must be runnable — and exactly reproducible — on a
# box with no jax and no compiler cache. Magnitudes match the compiled
# small-shape kernel cases to well within the fit's tolerance.
SYNTHETIC_ROWS = (
    {"op": "flash_attention", "flops": 8.6e6, "bytes": 5.2e5, "trips": 1},
    {"op": "decode_attention", "flops": 1.4e5, "bytes": 2.7e5, "trips": 1},
    {"op": "ssm_scan", "flops": 2.1e6, "bytes": 6.8e5, "trips": 2},
    {"op": "rwkv6_scan", "flops": 1.7e7, "bytes": 1.1e6, "trips": 4},
    {"op": "fused_rmsnorm", "flops": 4.0e5, "bytes": 5.3e5, "trips": 1},
    # Two larger synthetic points anchor the slope well away from the
    # intercept (a one-cluster design would fit noise).
    {"op": "synthetic_large_compute", "flops": 2.0e9, "bytes": 8.0e6,
     "trips": 8},
    {"op": "synthetic_large_memory", "flops": 5.0e7, "bytes": 6.4e7,
     "trips": 8},
)

# The hidden roofline the synthetic measurements come from: a fixed
# dispatch overhead plus compute at 50 GFLOP/s plus memory at 8 GB/s
# (interpret-mode-ish CPU numbers). The fit must recover this to within
# the seeded noise — that is what the MAPE bound certifies.
_SYNTH_T0_MS = 0.08
_SYNTH_MS_PER_GFLOP = 20.0
_SYNTH_MS_PER_MBYTE = 0.125


def synthetic_measure(rows: Sequence[dict], seed: int = 0,
                      noise: float = 0.03) -> list[dict]:
    """Deterministic stand-in measurements: hidden roofline times with
    seeded multiplicative noise. Returns new rows with ``measured_ms``."""
    rng = random.Random(seed)
    out = []
    for row in rows:
        base = (_SYNTH_T0_MS
                + row["flops"] / GFLOP * _SYNTH_MS_PER_GFLOP
                + row["bytes"] / MBYTE * _SYNTH_MS_PER_MBYTE)
        jitter = 1.0 + noise * (2.0 * rng.random() - 1.0)
        out.append(dict(row, measured_ms=base * jitter))
    return out


def measure_kernels(repeats: int = 5, small: bool = True) -> list[dict]:
    """Compile-and-replay: feature rows with median wall-clock
    ``measured_ms`` per compiled kernel. Requires jax."""
    import time

    import jax

    from ..launch.hlo_analysis import analyze
    from .features import _kernel_cases, compile_kernel

    rows = []
    for name, builder in _kernel_cases(small):
        compiled, args = compile_kernel(name, builder)
        a = analyze(compiled.as_text())
        jax.block_until_ready(compiled(*args))  # warm the executable
        times = []
        for _ in range(max(1, repeats)):
            t0 = time.perf_counter()
            jax.block_until_ready(compiled(*args))
            times.append((time.perf_counter() - t0) * 1e3)
        times.sort()
        rows.append({
            "op": name,
            "flops": float(a["flops"]),
            "bytes": float(a["bytes"]),
            "trips": int(a.get("n_computations", 1)) or 1,
            "measured_ms": times[len(times) // 2],
        })
    return rows


# -- the fit ----------------------------------------------------------------

def _solve3(A, b):
    """3x3 linear solve, partial pivoting, plain floats."""
    n = 3
    M = [list(A[i]) + [b[i]] for i in range(n)]
    for col in range(n):
        piv = max(range(col, n), key=lambda r: abs(M[r][col]))
        if abs(M[piv][col]) < 1e-300:
            raise ValueError("singular normal equations — need more "
                             "distinct calibration rows")
        M[col], M[piv] = M[piv], M[col]
        for r in range(col + 1, n):
            f = M[r][col] / M[col][col]
            for c in range(col, n + 1):
                M[r][c] -= f * M[col][c]
    x = [0.0] * n
    for r in range(n - 1, -1, -1):
        x[r] = (M[r][n] - sum(M[r][c] * x[c] for c in range(r + 1, n))) \
            / M[r][r]
    return x


def fit_ridge(rows: Sequence[dict], l2: float = 1e-6) -> list[float]:
    """Ridge fit of ``measured_ms`` on ``(1, gflops, mbytes)``.

    Rows are weighted ``1/measured_ms²``, so the solve minimizes
    RELATIVE squared error — the quantity the MAPE acceptance bound
    certifies. Unweighted least squares lets the slowest op dominate
    and leaves sub-millisecond kernels misfit by multiples. Every
    weight is clipped at zero post-solve, making predictions
    non-negative and monotone non-decreasing in FLOPs and bytes."""
    if len(rows) < 3:
        raise ValueError("need >= 3 calibration rows for a 3-weight fit")
    A = [[0.0] * 3 for _ in range(3)]
    b = [0.0] * 3
    for row in rows:
        x = feature_vector(row)
        y = max(float(row["measured_ms"]), 1e-9)
        w = 1.0 / (y * y)
        for i in range(3):
            b[i] += w * x[i] * y
            for j in range(3):
                A[i][j] += w * x[i] * x[j]
    for i in range(3):
        A[i][i] += l2
    return [max(0.0, w) for w in _solve3(A, b)]


def predict_ms(weights: Sequence[float], row: dict) -> float:
    x = feature_vector(row)
    return weights[0] * x[0] + weights[1] * x[1] + weights[2] * x[2]


def mape(rows: Sequence[dict], weights: Sequence[float]) -> float:
    """Mean absolute percentage error of the fit over its own rows."""
    errs = [abs(predict_ms(weights, r) - r["measured_ms"])
            / r["measured_ms"] for r in rows if r["measured_ms"] > 0.0]
    return math.fsum(errs) / len(errs) if errs else 0.0


# -- the artifact -----------------------------------------------------------

def calibrate(mode: str = "synthetic", seed: int = 0, repeats: int = 5,
              small: bool = True, model: str = "deepseek-7b",
              seq_len: int = 4096, l2: float = 1e-6) -> dict:
    """Run the full pipeline; returns the artifact dict.

    ``mode="measure"`` compiles and times the real kernels (jax);
    ``mode="synthetic"`` uses the frozen row table and the hidden
    roofline — fully deterministic per ``seed``, no jax needed.
    """
    if mode == "measure":
        rows = measure_kernels(repeats=repeats, small=small)
    elif mode == "synthetic":
        rows = synthetic_measure(SYNTHETIC_ROWS, seed=seed)
    else:
        raise KeyError(f"unknown calibration mode {mode!r}")
    weights = fit_ridge(rows, l2=l2)
    for row in rows:
        row["predicted_ms"] = predict_ms(weights, row)

    # Token costs for the llm consumer. The raw fit is in *calibration
    # host* units (interpret-mode CPU throughput); the sim prices
    # against the ModelConfig's spec'd accelerator. So the reference
    # model's token costs are ANCHORED to its spec constants, the raw
    # predictions ride along, and LearnedCostModel transfers costs to
    # other models by the predictor's relative ratios — calibration
    # learns the shape of the cost surface, the anchor pins its scale.
    from ..configs.registry import get_config
    cfg = get_config(model)
    prefill_tokens = 1024
    llm_rows = llm_chunk_features(cfg, seq_len=seq_len,
                                  prefill_tokens=prefill_tokens)
    pre, dec = llm_rows[0], llm_rows[1]
    pred_ms_per_ktoken_prefill = predict_ms(weights, pre) \
        / (prefill_tokens / 1000.0)
    pred_ms_per_token_decode = predict_ms(weights, dec)

    # The queueing prior for cost_aware / admission: under fair-share
    # scheduling one unit of load inflates a chunk by roughly one
    # chunk service time, so the prior is the anchored billed span of a
    # representative chunk (mean of the prefill task and one default
    # 256-token decode slice — serving.llm.LLMSpec.decode_chunk_tokens).
    decode_chunk_tokens = 256
    prefill_chunk_ms = cfg.ms_per_ktoken_prefill * prefill_tokens / 1000.0
    decode_chunk_ms = cfg.ms_per_token_decode * decode_chunk_tokens
    queue_ms_per_load = (prefill_chunk_ms + decode_chunk_ms) / 2.0

    return {
        "version": ARTIFACT_VERSION,
        "kind": ARTIFACT_KIND,
        "mode": mode,
        "seed": seed,
        "features": ["const", "gflops", "mbytes"],
        "weights": list(weights),
        "rows": rows,
        "mape": mape(rows, weights),
        "queue_ms_per_load": queue_ms_per_load,
        "token_costs": {
            "model": cfg.name,
            "seq_len": seq_len,
            "prefill_tokens": prefill_tokens,
            "ms_per_ktoken_prefill": float(cfg.ms_per_ktoken_prefill),
            "ms_per_token_decode": float(cfg.ms_per_token_decode),
            "pred_ms_per_ktoken_prefill": pred_ms_per_ktoken_prefill,
            "pred_ms_per_token_decode": pred_ms_per_token_decode,
        },
        "rls": {"lambda": 0.98, "prior_weight": 25.0},
    }


def default_artifact_path(root: Optional[Path] = None) -> Path:
    root = Path(root) if root is not None else DEFAULT_ARTIFACT_DIR
    return root / f"calibration_v{ARTIFACT_VERSION}.json"


def save_artifact(artifact: dict, path: Optional[Path] = None) -> Path:
    path = Path(path) if path is not None else default_artifact_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(artifact, indent=2, sort_keys=True))
    return path


def load_artifact(path) -> dict:
    artifact = json.loads(Path(path).read_text())
    if artifact.get("kind") != ARTIFACT_KIND:
        raise ValueError(f"{path}: not a {ARTIFACT_KIND} artifact")
    if artifact.get("version") != ARTIFACT_VERSION:
        raise ValueError(
            f"{path}: artifact version {artifact.get('version')!r} != "
            f"supported {ARTIFACT_VERSION}")
    return artifact
