"""Online per-function rate forecasting for predictive pre-warming.

The oracle planner (``cluster.prewarm.build_plan``) reads the trace's
OWN per-minute counts — it knows minute *m*'s burst before it happens.
A real provider forecasts: :func:`build_forecast_plan` walks the
minutes in order and provisions minute *m* from an EWMA over the counts
of minutes strictly before it (``costmodel.online.EwmaRate``). The
first minute a function ever fires is therefore always a cold burst —
exactly the regret a forecaster pays and an oracle hides — and the plan
remains fully deterministic: the forecast is plain arithmetic over the
observed history, with no RNG anywhere.

Row shape, clamping, lead time and sorting are IDENTICAL to the oracle
planner, so the two plans differ only in where the expected
concurrency number comes from.
"""
from __future__ import annotations

import math
from collections import defaultdict
from typing import Optional

from .online import EwmaRate

MINUTE_MS = 60_000.0


def build_forecast_plan(tasks, config=None, alpha: float = 0.5,
                        ) -> list:
    """Fold a workload into provisioning rows ``(t, func_id, mem_mb,
    n)`` using only PAST per-minute counts per function.

    Minute 0 has no history, so nothing is provisioned for it; each
    observed minute updates the function's EWMA, and every minute after
    a function's first observation gets a row when the smoothed rate
    clears ``min_per_min`` (same threshold and clamps as the oracle).
    """
    from ..cluster.prewarm import make_prewarm_config, per_minute_counts

    cfg = make_prewarm_config(config)
    svc_sum: dict[int, float] = defaultdict(float)
    svc_n: dict[int, int] = defaultdict(int)
    mem: dict[int, int] = {}
    for t in tasks:
        svc_sum[t.func_id] += t.service
        svc_n[t.func_id] += 1
        mem[t.func_id] = t.mem_mb
    counts = per_minute_counts(tasks)
    if not counts:
        return []
    last_minute = max(m for minutes in counts.values() for m in minutes)
    rows = []
    est: dict[int, EwmaRate] = {}
    for fid in sorted(counts):
        mean_svc = svc_sum[fid] / svc_n[fid]
        fc = est.setdefault(fid, EwmaRate(alpha))
        minutes = counts[fid]
        seen = False
        for minute in range(0, last_minute + 1):
            if seen:
                pred = fc.forecast(fid)
                if pred >= cfg.min_per_min:
                    conc = pred * mean_svc / MINUTE_MS * cfg.headroom
                    n = max(1, min(cfg.max_per_func, math.ceil(conc)))
                    t_prov = max(0.0, minute * MINUTE_MS - cfg.lead_ms)
                    rows.append((t_prov, fid, mem[fid], n))
            observed = minutes.get(minute, 0)
            if observed or seen:
                # A gap minute counts as zero once the function has
                # history — silence is evidence the rate fell.
                fc.update(fid, observed)
                seen = seen or bool(observed)
    rows.sort()
    return rows


def make_plan(tasks, config=None) -> Optional[list]:
    """Dispatch on ``PrewarmConfig.forecast``: ``"oracle"`` is the
    historical trace-reading planner (bit-identical default),
    ``"ewma"`` the online forecaster."""
    from ..cluster.prewarm import build_plan, make_prewarm_config

    cfg = make_prewarm_config(config)
    mode = getattr(cfg, "forecast", "oracle")
    if mode == "oracle":
        return build_plan(tasks, cfg)
    if mode == "ewma":
        return build_forecast_plan(tasks, cfg,
                                   alpha=getattr(cfg, "ewma_alpha", 0.5))
    raise KeyError(f"unknown prewarm forecast mode {mode!r}")
