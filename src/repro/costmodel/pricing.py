"""One frozen ``PricingSpec`` for every dollar in the repo.

Historically the pricing knobs were scattered: the AWS per-GB-second and
per-request rates were module constants in ``core.cost``, the warm-pool
hold rate was a derived constant next to them, and the heterogeneous-SKU
duration multipliers / spot discount lived in ``cluster.topology``'s
palette. A sweep that wanted to ask "what if requests were free?" had to
monkeypatch a module. :class:`PricingSpec` consolidates all of them into
one frozen, picklable value object accepted by ``Scenario(pricing=...)``
and carried by every ``CostModel`` — the cost helpers in ``core.cost``
take it as an optional argument and the legacy constants survive as
DeprecationWarning shims reading from :data:`DEFAULT_PRICING`.

Bit-identity contract: :data:`DEFAULT_PRICING`'s fields are *exactly*
the historical constants, and every derived quantity is computed by the
same float expression the constants produced, so a default-pricing run
rolls up bit-identically to the pre-``PricingSpec`` code.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Union


@dataclass(frozen=True)
class PricingSpec:
    """Every pricing knob in one place (picklable; sweep-cell safe).

    ``price_per_gb_second`` / ``price_per_request`` are the AWS Lambda
    x86 rates (2024). ``warm_hold_divisor`` sets the provider-side idle
    warm-memory rate as a fraction of the user-facing rate (idle DRAM is
    far cheaper than billed compute; 1/8 tracks provider COGS
    estimates). ``sku_price_mults`` / ``spot_discount`` are the
    heterogeneous-fleet duration multipliers the topology palette uses.
    """

    name: str = "default"
    price_per_gb_second: float = 1.66667e-5   # USD
    price_per_request: float = 2.0e-7         # USD ($0.20 / 1M requests)
    warm_hold_divisor: float = 8.0
    # Duration-bill multipliers per machine class (cluster.topology
    # palette): name -> multiplier on the per-ms rate.
    sku_price_mults: tuple = (("std", 1.0), ("turbo", 1.3),
                              ("value", 0.7), ("spot", 1.0))
    spot_discount: float = 0.6                # fraction off on spot SKUs

    def __post_init__(self):
        if self.price_per_gb_second < 0.0 or self.price_per_request < 0.0:
            raise ValueError("prices must be non-negative")
        if not self.warm_hold_divisor > 0.0:
            raise ValueError("warm_hold_divisor must be positive")
        if not 0.0 <= self.spot_discount < 1.0:
            raise ValueError("spot_discount must be in [0, 1)")

    # -- derived rates (same expressions as the legacy constants) ----------
    @property
    def warm_hold_per_gb_second(self) -> float:
        """Provider-side $/GB-second of idle warm sandbox memory."""
        return self.price_per_gb_second / self.warm_hold_divisor

    def price_per_ms(self, mem_mb: float) -> float:
        """Billed $/ms for one invocation of the given memory size."""
        return (mem_mb / 1024.0) * self.price_per_gb_second / 1000.0

    def sku_mult(self, sku_name: str) -> float:
        for name, mult in self.sku_price_mults:
            if name == sku_name:
                return mult
        return 1.0

    def with_(self, **kw) -> "PricingSpec":
        return replace(self, **kw)


#: The historical constants, as one spec. Callers that pass no pricing
#: get exactly this — and exactly the pre-PricingSpec arithmetic.
DEFAULT_PRICING = PricingSpec()

#: Named presets for the sweep/CLI ``--pricing`` axis. Additions are
#: cheap; renames are schema changes (rows key on the name).
PRICINGS = {
    "default": DEFAULT_PRICING,
    # Duration rate doubled: what the scheduler choice is worth when
    # compute is expensive relative to the request fee.
    "premium": PricingSpec(name="premium",
                           price_per_gb_second=2 * 1.66667e-5),
    # Request fee waived: pure duration billing — shedding becomes
    # literally free for the operator, which the roll-ups must show.
    "free_requests": PricingSpec(name="free_requests",
                                 price_per_request=0.0),
}


def make_pricing(pricing: Union[None, str, dict, PricingSpec],
                 ) -> PricingSpec:
    """Coerce ``None`` | preset name | kwargs dict | ``PricingSpec`` —
    the same accept-anything contract the container/admission specs
    give every other Scenario argument."""
    if pricing is None:
        return DEFAULT_PRICING
    if isinstance(pricing, PricingSpec):
        return pricing
    if isinstance(pricing, str):
        if pricing not in PRICINGS:
            raise KeyError(f"unknown pricing preset {pricing!r}; "
                           f"have {sorted(PRICINGS)}")
        return PRICINGS[pricing]
    if isinstance(pricing, dict):
        return PricingSpec(**pricing)
    raise TypeError(f"cannot build PricingSpec from {type(pricing)!r}")


def resolve_pricing(pricing: Union[None, str, dict, PricingSpec],
                    ) -> Optional[PricingSpec]:
    """Like :func:`make_pricing` but maps ``None`` to ``None`` — for
    call sites that must distinguish "caller said nothing" (keep the
    legacy constant path, bit-identically) from "caller asked for the
    default spec"."""
    return None if pricing is None else make_pricing(pricing)
