"""Per-op feature extraction for the learned cost model.

Two sources, one row schema:

* :func:`kernel_features` — the REAL Pallas kernels (``kernels.ops``):
  each op is lowered and compiled via the standard jax path
  (``jax.jit(...).lower(...).compile()``, the ``launch.dryrun`` idiom)
  and its optimized HLO text is folded through
  ``launch.hlo_analysis.analyze`` into FLOP / byte / trip-count
  features. This is the compile side of compile-and-replay: the same
  compiled executable the calibrator later times.
* :func:`llm_chunk_features` — the ``serving.llm`` chunk shapes,
  derived analytically (2·params FLOPs per token, weights + KV bytes
  per step) so the llm consumer works without jax in the process.

Row schema (``FEATURE_KEYS``): ``op`` (label), ``flops``, ``bytes``,
``trips`` (kernel grid / while-loop trip count where known, else 1),
``tokens`` (llm rows), plus pass-through shape metadata. The calibrator
fits latency on (1, gflops, mbytes) — scaled so the normal equations
stay well-conditioned in float64.
"""
from __future__ import annotations

from typing import Optional

FEATURE_KEYS = ("op", "flops", "bytes", "trips")

# Feature scaling used everywhere a predictor touches a row: raw FLOP /
# byte counts are ~1e9 / ~1e6 and would wreck the normal equations.
GFLOP = 1e9
MBYTE = 1e6


def feature_vector(row: dict) -> tuple:
    """(1.0, gflops, mbytes) — THE predictor input for a feature row."""
    return (1.0, float(row["flops"]) / GFLOP, float(row["bytes"]) / MBYTE)


# -- analytic llm chunk features (jax-free) ---------------------------------

def llm_chunk_features(cfg, seq_len: int = 4096,
                       prefill_tokens: int = 1024) -> list[dict]:
    """Feature rows for the two ``serving.llm`` chunk kinds.

    Dense-equivalent FLOPs: 2·params per token (the standard inference
    estimate); bytes: one full weight read plus the KV the step
    touches. MoE checkpoints ship every expert but activate
    ``n_active``/``n_experts`` of the MLP share — the analytic model
    follows the same approximation ``approx_param_bytes`` uses.
    """
    from ..serving.llm import BYTES_PER_PARAM, approx_param_bytes
    from ..serving.request import kv_bytes

    param_bytes = approx_param_bytes(cfg)
    params = param_bytes / BYTES_PER_PARAM
    n_exp = max(getattr(cfg, "n_experts", 0), 1)
    top_k = max(getattr(cfg, "top_k", 0), 1) if n_exp > 1 else 1
    active = params * (top_k / n_exp) if n_exp > 1 else params
    rows = [
        {
            "op": "llm_prefill",
            "tokens": prefill_tokens,
            "flops": 2.0 * active * prefill_tokens,
            "bytes": param_bytes + kv_bytes(cfg, prefill_tokens),
            "trips": max(1, prefill_tokens // 512),
        },
        {
            "op": "llm_decode",
            "tokens": 1,
            "flops": 2.0 * active,
            "bytes": param_bytes + kv_bytes(cfg, seq_len),
            "trips": 1,
        },
    ]
    return rows


# -- compiled kernel features (jax-gated) -----------------------------------

def _kernel_cases(small: bool = True) -> list[tuple]:
    """(name, builder) pairs; builder() -> (fn, args) ready to lower.
    Shapes are deliberately small: CPU interpret-mode Pallas is slow,
    and the predictor extrapolates on FLOPs/bytes, not on shape."""
    import jax.numpy as jnp
    import numpy as np

    from ..kernels import ops

    BH, S, hd, ds = (2, 128, 64, 16) if small else (4, 512, 64, 16)

    def _r(shape, seed):
        rng = np.random.default_rng(seed)
        return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))

    def flash():
        q, k, v = _r((BH, S, hd), 0), _r((BH, S, hd), 1), _r((BH, S, hd), 2)
        return ops.flash_attention, (q, k, v)

    def decode():
        q = _r((BH, 1, hd), 3)
        k, v = _r((BH, S, hd), 4), _r((BH, S, hd), 5)
        lengths = jnp.full((BH,), S, jnp.int32)
        return ops.decode_attention, (q, k, v, lengths)

    def ssm():
        xbar = _r((BH, S, hd), 6)
        B, C = _r((BH, S, ds), 7), _r((BH, S, ds), 8)
        cumlog = jnp.cumsum(-jnp.abs(_r((BH, S), 9)) * 0.01, axis=-1)
        return ops.ssm_scan, (xbar, B, C, cumlog)

    def rwkv():
        r, k, v = _r((BH, S, hd), 10), _r((BH, S, hd), 11), _r((BH, S, hd), 12)
        w = -jnp.abs(_r((BH, S, hd), 13)) * 0.1
        u = _r((BH, hd), 14)
        return ops.rwkv6_scan, (r, k, v, w, u)

    def rmsnorm():
        x, w = _r((S * BH, 4 * hd), 15), _r((4 * hd,), 16)
        return ops.fused_rmsnorm, (x, w)

    return [("flash_attention", flash), ("decode_attention", decode),
            ("ssm_scan", ssm), ("rwkv6_scan", rwkv),
            ("fused_rmsnorm", rmsnorm)]


def compile_kernel(name: str, builder):
    """Lower + compile one kernel case; returns ``(compiled, args)``.
    The compiled executable serves both sides of compile-and-replay:
    ``analyze(compiled.as_text())`` for features, timed invocation for
    the calibrator's measurements."""
    fn, args = builder()
    return fn.lower(*args).compile(), args


def kernel_features(small: bool = True, ops_filter: Optional[list] = None,
                    ) -> list[dict]:
    """FLOP/byte/trip rows for the compiled Pallas kernels.

    Requires jax; raises ImportError where it is absent (callers gate —
    the synthetic calibration path needs no compiler at all).
    """
    from ..launch.hlo_analysis import analyze

    rows = []
    for name, builder in _kernel_cases(small):
        if ops_filter is not None and name not in ops_filter:
            continue
        compiled, args = compile_kernel(name, builder)
        a = analyze(compiled.as_text())
        rows.append({
            "op": name,
            "flops": float(a["flops"]),
            "bytes": float(a["bytes"]),
            "trips": int(a.get("n_computations", 1)) or 1,
        })
    return rows
