"""The ``CostModel`` protocol and its two implementations.

One substrate, four consumers (DESIGN.md Sec. 18):

1. **llm chunk pricing** — ``token_costs()`` returns calibrated
   (ms_per_ktoken_prefill, ms_per_token_decode) for the workload's
   model, or None to keep the spec constants;
2. **cost_aware dispatch** — ``queue_ms_per_load()`` seeds the
   dispatcher's RLS prior with the calibrated inflation coefficient
   (the online loop stays the dispatcher's, as the online half of the
   model);
3. **GCRA admission** — ``derive_max_load(budget_ms)`` turns the
   predicted load->inflation curve into the fleet load ceiling
   (``AdmissionConfig(max_load="auto")``);
4. **predictive pre-warm** — ``prewarm_forecast()`` names the planner
   (``"oracle"`` | ``"ewma"``) a config-shaped prewarm spec should use
   when it does not choose one itself.

:class:`StaticCostModel` is today's constants: every hook returns the
do-nothing answer, so ``cost_model="static"`` (or None) is bit-identical
to the pre-CostModel code by construction. :class:`LearnedCostModel`
answers from a calibration artifact (``costmodel.calibrate``) and keeps
a :class:`~repro.costmodel.online.ScalarRLS` for completion feedback.
Both carry the run's :class:`~repro.costmodel.pricing.PricingSpec`.
"""
from __future__ import annotations

from typing import Optional, Union

from .calibrate import load_artifact, predict_ms
from .online import ScalarRLS
from .pricing import DEFAULT_PRICING, PricingSpec, make_pricing

#: Fallback queueing prior — the cost_aware dispatcher's historical
#: default coefficient (ms of billed inflation per unit node load).
STATIC_QUEUE_MS_PER_LOAD = 1_000.0


class CostModel:
    """Protocol base. Subclasses override the hooks they calibrate."""

    kind = "base"

    def __init__(self, pricing: Optional[PricingSpec] = None):
        self.pricing = pricing if pricing is not None else DEFAULT_PRICING

    # -- consumer 1: llm chunk pricing ---------------------------------
    def token_costs(self, cfg, seq_len: int) -> Optional[tuple]:
        """(ms_per_ktoken_prefill, ms_per_token_decode) or None to keep
        the ModelConfig constants."""
        return None

    # -- consumer 2: cost_aware dispatch -------------------------------
    def queue_ms_per_load(self) -> float:
        """The load->billed-ms prior the dispatcher's RLS starts from."""
        return STATIC_QUEUE_MS_PER_LOAD

    # -- consumer 3: admission ceiling ---------------------------------
    def derive_max_load(self, budget_ms: float) -> float:
        """Load ceiling implied by the inflation curve: the load at
        which predicted queueing inflation exhausts ``budget_ms``."""
        coeff = self.queue_ms_per_load()
        if coeff <= 0.0:
            return float("inf")
        return max(1.0, budget_ms / coeff)

    # -- consumer 4: predictive pre-warm -------------------------------
    def prewarm_forecast(self) -> str:
        return "oracle"

    # -- per-op predictions (benchmarks / diagnostics) -----------------
    def predict_op_ms(self, row: dict) -> Optional[float]:
        return None

    def describe(self) -> dict:
        return {"kind": self.kind, "pricing": self.pricing.name}


class StaticCostModel(CostModel):
    """Today's constants. Every hook is the identity/do-nothing answer;
    a run with this model is bit-identical to one with no model."""

    kind = "static"


class LearnedCostModel(CostModel):
    """Predictions from a calibration artifact + online RLS updates.

    ``artifact`` is a loaded dict or a path; ``observe(load,
    inflation_ms)`` folds completion feedback into the online half (the
    cost_aware dispatcher shares this estimator when the scenario wires
    it in, so routing and the reported coefficient stay one value).
    """

    kind = "learned"

    def __init__(self, artifact: Union[dict, str, "object"],
                 pricing: Optional[PricingSpec] = None):
        super().__init__(pricing)
        if not isinstance(artifact, dict):
            artifact = load_artifact(artifact)
        self.artifact = artifact
        self.weights = [float(w) for w in artifact["weights"]]
        rls_cfg = artifact.get("rls", {})
        self.rls = ScalarRLS(
            prior_coeff=float(artifact["queue_ms_per_load"]),
            prior_weight=float(rls_cfg.get("prior_weight", 25.0)),
            lam=float(rls_cfg.get("lambda", 0.98)))

    # -- consumer hooks -------------------------------------------------
    def token_costs(self, cfg, seq_len: int) -> Optional[tuple]:
        tc = self.artifact.get("token_costs")
        if tc is None:
            return None
        if tc.get("model") == getattr(cfg, "name", None) \
                and tc.get("seq_len") == seq_len:
            # Calibrated for exactly this model/seq_len: the anchored
            # values (the reference spec constants) apply as-is.
            return (float(tc["ms_per_ktoken_prefill"]),
                    float(tc["ms_per_token_decode"]))
        # Different model or seq_len: transfer by the predictor's
        # RELATIVE cost ratio against the calibration reference. The
        # raw fit is in calibration-host units; the anchor pins the
        # accelerator scale, the ratio carries the model shape.
        ref_pre = float(tc.get("pred_ms_per_ktoken_prefill", 0.0))
        ref_dec = float(tc.get("pred_ms_per_token_decode", 0.0))
        if ref_pre <= 0.0 or ref_dec <= 0.0:
            return None
        from .features import llm_chunk_features
        pre_tokens = int(tc.get("prefill_tokens", 1024))
        rows = llm_chunk_features(cfg, seq_len=seq_len,
                                  prefill_tokens=pre_tokens)
        pre = predict_ms(self.weights, rows[0]) / (pre_tokens / 1000.0)
        dec = predict_ms(self.weights, rows[1])
        return (float(tc["ms_per_ktoken_prefill"]) * pre / ref_pre,
                float(tc["ms_per_token_decode"]) * dec / ref_dec)

    def queue_ms_per_load(self) -> float:
        return self.rls.coeff

    def prewarm_forecast(self) -> str:
        return "ewma"

    def predict_op_ms(self, row: dict) -> float:
        return predict_ms(self.weights, row)

    # -- online half ----------------------------------------------------
    def observe(self, load: float, inflation_ms: float) -> float:
        return self.rls.observe(load, inflation_ms)

    def describe(self) -> dict:
        out = super().describe()
        out.update({
            "mape": self.artifact.get("mape"),
            "coeff": self.rls.coeff,
            "n_observed": self.rls.n_observed,
        })
        return out


def make_cost_model(model: Union[None, str, dict, CostModel],
                    pricing: Union[None, str, dict, PricingSpec] = None,
                    ) -> CostModel:
    """Coerce ``None`` | ``"static"`` | ``"learned"`` | artifact-dict |
    ``CostModel`` — the Scenario contract.

    ``"learned"`` loads the default artifact path
    (``results/costmodel/calibration_v1.json``), falling back to a
    fresh in-memory synthetic calibration when no artifact has been
    written yet — so ``cost_model="learned"`` always works, and always
    deterministically.
    """
    p = make_pricing(pricing)
    if isinstance(model, CostModel):
        if pricing is not None:
            model.pricing = p
        return model
    if model is None or model == "static":
        return StaticCostModel(p)
    if isinstance(model, dict):
        return LearnedCostModel(model, p)
    if model == "learned":
        from .calibrate import calibrate, default_artifact_path
        path = default_artifact_path()
        artifact = load_artifact(path) if path.exists() \
            else calibrate(mode="synthetic")
        return LearnedCostModel(artifact, p)
    if isinstance(model, str):
        # Any other string is an artifact path.
        return LearnedCostModel(load_artifact(model), p)
    raise TypeError(f"cannot build a CostModel from {type(model)!r}")
