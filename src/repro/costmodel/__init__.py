"""Profiled, learned cost models — one substrate behind every dollar.

* :mod:`~repro.costmodel.pricing`   — the frozen :class:`PricingSpec`
  every cost helper and consumer prices with;
* :mod:`~repro.costmodel.features`  — per-op FLOP/byte rows from the
  compiled Pallas kernels and the llm chunk shapes;
* :mod:`~repro.costmodel.calibrate` — compile-and-replay timing + ridge
  fit, persisted as a versioned JSON artifact;
* :mod:`~repro.costmodel.online`    — the online estimators (ScalarRLS,
  EWMA rates);
* :mod:`~repro.costmodel.forecast`  — the online pre-warm planner;
* :mod:`~repro.costmodel.model`     — the :class:`CostModel` protocol
  (:class:`StaticCostModel` / :class:`LearnedCostModel`) and its four
  consumers' hooks.

See DESIGN.md Sec. 18.
"""
from .calibrate import (calibrate, default_artifact_path, fit_ridge,
                        load_artifact, predict_ms, save_artifact)
from .model import (CostModel, LearnedCostModel, StaticCostModel,
                    make_cost_model)
from .online import EwmaRate, ScalarRLS
from .pricing import (DEFAULT_PRICING, PRICINGS, PricingSpec, make_pricing,
                      resolve_pricing)

__all__ = [
    "CostModel", "StaticCostModel", "LearnedCostModel", "make_cost_model",
    "PricingSpec", "DEFAULT_PRICING", "PRICINGS", "make_pricing",
    "resolve_pricing", "ScalarRLS", "EwmaRate",
    "calibrate", "fit_ridge", "predict_ms", "save_artifact",
    "load_artifact", "default_artifact_path",
]
