"""The unified entrypoint: ``repro.run(Scenario(...)) -> ScenarioResult``.

Historically the repo grew four overlapping front doors — single-node
``core.simulate.run_policy``, fleet-level ``cluster.sim.run_cluster``,
the serving gateway's ``run_gateway``/``run_gateway_fleet``, and the
grid runner's ``sweep.Cell`` — each with its own ad-hoc kwarg bundle
for the same underlying knobs (trace, containers, chaos, admission,
prewarm, policy). A :class:`Scenario` composes those knobs as four
orthogonal specs:

* :class:`WorkloadSpec`   — what arrives: an Azure-like synthetic trace,
  an explicit task list, or the **llm** workload (``serving.llm``) where
  model replicas are the functions, cold start = weight-load + compile,
  warm state = KV/weights residency, tasks = prefill/decode chunks;
* :class:`FleetSpec`      — where it runs: node count/size, front-end
  dispatcher, the sandbox layer (any ``ContainerSpec``-coercible shape),
  per-node policy overrides;
* :class:`PolicySpec`     — how each node schedules: policy name plus
  the paper's knobs (time-limit adaptation, rightsizing, FIFO split),
  and an optional :class:`ServingSpec` that switches nodes to the
  KV-penalty slot schedulers;
* :class:`ResilienceSpec` — chaos schedule, admission control,
  predictive pre-warming (DESIGN.md Sec. 14).

``run`` picks the execution engine from the specs: a lone node with no
dispatcher runs the single-node scheduler directly (bit-identical to
the historical ``run_policy``/``run_gateway``); anything else runs
through :class:`~repro.cluster.sim.ClusterSim`. The legacy entrypoints
survive as thin deprecation shims built on exactly this path, so their
roll-ups are reproduced bit-for-bit by construction.

``ScenarioResult.summary()`` is the versioned roll-up schema
(``SCHEMA_VERSION``/``SUMMARY_KEYS_V1``) shared by the benchmarks, the
CI regression gate, and the trend dashboard: every summary carries at
least the v1 keys, with zeros where a layer is off, and schema growth
is additive-only (enforced by ``tests/test_scenario.py``).
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field, replace
from typing import TYPE_CHECKING, Optional, Sequence, Union

from .cluster.admission import AdmissionConfig, AdmissionControl
from .cluster.chaos import ChaosSchedule
from .cluster.metrics import ClusterResult
from .cluster.prewarm import (PrewarmConfig, Provisioner,
                              make_prewarm_config)
from .cluster.retry import RetryPolicy
from .cluster.sim import ClusterSim
from .cluster.topology import TopologySpec
from .core.containers import (ContainerConfig, ContainerSpec,
                              as_container_config)
from .core.events import Task
from .core.metrics import SimResult
from .traces.azure import TraceSpec
from .traces.workload import generate_workload, scale_load

if TYPE_CHECKING:  # serving imports jax — resolved lazily at run time
    from .configs.base import ModelConfig
    from .serving.llm import LLMSpec

SCHEMA_VERSION = 1

# The frozen v1 core of ``ScenarioResult.summary()``: every summary —
# single-node or fleet, azure or llm — carries at least these keys.
# Growth is ADDITIVE-ONLY: removing or renaming any of these requires a
# SCHEMA_VERSION bump (and breaks tests/test_scenario.py loudly).
SUMMARY_KEYS_V1 = (
    "schema_version", "workload", "policy", "dispatcher",
    "n_nodes", "cores_per_node", "n", "failed", "n_requests",
    "p99_turnaround_s", "makespan_s",
    "cost_usd", "total_cost_usd", "usd_per_1k_requests",
    "cold_starts", "cold_start_rate", "init_cost_usd", "warm_hold_usd",
    "shed", "rejected_cost_usd", "requeued", "chaos_events",
    "queued", "spilled", "prewarmed",
    # -- v1 additive growth: failure-domain topology + retry layer
    # (DESIGN.md Sec. 17); stable zeros when those layers are off.
    "retries", "retry_wait_ms", "revoked", "degraded_ms",
    "cross_zone", "spot_savings_usd",
    # -- v1 additive growth: cost-model substrate (DESIGN.md Sec. 18).
    # Which engine produced the row and why a jax cell fell back
    # (promoted from the sweep's ad-hoc columns), which pricing/cost
    # model priced it, and the learned-coefficient state (cost_aware
    # RLS value, observation count, realized |prediction error|) so
    # the gate and trend dashboard can see model drift.
    "backend", "fallback_reason", "pricing", "cost_model",
    "cost_coeff", "cost_obs", "cost_pred_err_ms",
)


# -- the four orthogonal specs ------------------------------------------------

@dataclass(frozen=True)
class WorkloadSpec:
    """What arrives.

    ``kind``:

    * ``"azure"`` (alias ``"synthetic"``) — the calibrated Azure-like
      trace from ``traces`` (``trace`` is its :class:`TraceSpec`);
    * ``"tasks"`` — an explicit pre-built task list (``tasks``);
      ``fresh=False`` runs the caller's objects in place (the
      historical ``fresh_tasks=False`` contract);
    * ``"llm"`` — model replicas as functions (``llm`` is an
      :class:`~repro.serving.llm.LLMSpec`; ``trace`` drives arrivals).

    ``load_scale`` compresses inter-arrival times after generation
    (>1 = heavier load), exactly like ``traces.workload.scale_load``.
    """

    kind: str = "azure"
    trace: Optional[TraceSpec] = None
    load_scale: float = 1.0
    tasks: Optional[Sequence[Task]] = None
    fresh: bool = True
    llm: Optional["LLMSpec"] = None

    def build(self) -> tuple[list[Task], dict]:
        """Materialize ``(tasks, meta)``; deterministic per spec."""
        if self.kind == "llm":
            from .serving.llm import LLMSpec, llm_workload
            return llm_workload(self.llm or LLMSpec(), self.trace,
                                self.load_scale)
        if self.kind == "tasks":
            if self.tasks is None:
                raise ValueError("WorkloadSpec(kind='tasks') needs tasks=")
            tasks = list(self.tasks)
            if self.fresh:
                tasks = copy.deepcopy(tasks)
        elif self.kind in ("azure", "synthetic"):
            tasks = generate_workload(self.trace or TraceSpec()).tasks
        else:
            raise KeyError(f"unknown workload kind {self.kind!r}")
        if self.load_scale != 1.0:
            tasks = scale_load(tasks, self.load_scale)
        return tasks, {"n_requests": len(tasks)}


@dataclass(frozen=True)
class FleetSpec:
    """Where it runs.

    ``dispatcher=None`` with one node and no per-node overrides runs
    the scheduler directly (the historical single-node entrypoints);
    any dispatcher name (or instance) runs a :class:`ClusterSim` fleet.
    ``containers`` accepts every ``as_container_config`` shape —
    :class:`ContainerSpec`, raw :class:`ContainerConfig`, kwargs dict,
    or a policy-name string. ``nodes`` optionally overrides per-node
    policies (heterogeneous fleets); ``node_factory`` overrides
    scheduler construction outright (the shims' escape hatch).
    """

    n_nodes: int = 1
    cores_per_node: int = 50
    dispatcher: Union[None, str, object] = None
    containers: Union[None, ContainerSpec, ContainerConfig,
                      dict, str] = None
    seed: int = 0
    nodes: Optional[Sequence] = None
    node_factory: Optional[object] = None
    # Failure-domain topology (zones/racks/SKUs — DESIGN.md Sec. 17).
    # When set it IS the fleet shape: node count and placement come
    # from the topology, and ``n_nodes`` is ignored.
    topology: Optional[TopologySpec] = None

    @property
    def is_fleet(self) -> bool:
        return (self.dispatcher is not None or self.n_nodes > 1
                or self.nodes is not None or self.topology is not None)


@dataclass(frozen=True)
class ServingSpec:
    """Switch node schedulers to the serving slot variants: preemptions
    carry the model's KV-swap penalty, quanta scale to dominate it."""

    model: Union[str, "ModelConfig"] = "deepseek-7b"
    seq_len: int = 4096
    n_fifo_frac: float = 0.5        # hybrid: FIFO share of a node's slots
    straggler_factor: float = 0.0

    def resolve_model(self) -> "ModelConfig":
        from .configs.base import ModelConfig
        if isinstance(self.model, ModelConfig):
            return self.model
        from .configs.registry import get_config
        return get_config(self.model)


@dataclass(frozen=True)
class PolicySpec:
    """How each node schedules. ``adapt_pct``/``rightsize``/``n_fifo``
    apply to the hybrid policy; ``microvm``/``ghost_mode`` are the
    paper's single-node system models; ``kw`` passes any remaining
    scheduler kwargs through verbatim (the legacy ``**kw`` contract)."""

    name: str = "hybrid"
    adapt_pct: Optional[float] = None
    rightsize: bool = False
    n_fifo: Optional[int] = None
    microvm: bool = False
    ghost_mode: bool = False
    serving: Optional[ServingSpec] = None
    kw: dict = field(default_factory=dict)


@dataclass(frozen=True)
class ResilienceSpec:
    """Chaos / admission / pre-warm layers — all off by default, and
    bit-identical to the plain run when off (DESIGN.md Sec. 14)."""

    chaos: Optional[ChaosSchedule] = None
    admission: Union[None, dict, AdmissionConfig, AdmissionControl] = None
    prewarm: Union[None, dict, PrewarmConfig, Provisioner,
                   Sequence] = None
    # Retry layer for chaos-lost work: capped exponential backoff with
    # deterministic jitter, retry budget, per-function circuit breaker
    # (None keeps PR 5's instant-requeue semantics, bit-identically).
    retry: Union[None, dict, RetryPolicy] = None

    def materialize_prewarm(self, tasks) -> Union[None, Provisioner,
                                                  Sequence]:
        """Config-shaped prewarm builds a fresh plan from THIS run's
        workload (a ``Provisioner`` is single-use); plans/provisioners
        pass through for ``ClusterSim`` to consume."""
        pw = self.prewarm
        if isinstance(pw, (dict, PrewarmConfig)):
            return Provisioner.from_workload(tasks, make_prewarm_config(pw))
        return pw


@dataclass(frozen=True)
class Scenario:
    """One reproducible experiment: workload x fleet x policy x
    resilience — priced by ``pricing`` and costed by ``cost_model``.
    ``repro.run(scenario)`` executes it.

    ``pricing`` accepts ``None`` | preset name | kwargs dict |
    :class:`~repro.costmodel.pricing.PricingSpec`; ``None`` keeps the
    historical constants bit-identically. ``cost_model`` accepts
    ``None`` | ``"static"`` | ``"learned"`` | calibration-artifact dict
    or path | :class:`~repro.costmodel.model.CostModel`; ``None`` /
    ``"static"`` is the do-nothing default, ``"learned"`` threads the
    calibrated predictor into llm chunk pricing, cost_aware dispatch,
    the admission ceiling (``max_load="auto"``) and predictive pre-warm
    (DESIGN.md Sec. 18).
    """

    workload: WorkloadSpec = field(default_factory=WorkloadSpec)
    fleet: FleetSpec = field(default_factory=FleetSpec)
    policy: PolicySpec = field(default_factory=PolicySpec)
    resilience: ResilienceSpec = field(default_factory=ResilienceSpec)
    pricing: Union[None, str, dict, object] = None
    cost_model: Union[None, str, dict, object] = None


# -- cost-model plumbing ------------------------------------------------------

def _pricing_name(pricing) -> str:
    """The summary-schema label for a Scenario's pricing field."""
    if pricing is None:
        return "default"
    from .costmodel.pricing import make_pricing
    return make_pricing(pricing).name


def _cost_model_kind(cost_model) -> str:
    """The summary-schema label for a Scenario's cost_model field."""
    if cost_model is None or cost_model == "static":
        return "static"
    if isinstance(cost_model, (str, dict)):
        return "learned"
    return getattr(cost_model, "kind", "learned")


def _resolve_resilience(res: ResilienceSpec, cost_model) -> ResilienceSpec:
    """Resolve the cost-model-derived resilience knobs before the run:
    ``max_load="auto"`` becomes the model's predicted-inflation ceiling
    (consumer 3), and a learned model switches config-shaped pre-warm
    to its online forecaster unless the config chose one explicitly
    (consumer 4)."""
    adm = res.admission
    if isinstance(adm, dict) and adm.get("max_load") == "auto":
        budget = adm.get("max_queue_ms", AdmissionConfig.max_queue_ms)
        adm = dict(adm, max_load=cost_model.derive_max_load(budget))
        res = replace(res, admission=adm)
    elif isinstance(adm, AdmissionConfig) and adm.max_load == "auto":
        adm = replace(adm,
                      max_load=cost_model.derive_max_load(adm.max_queue_ms))
        res = replace(res, admission=adm)
    pw = res.prewarm
    if isinstance(pw, dict) and "forecast" not in pw \
            and cost_model.prewarm_forecast() != "oracle":
        res = replace(res, prewarm=dict(
            pw, forecast=cost_model.prewarm_forecast()))
    return res


# -- result + versioned summary schema ----------------------------------------

@dataclass
class ScenarioResult:
    """The scenario plus its raw engine result (``SimResult`` for a
    direct single-node run, ``ClusterResult`` for a fleet) and the
    workload metadata. ``summary()`` is the stable v1 schema."""

    scenario: Scenario
    raw: Union[SimResult, ClusterResult]
    meta: dict = field(default_factory=dict)
    # Batched-engine accounting (repro.mc): kernel while-loop trips and
    # scheduling events retired for this cell. Diagnostics only — NEVER
    # part of the v1 summary schema, so scalar and batched summaries
    # stay byte-identical.
    mc_stats: Optional[dict] = None

    @property
    def n_requests(self) -> int:
        return int(self.meta.get("n_requests", 0))

    def total_cost_usd(self) -> float:
        if isinstance(self.raw, ClusterResult):
            return self.raw.total_cost_usd()
        return self.raw.cost_usd()

    def usd_per_1k_requests(self) -> float:
        n = self.n_requests
        return self.total_cost_usd() / n * 1000.0 if n else 0.0

    def summary(self) -> dict:
        sc = self.scenario
        # v1 frame: stable zeros for every layer that is off, so the
        # gate/trend/CSV schemas never fork on topology or workload.
        out = {k: 0 for k in SUMMARY_KEYS_V1}
        out.update({
            "dispatcher": "none",
            "n_nodes": 1,
            "cores_per_node": sc.fleet.cores_per_node,
            "cold_start_rate": 0.0,
            "init_cost_usd": 0.0, "warm_hold_usd": 0.0,
            "rejected_cost_usd": 0.0,
            "retry_wait_ms": 0.0, "degraded_ms": 0.0,
            "spot_savings_usd": 0.0,
            # Cost-model substrate defaults (DESIGN.md Sec. 18): the
            # scalar python engine, no fallback, learned state zeroed
            # (ClusterResult overlays real values when the dispatcher
            # carries an estimator; the sweep overrides backend/
            # fallback_reason per row).
            "backend": "python", "fallback_reason": "none",
            "cost_coeff": 0.0, "cost_pred_err_ms": 0.0,
        })
        out.update(self.raw.summary())
        for k, v in self.meta.items():
            out.setdefault(k, v)
        out.update({
            "schema_version": SCHEMA_VERSION,
            "workload": sc.workload.kind,
            "policy": sc.policy.name,
            "pricing": _pricing_name(sc.pricing),
            "cost_model": _cost_model_kind(sc.cost_model),
            "n_requests": self.n_requests or out["n"],
            "total_cost_usd": self.total_cost_usd(),
        })
        n = out["n_requests"]
        out["usd_per_1k_requests"] = \
            out["total_cost_usd"] / n * 1000.0 if n else 0.0
        return out


# -- execution ----------------------------------------------------------------

def _serving_node_factory(serving: ServingSpec, pol: PolicySpec,
                          containers=None):
    from .serving.gateway import _slot_node_factory
    return _slot_node_factory(
        serving.resolve_model(), serving.seq_len, serving.n_fifo_frac,
        pol.adapt_pct, pol.rightsize,
        straggler_factor=serving.straggler_factor, containers=containers)


def _policy_node_factory(pol: PolicySpec):
    """Per-node scheduler construction honouring the hybrid knobs —
    adapter/rightsizer objects are stateful and must be FRESH per node,
    so they cannot ride in a shared NodeSpec kwargs dict."""
    from .core.hybrid import Rightsizer, TimeLimitAdapter
    from .core.simulate import make_scheduler

    def factory(policy: str, n_cores: int, **kw):
        if policy == "hybrid":
            if pol.adapt_pct is not None:
                kw.setdefault("adapter", TimeLimitAdapter(pct=pol.adapt_pct))
            if pol.rightsize:
                kw.setdefault("rightsizer", Rightsizer())
            if pol.n_fifo is not None:
                kw.setdefault("n_fifo", pol.n_fifo)
        return make_scheduler(policy, n_cores=n_cores, **kw)
    return factory


def _run_single(tasks: list[Task], containers, sc: Scenario,
                serving: Optional[ServingSpec]) -> SimResult:
    pol = sc.policy
    if serving is not None:
        from .core.metrics import collect
        factory = _serving_node_factory(serving, pol, containers)
        kw = dict(pol.kw)
        if pol.name == "hybrid" and pol.n_fifo is not None:
            kw["n_fifo"] = pol.n_fifo
        sched = factory(pol.name, n_cores=sc.fleet.cores_per_node, **kw)
        sched.run(tasks)
        out = collect(sched, pol.name)
        out.redispatches = getattr(sched, "redispatches", 0)
        return out
    from .core.simulate import execute_policy
    return execute_policy(
        pol.name, tasks, n_cores=sc.fleet.cores_per_node,
        adapt_pct=pol.adapt_pct, rightsize=pol.rightsize,
        microvm=pol.microvm, ghost_mode=pol.ghost_mode,
        containers=containers, fresh_tasks=False, **pol.kw)


def _run_fleet(tasks: list[Task], containers, sc: Scenario,
               serving: Optional[ServingSpec], cost_model=None,
               pricing=None,
               res: Optional[ResilienceSpec] = None) -> ClusterResult:
    fl, pol = sc.fleet, sc.policy
    if res is None:
        res = sc.resilience
    if pol.microvm or pol.ghost_mode:
        raise ValueError("microvm/ghost_mode are single-node system "
                         "models; use FleetSpec(dispatcher=None, "
                         "n_nodes=1)")
    factory = fl.node_factory
    if factory is None:
        if serving is not None:
            # Containers go through ClusterSim (not the factory) so
            # each node's pool keeps its own seed stream.
            factory = _serving_node_factory(serving, pol, containers=None)
        elif (pol.adapt_pct is not None or pol.rightsize
                or pol.n_fifo is not None):
            factory = _policy_node_factory(pol)
    if fl.nodes is not None:
        node_spec = list(fl.nodes)
    elif pol.kw:
        node_spec = (pol.name, dict(pol.kw))
    else:
        node_spec = pol.name
    dispatcher = fl.dispatcher if fl.dispatcher is not None \
        else "least_loaded"
    if dispatcher == "cost_aware" and cost_model is not None \
            and (cost_model.kind != "static" or pricing is not None):
        # Consumer 2: the cost model supplies the dispatcher's
        # queueing prior and (when learned) SHARES its online RLS, so
        # routing and the reported coefficient are one value. The
        # default static/no-pricing path keeps the plain string —
        # ClusterSim builds the identical historical dispatcher.
        from .cluster.dispatch import CostAwareDispatch
        kw = dict(seed=fl.seed, pricing=pricing,
                  queue_ms_per_load=cost_model.queue_ms_per_load())
        if getattr(cost_model, "rls", None) is not None:
            kw["rls"] = cost_model.rls
        dispatcher = CostAwareDispatch(**kw)
    sim = ClusterSim(
        n_nodes=fl.n_nodes, cores_per_node=fl.cores_per_node,
        node_policies=node_spec,
        dispatcher=dispatcher,
        seed=fl.seed, node_factory=factory, containers=containers,
        admission=res.admission, topology=fl.topology)
    out = sim.run(tasks, fresh_tasks=False, chaos=res.chaos,
                  prewarm=res.materialize_prewarm(tasks),
                  retry=res.retry)
    if serving is not None:
        out.redispatches = sum(getattr(n.sched, "redispatches", 0)
                               for n in sim.nodes)
    return out


def run(scenario: Scenario) -> ScenarioResult:
    """Execute a :class:`Scenario` — THE entrypoint every legacy front
    door now routes through."""
    sc = scenario
    from .costmodel.model import make_cost_model
    from .costmodel.pricing import resolve_pricing
    pricing = resolve_pricing(sc.pricing)   # None stays None: legacy path
    cost_model = make_cost_model(sc.cost_model, pricing=sc.pricing)
    workload = sc.workload
    llm = None
    if workload.kind == "llm":
        from .serving.llm import LLMSpec
        llm = workload.llm or LLMSpec()
        # Consumer 1: a learned model replaces the LLMSpec's constant
        # token costs with calibrated ones (static returns None and the
        # spec constants stand, bit-identically).
        tc = cost_model.token_costs(llm.resolve_model(), llm.seq_len)
        if tc is not None:
            cfg = llm.resolve_model().with_(
                ms_per_ktoken_prefill=tc[0], ms_per_token_decode=tc[1])
            llm = replace(llm, model=cfg)
            workload = replace(workload, llm=llm)
    tasks, meta = workload.build()
    serving = sc.policy.serving
    containers = sc.fleet.containers
    if llm is not None:
        if serving is None:
            # llm workloads serve through the slot schedulers by
            # default: preemption = KV swap, quanta sized to match.
            serving = ServingSpec(model=llm.model, seq_len=llm.seq_len)
        if containers is None:
            # ...and meter replica instantiation as the sandbox cold
            # start: weight-load + compile, warm pool = KV residency.
            containers = llm.container_spec()
    containers = as_container_config(containers, tasks)
    res = _resolve_resilience(sc.resilience, cost_model)
    if sc.fleet.is_fleet:
        raw = _run_fleet(tasks, containers, sc, serving,
                         cost_model=cost_model, pricing=pricing, res=res)
    else:
        raw = _run_single(tasks, containers, sc, serving)
    if pricing is not None:
        # Non-default pricing re-prices every roll-up; the None default
        # leaves the historical (bit-identical) constant path in place.
        raw.pricing = pricing
        if isinstance(raw, ClusterResult):
            for r in raw.node_results:
                r.pricing = pricing
    return ScenarioResult(scenario=sc, raw=raw, meta=dict(meta))
