"""Retry semantics for chaos-lost work: backoff, budgets, breakers.

Without a policy, a chaos kill requeues every lost invocation at the
kill instant — an *instant synchronized retry storm* that slams the
survivors with a correlated burst exactly when the fleet is smallest
(PR 5 semantics, still the default). A :class:`RetryPolicy` turns that
into the production shape:

* **capped exponential backoff** — attempt *n* waits
  ``min(cap_ms, base_ms x 2^(n-1))``, spreading the storm over time;
* **deterministic seeded jitter** — each wait is stretched by up to
  ``jitter_frac`` using a hash of (seed, tid, attempt), so retries
  decorrelate without any RNG state: the same fleet seed and schedule
  reproduce every delay bit-for-bit regardless of processing order;
* **retry budget** — an invocation is retried at most ``budget`` times;
  past that it is shed (priced like an admission reject — the fleet
  stops burning money on a lost cause);
* **per-function circuit breaker** — when ``breaker_threshold``
  failures of one function land within ``breaker_window_ms``, further
  retries of that function are shed through the admission accounting
  path until the window slides past: a poisoned function cannot keep
  the whole fleet in a retry loop.

:class:`RetryState` is the mutable per-run instance (budgets and
breaker windows are run state, like ``AdmissionControl``); the policy
dataclass is reusable across runs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union


@dataclass(frozen=True)
class RetryPolicy:
    """Declarative retry semantics (see module docstring)."""

    base_ms: float = 250.0        # first-retry backoff
    cap_ms: float = 8_000.0       # backoff ceiling
    jitter_frac: float = 0.5      # waits stretch by up to this fraction
    budget: int = 5               # max retries per invocation
    breaker_threshold: int = 0    # failures tripping the breaker (0=off)
    breaker_window_ms: float = 10_000.0

    def __post_init__(self):
        if self.base_ms < 0.0 or self.cap_ms < self.base_ms:
            raise ValueError("need 0 <= base_ms <= cap_ms")
        if not 0.0 <= self.jitter_frac <= 1.0:
            raise ValueError("jitter_frac must be in [0, 1]")
        if self.budget < 0 or self.breaker_threshold < 0:
            raise ValueError("budget/breaker_threshold must be >= 0")

    def backoff_ms(self, attempt: int, tid: int, seed: int = 0) -> float:
        """Wait before retry ``attempt`` (1-based) of task ``tid``.
        Pure arithmetic: a splitmix-style integer hash of
        (seed, tid, attempt) supplies the jitter fraction, so the wait
        is a function of identity, not of execution order."""
        base = min(self.cap_ms, self.base_ms * (2.0 ** (attempt - 1)))
        if self.jitter_frac <= 0.0:
            return base
        h = (tid * 0x9E3779B97F4A7C15 + attempt * 0xBF58476D1CE4E5B9
             + seed * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
        h ^= h >> 31
        h = (h * 0xD6E8FEB86659FD93) & 0xFFFFFFFFFFFFFFFF
        u = (h >> 11) / float(1 << 53)   # [0, 1)
        return base * (1.0 + self.jitter_frac * u)


class RetryState:
    """Per-run retry bookkeeping: budgets spent, breaker windows,
    roll-up counters. Decisions are pure functions of (policy, seed,
    task identity, failure history), so same seed + same chaos schedule
    reproduces every decision."""

    def __init__(self, policy: RetryPolicy, seed: int = 0):
        self.policy = policy
        self.seed = seed
        # func_id -> recent failure instants (pruned to the window).
        self._failures: dict[int, list[float]] = {}
        self.retries = 0            # retry dispatches scheduled
        self.retry_wait_ms = 0.0    # total backoff injected
        self.shed_budget = 0        # dropped: budget exhausted
        self.shed_breaker = 0       # dropped: circuit breaker open
        self.breaker_trips = 0

    def _breaker_open(self, func_id: int, t: float) -> bool:
        th = self.policy.breaker_threshold
        if th <= 0:
            return False
        window = self._failures.get(func_id)
        if not window:
            return False
        lo = t - self.policy.breaker_window_ms
        keep = [x for x in window if x > lo]
        if keep:
            self._failures[func_id] = keep
        else:
            del self._failures[func_id]
        return len(keep) >= th

    def on_failure(self, task, t: float) -> tuple[str, float]:
        """Decide the fate of one failed attempt of ``task`` at ``t``.

        Returns ``("retry", when)`` with the backoff-delayed re-dispatch
        instant, ``("shed", t)`` when the budget is exhausted or the
        function's breaker is open. Call BEFORE the task's retry
        counter is bumped for this attempt."""
        attempt = task.retries + 1
        if self.policy.breaker_threshold > 0:
            was_open = self._breaker_open(task.func_id, t)
            self._failures.setdefault(task.func_id, []).append(t)
            if not was_open and self._breaker_open(task.func_id, t):
                self.breaker_trips += 1
            if was_open:
                self.shed_breaker += 1
                return ("shed", t)
        if attempt > self.policy.budget:
            self.shed_budget += 1
            return ("shed", t)
        wait = self.policy.backoff_ms(attempt, task.tid, self.seed)
        self.retries += 1
        self.retry_wait_ms += wait
        return ("retry", t + wait)

    def stats(self) -> dict:
        return {
            "retries": self.retries,
            "retry_wait_ms": self.retry_wait_ms,
            "shed_budget": self.shed_budget,
            "shed_breaker": self.shed_breaker,
            "breaker_trips": self.breaker_trips,
        }


def make_retry(obj: Union[None, dict, RetryPolicy, RetryState],
               seed: int = 0) -> Optional[RetryState]:
    """Coerce any accepted ``retry=`` shape to a fresh per-run state."""
    if obj is None:
        return None
    if isinstance(obj, RetryState):
        return obj
    if isinstance(obj, dict):
        obj = RetryPolicy(**obj)
    if isinstance(obj, RetryPolicy):
        return RetryState(obj, seed=seed)
    raise TypeError(f"cannot build a RetryState from {type(obj).__name__}")
