"""Failure-domain topology: zones, racks, and heterogeneous node SKUs.

A provider fleet is not a flat list of identical hosts. Nodes live in
**racks** inside **availability zones** — the units that fail together
when a PDU trips or a zone browns out — and come in heterogeneous
**SKUs**: a fast-clock machine finishes the same invocation sooner (and
bills fewer wall-clock ms), a discounted *spot* machine is cheaper per
billed ms but can be revoked en masse, and different generations boot
sandboxes at different speeds. This module is the declarative side of
that world; ``ClusterSim`` consumes it:

* :class:`NodeSKU` — the hardware/pricing profile of one machine class:
  ``clock`` (service-rate multiplier: 1.25 runs chunks 25% faster,
  0.8 runs them slower — implemented through the engine's
  ``interference_fn`` channel, so slow hardware and chaos ``degrade``
  events compose in one place), ``price_mult`` (billed-$ multiplier on
  the duration share of the AWS model — memory price per SKU),
  a cold-start profile override (``cold_base_ms``/``cold_per_gb_ms``),
  and the spot axis (``spot`` + ``spot_discount``: cheap capacity the
  ``revoke_spot`` chaos action takes away — the price *incentive* and
  the revocation *risk* are two sides of one knob).
* :class:`TopologySpec` — zones x racks x nodes-per-rack plus a cycled
  SKU pattern and the ``cross_zone_ms`` latency penalty a dispatch
  pays when it leaves the invocation's home zone.

Determinism: placement is a pure function of the spec (node *i* fills
racks in order), a task's home zone is ``func_id % n_zones`` (the
front-door gateway it enters through), and every derived multiplier is
plain float arithmetic — the topology adds no RNG draws anywhere.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union


@dataclass(frozen=True)
class NodeSKU:
    """One machine class: clock, price, cold-start profile, spot axis."""

    name: str = "std"
    clock: float = 1.0          # service-rate multiplier (>1 = faster)
    price_mult: float = 1.0     # billed-$ multiplier (duration share)
    cold_base_ms: Optional[float] = None    # cold-start profile override
    cold_per_gb_ms: Optional[float] = None
    spot: bool = False
    spot_discount: float = 0.0  # fraction off the duration bill

    def __post_init__(self):
        if self.clock <= 0.0:
            raise ValueError("SKU clock multiplier must be positive")
        if not 0.0 <= self.spot_discount < 1.0:
            raise ValueError("spot_discount must be in [0, 1)")
        if self.spot_discount and not self.spot:
            raise ValueError("spot_discount on a non-spot SKU")

    @property
    def effective_price_mult(self) -> float:
        """Duration-bill multiplier after the spot discount."""
        return self.price_mult * (1.0 - self.spot_discount) \
            if self.spot else self.price_mult


# The benchmark SKU palette. "value" trades clock for price; "turbo"
# the reverse; "spot" is std hardware at a deep discount that the
# revoke_spot chaos action can take away mid-run.
SKUS = {
    "std": NodeSKU(name="std"),
    "turbo": NodeSKU(name="turbo", clock=1.25, price_mult=1.3),
    "value": NodeSKU(name="value", clock=0.8, price_mult=0.7),
    "spot": NodeSKU(name="spot", spot=True, spot_discount=0.6),
}


def as_sku(obj: Union[str, NodeSKU]) -> NodeSKU:
    if isinstance(obj, NodeSKU):
        return obj
    if obj not in SKUS:
        raise KeyError(f"unknown SKU {obj!r}; have {sorted(SKUS)}")
    return SKUS[obj]


@dataclass(frozen=True)
class NodePlacement:
    """Where one node sits and what hardware it is."""

    zone: str
    rack: str
    sku: NodeSKU


@dataclass(frozen=True)
class TopologySpec:
    """Zones x racks x nodes-per-rack with a cycled SKU pattern.

    ``sku_pattern`` is cycled over nodes in placement order (names into
    :data:`SKUS` or explicit :class:`NodeSKU` instances). Healed nodes
    join ``heal_zone`` (default: the first zone) as ``heal_sku``.
    ``cross_zone_ms`` is the latency an invocation pays when dispatch
    routes it outside its home zone (``func_id % n_zones``).
    """

    zones: Sequence[str] = ("z0", "z1")
    racks_per_zone: int = 2
    nodes_per_rack: int = 1
    sku_pattern: Sequence[Union[str, NodeSKU]] = ("std",)
    cross_zone_ms: float = 30.0
    heal_zone: Optional[str] = None
    heal_sku: Union[str, NodeSKU] = "std"

    def __post_init__(self):
        object.__setattr__(self, "zones", tuple(self.zones))
        object.__setattr__(self, "sku_pattern", tuple(
            as_sku(s) for s in self.sku_pattern))
        if not self.zones:
            raise ValueError("a topology needs at least one zone")
        if self.racks_per_zone < 1 or self.nodes_per_rack < 1:
            raise ValueError("racks_per_zone/nodes_per_rack must be >= 1")
        if not self.sku_pattern:
            raise ValueError("sku_pattern must name at least one SKU")
        if self.cross_zone_ms < 0.0:
            raise ValueError("cross_zone_ms must be >= 0")

    @property
    def n_nodes(self) -> int:
        return len(self.zones) * self.racks_per_zone * self.nodes_per_rack

    def placement(self) -> list[NodePlacement]:
        """Per-node (zone, rack, SKU), node ids filling racks in order."""
        out = []
        per_zone = self.racks_per_zone * self.nodes_per_rack
        for i in range(self.n_nodes):
            zone = self.zones[i // per_zone]
            rack = f"{zone}-r{(i % per_zone) // self.nodes_per_rack}"
            out.append(NodePlacement(
                zone=zone, rack=rack,
                sku=self.sku_pattern[i % len(self.sku_pattern)]))
        return out

    def heal_placement(self) -> NodePlacement:
        """Where a chaos-healed replacement node joins."""
        zone = self.heal_zone if self.heal_zone is not None else self.zones[0]
        return NodePlacement(zone=zone, rack=f"{zone}-heal",
                             sku=as_sku(self.heal_sku))

    def home_zone(self, func_id: int) -> str:
        """The gateway zone an invocation of ``func_id`` enters through
        (deterministic; no RNG)."""
        return self.zones[func_id % len(self.zones)]


class SlowdownDial:
    """The engine-facing slowdown of one node, as an ``interference_fn``.

    The scheduler's interference channel models stolen CPU: chunks run
    at ``rate = 1 - fn(t)``. A SKU clock *c* and a chaos ``degrade``
    severity *d* compose into one dial: ``rate = clock x (1 - d)``, so
    ``fn(t) = 1 - clock x (1 - d)``. The dial is mutable — ``degrade``
    raises ``d`` mid-run, ``restore`` drops it back to zero — and pure
    arithmetic, so same schedule => same rates (no RNG, no wall clock).
    """

    __slots__ = ("clock", "degrade")

    def __init__(self, clock: float = 1.0, degrade: float = 0.0):
        self.clock = clock
        self.degrade = degrade

    def __call__(self, t: float) -> float:
        return 1.0 - self.clock * (1.0 - self.degrade)
