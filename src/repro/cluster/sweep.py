"""Parallel experiment runner: policy x dispatcher x fleet-size grids.

Every cell is an independent ``ClusterSim`` run, so the grid is
embarrassingly parallel; ``run_sweep`` fans cells out over a
``multiprocessing`` pool and a paper-style comparison that takes serial
minutes finishes in seconds. Workers regenerate the workload from its
``TraceSpec`` (cheap, deterministic) instead of pickling task lists
across process boundaries.

CLI::

    python -m repro.cluster.sweep --nodes 2,4 --policies cfs,hybrid \
        --dispatchers random,least_loaded --minutes 1 --compare-serial

Past one machine, the same grid shards deterministically over hosts
(``--shard i/n`` runs the i-th 1/n slice; ``--merge`` folds the
per-shard ``--out`` files back into one canonical artifact)::

    python -m repro.cluster.sweep --preset heavy_traffic --shard 0/2 \
        --out ht0.json   # host A
    python -m repro.cluster.sweep --preset heavy_traffic --shard 1/2 \
        --out ht1.json   # host B
    python -m repro.cluster.sweep --merge ht0.json ht1.json \
        --out heavy_traffic.json
"""
from __future__ import annotations

import argparse
import itertools
import json
import multiprocessing as mp
import os
import sys
import time
from dataclasses import asdict, dataclass
from typing import TYPE_CHECKING, Optional

from ..core.containers import ContainerSpec
from ..traces.azure import TraceSpec
from .dispatch import DISPATCHERS

if TYPE_CHECKING:
    from ..scenario import Scenario


@dataclass(frozen=True)
class Cell:
    """One grid point; fully describes a reproducible cluster run.

    A cell is now sugar over the Scenario API: ``to_scenario()`` is the
    single translation and ``run_cell`` just executes it. ``workload``
    selects the stream — ``"azure"`` (the calibrated trace) or
    ``"llm"`` (model replicas as functions; ``model`` picks the
    registry arch and ``containers`` its keep-alive policy).
    """
    node_policy: str
    dispatcher: str
    n_nodes: int
    cores_per_node: int = 16
    load_scale: float = 1.0
    minutes: int = 1
    invocations_per_min: float = 1500.0
    n_functions: int = 80
    seed: int = 0
    # Container lifecycle layer: "off" | "fixed" | "histogram".
    containers: str = "off"
    container_capacity_mb: float = 4096.0
    keepalive_ms: float = 30_000.0
    # Workload kind: "azure" | "llm".
    workload: str = "azure"
    model: str = "deepseek-7b"
    # Failure-domain axes (DESIGN.md Sec. 17): "off" keeps the flat
    # fleet; "zoned" places n_nodes across 2 zones with a std/spot SKU
    # mix (n_nodes must be even). retry="on" attaches the default
    # backoff policy for chaos-lost work.
    topology: str = "off"
    retry: str = "off"
    # Cost-model substrate axes (DESIGN.md Sec. 18): pricing is a
    # PRICINGS preset name; cost_model is "static" | "learned". The
    # defaults keep cells bit-identical to the pre-CostModel grid.
    pricing: str = "default"
    cost_model: str = "static"

    def to_scenario(self) -> "Scenario":
        from ..scenario import (FleetSpec, PolicySpec, ResilienceSpec,
                                Scenario, WorkloadSpec)
        trace = TraceSpec(minutes=self.minutes,
                          invocations_per_min=self.invocations_per_min,
                          n_functions=self.n_functions, seed=self.seed)
        containers = None
        if self.workload == "llm":
            from ..serving.llm import LLMSpec
            wl = WorkloadSpec(kind="llm", trace=trace,
                              load_scale=self.load_scale,
                              llm=LLMSpec(
                                  model=self.model,
                                  keepalive_ms=self.keepalive_ms,
                                  container_policy=self.containers))
            # containers stay None: the llm workload derives its own
            # spec (cold = weight-load + compile) inside repro.run.
        else:
            wl = WorkloadSpec(kind=self.workload, trace=trace,
                              load_scale=self.load_scale)
            if self.containers != "off":
                containers = ContainerSpec(
                    policy=self.containers,
                    capacity_mb=self.container_capacity_mb,
                    keepalive_ms=self.keepalive_ms)
        # dispatcher="none" selects the single-node engine path (no
        # ClusterSim): the shape the batched MC backend accelerates.
        dispatcher = None if self.dispatcher == "none" else self.dispatcher
        topology = None
        if self.topology == "zoned":
            from .topology import TopologySpec
            if self.n_nodes % 2:
                raise ValueError("topology='zoned' needs an even "
                                 f"n_nodes, got {self.n_nodes}")
            topology = TopologySpec(
                zones=("z0", "z1"), racks_per_zone=self.n_nodes // 2,
                nodes_per_rack=1, sku_pattern=("std", "spot"))
        elif self.topology != "off":
            raise ValueError(f"unknown topology axis {self.topology!r}")
        if self.retry not in ("off", "on"):
            raise ValueError(f"unknown retry axis {self.retry!r}")
        resilience = ResilienceSpec()
        if self.retry == "on":
            from .retry import RetryPolicy
            resilience = ResilienceSpec(retry=RetryPolicy())
        return Scenario(
            workload=wl,
            fleet=FleetSpec(n_nodes=self.n_nodes,
                            cores_per_node=self.cores_per_node,
                            dispatcher=dispatcher,
                            containers=containers, seed=self.seed,
                            topology=topology),
            policy=PolicySpec(name=self.node_policy),
            resilience=resilience,
            pricing=None if self.pricing == "default" else self.pricing,
            cost_model=(None if self.cost_model == "static"
                        else self.cost_model))


def run_cell(cell: Cell) -> dict:
    """Execute one grid point and return its summary row."""
    from ..scenario import run
    res = run(cell.to_scenario())
    row = asdict(cell)
    row.update(res.summary())
    return row


def build_grid(node_policies, dispatchers, n_nodes, load_scales=(1.0,),
               **common) -> list[Cell]:
    return [Cell(node_policy=p, dispatcher=d, n_nodes=n, load_scale=ls,
                 **common)
            for p, d, n, ls in itertools.product(
                node_policies, dispatchers, n_nodes, load_scales)]


def run_sweep(grid: list[Cell], *, parallel: bool = True,
              processes: Optional[int] = None,
              backend: str = "python") -> list[dict]:
    """Run every cell and return summary rows in grid order.

    ``backend="jax"`` routes cells inside the batched Monte-Carlo
    regime (single node or flat ``round_robin``/``random`` fleets, no
    containers — see ``repro.mc.dispatch``) through one vmapped device
    program and everything else through the usual per-cell path; rows
    gain a ``backend`` key recording the route, and fallback rows a
    ``fallback_reason`` counter key.  Results are identical either
    way — the batched engine is bit-compatible and out-of-regime cells
    fall back transparently.
    """
    if backend == "jax":
        return _run_sweep_jax(grid, parallel=parallel,
                              processes=processes)
    if backend != "python":
        raise ValueError(f"unknown backend {backend!r}")
    if not parallel or len(grid) <= 1:
        return [run_cell(c) for c in grid]
    processes = processes or min(len(grid), os.cpu_count() or 2)
    with mp.Pool(processes) as pool:
        return pool.map(run_cell, grid)


def _run_sweep_jax(grid: list[Cell], *, parallel: bool,
                   processes: Optional[int]) -> list[dict]:
    from ..mc.dispatch import reason_key, supported, tasks_supported
    from ..mc.engine import run_scenarios

    scs = [c.to_scenario() for c in grid]
    # Per-cell gate refusal keys (None = batched): fallback rows carry
    # theirs as ``fallback_reason`` so a sweep that silently routes
    # most cells to the scalar path never reads as "batched".
    reasons: list[Optional[str]] = []
    for sc in scs:
        why = supported(sc)
        reasons.append(None if why is None else reason_key(why))
    jax_idx = [k for k in range(len(scs)) if reasons[k] is None]
    # Build once here (shared with the kernel via ``prebuilt``) so the
    # dynamic half of the gate can still demote caller-shaped streams.
    prebuilt = [scs[k].workload.build() for k in jax_idx]
    keep = []
    for j, k in enumerate(jax_idx):
        why = tasks_supported(prebuilt[j][0])
        if why is None:
            keep.append(j)
        else:
            reasons[k] = reason_key(why)
    jax_idx = [jax_idx[j] for j in keep]
    prebuilt = [prebuilt[j] for j in keep]

    rows: list[Optional[dict]] = [None] * len(grid)
    if jax_idx:
        batched = run_scenarios([scs[k] for k in jax_idx],
                                prebuilt=prebuilt)
        for k, res in zip(jax_idx, batched):
            row = asdict(grid[k])
            row.update(res.summary())
            row["backend"] = "jax"
            rows[k] = row
    rest = [k for k in range(len(grid)) if rows[k] is None]
    if rest:
        for k, row in zip(rest, run_sweep([grid[k] for k in rest],
                                          parallel=parallel,
                                          processes=processes)):
            row["backend"] = "python"
            row["fallback_reason"] = reasons[k]
            rows[k] = row
    return rows


def compare_serial(grid: list[Cell],
                   processes: Optional[int] = None) -> dict:
    """Time the same grid serially and in parallel; returns timings and
    the speedup (the sweep-runner acceptance check)."""
    t0 = time.time()
    run_sweep(grid, parallel=False)
    serial_s = time.time() - t0
    t0 = time.time()
    rows = run_sweep(grid, parallel=True, processes=processes)
    parallel_s = time.time() - t0
    return {"serial_s": serial_s, "parallel_s": parallel_s,
            "speedup": serial_s / max(parallel_s, 1e-9), "rows": rows}


def _csv(vals, cast=str):
    return [cast(v) for v in vals.split(",") if v]


# -- sharding: split one grid across machines ---------------------------------

def shard_grid(grid: list[Cell], shard: str) -> list[Cell]:
    """Deterministic cell partition for multi-host sweeps.

    ``shard`` is ``"i/n"``: this invocation runs every cell whose index
    in the (deterministic) grid order is ``i`` mod ``n``. The shards are
    disjoint, cover the grid exactly, and — because ``build_grid`` is a
    pure itertools product — every host computes the same partition from
    the same flags with no coordination. Merge the per-shard ``--out``
    files with ``--merge`` afterwards.
    """
    try:
        i_s, n_s = shard.split("/")
        i, n = int(i_s), int(n_s)
    except ValueError:
        raise ValueError(f"--shard wants 'i/n', got {shard!r}") from None
    if not (n >= 1 and 0 <= i < n):
        raise ValueError(f"shard index {i} out of range for {n} shards")
    return [c for k, c in enumerate(grid) if k % n == i]


def _row_key(row: dict) -> tuple:
    return tuple(str(row.get(k)) for k in (
        "node_policy", "dispatcher", "n_nodes", "load_scale",
        "containers", "seed", "minutes", "workload", "model",
        "topology", "retry", "pricing", "cost_model"))


def merge_rows(paths: list[str]) -> list[dict]:
    """Fold per-shard ``--out`` JSON files back into one artifact's
    rows, canonically ordered: any shard split of the same grid merges
    to the identical row list, and it contains exactly the rows an
    unsharded run produces (the unsharded artifact keeps grid order,
    so compare per cell — as the gate and trend report do — not by
    byte-diffing files)."""
    rows: list[dict] = []
    for p in paths:
        with open(p) as f:
            payload = json.load(f)
        rows.extend(payload["rows"] if isinstance(payload, dict)
                    else payload)
    rows.sort(key=_row_key)
    return rows


def profile_slowest_cell(grid: list[Cell], top: int = 20) -> dict:
    """Time every cell serially, then re-run the slowest one under
    cProfile and print its ``top`` hottest functions (cumulative). One
    command answers "where did my sweep's wall-clock go" — the next
    engine hot spot is whatever this prints first."""
    import cProfile
    import pstats

    timings = []
    for cell in grid:
        t0 = time.time()
        run_cell(cell)
        timings.append((time.time() - t0, cell))
    worst_s, worst = max(timings, key=lambda x: x[0])
    print(f"# slowest cell ({worst_s:.2f}s serial): {worst}",
          file=sys.stderr)
    prof = cProfile.Profile()
    prof.enable()
    row = run_cell(worst)
    prof.disable()
    stats = pstats.Stats(prof, stream=sys.stderr)
    stats.sort_stats("cumulative").print_stats(top)
    return {"slowest_cell": asdict(worst), "serial_s": worst_s,
            "row": row}


# Named grids. ``heavy_traffic`` is the paper-size nightly preset: the
# full 2-minute Azure-like trace crossed with load scales and fleet
# sizes, containers modelled with the Azure-style histogram keep-alive.
PRESETS: dict[str, dict] = {
    "heavy_traffic": {
        "policies": ["cfs", "hybrid"],
        "dispatchers": ["least_loaded", "affinity", "warm_affinity",
                        "cost_aware"],
        "nodes": [4, 8],
        "load_scales": [1.0, 2.0, 4.0],
        "minutes": 2,
        "invocations_per_min": 6221.0,   # paper volume: ~12,442 in 2 min
        "n_functions": 250,
        "cores_per_node": 16,
        "containers": "histogram",
    },
}


SUMMARY_COLS = ("node_policy", "dispatcher", "n_nodes", "load_scale",
                "cost_usd", "cold_start_rate", "warm_hold_usd",
                "p99_slowdown", "util_range")


def print_rows(rows: list[dict], cols=SUMMARY_COLS) -> None:
    """CSV-print summary rows (shared by the sweep CLI and benches).
    Missing columns print empty: single-node cells (dispatcher
    ``"none"``) carry no fleet-only keys like ``util_range``."""
    print(",".join(cols))
    for r in rows:
        print(",".join("" if c not in r
                       else f"{r[c]:.4g}" if isinstance(r[c], float)
                       else str(r[c]) for c in cols))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--policies", default="cfs,hybrid")
    ap.add_argument("--dispatchers", default=",".join(sorted(DISPATCHERS)))
    ap.add_argument("--nodes", default="2,4")
    ap.add_argument("--load-scales", default="1.0")
    ap.add_argument("--cores-per-node", type=int, default=16)
    ap.add_argument("--minutes", type=int, default=1)
    ap.add_argument("--invocations-per-min", type=float, default=1500.0)
    ap.add_argument("--n-functions", type=int, default=80)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--containers", default="off",
                    choices=("off", "fixed", "histogram"),
                    help="container lifecycle layer / keep-alive policy")
    ap.add_argument("--container-capacity-mb", type=float, default=4096.0)
    ap.add_argument("--keepalive-ms", type=float, default=30_000.0)
    ap.add_argument("--topology", default="off", choices=("off", "zoned"),
                    help="zoned: place nodes across 2 zones with a "
                         "std/spot SKU mix (needs even --nodes)")
    ap.add_argument("--retry", default="off", choices=("off", "on"),
                    help="attach the default backoff retry policy for "
                         "chaos-lost work")
    ap.add_argument("--pricing", default="default",
                    help="PricingSpec preset every cell bills with "
                         "(repro.costmodel.PRICINGS; default keeps the "
                         "historical constants bit-identically)")
    ap.add_argument("--cost-model", default="static",
                    choices=("static", "learned"),
                    help="cost model threaded into llm pricing, "
                         "cost_aware dispatch, admission and pre-warm")
    ap.add_argument("--preset", default=None, choices=sorted(PRESETS),
                    help="named grid (overrides the grid-shape flags)")
    ap.add_argument("--shard", default=None, metavar="i/n",
                    help="run only this deterministic 1/n slice of the "
                         "grid (fan a sweep out over hosts; recombine "
                         "the per-shard --out files with --merge)")
    ap.add_argument("--merge", nargs="+", default=None, metavar="JSON",
                    help="merge per-shard --out files into --out and "
                         "exit (no cells are run)")
    ap.add_argument("--backend", default="python",
                    choices=("python", "jax"),
                    help="jax: batch in-regime cells (single-node or "
                         "flat round_robin/random fleets, no "
                         "containers) into one vmapped device program; "
                         "out-of-regime cells fall back per cell")
    ap.add_argument("--serial", action="store_true",
                    help="disable the multiprocessing pool")
    ap.add_argument("--compare-serial", action="store_true",
                    help="time serial vs parallel and report the speedup")
    ap.add_argument("--profile", action="store_true",
                    help="run serially, then print a cProfile top-20 of "
                         "the slowest cell (engine hot-spot hunting)")
    ap.add_argument("--out", default=None, help="write rows as JSON here")
    args = ap.parse_args(argv)

    if args.merge:
        rows = merge_rows(args.merge)
        print_rows(rows)
        if not args.out:
            ap.error("--merge needs --out for the combined artifact")
        with open(args.out, "w") as f:
            json.dump({"meta": {"merged_from": args.merge}, "rows": rows},
                      f, indent=2)
        print(f"# merged {len(args.merge)} shard files "
              f"({len(rows)} rows) -> {args.out}", file=sys.stderr)
        return

    if args.preset:
        p = PRESETS[args.preset]
        grid = build_grid(
            p["policies"], p["dispatchers"], p["nodes"], p["load_scales"],
            cores_per_node=p["cores_per_node"], minutes=p["minutes"],
            invocations_per_min=p["invocations_per_min"],
            n_functions=p["n_functions"], seed=args.seed,
            containers=p["containers"],
            container_capacity_mb=args.container_capacity_mb,
            keepalive_ms=args.keepalive_ms,
            topology=args.topology, retry=args.retry,
            pricing=args.pricing, cost_model=args.cost_model)
    else:
        grid = build_grid(
            _csv(args.policies), _csv(args.dispatchers),
            _csv(args.nodes, int), _csv(args.load_scales, float),
            cores_per_node=args.cores_per_node, minutes=args.minutes,
            invocations_per_min=args.invocations_per_min,
            n_functions=args.n_functions, seed=args.seed,
            containers=args.containers,
            container_capacity_mb=args.container_capacity_mb,
            keepalive_ms=args.keepalive_ms,
            topology=args.topology, retry=args.retry,
            pricing=args.pricing, cost_model=args.cost_model)

    if args.shard:
        full = len(grid)
        grid = shard_grid(grid, args.shard)
        print(f"# shard {args.shard}: {len(grid)}/{full} cells",
              file=sys.stderr)

    if args.profile:
        profile_slowest_cell(grid)
        return

    meta = {}
    if args.compare_serial:
        meta = compare_serial(grid)
        rows = meta.pop("rows")
        print(f"# serial {meta['serial_s']:.2f}s  "
              f"parallel {meta['parallel_s']:.2f}s  "
              f"speedup {meta['speedup']:.2f}x", file=sys.stderr)
    else:
        rows = run_sweep(grid, parallel=not args.serial,
                         backend=args.backend)

    print_rows(rows)
    if args.out:
        payload = {"meta": meta, "rows": rows}
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)


if __name__ == "__main__":
    main()
