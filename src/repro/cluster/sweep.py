"""Parallel experiment runner: policy x dispatcher x fleet-size grids.

Every cell is an independent ``ClusterSim`` run, so the grid is
embarrassingly parallel; ``run_sweep`` fans cells out over a
``multiprocessing`` pool and a paper-style comparison that takes serial
minutes finishes in seconds. Workers regenerate the workload from its
``TraceSpec`` (cheap, deterministic) instead of pickling task lists
across process boundaries.

CLI::

    python -m repro.cluster.sweep --nodes 2,4 --policies cfs,hybrid \
        --dispatchers random,least_loaded --minutes 1 --compare-serial
"""
from __future__ import annotations

import argparse
import itertools
import json
import multiprocessing as mp
import os
import sys
import time
from dataclasses import asdict, dataclass
from typing import Optional

from ..traces.azure import TraceSpec
from ..traces.workload import generate_workload, scale_load
from .dispatch import DISPATCHERS
from .sim import run_cluster


@dataclass(frozen=True)
class Cell:
    """One grid point; fully describes a reproducible cluster run."""
    node_policy: str
    dispatcher: str
    n_nodes: int
    cores_per_node: int = 16
    load_scale: float = 1.0
    minutes: int = 1
    invocations_per_min: float = 1500.0
    n_functions: int = 80
    seed: int = 0


def run_cell(cell: Cell) -> dict:
    """Execute one grid point and return its summary row."""
    spec = TraceSpec(minutes=cell.minutes,
                     invocations_per_min=cell.invocations_per_min,
                     n_functions=cell.n_functions, seed=cell.seed)
    tasks = generate_workload(spec).tasks
    if cell.load_scale != 1.0:
        tasks = scale_load(tasks, cell.load_scale)
    res = run_cluster(tasks, n_nodes=cell.n_nodes,
                      cores_per_node=cell.cores_per_node,
                      node_policy=cell.node_policy,
                      dispatcher=cell.dispatcher, seed=cell.seed,
                      node_factory=None)
    row = asdict(cell)
    row.update(res.summary())
    return row


def build_grid(node_policies, dispatchers, n_nodes, load_scales=(1.0,),
               **common) -> list[Cell]:
    return [Cell(node_policy=p, dispatcher=d, n_nodes=n, load_scale=ls,
                 **common)
            for p, d, n, ls in itertools.product(
                node_policies, dispatchers, n_nodes, load_scales)]


def run_sweep(grid: list[Cell], *, parallel: bool = True,
              processes: Optional[int] = None) -> list[dict]:
    if not parallel or len(grid) <= 1:
        return [run_cell(c) for c in grid]
    processes = processes or min(len(grid), os.cpu_count() or 2)
    with mp.Pool(processes) as pool:
        return pool.map(run_cell, grid)


def compare_serial(grid: list[Cell],
                   processes: Optional[int] = None) -> dict:
    """Time the same grid serially and in parallel; returns timings and
    the speedup (the sweep-runner acceptance check)."""
    t0 = time.time()
    run_sweep(grid, parallel=False)
    serial_s = time.time() - t0
    t0 = time.time()
    rows = run_sweep(grid, parallel=True, processes=processes)
    parallel_s = time.time() - t0
    return {"serial_s": serial_s, "parallel_s": parallel_s,
            "speedup": serial_s / max(parallel_s, 1e-9), "rows": rows}


def _csv(vals, cast=str):
    return [cast(v) for v in vals.split(",") if v]


SUMMARY_COLS = ("node_policy", "dispatcher", "n_nodes", "load_scale",
                "cost_usd", "p99_slowdown", "util_range")


def print_rows(rows: list[dict], cols=SUMMARY_COLS) -> None:
    """CSV-print summary rows (shared by the sweep CLI and benches)."""
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.4g}" if isinstance(r[c], float)
                       else str(r[c]) for c in cols))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--policies", default="cfs,hybrid")
    ap.add_argument("--dispatchers", default=",".join(sorted(DISPATCHERS)))
    ap.add_argument("--nodes", default="2,4")
    ap.add_argument("--load-scales", default="1.0")
    ap.add_argument("--cores-per-node", type=int, default=16)
    ap.add_argument("--minutes", type=int, default=1)
    ap.add_argument("--invocations-per-min", type=float, default=1500.0)
    ap.add_argument("--n-functions", type=int, default=80)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--serial", action="store_true",
                    help="disable the multiprocessing pool")
    ap.add_argument("--compare-serial", action="store_true",
                    help="time serial vs parallel and report the speedup")
    ap.add_argument("--out", default=None, help="write rows as JSON here")
    args = ap.parse_args(argv)

    grid = build_grid(
        _csv(args.policies), _csv(args.dispatchers),
        _csv(args.nodes, int), _csv(args.load_scales, float),
        cores_per_node=args.cores_per_node, minutes=args.minutes,
        invocations_per_min=args.invocations_per_min,
        n_functions=args.n_functions, seed=args.seed)

    meta = {}
    if args.compare_serial:
        meta = compare_serial(grid)
        rows = meta.pop("rows")
        print(f"# serial {meta['serial_s']:.2f}s  "
              f"parallel {meta['parallel_s']:.2f}s  "
              f"speedup {meta['speedup']:.2f}x", file=sys.stderr)
    else:
        rows = run_sweep(grid, parallel=not args.serial)

    print_rows(rows)
    if args.out:
        payload = {"meta": meta, "rows": rows}
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=2)


if __name__ == "__main__":
    main()
