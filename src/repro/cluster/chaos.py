"""Declarative fleet chaos: kill/heal schedules driven through the run.

Real FaaS fleets churn — hosts die mid-burst, capacity rejoins minutes
later, a deploy wipes a node's warm pool — and a cost claim that only
holds on a static healthy fleet is not a cost claim. This module turns
``ClusterSim.add_node`` / ``remove_node`` from manual calls into a
first-class harness: a :class:`ChaosSchedule` is a time-ordered list of
declarative events the fleet loop applies mid-run, interleaved with
arrivals at exact instants.

Semantics (DESIGN.md Sec. 14 & 17):

``kill``        -- the node vanishes at ``t``: no graceful drain. Work
                   assigned-but-unfinished there is REQUEUED through the
                   front-end dispatcher at ``t`` with its runtime state
                   reset (progress is lost; queueing is still measured
                   from the invocation's true arrival). The node's warm
                   pool is destroyed at ``t`` — its memory meter stops
                   there — and its *finished* work still counts in the
                   fleet roll-up.
``heal``        -- a fresh node (optionally with a policy ``spec``)
                   joins at ``t``: empty warm pool, clean scheduler.
                   Consistent-hash dispatchers remap ~1/N of functions.
``flush_warm``  -- the node survives but its warm pool is lost at ``t``
                   (deploy / OOM / sandbox-runtime restart): every
                   subsequent invocation there pays a cold start until
                   warmth is rebuilt.

Correlated failure domains (PR 8; require a fleet ``topology``):

``kill_zone``   -- every live node in ``zone`` dies at ``t`` (zone
                   power/network loss). One event, many victims: the
                   canonical correlated failure.
``kill_rack``   -- every live node in ``rack`` dies (PDU / ToR loss).
``revoke_spot`` -- every live *spot*-SKU node is revoked at ``t`` (the
                   provider reclaims discounted capacity; ``zone``
                   optionally scopes the revocation). The price
                   incentive and the revocation risk are one axis.
``degrade``     -- slow-not-dead: the target node (or every node in
                   ``zone``) keeps running but loses ``severity`` of
                   its clock via the engine's ``interference_fn``
                   channel — a brownout, a noisy neighbour, a thermal
                   throttle. Nothing is requeued; everything there
                   just gets slower (and costs more per invocation).
``restore``     -- the matching recovery: degraded targets return to
                   their SKU clock.

A kill's lost work is requeued immediately (PR 5) or routed through the
run's :class:`~repro.cluster.retry.RetryPolicy` — capped exponential
backoff with deterministic jitter, a retry budget, and a per-function
circuit breaker — so a zone loss produces a bounded, priced storm.

Events name nodes by **node id** (``"node0"``), which is stable across
churn, or ``node=None`` = the first live node at fire time; correlated
events name a ``zone`` or ``rack`` label instead. An event whose target
is already gone records a no-op instead of failing: chaos schedules are
declarative wishes about a fleet that may have changed under them.

Determinism: the schedule is data, the fleet loop applies events at
exact times in (t, event-order), correlated events expand over live
nodes in fleet order, and every requeue decision flows through the same
seeded dispatcher — same seed + same schedule => bit-identical fleet
roll-ups (tested).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

ACTIONS = ("kill", "heal", "flush_warm",
           "kill_zone", "kill_rack", "revoke_spot", "degrade", "restore")

# Actions that need zone/rack/SKU labels on the fleet's nodes.
TOPOLOGY_ACTIONS = ("kill_zone", "kill_rack", "revoke_spot")


@dataclass(frozen=True)
class ChaosEvent:
    """One declarative fleet event.

    ``node`` is a node id (kill / flush_warm / degrade / restore;
    None = first live node); ``spec`` is the node policy spec a
    ``heal`` brings up (None = the fleet's default ``heal_spec``).
    ``zone``/``rack`` target failure domains (kill_zone / kill_rack;
    also accepted by degrade / restore / revoke_spot to scope them);
    ``severity`` is the clock fraction a ``degrade`` steals.
    """

    t: float
    action: str
    node: Optional[str] = None
    spec: Optional[object] = None
    zone: Optional[str] = None
    rack: Optional[str] = None
    severity: float = 0.5

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(
                f"unknown chaos action {self.action!r}; have {ACTIONS}")
        if self.action == "kill_zone" and self.zone is None:
            raise ValueError("kill_zone needs zone=")
        if self.action == "kill_rack" and self.rack is None:
            raise ValueError("kill_rack needs rack=")
        if not 0.0 <= self.severity < 1.0:
            raise ValueError("severity must be in [0, 1)")


@dataclass(frozen=True)
class ChaosSchedule:
    """Time-ordered chaos events plus the default heal policy spec."""

    events: tuple = ()
    heal_spec: object = "hybrid"

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(
            sorted(self.events, key=lambda e: e.t)))

    def __iter__(self):
        return iter(self.events)

    def __len__(self):
        return len(self.events)


def kill_heal(t_down: float, t_up: float, node: Optional[str] = None,
              spec: object = "hybrid") -> ChaosSchedule:
    """The canonical churn pair: ``node`` dies at ``t_down`` and an
    equivalent fresh (cold!) node joins at ``t_up``."""
    if t_up <= t_down:
        raise ValueError("heal must come after kill")
    return ChaosSchedule(events=(
        ChaosEvent(t=t_down, action="kill", node=node),
        ChaosEvent(t=t_up, action="heal", spec=spec),
    ), heal_spec=spec)


def churn_preset(horizon_ms: float, node_policy: object = "hybrid",
                 flush_node: Optional[str] = None) -> ChaosSchedule:
    """The benchmark/CI chaos preset: one mid-run node loss healed by a
    cold replacement, plus a warm-pool wipe on a surviving node — node
    churn AND cold-start-storm pressure in one schedule.

    * kill ``node0`` at 25% of the horizon (mid first burst),
    * wipe ``flush_node``'s warm pool (default ``node1``) at 45%,
    * heal with a fresh ``node_policy`` node at 60%.
    """
    return ChaosSchedule(events=(
        ChaosEvent(t=0.25 * horizon_ms, action="kill", node="node0"),
        ChaosEvent(t=0.45 * horizon_ms, action="flush_warm",
                   node=flush_node or "node1"),
        ChaosEvent(t=0.60 * horizon_ms, action="heal", spec=node_policy),
    ), heal_spec=node_policy)


def zone_failure_preset(horizon_ms: float,
                        kill: str = "z1", brownout: str = "z0",
                        node_policy: object = "hybrid",
                        severity: float = 0.5,
                        heals: int = 2) -> ChaosSchedule:
    """The correlated-failure preset the topology benchmark runs: a
    zone brownout, a full zone loss, a spot revocation sweep, partial
    recovery — every failure mode of DESIGN.md Sec. 17 in one schedule.

    * ``brownout`` zone degrades (slow-not-dead) at 15% of the horizon,
    * ``kill`` zone dies wholesale at 30% (correlated kill + storm),
    * every spot node is revoked at 50% (the price incentive bites),
    * ``heals`` fresh nodes join from 60% (one per 5% of horizon),
    * the brownout lifts at 75%.
    """
    events = [
        ChaosEvent(t=0.15 * horizon_ms, action="degrade", zone=brownout,
                   severity=severity),
        ChaosEvent(t=0.30 * horizon_ms, action="kill_zone", zone=kill),
        ChaosEvent(t=0.50 * horizon_ms, action="revoke_spot"),
    ]
    for k in range(heals):
        events.append(ChaosEvent(t=(0.60 + 0.05 * k) * horizon_ms,
                                 action="heal", spec=node_policy))
    events.append(ChaosEvent(t=0.75 * horizon_ms, action="restore",
                             zone=brownout))
    return ChaosSchedule(events=tuple(events), heal_spec=node_policy)
