"""Declarative fleet chaos: kill/heal schedules driven through the run.

Real FaaS fleets churn — hosts die mid-burst, capacity rejoins minutes
later, a deploy wipes a node's warm pool — and a cost claim that only
holds on a static healthy fleet is not a cost claim. This module turns
``ClusterSim.add_node`` / ``remove_node`` from manual calls into a
first-class harness: a :class:`ChaosSchedule` is a time-ordered list of
declarative events the fleet loop applies mid-run, interleaved with
arrivals at exact instants.

Semantics (DESIGN.md Sec. 14):

``kill``        -- the node vanishes at ``t``: no graceful drain. Work
                   assigned-but-unfinished there is REQUEUED through the
                   front-end dispatcher at ``t`` with its runtime state
                   reset (progress is lost; queueing is still measured
                   from the invocation's true arrival). The node's warm
                   pool is destroyed at ``t`` — its memory meter stops
                   there — and its *finished* work still counts in the
                   fleet roll-up.
``heal``        -- a fresh node (optionally with a policy ``spec``)
                   joins at ``t``: empty warm pool, clean scheduler.
                   Consistent-hash dispatchers remap ~1/N of functions.
``flush_warm``  -- the node survives but its warm pool is lost at ``t``
                   (deploy / OOM / sandbox-runtime restart): every
                   subsequent invocation there pays a cold start until
                   warmth is rebuilt.

Events name nodes by **node id** (``"node0"``), which is stable across
churn, or ``node=None`` = the first live node at fire time. An event
whose target is already gone records a no-op instead of failing: chaos
schedules are declarative wishes about a fleet that may have changed
under them.

Determinism: the schedule is data, the fleet loop applies events at
exact times in (t, event-order), and every requeue decision flows
through the same seeded dispatcher — same seed + same schedule =>
bit-identical fleet roll-ups (tested).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

ACTIONS = ("kill", "heal", "flush_warm")


@dataclass(frozen=True)
class ChaosEvent:
    """One declarative fleet event.

    ``node`` is a node id (kill / flush_warm; None = first live node);
    ``spec`` is the node policy spec a ``heal`` brings up (None = the
    fleet's default ``heal_spec``).
    """

    t: float
    action: str
    node: Optional[str] = None
    spec: Optional[object] = None

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(
                f"unknown chaos action {self.action!r}; have {ACTIONS}")


@dataclass(frozen=True)
class ChaosSchedule:
    """Time-ordered chaos events plus the default heal policy spec."""

    events: tuple = ()
    heal_spec: object = "hybrid"

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(
            sorted(self.events, key=lambda e: e.t)))

    def __iter__(self):
        return iter(self.events)

    def __len__(self):
        return len(self.events)


def kill_heal(t_down: float, t_up: float, node: Optional[str] = None,
              spec: object = "hybrid") -> ChaosSchedule:
    """The canonical churn pair: ``node`` dies at ``t_down`` and an
    equivalent fresh (cold!) node joins at ``t_up``."""
    if t_up <= t_down:
        raise ValueError("heal must come after kill")
    return ChaosSchedule(events=(
        ChaosEvent(t=t_down, action="kill", node=node),
        ChaosEvent(t=t_up, action="heal", spec=spec),
    ), heal_spec=spec)


def churn_preset(horizon_ms: float, node_policy: object = "hybrid",
                 flush_node: Optional[str] = None) -> ChaosSchedule:
    """The benchmark/CI chaos preset: one mid-run node loss healed by a
    cold replacement, plus a warm-pool wipe on a surviving node — node
    churn AND cold-start-storm pressure in one schedule.

    * kill ``node0`` at 25% of the horizon (mid first burst),
    * wipe ``flush_node``'s warm pool (default ``node1``) at 45%,
    * heal with a fresh ``node_policy`` node at 60%.
    """
    return ChaosSchedule(events=(
        ChaosEvent(t=0.25 * horizon_ms, action="kill", node="node0"),
        ChaosEvent(t=0.45 * horizon_ms, action="flush_warm",
                   node=flush_node or "node1"),
        ChaosEvent(t=0.60 * horizon_ms, action="heal", spec=node_policy),
    ), heal_spec=node_policy)
