"""Dispatcher-side admission control and per-function rate limits.

The fleet's front door decides — before any routing — whether an
invocation is *admitted* at all. Two independent guards, in order:

1. **Per-function token bucket** (GCRA form): each function may sustain
   ``rate_per_s`` invocations/second with ``burst`` of slack. A
   non-conforming invocation is shed or queued (held at the front end
   until its token matures) per ``rate_action``.
2. **Fleet load ceiling** (core-granular admission a la Kaffes et al.,
   "Practical Scheduling for Real-World Serverless Computing"): when
   even the least-loaded node is above ``max_load``
   (admitted-but-unfinished tasks per core), the invocation is shed,
   queued for ``queue_backoff_ms`` and retried, or *spilled* — admitted
   anyway but force-routed to the least-loaded node, overriding
   affinity-style dispatchers that would pile onto a hot ring owner.

Outcomes and their accounting (DESIGN.md Sec. 14):

``admit``  -- flows to the configured dispatcher as before.
``queue``  -- dispatch is DELAYED; the task's ``arrival`` keeps its true
              value, so front-door queueing shows up in turnaround and
              slowdown like any other queueing. Total front-door wait is
              bounded by ``max_queue_ms``; past it the task is shed.
``spill``  -- admitted to the least-loaded node; counted.
``shed``   -- rejected: the task is marked failed, never reaches a node,
              and is PRICED separately (the per-request fee is still
              incurred — ``core.cost.rejected_request_cost_usd``), so
              shedding load can never masquerade as a cost saving.

Every decision is deterministic: the bucket state is plain arithmetic
over arrival instants, and ties never depend on hash order.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

_INF = float("inf")


@dataclass(frozen=True)
class AdmissionConfig:
    """Front-door admission knobs (picklable; carried by bench cells)."""

    # -- per-function token bucket (GCRA) -------------------------------
    rate_per_s: float = _INF        # sustained invocations/s per function
    burst: float = 10.0             # bucket depth (invocations)
    rate_action: str = "queue"      # "shed" | "queue"
    # -- fleet load ceiling ---------------------------------------------
    # A float, or the string "auto": derive the ceiling from the cost
    # model's predicted load->inflation curve (resolved by the Scenario
    # layer via CostModel.derive_max_load before the run starts).
    max_load: float = _INF          # admit while min node load <= this
    overload_action: str = "queue"  # "shed" | "queue" | "spill"
    queue_backoff_ms: float = 250.0  # overload retry interval
    # -- shared queue bound ---------------------------------------------
    max_queue_ms: float = 10_000.0  # total front-door wait before shed

    def __post_init__(self):
        if self.rate_action not in ("shed", "queue"):
            raise ValueError(f"bad rate_action {self.rate_action!r}")
        if self.overload_action not in ("shed", "queue", "spill"):
            raise ValueError(f"bad overload_action {self.overload_action!r}")
        if not self.rate_per_s > 0.0:
            raise ValueError(
                "rate_per_s must be positive (use max_load/shed to block "
                f"traffic outright), got {self.rate_per_s}")
        if not self.burst > 0.0:
            raise ValueError(f"burst must be positive, got {self.burst}")


class AdmissionControl:
    """Stateful front-door guard; one instance per ClusterSim run.

    ``decide(task, snaps, t, first)`` returns ``(outcome, when)``:
    outcome in {"admit", "spill", "shed", "queue"}, with ``when`` the
    dispatch instant for "queue" (>= t) and ``t`` otherwise. ``first``
    is False on a re-presentation of a queued task — its token is
    already reserved, so only the load ceiling is re-checked.
    """

    def __init__(self, config: Optional[AdmissionConfig] = None, **overrides):
        if config is None:
            config = AdmissionConfig(**overrides)
        elif overrides:
            raise TypeError("pass either a config or keyword overrides")
        if config.max_load == "auto":
            raise ValueError(
                "max_load='auto' must be resolved by a cost model — run "
                "the config through repro.run(Scenario(...)) (any "
                "cost_model resolves it) or set a numeric ceiling")
        self.cfg = config
        # GCRA per function: theoretical arrival time of the NEXT
        # conforming invocation.
        self._tat: dict[int, float] = {}
        # first arrival instant of each queued task (bounds total wait)
        self._queued_since: dict[int, float] = {}
        # tasks holding a rate token (consumed on conformance or
        # reserved for a queued retry) that has not yet been SERVED by
        # a dispatch; if such a task is later shed by the load ceiling,
        # the token is refunded — bucket capacity is never spent on
        # work that never ran
        self._rate_charged: set[int] = set()
        self.admitted = 0
        self.shed = 0
        self.shed_rate = 0       # shed by the token bucket
        self.shed_overload = 0   # shed by the load ceiling
        self.queued = 0          # queue decisions (a task may queue twice)
        self.spilled = 0
        self.shed_no_capacity = 0  # fleet vanished under a queued task
        self.shed_retry = 0       # retry budget / circuit breaker sheds
        self.queue_wait_ms = 0.0  # total front-door delay actually served

    # -- token bucket ----------------------------------------------------
    def _bucket_wait_ms(self, task, t: float) -> Optional[float]:
        """GCRA conformance test at instant ``t``. Returns 0.0 for a
        conforming invocation (token consumed), a positive wait for one
        that conforms after a delay (token RESERVED at t + wait), or
        None when it should shed (no state consumed). Consumed and
        reserved tokens are tracked per task until served, so a later
        overload shed can refund them."""
        cfg = self.cfg
        if not math.isfinite(cfg.rate_per_s):
            return 0.0
        increment = 1_000.0 / cfg.rate_per_s          # ms per token
        tau = max(0.0, (cfg.burst - 1.0)) * increment  # burst tolerance
        tat = self._tat.get(task.func_id, -_INF)
        if tat <= t + tau:                            # conforming now
            self._tat[task.func_id] = max(t, tat) + increment
            self._rate_charged.add(task.tid)
            return 0.0
        wait = tat - tau - t                          # conforms then
        if self.cfg.rate_action == "shed" or wait > cfg.max_queue_ms:
            return None
        self._tat[task.func_id] = tat + increment     # reserve the slot
        self._rate_charged.add(task.tid)
        return wait

    # -- the decision ----------------------------------------------------
    def decide(self, task, snaps, t: float,
               first: bool = True) -> tuple[str, float]:
        cfg = self.cfg
        if first:
            wait = self._bucket_wait_ms(task, t)
            if wait is None:
                self.shed += 1
                self.shed_rate += 1
                return "shed", t
            if wait > 0.0:
                self.queued += 1
                self._queued_since[task.tid] = t
                return "queue", t + wait
        if math.isfinite(cfg.max_load) and snaps:
            lo = min(s["load"] for s in snaps)
            if lo > cfg.max_load:
                if cfg.overload_action == "spill":
                    self.spilled += 1
                    self._admitted_at(task, t)
                    return "spill", t
                since = self._queued_since.get(task.tid, t)
                waited = t - since
                if cfg.overload_action == "shed" or \
                        waited + cfg.queue_backoff_ms > cfg.max_queue_ms:
                    self.shed += 1
                    self.shed_overload += 1
                    self._queued_since.pop(task.tid, None)
                    self._refund_token(task)
                    return "shed", t
                self.queued += 1
                self._queued_since.setdefault(task.tid, t)
                return "queue", t + cfg.queue_backoff_ms
        self._admitted_at(task, t)
        return "admit", t

    def on_external_shed(self, task) -> None:
        """The fleet loop shed this task outside a decide() call (e.g.
        chaos emptied the fleet): keep the books consistent — count it,
        close its queue-wait record, and refund its rate token so
        capacity is never left spent on work that never ran."""
        self.shed += 1
        self.shed_no_capacity += 1
        self._queued_since.pop(task.tid, None)
        self._refund_token(task)

    def on_retry_shed(self, task) -> None:
        """A chaos-lost invocation ran out of retry budget (or its
        function's circuit breaker is open): the retry layer sheds it
        through THIS front door so the admission books stay the single
        source of shed accounting. The task was admitted and served
        once, so there is no token to refund — the count is the point."""
        self.shed += 1
        self.shed_retry += 1
        self._queued_since.pop(task.tid, None)
        self._refund_token(task)

    def _refund_token(self, task) -> None:
        """A task shed before dispatch gives its rate token (consumed
        or reserved) back: the work never ran, so later invocations of
        the function must not be throttled by it."""
        if task.tid in self._rate_charged:
            self._rate_charged.discard(task.tid)
            self._tat[task.func_id] -= 1_000.0 / self.cfg.rate_per_s

    def _admitted_at(self, task, t: float) -> None:
        self.admitted += 1
        self._rate_charged.discard(task.tid)    # token served
        since = self._queued_since.pop(task.tid, None)
        if since is not None:
            self.queue_wait_ms += t - since

    # -- roll-up ---------------------------------------------------------
    def stats(self) -> dict:
        return {
            "admitted": self.admitted,
            "shed": self.shed,
            "shed_rate": self.shed_rate,
            "shed_overload": self.shed_overload,
            "queued": self.queued,
            "spilled": self.spilled,
            "shed_no_capacity": self.shed_no_capacity,
            "shed_retry": self.shed_retry,
            "queue_wait_ms": self.queue_wait_ms,
        }


def make_admission(admission) -> Optional[AdmissionControl]:
    """Coerce None | kwargs dict | AdmissionConfig | AdmissionControl
    (ClusterSim / Scenario)."""
    if admission is None or isinstance(admission, AdmissionControl):
        return admission
    if isinstance(admission, dict):
        admission = AdmissionConfig(**admission)
    if isinstance(admission, AdmissionConfig):
        return AdmissionControl(admission)
    raise TypeError(f"cannot build admission control from {admission!r}")
