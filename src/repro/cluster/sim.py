"""Multi-node fleet simulation composing per-node schedulers.

``ClusterSim`` owns N ``ClusterNode`` handles, each wrapping one
single-node :class:`~repro.core.events.Scheduler` (any policy from
``core.simulate.POLICIES``; heterogeneous mixes allowed). The cluster
loop walks a merged time-ordered stream — provisioning actions, chaos
events, invocation dispatches — in (time, kind) order: before each
routing decision every node is stepped to the instant, so state-aware
dispatchers (least-loaded, join-idle-queue) observe exactly what a
heartbeat at that instant would report. After the last event the nodes
drain independently — their event streams no longer interact.

Resilience & elasticity layers (DESIGN.md Sec. 14). The chaos,
admission, and prewarm layers are off by default and bit-identical to
the plain fleet when off; the one deliberate default change is the
``cost_aware`` dispatcher, which now LEARNS its coefficient from
completion feedback (construct it with ``learn=False`` for the PR-2
fixed-constant routing).

* ``chaos=``      a :class:`~repro.cluster.chaos.ChaosSchedule` of
                  declarative kill/heal/flush_warm events applied
                  mid-run; a kill requeues the victim's in-flight work
                  through the front-end dispatcher.
* ``admission=``  an :class:`~repro.cluster.admission.AdmissionControl`
                  (or config) consulted before routing: invocations are
                  admitted, queued at the front door, spilled to the
                  least-loaded node, or shed (priced separately).
* ``prewarm=``    a :class:`~repro.cluster.prewarm.Provisioner` (or
                  plan) that places predicted warm sandboxes into node
                  pools ahead of per-minute bursts.
* learning dispatchers (``cost_aware``) receive completion feedback in
  canonical (completion, tid) order as the run advances.
"""
from __future__ import annotations

import copy
import heapq
import math
import warnings
from typing import Optional, Sequence, Union

from ..core.containers import (ContainerConfig, ContainerSpec,
                               as_container_config)
from ..core.events import Scheduler, Task
from ..core.metrics import collect
from ..core.simulate import make_scheduler
from .admission import AdmissionConfig, AdmissionControl, make_admission
from .chaos import ChaosSchedule
from .dispatch import Dispatcher, make_dispatcher
from .metrics import ClusterResult
from .prewarm import Provisioner

# Merged-stream event classes: provisioning at an instant precedes chaos
# at it, which precedes dispatches at it (a node killed at t is gone for
# a same-instant arrival; a sandbox pre-warmed at t is warm for it).
_PREWARM, _CHAOS, _DISPATCH = 0, 1, 2


class ClusterNode:
    """One host in the fleet: a scheduler plus dispatch bookkeeping."""

    def __init__(self, node_id: str, sched: Scheduler, policy: str):
        self.node_id = node_id
        self.sched = sched
        self.policy = policy
        self.assigned = 0
        # Every task ever injected here (chaos kills walk it for
        # in-flight requeue) and the completion-feedback watermark.
        self.inflight: list[Task] = []
        self.harvested = 0

    def prime(self) -> None:
        self.sched.prime([])

    def inject(self, task: Task, t: float) -> None:
        self.assigned += 1
        self.inflight.append(task)
        self.sched.inject(task, t)

    def step(self, until: float) -> None:
        self.sched.step(until)

    def drain(self) -> None:
        self.sched.drain()

    def snapshot(self) -> dict:
        return self.sched.load_snapshot()


NodeSpec = Union[str, tuple]  # "hybrid" or ("hybrid", {kwargs})


def _make_node(i: int, spec: NodeSpec, cores_per_node: int,
               node_factory=None,
               containers: Optional[ContainerConfig] = None,
               seed: int = 0) -> ClusterNode:
    if isinstance(spec, str):
        policy, kw = spec, {}
    else:
        policy, kw = spec[0], dict(spec[1])
    if containers is not None:
        # Fleet-wide container config; per-spec kwargs still win, and
        # each node's pool gets its own deterministic seed stream.
        kw.setdefault("containers", containers)
        kw.setdefault("seed", seed + i)
    if node_factory is not None:
        sched = node_factory(policy, n_cores=cores_per_node, **kw)
    else:
        sched = make_scheduler(policy, n_cores=cores_per_node, **kw)
    return ClusterNode(f"node{i}", sched, policy)


def _reset_for_retry(task: Task) -> None:
    """A chaos kill loses the victim's progress: the invocation restarts
    from scratch elsewhere. Queueing stays measured from the TRUE
    arrival; the billed execution span is the successful attempt's."""
    task.remaining = task.service
    task.cpu_time = 0.0
    task.first_run = None
    task.completion = None
    task.vruntime = 0.0
    task.cold_start = False
    task.init_ms = 0.0
    task.retries += 1


class ClusterSim:
    """Fleet of nodes behind a pluggable front-end dispatcher.

    ``node_policies`` is either one policy applied fleet-wide or a
    per-node list (heterogeneous fleets — e.g. half hybrid, half CFS).
    ``node_factory`` overrides scheduler construction for domains whose
    schedulers need extra arguments (the serving gateway's slot
    schedulers). ``containers`` attaches the sandbox lifecycle layer to
    every node: each gets its own memory-bounded warm pool, heartbeats
    report warm-set contents, and warm-aware dispatchers route on them.
    ``admission`` attaches the front-door guard (see module docstring).
    """

    def __init__(self,
                 n_nodes: int = 4,
                 cores_per_node: int = 16,
                 node_policies: Union[NodeSpec, Sequence[NodeSpec]] = "hybrid",
                 dispatcher: Union[str, Dispatcher] = "least_loaded",
                 seed: int = 0,
                 node_factory=None,
                 containers: Union[None, ContainerConfig, ContainerSpec,
                                   dict, str] = None,
                 admission: Union[None, AdmissionConfig,
                                  AdmissionControl] = None):
        if n_nodes < 1:
            raise ValueError("a fleet needs at least one node")
        # Any accepted ``containers=`` shape normalizes to a pool config
        # here, before nodes are built. Workload-driven histogram hints
        # need the task list and so cannot be derived at construction
        # time — Scenario materializes hinted configs before this point.
        containers = as_container_config(containers)
        if isinstance(node_policies, (str, tuple)):
            node_policies = [node_policies] * n_nodes
        if len(node_policies) != n_nodes:
            raise ValueError(
                f"{len(node_policies)} node policies for {n_nodes} nodes")
        self.node_factory = node_factory
        self.containers = containers
        self.seed = seed
        self.nodes = [_make_node(i, spec, cores_per_node, node_factory,
                                 containers=containers, seed=seed)
                      for i, spec in enumerate(node_policies)]
        # Monotonic id counter: node ids must stay unique across
        # add/remove churn or the affinity ring maps two nodes to the
        # same hash points.
        self._next_node_id = n_nodes
        self.cores_per_node = cores_per_node
        if isinstance(dispatcher, str):
            dispatcher = make_dispatcher(dispatcher, seed=seed)
        self.dispatcher = dispatcher
        self.dispatcher.on_topology_change(self.nodes)
        self.admission = make_admission(admission)
        # (tid, node_id): ids stay valid across add/remove churn, where
        # live-list indices shift.
        self.assignments: list[tuple[int, str]] = []
        self._retired: list[ClusterNode] = []
        self.shed: list[Task] = []          # front-door rejects
        self.chaos_log: list[dict] = []     # one record per chaos event
        self._provisioner: Optional[Provisioner] = None

    # -- elasticity --------------------------------------------------------
    def add_node(self, spec: NodeSpec = "hybrid") -> ClusterNode:
        node = _make_node(self._next_node_id, spec, self.cores_per_node,
                          self.node_factory, containers=self.containers,
                          seed=self.seed)
        self._next_node_id += 1
        node.prime()
        self.nodes.append(node)
        self.dispatcher.on_topology_change(self.nodes)
        return node

    def remove_node(self, index: int,
                    t: Optional[float] = None) -> ClusterNode:
        """Gracefully drain and decommission a node (its in-flight work
        completes and is still counted in the fleet roll-up via
        ``_retired``). ``t`` steps the node to the removal instant
        first. Decommission closes the node's warm pool at removal —
        the memory-hold meter stops, the warm set is destroyed, and the
        parked keep-alive reaper dies with the machine instead of
        leaking an open meter into later roll-ups."""
        node = self.nodes[index]
        if t is not None:
            node.step(t)
        node.drain()
        self._decommission(index, t)
        return node

    def _decommission(self, index: int, t: Optional[float]) -> None:
        """Shared tail of graceful removal and chaos kill: harvest the
        node's final completion feedback, detach it, close its warm
        pool and parked timers at ``t``, and retire its roll-up row."""
        node = self.nodes[index]
        if self.dispatcher.wants_feedback:
            self._harvest()  # its completions still teach
        self.nodes.pop(index)
        node.sched.shutdown(t)
        self._retired.append(node)
        self.dispatcher.on_topology_change(self.nodes)

    # -- chaos -------------------------------------------------------------
    def _find_node(self, node_id: Optional[str]) -> Optional[int]:
        if node_id is None:
            return 0 if self.nodes else None
        for i, n in enumerate(self.nodes):
            if n.node_id == node_id:
                return i
        return None

    def _apply_chaos(self, ev, t: float, requeue) -> None:
        rec = {"t": t, "action": ev.action, "node": ev.node,
               "requeued": 0, "warm_flushed": 0}
        if ev.action == "heal":
            spec = ev.spec if ev.spec is not None else self._heal_spec
            node = self.add_node(spec)
            node.step(t)
            rec["node"] = node.node_id
        else:
            idx = self._find_node(ev.node)
            if idx is None:
                rec["action"] += ":noop"  # target already gone
                self.chaos_log.append(rec)
                return
            node = self.nodes[idx]
            node.step(t)
            rec["node"] = node.node_id
            if ev.action == "flush_warm":
                pool = getattr(node.sched, "containers", None)
                if pool is not None:
                    rec["warm_flushed"] = pool.flush(t)
            else:  # kill: no drain — the machine is simply gone
                lost = [x for x in node.inflight
                        if x.completion is None and not x.failed]
                self._decommission(idx, t)
                for x in sorted(lost, key=lambda x: (x.arrival, x.tid)):
                    _reset_for_retry(x)
                    requeue(x, t)
                rec["requeued"] = len(lost)
        self.chaos_log.append(rec)

    # -- learning-dispatcher feedback --------------------------------------
    def _harvest(self) -> None:
        """Feed newly completed tasks to a learning dispatcher, in
        canonical (completion, tid) order so the feedback stream never
        depends on node iteration order."""
        batch: list[Task] = []
        for node in self.nodes:
            done = node.sched.completed
            if len(done) > node.harvested:
                batch.extend(done[node.harvested:])
                node.harvested = len(done)
        if batch:
            batch.sort(key=lambda x: (x.completion, x.tid))
            for task in batch:
                self.dispatcher.observe_completion(task)

    # -- simulation --------------------------------------------------------
    def run(self, workload: list[Task], *,
            fresh_tasks: bool = True,
            chaos: Optional[ChaosSchedule] = None,
            prewarm: Union[None, Provisioner, Sequence] = None,
            ) -> ClusterResult:
        tasks = copy.deepcopy(workload) if fresh_tasks else workload
        tasks = sorted(tasks, key=lambda x: (x.arrival, x.tid))
        if prewarm is not None and not isinstance(prewarm, Provisioner):
            prewarm = Provisioner(prewarm)
        if prewarm is not None and prewarm.rows_applied:
            # A consumed cursor would silently provision NOTHING and
            # report the previous run's stats as this run's.
            raise ValueError("Provisioner already consumed by a previous "
                             "run; build a fresh one per run")
        self._provisioner = prewarm
        # Heal events without an explicit spec bring up the schedule's
        # default node policy.
        self._heal_spec = chaos.heal_spec if chaos is not None else "hybrid"
        for node in self.nodes:
            node.prime()

        # Merged stream: (t, class, seq, payload, first). ``first`` is
        # False when an admission-queued task is re-presented (its rate
        # token is already reserved) and None for a chaos-requeued task
        # (already admitted once — the fleet owes it execution, so it
        # bypasses admission entirely on retry).
        stream: list = []
        seq = 0
        for task in tasks:
            stream.append((task.arrival, _DISPATCH, seq, task, True))
            seq += 1
        if chaos is not None:
            for ev in chaos:
                stream.append((ev.t, _CHAOS, seq, ev, True))
                seq += 1
        if prewarm is not None:
            # Rows are applied in bulk by apply_due; one stream entry
            # per distinct provisioning instant keeps the heap small.
            for t_prov in sorted({row[0] for row in prewarm.plan}):
                stream.append((t_prov, _PREWARM, seq, None, True))
                seq += 1
        heapq.heapify(stream)

        feedback = self.dispatcher.wants_feedback

        def requeue(task: Task, t: float) -> None:
            nonlocal seq
            heapq.heappush(stream, (t, _DISPATCH, seq, task, None))
            seq += 1

        while stream:
            t, klass, _, payload, first = heapq.heappop(stream)
            if klass == _PREWARM:
                # Bring every node to the provisioning instant FIRST:
                # pool op timestamps stay monotone and no pending event
                # before t can warm-hit a sandbox that does not exist
                # yet at its own instant.
                for node in self.nodes:
                    node.step(t)
                prewarm.apply_due(t, self.nodes, self.dispatcher)
                continue
            if klass == _CHAOS:
                self._apply_chaos(payload, t, requeue)
                continue
            task = payload
            t = max(t, task.arrival)
            if not self.nodes:
                # Chaos emptied the fleet: nothing can serve this. The
                # admission books must still balance (refund any rate
                # token the task holds, count the shed).
                task.failed = True
                self.shed.append(task)
                if self.admission is not None:
                    self.admission.on_external_shed(task)
                continue
            for node in self.nodes:
                node.step(t)
            if feedback:
                self._harvest()
            forced = None
            if self.admission is not None and first is not None:
                need_load = math.isfinite(self.admission.cfg.max_load)
                # The guard needs only occupancy, not the full
                # heartbeat (the warm-set live_view is the expensive
                # part) — and the dispatcher takes its own snapshots.
                loads = [{"load": (n.sched.n_running() + n.sched.n_queued())
                          / n.sched.n_cores} for n in self.nodes] \
                    if need_load else []
                outcome, when = self.admission.decide(task, loads, t,
                                                      first=first)
                if outcome == "shed":
                    task.failed = True
                    self.shed.append(task)
                    continue
                if outcome == "queue":
                    heapq.heappush(stream,
                                   (when, _DISPATCH, seq, task, False))
                    seq += 1
                    continue
                if outcome == "spill":
                    forced = min(range(len(self.nodes)),
                                 key=lambda i: (loads[i]["load"], i))
            i = forced if forced is not None else \
                self.dispatcher.select(task, self.nodes, t)
            self.assignments.append((task.tid, self.nodes[i].node_id))
            self.nodes[i].inject(task, t)

        for node in self.nodes:
            node.drain()
        if feedback:
            self._harvest()
        return self.result()

    def result(self) -> ClusterResult:
        everything = self.nodes + getattr(self, "_retired", [])
        per_node = [collect(n.sched, n.policy) for n in everything]
        return ClusterResult(
            node_results=per_node,
            node_ids=[n.node_id for n in everything],
            node_policies=[n.policy for n in everything],
            dispatcher=self.dispatcher.name,
            cores_per_node=self.cores_per_node,
            assignments=list(self.assignments),
            n_retired=len(getattr(self, "_retired", [])),
            shed=list(self.shed),
            chaos_events=list(self.chaos_log),
            admission=self.admission.stats() if self.admission else None,
            prewarm_stats=(self._provisioner.stats()
                           if self._provisioner else None),
        )


def run_cluster(workload: list[Task], *,
                n_nodes: int = 4,
                cores_per_node: int = 16,
                node_policy: Union[NodeSpec, Sequence[NodeSpec]] = "hybrid",
                dispatcher: str = "least_loaded",
                seed: int = 0,
                node_factory=None,
                containers: Union[None, ContainerConfig, ContainerSpec,
                                  dict, str] = None,
                admission: Union[None, AdmissionConfig,
                                 AdmissionControl] = None,
                chaos: Optional[ChaosSchedule] = None,
                prewarm: Union[None, Provisioner, Sequence] = None,
                ) -> ClusterResult:
    """Deprecated: build a :class:`repro.Scenario` with a fleet spec
    and call ``repro.run``. This shim routes through exactly that path
    (results stay bit-identical to the Scenario API)."""
    warnings.warn(
        "run_cluster() is deprecated; use repro.run(Scenario(fleet="
        "FleetSpec(n_nodes=..., dispatcher=...), ...)) instead",
        DeprecationWarning, stacklevel=2)
    from ..scenario import (FleetSpec, PolicySpec, ResilienceSpec,
                            Scenario, WorkloadSpec, run)
    nodes = None
    if isinstance(node_policy, str):
        policy = PolicySpec(name=node_policy)
    elif isinstance(node_policy, tuple):
        policy = PolicySpec(name=node_policy[0],
                            kw=dict(node_policy[1]))
    else:  # heterogeneous per-node list
        nodes = tuple(node_policy)
        first = nodes[0]
        policy = PolicySpec(name=first if isinstance(first, str)
                            else first[0])
    sc = Scenario(
        workload=WorkloadSpec(kind="tasks", tasks=workload),
        fleet=FleetSpec(n_nodes=n_nodes, cores_per_node=cores_per_node,
                        dispatcher=dispatcher, containers=containers,
                        seed=seed, nodes=nodes,
                        node_factory=node_factory),
        policy=policy,
        resilience=ResilienceSpec(chaos=chaos, admission=admission,
                                  prewarm=prewarm))
    return run(sc).raw
