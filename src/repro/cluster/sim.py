"""Multi-node fleet simulation composing per-node schedulers.

``ClusterSim`` owns N ``ClusterNode`` handles, each wrapping one
single-node :class:`~repro.core.events.Scheduler` (any policy from
``core.simulate.POLICIES``; heterogeneous mixes allowed). The cluster
loop walks the workload in arrival order: before each routing decision
every node is stepped to the invocation's arrival time, so state-aware
dispatchers (least-loaded, join-idle-queue) observe exactly what a
heartbeat at that instant would report. After the last arrival the
nodes drain independently — their event streams no longer interact.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Optional, Sequence, Union

from ..core.containers import ContainerConfig
from ..core.events import Scheduler, Task
from ..core.metrics import collect
from ..core.simulate import make_scheduler
from .dispatch import Dispatcher, make_dispatcher
from .metrics import ClusterResult


class ClusterNode:
    """One host in the fleet: a scheduler plus dispatch bookkeeping."""

    def __init__(self, node_id: str, sched: Scheduler, policy: str):
        self.node_id = node_id
        self.sched = sched
        self.policy = policy
        self.assigned = 0

    def prime(self) -> None:
        self.sched.prime([])

    def inject(self, task: Task, t: float) -> None:
        self.assigned += 1
        self.sched.inject(task, t)

    def step(self, until: float) -> None:
        self.sched.step(until)

    def drain(self) -> None:
        self.sched.drain()

    def snapshot(self) -> dict:
        return self.sched.load_snapshot()


NodeSpec = Union[str, tuple]  # "hybrid" or ("hybrid", {kwargs})


def _make_node(i: int, spec: NodeSpec, cores_per_node: int,
               node_factory=None,
               containers: Optional[ContainerConfig] = None,
               seed: int = 0) -> ClusterNode:
    if isinstance(spec, str):
        policy, kw = spec, {}
    else:
        policy, kw = spec[0], dict(spec[1])
    if containers is not None:
        # Fleet-wide container config; per-spec kwargs still win, and
        # each node's pool gets its own deterministic seed stream.
        kw.setdefault("containers", containers)
        kw.setdefault("seed", seed + i)
    if node_factory is not None:
        sched = node_factory(policy, n_cores=cores_per_node, **kw)
    else:
        sched = make_scheduler(policy, n_cores=cores_per_node, **kw)
    return ClusterNode(f"node{i}", sched, policy)


class ClusterSim:
    """Fleet of nodes behind a pluggable front-end dispatcher.

    ``node_policies`` is either one policy applied fleet-wide or a
    per-node list (heterogeneous fleets — e.g. half hybrid, half CFS).
    ``node_factory`` overrides scheduler construction for domains whose
    schedulers need extra arguments (the serving gateway's slot
    schedulers). ``containers`` attaches the sandbox lifecycle layer to
    every node: each gets its own memory-bounded warm pool, heartbeats
    report warm-set contents, and warm-aware dispatchers route on them.
    """

    def __init__(self,
                 n_nodes: int = 4,
                 cores_per_node: int = 16,
                 node_policies: Union[NodeSpec, Sequence[NodeSpec]] = "hybrid",
                 dispatcher: Union[str, Dispatcher] = "least_loaded",
                 seed: int = 0,
                 node_factory=None,
                 containers: Optional[ContainerConfig] = None):
        if n_nodes < 1:
            raise ValueError("a fleet needs at least one node")
        if isinstance(node_policies, (str, tuple)):
            node_policies = [node_policies] * n_nodes
        if len(node_policies) != n_nodes:
            raise ValueError(
                f"{len(node_policies)} node policies for {n_nodes} nodes")
        self.node_factory = node_factory
        self.containers = containers
        self.seed = seed
        self.nodes = [_make_node(i, spec, cores_per_node, node_factory,
                                 containers=containers, seed=seed)
                      for i, spec in enumerate(node_policies)]
        # Monotonic id counter: node ids must stay unique across
        # add/remove churn or the affinity ring maps two nodes to the
        # same hash points.
        self._next_node_id = n_nodes
        self.cores_per_node = cores_per_node
        if isinstance(dispatcher, str):
            dispatcher = make_dispatcher(dispatcher, seed=seed)
        self.dispatcher = dispatcher
        self.dispatcher.on_topology_change(self.nodes)
        # (tid, node_id): ids stay valid across add/remove churn, where
        # live-list indices shift.
        self.assignments: list[tuple[int, str]] = []
        self._retired: list[ClusterNode] = []

    # -- elasticity --------------------------------------------------------
    def add_node(self, spec: NodeSpec = "hybrid") -> ClusterNode:
        node = _make_node(self._next_node_id, spec, self.cores_per_node,
                          self.node_factory, containers=self.containers,
                          seed=self.seed)
        self._next_node_id += 1
        node.prime()
        self.nodes.append(node)
        self.dispatcher.on_topology_change(self.nodes)
        return node

    def remove_node(self, index: int) -> ClusterNode:
        """Drain and detach a node (its in-flight work completes and is
        still counted in the fleet roll-up via ``_retired``)."""
        node = self.nodes.pop(index)
        node.drain()
        self._retired.append(node)
        self.dispatcher.on_topology_change(self.nodes)
        return node

    # -- simulation --------------------------------------------------------
    def run(self, workload: list[Task], *,
            fresh_tasks: bool = True) -> ClusterResult:
        tasks = copy.deepcopy(workload) if fresh_tasks else workload
        tasks = sorted(tasks, key=lambda x: (x.arrival, x.tid))
        for node in self.nodes:
            node.prime()
        for task in tasks:
            t = task.arrival
            for node in self.nodes:
                node.step(t)
            i = self.dispatcher.select(task, self.nodes, t)
            self.assignments.append((task.tid, self.nodes[i].node_id))
            self.nodes[i].inject(task, t)
        for node in self.nodes:
            node.drain()
        return self.result()

    def result(self) -> ClusterResult:
        everything = self.nodes + getattr(self, "_retired", [])
        per_node = [collect(n.sched, n.policy) for n in everything]
        return ClusterResult(
            node_results=per_node,
            node_ids=[n.node_id for n in everything],
            node_policies=[n.policy for n in everything],
            dispatcher=self.dispatcher.name,
            cores_per_node=self.cores_per_node,
            assignments=list(self.assignments),
            n_retired=len(getattr(self, "_retired", [])),
        )


def run_cluster(workload: list[Task], *,
                n_nodes: int = 4,
                cores_per_node: int = 16,
                node_policy: Union[NodeSpec, Sequence[NodeSpec]] = "hybrid",
                dispatcher: str = "least_loaded",
                seed: int = 0,
                node_factory=None,
                containers: Optional[ContainerConfig] = None) -> ClusterResult:
    """One-call analogue of ``core.simulate.run_policy`` for fleets."""
    sim = ClusterSim(n_nodes=n_nodes, cores_per_node=cores_per_node,
                     node_policies=node_policy, dispatcher=dispatcher,
                     seed=seed, node_factory=node_factory,
                     containers=containers)
    return sim.run(workload)
