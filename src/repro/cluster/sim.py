"""Multi-node fleet simulation composing per-node schedulers.

``ClusterSim`` owns N ``ClusterNode`` handles, each wrapping one
single-node :class:`~repro.core.events.Scheduler` (any policy from
``core.simulate.POLICIES``; heterogeneous mixes allowed). The cluster
loop walks a merged time-ordered stream — provisioning actions, chaos
events, invocation dispatches — in (time, kind) order: before each
routing decision every node is stepped to the instant, so state-aware
dispatchers (least-loaded, join-idle-queue) observe exactly what a
heartbeat at that instant would report. After the last event the nodes
drain independently — their event streams no longer interact.

Resilience & elasticity layers (DESIGN.md Sec. 14). The chaos,
admission, and prewarm layers are off by default and bit-identical to
the plain fleet when off; the one deliberate default change is the
``cost_aware`` dispatcher, which now LEARNS its coefficient from
completion feedback (construct it with ``learn=False`` for the PR-2
fixed-constant routing).

* ``chaos=``      a :class:`~repro.cluster.chaos.ChaosSchedule` of
                  declarative kill/heal/flush_warm events applied
                  mid-run; a kill requeues the victim's in-flight work
                  through the front-end dispatcher.
* ``admission=``  an :class:`~repro.cluster.admission.AdmissionControl`
                  (or config) consulted before routing: invocations are
                  admitted, queued at the front door, spilled to the
                  least-loaded node, or shed (priced separately).
* ``prewarm=``    a :class:`~repro.cluster.prewarm.Provisioner` (or
                  plan) that places predicted warm sandboxes into node
                  pools ahead of per-minute bursts.
* learning dispatchers (``cost_aware``) receive completion feedback in
  canonical (completion, tid) order as the run advances.

Failure-domain topology (DESIGN.md Sec. 17). ``topology=`` attaches a
:class:`~repro.cluster.topology.TopologySpec`: nodes carry zone/rack/
SKU labels, correlated chaos actions (``kill_zone`` / ``kill_rack`` /
``revoke_spot`` / ``degrade`` / ``restore``) target whole failure
domains, dispatch outside an invocation's home zone pays the priced
``cross_zone_ms`` latency penalty, and per-node SKU price multipliers
flow into the fleet bill. ``run(retry=...)`` routes chaos-lost work
through a :class:`~repro.cluster.retry.RetryPolicy` (capped exponential
backoff with deterministic jitter, retry budget, per-function circuit
breaker shedding through the admission books) instead of the default
instant requeue. With a per-function concurrency cap configured
(``ContainerSpec(max_concurrency=...)``), the dispatch path routes
through the pool slot API: over-cap dispatches wait at the node and are
injected when a slot frees — the cap shapes simulated traffic.
"""
from __future__ import annotations

import copy
import dataclasses
import heapq
import math
import warnings
from typing import Optional, Sequence, Union

from ..core.containers import (ContainerConfig, ContainerSpec,
                               as_container_config)
from ..core.events import Scheduler, Task
from ..core.metrics import collect
from ..core.simulate import make_scheduler
from .admission import AdmissionConfig, AdmissionControl, make_admission
from .chaos import TOPOLOGY_ACTIONS, ChaosSchedule
from .dispatch import Dispatcher, make_dispatcher
from .metrics import ClusterResult
from .prewarm import Provisioner
from .retry import RetryPolicy, RetryState, make_retry
from .topology import NodePlacement, SlowdownDial, TopologySpec

# Merged-stream event classes: provisioning at an instant precedes chaos
# at it, which precedes dispatches at it (a node killed at t is gone for
# a same-instant arrival; a sandbox pre-warmed at t is warm for it).
_PREWARM, _CHAOS, _DISPATCH = 0, 1, 2


class ClusterNode:
    """One host in the fleet: a scheduler plus dispatch bookkeeping."""

    def __init__(self, node_id: str, sched: Scheduler, policy: str,
                 place: Optional[NodePlacement] = None):
        self.node_id = node_id
        self.sched = sched
        self.policy = policy
        self.assigned = 0
        # Every task ever injected here (chaos kills walk it for
        # in-flight requeue) and the completion-feedback watermark.
        self.inflight: list[Task] = []
        self.harvested = 0
        # Failure-domain labels (None on flat fleets): zone/rack are
        # the correlated-chaos targets, the SKU carries clock/price/
        # cold-profile/spot, and price_mult is the EFFECTIVE billed-$
        # multiplier (spot discount folded in) the roll-up applies.
        self.zone = place.zone if place is not None else None
        self.rack = place.rack if place is not None else None
        self.sku = place.sku if place is not None else None
        self.spot = place.sku.spot if place is not None else False
        self.price_mult = (place.sku.effective_price_mult
                           if place is not None else 1.0)
        # Slow-not-dead state: the interference dial (set for non-unit
        # SKU clocks and by chaos ``degrade``) and the open degrade
        # interval start (degraded-time accounting).
        self.dial: Optional[SlowdownDial] = None
        self.degrade_since: Optional[float] = None
        # Per-function concurrency-cap bookkeeping (pool slot API):
        # dispatches waiting for a slot (tid -> (task, earliest inject
        # instant)), running slot holders (tid -> (func_id, mem_mb)),
        # and the completed-list watermark the release scan resumes at.
        self.slot_waiters: dict[int, tuple[Task, float]] = {}
        self.slot_holders: dict[int, tuple[int, float]] = {}
        self.slot_harvested = 0

    def prime(self) -> None:
        self.sched.prime([])

    def inject(self, task: Task, t: float) -> None:
        self.assigned += 1
        self.inflight.append(task)
        self.sched.inject(task, t)

    def step(self, until: float) -> None:
        self.sched.step(until)

    def drain(self) -> None:
        self.sched.drain()

    def snapshot(self) -> dict:
        return self.sched.load_snapshot()


NodeSpec = Union[str, tuple]  # "hybrid" or ("hybrid", {kwargs})


def _make_node(i: int, spec: NodeSpec, cores_per_node: int,
               node_factory=None,
               containers: Optional[ContainerConfig] = None,
               seed: int = 0,
               place: Optional[NodePlacement] = None) -> ClusterNode:
    if isinstance(spec, str):
        policy, kw = spec, {}
    else:
        policy, kw = spec[0], dict(spec[1])
    if containers is not None:
        # Fleet-wide container config; per-spec kwargs still win, and
        # each node's pool gets its own deterministic seed stream. A
        # placed node's SKU may override the cold-start profile (a
        # newer machine generation boots sandboxes faster).
        if place is not None:
            over = {k: v for k, v in
                    (("cold_base_ms", place.sku.cold_base_ms),
                     ("cold_per_gb_ms", place.sku.cold_per_gb_ms))
                    if v is not None}
            if over:
                containers = dataclasses.replace(containers, **over)
        kw.setdefault("containers", containers)
        kw.setdefault("seed", seed + i)
    if node_factory is not None:
        sched = node_factory(policy, n_cores=cores_per_node, **kw)
    else:
        sched = make_scheduler(policy, n_cores=cores_per_node, **kw)
    node = ClusterNode(f"node{i}", sched, policy, place=place)
    if place is not None and place.sku.clock != 1.0:
        # Non-unit SKU clock rides the interference channel: attached
        # post-construction so ANY node factory (serving slot
        # schedulers included) gets the same treatment.
        node.dial = SlowdownDial(clock=place.sku.clock)
        sched.set_interference(node.dial)
    return node


def _reset_for_retry(task: Task) -> None:
    """A chaos kill loses the victim's progress: the invocation restarts
    from scratch elsewhere. Queueing stays measured from the TRUE
    arrival; the billed execution span is the successful attempt's."""
    task.remaining = task.service
    task.cpu_time = 0.0
    task.first_run = None
    task.completion = None
    task.vruntime = 0.0
    task.cold_start = False
    task.init_ms = 0.0
    task.retries += 1


class ClusterSim:
    """Fleet of nodes behind a pluggable front-end dispatcher.

    ``node_policies`` is either one policy applied fleet-wide or a
    per-node list (heterogeneous fleets — e.g. half hybrid, half CFS).
    ``node_factory`` overrides scheduler construction for domains whose
    schedulers need extra arguments (the serving gateway's slot
    schedulers). ``containers`` attaches the sandbox lifecycle layer to
    every node: each gets its own memory-bounded warm pool, heartbeats
    report warm-set contents, and warm-aware dispatchers route on them.
    ``admission`` attaches the front-door guard (see module docstring).
    """

    def __init__(self,
                 n_nodes: int = 4,
                 cores_per_node: int = 16,
                 node_policies: Union[NodeSpec, Sequence[NodeSpec]] = "hybrid",
                 dispatcher: Union[str, Dispatcher] = "least_loaded",
                 seed: int = 0,
                 node_factory=None,
                 containers: Union[None, ContainerConfig, ContainerSpec,
                                   dict, str] = None,
                 admission: Union[None, AdmissionConfig,
                                  AdmissionControl] = None,
                 topology: Optional[TopologySpec] = None):
        # A topology IS the fleet shape: it decides the node count and
        # every node's zone/rack/SKU placement.
        if topology is not None:
            n_nodes = topology.n_nodes
        if n_nodes < 1:
            raise ValueError("a fleet needs at least one node")
        # Any accepted ``containers=`` shape normalizes to a pool config
        # here, before nodes are built. Workload-driven histogram hints
        # need the task list and so cannot be derived at construction
        # time — Scenario materializes hinted configs before this point.
        containers = as_container_config(containers)
        if isinstance(node_policies, (str, tuple)):
            node_policies = [node_policies] * n_nodes
        if len(node_policies) != n_nodes:
            raise ValueError(
                f"{len(node_policies)} node policies for {n_nodes} nodes")
        self.node_factory = node_factory
        self.containers = containers
        self.seed = seed
        self.topology = topology
        places = topology.placement() if topology is not None \
            else [None] * n_nodes
        self.nodes = [_make_node(i, spec, cores_per_node, node_factory,
                                 containers=containers, seed=seed,
                                 place=places[i])
                      for i, spec in enumerate(node_policies)]
        # Monotonic id counter: node ids must stay unique across
        # add/remove churn or the affinity ring maps two nodes to the
        # same hash points.
        self._next_node_id = n_nodes
        self.cores_per_node = cores_per_node
        if isinstance(dispatcher, str):
            dispatcher = make_dispatcher(dispatcher, seed=seed)
        self.dispatcher = dispatcher
        if topology is not None:
            self.dispatcher.attach_topology(topology)
        self.dispatcher.on_topology_change(self.nodes)
        self.admission = make_admission(admission)
        # (tid, node_id): ids stay valid across add/remove churn, where
        # live-list indices shift.
        self.assignments: list[tuple[int, str]] = []
        self._retired: list[ClusterNode] = []
        self.shed: list[Task] = []          # front-door rejects
        self.chaos_log: list[dict] = []     # one record per chaos event
        self._provisioner: Optional[Provisioner] = None
        self._retry: Optional[RetryState] = None
        self.cross_zone = 0                 # out-of-home-zone dispatches
        self._degraded_closed_ms = 0.0      # closed degrade intervals
        # Per-function concurrency cap (slot-routed dispatch) — None
        # keeps the historical direct-inject path, bit-identically.
        self._slot_cap = containers.max_concurrency \
            if containers is not None else None

    # -- elasticity --------------------------------------------------------
    def add_node(self, spec: NodeSpec = "hybrid") -> ClusterNode:
        place = self.topology.heal_placement() \
            if self.topology is not None else None
        node = _make_node(self._next_node_id, spec, self.cores_per_node,
                          self.node_factory, containers=self.containers,
                          seed=self.seed, place=place)
        self._next_node_id += 1
        node.prime()
        self.nodes.append(node)
        self.dispatcher.on_topology_change(self.nodes)
        return node

    def remove_node(self, index: int,
                    t: Optional[float] = None) -> ClusterNode:
        """Gracefully drain and decommission a node (its in-flight work
        completes and is still counted in the fleet roll-up via
        ``_retired``). ``t`` steps the node to the removal instant
        first. Decommission closes the node's warm pool at removal —
        the memory-hold meter stops, the warm set is destroyed, and the
        parked keep-alive reaper dies with the machine instead of
        leaking an open meter into later roll-ups. Queued slot waiters
        are granted (drain + release cycles) before decommission, so a
        graceful removal never strands a dispatch."""
        node = self.nodes[index]
        if t is not None:
            node.step(t)
        node.drain()
        guard = 0
        while node.slot_waiters:
            self._service_slots([node])
            node.drain()
            guard += 1
            if guard > len(node.inflight) + 1:
                raise RuntimeError("slot waiters cannot make progress "
                                   "on a draining node")
        self._decommission(index, t)
        return node

    def _decommission(self, index: int, t: Optional[float]) -> None:
        """Shared tail of graceful removal and chaos kill: harvest the
        node's final completion feedback, detach it, close its warm
        pool and parked timers at ``t``, close any open degrade
        interval, and retire its roll-up row."""
        node = self.nodes[index]
        if self.dispatcher.wants_feedback:
            self._harvest()  # its completions still teach
        self.nodes.pop(index)
        node.sched.shutdown(t)
        if node.degrade_since is not None:
            end = node.sched.now if t is None else max(t, node.degrade_since)
            self._degraded_closed_ms += end - node.degrade_since
            node.degrade_since = None
        self._retired.append(node)
        self.dispatcher.on_topology_change(self.nodes)

    # -- chaos -------------------------------------------------------------
    def _find_node(self, node_id: Optional[str]) -> Optional[int]:
        if node_id is None:
            return 0 if self.nodes else None
        for i, n in enumerate(self.nodes):
            if n.node_id == node_id:
                return i
        return None

    def _match_nodes(self, ev) -> list[ClusterNode]:
        """Live nodes a chaos event targets, in fleet order (the
        deterministic expansion of a correlated event)."""
        if ev.action == "kill_zone":
            return [n for n in self.nodes if n.zone == ev.zone]
        if ev.action == "kill_rack":
            return [n for n in self.nodes if n.rack == ev.rack]
        if ev.action == "revoke_spot":
            return [n for n in self.nodes if n.spot and
                    (ev.zone is None or n.zone == ev.zone)]
        # degrade / restore: zone > rack > node id > first live node.
        if ev.zone is not None:
            return [n for n in self.nodes if n.zone == ev.zone]
        if ev.rack is not None:
            return [n for n in self.nodes if n.rack == ev.rack]
        idx = self._find_node(ev.node)
        return [] if idx is None else [self.nodes[idx]]

    def _kill_nodes(self, victims: list[ClusterNode], t: float,
                    requeue, rec: dict) -> None:
        """Shared kill body, single-node and correlated: the machines
        are simply gone at ``t`` (no drain). Lost in-flight work flows
        through the retry policy (or requeues instantly without one);
        queued slot waiters never started, so they re-dispatch
        immediately with no retry penalty."""
        lost: list[Task] = []
        stranded: list[Task] = []
        for node in victims:
            node.step(t)
            lost.extend(x for x in node.inflight
                        if x.completion is None and not x.failed)
            stranded.extend(task for task, _ in node.slot_waiters.values())
            node.slot_waiters.clear()
            node.slot_holders.clear()
            self._decommission(self.nodes.index(node), t)
        for x in sorted(stranded, key=lambda x: (x.arrival, x.tid)):
            requeue(x, t)
        rec["slot_requeued"] = len(stranded)
        for x in sorted(lost, key=lambda x: (x.arrival, x.tid)):
            self._retry_or_requeue(x, t, requeue, rec)

    def _retry_or_requeue(self, task: Task, t: float, requeue,
                          rec: dict) -> None:
        """Route one chaos-lost invocation: instant requeue without a
        policy (PR 5 semantics, bit-identical), else backoff-delayed
        retry, budget- or breaker-shed through the admission books."""
        if self._retry is None:
            _reset_for_retry(task)
            requeue(task, t)
            rec["requeued"] += 1
            return
        verdict, when = self._retry.on_failure(task, t)
        if verdict == "shed":
            task.failed = True
            self.shed.append(task)
            if self.admission is not None:
                self.admission.on_retry_shed(task)
            rec["retry_shed"] = rec.get("retry_shed", 0) + 1
            return
        _reset_for_retry(task)
        requeue(task, when)
        rec["requeued"] += 1

    def _degrade(self, node: ClusterNode, t: float,
                 severity: float) -> None:
        """Slow-not-dead: steal ``severity`` of the node's clock via
        the interference dial (composes with the SKU clock). Nothing is
        requeued — everything there just runs slower."""
        if node.dial is None:
            clock = node.sku.clock if node.sku is not None else 1.0
            node.dial = SlowdownDial(clock=clock)
            node.sched.set_interference(node.dial)
        node.dial.degrade = severity
        if node.degrade_since is None:
            node.degrade_since = t

    def _restore(self, node: ClusterNode, t: float) -> None:
        if node.dial is not None:
            node.dial.degrade = 0.0
        if node.degrade_since is not None:
            self._degraded_closed_ms += t - node.degrade_since
            node.degrade_since = None

    def _apply_chaos(self, ev, t: float, requeue) -> None:
        rec = {"t": t, "action": ev.action, "node": ev.node,
               "requeued": 0, "warm_flushed": 0}
        if ev.action == "heal":
            spec = ev.spec if ev.spec is not None else self._heal_spec
            node = self.add_node(spec)
            node.step(t)
            rec["node"] = node.node_id
        elif ev.action in ("kill_zone", "kill_rack", "revoke_spot"):
            victims = self._match_nodes(ev)
            rec["nodes"] = [n.node_id for n in victims]
            if ev.action == "revoke_spot":
                rec["revoked"] = len(victims)
            if not victims:
                rec["action"] += ":noop"  # domain already empty
            else:
                self._kill_nodes(victims, t, requeue, rec)
        elif ev.action in ("degrade", "restore"):
            targets = self._match_nodes(ev)
            rec["nodes"] = [n.node_id for n in targets]
            if not targets:
                rec["action"] += ":noop"
            for node in targets:
                node.step(t)
                if ev.action == "degrade":
                    self._degrade(node, t, ev.severity)
                else:
                    self._restore(node, t)
            if ev.action == "degrade":
                rec["severity"] = ev.severity
        else:
            idx = self._find_node(ev.node)
            if idx is None:
                rec["action"] += ":noop"  # target already gone
                self.chaos_log.append(rec)
                return
            node = self.nodes[idx]
            node.step(t)
            rec["node"] = node.node_id
            if ev.action == "flush_warm":
                pool = getattr(node.sched, "containers", None)
                if pool is not None:
                    rec["warm_flushed"] = pool.flush(t)
            else:  # kill: no drain — the machine is simply gone
                self._kill_nodes([node], t, requeue, rec)
        self.chaos_log.append(rec)

    # -- per-function concurrency slots ------------------------------------
    def _dispatch_to(self, node: ClusterNode, task: Task, t: float,
                     t_inject: float) -> None:
        """Inject through the pool slot API when a per-function cap is
        configured: an over-cap dispatch parks at the node until a
        completion frees a slot (the PR 7 cap shapes simulated
        traffic). ``t`` is the routing instant (pool clock); ``t_inject``
        is the arrival at the node (>= t under a cross-zone hop)."""
        pool = getattr(node.sched, "containers", None) \
            if self._slot_cap is not None else None
        if pool is None:
            node.inject(task, t_inject)
            return
        status = pool.request_slot(task.func_id, task.mem_mb, t,
                                   tid=task.tid, claim=False)
        if status == "queued":
            node.slot_waiters[task.tid] = (task, t_inject)
            return
        node.slot_holders[task.tid] = (task.func_id, task.mem_mb)
        node.inject(task, t_inject)

    def _service_slots(self,
                       nodes: Optional[list[ClusterNode]] = None) -> None:
        """Release concurrency slots for completions past each node's
        watermark (canonical (completion, tid) order) and inject any
        waiters those releases grant. Grants are observed at heartbeat
        instants — the next front-door event, or drain boundaries in
        the tail — because the cluster loop has no clock between
        events; the engine clamps the injection to its own ``now``, so
        a waiter's queueing is still measured from true arrival."""
        if self._slot_cap is None:
            return
        for node in (self.nodes if nodes is None else nodes):
            done = node.sched.completed
            if len(done) <= node.slot_harvested:
                continue
            fresh = [x for x in done[node.slot_harvested:]
                     if x.tid in node.slot_holders]
            node.slot_harvested = len(done)
            if not fresh:
                continue
            fresh.sort(key=lambda x: (x.completion, x.tid))
            pool = getattr(node.sched, "containers", None)
            for x in fresh:
                fid, mem = node.slot_holders.pop(x.tid)
                if pool is None:
                    continue
                grants = pool.release_slot(fid, mem, x.completion,
                                           keep_warm=False, claim=False)
                for tid, _status in grants:
                    entry = node.slot_waiters.pop(tid, None)
                    if entry is None:
                        continue
                    waiter, t_inject = entry
                    node.slot_holders[waiter.tid] = (waiter.func_id,
                                                     waiter.mem_mb)
                    node.inject(waiter, max(x.completion, t_inject))

    # -- learning-dispatcher feedback --------------------------------------
    def _harvest(self) -> None:
        """Feed newly completed tasks to a learning dispatcher, in
        canonical (completion, tid) order so the feedback stream never
        depends on node iteration order."""
        batch: list[Task] = []
        for node in self.nodes:
            done = node.sched.completed
            if len(done) > node.harvested:
                batch.extend(done[node.harvested:])
                node.harvested = len(done)
        if batch:
            batch.sort(key=lambda x: (x.completion, x.tid))
            for task in batch:
                self.dispatcher.observe_completion(task)

    # -- simulation --------------------------------------------------------
    def run(self, workload: list[Task], *,
            fresh_tasks: bool = True,
            chaos: Optional[ChaosSchedule] = None,
            prewarm: Union[None, Provisioner, Sequence] = None,
            retry: Union[None, dict, RetryPolicy, RetryState] = None,
            ) -> ClusterResult:
        tasks = copy.deepcopy(workload) if fresh_tasks else workload
        tasks = sorted(tasks, key=lambda x: (x.arrival, x.tid))
        if chaos is not None and self.topology is None:
            for ev in chaos:
                if ev.action in TOPOLOGY_ACTIONS or ev.zone is not None \
                        or ev.rack is not None:
                    raise ValueError(
                        f"chaos action {ev.action!r} targets a failure "
                        "domain, but the fleet has no topology= attached")
        self._retry = make_retry(retry, seed=self.seed)
        if prewarm is not None and not isinstance(prewarm, Provisioner):
            prewarm = Provisioner(prewarm)
        if prewarm is not None and prewarm.rows_applied:
            # A consumed cursor would silently provision NOTHING and
            # report the previous run's stats as this run's.
            raise ValueError("Provisioner already consumed by a previous "
                             "run; build a fresh one per run")
        self._provisioner = prewarm
        # Heal events without an explicit spec bring up the schedule's
        # default node policy.
        self._heal_spec = chaos.heal_spec if chaos is not None else "hybrid"
        for node in self.nodes:
            node.prime()

        # Merged stream: (t, class, seq, payload, first). ``first`` is
        # False when an admission-queued task is re-presented (its rate
        # token is already reserved) and None for a chaos-requeued task
        # (already admitted once — the fleet owes it execution, so it
        # bypasses admission entirely on retry).
        stream: list = []
        seq = 0
        for task in tasks:
            stream.append((task.arrival, _DISPATCH, seq, task, True))
            seq += 1
        if chaos is not None:
            for ev in chaos:
                stream.append((ev.t, _CHAOS, seq, ev, True))
                seq += 1
        if prewarm is not None:
            # Rows are applied in bulk by apply_due; one stream entry
            # per distinct provisioning instant keeps the heap small.
            for t_prov in sorted({row[0] for row in prewarm.plan}):
                stream.append((t_prov, _PREWARM, seq, None, True))
                seq += 1
        heapq.heapify(stream)

        feedback = self.dispatcher.wants_feedback

        def requeue(task: Task, t: float) -> None:
            nonlocal seq
            heapq.heappush(stream, (t, _DISPATCH, seq, task, None))
            seq += 1

        while stream:
            t, klass, _, payload, first = heapq.heappop(stream)
            if klass == _PREWARM:
                # Bring every node to the provisioning instant FIRST:
                # pool op timestamps stay monotone and no pending event
                # before t can warm-hit a sandbox that does not exist
                # yet at its own instant.
                for node in self.nodes:
                    node.step(t)
                self._service_slots()
                prewarm.apply_due(t, self.nodes, self.dispatcher)
                continue
            if klass == _CHAOS:
                self._apply_chaos(payload, t, requeue)
                continue
            task = payload
            t = max(t, task.arrival)
            if not self.nodes:
                # Chaos emptied the fleet: nothing can serve this. The
                # admission books must still balance (refund any rate
                # token the task holds, count the shed).
                task.failed = True
                self.shed.append(task)
                if self.admission is not None:
                    self.admission.on_external_shed(task)
                continue
            for node in self.nodes:
                node.step(t)
            self._service_slots()
            if feedback:
                self._harvest()
            forced = None
            if self.admission is not None and first is not None:
                need_load = math.isfinite(self.admission.cfg.max_load)
                # The guard needs only occupancy, not the full
                # heartbeat (the warm-set live_view is the expensive
                # part) — and the dispatcher takes its own snapshots.
                loads = [{"load": (n.sched.n_running() + n.sched.n_queued())
                          / n.sched.n_cores} for n in self.nodes] \
                    if need_load else []
                outcome, when = self.admission.decide(task, loads, t,
                                                      first=first)
                if outcome == "shed":
                    task.failed = True
                    self.shed.append(task)
                    continue
                if outcome == "queue":
                    heapq.heappush(stream,
                                   (when, _DISPATCH, seq, task, False))
                    seq += 1
                    continue
                if outcome == "spill":
                    # Spill prefers the invocation's home zone: a
                    # cross-zone hop costs priced latency, so overflow
                    # only leaves the zone when it is entirely full.
                    pool_idx = range(len(self.nodes))
                    if self.topology is not None:
                        home = self.topology.home_zone(task.func_id)
                        local = [i for i in pool_idx
                                 if self.nodes[i].zone == home]
                        if local:
                            pool_idx = local
                    forced = min(pool_idx,
                                 key=lambda i: (loads[i]["load"], i))
            i = forced if forced is not None else \
                self.dispatcher.select(task, self.nodes, t)
            node = self.nodes[i]
            self.assignments.append((task.tid, node.node_id))
            t_inject = t
            if self.topology is not None and node.zone is not None \
                    and node.zone != self.topology.home_zone(task.func_id):
                self.cross_zone += 1
                t_inject = t + self.topology.cross_zone_ms
            self._dispatch_to(node, task, t, t_inject)

        for node in self.nodes:
            node.drain()
        # Slot waiters parked at nodes are granted as drained
        # completions free slots; each grant injects new work, so
        # drain/service cycles until the books are empty. A pass that
        # grants nothing while waiters remain is a wedged cap.
        if self._slot_cap is not None:
            while any(n.slot_waiters for n in self.nodes):
                before = sum(len(n.slot_waiters) for n in self.nodes)
                self._service_slots()
                for node in self.nodes:
                    node.drain()
                if sum(len(n.slot_waiters) for n in self.nodes) >= before:
                    raise RuntimeError("queued slot waiters cannot make "
                                       "progress after fleet drain")
            self._service_slots()  # final release scan empties holders
        if feedback:
            self._harvest()
        return self.result()

    def result(self) -> ClusterResult:
        everything = self.nodes + getattr(self, "_retired", [])
        per_node = [collect(n.sched, n.policy) for n in everything]
        # Degrade intervals still open at roll-up time end at each
        # node's own clock (the fleet has no later instant for them).
        degraded = self._degraded_closed_ms + sum(
            n.sched.now - n.degrade_since for n in self.nodes
            if n.degrade_since is not None)
        meta = [{"node_id": n.node_id, "zone": n.zone, "rack": n.rack,
                 "sku": n.sku.name if n.sku is not None else None,
                 "spot": n.spot, "price_mult": n.price_mult,
                 "base_price_mult": (n.sku.price_mult
                                     if n.sku is not None else 1.0),
                 "spot_discount": (n.sku.spot_discount
                                   if n.sku is not None and n.sku.spot
                                   else 0.0)}
                for n in everything]
        return ClusterResult(
            node_results=per_node,
            node_ids=[n.node_id for n in everything],
            node_policies=[n.policy for n in everything],
            dispatcher=self.dispatcher.name,
            cores_per_node=self.cores_per_node,
            assignments=list(self.assignments),
            n_retired=len(getattr(self, "_retired", [])),
            shed=list(self.shed),
            chaos_events=list(self.chaos_log),
            admission=self.admission.stats() if self.admission else None,
            prewarm_stats=(self._provisioner.stats()
                           if self._provisioner else None),
            node_meta=meta,
            cross_zone=self.cross_zone,
            retry_stats=(self._retry.stats()
                         if self._retry is not None else None),
            degraded_ms=degraded,
            dispatcher_state=(self.dispatcher.snapshot()
                              if hasattr(self.dispatcher, "snapshot")
                              else None),
        )


def run_cluster(workload: list[Task], *,
                n_nodes: int = 4,
                cores_per_node: int = 16,
                node_policy: Union[NodeSpec, Sequence[NodeSpec]] = "hybrid",
                dispatcher: str = "least_loaded",
                seed: int = 0,
                node_factory=None,
                containers: Union[None, ContainerConfig, ContainerSpec,
                                  dict, str] = None,
                admission: Union[None, AdmissionConfig,
                                 AdmissionControl] = None,
                chaos: Optional[ChaosSchedule] = None,
                prewarm: Union[None, Provisioner, Sequence] = None,
                ) -> ClusterResult:
    """Deprecated: build a :class:`repro.Scenario` with a fleet spec
    and call ``repro.run``. This shim routes through exactly that path
    (results stay bit-identical to the Scenario API)."""
    warnings.warn(
        "run_cluster() is deprecated; use repro.run(Scenario(fleet="
        "FleetSpec(n_nodes=..., dispatcher=...), ...)) instead",
        DeprecationWarning, stacklevel=2)
    from ..scenario import (FleetSpec, PolicySpec, ResilienceSpec,
                            Scenario, WorkloadSpec, run)
    nodes = None
    if isinstance(node_policy, str):
        policy = PolicySpec(name=node_policy)
    elif isinstance(node_policy, tuple):
        policy = PolicySpec(name=node_policy[0],
                            kw=dict(node_policy[1]))
    else:  # heterogeneous per-node list
        nodes = tuple(node_policy)
        first = nodes[0]
        policy = PolicySpec(name=first if isinstance(first, str)
                            else first[0])
    sc = Scenario(
        workload=WorkloadSpec(kind="tasks", tasks=workload),
        fleet=FleetSpec(n_nodes=n_nodes, cores_per_node=cores_per_node,
                        dispatcher=dispatcher, containers=containers,
                        seed=seed, nodes=nodes,
                        node_factory=node_factory),
        policy=policy,
        resilience=ResilienceSpec(chaos=chaos, admission=admission,
                                  prewarm=prewarm))
    return run(sc).raw
