"""Cluster front-end dispatch policies.

The node-level FIFO+CFS hybrid only sees the invocations the cluster
dispatcher hands it, so the routing layer bounds how much money the
per-node scheduler can save. Five policies spanning the design space of
the related work:

random          -- seeded uniform choice (the strawman baseline).
round_robin     -- cyclic assignment, oblivious to node state.
least_loaded    -- route to the node with the fewest admitted-but-
                   unfinished tasks per core (power-of-d with d = N).
join_idle_queue -- pull-based dispatch a la Hiku: nodes advertise
                   idleness; an invocation goes to the idle node that
                   has waited longest, falling back to least-loaded
                   when the idle queue is empty.
affinity        -- consistent-hash function affinity a la Kaffes et al.:
                   invocations of one function land on one node (warm
                   containers, code locality), with a virtual-node ring
                   so node add/remove only remaps ~1/N of functions.

All policies are deterministic under a fixed seed. ``select`` sees the
live node handles and the cluster clock; node state is whatever the
scheduler's ``load_snapshot`` reports at that instant.
"""
from __future__ import annotations

import bisect
import hashlib
import random
from collections import OrderedDict
from typing import TYPE_CHECKING, Sequence

if TYPE_CHECKING:  # pragma: no cover - typing only
    from .sim import ClusterNode

from ..core.events import Task


class Dispatcher:
    name = "base"

    def __init__(self, seed: int = 0):
        self.seed = seed
        self.rng = random.Random(seed)

    def select(self, task: Task, nodes: Sequence["ClusterNode"],
               t: float) -> int:
        """Return the index into ``nodes`` this task is routed to."""
        raise NotImplementedError

    def on_topology_change(self, nodes: Sequence["ClusterNode"]) -> None:
        """Called when nodes join or leave the fleet."""


class RandomDispatch(Dispatcher):
    name = "random"

    def select(self, task, nodes, t):
        return self.rng.randrange(len(nodes))


class RoundRobinDispatch(Dispatcher):
    name = "round_robin"

    def __init__(self, seed: int = 0):
        super().__init__(seed)
        self._next = 0

    def select(self, task, nodes, t):
        i = self._next % len(nodes)
        self._next += 1
        return i


class LeastLoadedDispatch(Dispatcher):
    name = "least_loaded"

    def select(self, task, nodes, t):
        return min(range(len(nodes)),
                   key=lambda i: (nodes[i].snapshot()["load"], i))


class JoinIdleQueueDispatch(Dispatcher):
    """Pull-based: an ordered set of idle node ids, longest-idle first.

    A real Hiku-style worker pulls work when it idles; in the
    simulation the equivalent information arrives with the snapshot we
    take at each dispatch decision, so the idle queue is refreshed then.
    """

    name = "join_idle_queue"

    def __init__(self, seed: int = 0):
        super().__init__(seed)
        self._idle: OrderedDict[int, None] = OrderedDict()

    def select(self, task, nodes, t):
        snaps = [n.snapshot() for n in nodes]
        for i, s in enumerate(snaps):
            if s["idle"]:
                if i not in self._idle:
                    self._idle[i] = None
            else:
                self._idle.pop(i, None)
        if self._idle:
            i, _ = self._idle.popitem(last=False)
            return i
        return min(range(len(nodes)), key=lambda i: (snaps[i]["load"], i))

    def on_topology_change(self, nodes):
        self._idle.clear()


def _hash64(key: str) -> int:
    return int.from_bytes(hashlib.blake2b(key.encode(),
                                          digest_size=8).digest(), "big")


class AffinityDispatch(Dispatcher):
    """Consistent-hash ring over (node id, virtual replica) points keyed
    by ``func_id``: the per-function-invocation affinity scheduler of
    Kaffes et al., made elastic."""

    name = "affinity"

    def __init__(self, seed: int = 0, vnodes: int = 64):
        super().__init__(seed)
        self.vnodes = vnodes
        self._ring: list[tuple[int, int]] = []  # (point, node index)
        self._points: list[int] = []

    def _build(self, nodes) -> None:
        self._ring = sorted(
            (_hash64(f"{n.node_id}:{v}:{self.seed}"), i)
            for i, n in enumerate(nodes) for v in range(self.vnodes))
        self._points = [p for p, _ in self._ring]

    def on_topology_change(self, nodes):
        self._build(nodes)

    def select(self, task, nodes, t):
        return self.owner(task.func_id, nodes)

    def owner(self, func_id: int, nodes) -> int:
        """Ring lookup without dispatching (affinity-stability tests)."""
        if len(self._ring) != len(nodes) * self.vnodes:
            self._build(nodes)
        j = bisect.bisect_right(self._points, _hash64(f"f{func_id}"))
        return self._ring[j % len(self._ring)][1]


DISPATCHERS = {
    "random": RandomDispatch,
    "round_robin": RoundRobinDispatch,
    "least_loaded": LeastLoadedDispatch,
    "join_idle_queue": JoinIdleQueueDispatch,
    "affinity": AffinityDispatch,
}


def make_dispatcher(name: str, **kw) -> Dispatcher:
    if name not in DISPATCHERS:
        raise KeyError(f"unknown dispatcher {name!r}; "
                       f"have {sorted(DISPATCHERS)}")
    return DISPATCHERS[name](**kw)
